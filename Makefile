# Maple — build / verify entry points.
#
#   make verify         fmt + clippy + tests + vet on the rust crate
#   make test           tier-1 verify exactly: build --release && test -q
#   make vet            determinism lint + lease-protocol model checker
#   make bench          all harness-less benches, release mode
#   make sweep-noc      topology × MACs design-space sweep on the wv workload
#   make sweep-format   compression-format axis sweep, pivoted on fmt
#   make sweep-sharded  2-way sharded sweep + merge, diffed vs the unsharded run
#   make chaos          fault-injection harness: coordinator + workers, one faulty
#   make explore        guided search vs the exhaustive grid + estval gate
#   make tiling         out-of-core ingest -> tiled profile, diffed vs whole-matrix
#   make artifacts      AOT-lower the Pallas kernel to HLO text (needs jax)

CARGO ?= cargo
RUST_DIR := rust

.PHONY: verify fmt clippy test vet bench sweep-noc sweep-format sweep-sharded chaos explore tiling artifacts

verify: fmt clippy test vet

# Blocking since the crate was bulk-formatted (PR 5); CI gates on it too.
fmt:
	cd $(RUST_DIR) && $(CARGO) fmt --check

clippy:
	cd $(RUST_DIR) && $(CARGO) clippy --all-targets -- -D warnings

test:
	cd $(RUST_DIR) && $(CARGO) build --release && $(CARGO) test -q

# Determinism lint over src/ plus the bounded model checker for the
# lease/ledger protocol (3 shards x 2 workers, exhaustive). Non-zero exit
# on any finding, invariant violation, or non-exhausted search.
vet:
	cd $(RUST_DIR) && $(CARGO) run --release -- vet

bench:
	cd $(RUST_DIR) && for b in fig3_energy_ops fig8_area fig9_energy fig9_speedup \
	        table1_datasets ablation_macs des_validation hotpath; do \
	    $(CARGO) bench --bench $$b; \
	done

# NoC-aware design-space sweep: topology × MACs/PE over the cached wv
# workload (warm-starts from the on-disk cache; CI runs the same grid at 1
# and 4 worker threads and asserts byte-identical output).
sweep-noc:
	cd $(RUST_DIR) && $(CARGO) run --release -- sweep --dataset wv --scale 64 \
	        --axis noc=crossbar:8,mesh:4x2 --axis macs=2,4,8,16

# Compression-format axis: re-price the wv/fb workloads under every
# operand format and pivot the cycle grid on fmt (the csr column is the
# formatless baseline; CI additionally asserts thread determinism and
# publishes BENCH_format.json from the same grid).
sweep-format:
	cd $(RUST_DIR) && $(CARGO) run --release -- sweep --dataset wv,fb --scale 64 \
	        --axis fmt=csr,csc,coo,bitmap,blocked --pivot fmt

# The CI shard-matrix logic, reproducible on a laptop: run a small grid
# 2-way sharded, merge the artifacts, and diff the merged CSV against the
# unsharded sweep — byte-identical or the target fails.
sweep-sharded:
	cd $(RUST_DIR) && rm -rf target/sweep-shards && \
	$(CARGO) run --release -- sweep --dataset wv,fb --scale 64 \
	        --axis macs=2,4 --shard 0/2 --out target/sweep-shards && \
	$(CARGO) run --release -- sweep --dataset wv,fb --scale 64 \
	        --axis macs=2,4 --shard 1/2 --out target/sweep-shards && \
	$(CARGO) run --release -- merge target/sweep-shards --csv \
	        > target/sweep-merged.csv && \
	$(CARGO) run --release -- sweep --dataset wv,fb --scale 64 \
	        --axis macs=2,4 --csv > target/sweep-unsharded.csv && \
	diff target/sweep-merged.csv target/sweep-unsharded.csv && \
	echo "sharded run == unsharded run"

# Distributed-sweep rehearsal: one coordinator + three in-process workers
# over loopback TCP, worker w0 dying mid-lease. The command itself exits
# non-zero unless the merged grid is bit-identical to the unsharded sweep
# of the same flags (survivors must steal and recompute the lost lease).
chaos:
	cd $(RUST_DIR) && $(CARGO) run --release -- chaos --dataset wv,fb --scale 64 \
	        --axis macs=2,4 --workers 3 --shards 6 --fault die --lease-ms 500

# Search-driven design-space exploration: validate the sampled profiler
# against the exact pass (estval exits non-zero outside the agreement
# band), then run the two-tier (μ+λ) search over the macs × prefetch ×
# noc × policy cube and cross-check it against the exhaustive grid argmin
# (non-zero exit if the search leaves the band; BENCH_explore.json is
# written either way).
explore:
	cd $(RUST_DIR) && $(CARGO) run --release -- estval --datasets wv,fb --budget 64 && \
	$(CARGO) run --release -- explore --datasets wv,fb --scale 64 \
	        --axis macs=1,2,3,4,6,8,12,16,24,32,48,64 \
	        --axis prefetch=1,2,3,4,6,8,12,16,24,32 \
	        --axis noc=crossbar:2,crossbar:4,crossbar:8,crossbar:16,crossbar:32,crossbar:64,mesh:2x2,mesh:4x2,mesh:4x4,mesh:8x4,mesh:8x8,mesh:16x8 \
	        --policy round-robin,chunked,greedy \
	        --budget 32 --exhaustive --bench-json ../BENCH_explore.json

# The CI out-of-core contract, laptop-sized: generate a banded matrix a
# few times larger than a small --mem-budget, stream it into a row-group
# container, profile it tile-by-tile through the partial cache, and diff
# the artifact byte-for-byte against the whole-matrix profile.
tiling:
	cd $(RUST_DIR) && rm -rf target/tiling-demo && mkdir -p target/tiling-demo && \
	$(CARGO) run --release -- ingest --gen banded:0.001:4 \
	        --rows 13000 --nnz 312000 --seed 7 --mtx-out target/tiling-demo/oc.mtx && \
	$(CARGO) run --release -- ingest target/tiling-demo/oc.mtx \
	        --out target/tiling-demo/oc.mrg --mem-budget 630000 && \
	$(CARGO) run --release -- ingest target/tiling-demo/oc.mrg --report --csv && \
	MAPLE_CACHE_DIR=target/tiling-demo/cache $(CARGO) run --release -- ingest \
	        target/tiling-demo/oc.mrg --profile-out target/tiling-demo/tiled.mwl --tile 650 && \
	$(CARGO) run --release -- ingest target/tiling-demo/oc.mtx \
	        --profile-out target/tiling-demo/whole.mwl --tile 1000000 && \
	cmp target/tiling-demo/tiled.mwl target/tiling-demo/whole.mwl && \
	echo "out-of-core profile == whole-matrix profile"

# Skips the rebuild when the artifacts are newer than the Python sources.
artifacts: artifacts/maple_pe.hlo.txt

artifacts/maple_pe.hlo.txt: $(wildcard python/compile/*.py python/compile/kernels/*.py)
	cd python && python3 -m compile.aot --out-dir ../artifacts
