# Maple — build / verify entry points.
#
#   make verify     fmt + clippy + tests on the rust crate (tier-1 + lint)
#   make test       tier-1 verify exactly: build --release && test -q
#   make bench      all harness-less benches, release mode
#   make sweep-noc  topology × MACs design-space sweep on the wv workload
#   make artifacts  AOT-lower the Pallas kernel to HLO text (needs jax)

CARGO ?= cargo
RUST_DIR := rust

.PHONY: verify fmt clippy test bench sweep-noc artifacts

verify: fmt clippy test

# Advisory until the crate is bulk-formatted: the seed predates rustfmt
# enforcement, so a drift report must not mask clippy/test failures.
fmt:
	-cd $(RUST_DIR) && $(CARGO) fmt --check

clippy:
	cd $(RUST_DIR) && $(CARGO) clippy --all-targets -- -D warnings

test:
	cd $(RUST_DIR) && $(CARGO) build --release && $(CARGO) test -q

bench:
	cd $(RUST_DIR) && for b in fig3_energy_ops fig8_area fig9_energy fig9_speedup \
	        table1_datasets ablation_macs des_validation hotpath; do \
	    $(CARGO) bench --bench $$b; \
	done

# NoC-aware design-space sweep: topology × MACs/PE over the cached wv
# workload (warm-starts from the on-disk cache; CI runs the same grid at 1
# and 4 worker threads and asserts byte-identical output).
sweep-noc:
	cd $(RUST_DIR) && $(CARGO) run --release -- sweep --dataset wv --scale 64 \
	        --axis noc=crossbar:8,mesh:4x2 --axis macs=2,4,8,16

# Skips the rebuild when the artifacts are newer than the Python sources.
artifacts: artifacts/maple_pe.hlo.txt

artifacts/maple_pe.hlo.txt: $(wildcard python/compile/*.py python/compile/kernels/*.py)
	cd python && python3 -m compile.aot --out-dir ../artifacts
