//! Numeric verification across all three layers:
//!
//! * L3 functional simulator (`MaplePe::simulate_row`) vs
//! * software Gustavson reference (`spgemm_rowwise`) vs
//! * the AOT-compiled Pallas datapath executed through PJRT
//!   (`artifacts/maple_pe.hlo.txt`, built by `make artifacts`).
//!
//! ```text
//! make artifacts && cargo run --release --features runtime --example verify_numerics
//! ```
//!
//! The PJRT layer needs the `runtime` cargo feature; without it this prints
//! a skip notice.

#[cfg(not(feature = "runtime"))]
fn main() {
    eprintln!("SKIP: verify_numerics needs the PJRT runtime; rebuild with --features runtime");
}

#[cfg(feature = "runtime")]
use maple::config::AcceleratorConfig;
#[cfg(feature = "runtime")]
use maple::gustavson::spgemm_rowwise;
#[cfg(feature = "runtime")]
use maple::pe::MaplePe;
#[cfg(feature = "runtime")]
use maple::runtime::{artifacts_dir, MapleDatapath};
#[cfg(feature = "runtime")]
use maple::sparse::gen::{generate, Profile};
#[cfg(feature = "runtime")]
use maple::trace::Counters;

#[cfg(feature = "runtime")]
fn main() {
    let a = generate(96, 96, 900, Profile::PowerLaw { alpha: 0.6 }, 42);
    let reference = spgemm_rowwise(&a, &a);
    println!(
        "workload: {}x{} matrix, {} nnz, C=A*A has {} nnz",
        a.rows(),
        a.cols(),
        a.nnz(),
        reference.nnz()
    );

    // --- L3 functional PE vs reference ---
    let pe = MaplePe::from_config(&AcceleratorConfig::extensor_maple());
    let mut counters = Counters::default();
    let mut max_err = 0f32;
    for i in 0..a.rows() {
        let (cols, vals, _) = pe.simulate_row(&a, &a, i, &mut counters);
        assert_eq!(cols.as_slice(), reference.row_cols(i), "row {i}: column set");
        for (v, r) in vals.iter().zip(reference.row_values(i)) {
            max_err = max_err.max((v - r).abs());
        }
    }
    println!("L3 functional Maple PE vs reference: {} rows, max |err| = {max_err:.2e}", a.rows());
    assert!(max_err < 1e-4);

    // --- AOT Pallas datapath vs reference ---
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let dp = match MapleDatapath::load(&client, &artifacts_dir()) {
        Ok(dp) => dp,
        Err(e) => {
            eprintln!("SKIP: compiled datapath unavailable ({e}); run `make artifacts`");
            return;
        }
    };
    let meta = dp.meta();
    println!("AOT datapath tile: kt={} nt={} (from artifacts/meta.json)", meta.kt, meta.nt);

    let mut rows_checked = 0usize;
    let mut max_err = 0f32;
    for i in 0..a.rows() {
        let out_cols = reference.row_cols(i);
        if out_cols.is_empty() {
            continue;
        }
        // Process the row in PSB windows of nt columns and ARB tiles of kt
        // A-elements — exactly the Maple segmentation (paper §III).
        let mut result = vec![0f32; out_cols.len()];
        let lo0 = out_cols[0] as usize;
        let hi = *out_cols.last().unwrap() as usize;
        let mut win = lo0;
        while win <= hi {
            for (ci, chunk) in a.row_cols(i).chunks(meta.kt).enumerate() {
                let base = a.row_ptr[i] + ci * meta.kt;
                let mut a_vals = vec![0f32; meta.kt];
                let mut b_dense = vec![0f32; meta.kt * meta.nt];
                for (lane, &k) in chunk.iter().enumerate() {
                    a_vals[lane] = a.value[base + lane];
                    for (j, bv) in a.row_iter(k as usize) {
                        let off = j as i64 - win as i64;
                        if (0..meta.nt as i64).contains(&off) {
                            b_dense[lane * meta.nt + off as usize] = bv;
                        }
                    }
                }
                let psb = dp.run_tile(&a_vals, &b_dense).expect("tile executes");
                for (slot, &c) in out_cols.iter().enumerate() {
                    let off = c as i64 - win as i64;
                    if (0..meta.nt as i64).contains(&off) {
                        result[slot] += psb[off as usize];
                    }
                }
            }
            win += meta.nt;
        }
        for (r, &want) in result.iter().zip(reference.row_values(i)) {
            max_err = max_err.max((r - want).abs());
        }
        rows_checked += 1;
        if rows_checked >= 48 {
            break; // enough coverage; each row is many PJRT executions
        }
    }
    println!("AOT Pallas datapath vs reference: {rows_checked} rows, max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "compiled datapath diverges");
    println!("OK: all three layers agree");
}
