//! Quickstart: simulate the paper's headline comparison on one dataset.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::suite;

fn main() {
    // 1. A Table-I workload (synthetic wikiVote-like; C = A × A as in §IV.A).
    let spec = suite::by_name("wikiVote").expect("dataset registered");
    let a = spec.generate(7);
    println!("dataset: {} — {}x{}, {} nnz", spec.name, a.rows(), a.cols(), a.nnz());

    // 2. Profile once (exact functional execution), reuse for both configs.
    let w = profile_workload(&a, &a);
    println!("workload: {} products -> {} output nnz", w.total_products, w.out_nnz);

    // 3. Baseline Extensor vs Maple-based Extensor (128 MACs each).
    let base = simulate_workload(&AcceleratorConfig::extensor_baseline(), &w, Policy::RoundRobin);
    let mpl = simulate_workload(&AcceleratorConfig::extensor_maple(), &w, Policy::RoundRobin);

    println!("\n{:<22} {:>14} {:>14}", "", "baseline", "maple");
    println!("{:<22} {:>14} {:>14}", "cycles", base.cycles_compute, mpl.cycles_compute);
    println!(
        "{:<22} {:>14.1} {:>14.1}",
        "energy (uJ)",
        base.energy.total_pj() / 1e6,
        mpl.energy.total_pj() / 1e6
    );
    println!(
        "\nenergy benefit: {:.1}%   speedup: {:.1}%   (paper: ~60%, ~22%)",
        mpl.energy_benefit_pct(&base),
        mpl.speedup_pct(&base)
    );
    assert_eq!(base.checksum, mpl.checksum, "both configs computed the same C");
}
