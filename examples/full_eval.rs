//! **End-to-end evaluation driver** — the full paper reproduction in one
//! binary (EXPERIMENTS.md records its output):
//!
//! 1. synthesise all fourteen Table-I workloads,
//! 2. run `C = A × A` through all four accelerator configurations via one
//!    [`SimEngine`] sweep (each dataset profiled exactly once, all
//!    56 cells concurrent),
//! 3. cross-check numerics against the software Gustavson reference; the
//!    analytic cycle model against the transaction-level DES (a
//!    `CellModel::Both` sweep, asserting the documented agreement band);
//!    and — when built `--features runtime` and `artifacts/` exist —
//!    the AOT-compiled Pallas datapath executed via PJRT (no Python at
//!    runtime),
//! 4. print Fig. 9(a)+(b) rows and the paper-style means, plus the Fig. 8
//!    area ratios and the headline abstract numbers.
//!
//! ```text
//! cargo run --release --example full_eval [scale] [--full]
//! ```
//!
//! `scale` down-scales the Table-I matrices (default 16; `--full` = 1,
//! several minutes — though a re-run warm-starts from the on-disk workload
//! cache and skips the synthesis + profile stage entirely; set
//! `MAPLE_NO_CACHE=1` to force a cold evaluation, `MAPLE_CACHE_DIR` to
//! relocate the cache).

use maple::config::AcceleratorConfig;
use maple::report::{fig9_report, fig9_rows_from_sweep, Fig9Row};
use maple::sim::{CellModel, DesignSpace, SimEngine, WorkloadKey};
use maple::sparse::suite;

/// Cross-check 2: replay a few rows of a small workload through the
/// AOT-compiled Maple datapath (Pallas kernel → HLO → PJRT) and compare
/// against the software reference. Skipped with a notice if `make artifacts`
/// has not run.
#[cfg(feature = "runtime")]
fn pjrt_crosscheck() {
    let dir = maple::runtime::artifacts_dir();
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            println!("PJRT cross-check skipped: no CPU client ({e})");
            return;
        }
    };
    let dp = match maple::runtime::MapleDatapath::load(&client, &dir) {
        Ok(dp) => dp,
        Err(e) => {
            println!("PJRT cross-check skipped: {e}");
            return;
        }
    };
    let meta = dp.meta();
    // A workload whose rows fit one (kt, nt) tile, so every row exercises
    // the compiled datapath end-to-end (wider rows are covered by
    // examples/verify_numerics.rs's multi-window driver).
    let a = maple::sparse::gen::generate(
        256,
        256,
        1200,
        maple::sparse::gen::Profile::Uniform,
        3,
    );
    let reference = maple::gustavson::spgemm_rowwise(&a, &a);

    // Drive the compiled datapath exactly like the Maple PE control logic:
    // ARB tile of A-row values, BRB expanded to a dense PSB-window tile.
    let mut rows_checked = 0;
    let mut max_err = 0f32;
    for i in 0..a.rows().min(64) {
        let cols = reference.row_cols(i);
        if cols.is_empty() || cols.len() > meta.nt || a.row_nnz(i) > meta.kt {
            continue;
        }
        let lo = cols[0];
        let mut a_vals = vec![0f32; meta.kt];
        let mut b_dense = vec![0f32; meta.kt * meta.nt];
        for (lane, (k, av)) in a.row_iter(i).enumerate() {
            a_vals[lane] = av;
            for (j, bv) in a.row_iter(k as usize) {
                let off = j as i64 - lo as i64;
                if (0..meta.nt as i64).contains(&off) {
                    b_dense[lane * meta.nt + off as usize] = bv;
                }
            }
        }
        let psb = dp.run_tile(&a_vals, &b_dense).expect("tile executes");
        for (c, v) in reference.row_iter(i) {
            let off = (c - lo) as usize;
            if off < meta.nt {
                max_err = max_err.max((psb[off] - v).abs());
            }
        }
        rows_checked += 1;
    }
    println!(
        "PJRT cross-check: {rows_checked} rows through the compiled Pallas datapath, \
         max |err| = {max_err:.2e}"
    );
    assert!(rows_checked > 0, "cross-check exercised no rows");
    assert!(max_err < 1e-3, "AOT datapath diverges from reference");
}

#[cfg(not(feature = "runtime"))]
fn pjrt_crosscheck() {
    println!("PJRT cross-check skipped: built without the `runtime` feature");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let scale: usize = if full {
        1
    } else {
        args.iter().find_map(|a| a.parse().ok()).unwrap_or(16)
    };
    let seed = 7u64;
    println!("=== Maple full evaluation (Table-I scale 1/{scale}) ===\n");

    // Shared env contract: MAPLE_CACHE_DIR / MAPLE_NO_CACHE.
    let engine = SimEngine::from_env();
    let keys: Vec<WorkloadKey> =
        suite::TABLE_I.iter().map(|d| WorkloadKey::suite(d.abbrev, seed, scale)).collect();

    let t0 = std::time::Instant::now();
    let grid = engine.sweep(&DesignSpace::paper(keys.clone())).expect("Table-I sweep");
    let elapsed = t0.elapsed();

    // Numeric cross-check 1: every config reports the same checksum/out_nnz
    // as the functional profile (they all execute the same Gustavson math).
    for (d, key) in keys.iter().enumerate() {
        let w = engine.workload(key).expect("cached workload");
        for c in 0..grid.configs.len() {
            let r = &grid.get(d, c, 0).analytic;
            assert_eq!(r.out_nnz, w.out_nnz, "{}/{}: out_nnz mismatch", key.dataset, r.config);
            assert_eq!(r.checksum, w.checksum, "{}/{}: checksum mismatch", key.dataset, r.config);
        }
    }
    assert_eq!(
        (engine.profiles_run() + engine.disk_hits()) as usize,
        keys.len(),
        "one profile or disk hit per dataset"
    );

    let matraptor: Vec<Fig9Row> = fig9_rows_from_sweep(&grid, 0, 1, 0);
    let extensor: Vec<Fig9Row> = fig9_rows_from_sweep(&grid, 2, 3, 0);
    println!("{}", fig9_report("Fig. 9 — Matraptor (Maple vs baseline)", &matraptor, true));
    println!("{}", fig9_report("Fig. 9 — Extensor (Maple vs baseline)", &extensor, true));

    // Fig. 8 headline area ratios.
    let (_, _, rm) = maple::accel::fig8(
        &AcceleratorConfig::matraptor_baseline(),
        &AcceleratorConfig::matraptor_maple(),
    );
    let (_, _, re) = maple::accel::fig8(
        &AcceleratorConfig::extensor_baseline(),
        &AcceleratorConfig::extensor_maple(),
    );
    println!(
        "Fig. 8 — area ratios: Matraptor {rm:.1}x (paper 5.9x), Extensor {re:.1}x (paper 15.5x)\n"
    );

    // Abstract headline summary.
    let mean = |rows: &[Fig9Row], f: fn(&Fig9Row) -> f64| {
        rows.iter().map(f).sum::<f64>() / rows.len() as f64
    };
    println!("=== Headline (paper abstract: 50%/60% energy, 15%/22% speedup) ===");
    println!(
        "Matraptor+Maple: {:.0}% energy benefit, {:.0}% speedup",
        mean(&matraptor, |r| r.energy_benefit_pct),
        mean(&matraptor, |r| r.speedup_pct)
    );
    println!(
        "Extensor+Maple : {:.0}% energy benefit, {:.0}% speedup",
        mean(&extensor, |r| r.energy_benefit_pct),
        mean(&extensor, |r| r.speedup_pct)
    );

    // Cross-check 3: the transaction-level DES against the analytic model
    // on the first four datasets (a `CellModel::Both` sweep — the datasets
    // are already profile-cached, so only the event simulations run).
    let crossval_keys: Vec<WorkloadKey> = keys.iter().take(4).cloned().collect();
    let xval = engine
        .sweep(&DesignSpace::paper(crossval_keys).with_cell_model(CellModel::Both))
        .expect("DES cross-validation sweep");
    println!("{}", maple::report::des_validation_report(&xval, true));
    assert!(
        xval.des_out_of_band().is_empty(),
        "DES left the documented agreement band: {:?}",
        xval.des_out_of_band()
    );

    // Verification summary across all runs.
    println!("\nverification: {} simulations, all checksums consistent", grid.cell_count());
    println!(
        "wall time: {:.1}s ({} datasets profiled once, cells in parallel; \
         {} warm-loaded from the workload cache)",
        elapsed.as_secs_f64(),
        keys.len(),
        engine.disk_hits()
    );

    pjrt_crosscheck();
}
