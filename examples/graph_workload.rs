//! Graph-analytics workload: the paper's motivating application domain
//! ("Sparse tensor algebra is used in applications such as graph
//! algorithms", §I, citing the web-Google matrix).
//!
//! `A²` of an adjacency matrix counts length-2 paths between vertex pairs —
//! the core of triangle counting and 2-hop reachability. This example runs
//! the full pipeline on a web-Google-like synthetic graph: generate, profile,
//! simulate all four accelerator configurations, and report both the graph
//! statistics and the accelerator comparison.
//!
//! ```text
//! cargo run --release --example graph_workload [scale]
//! ```

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::{stats, suite};

fn main() {
    let scale: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let spec = suite::by_name("web-Google").expect("dataset registered");
    let a = if scale <= 1 { spec.generate(7) } else { spec.generate_scaled(7, scale) };

    let s = stats::row_stats(&a);
    println!("web-Google-like graph (1/{scale} scale)");
    println!("  vertices            : {}", s.rows);
    println!("  edges               : {}", s.nnz);
    println!("  mean out-degree     : {:.2}", s.mean_row_nnz);
    println!("  max out-degree      : {}", s.max_row_nnz);
    println!("  degree stddev       : {:.2}", s.row_nnz_stddev);
    println!("  col adjacency       : {:.3}", s.adjacency_fraction);

    // 2-hop reachability: C = A × A.
    let w = profile_workload(&a, &a);
    println!("\nA x A (2-hop paths):");
    println!("  length-2 path count : {}", w.total_products);
    println!("  reachable pairs     : {}", w.out_nnz);
    println!("  accumulation factor : {:.2}", w.accumulation_factor());

    println!(
        "\n{:<22} {:>12} {:>12} {:>12} {:>10}",
        "config", "cycles", "energy(uJ)", "dram-bnd", "util(%)"
    );
    let mut results = Vec::new();
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        println!(
            "{:<22} {:>12} {:>12.1} {:>12} {:>10.1}",
            r.config,
            r.cycles_compute,
            r.energy.total_pj() / 1e6,
            r.cycles_dram_bound,
            100.0 * r.mac_utilisation(&cfg)
        );
        results.push(r);
    }
    println!(
        "\nMatraptor: energy benefit {:.1}%, speedup {:.1}%   (paper: ~50%, ~15%)",
        results[1].energy_benefit_pct(&results[0]),
        results[1].speedup_pct(&results[0])
    );
    println!(
        "Extensor : energy benefit {:.1}%, speedup {:.1}%   (paper: ~60%, ~22%)",
        results[3].energy_benefit_pct(&results[2]),
        results[3].speedup_pct(&results[2])
    );
}
