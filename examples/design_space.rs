//! Design-space exploration: "The number of MACs per PE may be determined
//! during the design phase" (paper §III). This example sweeps MACs-per-PE
//! and the PSB depth for a fixed total MAC budget, reporting cycles, energy,
//! area and MAC utilisation — the trade study a Maple adopter would run.
//!
//! ```text
//! cargo run --release --example design_space
//! ```

use maple::accel::accelerator_pe_area;
use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::suite;

fn main() {
    let spec = suite::by_name("poisson3Da").expect("dataset registered");
    let a = spec.generate_scaled(7, 2);
    let w = profile_workload(&a, &a);
    println!(
        "dataset {} (1/2 scale): {}x{}, {} nnz, {} products\n",
        spec.name,
        a.rows(),
        a.cols(),
        a.nnz(),
        w.total_products
    );

    // Fixed budget of 128 MACs, like the Extensor comparison (§IV.B.2).
    const MAC_BUDGET: usize = 128;
    println!(
        "{:>8} {:>6} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "macs/pe", "pes", "cycles", "energy(uJ)", "area(mm2)", "util(%)", "balance"
    );
    for k in [1, 2, 4, 8, 16, 32, 64] {
        let num_pes = MAC_BUDGET / k;
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.name = format!("maple-k{k}");
        cfg.pe.macs_per_pe = k;
        cfg.num_pes = num_pes;
        // Scale PE buffers with lane count: wider PEs need deeper BRB/PSB.
        cfg.pe.brb_entries = 16 * k;
        cfg.pe.psb_entries = 16 * k;
        cfg.noc = maple::noc::Topology::Mesh {
            width: num_pes.min(16),
            height: num_pes.div_ceil(num_pes.min(16)),
        };
        let r = simulate_workload(&cfg, &w, Policy::GreedyBalance);
        let area = accelerator_pe_area(&cfg).total_mm2();
        println!(
            "{:>8} {:>6} {:>10} {:>12.2} {:>10.3} {:>10.1} {:>8.3}",
            k,
            num_pes,
            r.cycles_compute,
            r.energy.total_pj() / 1e6,
            area,
            100.0 * r.mac_utilisation(&cfg),
            r.balance
        );
    }

    // PSB depth ablation at the paper's 16-MAC point: how small can the
    // accumulator array get before segmentation passes bite?
    println!("\nPSB depth ablation (16 MACs/PE, 8 PEs):");
    println!("{:>8} {:>10} {:>12} {:>14}", "psb", "cycles", "energy(uJ)", "arb re-reads");
    for psb in [32, 64, 128, 256, 512, 1024] {
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.name = format!("maple-psb{psb}");
        cfg.pe.psb_entries = psb;
        let r = simulate_workload(&cfg, &w, Policy::GreedyBalance);
        println!(
            "{:>8} {:>10} {:>12.2} {:>14}",
            psb,
            r.cycles_compute,
            r.energy.total_pj() / 1e6,
            r.counters.arb_read
        );
    }
}
