"""AOT pipeline: lower the L1 kernel and L2 model to HLO **text** artifacts.

Interchange is HLO text, NOT ``lowered.compile()`` / serialized protos:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
pinned xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. Lower via stablehlo ->
XlaComputation with ``return_tuple=True`` and unwrap with ``to_tuple1()``
on the rust side (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--kt 16] [--nt 128] [--rows 8] [--block-n 64]

Python runs ONCE at build time; `make artifacts` skips the rebuild when the
inputs are unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import maple_pe


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_kernel(kt: int, nt: int, block_n: int) -> str:
    """Lower one Maple-PE tile invocation: (kt,) x (kt, nt) -> (nt,)."""
    a = jax.ShapeDtypeStruct((kt,), jnp.float32)
    b = jax.ShapeDtypeStruct((kt, nt), jnp.float32)
    lowered = jax.jit(
        lambda av, bd: maple_pe.maple_pe(av, bd, block_n=block_n)
    ).lower(a, b)
    return to_hlo_text(lowered)


def lower_model(rows: int, kt: int, nt: int, block_n: int) -> str:
    """Lower the batched PE model: (rows, kt) x (kt, nt) -> (rows, nt)."""
    a = jax.ShapeDtypeStruct((rows, kt), jnp.float32)
    b = jax.ShapeDtypeStruct((kt, nt), jnp.float32)
    lowered = jax.jit(
        lambda ar, bd: model.maple_model(ar, bd, block_n=block_n)
    ).lower(a, b)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--kt", type=int, default=maple_pe.KT)
    ap.add_argument("--nt", type=int, default=maple_pe.NT)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--block-n", type=int, default=maple_pe.BLOCK_N)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)

    kernel_hlo = lower_kernel(args.kt, args.nt, args.block_n)
    kernel_path = os.path.join(args.out_dir, "maple_pe.hlo.txt")
    with open(kernel_path, "w") as f:
        f.write(kernel_hlo)
    print(f"wrote {len(kernel_hlo)} chars to {kernel_path}")

    model_hlo = lower_model(args.rows, args.kt, args.nt, args.block_n)
    model_path = os.path.join(args.out_dir, "model.hlo.txt")
    with open(model_path, "w") as f:
        f.write(model_hlo)
    print(f"wrote {len(model_hlo)} chars to {model_path}")

    meta = {"kt": args.kt, "nt": args.nt, "rows": args.rows}
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    print(f"wrote {meta} to {meta_path}")

    # Static perf notes for DESIGN.md §Perf (interpret=True gives no real
    # TPU timing; structure is what we can assert at build time).
    words = maple_pe.vmem_words(args.kt, args.nt, args.block_n)
    util = maple_pe.mxu_utilization_estimate(args.kt, args.block_n)
    print(
        f"VMEM working set per grid step: {words['total']} f32 words "
        f"({words}); MXU pass occupancy estimate: {util:.3f}"
    )


if __name__ == "__main__":
    main()
