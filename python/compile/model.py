"""Layer 2 — the Gustavson compute graph, calling the Layer-1 kernel.

The "model" for a sparse-accelerator paper is the dataflow itself: a batch
of A-row tiles multiplied against a shared BRB expansion — what one Maple PE
does for `rows` consecutive output rows of `C = A x B` (the coordinator's
per-PE batch, rust `coordinator::batch_rows_by_reuse`).

This module is build-time only: `aot.py` lowers [`maple_model`] to HLO text
once; the rust runtime executes the artifact via PJRT with no Python on the
request path.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import maple_pe


@functools.partial(jax.jit, static_argnames=("block_n",))
def maple_model(a_rows: jax.Array, b_dense: jax.Array, *, block_n: int = maple_pe.BLOCK_N):
    """A PE batch: `rows` A-row tiles against one shared BRB expansion.

    Args:
      a_rows: ``(rows, kt)`` f32 — ARB contents for a batch of output rows
        (zero-padded lanes).
      b_dense: ``(kt, nt)`` f32 — shared BRB expansion; batching rows that
        reference the same B rows is exactly the reuse the coordinator's
        batcher creates.

    Returns:
      ``(rows, nt)`` f32 — one PSB window per output row.
    """
    # vmap over the batch: each row is an independent Maple PE invocation;
    # XLA fuses the batch into one (rows,kt)x(kt,nt) MXU product.
    return jax.vmap(lambda a: maple_pe.maple_pe(a, b_dense, block_n=block_n))(a_rows)


def loss_fn(a_rows, b_dense, target):
    """A scalar objective over the model output, used only to exercise the
    backward pass: grads w.r.t. the ARB values flow through the Pallas
    kernel (interpret mode differentiates cleanly)."""
    out = maple_model(a_rows, b_dense)
    return jnp.sum((out - target) ** 2)


maple_model_grad = jax.jit(jax.grad(loss_fn, argnums=0))
