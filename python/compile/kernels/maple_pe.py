"""Layer 1 — the Maple PE datapath as a Pallas kernel.

The paper's PE (Fig. 6) is k scalar MACs with a scatter-addressed PSB
register file at 45 nm. A TPU has neither scalar lanes nor a
scatter-addressed register file, so the kernel re-expresses the insight —
*do as much local work per operand fetch as possible* — in TPU terms
(DESIGN.md §Hardware-Adaptation):

* ARB / BRB / PSB map to **VMEM tiles** via ``BlockSpec``; the HBM↔VMEM
  schedule plays the role of the paper's L1↔L0 staging.
* the k-lane multiply plus the per-register adder array (Eqs. 3/7/8) become
  one **MXU pass**: ``psb = a_vals @ b_dense`` where ``b_dense[k, n]`` is the
  BRB content expanded over the PSB window — the systolic array performs the
  parallel multiplies *and* the parallel accumulation in one shot.
* "MACs per PE" becomes the PSB-window block width ``block_n``, swept by
  the AOT pipeline exactly like the paper's design-phase MAC-count knob.

The kernel must run with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (aot_recipe).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile geometry: one ARB load (kt A-elements) against one PSB
# window of nt output columns, processed in block_n-wide MXU passes.
KT = 16
NT = 128
BLOCK_N = 64


def _maple_pe_block(a_ref, b_ref, o_ref):
    """One PSB block: o[n] = sum_k a[k] * b[k, n] (Eq. 3 + Eq. 7).

    ``a_ref`` is the whole ARB (kt values, VMEM-resident for every block —
    an A-element is fetched once and reused across the PSB window, the
    locality Maple's ARB exists to provide). ``b_ref`` is the BRB slice for
    this block; the dot contracts over k on the MXU.
    """
    a = a_ref[...]  # (kt,)
    b = b_ref[...]  # (kt, block_n)
    # MXU pass: parallel multiply + parallel accumulate (the adder array).
    o_ref[...] = jnp.dot(a, b, preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _maple_pe_core(a_vals: jax.Array, b_dense: jax.Array, block_n: int) -> jax.Array:
    """Differentiable core: forward runs the Pallas kernel; the VJP is the
    closed-form transpose (interpret-mode Pallas does not provide
    reverse-mode autodiff in this JAX version, and the explicit rule is
    what a production kernel would ship anyway)."""
    kt, nt = b_dense.shape
    grid = (nt // block_n,)
    return pl.pallas_call(
        _maple_pe_block,
        grid=grid,
        in_specs=[
            # ARB: replicated to every block (A-value reuse).
            pl.BlockSpec((kt,), lambda n: (0,)),
            # BRB: one PSB-window slice per block.
            pl.BlockSpec((kt, block_n), lambda n: (0, n)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda n: (n,)),
        out_shape=jax.ShapeDtypeStruct((nt,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a_vals, b_dense)


def _maple_pe_fwd(a_vals, b_dense, block_n):
    return _maple_pe_core(a_vals, b_dense, block_n), (a_vals, b_dense)


def _maple_pe_bwd(block_n, res, g):
    a_vals, b_dense = res
    # psb = a @ b  =>  d a = g @ bᵀ,  d b = a ⊗ g.
    return (g @ b_dense.T, jnp.outer(a_vals, g))


_maple_pe_core.defvjp(_maple_pe_fwd, _maple_pe_bwd)


@functools.partial(jax.jit, static_argnames=("block_n",))
def maple_pe(a_vals: jax.Array, b_dense: jax.Array, *, block_n: int = BLOCK_N) -> jax.Array:
    """PSB contents for one (A-row-tile, PSB-window) pair.

    Args:
      a_vals: ``(kt,)`` f32 — ARB lane values (zero-padded when the A row
        has fewer nonzeros; zeros contribute nothing, matching the PE
        control's ``row_ptr`` gating, Fig. 7).
      b_dense: ``(kt, nt)`` f32 — BRB content: row ``k`` holds the nonzeros
        of ``B[k',:]`` expanded over the PSB window's column range (the C/D
        expansion the rust runtime performs from CSR metadata).
      block_n: PSB columns per MXU pass (the "MACs per PE" analogue).

    Returns:
      ``(nt,)`` f32 — the PSB after accumulation (Eq. 8).
    """
    kt, nt = b_dense.shape
    if a_vals.shape != (kt,):
        raise ValueError(f"a_vals {a_vals.shape} incompatible with b_dense {b_dense.shape}")
    if nt % block_n != 0:
        raise ValueError(f"nt={nt} not a multiple of block_n={block_n}")
    return _maple_pe_core(a_vals, b_dense, block_n)


def vmem_words(kt: int = KT, nt: int = NT, block_n: int = BLOCK_N) -> dict:
    """Static VMEM footprint estimate per grid step (DESIGN.md §Perf):
    the resident working set is ARB + one BRB block + one PSB block."""
    return {
        "arb": kt,
        "brb_block": kt * block_n,
        "psb_block": block_n,
        "total": kt + kt * block_n + block_n,
    }


def mxu_utilization_estimate(kt: int = KT, block_n: int = BLOCK_N) -> float:
    """Fraction of a 128x128 MXU pass doing useful work for one block:
    a (1,kt)x(kt,block_n) product occupies kt rows and block_n columns."""
    return min(kt, 128) * min(block_n, 128) / (128.0 * 128.0)
