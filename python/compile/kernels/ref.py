"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Everything here is straight-line jnp with no Pallas, no blocking and no
cleverness: `maple_pe_ref` is literally Eqs. (3)+(7) of the paper on the
expanded tile.
"""

import jax.numpy as jnp


def maple_pe_ref(a_vals, b_dense):
    """PSB reference: psb[n] = sum_k a[k] * b[k, n]."""
    return jnp.einsum("k,kn->n", a_vals, b_dense)


def maple_batch_ref(a_rows, b_dense):
    """Batched-rows reference: out[r, n] = sum_k a[r, k] * b[k, n]."""
    return jnp.einsum("rk,kn->rn", a_rows, b_dense)


def gustavson_dense_ref(a, b):
    """Dense Gustavson reference: row-by-row accumulation of scaled B rows,
    written exactly as the paper's Eq. (1)/(2) (used to cross-check that the
    tile decomposition reconstructs full SpGEMM)."""
    m = a.shape[0]
    rows = []
    for i in range(m):
        # C[i,:] = sum_k A[i,k] * B[k,:]
        rows.append(jnp.sum(a[i][:, None] * b, axis=0))
    return jnp.stack(rows)
