"""L2 correctness: the batched PE model (vmapped kernel) and its gradient."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import maple_pe, ref

hypothesis.settings.register_profile(
    "model", deadline=None, max_examples=20, derandomize=True
)
hypothesis.settings.load_profile("model")


def test_model_matches_batch_ref():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((8, maple_pe.KT)).astype(np.float32)
    b = rng.standard_normal((maple_pe.KT, maple_pe.NT)).astype(np.float32)
    got = model.maple_model(a, b)
    want = ref.maple_batch_ref(a, b)
    assert got.shape == (8, maple_pe.NT)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@hypothesis.given(
    rows=st.sampled_from([1, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_model_rows_sweep(rows, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((rows, maple_pe.KT)).astype(np.float32)
    b = rng.standard_normal((maple_pe.KT, maple_pe.NT)).astype(np.float32)
    got = model.maple_model(a, b)
    np.testing.assert_allclose(got, ref.maple_batch_ref(a, b), rtol=1e-4, atol=1e-5)


def test_tile_decomposition_reconstructs_spgemm():
    """Tiles compose back to the full product: split a dense matmul into
    (kt, nt) windows, run each through the model, reassemble — this is the
    exact loop the rust runtime drives (examples/verify_numerics.rs)."""
    rng = np.random.default_rng(2)
    k, n, rows = 32, 256, 4
    a = rng.standard_normal((rows, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    kt, nt = maple_pe.KT, maple_pe.NT

    out = np.zeros((rows, n), np.float32)
    for k0 in range(0, k, kt):
        for n0 in range(0, n, nt):
            out[:, n0 : n0 + nt] += np.asarray(
                model.maple_model(a[:, k0 : k0 + kt], b[k0 : k0 + kt, n0 : n0 + nt])
            )
    np.testing.assert_allclose(out, ref.gustavson_dense_ref(a, b), rtol=1e-4, atol=1e-4)


def test_model_gradient_flows_through_kernel():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((8, maple_pe.KT)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((maple_pe.KT, maple_pe.NT)).astype(np.float32))
    target = jnp.zeros((8, maple_pe.NT), jnp.float32)
    g = model.maple_model_grad(a, b, target)
    want = 2.0 * (ref.maple_batch_ref(a, b) @ b.T)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_model_jits_once_and_is_pure():
    a = jnp.ones((8, maple_pe.KT), jnp.float32)
    b = jnp.ones((maple_pe.KT, maple_pe.NT), jnp.float32)
    o1 = model.maple_model(a, b)
    o2 = model.maple_model(a, b)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    # all-ones: psb[n] = kt
    np.testing.assert_allclose(np.asarray(o1), maple_pe.KT, rtol=0)
