"""L1 correctness: the Pallas Maple-PE kernel against the pure-jnp oracle.

Hypothesis sweeps tile shapes, block widths and value distributions;
every case asserts allclose against `ref.maple_pe_ref`.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import maple_pe, ref

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=40, derandomize=True
)
hypothesis.settings.load_profile("kernel")


def _rand(rng, shape, sparsity=0.0):
    x = rng.standard_normal(shape).astype(np.float32)
    if sparsity > 0.0:
        mask = rng.random(shape) < sparsity
        x = np.where(mask, 0.0, x)
    return x


def test_single_tile_matches_ref():
    rng = np.random.default_rng(0)
    a = _rand(rng, (maple_pe.KT,))
    b = _rand(rng, (maple_pe.KT, maple_pe.NT))
    got = maple_pe.maple_pe(a, b)
    want = ref.maple_pe_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_zero_padded_lanes_are_inert():
    """Zero ARB lanes (row_ptr gating, Fig. 7) must not perturb the PSB."""
    rng = np.random.default_rng(1)
    kt, nt = maple_pe.KT, maple_pe.NT
    a = _rand(rng, (kt,))
    b = _rand(rng, (kt, nt))
    a_padded = a.copy()
    a_padded[kt // 2 :] = 0.0
    got = maple_pe.maple_pe(a_padded, b)
    want = ref.maple_pe_ref(a_padded, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # And equals the half-tile contraction explicitly.
    want_half = ref.maple_pe_ref(a[: kt // 2], b[: kt // 2])
    np.testing.assert_allclose(got, want_half, rtol=1e-4, atol=1e-5)


@hypothesis.given(
    kt=st.sampled_from([4, 8, 16, 32]),
    nblocks=st.integers(min_value=1, max_value=4),
    block_n=st.sampled_from([8, 32, 64, 128]),
    sparsity=st.sampled_from([0.0, 0.5, 0.9]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_shape_sweep(kt, nblocks, block_n, sparsity, seed):
    nt = nblocks * block_n
    rng = np.random.default_rng(seed)
    a = _rand(rng, (kt,), sparsity)
    b = _rand(rng, (kt, nt), sparsity)
    got = maple_pe.maple_pe(a, b, block_n=block_n)
    want = ref.maple_pe_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@hypothesis.given(
    scale=st.sampled_from([1e-6, 1.0, 1e6]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_value_range_sweep(scale, seed):
    """Magnitude sweep: tiny and huge values stay allclose (fp32)."""
    rng = np.random.default_rng(seed)
    a = _rand(rng, (maple_pe.KT,)) * scale
    b = _rand(rng, (maple_pe.KT, maple_pe.NT))
    got = maple_pe.maple_pe(a, b)
    want = ref.maple_pe_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * scale)


def test_block_width_is_numerically_irrelevant():
    """block_n (the MACs-per-PE analogue) changes scheduling, not values."""
    rng = np.random.default_rng(3)
    a = _rand(rng, (maple_pe.KT,))
    b = _rand(rng, (maple_pe.KT, maple_pe.NT))
    outs = [
        np.asarray(maple_pe.maple_pe(a, b, block_n=w)) for w in (8, 16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)


def test_shape_validation():
    a = jnp.zeros((8,), jnp.float32)
    b = jnp.zeros((16, 128), jnp.float32)
    with pytest.raises(ValueError):
        maple_pe.maple_pe(a, b)
    with pytest.raises(ValueError):
        maple_pe.maple_pe(jnp.zeros((16,)), b, block_n=96)  # 128 % 96 != 0


def test_vmem_and_mxu_estimates_monotone():
    """Structural perf model sanity: bigger blocks = bigger working set and
    higher MXU occupancy (until the 128-lane edge)."""
    small = maple_pe.vmem_words(block_n=32)["total"]
    large = maple_pe.vmem_words(block_n=128)["total"]
    assert large > small
    assert maple_pe.mxu_utilization_estimate(16, 128) > maple_pe.mxu_utilization_estimate(16, 32)
    assert maple_pe.mxu_utilization_estimate(128, 128) == 1.0


def test_kernel_is_differentiable():
    """Interpret-mode Pallas must differentiate (the L2 backward pass)."""
    rng = np.random.default_rng(4)
    a = jnp.asarray(_rand(rng, (maple_pe.KT,)))
    b = jnp.asarray(_rand(rng, (maple_pe.KT, maple_pe.NT)))
    g = jax.grad(lambda av: jnp.sum(maple_pe.maple_pe(av, b) ** 2))(a)
    want = 2.0 * (ref.maple_pe_ref(a, b) @ b.T)
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-4, atol=1e-4)
