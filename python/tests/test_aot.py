"""AOT pipeline tests: HLO-text artifacts are produced, parse as HLO, and
stay within the version constraints the rust loader depends on."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot
from compile.kernels import maple_pe


def test_kernel_lowers_to_hlo_text():
    hlo = aot.lower_kernel(kt=16, nt=128, block_n=64)
    assert hlo.startswith("HloModule")
    # Lowered with return_tuple=True: the root must be a tuple (the rust
    # side unwraps with to_tuple1()).
    assert "f32[128]" in hlo
    assert "ROOT" in hlo and "tuple" in hlo


def test_model_lowers_to_hlo_text():
    hlo = aot.lower_model(rows=8, kt=16, nt=128, block_n=64)
    assert hlo.startswith("HloModule")
    assert "f32[8,128]" in hlo


def test_interpret_mode_leaves_no_custom_calls():
    """interpret=True must lower to plain HLO ops — a Mosaic custom-call
    would be unloadable by the CPU PJRT client (aot_recipe)."""
    hlo = aot.lower_kernel(kt=16, nt=128, block_n=64)
    assert "custom-call" not in hlo, "Mosaic custom-call leaked into the artifact"


def test_cli_writes_all_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--kt",
            "8",
            "--nt",
            "64",
            "--rows",
            "4",
            "--block-n",
            "32",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert (out / "maple_pe.hlo.txt").exists()
    assert (out / "model.hlo.txt").exists()
    meta = json.loads((out / "meta.json").read_text())
    assert meta == {"kt": 8, "nt": 64, "rows": 4}


@pytest.mark.parametrize("kt,nt,block_n", [(8, 64, 32), (16, 128, 64), (32, 256, 128)])
def test_lowering_shape_matrix(kt, nt, block_n):
    hlo = aot.lower_kernel(kt=kt, nt=nt, block_n=block_n)
    assert f"f32[{nt}]" in hlo


def test_meta_matches_kernel_defaults():
    assert maple_pe.KT == 16
    assert maple_pe.NT == 128
    assert maple_pe.NT % maple_pe.BLOCK_N == 0
