//! Bench: the L3 hot paths — profile pass throughput, full-simulation
//! throughput, functional PE datapath, reference SpGEMM, and partition
//! policies. This is the §Perf working set (EXPERIMENTS.md).
//!
//! ```text
//! cargo bench --bench hotpath
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::{partition, Policy};
use maple::gustavson::spgemm_rowwise;

fn main() {
    // Workload: wikiVote-like at half scale — large enough to be
    // representative (~1M products), small enough to iterate.
    let spec = maple::sparse::suite::by_name("wv").unwrap();
    let a = spec.generate_scaled(7, 2);
    let w = maple::sim::profile_workload(&a, &a);
    println!(
        "workload: {}x{}, {} nnz, {} products, {} out nnz\n",
        a.rows(),
        a.cols(),
        a.nnz(),
        w.total_products,
        w.out_nnz
    );

    // 1. Profile pass (exact functional execution).
    let (iters, total) = measure(std::time::Duration::from_secs(1), || {
        std::hint::black_box(maple::sim::profile_workload(&a, &a).total_products);
    });
    report_line("profile_workload", iters, total, Some((w.total_products, "products")));

    // 2. Reference SpGEMM (materialises C).
    let (iters, total) = measure(std::time::Duration::from_secs(1), || {
        std::hint::black_box(spgemm_rowwise(&a, &a).nnz());
    });
    report_line("spgemm_rowwise", iters, total, Some((w.total_products, "products")));

    // 3. Cost-model simulation per config (given a profile).
    for cfg in AcceleratorConfig::paper_configs() {
        let (iters, total) = measure(std::time::Duration::from_millis(700), || {
            std::hint::black_box(
                maple::sim::simulate_workload(&cfg, &w, Policy::RoundRobin).cycles_compute,
            );
        });
        let label = format!("simulate[{}]", cfg.name);
        report_line(&label, iters, total, Some((w.rows as u64, "rows")));
    }

    // 4. Functional Maple PE datapath (element-exact simulation).
    let pe = maple::pe::MaplePe::from_config(&AcceleratorConfig::extensor_maple());
    let (iters, total) = measure(std::time::Duration::from_secs(1), || {
        let mut c = maple::trace::Counters::default();
        let mut acc = 0u64;
        for i in 0..a.rows().min(512) {
            let (cols, _, cyc) = pe.simulate_row(&a, &a, i, &mut c);
            acc += cols.len() as u64 + cyc;
        }
        std::hint::black_box(acc);
    });
    report_line("MaplePe::simulate_row (512 rows)", iters, total, Some((512, "rows")));

    // 5. Partition policies.
    for policy in [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance] {
        let (iters, total) = measure(std::time::Duration::from_millis(400), || {
            std::hint::black_box(partition(policy, 128, &w.profiles).total_rows());
        });
        report_line(&format!("partition[{policy:?}]"), iters, total, Some((w.rows as u64, "rows")));
    }
}
