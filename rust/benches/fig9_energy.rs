//! Bench: regenerate **Fig. 9(a)** — per-dataset energy benefit (%) of the
//! Maple-based configurations over the baselines, plus the paper-style mean
//! (paper: ~50% Matraptor, ~60% Extensor).
//!
//! One [`SimEngine`] sweep: each dataset is profiled once, all
//! (config × dataset) cells run concurrently.
//!
//! ```text
//! cargo bench --bench fig9_energy
//! MAPLE_BENCH_SCALE=1 cargo bench --bench fig9_energy    # full Table-I scale
//! ```

include!("harness.rs");

use maple::report::fig9_rows_from_sweep;
use maple::sim::{DesignSpace, WorkloadKey};
use maple::sparse::suite;

fn main() {
    let scale = bench_scale();
    println!("=== Fig. 9(a) — energy benefit %, scale 1/{scale} ===\n");
    println!(
        "{:<8} {:>14} {:>14} | {:>14} {:>14}",
        "dataset", "matraptor %", "extensor %", "base uJ (mat)", "maple uJ (mat)"
    );

    let engine = bench_engine();
    let keys = suite::TABLE_I.iter().map(|d| WorkloadKey::suite(d.abbrev, 7, scale)).collect();
    let grid = engine.sweep(&DesignSpace::paper(keys)).expect("Table-I sweep");
    let m_rows = fig9_rows_from_sweep(&grid, 0, 1, 0);
    let e_rows = fig9_rows_from_sweep(&grid, 2, 3, 0);

    for (m, e) in m_rows.iter().zip(&e_rows) {
        println!(
            "{:<8} {:>14.1} {:>14.1} | {:>14.1} {:>14.1}",
            m.dataset,
            m.energy_benefit_pct,
            e.energy_benefit_pct,
            m.baseline_pj / 1e6,
            m.maple_pj / 1e6
        );
    }
    let mean_m =
        m_rows.iter().map(|m| m.energy_benefit_pct).sum::<f64>() / m_rows.len() as f64;
    let mean_e =
        e_rows.iter().map(|e| e.energy_benefit_pct).sum::<f64>() / e_rows.len() as f64;
    print!("\nmean energy benefit: Matraptor {mean_m:.1}% (paper ~50%), ");
    println!("Extensor {mean_e:.1}% (paper ~60%)");
    report_cache_line(&engine);
}
