//! Bench: regenerate **Fig. 9(a)** — per-dataset energy benefit (%) of the
//! Maple-based configurations over the baselines, plus the paper-style mean
//! (paper: ~50% Matraptor, ~60% Extensor).
//!
//! ```text
//! cargo bench --bench fig9_energy
//! MAPLE_BENCH_SCALE=1 cargo bench --bench fig9_energy    # full Table-I scale
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::report::Fig9Row;
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::suite;

fn main() {
    let scale = bench_scale();
    println!("=== Fig. 9(a) — energy benefit %, scale 1/{scale} ===\n");
    println!(
        "{:<8} {:>14} {:>14} | {:>14} {:>14}",
        "dataset", "matraptor %", "extensor %", "base uJ (mat)", "maple uJ (mat)"
    );

    let rows: Vec<(Fig9Row, Fig9Row)> = std::thread::scope(|scope| {
        let handles: Vec<_> = suite::TABLE_I
            .iter()
            .map(|spec| {
                scope.spawn(move || {
                    let a = if scale <= 1 {
                        spec.generate(7)
                    } else {
                        spec.generate_scaled(7, scale)
                    };
                    let w = profile_workload(&a, &a);
                    let run = |c: &AcceleratorConfig| simulate_workload(c, &w, Policy::RoundRobin);
                    let mb = run(&AcceleratorConfig::matraptor_baseline());
                    let mm = run(&AcceleratorConfig::matraptor_maple());
                    let eb = run(&AcceleratorConfig::extensor_baseline());
                    let em = run(&AcceleratorConfig::extensor_maple());
                    (
                        Fig9Row::from_results(spec.abbrev, &mb, &mm),
                        Fig9Row::from_results(spec.abbrev, &eb, &em),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (m, e) in &rows {
        println!(
            "{:<8} {:>14.1} {:>14.1} | {:>14.1} {:>14.1}",
            m.dataset,
            m.energy_benefit_pct,
            e.energy_benefit_pct,
            m.baseline_pj / 1e6,
            m.maple_pj / 1e6
        );
    }
    let mean_m =
        rows.iter().map(|(m, _)| m.energy_benefit_pct).sum::<f64>() / rows.len() as f64;
    let mean_e =
        rows.iter().map(|(_, e)| e.energy_benefit_pct).sum::<f64>() / rows.len() as f64;
    println!("\nmean energy benefit: Matraptor {mean_m:.1}% (paper ~50%), Extensor {mean_e:.1}% (paper ~60%)");
}
