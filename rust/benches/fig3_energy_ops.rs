//! Bench: regenerate **Fig. 3** — normalized energy cost of computation and
//! data movement at 45 nm — and measure the energy-aggregation hot path.
//!
//! ```text
//! cargo bench --bench fig3_energy_ops
//! ```

include!("harness.rs");

use maple::energy::{BufferSizes, EnergyBreakdown, TechModel};
use maple::report;
use maple::trace::Counters;

fn main() {
    println!("=== Fig. 3 (paper §III) ===\n{}", report::fig3(true));

    // Sanity: the figure's message — movement dominates arithmetic.
    let t = TechModel::tech45();
    let rows: std::collections::HashMap<_, _> = t.fig3_rows().into_iter().collect();
    println!(
        "L2<->MAC / MAC = {:.0}x   L1<->MAC / MAC = {:.1}x   PE<->MAC / MAC = {:.1}x",
        rows["L2<->MAC"], rows["L1<->MAC"], rows["PE<->MAC"]
    );

    // Aggregation throughput (the per-run energy fold).
    let c = Counters {
        mac_mul: 1 << 20,
        mac_add: 1 << 20,
        brb_read: 1 << 21,
        psb_write: 1 << 20,
        l1_read: 1 << 19,
        dram_read: 1 << 18,
        noc_flit_hops: 1 << 19,
        ..Default::default()
    };
    let sizes = BufferSizes {
        pe_buffer_bytes: 48 << 10,
        l1_bytes: 512 << 10,
        pob_bytes: 0,
        reg_bytes: 2 << 10,
    };
    let (iters, total) = measure(std::time::Duration::from_millis(300), || {
        let e = EnergyBreakdown::from_counters(&c, &t, &sizes);
        std::hint::black_box(e.total_pj());
    });
    report_line("EnergyBreakdown::from_counters", iters, total, None);
}
