// Shared micro-bench helpers for the harness-less (`harness = false`)
// benches — the offline build has no criterion (DESIGN.md §Dependencies).
// Each bench is a plain binary that prints a stable, grep-able report.
//
// Pulled into each bench via `include!("harness.rs")`.

use std::time::{Duration, Instant};

/// Benchmark scale divisor for the Table-I matrices; override with
/// `MAPLE_BENCH_SCALE=1` for full-size runs.
#[allow(dead_code)]
fn bench_scale() -> usize {
    std::env::var("MAPLE_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(24)
}

/// Engine for benches: disk-cache-backed via the shared env contract
/// (`SimEngine::from_env`), so a re-run skips the synthesis + profile stage
/// entirely — `MAPLE_CACHE_DIR` relocates the cache, `MAPLE_NO_CACHE=1`
/// opts out for cold measurements.
#[allow(dead_code)]
fn bench_engine() -> maple::sim::SimEngine {
    maple::sim::SimEngine::from_env()
}

/// One grep-able summary line of an engine's cache traffic (CI asserts on
/// the warm pass's disk-hit count).
#[allow(dead_code)]
fn report_cache_line(engine: &maple::sim::SimEngine) {
    println!(
        "cache: {} disk hits, {} profiled, {} stored ({})",
        engine.disk_hits(),
        engine.profiles_run(),
        engine.disk_stores(),
        engine
            .disk_cache()
            .map(|d| d.dir().display().to_string())
            .unwrap_or_else(|| "disabled".into()),
    );
}

/// Run `f` repeatedly for at least `min_time`, returning (iters, total).
#[allow(dead_code)]
fn measure<F: FnMut()>(min_time: Duration, mut f: F) -> (u32, Duration) {
    // Warm-up.
    f();
    let start = Instant::now();
    let mut iters = 0u32;
    while start.elapsed() < min_time {
        f();
        iters += 1;
    }
    (iters, start.elapsed())
}

/// Print one benchmark line: name, per-iteration time, optional throughput.
#[allow(dead_code)]
fn report_line(name: &str, iters: u32, total: Duration, items_per_iter: Option<(u64, &str)>) {
    let per_iter = total.as_secs_f64() / iters.max(1) as f64;
    match items_per_iter {
        Some((n, unit)) => {
            let rate = n as f64 / per_iter;
            println!(
                "{name:<44} {:>12.3} ms/iter   {:>14.0} {unit}/s",
                per_iter * 1e3,
                rate
            );
        }
        None => println!("{name:<44} {:>12.3} ms/iter", per_iter * 1e3),
    }
}
