//! Bench: ablation of the design choices DESIGN.md calls out —
//! MACs-per-PE (the paper's central design knob, §III), PSB depth (the
//! segmentation trade-off), Matraptor merge passes, and partition policy.
//!
//! All four sections run through one [`SimEngine`]: the dataset is profiled
//! once and every ablation sweep reuses the cached workload, with cells
//! running concurrently.
//!
//! ```text
//! cargo bench --bench ablation_macs
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{DesignSpace, WorkloadKey};

fn main() {
    let scale = bench_scale();
    let spec = maple::sparse::suite::by_name("p3").unwrap();
    let engine = bench_engine();
    let key = WorkloadKey::suite(spec.abbrev, 7, scale.min(4));
    let w = engine.workload(&key).expect("p3 profiles");
    println!(
        "dataset {} (1/{} scale): {} products, {} out nnz\n",
        spec.abbrev,
        scale.min(4),
        w.total_products,
        w.out_nnz
    );
    let sweep = |configs: Vec<AcceleratorConfig>, policies: Vec<Policy>| {
        engine
            .sweep(&DesignSpace::new(configs, vec![key.clone()], policies))
            .expect("ablation sweep")
    };

    println!("--- MACs/PE at a fixed 128-MAC budget (who wins where?) ---");
    println!("{:>8} {:>6} {:>12} {:>12} {:>9}", "macs/pe", "pes", "cycles", "energy uJ", "util %");
    let ks = [1usize, 2, 4, 8, 16, 32];
    let configs: Vec<AcceleratorConfig> = ks
        .iter()
        .map(|&k| {
            let mut cfg = AcceleratorConfig::extensor_maple();
            cfg.pe.macs_per_pe = k;
            cfg.num_pes = 128 / k;
            cfg.pe.brb_entries = 16 * k;
            cfg.pe.psb_entries = 16 * k;
            cfg.name = format!("extensor-maple-k{k}");
            cfg
        })
        .collect();
    let grid = sweep(configs.clone(), vec![Policy::RoundRobin]);
    for (i, (&k, cfg)) in ks.iter().zip(&configs).enumerate() {
        let r = &grid.get(0, i, 0).analytic;
        println!(
            "{:>8} {:>6} {:>12} {:>12.2} {:>9.1}",
            k,
            cfg.num_pes,
            r.cycles_compute,
            r.energy.total_pj() / 1e6,
            100.0 * r.mac_utilisation(cfg)
        );
    }

    println!("\n--- PSB depth (segmentation cost) ---");
    println!("{:>8} {:>12} {:>12}", "psb", "cycles", "arb re-reads");
    let depths = [16usize, 32, 64, 128, 256, 512];
    let configs: Vec<AcceleratorConfig> = depths
        .iter()
        .map(|&psb| {
            let mut cfg = AcceleratorConfig::extensor_maple();
            cfg.pe.psb_entries = psb;
            cfg.name = format!("extensor-maple-psb{psb}");
            cfg
        })
        .collect();
    let grid = sweep(configs, vec![Policy::RoundRobin]);
    for (i, &psb) in depths.iter().enumerate() {
        let r = &grid.get(0, i, 0).analytic;
        println!("{:>8} {:>12} {:>12}", psb, r.cycles_compute, r.counters.arb_read);
    }

    println!("\n--- Matraptor baseline merge passes (round-robin accumulate depth) ---");
    println!("{:>8} {:>12} {:>14}", "passes", "queue words", "energy uJ");
    let passes = [1u32, 2, 4, 6, 8];
    let configs: Vec<AcceleratorConfig> = passes
        .iter()
        .map(|&p| {
            let mut cfg = AcceleratorConfig::matraptor_baseline();
            cfg.merge_passes = p;
            cfg.name = format!("matraptor-baseline-m{p}");
            cfg
        })
        .collect();
    let grid = sweep(configs, vec![Policy::RoundRobin]);
    for (i, &p) in passes.iter().enumerate() {
        let r = &grid.get(0, i, 0).analytic;
        println!(
            "{:>8} {:>12} {:>14.2}",
            p,
            r.counters.queue_read + r.counters.queue_write,
            r.energy.total_pj() / 1e6
        );
    }

    println!("\n--- Partition policy (coordinator ablation) ---");
    println!("{:>14} {:>12} {:>9}", "policy", "cycles", "balance");
    let policies = [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance];
    let grid = sweep(vec![AcceleratorConfig::extensor_maple()], policies.to_vec());
    for (i, policy) in policies.iter().enumerate() {
        let r = &grid.get(0, 0, i).analytic;
        println!("{:>14} {:>12} {:>9.3}", format!("{policy:?}"), r.cycles_compute, r.balance);
    }

    // The whole ablation ran on a single profile pass (or one disk hit
    // when a prior run already persisted the profile).
    assert_eq!(
        engine.profiles_run() + engine.disk_hits(),
        1,
        "workload must be profiled (or loaded) exactly once"
    );
    report_cache_line(&engine);
}
