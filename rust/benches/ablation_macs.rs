//! Bench: ablation of the design choices DESIGN.md calls out —
//! MACs-per-PE (the paper's central design knob, §III), PSB depth (the
//! segmentation trade-off), Matraptor merge passes, and partition policy.
//!
//! ```text
//! cargo bench --bench ablation_macs
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{profile_workload, simulate_workload};

fn main() {
    let scale = bench_scale();
    let spec = maple::sparse::suite::by_name("p3").unwrap();
    let a = spec.generate_scaled(7, scale.min(4));
    let w = profile_workload(&a, &a);
    println!(
        "dataset {} (1/{} scale): {} products, {} out nnz\n",
        spec.abbrev,
        scale.min(4),
        w.total_products,
        w.out_nnz
    );

    println!("--- MACs/PE at a fixed 128-MAC budget (who wins where?) ---");
    println!("{:>8} {:>6} {:>12} {:>12} {:>9}", "macs/pe", "pes", "cycles", "energy uJ", "util %");
    for k in [1, 2, 4, 8, 16, 32] {
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.pe.macs_per_pe = k;
        cfg.num_pes = 128 / k;
        cfg.pe.brb_entries = 16 * k;
        cfg.pe.psb_entries = 16 * k;
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        println!(
            "{:>8} {:>6} {:>12} {:>12.2} {:>9.1}",
            k,
            cfg.num_pes,
            r.cycles_compute,
            r.energy.total_pj() / 1e6,
            100.0 * r.mac_utilisation(&cfg)
        );
    }

    println!("\n--- PSB depth (segmentation cost) ---");
    println!("{:>8} {:>12} {:>12}", "psb", "cycles", "arb re-reads");
    for psb in [16, 32, 64, 128, 256, 512] {
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.pe.psb_entries = psb;
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        println!("{:>8} {:>12} {:>12}", psb, r.cycles_compute, r.counters.arb_read);
    }

    println!("\n--- Matraptor baseline merge passes (round-robin accumulate depth) ---");
    println!("{:>8} {:>12} {:>14}", "passes", "queue words", "energy uJ");
    for passes in [1, 2, 4, 6, 8] {
        let mut cfg = AcceleratorConfig::matraptor_baseline();
        cfg.merge_passes = passes;
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        println!(
            "{:>8} {:>12} {:>14.2}",
            passes,
            r.counters.queue_read + r.counters.queue_write,
            r.energy.total_pj() / 1e6
        );
    }

    println!("\n--- Partition policy (coordinator ablation) ---");
    println!("{:>14} {:>12} {:>9}", "policy", "cycles", "balance");
    for policy in [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance] {
        let r = simulate_workload(&AcceleratorConfig::extensor_maple(), &w, policy);
        println!("{:>14} {:>12} {:>9.3}", format!("{policy:?}"), r.cycles_compute, r.balance);
    }
}
