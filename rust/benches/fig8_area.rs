//! Bench: regenerate **Fig. 8(a)+(b)** — PE area, baseline vs Maple, for
//! both reference accelerators, with the paper's headline ratios.
//!
//! ```text
//! cargo bench --bench fig8_area
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::report;

fn main() {
    println!("=== Fig. 8(a) — Matraptor (paper: 5.9x / 84% less) ===");
    print!(
        "{}",
        report::fig8_report(
            &AcceleratorConfig::matraptor_baseline(),
            &AcceleratorConfig::matraptor_maple(),
            true,
        )
    );
    println!("\n=== Fig. 8(b) — Extensor (paper: 15.5x / 90% less) ===");
    print!(
        "{}",
        report::fig8_report(
            &AcceleratorConfig::extensor_baseline(),
            &AcceleratorConfig::extensor_maple(),
            true,
        )
    );

    let (iters, total) = measure(std::time::Duration::from_millis(200), || {
        for cfg in AcceleratorConfig::paper_configs() {
            std::hint::black_box(maple::accel::accelerator_pe_area(&cfg).total_mm2());
        }
    });
    report_line("accelerator_pe_area (4 configs)", iters, total, None);
}
