//! Bench: the two-tier explorer against the exhaustive sweep it replaces —
//! the sampled-profiler speedup over the exact pass, and the search's
//! evaluation count / wall-clock as a fraction of the full grid.
//!
//! ```text
//! cargo bench --bench explore_search
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{
    check_against_exhaustive, profile_workload, profile_workload_sampled, Axis, DesignSpace,
    ExploreSpec, Explorer, Tier, WorkloadKey,
};

fn main() {
    let scale = bench_scale();
    let spec = maple::sparse::suite::by_name("wv").unwrap();
    let a = spec.generate_scaled(7, scale);
    let exact = profile_workload(&a, &a);
    println!(
        "workload: wikiVote/{scale} — {}x{}, {} nnz, {} products\n",
        a.rows(),
        a.cols(),
        a.nnz(),
        exact.total_products
    );

    // 1. Fitness-tier cost: exact profile pass vs the sampled estimator.
    let (iters, total) = measure(std::time::Duration::from_secs(1), || {
        std::hint::black_box(profile_workload(&a, &a).total_products);
    });
    report_line("profile_workload (exact)", iters, total, Some((exact.total_products, "products")));
    let exact_per_iter = total.as_secs_f64() / iters.max(1) as f64;
    for budget in [64usize, 256] {
        let est = profile_workload_sampled(&a, &a, budget, 7);
        let (iters, total) = measure(std::time::Duration::from_millis(500), || {
            std::hint::black_box(profile_workload_sampled(&a, &a, budget, 7).workload.out_nnz);
        });
        let label = format!("profile_workload_sampled[{budget}]");
        report_line(&label, iters, total, Some((exact.total_products, "products")));
        let per_iter = total.as_secs_f64() / iters.max(1) as f64;
        let err = (est.workload.out_nnz as f64 - exact.out_nnz as f64).abs()
            / exact.out_nnz.max(1) as f64;
        println!(
            "    speedup {:>6.1}x   out-nnz err {:>6.3}% (claimed ≤ {:.3}%)",
            exact_per_iter / per_iter.max(1e-12),
            err * 1e2,
            est.out_nnz_rel_err * 1e2
        );
    }

    // 2. Search vs exhaustive grid over the macs × prefetch × policy cube.
    let engine = bench_engine();
    let space = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, scale),
            WorkloadKey::suite("fb", 7, scale),
        ]))
        .with_axis(Axis::macs_per_pe(vec![1, 2, 4, 8, 16, 32]))
        .with_axis(Axis::prefetch_depth(vec![1, 2, 4, 8]))
        .with_axis(Axis::Policy(vec![
            Policy::RoundRobin,
            Policy::Chunked,
            Policy::GreedyBalance,
        ]));
    let explore_spec =
        ExploreSpec { tier: Tier::TwoTier, budget: 48, sample_budget: 128, ..Default::default() };

    let t0 = std::time::Instant::now();
    let result = Explorer::new(&engine, space.clone(), explore_spec).run().unwrap();
    let search_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    let grid = engine.sweep(&space).unwrap();
    let sweep_ms = t1.elapsed().as_secs_f64() * 1e3;
    let check = check_against_exhaustive(&result, &grid, t1.elapsed().as_millis() as u64);

    println!();
    println!(
        "explore: {} fresh evals ({} est + {} exact) over {} cells = {:.2}% of the grid",
        result.evals_total(),
        result.evals_estimate(),
        result.evals_exact(),
        result.grid_cells,
        result.eval_fraction() * 1e2
    );
    println!(
        "explore: search {search_ms:.0} ms vs sweep {sweep_ms:.0} ms ({:.1}x), in-band {}/{}",
        sweep_ms / search_ms.max(1e-9),
        check.per_dataset.iter().filter(|d| d.in_band).count(),
        check.per_dataset.len()
    );
    for best in &check.per_dataset {
        println!(
            "explore[{}]: search {:.0} vs optimum {:.0} cycles, argmin_match={}, in_band={}",
            best.dataset,
            best.search_fitness,
            best.best_fitness,
            best.argmin_match,
            best.in_band
        );
    }
    report_cache_line(&engine);
}
