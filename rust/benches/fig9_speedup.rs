//! Bench: regenerate **Fig. 9(b)** — per-dataset speedup (%) of the
//! Maple-based configurations over the baselines, plus the paper-style mean
//! (paper: ~15% Matraptor, ~22% Extensor).
//!
//! ```text
//! cargo bench --bench fig9_speedup
//! MAPLE_BENCH_SCALE=1 cargo bench --bench fig9_speedup   # full Table-I scale
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::report::Fig9Row;
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::suite;

fn main() {
    let scale = bench_scale();
    println!("=== Fig. 9(b) — speedup %, scale 1/{scale} ===\n");
    println!(
        "{:<8} {:>14} {:>14} | {:>14} {:>14}",
        "dataset", "matraptor %", "extensor %", "base cyc (ext)", "maple cyc (ext)"
    );

    let rows: Vec<(Fig9Row, Fig9Row)> = std::thread::scope(|scope| {
        let handles: Vec<_> = suite::TABLE_I
            .iter()
            .map(|spec| {
                scope.spawn(move || {
                    let a = if scale <= 1 {
                        spec.generate(7)
                    } else {
                        spec.generate_scaled(7, scale)
                    };
                    let w = profile_workload(&a, &a);
                    let run = |c: &AcceleratorConfig| simulate_workload(c, &w, Policy::RoundRobin);
                    let mb = run(&AcceleratorConfig::matraptor_baseline());
                    let mm = run(&AcceleratorConfig::matraptor_maple());
                    let eb = run(&AcceleratorConfig::extensor_baseline());
                    let em = run(&AcceleratorConfig::extensor_maple());
                    (
                        Fig9Row::from_results(spec.abbrev, &mb, &mm),
                        Fig9Row::from_results(spec.abbrev, &eb, &em),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for (m, e) in &rows {
        println!(
            "{:<8} {:>14.1} {:>14.1} | {:>14} {:>14}",
            m.dataset, m.speedup_pct, e.speedup_pct, e.baseline_cycles, e.maple_cycles
        );
    }
    let mean_m = rows.iter().map(|(m, _)| m.speedup_pct).sum::<f64>() / rows.len() as f64;
    let mean_e = rows.iter().map(|(_, e)| e.speedup_pct).sum::<f64>() / rows.len() as f64;
    println!("\nmean speedup: Matraptor {mean_m:.1}% (paper ~15%), Extensor {mean_e:.1}% (paper ~22%)");
}
