//! Bench: regenerate **Fig. 9(b)** — per-dataset speedup (%) of the
//! Maple-based configurations over the baselines, plus the paper-style mean
//! (paper: ~15% Matraptor, ~22% Extensor).
//!
//! One [`SimEngine`] sweep: each dataset is profiled once, all
//! (config × dataset) cells run concurrently.
//!
//! ```text
//! cargo bench --bench fig9_speedup
//! MAPLE_BENCH_SCALE=1 cargo bench --bench fig9_speedup   # full Table-I scale
//! ```

include!("harness.rs");

use maple::report::fig9_rows_from_sweep;
use maple::sim::{DesignSpace, WorkloadKey};
use maple::sparse::suite;

fn main() {
    let scale = bench_scale();
    println!("=== Fig. 9(b) — speedup %, scale 1/{scale} ===\n");
    println!(
        "{:<8} {:>14} {:>14} | {:>14} {:>14}",
        "dataset", "matraptor %", "extensor %", "base cyc (ext)", "maple cyc (ext)"
    );

    let engine = bench_engine();
    let keys = suite::TABLE_I.iter().map(|d| WorkloadKey::suite(d.abbrev, 7, scale)).collect();
    let grid = engine.sweep(&DesignSpace::paper(keys)).expect("Table-I sweep");
    let m_rows = fig9_rows_from_sweep(&grid, 0, 1, 0);
    let e_rows = fig9_rows_from_sweep(&grid, 2, 3, 0);

    for (m, e) in m_rows.iter().zip(&e_rows) {
        println!(
            "{:<8} {:>14.1} {:>14.1} | {:>14} {:>14}",
            m.dataset, m.speedup_pct, e.speedup_pct, e.baseline_cycles, e.maple_cycles
        );
    }
    let mean_m = m_rows.iter().map(|m| m.speedup_pct).sum::<f64>() / m_rows.len() as f64;
    let mean_e = e_rows.iter().map(|e| e.speedup_pct).sum::<f64>() / e_rows.len() as f64;
    println!(
        "\nmean speedup: Matraptor {mean_m:.1}% (paper ~15%), Extensor {mean_e:.1}% (paper ~22%)"
    );
    report_cache_line(&engine);
}
