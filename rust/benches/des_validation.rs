//! Bench: transaction-level DES vs the analytic pipeline model — the
//! methodology check behind every Fig.-9(b) number (DESIGN.md §4 `sim/`),
//! now running through the engine's `CellModel::Both` sweep path: one
//! cross-validation sweep over four dataset families × the four paper
//! configurations, warm-started from the on-disk workload cache like every
//! other engine bench. Prints, per cell, both cycle counts, their
//! agreement ratio, DES utilisation/skew, and the in-band verdict; the
//! fixed DES semantics guarantee DES ≥ analytic in every cell.
//!
//! ```text
//! cargo bench --bench des_validation
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::report::des_validation_report;
use maple::sim::{simulate_des, CellModel, DesignSpace, WorkloadKey};

fn main() {
    let scale = bench_scale();
    println!("=== DES vs analytic cycle model (scale 1/{scale}, engine sweep) ===\n");

    let engine = bench_engine();
    let keys: Vec<WorkloadKey> = ["wg", "of", "sc", "wv"]
        .iter()
        .map(|&n| WorkloadKey::suite(n, 7, scale.max(32)))
        .collect();
    let t0 = std::time::Instant::now();
    let grid = engine
        .sweep(&DesignSpace::paper(keys).with_cell_model(CellModel::Both))
        .expect("cross-validation sweep");
    let sweep_ms = t0.elapsed().as_secs_f64() * 1e3;
    print!("{}", des_validation_report(&grid, true));
    println!(
        "\n{} Both-model cells in {sweep_ms:.0} ms; {} out of band",
        grid.cell_count(),
        grid.des_out_of_band().len()
    );

    // Winner agreement within each (baseline, maple) pair under the DES.
    let mut agreements = 0;
    let mut comparisons = 0;
    for d in 0..grid.datasets.len() {
        for (base_ix, maple_ix) in [(0usize, 1usize), (2, 3)] {
            comparisons += 1;
            let (b, m) = (grid.get(d, base_ix, 0), grid.get(d, maple_ix, 0));
            let analytic_maple_wins =
                m.analytic.cycles_compute <= b.analytic.cycles_compute;
            // Allow 2% slack for event-ordering noise when DRAM-saturated.
            let des_maple_wins = m.des.as_ref().unwrap().cycles as f64
                <= b.des.as_ref().unwrap().cycles as f64 * 1.02;
            if analytic_maple_wins == des_maple_wins {
                agreements += 1;
            }
        }
    }
    println!("winner agreement: {agreements}/{comparisons} (baseline, maple) pairs");
    report_cache_line(&engine);

    // DES throughput on a profile-cached workload.
    let key = WorkloadKey::suite("wv", 7, 4);
    let w = engine.workload(&key).expect("wv workload");
    let cfg = AcceleratorConfig::extensor_maple();
    let (iters, total) = measure(std::time::Duration::from_millis(700), || {
        std::hint::black_box(simulate_des(&cfg, &w, Policy::RoundRobin).cycles);
    });
    report_line("simulate_des[extensor-maple]", iters, total, Some((w.rows as u64, "rows")));
}
