//! Bench: transaction-level DES vs the analytic pipeline model — the
//! methodology check behind every Fig.-9(b) number (DESIGN.md §4 `sim/`).
//! Prints, per dataset family and configuration, both cycle counts and
//! their ratio; the DES includes DRAM/NoC fetch latency the analytic model
//! idealises, so ratios sit modestly above 1.0 and both models must agree
//! on the Maple-vs-baseline winner.
//!
//! ```text
//! cargo bench --bench des_validation
//! ```

include!("harness.rs");

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{profile_workload, simulate_des, simulate_workload};

fn main() {
    let scale = bench_scale();
    println!("=== DES vs analytic cycle model (scale 1/{scale}) ===\n");
    println!(
        "{:<8} {:<22} {:>12} {:>12} {:>12} {:>7} {:>7} {:>12}",
        "dataset", "config", "analytic", "fetch-bnd", "DES", "ratio", "util%", "regime"
    );
    let mut agreements = 0;
    let mut comparisons = 0;
    for name in ["wg", "of", "sc", "wv"] {
        let spec = maple::sparse::suite::by_name(name).unwrap();
        let a = spec.generate_scaled(7, scale.max(32));
        let w = profile_workload(&a, &a);
        // The DES models the *un-idealised* fetch path: every row pulls its
        // own operands (2·a_nnz + 2·products words) from DRAM, so its lower
        // bound is that volume over the port bandwidth — not the compulsory
        // bound the analytic energy model idealises (DESIGN.md §6b.1).
        let fetch_words: u64 =
            w.profiles.iter().map(|p| 2 * p.a_nnz as u64 + 2 * p.products).sum();
        let mut rows = Vec::new();
        for cfg in AcceleratorConfig::paper_configs() {
            let analytic = simulate_workload(&cfg, &w, Policy::RoundRobin);
            let fetch_bound = (fetch_words as f64 / cfg.dram.words_per_cycle).ceil() as u64;
            let expected = analytic.cycles_compute.max(fetch_bound);
            let des = simulate_des(&cfg, &w, Policy::RoundRobin);
            let regime = if fetch_bound > analytic.cycles_compute { "fetch" } else { "datapath" };
            println!(
                "{:<8} {:<22} {:>12} {:>12} {:>12} {:>7.2} {:>7.1} {:>12}",
                name,
                cfg.name,
                analytic.cycles_compute,
                fetch_bound,
                des.cycles,
                des.cycles as f64 / expected as f64,
                100.0 * des.pe_utilisation,
                regime
            );
            rows.push((expected, des.cycles, regime));
        }
        // Winner agreement within each pair, on the bound-aware expectation.
        for pair in [(0usize, 1usize), (2, 3)] {
            comparisons += 1;
            let expect_maple_wins_or_ties = rows[pair.1].0 <= rows[pair.0].0;
            // Allow 2% slack for event-ordering noise when DRAM-saturated.
            let des_maple_wins_or_ties =
                rows[pair.1].1 as f64 <= rows[pair.0].1 as f64 * 1.02;
            if expect_maple_wins_or_ties == des_maple_wins_or_ties {
                agreements += 1;
            }
        }
    }
    println!(
        "\nbound-aware winner agreement: {agreements}/{comparisons} comparisons \
         (DES ratio ≈ 1 in the fetch regime, 1–2 in the datapath regime)"
    );

    // DES throughput.
    let spec = maple::sparse::suite::by_name("wv").unwrap();
    let a = spec.generate_scaled(7, 4);
    let w = profile_workload(&a, &a);
    let cfg = AcceleratorConfig::extensor_maple();
    let (iters, total) = measure(std::time::Duration::from_millis(700), || {
        std::hint::black_box(simulate_des(&cfg, &w, Policy::RoundRobin).cycles);
    });
    report_line("simulate_des[extensor-maple]", iters, total, Some((w.rows as u64, "rows")));
}
