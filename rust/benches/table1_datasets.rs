//! Bench: regenerate **Table I** — synthesise every dataset, validate its
//! statistics against the paper's columns, measure generator throughput,
//! and profile the whole suite once through the [`SimEngine`] cache.
//!
//! ```text
//! cargo bench --bench table1_datasets
//! MAPLE_BENCH_SCALE=1 cargo bench --bench table1_datasets   # full scale
//! ```

include!("harness.rs");

use maple::report;
use maple::sim::{DesignSpace, WorkloadKey};
use maple::sparse::{stats, suite};

fn main() {
    let scale = bench_scale();
    println!("=== Table I (paper §IV.A) ===\n{}", report::table1(true));
    println!("=== synthesis at scale 1/{scale}: measured statistics ===");
    println!(
        "{:<20} {:>9} {:>10} {:>11} {:>11} {:>9}",
        "dataset", "rows", "nnz", "density", "paper", "gen ms"
    );
    for spec in suite::TABLE_I {
        let t0 = std::time::Instant::now();
        let a = if scale <= 1 { spec.generate(7) } else { spec.generate_scaled(7, scale) };
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let s = stats::row_stats(&a);
        println!(
            "{:<20} {:>9} {:>10} {:>11.2e} {:>11.2e} {:>9.1}",
            spec.abbrev,
            s.rows,
            s.nnz,
            s.density,
            spec.density(),
            ms
        );
    }

    // Profile the whole suite once through the engine: fourteen cached
    // workloads, profiled concurrently (warm-started from the disk cache
    // when a prior run populated it), then a Maple-vs-baseline cell per
    // dataset from the same cache.
    let engine = bench_engine();
    let keys: Vec<WorkloadKey> =
        suite::TABLE_I.iter().map(|d| WorkloadKey::suite(d.abbrev, 7, scale)).collect();
    let t0 = std::time::Instant::now();
    let grid = engine
        .sweep(&DesignSpace::paper(keys.clone()))
        .expect("Table-I sweep");
    let sweep_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("\n=== profiled workloads (SimEngine, scale 1/{scale}) ===");
    println!(
        "{:<20} {:>12} {:>10} {:>8} {:>14}",
        "dataset", "products", "out nnz", "acc", "ext speedup %"
    );
    for (i, key) in keys.iter().enumerate() {
        let w = engine.workload(key).expect("cached");
        let (eb, em) = (&grid.get(i, 2, 0).analytic, &grid.get(i, 3, 0).analytic);
        println!(
            "{:<20} {:>12} {:>10} {:>8.2} {:>14.1}",
            key.dataset,
            w.total_products,
            w.out_nnz,
            w.accumulation_factor(),
            em.speedup_pct(eb)
        );
    }
    assert_eq!(
        (engine.profiles_run() + engine.disk_hits()) as usize,
        keys.len(),
        "one profile or disk hit per dataset"
    );
    println!(
        "{} cells over {} workloads in {sweep_ms:.0} ms (each dataset profiled once)",
        grid.cell_count(),
        keys.len()
    );
    report_cache_line(&engine);

    // Generator throughput micro-bench on the densest dataset.
    let spec = suite::by_name("fb").unwrap();
    let (iters, total) = measure(std::time::Duration::from_millis(500), || {
        let a = spec.generate_scaled(7, scale.max(2));
        std::hint::black_box(a.nnz());
    });
    let nnz = spec.generate_scaled(7, scale.max(2)).nnz() as u64;
    report_line("generate(facebook, scaled)", iters, total, Some((nnz, "nnz")));
}
