//! Bench: regenerate **Table I** — synthesise every dataset, validate its
//! statistics against the paper's columns, and measure generator throughput.
//!
//! ```text
//! cargo bench --bench table1_datasets
//! MAPLE_BENCH_SCALE=1 cargo bench --bench table1_datasets   # full scale
//! ```

include!("harness.rs");

use maple::report;
use maple::sparse::{stats, suite};

fn main() {
    let scale = bench_scale();
    println!("=== Table I (paper §IV.A) ===\n{}", report::table1(true));
    println!("=== synthesis at scale 1/{scale}: measured statistics ===");
    println!(
        "{:<20} {:>9} {:>10} {:>11} {:>11} {:>9}",
        "dataset", "rows", "nnz", "density", "paper", "gen ms"
    );
    for spec in suite::TABLE_I {
        let t0 = std::time::Instant::now();
        let a = if scale <= 1 { spec.generate(7) } else { spec.generate_scaled(7, scale) };
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let s = stats::row_stats(&a);
        println!(
            "{:<20} {:>9} {:>10} {:>11.2e} {:>11.2e} {:>9.1}",
            spec.abbrev,
            s.rows,
            s.nnz,
            s.density,
            spec.density(),
            ms
        );
    }

    // Generator throughput micro-bench on the densest dataset.
    let spec = suite::by_name("fb").unwrap();
    let (iters, total) = measure(std::time::Duration::from_millis(500), || {
        let a = spec.generate_scaled(7, scale.max(2));
        std::hint::black_box(a.nnz());
    });
    let nnz = spec.generate_scaled(7, scale.max(2)).nnz() as u64;
    report_line("generate(facebook, scaled)", iters, total, Some((nnz, "nnz")));
}
