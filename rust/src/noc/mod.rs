//! Network-on-Chip models (paper §II.C: "All the components of an
//! accelerator are connected through the NoC... Extensor uses an NoC with
//! unicast, multicast, and broadcast capabilities. Matraptor and GAMMA
//! employ a customized and simplified crossbar").

use crate::trace::Counters;

/// NoC topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Single-stage crossbar with `ports` endpoints (Matraptor-style).
    Crossbar { ports: usize },
    /// 2-D mesh of `width × height` routers (Extensor-style), XY-routed.
    Mesh { width: usize, height: usize },
}

/// Delivery pattern for one transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cast<'a> {
    /// One source to one destination.
    Unicast { src: usize, dst: usize },
    /// One source to an explicit destination set.
    Multicast { src: usize, dsts: &'a [usize] },
    /// One source to every endpoint.
    Broadcast { src: usize },
}

/// A counted NoC instance.
#[derive(Debug, Clone)]
pub struct Noc {
    topology: Topology,
    /// Cycles for one flit to cross one hop (router + link).
    cycles_per_hop: u64,
    /// 32-bit words per flit.
    words_per_flit: u64,
    total_transfers: u64,
}

impl Noc {
    /// New NoC with 1-cycle hops and 1-word flits (the common setup for
    /// 32-bit datapaths).
    pub fn new(topology: Topology) -> Self {
        Self { topology, cycles_per_hop: 1, words_per_flit: 1, total_transfers: 0 }
    }

    /// Endpoint count.
    pub fn endpoints(&self) -> usize {
        match self.topology {
            Topology::Crossbar { ports } => ports,
            Topology::Mesh { width, height } => width * height,
        }
    }

    /// Hop count between two endpoints.
    pub fn hops(&self, src: usize, dst: usize) -> u64 {
        match self.topology {
            // A crossbar is a single traversal regardless of port pair.
            Topology::Crossbar { .. } => 1,
            Topology::Mesh { width, .. } => {
                let (sx, sy) = (src % width, src / width);
                let (dx, dy) = (dst % width, dst / width);
                (sx.abs_diff(dx) + sy.abs_diff(dy)).max(1) as u64
            }
        }
    }

    /// Transfer `words` according to `cast`; counts flit-hops and returns the
    /// serialisation latency in cycles (head-flit hops + pipeline drain).
    pub fn transfer(&mut self, c: &mut Counters, cast: Cast<'_>, words: u64) -> u64 {
        self.total_transfers += 1;
        let flits = words.div_ceil(self.words_per_flit).max(1);
        match cast {
            Cast::Unicast { src, dst } => {
                let h = self.hops(src, dst);
                c.noc_flit_hops += flits * h;
                h * self.cycles_per_hop + flits - 1
            }
            Cast::Multicast { src, dsts } => {
                // Tree multicast: flits traverse shared prefix paths once; we
                // approximate the tree as the union cost = max path + extra
                // leaf hops, and count energy on every delivered copy's last
                // hop plus one shared trunk.
                let mut max_h = 0;
                let mut total_h = 0;
                for &d in dsts {
                    let h = self.hops(src, d);
                    max_h = max_h.max(h);
                    total_h += h;
                }
                // Energy: trunk (max path) + one extra hop per additional
                // destination (tree fan-out approximation).
                let tree_hops = max_h + (dsts.len().saturating_sub(1)) as u64;
                let _ = total_h;
                c.noc_flit_hops += flits * tree_hops.max(1);
                max_h.max(1) * self.cycles_per_hop + flits - 1
            }
            Cast::Broadcast { src } => {
                let n = self.endpoints();
                let max_h = (0..n).map(|d| self.hops(src, d)).max().unwrap_or(1);
                let tree_hops = max_h + (n.saturating_sub(1)) as u64;
                c.noc_flit_hops += flits * tree_hops;
                max_h * self.cycles_per_hop + flits - 1
            }
        }
    }

    /// Transfers issued.
    pub fn total_transfers(&self) -> u64 {
        self.total_transfers
    }

    /// The topology this NoC implements.
    pub fn topology(&self) -> Topology {
        self.topology
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossbar_is_single_hop() {
        let mut n = Noc::new(Topology::Crossbar { ports: 8 });
        let mut c = Counters::default();
        let lat = n.transfer(&mut c, Cast::Unicast { src: 0, dst: 7 }, 4);
        assert_eq!(c.noc_flit_hops, 4);
        assert_eq!(lat, 1 + 3);
    }

    #[test]
    fn mesh_hops_are_manhattan() {
        let n = Noc::new(Topology::Mesh { width: 4, height: 4 });
        assert_eq!(n.hops(0, 15), 6); // (0,0) -> (3,3)
        assert_eq!(n.hops(5, 5), 1); // self-delivery still crosses the NIC
        assert_eq!(n.hops(1, 2), 1);
    }

    #[test]
    fn multicast_cheaper_than_repeated_unicast() {
        let mut n1 = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut n2 = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut cm = Counters::default();
        let mut cu = Counters::default();
        let dsts = [3, 5, 6, 7];
        n1.transfer(&mut cm, Cast::Multicast { src: 0, dsts: &dsts }, 8);
        for &d in &dsts {
            n2.transfer(&mut cu, Cast::Unicast { src: 0, dst: d }, 8);
        }
        assert!(cm.noc_flit_hops < cu.noc_flit_hops);
    }

    #[test]
    fn broadcast_reaches_all_endpoints() {
        let mut n = Noc::new(Topology::Mesh { width: 2, height: 2 });
        let mut c = Counters::default();
        let lat = n.transfer(&mut c, Cast::Broadcast { src: 0 }, 1);
        assert!(c.noc_flit_hops >= 4);
        assert!(lat >= 2);
    }

    #[test]
    fn endpoints_match_topology() {
        assert_eq!(Noc::new(Topology::Crossbar { ports: 5 }).endpoints(), 5);
        assert_eq!(Noc::new(Topology::Mesh { width: 16, height: 8 }).endpoints(), 128);
    }
}
