//! Network-on-Chip models (paper §II.C: "All the components of an
//! accelerator are connected through the NoC... Extensor uses an NoC with
//! unicast, multicast, and broadcast capabilities. Matraptor and GAMMA
//! employ a customized and simplified crossbar").

use crate::trace::Counters;

/// NoC topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Single-stage crossbar with `ports` endpoints (Matraptor-style).
    Crossbar { ports: usize },
    /// 2-D mesh of `width × height` routers (Extensor-style), XY-routed.
    Mesh { width: usize, height: usize },
}

impl Topology {
    /// Whether any dimension is zero — a degenerate instance that cannot
    /// route ([`Noc::hops`] would divide by the zero width). The single
    /// predicate behind the spec parser, the TOML loader, and axis
    /// validation.
    pub fn is_degenerate(self) -> bool {
        match self {
            Topology::Crossbar { ports } => ports == 0,
            Topology::Mesh { width, height } => width == 0 || height == 0,
        }
    }
}

/// Error parsing a [`Topology`] spec string.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("bad topology {0:?} (expected crossbar:<ports> or mesh:<width>x<height>, dims ≥ 1)")]
pub struct TopologyParseError(pub String);

/// The canonical spec syntax, shared by TOML io, the CLI `--axis noc=...`
/// flag, and report labels: `crossbar:<ports>` / `mesh:<width>x<height>`.
impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Topology::Crossbar { ports } => write!(f, "crossbar:{ports}"),
            Topology::Mesh { width, height } => write!(f, "mesh:{width}x{height}"),
        }
    }
}

impl std::str::FromStr for Topology {
    type Err = TopologyParseError;

    /// Parse `crossbar:<ports>` / `mesh:<width>x<height>`. Every dimension
    /// must be ≥ 1 — a zero-port crossbar or `mesh:0x4` cannot route.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let err = || TopologyParseError(s.to_string());
        let (name, dims) = s.trim().split_once(':').ok_or_else(err)?;
        let dim = |t: &str| t.trim().parse::<usize>().map_err(|_| err());
        let t = match name.trim() {
            "crossbar" => Topology::Crossbar { ports: dim(dims)? },
            "mesh" => {
                let (w, h) = dims.split_once('x').ok_or_else(err)?;
                Topology::Mesh { width: dim(w)?, height: dim(h)? }
            }
            _ => return Err(err()),
        };
        if t.is_degenerate() {
            return Err(err());
        }
        Ok(t)
    }
}

/// Delivery pattern for one transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cast<'a> {
    /// One source to one destination.
    Unicast { src: usize, dst: usize },
    /// One source to an explicit destination set.
    Multicast { src: usize, dsts: &'a [usize] },
    /// One source to every endpoint.
    Broadcast { src: usize },
}

/// A counted NoC instance.
#[derive(Debug, Clone)]
pub struct Noc {
    topology: Topology,
    /// Cycles for one flit to cross one hop (router + link).
    cycles_per_hop: u64,
    /// 32-bit words per flit.
    words_per_flit: u64,
    total_transfers: u64,
}

impl Noc {
    /// New NoC with 1-cycle hops and 1-word flits (the common setup for
    /// 32-bit datapaths).
    pub fn new(topology: Topology) -> Self {
        Self { topology, cycles_per_hop: 1, words_per_flit: 1, total_transfers: 0 }
    }

    /// Endpoint count.
    pub fn endpoints(&self) -> usize {
        match self.topology {
            Topology::Crossbar { ports } => ports,
            Topology::Mesh { width, height } => width * height,
        }
    }

    /// Hop count between two endpoints.
    pub fn hops(&self, src: usize, dst: usize) -> u64 {
        match self.topology {
            // A crossbar is a single traversal regardless of port pair.
            Topology::Crossbar { .. } => 1,
            Topology::Mesh { width, .. } => {
                let (sx, sy) = (src % width, src / width);
                let (dx, dy) = (dst % width, dst / width);
                (sx.abs_diff(dx) + sy.abs_diff(dy)).max(1) as u64
            }
        }
    }

    /// Transfer `words` according to `cast`; counts flit-hops and returns the
    /// serialisation latency in cycles (head-flit hops + pipeline drain).
    pub fn transfer(&mut self, c: &mut Counters, cast: Cast<'_>, words: u64) -> u64 {
        self.total_transfers += 1;
        let flits = words.div_ceil(self.words_per_flit).max(1);
        match cast {
            Cast::Unicast { src, dst } => {
                let h = self.hops(src, dst);
                c.noc_flit_hops += flits * h;
                h * self.cycles_per_hop + flits - 1
            }
            // Tree-fanout approximation (multicast and broadcast): flits
            // traverse shared prefix paths once, so the delivery tree is
            // costed as one trunk — the longest source→destination path —
            // plus one extra leaf hop per additional destination. Energy
            // (flit-hops) is charged on that tree; latency is the trunk
            // traversal plus the pipeline drain. Summing per-destination
            // paths would double-charge the shared prefix (that sum is what
            // repeated unicast costs, the upper bound the regression tests
            // compare against).
            Cast::Multicast { src, dsts } => {
                let max_h = dsts.iter().map(|&d| self.hops(src, d)).max().unwrap_or(0);
                let tree_hops = max_h + (dsts.len().saturating_sub(1)) as u64;
                c.noc_flit_hops += flits * tree_hops.max(1);
                max_h.max(1) * self.cycles_per_hop + flits - 1
            }
            Cast::Broadcast { src } => {
                let n = self.endpoints();
                let max_h = (0..n).map(|d| self.hops(src, d)).max().unwrap_or(1);
                let tree_hops = max_h + (n.saturating_sub(1)) as u64;
                c.noc_flit_hops += flits * tree_hops;
                max_h * self.cycles_per_hop + flits - 1
            }
        }
    }

    /// Transfers issued.
    pub fn total_transfers(&self) -> u64 {
        self.total_transfers
    }

    /// The topology this NoC implements.
    pub fn topology(&self) -> Topology {
        self.topology
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossbar_is_single_hop() {
        let mut n = Noc::new(Topology::Crossbar { ports: 8 });
        let mut c = Counters::default();
        let lat = n.transfer(&mut c, Cast::Unicast { src: 0, dst: 7 }, 4);
        assert_eq!(c.noc_flit_hops, 4);
        assert_eq!(lat, 1 + 3);
    }

    #[test]
    fn mesh_hops_are_manhattan() {
        let n = Noc::new(Topology::Mesh { width: 4, height: 4 });
        assert_eq!(n.hops(0, 15), 6); // (0,0) -> (3,3)
        assert_eq!(n.hops(5, 5), 1); // self-delivery still crosses the NIC
        assert_eq!(n.hops(1, 2), 1);
    }

    #[test]
    fn multicast_cheaper_than_repeated_unicast() {
        let mut n1 = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut n2 = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut cm = Counters::default();
        let mut cu = Counters::default();
        let dsts = [3, 5, 6, 7];
        n1.transfer(&mut cm, Cast::Multicast { src: 0, dsts: &dsts }, 8);
        for &d in &dsts {
            n2.transfer(&mut cu, Cast::Unicast { src: 0, dst: d }, 8);
        }
        assert!(cm.noc_flit_hops < cu.noc_flit_hops);
    }

    #[test]
    fn broadcast_reaches_all_endpoints() {
        let mut n = Noc::new(Topology::Mesh { width: 2, height: 2 });
        let mut c = Counters::default();
        let lat = n.transfer(&mut c, Cast::Broadcast { src: 0 }, 1);
        assert!(c.noc_flit_hops >= 4);
        assert!(lat >= 2);
    }

    #[test]
    fn endpoints_match_topology() {
        assert_eq!(Noc::new(Topology::Crossbar { ports: 5 }).endpoints(), 5);
        assert_eq!(Noc::new(Topology::Mesh { width: 16, height: 8 }).endpoints(), 128);
    }

    #[test]
    fn multicast_tree_fanout_mesh_vs_crossbar_is_pinned() {
        // Regression for the tree-fanout approximation (the dead `total_h`
        // sum is gone): same destination set, one flit stream of 8 words.
        //
        // Mesh 4×2, src 0, dsts {3, 5, 6, 7}: hops 3, 2, 3, 4 → trunk 4,
        // tree = 4 + 3 extra leaves = 7 → 8 flits × 7 = 56 flit-hops,
        // latency = 4 hops + 7 drain = 11.
        let mut mesh = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut cm = Counters::default();
        let dsts = [3, 5, 6, 7];
        let lat_m = mesh.transfer(&mut cm, Cast::Multicast { src: 0, dsts: &dsts }, 8);
        assert_eq!(cm.noc_flit_hops, 56);
        assert_eq!(lat_m, 11);
        // Crossbar 8: every path is 1 hop → tree = 1 + 3 = 4 → 32 flit-hops,
        // latency = 1 + 7 = 8. Strictly cheaper than the mesh on both axes.
        let mut xbar = Noc::new(Topology::Crossbar { ports: 8 });
        let mut cx = Counters::default();
        let lat_x = xbar.transfer(&mut cx, Cast::Multicast { src: 0, dsts: &dsts }, 8);
        assert_eq!(cx.noc_flit_hops, 32);
        assert_eq!(lat_x, 8);
        assert!(cx.noc_flit_hops < cm.noc_flit_hops && lat_x < lat_m);
        // And the tree stays below the repeated-unicast sum on the mesh
        // (3+2+3+4 = 12 path-hops > 7 tree-hops).
        let mut uni = Noc::new(Topology::Mesh { width: 4, height: 2 });
        let mut cu = Counters::default();
        for &d in &dsts {
            uni.transfer(&mut cu, Cast::Unicast { src: 0, dst: d }, 8);
        }
        assert_eq!(cu.noc_flit_hops, 96);
        assert!(cm.noc_flit_hops < cu.noc_flit_hops);
    }

    #[test]
    fn topology_display_round_trips() {
        for t in [
            Topology::Crossbar { ports: 8 },
            Topology::Crossbar { ports: 1 },
            Topology::Mesh { width: 16, height: 8 },
            Topology::Mesh { width: 1, height: 1 },
        ] {
            assert_eq!(t.to_string().parse::<Topology>(), Ok(t));
        }
        assert_eq!("crossbar:8".parse::<Topology>(), Ok(Topology::Crossbar { ports: 8 }));
        assert_eq!(
            " mesh:4x2 ".parse::<Topology>(),
            Ok(Topology::Mesh { width: 4, height: 2 })
        );
    }

    #[test]
    fn topology_parse_rejects_bad_specs() {
        for bad in [
            "", "mesh", "crossbar", "crossbar:", "crossbar:0", "crossbar:x",
            "mesh:0x4", "mesh:4x0", "mesh:4", "mesh:4x", "mesh:x4", "mesh:axb",
            "torus:4x4", "mesh:4x4x4", "crossbar:-1", "mesh:-1x4",
        ] {
            assert!(bad.parse::<Topology>().is_err(), "{bad:?} must not parse");
        }
    }
}
