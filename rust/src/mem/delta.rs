//! Functional CSR metadata codec: delta + varint encoding of `col_id` runs.
//!
//! The C/D units of paper Fig. 2 are modelled energetically by
//! [`super::CsrCodec`]; this module is the *functional* counterpart — the
//! actual bitstream a compressor at a level boundary would produce. Column
//! ids within a row are strictly increasing (CSR invariant), so their
//! first-order deltas are small positive integers; LEB128 varints then give
//! ~1 byte per nonzero on clustered rows versus 4 uncompressed — which is
//! why the paper's accelerators ship compressed metadata between levels.

/// Encode a strictly-increasing column-id slice as delta + LEB128 varints.
/// First value is encoded absolutely (plus one, so empty ≠ zero).
pub fn encode_cols(cols: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(cols.len() + 4);
    let mut prev: i64 = -1;
    for &c in cols {
        debug_assert!((c as i64) > prev, "col_id must be strictly increasing");
        let delta = (c as i64 - prev) as u64; // ≥ 1
        push_varint(&mut out, delta);
        prev = c as i64;
    }
    out
}

/// Decode a [`encode_cols`] bitstream back to column ids.
pub fn decode_cols(bytes: &[u8]) -> Result<Vec<u32>, CodecError> {
    let mut out = Vec::new();
    let mut prev: i64 = -1;
    let mut pos = 0usize;
    while pos < bytes.len() {
        let (delta, next) = read_varint(bytes, pos)?;
        if delta == 0 {
            return Err(CodecError::ZeroDelta { pos });
        }
        let v = prev + delta as i64;
        if v > u32::MAX as i64 {
            return Err(CodecError::Overflow { pos });
        }
        out.push(v as u32);
        prev = v;
        pos = next;
    }
    Ok(out)
}

/// Codec failure modes.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum CodecError {
    #[error("truncated varint at byte {pos}")]
    Truncated { pos: usize },
    #[error("zero delta at byte {pos} (col_id not strictly increasing)")]
    ZeroDelta { pos: usize },
    #[error("column id overflow at byte {pos}")]
    Overflow { pos: usize },
}

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8], mut pos: usize) -> Result<(u64, usize), CodecError> {
    let start = pos;
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = bytes.get(pos) else {
            return Err(CodecError::Truncated { pos: start });
        };
        pos += 1;
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok((v, pos));
        }
        shift += 7;
        if shift > 63 {
            return Err(CodecError::Overflow { pos: start });
        }
    }
}

/// Compression ratio (uncompressed bytes / encoded bytes) of a whole
/// matrix's metadata. Clustered (banded/FEM) matrices approach 4×; random
/// hypersparse rows approach ~1.3× — the statistic behind the paper's use
/// of CSR between levels.
pub fn metadata_compression_ratio(a: &crate::sparse::Csr) -> f64 {
    let mut encoded = 0usize;
    for i in 0..a.rows() {
        encoded += encode_cols(a.row_cols(i)).len();
    }
    if encoded == 0 {
        return 1.0;
    }
    (a.nnz() * 4) as f64 / encoded as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn round_trip_simple() {
        let cols = vec![0u32, 1, 2, 100, 1000, 1_000_000];
        let enc = encode_cols(&cols);
        assert_eq!(decode_cols(&enc).unwrap(), cols);
    }

    #[test]
    fn empty_row_is_empty_stream() {
        assert!(encode_cols(&[]).is_empty());
        assert_eq!(decode_cols(&[]).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn adjacent_runs_compress_to_one_byte_each() {
        // A run of consecutive ids: every delta = 1 = one varint byte.
        let cols: Vec<u32> = (10..200).collect();
        let enc = encode_cols(&cols);
        assert_eq!(enc.len(), cols.len());
    }

    #[test]
    fn round_trip_every_row_of_generated_matrices() {
        for (seed, profile) in [
            (1, Profile::Uniform),
            (2, Profile::PowerLaw { alpha: 0.7 }),
            (3, Profile::Banded { rel_bandwidth: 0.05, cluster: 4 }),
        ] {
            let a = generate(200, 4000, 3000, profile, seed);
            for i in 0..a.rows() {
                let enc = encode_cols(a.row_cols(i));
                assert_eq!(decode_cols(&enc).unwrap(), a.row_cols(i), "seed {seed} row {i}");
            }
        }
    }

    #[test]
    fn clustered_compresses_better_than_random() {
        let banded =
            generate(500, 5000, 10_000, Profile::Banded { rel_bandwidth: 0.01, cluster: 6 }, 4);
        let uniform = generate(500, 5000, 10_000, Profile::Uniform, 4);
        let rb = metadata_compression_ratio(&banded);
        let ru = metadata_compression_ratio(&uniform);
        assert!(rb > ru, "banded {rb:.2} vs uniform {ru:.2}");
        assert!(rb > 2.5, "clustered metadata must compress well, got {rb:.2}");
    }

    #[test]
    fn corrupt_streams_are_rejected() {
        assert_eq!(decode_cols(&[0x80]), Err(CodecError::Truncated { pos: 0 }));
        assert_eq!(decode_cols(&[0x00]), Err(CodecError::ZeroDelta { pos: 0 }));
        // 10-byte varint overflows the shift guard.
        let huge = vec![0xFF; 10];
        assert!(matches!(decode_cols(&huge), Err(CodecError::Overflow { .. })));
    }
}
