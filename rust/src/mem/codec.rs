//! CSR compressor / decompressor unit (the `C/D` blocks of paper Fig. 2).
//!
//! Baseline accelerators that buffer *uncompressed* rows must pass every
//! element through a C/D unit at the level boundary. Maple operates
//! *directly* on CSR data using metadata (paper §I: "there is no need to use
//! separate logic in the input and output ports of the Maple PE to perform
//! intersection and the CSR decompression functions"), so Maple-based
//! configurations only use C/D at the DRAM boundary.

use crate::trace::Counters;

/// A counted compress/decompress unit.
#[derive(Debug, Clone, Default)]
pub struct CsrCodec {
    elems: u64,
}

impl CsrCodec {
    /// New codec.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pass `n` elements through the decompressor (CSR → expanded form).
    pub fn decompress(&mut self, c: &mut Counters, n: u64) {
        c.cd_elems += n;
        self.elems += n;
    }

    /// Pass `n` elements through the compressor (row → CSR).
    pub fn compress(&mut self, c: &mut Counters, n: u64) {
        c.cd_elems += n;
        self.elems += n;
    }

    /// Total elements processed by this unit.
    pub fn total_elems(&self) -> u64 {
        self.elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_counts_both_directions() {
        let mut cd = CsrCodec::new();
        let mut c = Counters::default();
        cd.decompress(&mut c, 10);
        cd.compress(&mut c, 5);
        assert_eq!(c.cd_elems, 15);
        assert_eq!(cd.total_elems(), 15);
    }
}
