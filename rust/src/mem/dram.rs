//! DRAM (L2) model: bandwidth-limited, burst-granular, with a row-buffer
//! locality bonus for streaming CSR arrays (which is how every row-wise
//! product accelerator reads its operands).

use crate::trace::Counters;

/// DRAM timing parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramParams {
    /// Peak words (32-bit) per accelerator cycle across all channels.
    pub words_per_cycle: f64,
    /// Cycles of fixed latency for the first beat of a transaction.
    pub access_latency: u64,
    /// Words per burst; short transfers round up to a burst.
    pub burst_words: u64,
}

impl Default for DramParams {
    fn default() -> Self {
        // 1 GHz accelerator with ~64 GB/s DRAM: 16 words/cycle;
        // DDR4-class 60 ns first-word latency at 1 GHz ≈ 60 cycles.
        DramParams { words_per_cycle: 16.0, access_latency: 60, burst_words: 16 }
    }
}

/// A counted DRAM port shared by the whole accelerator.
#[derive(Debug, Clone)]
pub struct DramModel {
    params: DramParams,
    /// Absolute cycle at which the port next frees up (for contention).
    busy_until: u64,
    total_transactions: u64,
}

impl DramModel {
    /// New idle DRAM port.
    pub fn new(params: DramParams) -> Self {
        Self { params, busy_until: 0, total_transactions: 0 }
    }

    /// Issue a read of `words` at time `now`; counts traffic and returns the
    /// completion cycle given port contention.
    pub fn read(&mut self, c: &mut Counters, now: u64, words: u64) -> u64 {
        c.dram_read += words;
        self.schedule(now, words)
    }

    /// Issue a write of `words` at time `now`.
    pub fn write(&mut self, c: &mut Counters, now: u64, words: u64) -> u64 {
        c.dram_write += words;
        self.schedule(now, words)
    }

    fn schedule(&mut self, now: u64, words: u64) -> u64 {
        self.total_transactions += 1;
        let burst = self.params.burst_words.max(1);
        let padded = words.div_ceil(burst) * burst;
        let xfer = (padded as f64 / self.params.words_per_cycle).ceil() as u64;
        let start = now.max(self.busy_until);
        let done = start + self.params.access_latency + xfer;
        self.busy_until = start + xfer; // pipelined: latency overlaps next txn
        done
    }

    /// Transactions issued so far.
    pub fn transactions(&self) -> u64 {
        self.total_transactions
    }

    /// Cycle at which the port frees up.
    pub fn busy_until(&self) -> u64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_words_and_schedules() {
        let mut d =
            DramModel::new(DramParams { words_per_cycle: 4.0, access_latency: 10, burst_words: 4 });
        let mut c = Counters::default();
        let t1 = d.read(&mut c, 0, 8); // 2 cycles xfer + 10 latency
        assert_eq!(c.dram_read, 8);
        assert_eq!(t1, 12);
        // Second txn starts when port frees (cycle 2), not at t1.
        let t2 = d.read(&mut c, 0, 4);
        assert_eq!(t2, 2 + 10 + 1);
        assert_eq!(d.transactions(), 2);
    }

    #[test]
    fn short_transfers_round_to_burst() {
        let mut d =
            DramModel::new(DramParams { words_per_cycle: 4.0, access_latency: 0, burst_words: 16 });
        let mut c = Counters::default();
        let t = d.write(&mut c, 0, 1);
        // 1 word pads to 16 -> 4 cycles.
        assert_eq!(t, 4);
        assert_eq!(c.dram_write, 1, "traffic counts real words, timing counts bursts");
    }

    #[test]
    fn contention_serialises_back_to_back() {
        let mut d = DramModel::new(DramParams::default());
        let mut c = Counters::default();
        let a = d.read(&mut c, 0, 1600);
        let b = d.read(&mut c, 0, 1600);
        assert!(b > a, "second transaction must finish later");
    }
}
