//! Scratch-pad memory (L1 storage element: SpAL, SpBL, LLB, POB).
//!
//! Capacity-checked and counted. The simulator stages CSR rows through these
//! buffers; when a working set exceeds capacity the excess traffic spills to
//! DRAM — the effect that makes L1 sizing matter in the baselines.

use super::Lane;
use crate::trace::Counters;

/// A counted scratchpad with an occupancy model.
#[derive(Debug, Clone)]
pub struct Scratchpad {
    name: &'static str,
    lane: Lane,
    capacity_words: u64,
    occupied_words: u64,
    high_water: u64,
    /// Words that could not be held and had to be re-fetched from the level
    /// above (capacity misses).
    spilled_words: u64,
}

impl Scratchpad {
    /// New empty scratchpad of `capacity_bytes`.
    pub fn new(name: &'static str, lane: Lane, capacity_bytes: usize) -> Self {
        Self {
            name,
            lane,
            capacity_words: (capacity_bytes / 4) as u64,
            occupied_words: 0,
            high_water: 0,
            spilled_words: 0,
        }
    }

    /// Component name (for reports).
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Capacity in 32-bit words.
    pub fn capacity_words(&self) -> u64 {
        self.capacity_words
    }

    /// Try to allocate `words` of residency; returns how many words fit.
    /// The remainder is recorded as spilled.
    pub fn allocate(&mut self, words: u64) -> u64 {
        let free = self.capacity_words.saturating_sub(self.occupied_words);
        let fit = words.min(free);
        self.occupied_words += fit;
        self.high_water = self.high_water.max(self.occupied_words);
        self.spilled_words += words - fit;
        fit
    }

    /// Release `words` of residency (tile retired).
    pub fn free(&mut self, words: u64) {
        self.occupied_words = self.occupied_words.saturating_sub(words);
    }

    /// Counted read of `words` from this scratchpad.
    pub fn read(&self, c: &mut Counters, words: u64) {
        super::read(c, self.lane, words);
    }

    /// Counted write of `words` into this scratchpad.
    pub fn write(&self, c: &mut Counters, words: u64) {
        super::write(c, self.lane, words);
    }

    /// Peak residency seen.
    pub fn high_water(&self) -> u64 {
        self.high_water
    }

    /// Words that exceeded capacity.
    pub fn spilled_words(&self) -> u64 {
        self.spilled_words
    }

    /// Current occupancy.
    pub fn occupied_words(&self) -> u64 {
        self.occupied_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_respects_capacity() {
        let mut s = Scratchpad::new("LLB", Lane::L1, 64); // 16 words
        assert_eq!(s.allocate(10), 10);
        assert_eq!(s.allocate(10), 6);
        assert_eq!(s.spilled_words(), 4);
        assert_eq!(s.high_water(), 16);
        s.free(8);
        assert_eq!(s.occupied_words(), 8);
        assert_eq!(s.allocate(4), 4);
    }

    #[test]
    fn reads_and_writes_land_on_lane() {
        let s = Scratchpad::new("POB", Lane::Pob, 1024);
        let mut c = Counters::default();
        s.read(&mut c, 5);
        s.write(&mut c, 3);
        assert_eq!(c.pob_read, 5);
        assert_eq!(c.pob_write, 3);
    }

    #[test]
    fn free_never_underflows() {
        let mut s = Scratchpad::new("SpAL", Lane::L1, 16);
        s.free(100);
        assert_eq!(s.occupied_words(), 0);
    }
}
