//! Bounded FIFO — the structural model behind ARB and BRB (paper §III:
//! "the multiply operation requires two FIFO buffers to store non-zero
//! elements ..."). Tracks high-water mark and stall events so buffer-sizing
//! sweeps can see when a configuration would have back-pressured.

/// A bounded FIFO with occupancy statistics.
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    buf: std::collections::VecDeque<T>,
    capacity: usize,
    high_water: usize,
    /// Pushes rejected because the FIFO was full.
    stalls: u64,
    total_pushes: u64,
}

impl<T> Fifo<T> {
    /// A FIFO holding at most `capacity` entries.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "FIFO capacity must be positive");
        Self {
            buf: std::collections::VecDeque::with_capacity(capacity),
            capacity,
            high_water: 0,
            stalls: 0,
            total_pushes: 0,
        }
    }

    /// Try to enqueue; returns the value back on overflow (a stall).
    pub fn push(&mut self, v: T) -> Result<(), T> {
        if self.buf.len() == self.capacity {
            self.stalls += 1;
            return Err(v);
        }
        self.buf.push_back(v);
        self.total_pushes += 1;
        self.high_water = self.high_water.max(self.buf.len());
        Ok(())
    }

    /// Dequeue the oldest entry.
    pub fn pop(&mut self) -> Option<T> {
        self.buf.pop_front()
    }

    /// Current occupancy.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True when at capacity.
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.capacity
    }

    /// Configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Maximum occupancy ever reached.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Number of rejected pushes.
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Total accepted pushes.
    pub fn total_pushes(&self) -> u64 {
        self.total_pushes
    }

    /// Drop all contents (end of a row/tile), keeping statistics.
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut f = Fifo::new(2);
        assert!(f.push(1).is_ok());
        assert!(f.push(2).is_ok());
        assert!(f.is_full());
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.stalls(), 1);
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        f.pop();
        f.pop();
        assert_eq!(f.high_water(), 5);
        assert_eq!(f.len(), 3);
        f.clear();
        assert!(f.is_empty());
        assert_eq!(f.high_water(), 5);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u32>::new(0);
    }
}
