//! Storage-element models (paper Fig. 2): DRAM (L2), scratch-pad storage
//! elements (L1: SpAL/SpBL/LLB/POB), PE-local buffers (L0: sorting queues,
//! PEB, and Maple's ARB/BRB/PSB), and the CSR compressor/decompressor units
//! that sit between levels.
//!
//! Every model is *counted*: each access lands in the run's
//! [`Counters`](crate::trace::Counters) so the energy aggregation sees
//! exactly what the functional simulation did.

mod codec;
pub mod delta;
mod dram;
mod fifo;
mod spm;

pub use codec::CsrCodec;
pub use dram::{DramModel, DramParams};
pub use fifo::Fifo;
pub use spm::Scratchpad;

use crate::trace::Counters;

/// Which counter lane a storage access belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Maple A-row buffer (L0 register file).
    Arb,
    /// Maple B-rows buffer (L0 register file).
    Brb,
    /// Maple partial-sum buffer (L0 register file).
    Psb,
    /// Matraptor sorting queues (L0 SRAM).
    Queue,
    /// Extensor PE buffer (L0 SRAM).
    Peb,
    /// L1 storage element (SpAL/SpBL or LLB).
    L1,
    /// Extensor partial-output buffer (L1).
    Pob,
    /// DRAM (L2).
    Dram,
}

/// Record `words` 32-bit reads on `lane`.
#[inline]
pub fn read(c: &mut Counters, lane: Lane, words: u64) {
    match lane {
        Lane::Arb => c.arb_read += words,
        Lane::Brb => c.brb_read += words,
        Lane::Psb => c.psb_read += words,
        Lane::Queue => c.queue_read += words,
        Lane::Peb => c.peb_read += words,
        Lane::L1 => c.l1_read += words,
        Lane::Pob => c.pob_read += words,
        Lane::Dram => c.dram_read += words,
    }
}

/// Record `words` 32-bit writes on `lane`.
#[inline]
pub fn write(c: &mut Counters, lane: Lane, words: u64) {
    match lane {
        Lane::Arb => c.arb_write += words,
        Lane::Brb => c.brb_write += words,
        Lane::Psb => c.psb_write += words,
        Lane::Queue => c.queue_write += words,
        Lane::Peb => c.peb_write += words,
        Lane::L1 => c.l1_write += words,
        Lane::Pob => c.pob_write += words,
        Lane::Dram => c.dram_write += words,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_route_to_the_right_counter() {
        let mut c = Counters::default();
        read(&mut c, Lane::Arb, 3);
        write(&mut c, Lane::Psb, 2);
        read(&mut c, Lane::Dram, 7);
        write(&mut c, Lane::Pob, 4);
        assert_eq!(c.arb_read, 3);
        assert_eq!(c.psb_write, 2);
        assert_eq!(c.dram_read, 7);
        assert_eq!(c.pob_write, 4);
        assert_eq!(c.l1_read, 0);
    }
}
