//! Action tracing: every architectural event the simulator produces.
//!
//! This is the Sparseloop/Accelergy substitution's backbone (DESIGN.md §2):
//! Accelergy computes `energy = Σ_component count(action) × pJ(action)`;
//! our simulator produces the same per-component action counts from a real
//! functional execution, and [`crate::energy`] supplies the pJ table.
//! Counters are plain `u64` fields so the hot loop pays one increment per
//! event — no hashing, no allocation.

/// Per-component action counts for one simulation run.
///
/// Component naming follows the paper (Fig. 2 and §IV.B):
/// * `arb/brb/psb` — the Maple PE register buffers (L0),
/// * `queue` — Matraptor's per-PE sorting queues (L0, SRAM),
/// * `peb` — Extensor's per-PE buffer (L0, SRAM),
/// * `l1` — SpAL/SpBL (Matraptor) or LLB (Extensor),
/// * `pob` — Extensor's partial-output buffer (L1),
/// * `dram` — L2. All read/write counts are in 32-bit words.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    // -- compute --
    /// Scalar multiplications (Eq. 3 events).
    pub mac_mul: u64,
    /// Scalar additions into partial/final sums (Eq. 7 events).
    pub mac_add: u64,
    /// Index comparisons inside intersection units.
    pub intersect_cmp: u64,
    /// Elements passed through CSR compress/decompress units.
    pub cd_elems: u64,

    // -- L0: register buffers (Maple) --
    pub arb_read: u64,
    pub arb_write: u64,
    pub brb_read: u64,
    pub brb_write: u64,
    pub psb_read: u64,
    pub psb_write: u64,

    // -- L0: SRAM PE buffers (baselines) --
    /// Matraptor sorting-queue accesses.
    pub queue_read: u64,
    pub queue_write: u64,
    /// Extensor PEB accesses.
    pub peb_read: u64,
    pub peb_write: u64,

    // -- L1 --
    pub l1_read: u64,
    pub l1_write: u64,
    /// Extensor partial-output-buffer traffic (absent in Maple-based config).
    pub pob_read: u64,
    pub pob_write: u64,

    // -- L2 --
    pub dram_read: u64,
    pub dram_write: u64,

    // -- interconnect --
    /// 32-bit flit-hops through the NoC / crossbar.
    pub noc_flit_hops: u64,
}

impl Counters {
    /// Element-wise sum (merging per-PE counters into a run total).
    pub fn merge(&mut self, o: &Counters) {
        self.mac_mul += o.mac_mul;
        self.mac_add += o.mac_add;
        self.intersect_cmp += o.intersect_cmp;
        self.cd_elems += o.cd_elems;
        self.arb_read += o.arb_read;
        self.arb_write += o.arb_write;
        self.brb_read += o.brb_read;
        self.brb_write += o.brb_write;
        self.psb_read += o.psb_read;
        self.psb_write += o.psb_write;
        self.queue_read += o.queue_read;
        self.queue_write += o.queue_write;
        self.peb_read += o.peb_read;
        self.peb_write += o.peb_write;
        self.l1_read += o.l1_read;
        self.l1_write += o.l1_write;
        self.pob_read += o.pob_read;
        self.pob_write += o.pob_write;
        self.dram_read += o.dram_read;
        self.dram_write += o.dram_write;
        self.noc_flit_hops += o.noc_flit_hops;
    }

    /// Total multiply-accumulate operations.
    pub fn mac_ops(&self) -> u64 {
        self.mac_mul
    }

    /// Total L0 accesses (registers + PE SRAM), the paper's `L0 ↔ MAC` lane.
    pub fn l0_accesses(&self) -> u64 {
        self.arb_read
            + self.arb_write
            + self.brb_read
            + self.brb_write
            + self.psb_read
            + self.psb_write
    }

    /// PE-buffer (SRAM) accesses, the paper's `PE ↔ MAC` lane.
    pub fn pe_buffer_accesses(&self) -> u64 {
        self.queue_read + self.queue_write + self.peb_read + self.peb_write
    }

    /// L1 accesses including POB.
    pub fn l1_accesses(&self) -> u64 {
        self.l1_read + self.l1_write + self.pob_read + self.pob_write
    }

    /// DRAM word accesses.
    pub fn dram_accesses(&self) -> u64 {
        self.dram_read + self.dram_write
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_adds_fieldwise() {
        let mut a = Counters { mac_mul: 3, dram_read: 5, ..Default::default() };
        let b = Counters { mac_mul: 2, psb_write: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.mac_mul, 5);
        assert_eq!(a.psb_write, 7);
        assert_eq!(a.dram_read, 5);
    }

    #[test]
    fn lane_rollups() {
        let c = Counters {
            arb_read: 1,
            brb_write: 2,
            psb_read: 3,
            queue_read: 10,
            peb_write: 20,
            l1_read: 5,
            pob_write: 6,
            dram_read: 7,
            dram_write: 8,
            ..Default::default()
        };
        assert_eq!(c.l0_accesses(), 6);
        assert_eq!(c.pe_buffer_accesses(), 30);
        assert_eq!(c.l1_accesses(), 11);
        assert_eq!(c.dram_accesses(), 15);
    }
}
