//! The flag scanner and shared argument parsers of the `maple` CLI.
//!
//! One grid definition, many drivers: [`space_from_args`] builds the
//! design space that `sweep`, `explore`, `serve`, and `chaos` all run, so
//! an explore result is always checkable against the sweep of the same
//! flags. The legacy `--macs` shorthand is deprecated: it still works, but
//! warns and rewrites itself to the typed `--axis macs=...` form.

use crate::config::{axis, AcceleratorConfig, ConfigAxis};
use crate::coordinator::Policy;
use crate::sim::{Axis, CellModel, DesignSpace, SimEngine, WorkloadKey};
use crate::sparse::{gen, suite, TileShape};

/// Dependency-free CLI error type.
pub type CliError = Box<dyn std::error::Error>;
pub type CliResult<T = ()> = Result<T, CliError>;

/// Minimal `--key value` / flag argument scanner.
pub struct Args {
    pub argv: Vec<String>,
}

impl Args {
    pub fn new(argv: Vec<String>) -> Self {
        Self { argv }
    }

    /// Value of `--key`, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.argv
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.argv.get(i + 1))
            .map(|s| s.as_str())
    }

    /// Value of `--key` or a default.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// Every value of a repeatable `--key` flag, in argv order. A trailing
    /// occurrence with no following value yields nothing — compare against
    /// [`Args::count`] to reject it instead of silently dropping it.
    pub fn opt_all(&self, key: &str) -> Vec<&str> {
        self.argv
            .iter()
            .enumerate()
            .filter(|(_, a)| a.as_str() == key)
            .filter_map(|(i, _)| self.argv.get(i + 1))
            .map(|s| s.as_str())
            .collect()
    }

    /// How many times `--key` appears.
    pub fn count(&self, key: &str) -> usize {
        self.argv.iter().filter(|a| a.as_str() == key).count()
    }

    /// Presence of a bare flag.
    pub fn flag(&self, key: &str) -> bool {
        self.argv.iter().any(|a| a == key)
    }

    /// Parsed value of `--key` or a default.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> CliResult<T> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value for {key}: {v}").into()),
        }
    }
}

/// The first positional (non-flag) argument, skipping the *values* of the
/// listed value-bearing flags — `merge --bench-json out.json shards/` must
/// not read `out.json` as the directory. Shared by `merge` and `ingest`.
pub fn positional<'a>(args: &'a Args, value_flags: &[&str]) -> Option<&'a str> {
    args.argv
        .iter()
        .enumerate()
        .find(|(i, s)| {
            !s.starts_with("--")
                && (*i == 0 || !value_flags.contains(&args.argv[i - 1].as_str()))
        })
        .map(|(_, s)| s.as_str())
}

/// A built-in preset configuration, if `name` names one.
pub fn parse_preset(name: &str) -> Option<AcceleratorConfig> {
    match name {
        "matraptor-baseline" => Some(AcceleratorConfig::matraptor_baseline()),
        "matraptor-maple" => Some(AcceleratorConfig::matraptor_maple()),
        "extensor-baseline" => Some(AcceleratorConfig::extensor_baseline()),
        "extensor-maple" => Some(AcceleratorConfig::extensor_maple()),
        _ => None,
    }
}

/// The raw text of a `--config` file argument.
pub fn read_config_file(path: &str) -> CliResult<String> {
    std::fs::read_to_string(path)
        .map_err(|e| format!("config {path} is not a preset and not readable: {e}").into())
}

/// A `--config` argument: a preset name first, then a TOML file path.
pub fn parse_config(name: &str) -> CliResult<AcceleratorConfig> {
    match parse_preset(name) {
        Some(cfg) => Ok(cfg),
        None => Ok(AcceleratorConfig::from_toml(&read_config_file(name)?)?),
    }
}

/// Engine for one CLI invocation: disk-cache-backed (warm-start) per the
/// shared env contract ([`SimEngine::from_env`]: `MAPLE_CACHE_DIR`,
/// `MAPLE_NO_CACHE`) unless the user passed `--no-cache`.
pub fn make_engine(args: &Args) -> SimEngine {
    if args.flag("--no-cache") {
        return SimEngine::new();
    }
    SimEngine::from_env()
}

/// A `--policy` point.
pub fn parse_policy(name: &str) -> CliResult<Policy> {
    match name {
        "round-robin" => Ok(Policy::RoundRobin),
        "chunked" => Ok(Policy::Chunked),
        "greedy" => Ok(Policy::GreedyBalance),
        other => Err(format!("unknown policy {other}").into()),
    }
}

/// The `--cell-model` flag (analytic when absent).
pub fn parse_cell_model(args: &Args) -> CliResult<CellModel> {
    args.opt_or("--cell-model", "analytic").parse::<CellModel>().map_err(CliError::from)
}

/// Canonical Table-I abbreviations for a `--datasets` list (comma-separated
/// names or abbreviations); the whole suite when the flag is absent or
/// spelled `all`.
pub fn dataset_names(datasets: Option<&str>) -> CliResult<Vec<&'static str>> {
    match datasets {
        Some("all") => Ok(suite::TABLE_I.iter().map(|d| d.abbrev).collect()),
        Some(list) => list
            .split(',')
            .map(|s| {
                suite::by_name(s.trim())
                    .map(|d| d.abbrev)
                    .ok_or_else(|| CliError::from(format!("unknown dataset {s}")))
            })
            .collect(),
        None => Ok(suite::TABLE_I.iter().map(|d| d.abbrev).collect()),
    }
}

/// `--mem-budget` byte counts: a plain number or one with a K/M/G
/// binary-unit suffix (`64M` = 64 MiB).
pub fn parse_mem_budget(spec: &str) -> CliResult<u64> {
    let s = spec.trim();
    let (digits, unit) = match s.as_bytes().last() {
        Some(b'K' | b'k') => (&s[..s.len() - 1], 1u64 << 10),
        Some(b'M' | b'm') => (&s[..s.len() - 1], 1u64 << 20),
        Some(b'G' | b'g') => (&s[..s.len() - 1], 1u64 << 30),
        _ => (s, 1),
    };
    let n: u64 = digits
        .parse()
        .map_err(|_| CliError::from(format!("bad --mem-budget {spec} (expected N[K|M|G])")))?;
    n.checked_mul(unit).ok_or_else(|| format!("--mem-budget {spec} overflows u64").into())
}

/// A `--gen` family spec that is not a Table-I name:
/// `uniform`, `powerlaw:ALPHA`, or `banded:REL_BW:CLUSTER`.
pub fn parse_gen_profile(spec: &str) -> CliResult<gen::Profile> {
    let mut parts = spec.split(':');
    let kind = parts.next().unwrap_or("");
    let parsed = match kind {
        "uniform" => Some(gen::Profile::Uniform),
        "powerlaw" => parts
            .next()
            .and_then(|v| v.parse().ok())
            .map(|alpha| gen::Profile::PowerLaw { alpha }),
        "banded" => {
            let bw = parts.next().and_then(|v| v.parse().ok());
            let cl = parts.next().and_then(|v| v.parse().ok());
            match (bw, cl) {
                (Some(rel_bandwidth), Some(cluster)) => {
                    Some(gen::Profile::Banded { rel_bandwidth, cluster })
                }
                _ => None,
            }
        }
        _ => None,
    };
    match parsed {
        Some(p) if parts.next().is_none() => Ok(p),
        _ => Err(format!(
            "bad --gen {spec}: expected a Table-I dataset name or \
             uniform | powerlaw:ALPHA | banded:REL_BW:CLUSTER"
        )
        .into()),
    }
}

/// The `--tile` flag as a [`TileShape`]; `4096x4096` when absent (a shape
/// big enough that small matrices degenerate to the untiled pass).
pub fn parse_tile(args: &Args) -> CliResult<TileShape> {
    TileShape::parse(args.opt_or("--tile", "4096"))
        .map_err(|e| format!("bad --tile value: {e}").into())
}

/// Build the design space shared by `sweep`, `explore`, `serve`, and
/// `chaos` from the `--config`/`--datasets`/`--axis`/`--policy`/`--scale`/
/// `--seed` flags (one grid definition, many drivers — an explore result
/// is always checkable against the sweep of the same flags).
///
/// Config axes: the [sweep] block of a --config TOML file first, then
/// every repeatable --axis flag (including the operand-format axis,
/// `--axis fmt=csr,csc,coo,bitmap,blocked`), then the deprecated --macs
/// shorthand — which warns and rewrites itself to `--axis macs=...`; with
/// no axis at all (and a single base config), the historical default
/// MACs/PE sweep. Presets resolve before the filesystem (same order as
/// [`parse_config`]), so only a genuinely loaded file contributes a
/// [sweep] block. `--config paper` sweeps the four paper configurations as
/// the base set — the Table-I / Fig.-9 grid — with no implicit default
/// axis. `--pivot`, when present, is validated against the axis names here
/// so a typo fails in milliseconds, not after minutes of simulation.
pub fn space_from_args(args: &Args) -> CliResult<DesignSpace> {
    let config_arg = args.opt_or("--config", "extensor-maple");
    let (bases, mut axes): (Vec<AcceleratorConfig>, Vec<ConfigAxis>) = if config_arg == "paper" {
        (AcceleratorConfig::paper_configs(), Vec::new())
    } else {
        match parse_preset(config_arg) {
            Some(cfg) => (vec![cfg], Vec::new()),
            None => {
                let s = read_config_file(config_arg)?;
                (vec![AcceleratorConfig::from_toml(&s)?], axis::sweep_axes_from_toml(&s)?)
            }
        }
    };
    let scale = args.parse_or("--scale", 4usize)?;
    let seed = args.parse_or("--seed", 7u64)?;
    let datasets = args.opt("--datasets").or_else(|| args.opt("--dataset"));
    let keys: Vec<WorkloadKey> = dataset_names(Some(datasets.unwrap_or("wikiVote")))?
        .iter()
        .map(|&n| WorkloadKey::suite(n, seed, scale))
        .collect();

    let axis_flags = args.opt_all("--axis");
    if axis_flags.len() != args.count("--axis") {
        return Err("--axis expects a following name=v1,v2,... value".into());
    }
    for spec in axis_flags {
        let (name, values) = spec.split_once('=').ok_or_else(|| {
            CliError::from(format!("--axis expects name=v1,v2,... (got {spec:?})"))
        })?;
        axes.push(ConfigAxis::parse(name, values)?);
    }
    // The retired shorthand: still honoured, loudly, as its typed form.
    if let Some(macs) = args.opt("--macs") {
        eprintln!("warning: --macs is deprecated, use --axis macs={macs}");
        axes.push(ConfigAxis::parse("macs", macs)?);
    }
    if axes.is_empty() && bases.len() == 1 {
        axes.push(ConfigAxis::parse("macs", "1,2,4,8,16,32")?);
    }
    if let Some(p) = args.opt("--pivot") {
        let mut known = vec!["dataset", "config"];
        known.extend(axes.iter().map(|a| a.name()));
        known.push("policy");
        if !known.contains(&p) {
            return Err(format!(
                "--pivot {p}: not an axis of this sweep (expected one of: {})",
                known.join(", ")
            )
            .into());
        }
    }
    let policies: Vec<Policy> = args
        .opt_or("--policy", "round-robin")
        .split(',')
        .map(|p| parse_policy(p.trim()))
        .collect::<CliResult<_>>()?;

    let model = parse_cell_model(args)?;
    let mut space = DesignSpace::over(bases).with_cell_model(model).with_axis(Axis::Dataset(keys));
    for a in axes {
        space = space.with_axis(Axis::Config(a));
    }
    Ok(space.with_axis(Axis::Policy(policies)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Args {
        Args::new(list.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn flag_scanner_basics() {
        let a = args(&["--seed", "9", "--axis", "macs=2,4", "--axis", "fmt=csr,coo", "--csv"]);
        assert_eq!(a.opt("--seed"), Some("9"));
        assert_eq!(a.opt_or("--scale", "4"), "4");
        assert_eq!(a.opt_all("--axis"), ["macs=2,4", "fmt=csr,coo"]);
        assert_eq!(a.count("--axis"), 2);
        assert!(a.flag("--csv") && !a.flag("--quiet"));
        assert_eq!(a.parse_or("--seed", 7u64).unwrap(), 9);
        assert!(a.parse_or("--axis", 0u64).is_err());
    }

    #[test]
    fn positional_skips_value_flag_values() {
        let a = args(&["--bench-json", "out.json", "shards"]);
        assert_eq!(positional(&a, &["--bench-json"]), Some("shards"));
        assert_eq!(positional(&a, &[]), Some("out.json"));
        assert_eq!(positional(&args(&["--csv"]), &[]), None);
    }

    #[test]
    fn deprecated_macs_rewrites_to_the_typed_axis() {
        let legacy = space_from_args(&args(&["--dataset", "wv", "--macs", "2,4"])).unwrap();
        let typed = space_from_args(&args(&["--dataset", "wv", "--axis", "macs=2,4"])).unwrap();
        assert_eq!(legacy.fingerprint().unwrap(), typed.fingerprint().unwrap());
    }

    #[test]
    fn format_axis_parses_and_defaults_stay_put() {
        let space = space_from_args(&args(&[
            "--dataset",
            "wv",
            "--axis",
            "fmt=csr,csc,coo,bitmap,blocked",
        ]))
        .unwrap();
        let fmt = space.axes.iter().find(|a| a.name() == "fmt").expect("fmt axis");
        assert_eq!(fmt.len(), 5);
        // No axis at all still expands the historical default MACs sweep.
        let plain = space_from_args(&args(&["--dataset", "wv"])).unwrap();
        assert!(plain.axes.iter().any(|a| a.name() == "macs"));
        // A typo'd pivot fails fast, before any simulation.
        let bad = space_from_args(&args(&["--dataset", "wv", "--pivot", "warp"]));
        assert!(bad.is_err());
    }

    #[test]
    fn dataset_lists_and_misc_parsers() {
        assert_eq!(dataset_names(Some("wv,fb")).unwrap(), ["wv", "fb"]);
        assert_eq!(dataset_names(Some("all")).unwrap().len(), suite::TABLE_I.len());
        assert!(dataset_names(Some("nope")).is_err());
        assert_eq!(parse_mem_budget("64M").unwrap(), 64 << 20);
        assert_eq!(parse_mem_budget("123").unwrap(), 123);
        assert!(parse_mem_budget("lots").is_err());
        assert!(matches!(parse_gen_profile("uniform").unwrap(), gen::Profile::Uniform));
        assert!(parse_gen_profile("banded:0.1").is_err());
        assert!(parse_preset("extensor-maple").is_some());
        assert!(parse_preset("warp-core").is_none());
        assert!(parse_policy("greedy").is_ok() && parse_policy("jittery").is_err());
    }
}
