//! Command-line front-end support shared by the `maple` binary.
//!
//! The binary itself ([`crate`]'s `main.rs`) only dispatches commands and
//! renders output; everything that *interprets* arguments — the flag
//! scanner, the config/preset/policy parsers, and the design-space builder
//! shared by `sweep`, `explore`, `serve`, and `chaos` — lives in
//! [`args`], so every command parses the same flag the same way and unit
//! tests can exercise parsing without spawning a process. Argument parsing
//! is in-tree: the offline build has no CLI dependency (DESIGN.md
//! §Dependencies).

pub mod args;

pub use args::{
    dataset_names, make_engine, parse_cell_model, parse_config, parse_gen_profile,
    parse_mem_budget, parse_policy, parse_preset, parse_tile, positional, read_config_file,
    space_from_args, Args, CliError, CliResult,
};
