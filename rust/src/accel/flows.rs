//! Run-level memory-hierarchy and interconnect flows.
//!
//! The PE cost models count PE-*local* actions; everything that moves data
//! *between* levels is charged here, per configuration (paper Fig. 2):
//!
//! * DRAM: compulsory CSR streaming — operands in once, result out once.
//!   Both baseline and Maple configurations are charged identically; the
//!   reference dataflows achieve this with their L1 tiling, and Maple's
//!   direct-to-PE path is reflected in the NoC hop counts and the removed
//!   L1 lanes instead (DESIGN.md §Modeling).
//! * L1 (SpAL/SpBL or LLB): staged writes once per operand element, reads
//!   once per product-side operand delivery.
//! * C/D: CSR codec elements at the DRAM boundary for all configs; the
//!   baselines also decompress/compress at the L1↔L0 boundary, Maple does
//!   not ("no need to use separate logic ... to perform intersection and
//!   the CSR decompression functions", §I).
//! * NoC: flit-hops for every transfer, with topology-aware mean hop counts.

use crate::config::{AcceleratorConfig, AcceleratorKind, PeKind};
use crate::noc::{Noc, Topology};
use crate::sim::Workload;
use crate::sparse::SparseFormat;
use crate::trace::Counters;

/// Mean hop count from the L1/DRAM port (endpoint 0) to all endpoints.
fn mean_hops(topology: Topology) -> f64 {
    let noc = Noc::new(topology);
    let n = noc.endpoints();
    let total: u64 = (0..n).map(|d| noc.hops(0, d)).sum();
    total as f64 / n as f64
}

/// Account all run-level flows for `cfg` into `c`.
pub fn account_run_flows(cfg: &AcceleratorConfig, w: &Workload, c: &mut Counters) {
    let a_words = w.fmt.a_words;
    let b_words = w.fmt.b_words;
    let c_words = w.fmt.c_words;
    let operand_delivery = 2 * w.total_products + 2 * w.nnz_a; // B + A streams to PEs

    // -- DRAM: compulsory operand streaming in the configured format, plus
    //    the plan's gather and conversion terms (all zero for native CSR,
    //    so CSR traffic is identical across configs) --
    c.dram_read += a_words + b_words + w.fmt.gather_words + w.fmt.convert_read_words;
    c.dram_write += c_words + w.fmt.convert_write_words;

    // -- codec at the DRAM boundary (all configs); a non-CSR operand
    //    format also re-encodes both operands through the converter --
    c.cd_elems += w.nnz_a + w.nnz_b + w.out_nnz;
    if w.fmt.format != SparseFormat::Csr {
        c.cd_elems += w.nnz_a + w.nnz_b;
    }

    let hops = mean_hops(cfg.noc).max(1.0);
    let flit = |words: u64, h: f64| (words as f64 * h).round() as u64;

    match (cfg.kind, cfg.pe.kind) {
        (AcceleratorKind::Matraptor, PeKind::Baseline) => {
            // DRAM → SpAL/SpBL staging, then per-product delivery to PEs.
            c.l1_write += a_words + b_words;
            c.l1_read += operand_delivery;
            // Baseline decompresses between L1 and L0 (Fig. 2 C/D units).
            c.cd_elems += w.total_products + w.nnz_a;
            // Crossbar: DRAM→L1 (1 hop), L1→PE (1 hop), PE→DRAM (1 hop).
            c.noc_flit_hops += flit(a_words + b_words, 1.0)
                + flit(operand_delivery, hops)
                + flit(c_words, hops);
        }
        (AcceleratorKind::Matraptor, PeKind::Maple) => {
            // Single memory level: DRAM streams straight into ARB/BRB.
            c.noc_flit_hops += flit(operand_delivery, hops) + flit(c_words, hops);
        }
        (AcceleratorKind::Extensor, PeKind::Baseline) => {
            c.l1_write += a_words + b_words;
            c.l1_read += operand_delivery;
            c.cd_elems += w.total_products + w.nnz_a;
            // Mesh: DRAM→LLB at the port, LLB→PE across the mesh, PE↔POB
            // traffic crosses the mesh too (POB at the port side).
            let pob_words = c.pob_read + c.pob_write;
            c.noc_flit_hops += flit(a_words + b_words, 1.0)
                + flit(operand_delivery, hops)
                + flit(pob_words, hops)
                + flit(c_words, hops);
        }
        (AcceleratorKind::Extensor, PeKind::Maple) => {
            // LLB retained; POB gone (§IV.B.4).
            c.l1_write += a_words + b_words;
            c.l1_read += operand_delivery;
            c.noc_flit_hops += flit(a_words + b_words, 1.0)
                + flit(operand_delivery, hops)
                + flit(c_words, hops);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sparse::gen::{generate, Profile};

    fn workload() -> Workload {
        let a = generate(100, 100, 800, Profile::Uniform, 7);
        profile_workload(&a, &a)
    }

    #[test]
    fn dram_traffic_identical_across_all_configs() {
        let w = workload();
        let mut totals = Vec::new();
        for cfg in AcceleratorConfig::paper_configs() {
            let mut c = Counters::default();
            account_run_flows(&cfg, &w, &mut c);
            totals.push((c.dram_read, c.dram_write));
        }
        assert!(totals.windows(2).all(|p| p[0] == p[1]), "{totals:?}");
    }

    #[test]
    fn maple_matraptor_has_no_l1_traffic() {
        let w = workload();
        let mut c = Counters::default();
        account_run_flows(&AcceleratorConfig::matraptor_maple(), &w, &mut c);
        assert_eq!(c.l1_read + c.l1_write, 0);
    }

    #[test]
    fn baselines_pay_level_boundary_codec() {
        let w = workload();
        let mut cb = Counters::default();
        let mut cm = Counters::default();
        account_run_flows(&AcceleratorConfig::matraptor_baseline(), &w, &mut cb);
        account_run_flows(&AcceleratorConfig::matraptor_maple(), &w, &mut cm);
        assert!(cb.cd_elems > cm.cd_elems);
        assert_eq!(cm.cd_elems, w.nnz_a + w.nnz_b + w.out_nnz);
    }

    #[test]
    fn extensor_maple_keeps_llb() {
        let w = workload();
        let mut c = Counters::default();
        account_run_flows(&AcceleratorConfig::extensor_maple(), &w, &mut c);
        assert!(c.l1_read > 0 && c.l1_write > 0);
        assert_eq!(c.pob_read + c.pob_write, 0);
    }

    #[test]
    fn non_csr_plans_add_gather_and_conversion_traffic() {
        let mut w = workload();
        let mut base = Counters::default();
        account_run_flows(&AcceleratorConfig::matraptor_maple(), &w, &mut base);
        w.fmt = crate::sparse::FormatPlan::from_totals(
            SparseFormat::Csc,
            w.rows,
            w.cols,
            w.rows_b,
            w.nnz_a,
            w.nnz_b,
            w.out_nnz,
        );
        let mut c = Counters::default();
        account_run_flows(&AcceleratorConfig::matraptor_maple(), &w, &mut c);
        assert!(c.dram_read > base.dram_read, "gather + convert reads charged");
        assert!(c.dram_write > base.dram_write, "convert writes charged");
        assert_eq!(c.cd_elems, base.cd_elems + w.nnz_a + w.nnz_b);
    }

    #[test]
    fn mesh_hops_exceed_crossbar_hops() {
        assert!(
            mean_hops(Topology::Mesh { width: 16, height: 8 })
                > mean_hops(Topology::Crossbar { ports: 8 })
        );
    }
}
