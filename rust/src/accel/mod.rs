//! Accelerator assemblies: the four evaluated machines (§IV.B), composed
//! from PE models, the coordinator's partition, run-level memory/NoC flows,
//! and the energy/area models.

mod area;
mod flows;

pub use area::{accelerator_pe_area, fig8, pe_area, Fig8Row};

use crate::config::AcceleratorConfig;
use crate::coordinator::{partition, Policy};
use crate::energy::EnergyBreakdown;
use crate::pe::{registry, PeModel};
use crate::sim::timeline::TwoStageTimeline;
use crate::sim::{SimResult, Workload};
use crate::trace::Counters;

/// One configured accelerator instance.
pub struct Accelerator {
    cfg: AcceleratorConfig,
}

impl Accelerator {
    /// Assemble from a configuration.
    pub fn new(cfg: AcceleratorConfig) -> Self {
        Self { cfg }
    }

    /// The configuration.
    pub fn config(&self) -> &AcceleratorConfig {
        &self.cfg
    }

    /// Instantiate the configured PE cost model via the open registry
    /// ([`crate::pe::registry`]): new PEs plug in with one `register` call
    /// plus a `cfg.pe.model` name, no change to this layer.
    ///
    /// Panics if the configuration names an unregistered model; use
    /// [`Accelerator::try_pe_model`] to handle that as an error.
    pub fn pe_model(&self) -> Box<dyn PeModel> {
        self.try_pe_model().expect("configured PE model is registered")
    }

    /// Fallible counterpart of [`Accelerator::pe_model`].
    pub fn try_pe_model(&self) -> Result<Box<dyn PeModel>, registry::RegistryError> {
        registry::build(&self.cfg)
    }

    /// Execute a profiled workload: PE timelines + run-level flows + energy.
    pub fn run(&self, w: &Workload, policy: Policy) -> SimResult {
        let pe = self.pe_model();
        // Column-tile giant output rows (both reference dataflows do) so a
        // single wide row cannot serialise one PE; threshold scales with the
        // workload's mean row work.
        let split_at = (4 * w.total_products / (w.rows as u64).max(1)).max(2048);
        let profiles = crate::coordinator::split_wide_rows(&w.profiles, split_at);
        let part = partition(policy, self.cfg.num_pes, &profiles);

        let mut counters = Counters::default();
        let mut max_pe_cycles = 0u64;

        // Per-PE two-stage pipeline with queue-decoupled overlap; the
        // composition (fill + slower aggregate stage + drain) lives in
        // [`crate::sim::timeline`].
        for rows in &part.assignments {
            let mut tl = TwoStageTimeline::new();
            for &r in rows {
                tl.push(pe.row_cost(&profiles[r as usize], &mut counters));
            }
            max_pe_cycles = max_pe_cycles.max(tl.makespan());
        }

        // Run-level memory-hierarchy and interconnect flows.
        flows::account_run_flows(&self.cfg, w, &mut counters);

        // Format conversion is a serial pre-pass through the converter, so
        // its cycles add to the DRAM-bound time rather than overlapping it.
        let dram_words = w.compulsory_dram_words();
        let cycles_dram_bound = (dram_words as f64 / self.cfg.dram.words_per_cycle).ceil() as u64
            + w.fmt.convert_cycles;

        let energy = EnergyBreakdown::from_counters(
            &counters,
            &crate::energy::TechModel::tech45(),
            &self.cfg.buffer_sizes(),
        );

        SimResult {
            config: self.cfg.name.clone(),
            cycles_compute: max_pe_cycles,
            cycles_dram_bound,
            cycles: max_pe_cycles.max(cycles_dram_bound),
            counters,
            energy,
            out_nnz: w.out_nnz,
            checksum: w.checksum,
            total_products: w.total_products,
            balance: part.balance(&profiles),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn pe_model_dispatch() {
        assert_eq!(
            Accelerator::new(AcceleratorConfig::matraptor_baseline()).pe_model().name(),
            "matraptor-baseline"
        );
        assert_eq!(
            Accelerator::new(AcceleratorConfig::matraptor_maple()).pe_model().name(),
            "maple"
        );
        assert_eq!(
            Accelerator::new(AcceleratorConfig::extensor_baseline()).pe_model().name(),
            "extensor-baseline"
        );
        assert_eq!(
            Accelerator::new(AcceleratorConfig::extensor_maple()).pe_model().name(),
            "maple"
        );
    }

    #[test]
    fn more_pes_fewer_cycles() {
        let a = generate(256, 256, 2600, Profile::Uniform, 23);
        let w = profile_workload(&a, &a);
        let mut small = AcceleratorConfig::extensor_maple();
        small.num_pes = 2;
        let mut large = AcceleratorConfig::extensor_maple();
        large.num_pes = 16;
        let rs = Accelerator::new(small).run(&w, Policy::RoundRobin);
        let rl = Accelerator::new(large).run(&w, Policy::RoundRobin);
        assert!(rl.cycles_compute < rs.cycles_compute);
    }

    #[test]
    fn pipeline_back_stage_overlaps() {
        // A config whose back stage is large must still be bounded by
        // Σ max(front, back) + last back, not Σ (front + back).
        let a = generate(64, 64, 640, Profile::Uniform, 29);
        let w = profile_workload(&a, &a);
        let cfg = AcceleratorConfig::extensor_baseline();
        let r = Accelerator::new(cfg.clone()).run(&w, Policy::RoundRobin);
        let pe = Accelerator::new(cfg).pe_model();
        // Serial upper bound.
        let mut serial = 0u64;
        let mut c = Counters::default();
        for p in &w.profiles {
            let cost = pe.row_cost(p, &mut c);
            serial += cost.front + cost.back;
        }
        assert!(r.cycles_compute <= serial);
    }
}
