//! PE and accelerator area assembly — reproduces the paper's Fig. 8.
//!
//! Fig. 8 splits PE area into MAC / buffers / logic and compares the
//! baseline PE complex against the Maple PE complex for each reference
//! accelerator; the headline ratios are **5.9×** (Matraptor) and **15.5×**
//! (Extensor) smaller total PE area ("Maple consumes 84% and 90% less
//! area", §IV.B.3 — the same comparison expressed as a percentage).

use crate::area::{adder_mm2, control_mm2, latch_mm2, mac_mm2, sram_mm2, PeArea};
use crate::config::{AcceleratorConfig, PeKind};

/// Area of one PE under `cfg`, split into Fig. 8's categories.
pub fn pe_area(cfg: &AcceleratorConfig) -> PeArea {
    let k = cfg.pe.macs_per_pe;
    match cfg.pe.kind {
        PeKind::Baseline => PeArea {
            mac_mm2: k as f64 * mac_mm2(),
            // Sorting queues (Matraptor) or PEB (Extensor) — SRAM macros.
            buffers_mm2: sram_mm2(cfg.pe.baseline_buffer_bytes()),
            logic_mm2: control_mm2(k),
        },
        PeKind::Maple => PeArea {
            mac_mm2: k as f64 * mac_mm2(),
            // ARB + BRB + PSB as latch arrays (value + col_id per entry).
            buffers_mm2: latch_mm2(cfg.pe.maple_buffer_bytes()),
            // "Maple logic consumes the most area because it uses more
            // computational components, such as parallel adders" (§IV.B.3):
            // the PSB accumulate tree is 2 adders per MAC lane, plus the
            // row_ptr control FSM (Fig. 7).
            logic_mm2: 2.0 * k as f64 * adder_mm2() + control_mm2(k),
        },
    }
}

/// Total PE-complex area (all PEs) under `cfg`.
pub fn accelerator_pe_area(cfg: &AcceleratorConfig) -> PeArea {
    pe_area(cfg).scaled(cfg.num_pes)
}

/// One row of the Fig. 8 report.
#[derive(Debug, Clone)]
pub struct Fig8Row {
    pub config: String,
    pub num_pes: usize,
    pub macs_per_pe: usize,
    pub mac_mm2: f64,
    pub buffers_mm2: f64,
    pub logic_mm2: f64,
    pub total_mm2: f64,
}

/// Produce the Fig. 8 comparison for one reference accelerator:
/// `(baseline_row, maple_row, area_ratio)`.
pub fn fig8(base: &AcceleratorConfig, maple: &AcceleratorConfig) -> (Fig8Row, Fig8Row, f64) {
    let row = |cfg: &AcceleratorConfig| {
        let a = accelerator_pe_area(cfg);
        Fig8Row {
            config: cfg.name.clone(),
            num_pes: cfg.num_pes,
            macs_per_pe: cfg.pe.macs_per_pe,
            mac_mm2: a.mac_mm2,
            buffers_mm2: a.buffers_mm2,
            logic_mm2: a.logic_mm2,
            total_mm2: a.total_mm2(),
        }
    };
    let rb = row(base);
    let rm = row(maple);
    let ratio = rb.total_mm2 / rm.total_mm2;
    (rb, rm, ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matraptor_area_ratio_in_paper_band() {
        // Paper: 5.9× smaller PE area (84% less).
        let (_, _, ratio) = fig8(
            &AcceleratorConfig::matraptor_baseline(),
            &AcceleratorConfig::matraptor_maple(),
        );
        assert!((4.5..7.5).contains(&ratio), "matraptor ratio {ratio:.2} vs paper 5.9");
    }

    #[test]
    fn extensor_area_ratio_in_paper_band() {
        // Paper: 15.5× smaller PE area (90% less).
        let (_, _, ratio) = fig8(
            &AcceleratorConfig::extensor_baseline(),
            &AcceleratorConfig::extensor_maple(),
        );
        assert!((12.0..19.0).contains(&ratio), "extensor ratio {ratio:.2} vs paper 15.5");
    }

    #[test]
    fn baseline_buffers_dominate_baseline_pe() {
        // §IV.B.3: "the PEB in Extensor and the PE's sorting queues in
        // Matraptor consume a significant amount of area".
        for cfg in [AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::extensor_baseline()]
        {
            let a = pe_area(&cfg);
            assert!(a.buffers_mm2 > a.mac_mm2 + a.logic_mm2, "{}", cfg.name);
        }
    }

    #[test]
    fn maple_compute_dominates_maple_pe() {
        // §IV.B.3: "Maple logic consumes the most area because it uses more
        // computational components" — computational area (MAC + adder/ctrl
        // logic) exceeds the buffer area in the Maple PE.
        for cfg in [AcceleratorConfig::matraptor_maple(), AcceleratorConfig::extensor_maple()] {
            let a = pe_area(&cfg);
            assert!(
                a.mac_mm2 + a.logic_mm2 > a.buffers_mm2,
                "{}: mac {} logic {} buffers {}",
                cfg.name,
                a.mac_mm2,
                a.logic_mm2,
                a.buffers_mm2
            );
        }
    }

    #[test]
    fn per_pe_maple_is_smaller_than_baseline_pe() {
        let b = pe_area(&AcceleratorConfig::matraptor_baseline()).total_mm2();
        let m = pe_area(&AcceleratorConfig::matraptor_maple()).total_mm2();
        assert!(m < b);
    }
}
