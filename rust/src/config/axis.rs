//! Typed configuration axes for design-space sweeps.
//!
//! A [`ConfigAxis`] is an ordered list of points on one named knob of the
//! accelerator; applying point `i` to a base [`AcceleratorConfig`] is a
//! *pure transform* — it sets that one knob and records the point in the
//! configuration name (`extensor-maple+noc=mesh:4x2+macs=8`), so every
//! expanded cell of a sweep grid is self-describing. The same axis syntax
//! is shared by the CLI (`maple sweep --axis noc=crossbar:8,mesh:4x2`) and
//! the TOML `[sweep]` block of a config file:
//!
//! ```toml
//! [sweep]
//! noc = "crossbar:8,mesh:4x2"
//! macs = "2,4,8,16"
//! ```
//!
//! Dataset and policy axes are *not* config transforms; they live in
//! [`crate::sim::engine::Axis`], which wraps this type for the knobs that
//! are.

use super::AcceleratorConfig;
use crate::noc::Topology;
use crate::sparse::{SparseFormat, TileShape};

/// Axis parse/validation error.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum AxisError {
    #[error("unknown sweep axis {0:?} (noc | macs | prefetch | pe-model | tile | fmt)")]
    UnknownAxis(String),
    #[error("axis {axis}: bad point {value:?} ({reason})")]
    BadPoint { axis: &'static str, value: String, reason: String },
}

/// One typed design-space axis over the accelerator configuration. Points
/// are ordered; each is a pure transform of the base config.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigAxis {
    /// NoC topology (`noc = crossbar:<ports> | mesh:<w>x<h>`).
    Topology(Vec<Topology>),
    /// MAC units per PE (`macs`), the paper's central design knob (§III).
    MacsPerPe(Vec<usize>),
    /// Operand-loader FIFO depth in rows (`prefetch`), the DES buffer credit.
    PrefetchDepth(Vec<usize>),
    /// Registered PE cost-model name (`pe-model`, see [`crate::pe::registry`]).
    PeModel(Vec<String>),
    /// Out-of-core tile shape (`tile = RxC | N` for NxN). Setting it does
    /// not change any simulated quantity — the tiled profile is
    /// bit-identical to the whole-matrix profile by construction
    /// ([`crate::sim::profile_workload_tiled`]) — but each point is
    /// feasibility-checked against the config's scratchpad capacity at
    /// sweep expansion, so the axis ranges over *deployable* tilings.
    Tiling(Vec<TileShape>),
    /// Operand compression format (`fmt = csr | csc | coo | bitmap |
    /// blocked`). Each point swaps the operand images in the DRAM traffic
    /// model ([`crate::sparse::FormatPlan`]); the `csr` point reproduces
    /// the formatless sweep bit-for-bit.
    Format(Vec<SparseFormat>),
}

impl ConfigAxis {
    /// The axis name used by the CLI flag, TOML `[sweep]` keys, grid
    /// dimensions, and report headers.
    pub fn name(&self) -> &'static str {
        match self {
            ConfigAxis::Topology(_) => "noc",
            ConfigAxis::MacsPerPe(_) => "macs",
            ConfigAxis::PrefetchDepth(_) => "prefetch",
            ConfigAxis::PeModel(_) => "pe-model",
            ConfigAxis::Tiling(_) => "tile",
            ConfigAxis::Format(_) => "fmt",
        }
    }

    /// Number of points on this axis.
    pub fn len(&self) -> usize {
        match self {
            ConfigAxis::Topology(v) => v.len(),
            ConfigAxis::MacsPerPe(v) => v.len(),
            ConfigAxis::PrefetchDepth(v) => v.len(),
            ConfigAxis::PeModel(v) => v.len(),
            ConfigAxis::Tiling(v) => v.len(),
            ConfigAxis::Format(v) => v.len(),
        }
    }

    /// Whether the axis has no points (rejected at sweep expansion).
    pub fn is_empty(&self) -> bool {
        match self {
            ConfigAxis::Topology(v) => v.is_empty(),
            ConfigAxis::MacsPerPe(v) => v.is_empty(),
            ConfigAxis::PrefetchDepth(v) => v.is_empty(),
            ConfigAxis::PeModel(v) => v.is_empty(),
            ConfigAxis::Tiling(v) => v.is_empty(),
            ConfigAxis::Format(v) => v.is_empty(),
        }
    }

    /// Display label of point `i` (the spec-syntax form for topologies).
    pub fn label(&self, i: usize) -> String {
        match self {
            ConfigAxis::Topology(v) => v[i].to_string(),
            ConfigAxis::MacsPerPe(v) => v[i].to_string(),
            ConfigAxis::PrefetchDepth(v) => v[i].to_string(),
            ConfigAxis::PeModel(v) => v[i].clone(),
            ConfigAxis::Tiling(v) => v[i].to_string(),
            ConfigAxis::Format(v) => v[i].to_string(),
        }
    }

    /// All point labels, in axis order.
    pub fn labels(&self) -> Vec<String> {
        (0..self.len()).map(|i| self.label(i)).collect()
    }

    /// Apply point `i` to `cfg`: set the knob and suffix the configuration
    /// name with `+<axis>=<label>` so expanded grid cells stay
    /// self-describing.
    pub fn apply(&self, i: usize, cfg: &mut AcceleratorConfig) {
        match self {
            ConfigAxis::Topology(v) => cfg.noc = v[i],
            ConfigAxis::MacsPerPe(v) => cfg.pe.macs_per_pe = v[i],
            ConfigAxis::PrefetchDepth(v) => cfg.pe.prefetch_depth = v[i],
            ConfigAxis::PeModel(v) => cfg.pe.model = Some(v[i].clone()),
            ConfigAxis::Tiling(v) => cfg.tiling = Some(v[i]),
            ConfigAxis::Format(v) => cfg.operand_format = v[i],
        }
        cfg.name.push_str(&format!("+{}={}", self.name(), self.label(i)));
    }

    /// Check every point is applicable: integer knobs must be ≥ 1 (a
    /// zero-MAC PE cannot compute; a zero prefetch credit deadlocks the DES
    /// loader), topology dimensions ≥ 1, PE-model names non-empty (their
    /// registration is checked at sweep time). Returns the offending label.
    pub fn validate(&self) -> Result<(), String> {
        let bad = |label: String, reason: &str| Err(format!("{label} ({reason})"));
        match self {
            ConfigAxis::Topology(v) => {
                if let Some(t) = v.iter().find(|t| t.is_degenerate()) {
                    return bad(t.to_string(), "every dimension must be ≥ 1");
                }
            }
            ConfigAxis::MacsPerPe(v) | ConfigAxis::PrefetchDepth(v) => {
                if let Some(&k) = v.iter().find(|&&k| k == 0) {
                    return bad(k.to_string(), "must be ≥ 1");
                }
            }
            ConfigAxis::PeModel(v) => {
                if v.iter().any(|m| m.trim().is_empty()) {
                    return bad("\"\"".into(), "model name must be non-empty");
                }
            }
            ConfigAxis::Tiling(v) => {
                // TileShape construction clamps extents to ≥ 1, so the only
                // degenerate form left is a repeated point (an aliased grid
                // cell that would collide in reports and cache keys).
                for (i, s) in v.iter().enumerate() {
                    if v[..i].contains(s) {
                        return bad(s.to_string(), "duplicate tile shape");
                    }
                }
            }
            ConfigAxis::Format(v) => {
                // The format set is closed, so — like tile shapes — the
                // only degenerate form is a repeated point.
                for (i, f) in v.iter().enumerate() {
                    if v[..i].contains(f) {
                        return bad(f.to_string(), "duplicate format");
                    }
                }
            }
        }
        Ok(())
    }

    /// Parse one axis from its name and comma-separated point list — the
    /// payload of a CLI `--axis name=v1,v2,...` flag or a TOML `[sweep]`
    /// `name = "v1,v2,..."` entry.
    pub fn parse(name: &str, values: &str) -> Result<Self, AxisError> {
        fn ints(axis: &'static str, values: &str) -> Result<Vec<usize>, AxisError> {
            values
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    v.parse::<usize>().ok().filter(|&k| k >= 1).ok_or_else(|| {
                        AxisError::BadPoint {
                            axis,
                            value: v.to_string(),
                            reason: "expected an integer ≥ 1".into(),
                        }
                    })
                })
                .collect()
        }
        match name.trim() {
            "noc" => values
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    v.parse::<Topology>().map_err(|e| AxisError::BadPoint {
                        axis: "noc",
                        value: v.to_string(),
                        reason: e.to_string(),
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(ConfigAxis::Topology),
            "macs" => ints("macs", values).map(ConfigAxis::MacsPerPe),
            "prefetch" => ints("prefetch", values).map(ConfigAxis::PrefetchDepth),
            "pe-model" => values
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    if v.is_empty() {
                        Err(AxisError::BadPoint {
                            axis: "pe-model",
                            value: v.to_string(),
                            reason: "model name must be non-empty".into(),
                        })
                    } else {
                        Ok(v.to_string())
                    }
                })
                .collect::<Result<Vec<_>, _>>()
                .map(ConfigAxis::PeModel),
            "tile" => values
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    TileShape::parse(v).map_err(|reason| AxisError::BadPoint {
                        axis: "tile",
                        value: v.to_string(),
                        reason,
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(ConfigAxis::Tiling),
            "fmt" => values
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    v.parse::<SparseFormat>().map_err(|reason| AxisError::BadPoint {
                        axis: "fmt",
                        value: v.to_string(),
                        reason,
                    })
                })
                .collect::<Result<Vec<_>, _>>()
                .map(ConfigAxis::Format),
            other => Err(AxisError::UnknownAxis(other.to_string())),
        }
    }
}

/// Parse the `[sweep]` section of a config TOML into axes, in file order
/// (axis order is grid order). Each entry is `name = "v1,v2,..."` using the
/// same syntax as the CLI `--axis` flag; values must be quoted so the file
/// still parses as an [`AcceleratorConfig`] (which ignores the `[sweep]`
/// section). A file without the section yields no axes.
pub fn sweep_axes_from_toml(s: &str) -> Result<Vec<ConfigAxis>, AxisError> {
    let mut axes = Vec::new();
    let mut in_sweep = false;
    for raw in s.lines() {
        let line = super::toml_io::strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
            in_sweep = name.trim() == "sweep";
            continue;
        }
        if !in_sweep {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            continue; // syntax is validated by the config parser proper
        };
        let v = v.trim();
        let v = v.strip_prefix('"').and_then(|t| t.strip_suffix('"')).unwrap_or(v);
        axes.push(ConfigAxis::parse(k.trim(), v)?);
    }
    Ok(axes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_each_axis_kind() {
        assert_eq!(
            ConfigAxis::parse("noc", "crossbar:8, mesh:4x2").unwrap(),
            ConfigAxis::Topology(vec![
                Topology::Crossbar { ports: 8 },
                Topology::Mesh { width: 4, height: 2 },
            ])
        );
        assert_eq!(
            ConfigAxis::parse("macs", "2,4,8,16").unwrap(),
            ConfigAxis::MacsPerPe(vec![2, 4, 8, 16])
        );
        assert_eq!(
            ConfigAxis::parse("prefetch", " 2 , 6 ").unwrap(),
            ConfigAxis::PrefetchDepth(vec![2, 6])
        );
        assert_eq!(
            ConfigAxis::parse("pe-model", "maple,dummy-test-pe").unwrap(),
            ConfigAxis::PeModel(vec!["maple".into(), "dummy-test-pe".into()])
        );
        assert_eq!(
            ConfigAxis::parse("tile", "64x32, 128, 1x256").unwrap(),
            ConfigAxis::Tiling(vec![
                TileShape::new(64, 32),
                TileShape::new(128, 128),
                TileShape::new(1, 256),
            ])
        );
        assert_eq!(
            ConfigAxis::parse("fmt", "csr, csc,coo,bitmap, blocked").unwrap(),
            ConfigAxis::Format(SparseFormat::ALL.to_vec())
        );
    }

    #[test]
    fn parse_rejects_bad_axes_and_points() {
        assert!(matches!(
            ConfigAxis::parse("warp-drive", "1,2"),
            Err(AxisError::UnknownAxis(_))
        ));
        for (name, values) in [
            ("macs", "2,0,8"),
            ("macs", "2,,8"),
            ("macs", ""),
            ("prefetch", "-1"),
            ("noc", "mesh:0x4"),
            ("noc", "crossbar:"),
            ("noc", "torus:4x4"),
            ("pe-model", "maple,,gamma"),
            ("tile", "64x"),
            ("tile", "0x32"),
            ("tile", "axb"),
            ("fmt", "csr,csx"),
            ("fmt", ""),
            ("fmt", "CSR"),
        ] {
            assert!(
                matches!(ConfigAxis::parse(name, values), Err(AxisError::BadPoint { .. })),
                "{name}={values:?} must be rejected"
            );
        }
    }

    #[test]
    fn apply_transforms_and_suffixes_the_name() {
        let axis = ConfigAxis::parse("noc", "crossbar:8,mesh:4x2").unwrap();
        let mut cfg = AcceleratorConfig::extensor_maple();
        axis.apply(1, &mut cfg);
        assert_eq!(cfg.noc, Topology::Mesh { width: 4, height: 2 });
        assert_eq!(cfg.name, "extensor-maple+noc=mesh:4x2");
        let macs = ConfigAxis::MacsPerPe(vec![2, 8]);
        macs.apply(1, &mut cfg);
        assert_eq!(cfg.pe.macs_per_pe, 8);
        assert_eq!(cfg.name, "extensor-maple+noc=mesh:4x2+macs=8");
        let pf = ConfigAxis::PrefetchDepth(vec![3]);
        pf.apply(0, &mut cfg);
        assert_eq!(cfg.pe.prefetch_depth, 3);
        let pm = ConfigAxis::PeModel(vec!["maple".into()]);
        pm.apply(0, &mut cfg);
        assert_eq!(cfg.pe.model.as_deref(), Some("maple"));
        let tile = ConfigAxis::Tiling(vec![TileShape::new(64, 32)]);
        tile.apply(0, &mut cfg);
        assert_eq!(cfg.tiling, Some(TileShape::new(64, 32)));
        assert!(cfg.name.ends_with("+tile=64x32"), "{}", cfg.name);
        let fmt = ConfigAxis::Format(vec![SparseFormat::Csr, SparseFormat::Bitmap]);
        fmt.apply(1, &mut cfg);
        assert_eq!(cfg.operand_format, SparseFormat::Bitmap);
        assert!(cfg.name.ends_with("+fmt=bitmap"), "{}", cfg.name);
    }

    #[test]
    fn validate_catches_degenerate_points() {
        assert!(ConfigAxis::MacsPerPe(vec![2, 0]).validate().is_err());
        assert!(ConfigAxis::PrefetchDepth(vec![0]).validate().is_err());
        assert!(ConfigAxis::Topology(vec![Topology::Mesh { width: 0, height: 4 }])
            .validate()
            .is_err());
        assert!(ConfigAxis::PeModel(vec!["  ".into()]).validate().is_err());
        assert!(ConfigAxis::parse("macs", "1,2").unwrap().validate().is_ok());
        let dup = ConfigAxis::Tiling(vec![TileShape::new(4, 4), TileShape::new(4, 4)]);
        assert!(dup.validate().is_err());
        assert!(ConfigAxis::parse("tile", "4x4,8x8").unwrap().validate().is_ok());
        let dup = ConfigAxis::Format(vec![SparseFormat::Coo, SparseFormat::Coo]);
        assert!(dup.validate().is_err());
        assert!(ConfigAxis::parse("fmt", "csr,csc,coo,bitmap,blocked")
            .unwrap()
            .validate()
            .is_ok());
    }

    #[test]
    fn sweep_block_parses_in_file_order_and_composes_with_config_io() {
        let mut toml = AcceleratorConfig::extensor_maple().to_toml();
        toml.push_str("\n[sweep]\nnoc = \"crossbar:8,mesh:4x2\"  # comment\nmacs = \"2,4\"\n");
        let axes = sweep_axes_from_toml(&toml).unwrap();
        assert_eq!(axes.len(), 2);
        assert_eq!(axes[0].name(), "noc");
        assert_eq!(axes[1].name(), "macs");
        assert_eq!(axes[1], ConfigAxis::MacsPerPe(vec![2, 4]));
        // The config parser ignores the [sweep] section entirely.
        let cfg = AcceleratorConfig::from_toml(&toml).unwrap();
        assert_eq!(cfg, AcceleratorConfig::extensor_maple());
        // No [sweep] section → no axes.
        assert!(sweep_axes_from_toml(&AcceleratorConfig::extensor_maple().to_toml())
            .unwrap()
            .is_empty());
        // Bad points in the block surface as axis errors.
        assert!(sweep_axes_from_toml("[sweep]\nmacs = \"0\"\n").is_err());
        assert!(sweep_axes_from_toml("[sweep]\nwarp = \"1\"\n").is_err());
    }
}
