//! Minimal in-tree TOML subset (sections, `key = value` with strings,
//! integers, floats) — the offline build has no external TOML dependency
//! (DESIGN.md §Dependencies). Only what [`AcceleratorConfig`] needs.

use super::{AcceleratorConfig, AcceleratorKind, PeConfig, PeKind, DEFAULT_PREFETCH_DEPTH};
use crate::mem::DramParams;
use crate::noc::Topology;
use crate::sparse::{SparseFormat, TileShape};
use std::collections::BTreeMap;

/// Config (de)serialisation error.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("line {0}: {1}")]
    Parse(usize, String),
    #[error("missing key: {0}")]
    Missing(&'static str),
    #[error("bad value for {0}: {1}")]
    BadValue(&'static str, String),
}

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
enum Value {
    Str(String),
    Int(i64),
    Float(f64),
}

/// Strip a `#` comment from one line, honouring quotes: a `#` inside a
/// quoted string is content, not a comment delimiter. Shared with the
/// `[sweep]` axis scanner ([`crate::config::axis`]).
pub(crate) fn strip_comment(raw: &str) -> &str {
    let mut in_str = false;
    for (i, ch) in raw.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &raw[..i],
            _ => {}
        }
    }
    raw
}

/// Parse the TOML subset into `(section.key → value)`.
fn parse_flat(s: &str) -> Result<BTreeMap<String, Value>, ConfigError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (no, raw) in s.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| ConfigError::Parse(no + 1, format!("expected key = value: {line}")))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let v = v.trim();
        let value = if let Some(q) = v.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
            Value::Str(q.to_string())
        } else if let Ok(i) = v.parse::<i64>() {
            Value::Int(i)
        } else if let Ok(f) = v.parse::<f64>() {
            Value::Float(f)
        } else {
            return Err(ConfigError::Parse(no + 1, format!("unparseable value: {v}")));
        };
        out.insert(key, value);
    }
    Ok(out)
}

fn get_str(m: &BTreeMap<String, Value>, k: &'static str) -> Result<String, ConfigError> {
    match m.get(k) {
        Some(Value::Str(s)) => Ok(s.clone()),
        Some(v) => Err(ConfigError::BadValue(k, format!("{v:?}"))),
        None => Err(ConfigError::Missing(k)),
    }
}

/// Optional string key: absent → `Ok(None)`; present with a non-string
/// value → a type error like every mandatory key.
fn get_opt_str(
    m: &BTreeMap<String, Value>,
    k: &'static str,
) -> Result<Option<String>, ConfigError> {
    match m.get(k) {
        Some(Value::Str(s)) => Ok(Some(s.clone())),
        Some(v) => Err(ConfigError::BadValue(k, format!("{v:?}"))),
        None => Ok(None),
    }
}

fn get_usize(m: &BTreeMap<String, Value>, k: &'static str) -> Result<usize, ConfigError> {
    match m.get(k) {
        Some(Value::Int(i)) if *i >= 0 => Ok(*i as usize),
        Some(v) => Err(ConfigError::BadValue(k, format!("{v:?}"))),
        None => Err(ConfigError::Missing(k)),
    }
}

/// Optional integer key with a default — for fields added after configs
/// were already written to disk (absent → `default`, wrong type → error).
fn get_usize_or(
    m: &BTreeMap<String, Value>,
    k: &'static str,
    default: usize,
) -> Result<usize, ConfigError> {
    match m.get(k) {
        None => Ok(default),
        Some(_) => get_usize(m, k),
    }
}

fn get_f64(m: &BTreeMap<String, Value>, k: &'static str) -> Result<f64, ConfigError> {
    match m.get(k) {
        Some(Value::Float(f)) => Ok(*f),
        Some(Value::Int(i)) => Ok(*i as f64),
        Some(v) => Err(ConfigError::BadValue(k, format!("{v:?}"))),
        None => Err(ConfigError::Missing(k)),
    }
}

/// Serialise a configuration to the TOML subset.
pub fn to_toml(c: &AcceleratorConfig) -> String {
    let kind = match c.kind {
        AcceleratorKind::Matraptor => "matraptor",
        AcceleratorKind::Extensor => "extensor",
    };
    let pe_kind = match c.pe.kind {
        PeKind::Baseline => "baseline",
        PeKind::Maple => "maple",
    };
    let mut s = String::new();
    s.push_str(&format!("name = \"{}\"\n", c.name));
    s.push_str(&format!("kind = \"{kind}\"\n"));
    s.push_str(&format!("num_pes = {}\n", c.num_pes));
    s.push_str(&format!("l1_bytes = {}\n", c.l1_bytes));
    s.push_str(&format!("pob_bytes = {}\n", c.pob_bytes));
    s.push_str(&format!("merge_passes = {}\n", c.merge_passes));
    s.push_str(&format!(
        "pob_words_per_cycle_per_pe = {:?}\n",
        c.pob_words_per_cycle_per_pe
    ));
    s.push_str("\n[pe]\n");
    s.push_str(&format!("kind = \"{pe_kind}\"\n"));
    if let Some(model) = &c.pe.model {
        s.push_str(&format!("model = \"{model}\"\n"));
    }
    s.push_str(&format!("macs_per_pe = {}\n", c.pe.macs_per_pe));
    s.push_str(&format!("arb_entries = {}\n", c.pe.arb_entries));
    s.push_str(&format!("brb_entries = {}\n", c.pe.brb_entries));
    s.push_str(&format!("psb_entries = {}\n", c.pe.psb_entries));
    s.push_str(&format!("num_queues = {}\n", c.pe.num_queues));
    s.push_str(&format!("queue_bytes = {}\n", c.pe.queue_bytes));
    s.push_str(&format!("peb_bytes = {}\n", c.pe.peb_bytes));
    s.push_str(&format!("prefetch_depth = {}\n", c.pe.prefetch_depth));
    // Emitted only when set, like `[pe] model`: every config written before
    // the knob existed parses unchanged, and `None` round-trips as absence.
    if let Some(t) = c.tiling {
        s.push_str("\n[tile]\n");
        s.push_str(&format!("shape = \"{t}\"\n"));
    }
    // Same optional-section contract as `[tile]`: CSR (the default and
    // every paper preset) is absence, so pre-format configs and their
    // serialisations are byte-identical to today's.
    if c.operand_format != SparseFormat::Csr {
        s.push_str("\n[format]\n");
        s.push_str(&format!("operand = \"{}\"\n", c.operand_format));
    }
    s.push_str("\n[noc]\n");
    // The canonical spec syntax (`Topology: Display`), shared with the CLI
    // `--axis noc=...` flag and report labels.
    s.push_str(&format!("topology = \"{}\"\n", c.noc));
    s.push_str("\n[dram]\n");
    s.push_str(&format!("words_per_cycle = {:?}\n", c.dram.words_per_cycle));
    s.push_str(&format!("access_latency = {}\n", c.dram.access_latency));
    s.push_str(&format!("burst_words = {}\n", c.dram.burst_words));
    s
}

/// Parse a configuration from the TOML subset.
pub fn from_toml(s: &str) -> Result<AcceleratorConfig, ConfigError> {
    let m = parse_flat(s)?;
    let kind = match get_str(&m, "kind")?.as_str() {
        "matraptor" => AcceleratorKind::Matraptor,
        "extensor" => AcceleratorKind::Extensor,
        other => return Err(ConfigError::BadValue("kind", other.to_string())),
    };
    let pe_kind = match get_str(&m, "pe.kind")?.as_str() {
        "baseline" => PeKind::Baseline,
        "maple" => PeKind::Maple,
        other => return Err(ConfigError::BadValue("pe.kind", other.to_string())),
    };
    // `Topology: FromStr` owns the spec syntax; the dimensioned legacy form
    // (`topology = "mesh"` + separate width/height/ports keys) still parses
    // so configs serialised before the shared syntax keep loading. Both
    // forms reject degenerate dimensions — a zero-width mesh cannot route
    // (`Noc::hops` would divide by it).
    let noc = match get_str(&m, "noc.topology")?.as_str() {
        "crossbar" => Topology::Crossbar { ports: get_usize(&m, "noc.ports")? },
        "mesh" => Topology::Mesh {
            width: get_usize(&m, "noc.width")?,
            height: get_usize(&m, "noc.height")?,
        },
        spec => spec
            .parse::<Topology>()
            .map_err(|_| ConfigError::BadValue("noc.topology", spec.to_string()))?,
    };
    if noc.is_degenerate() {
        return Err(ConfigError::BadValue("noc.topology", noc.to_string()));
    }
    Ok(AcceleratorConfig {
        name: get_str(&m, "name")?,
        kind,
        pe: PeConfig {
            kind: pe_kind,
            model: get_opt_str(&m, "pe.model")?,
            macs_per_pe: get_usize(&m, "pe.macs_per_pe")?,
            arb_entries: get_usize(&m, "pe.arb_entries")?,
            brb_entries: get_usize(&m, "pe.brb_entries")?,
            psb_entries: get_usize(&m, "pe.psb_entries")?,
            num_queues: get_usize(&m, "pe.num_queues")?,
            queue_bytes: get_usize(&m, "pe.queue_bytes")?,
            peb_bytes: get_usize(&m, "pe.peb_bytes")?,
            prefetch_depth: get_usize_or(&m, "pe.prefetch_depth", DEFAULT_PREFETCH_DEPTH)?,
        },
        num_pes: get_usize(&m, "num_pes")?,
        l1_bytes: get_usize(&m, "l1_bytes")?,
        pob_bytes: get_usize(&m, "pob_bytes")?,
        noc,
        dram: DramParams {
            words_per_cycle: get_f64(&m, "dram.words_per_cycle")?,
            access_latency: get_usize(&m, "dram.access_latency")? as u64,
            burst_words: get_usize(&m, "dram.burst_words")? as u64,
        },
        merge_passes: get_usize(&m, "merge_passes")? as u32,
        pob_words_per_cycle_per_pe: get_f64(&m, "pob_words_per_cycle_per_pe")?,
        tiling: match get_opt_str(&m, "tile.shape")? {
            None => None,
            Some(spec) => Some(
                TileShape::parse(&spec)
                    .map_err(|e| ConfigError::BadValue("tile.shape", format!("{spec}: {e}")))?,
            ),
        },
        operand_format: match get_opt_str(&m, "format.operand")? {
            None => SparseFormat::Csr,
            Some(spec) => spec
                .parse::<SparseFormat>()
                .map_err(|e| ConfigError::BadValue("format.operand", format!("{spec}: {e}")))?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_rejects_garbage() {
        assert!(from_toml("nonsense").is_err());
        assert!(from_toml("name = \"x\"\nkind = \"bogus\"\n").is_err());
    }

    #[test]
    fn parse_flat_handles_comments_and_sections() {
        let m = parse_flat("# hi\na = 1\n[s]\nb = \"x\" # trail\nc = 2.5\n").unwrap();
        assert_eq!(m["a"], Value::Int(1));
        assert_eq!(m["s.b"], Value::Str("x".into()));
        assert_eq!(m["s.c"], Value::Float(2.5));
    }

    #[test]
    fn comment_stripping_honours_quotes() {
        // A `#` inside a quoted value is content, not a comment.
        let m = parse_flat("a = \"x#y\" # real comment\n").unwrap();
        assert_eq!(m["a"], Value::Str("x#y".into()));
        assert_eq!(strip_comment("plain # c"), "plain ");
        assert_eq!(strip_comment("\"a#b\" # c"), "\"a#b\" ");
        assert_eq!(strip_comment("no comment"), "no comment");
    }

    #[test]
    fn mistyped_pe_model_is_rejected() {
        let mut s = to_toml(&AcceleratorConfig::extensor_maple());
        s = s.replace("[pe]\n", "[pe]\nmodel = 123\n");
        assert!(matches!(from_toml(&s), Err(ConfigError::BadValue("pe.model", _))));
    }

    #[test]
    fn pe_model_override_round_trips() {
        let mut c = AcceleratorConfig::extensor_maple();
        c.pe.model = Some("custom-pe".into());
        let s = to_toml(&c);
        assert!(s.contains("model = \"custom-pe\""));
        assert_eq!(from_toml(&s).unwrap(), c);
    }

    #[test]
    fn prefetch_depth_defaults_when_absent() {
        // Configs serialised before the knob existed still parse (loader
        // FIFO depth defaults to the preset value of 6).
        let mut s = to_toml(&AcceleratorConfig::extensor_maple());
        s = s.lines().filter(|l| !l.starts_with("prefetch_depth")).collect::<Vec<_>>().join("\n");
        assert_eq!(from_toml(&s).unwrap().pe.prefetch_depth, 6);
        // And an explicit value round-trips.
        let mut c = AcceleratorConfig::matraptor_maple();
        c.pe.prefetch_depth = 2;
        assert_eq!(from_toml(&to_toml(&c)).unwrap(), c);
    }

    #[test]
    fn tile_shape_round_trips_and_rejects_garbage() {
        // Absent section → None (configs written before the knob existed).
        let c = AcceleratorConfig::extensor_maple();
        assert!(!to_toml(&c).contains("[tile]"));
        assert_eq!(from_toml(&to_toml(&c)).unwrap().tiling, None);
        // An explicit shape round-trips through the [tile] section.
        let mut c = AcceleratorConfig::extensor_maple();
        c.tiling = Some(TileShape::new(64, 32));
        let s = to_toml(&c);
        assert!(s.contains("[tile]") && s.contains("shape = \"64x32\""), "{s}");
        assert_eq!(from_toml(&s).unwrap(), c);
        // A malformed shape is a typed error, not a silent None.
        let bad = s.replace("shape = \"64x32\"", "shape = \"64x\"");
        assert!(matches!(from_toml(&bad), Err(ConfigError::BadValue("tile.shape", _))));
    }

    #[test]
    fn operand_format_round_trips_and_rejects_garbage() {
        // Absent section → CSR, and CSR serialises as absence: pre-format
        // configs (and the paper presets) are byte-identical to before.
        let c = AcceleratorConfig::extensor_maple();
        assert!(!to_toml(&c).contains("[format]"));
        assert_eq!(from_toml(&to_toml(&c)).unwrap().operand_format, SparseFormat::Csr);
        // Every non-CSR format round-trips through the [format] section.
        for f in SparseFormat::ALL.into_iter().filter(|&f| f != SparseFormat::Csr) {
            let mut c = AcceleratorConfig::extensor_maple();
            c.operand_format = f;
            let s = to_toml(&c);
            assert!(
                s.contains("[format]") && s.contains(&format!("operand = \"{f}\"")),
                "{s}"
            );
            assert_eq!(from_toml(&s).unwrap(), c);
        }
        // A malformed format is a typed error, not a silent CSR.
        let mut c = AcceleratorConfig::extensor_maple();
        c.operand_format = SparseFormat::Bitmap;
        let bad = to_toml(&c).replace("operand = \"bitmap\"", "operand = \"bitmop\"");
        assert!(matches!(from_toml(&bad), Err(ConfigError::BadValue("format.operand", _))));
    }

    #[test]
    fn round_trip_all_presets() {
        for c in AcceleratorConfig::paper_configs() {
            let s = to_toml(&c);
            let back = from_toml(&s).unwrap();
            assert_eq!(back, c, "preset {} does not round-trip", c.name);
        }
    }

    #[test]
    fn topology_serialises_on_the_shared_spec_syntax() {
        let s = to_toml(&AcceleratorConfig::extensor_baseline());
        assert!(s.contains("topology = \"mesh:16x8\""), "{s}");
        let s = to_toml(&AcceleratorConfig::matraptor_baseline());
        assert!(s.contains("topology = \"crossbar:8\""), "{s}");
        // No legacy per-dimension keys are emitted any more.
        assert!(!s.contains("ports =") && !s.contains("width ="), "{s}");
    }

    #[test]
    fn legacy_dimensioned_topology_form_still_parses() {
        let mut c = AcceleratorConfig::extensor_maple();
        let legacy = to_toml(&c).replace(
            "topology = \"mesh:4x2\"",
            "topology = \"mesh\"\nwidth = 4\nheight = 2",
        );
        assert_eq!(from_toml(&legacy).unwrap(), c);
        c = AcceleratorConfig::matraptor_maple();
        let legacy = to_toml(&c)
            .replace("topology = \"crossbar:4\"", "topology = \"crossbar\"\nports = 4");
        assert_eq!(from_toml(&legacy).unwrap(), c);
    }

    #[test]
    fn bad_topology_specs_are_rejected() {
        let good = to_toml(&AcceleratorConfig::extensor_maple());
        for bad in ["mesh:0x4", "mesh:4x0", "crossbar:", "crossbar:0", "torus:4x4", "nonsense"] {
            let s = good.replace("topology = \"mesh:4x2\"", &format!("topology = \"{bad}\""));
            assert!(
                matches!(from_toml(&s), Err(ConfigError::BadValue("noc.topology", _))),
                "{bad:?} must be rejected"
            );
        }
        // The legacy form with its dimension keys missing is also an error.
        let s = good.replace("topology = \"mesh:4x2\"", "topology = \"mesh\"");
        assert!(matches!(from_toml(&s), Err(ConfigError::Missing("noc.width"))));
        // And legacy dimension keys carrying zeroes are rejected like the
        // spec syntax, not deferred to a divide-by-zero in `Noc::hops`.
        for legacy in [
            "topology = \"mesh\"\nwidth = 0\nheight = 4",
            "topology = \"mesh\"\nwidth = 4\nheight = 0",
            "topology = \"crossbar\"\nports = 0",
        ] {
            let s = good.replace("topology = \"mesh:4x2\"", legacy);
            assert!(
                matches!(from_toml(&s), Err(ConfigError::BadValue("noc.topology", _))),
                "{legacy:?} must be rejected"
            );
        }
    }
}
