//! Accelerator configuration system.
//!
//! Every experiment is a pure function of an [`AcceleratorConfig`]; the four
//! paper configurations (§IV.B) ship as presets and any variant can be
//! loaded from TOML (see `configs/*.toml` and the `design_space` example).
//! Design-space sweeps vary configs along typed [`axis::ConfigAxis`] values
//! (NoC topology, MACs/PE, prefetch depth, PE model, tile shape, operand
//! format), each point a pure transform of a base config.

pub mod axis;
pub mod toml_io;

pub use axis::{AxisError, ConfigAxis};

use crate::mem::DramParams;
use crate::noc::Topology;
use crate::sparse::{SparseFormat, TileShape};

/// Which reference accelerator the configuration instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcceleratorKind {
    /// Srivastava et al., MICRO'20 — crossbar, SpAL/SpBL L1, sorting-queue PEs.
    Matraptor,
    /// Hegde et al., MICRO'19 — mesh NoC, LLB+POB L1, PEB PEs.
    Extensor,
}

/// Which processing element fills the PE array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeKind {
    /// The reference accelerator's own PE (1 MAC + large PE buffer).
    Baseline,
    /// The paper's Maple PE (k MACs + ARB/BRB/PSB).
    Maple,
}

/// Loader FIFO depth all paper presets use, and the fallback for configs
/// serialised before the knob existed (`[pe] prefetch_depth` in TOML).
pub const DEFAULT_PREFETCH_DEPTH: usize = 6;

/// Processing-element micro-architecture parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct PeConfig {
    pub kind: PeKind,
    /// Explicit PE cost-model name in [`crate::pe::registry`]. `None`
    /// (the default, and all paper presets) selects by `(kind, pe.kind)`;
    /// a registered plug-in PE is picked by setting its name here.
    pub model: Option<String>,
    /// MAC units per PE (1 for baselines; "determined during the design
    /// phase" for Maple, paper §III).
    pub macs_per_pe: usize,
    /// ARB capacity in (value, col_id) element pairs (Maple only).
    pub arb_entries: usize,
    /// BRB capacity in element pairs (Maple only).
    pub brb_entries: usize,
    /// PSB register count (Maple only) — the `1 × N` accumulator array.
    pub psb_entries: usize,
    /// Sorting-queue count per PE (Matraptor baseline only).
    pub num_queues: usize,
    /// Total sorting-queue bytes per PE (Matraptor baseline only).
    pub queue_bytes: usize,
    /// PEB bytes per PE (Extensor baseline only).
    pub peb_bytes: usize,
    /// Operand-loader FIFO depth in rows: how many rows the stream
    /// prefetcher (SpAL/SpBL/LLB, or Maple's ARB/BRB fill path) may have
    /// fetched-but-not-yet-computing per PE. The DES enforces this as a
    /// hard buffer credit (fetched-and-waiting + in-flight fetches never
    /// exceed it); the analytic model idealises fetch away and ignores it.
    pub prefetch_depth: usize,
}

impl PeConfig {
    /// Maple register-buffer footprint in bytes. ARB and BRB store
    /// (value, col_id) pairs; the PSB stores values only — it is *addressed
    /// by* `j'` (paper Eq. 8), so the output coordinate is implicit in the
    /// register index.
    pub fn maple_buffer_bytes(&self) -> usize {
        (self.arb_entries + self.brb_entries) * 8 + self.psb_entries * 4
    }

    /// The L0 SRAM footprint of a baseline PE.
    pub fn baseline_buffer_bytes(&self) -> usize {
        self.queue_bytes + self.peb_bytes
    }
}

/// A complete accelerator instance description.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorConfig {
    /// Human-readable configuration name.
    pub name: String,
    pub kind: AcceleratorKind,
    pub pe: PeConfig,
    /// Number of processing elements.
    pub num_pes: usize,
    /// L1 storage-element bytes (SpAL+SpBL for Matraptor, LLB for Extensor).
    /// Zero when the configuration has no L1 (Maple-based Matraptor, §IV.B.1).
    pub l1_bytes: usize,
    /// Partial-output-buffer bytes (Extensor baseline only).
    pub pob_bytes: usize,
    /// Interconnect topology.
    pub noc: Topology,
    /// DRAM port model.
    pub dram: DramParams,
    /// Merge passes the Matraptor baseline performs over each partial sum
    /// (round-robin accumulate, §IV.B.4); derived from `num_queues`.
    pub merge_passes: u32,
    /// POB bandwidth share per PE in words/cycle (Extensor baseline).
    pub pob_words_per_cycle_per_pe: f64,
    /// Out-of-core tile shape for the streaming profile pass (`[tile]` in
    /// TOML, `tile` sweep axis). `None` — every paper preset — profiles the
    /// whole matrix resident. Setting it changes *how* the profile is
    /// computed, never *what*: the tiled result is bit-identical
    /// ([`crate::sim::profile_workload_tiled`]), so no simulated quantity
    /// depends on it. Sweep expansion feasibility-checks each shape against
    /// `l1_bytes` ([`crate::sparse::tile::check_fits`]).
    pub tiling: Option<TileShape>,
    /// Operand compression format the accelerator streams from DRAM
    /// (`[format] operand` in TOML, `fmt` sweep axis). [`SparseFormat::Csr`]
    /// — every paper preset — reproduces the legacy traffic model exactly;
    /// any other format swaps the operand images in the DRAM model
    /// ([`crate::sparse::FormatPlan`]) and charges the one-time CSR →
    /// format conversion of A and B.
    pub operand_format: SparseFormat,
}

impl AcceleratorConfig {
    /// Total MAC units — the paper equalises this across compared configs
    /// (8 vs 8 for Matraptor, 128 vs 128 for Extensor).
    pub fn total_macs(&self) -> usize {
        self.num_pes * self.pe.macs_per_pe
    }

    /// Baseline Matraptor (§IV.B.1): 8 PEs × 1 MAC, SpAL/SpBL (L1) +
    /// per-PE sorting queues (L0), crossbar to DRAM.
    pub fn matraptor_baseline() -> Self {
        let num_queues = 12;
        AcceleratorConfig {
            name: "matraptor-baseline".into(),
            kind: AcceleratorKind::Matraptor,
            pe: PeConfig {
                kind: PeKind::Baseline,
                model: None,
                macs_per_pe: 1,
                arb_entries: 0,
                brb_entries: 0,
                psb_entries: 0,
                num_queues,
                queue_bytes: 48 << 10, // 12 × 4 KiB
                peb_bytes: 0,
                prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            },
            num_pes: 8,
            l1_bytes: 256 << 10, // SpAL + SpBL, 128 KiB each
            pob_bytes: 0,
            noc: Topology::Crossbar { ports: 8 },
            dram: DramParams::default(),
            merge_passes: (num_queues as f64).log2().ceil() as u32,
            pob_words_per_cycle_per_pe: 0.0,
            tiling: None,
            operand_format: SparseFormat::Csr,
        }
    }

    /// Maple-based Matraptor (§IV.B.1): 4 PEs × 2 MACs, a single memory
    /// level (ARB/BRB/PSB as L0), same simplified crossbar.
    pub fn matraptor_maple() -> Self {
        AcceleratorConfig {
            name: "matraptor-maple".into(),
            kind: AcceleratorKind::Matraptor,
            pe: PeConfig {
                kind: PeKind::Maple,
                model: None,
                macs_per_pe: 2,
                arb_entries: 16,
                brb_entries: 64,
                psb_entries: 128,
                num_queues: 0,
                queue_bytes: 0,
                peb_bytes: 0,
                prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            },
            num_pes: 4,
            l1_bytes: 0, // "consists of one memory level" (§IV.B.1)
            pob_bytes: 0,
            noc: Topology::Crossbar { ports: 4 },
            dram: DramParams::default(),
            merge_passes: 0,
            pob_words_per_cycle_per_pe: 0.0,
            tiling: None,
            operand_format: SparseFormat::Csr,
        }
    }

    /// Baseline Extensor (§IV.B.2): 128 PEs × 1 MAC in a 16 × 8 mesh,
    /// LLB + POB (L1), PEB per PE (L0).
    pub fn extensor_baseline() -> Self {
        AcceleratorConfig {
            name: "extensor-baseline".into(),
            kind: AcceleratorKind::Extensor,
            pe: PeConfig {
                kind: PeKind::Baseline,
                model: None,
                macs_per_pe: 1,
                arb_entries: 0,
                brb_entries: 0,
                psb_entries: 0,
                num_queues: 0,
                queue_bytes: 0,
                peb_bytes: 80 << 10,
                prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            },
            num_pes: 128,
            l1_bytes: 2 << 20,  // LLB
            pob_bytes: 1 << 20, // POB
            noc: Topology::Mesh { width: 16, height: 8 },
            dram: DramParams::default(),
            merge_passes: 0,
            pob_words_per_cycle_per_pe: 12.0,
            tiling: None,
            operand_format: SparseFormat::Csr,
        }
    }

    /// Maple-based Extensor (§IV.B.2): 8 PEs × 16 MACs (128 MACs total),
    /// LLB retained as L1, Maple buffers as L0 — no POB ("there is no need
    /// to utilize POB to store partial sums", §IV.B.4).
    pub fn extensor_maple() -> Self {
        AcceleratorConfig {
            name: "extensor-maple".into(),
            kind: AcceleratorKind::Extensor,
            pe: PeConfig {
                kind: PeKind::Maple,
                model: None,
                macs_per_pe: 16,
                arb_entries: 32,
                brb_entries: 256,
                psb_entries: 256,
                num_queues: 0,
                queue_bytes: 0,
                peb_bytes: 0,
                prefetch_depth: DEFAULT_PREFETCH_DEPTH,
            },
            num_pes: 8,
            l1_bytes: 2 << 20, // LLB retained
            pob_bytes: 0,
            noc: Topology::Mesh { width: 4, height: 2 },
            dram: DramParams::default(),
            merge_passes: 0,
            pob_words_per_cycle_per_pe: 0.0,
            tiling: None,
            operand_format: SparseFormat::Csr,
        }
    }

    /// The four paper configurations, in comparison order.
    pub fn paper_configs() -> Vec<AcceleratorConfig> {
        vec![
            Self::matraptor_baseline(),
            Self::matraptor_maple(),
            Self::extensor_baseline(),
            Self::extensor_maple(),
        ]
    }

    /// The Maple counterpart of a baseline config (or vice versa).
    pub fn counterpart(&self) -> AcceleratorConfig {
        match (self.kind, self.pe.kind) {
            (AcceleratorKind::Matraptor, PeKind::Baseline) => Self::matraptor_maple(),
            (AcceleratorKind::Matraptor, PeKind::Maple) => Self::matraptor_baseline(),
            (AcceleratorKind::Extensor, PeKind::Baseline) => Self::extensor_maple(),
            (AcceleratorKind::Extensor, PeKind::Maple) => Self::extensor_baseline(),
        }
    }

    /// Buffer sizes for the energy aggregation.
    pub fn buffer_sizes(&self) -> crate::energy::BufferSizes {
        crate::energy::BufferSizes {
            pe_buffer_bytes: self.pe.baseline_buffer_bytes(),
            l1_bytes: self.l1_bytes,
            pob_bytes: self.pob_bytes,
            reg_bytes: self.pe.maple_buffer_bytes(),
        }
    }

    /// Serialise to TOML.
    pub fn to_toml(&self) -> String {
        toml_io::to_toml(self)
    }

    /// Parse from TOML.
    pub fn from_toml(s: &str) -> Result<Self, toml_io::ConfigError> {
        toml_io::from_toml(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mac_counts_are_equalised() {
        // §IV.B: "we compare two different configurations with eight MAC
        // units" and "two configurations with 128 MAC units each".
        assert_eq!(AcceleratorConfig::matraptor_baseline().total_macs(), 8);
        assert_eq!(AcceleratorConfig::matraptor_maple().total_macs(), 8);
        assert_eq!(AcceleratorConfig::extensor_baseline().total_macs(), 128);
        assert_eq!(AcceleratorConfig::extensor_maple().total_macs(), 128);
    }

    #[test]
    fn paper_pe_counts() {
        assert_eq!(AcceleratorConfig::matraptor_baseline().num_pes, 8);
        assert_eq!(AcceleratorConfig::matraptor_maple().num_pes, 4);
        assert_eq!(AcceleratorConfig::extensor_baseline().num_pes, 128);
        assert_eq!(AcceleratorConfig::extensor_maple().num_pes, 8);
    }

    #[test]
    fn maple_matraptor_has_single_memory_level() {
        let c = AcceleratorConfig::matraptor_maple();
        assert_eq!(c.l1_bytes, 0);
        assert_eq!(c.pob_bytes, 0);
        assert!(c.pe.maple_buffer_bytes() > 0);
    }

    #[test]
    fn maple_extensor_keeps_llb_drops_pob() {
        let c = AcceleratorConfig::extensor_maple();
        assert!(c.l1_bytes > 0);
        assert_eq!(c.pob_bytes, 0);
    }

    #[test]
    fn counterparts_are_involutive() {
        for c in AcceleratorConfig::paper_configs() {
            assert_eq!(c.counterpart().counterpart().name, c.name);
        }
    }

    #[test]
    fn toml_round_trip() {
        for c in AcceleratorConfig::paper_configs() {
            let s = c.to_toml();
            let back = AcceleratorConfig::from_toml(&s).unwrap();
            assert_eq!(back, c);
        }
    }

    #[test]
    fn extensor_mesh_matches_pe_count() {
        let c = AcceleratorConfig::extensor_baseline();
        match c.noc {
            Topology::Mesh { width, height } => assert_eq!(width * height, c.num_pes),
            _ => panic!("extensor uses a mesh"),
        }
    }
}
