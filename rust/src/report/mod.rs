//! Report emitters: regenerate every table and figure of the paper as
//! markdown (for humans) and CSV (for plotting), in the paper's own layout.

use crate::accel::fig8;
use crate::config::AcceleratorConfig;
use crate::energy::TechModel;
use crate::sim::{
    CacheStats, ExhaustiveCheck, ExploreResult, PartialSweep, ServiceStats, SimResult,
    SweepResult, SweepShard,
};
use crate::sparse::suite::TABLE_I;

/// Render a markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", header.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Render CSV.
pub fn csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = header.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    s
}

/// Table I: the simulation datasets.
pub fn table1(markdown: bool) -> String {
    let header = ["Matrix", "Dim", "nnz", "Density"];
    let rows: Vec<Vec<String>> = TABLE_I
        .iter()
        .map(|d| {
            vec![
                format!("{} ({})", d.name, d.abbrev),
                format!("{}K x {}K", d.rows / 1000, d.cols / 1000),
                format!("{:.1}M", d.nnz as f64 / 1e6),
                format!("{:.1e}", d.density()),
            ]
        })
        .collect();
    if markdown {
        markdown_table(&header, &rows)
    } else {
        csv(&header, &rows)
    }
}

/// Fig. 3: normalized energy of computation vs data movement at 45 nm.
pub fn fig3(markdown: bool) -> String {
    let header = ["Operation", "Normalized energy (MAC = 1)"];
    let rows: Vec<Vec<String>> = TechModel::tech45()
        .fig3_rows()
        .into_iter()
        .map(|(name, v)| vec![name.to_string(), format!("{v:.2}")])
        .collect();
    if markdown {
        markdown_table(&header, &rows)
    } else {
        csv(&header, &rows)
    }
}

/// Fig. 8: PE-complex area, baseline vs Maple, for one accelerator pair.
pub fn fig8_report(base: &AcceleratorConfig, maple: &AcceleratorConfig, markdown: bool) -> String {
    let (rb, rm, ratio) = fig8(base, maple);
    let header = ["Config", "PEs", "MACs/PE", "MAC mm2", "Buffers mm2", "Logic mm2", "Total mm2"];
    let row = |r: &crate::accel::Fig8Row| {
        vec![
            r.config.clone(),
            r.num_pes.to_string(),
            r.macs_per_pe.to_string(),
            format!("{:.4}", r.mac_mm2),
            format!("{:.4}", r.buffers_mm2),
            format!("{:.4}", r.logic_mm2),
            format!("{:.4}", r.total_mm2),
        ]
    };
    let rows = vec![row(&rb), row(&rm)];
    let mut s = if markdown { markdown_table(&header, &rows) } else { csv(&header, &rows) };
    s.push_str(&format!("\narea ratio (baseline / maple): {ratio:.2}x\n"));
    s
}

/// The `maple cache stats` report: one row per metric of the on-disk
/// workload cache (see [`crate::sim::cache`] for the layout).
pub fn cache_stats_report(stats: &CacheStats, markdown: bool) -> String {
    let header = ["Metric", "Value"];
    let rows = vec![
        vec!["cache dir".into(), stats.dir.display().to_string()],
        vec!["workload artifacts (current codec)".into(), stats.workloads.to_string()],
        vec!["matrix artifacts (current codec)".into(), stats.matrices.to_string()],
        vec!["eval journals (current codec)".into(), stats.evals.to_string()],
        vec!["tile partials (current codec)".into(), stats.tiles.to_string()],
        vec!["stale / foreign files".into(), stats.stale.to_string()],
        vec!["total bytes".into(), stats.bytes.to_string()],
    ];
    if markdown {
        markdown_table(&header, &rows)
    } else {
        csv(&header, &rows)
    }
}

/// Per-row-group nnz balance under a tile shape: one row per row group,
/// built from [`crate::sparse::tile::row_group_summaries`]. Surfaces the
/// load-skew a tiled out-of-core profile will see before running it.
pub fn tiling_report(
    name: &str,
    a: &crate::sparse::Csr,
    shape: crate::sparse::TileShape,
    markdown: bool,
) -> String {
    let header = ["Group", "Rows", "nnz", "Mean/row", "CV", "Max row", "Max share", "Heavy share"];
    let rows: Vec<Vec<String>> = crate::sparse::tile::row_group_summaries(a, shape)
        .iter()
        .map(|t| {
            vec![
                format!("{} [{}, {})", t.index, t.row_lo, t.row_hi),
                t.summary.rows.to_string(),
                t.summary.nnz.to_string(),
                format!("{:.2}", t.summary.mean),
                format!("{:.2}", t.summary.cv),
                t.summary.max.to_string(),
                format!("{:.3}", t.summary.max_share),
                format!("{:.3}", t.summary.heavy_share),
            ]
        })
        .collect();
    let mut s = format!("tiling {name}: {}x{} at tile {shape}\n", a.rows(), a.cols());
    s.push_str(&if markdown { markdown_table(&header, &rows) } else { csv(&header, &rows) });
    s
}

/// One dataset's row in the Fig. 9 comparison.
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub dataset: String,
    /// Fig. 9(a): energy benefit % of Maple config over baseline.
    pub energy_benefit_pct: f64,
    /// Fig. 9(b): speedup % of Maple config over baseline.
    pub speedup_pct: f64,
    pub baseline_pj: f64,
    pub maple_pj: f64,
    pub baseline_cycles: u64,
    pub maple_cycles: u64,
}

impl Fig9Row {
    /// Build from a (baseline, maple) result pair.
    pub fn from_results(dataset: &str, base: &SimResult, maple: &SimResult) -> Self {
        Fig9Row {
            dataset: dataset.to_string(),
            energy_benefit_pct: maple.energy_benefit_pct(base),
            speedup_pct: maple.speedup_pct(base),
            baseline_pj: base.energy.total_pj(),
            maple_pj: maple.energy.total_pj(),
            baseline_cycles: base.cycles_compute,
            maple_cycles: maple.cycles_compute,
        }
    }
}

/// Fig. 9 rows for one (baseline, maple) config pair out of a sweep grid:
/// one row per dataset, labelled with the dataset key's name, all at the
/// given policy index.
pub fn fig9_rows_from_sweep(
    sweep: &SweepResult,
    baseline: usize,
    maple: usize,
    policy: usize,
) -> Vec<Fig9Row> {
    (0..sweep.datasets.len())
        .map(|d| {
            Fig9Row::from_results(
                &sweep.datasets[d].dataset,
                &sweep.get(d, baseline, policy).analytic,
                &sweep.get(d, maple, policy).analytic,
            )
        })
        .collect()
}

/// DES cross-validation table over a sweep that ran with
/// [`crate::sim::CellModel::Des`] or `Both`: per dataset × config (× policy
/// when more than one), the analytic and DES cycle counts, their agreement
/// ratio, the DES front-stage utilisation and finish skew (from the per-PE
/// stats), and whether the cell sits inside the documented band
/// ([`crate::sim::agreement_band`]). Cells without a DES result (analytic
/// sweeps) render as a single explanatory line instead.
pub fn des_validation_report(sweep: &SweepResult, markdown: bool) -> String {
    if sweep.iter().all(|(_, _, _, cell)| cell.des.is_none()) {
        return "no DES cells: run the sweep with cell model `des` or `both`\n".into();
    }
    let multi_policy = sweep.policies.len() > 1;
    let mut header = vec!["Dataset", "Config"];
    if multi_policy {
        header.push("Policy");
    }
    header.extend(["Analytic", "DES", "Ratio", "Util %", "Skew", "In band"]);
    let mut in_band_cells = 0usize;
    let mut des_cells = 0usize;
    let rows: Vec<Vec<String>> = sweep
        .iter()
        .filter_map(|(d, c, p, cell)| {
            let des = cell.des.as_ref()?;
            des_cells += 1;
            let in_band = cell.des_in_band() == Some(true);
            in_band_cells += in_band as usize;
            let mut row = vec![sweep.datasets[d].dataset.clone(), sweep.configs[c].clone()];
            if multi_policy {
                row.push(format!("{:?}", sweep.policies[p]));
            }
            row.extend([
                cell.analytic.cycles_compute.to_string(),
                des.cycles.to_string(),
                format!("{:.3}", cell.agreement_ratio().unwrap_or(0.0)),
                format!("{:.1}", 100.0 * des.pe_utilisation),
                format!("{:.2}", des.finish_skew()),
                if in_band { "yes" } else { "NO" }.to_string(),
            ]);
            Some(row)
        })
        .collect();
    let mut s =
        if markdown { markdown_table(&header, &rows) } else { csv(&header, &rows) };
    s.push_str(&format!(
        "\nDES/analytic agreement: {in_band_cells}/{des_cells} cells in band \
         (DES ≥ analytic; ratio ≈ 1 when datapath-bound)\n"
    ));
    s
}

/// Axis-grouped sweep table — the generic renderer for [`SweepResult`]
/// grids of any dimensionality (it replaces the dataset-major-only view):
/// one row per cell in grid (row-major) order, one leading column per
/// non-trivial axis (every axis when the grid is a single cell), then the
/// authoritative cycle count, energy, and — when the sweep ran the DES —
/// the DES cycles and agreement ratio.
pub fn sweep_axis_report(sweep: &SweepResult, markdown: bool) -> String {
    let has_des = (0..sweep.cell_count()).any(|i| sweep.cell(i).des.is_some());
    let mut shown: Vec<usize> =
        (0..sweep.dims.len()).filter(|&i| sweep.dims[i].len() > 1).collect();
    if shown.is_empty() {
        shown = (0..sweep.dims.len()).collect();
    }
    let mut header: Vec<&str> = shown.iter().map(|&i| sweep.dims[i].name).collect();
    header.extend(["cycles", "energy uJ"]);
    if has_des {
        header.extend(["DES", "ratio"]);
    }
    let rows: Vec<Vec<String>> = (0..sweep.cell_count())
        .map(|idx| {
            let cell = sweep.cell(idx);
            let mut row: Vec<String> =
                shown.iter().map(|&i| cell.coords[i].label.clone()).collect();
            row.push(cell.cycles(sweep.cell_model).to_string());
            row.push(format!("{:.3}", cell.analytic.energy.total_pj() / 1e6));
            if has_des {
                match &cell.des {
                    Some(d) => {
                        row.push(d.cycles.to_string());
                        row.push(format!("{:.3}", cell.agreement_ratio().unwrap_or(0.0)));
                    }
                    None => row.extend(["-".to_string(), "-".to_string()]),
                }
            }
            row
        })
        .collect();
    if markdown {
        markdown_table(&header, &rows)
    } else {
        csv(&header, &rows)
    }
}

/// Pivot the sweep grid on any named axis: one column of authoritative
/// cycle counts per point of the pivot axis, one row per combination of
/// the remaining axes (row-major grid order; trivial single-point axes are
/// elided from the row labels). `None` when `pivot` is not a dimension of
/// this grid.
pub fn sweep_pivot_report(sweep: &SweepResult, pivot: &str, markdown: bool) -> Option<String> {
    let p = sweep.dims.iter().position(|d| d.name == pivot)?;
    let others: Vec<usize> = (0..sweep.dims.len()).filter(|&i| i != p).collect();
    let shown: Vec<usize> =
        others.iter().copied().filter(|&i| sweep.dims[i].len() > 1).collect();
    let mut header: Vec<String> = shown.iter().map(|&i| sweep.dims[i].name.to_string()).collect();
    if header.is_empty() {
        header.push("cell".into());
    }
    for label in &sweep.dims[p].labels {
        header.push(format!("{pivot}={label}"));
    }
    let row_count: usize = others.iter().map(|&i| sweep.dims[i].len()).product();
    let mut rows = Vec::with_capacity(row_count);
    for r in 0..row_count {
        let mut coord = vec![0usize; sweep.dims.len()];
        let mut rem = r;
        for &i in others.iter().rev() {
            coord[i] = rem % sweep.dims[i].len();
            rem /= sweep.dims[i].len();
        }
        let mut row: Vec<String> =
            shown.iter().map(|&i| sweep.dims[i].labels[coord[i]].clone()).collect();
        if row.is_empty() {
            row.push("-".into());
        }
        for pi in 0..sweep.dims[p].len() {
            coord[p] = pi;
            row.push(sweep.at(&coord).cycles(sweep.cell_model).to_string());
        }
        rows.push(row);
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    Some(if markdown { markdown_table(&header_refs, &rows) } else { csv(&header_refs, &rows) })
}

/// Provenance of a merged sharded sweep: which shards made up the grid,
/// their cell ranges, wall-times, and warm-vs-cold cache behaviour, headed
/// by the shared space fingerprint. `maple merge` prints this to stderr so
/// stdout stays byte-identical to the unsharded sweep's table.
pub fn merge_provenance(shards: &[SweepShard], grid: &SweepResult) -> String {
    let fingerprint = shards.first().map(|s| s.fingerprint).unwrap_or(0);
    let mut s = format!(
        "merged {} shards (fingerprint {fingerprint:016x}): {} -> {} cells\n",
        shards.len(),
        grid.shape_line(),
        grid.cell_count()
    );
    for sh in shards {
        s.push_str(&format!(
            "  shard {}: cells [{}..{}) in {} ms ({} profiled, {} disk hits)\n",
            sh.spec,
            sh.range().start,
            sh.range().end,
            sh.meta.wall_ms,
            sh.meta.profiles_run,
            sh.meta.disk_hits
        ));
    }
    s
}

/// Provenance of a distributed sweep: the service counters that say *how*
/// the grid was assembled — worker count, lease reassignments (work
/// stolen from dead or stalled workers), idempotent duplicates, rejected
/// submissions, quarantines. `maple serve` prints this to stderr; the
/// chaos CI job greps the `reassignments:` line to prove the kill was
/// actually recovered from, so the indented counter lines are part of the
/// format contract.
pub fn service_provenance(stats: &ServiceStats) -> String {
    let mut s = format!(
        "service: fingerprint {:016x}: {}/{} shards from {} workers in {} ms\n",
        stats.fingerprint, stats.completed, stats.shard_count, stats.workers, stats.wall_ms
    );
    s.push_str(&format!("  reassignments: {}\n", stats.reassignments));
    s.push_str(&format!("  duplicates: {}\n", stats.duplicates));
    s.push_str(&format!("  rejected: {}\n", stats.rejected));
    s.push_str(&format!("  quarantined: {}\n", stats.quarantined));
    s
}

/// Provenance of a partial merge (`--allow-partial`): which shards made it,
/// which cell spans are missing, and how much of the grid the rendered
/// table actually covers. Loud by design — a partial result must never
/// read like a full one.
pub fn partial_provenance(partial: &PartialSweep) -> String {
    let mut s = format!(
        "PARTIAL merge: {} of {} shards (fingerprint {:016x}): {}/{} cells covered\n",
        partial.present.len(),
        partial.shard_count,
        partial.fingerprint,
        partial.covered_cells(),
        partial.total_cells
    );
    for spec in &partial.present {
        let r = spec.range(partial.total_cells);
        s.push_str(&format!("  shard {}: cells [{}..{})\n", spec, r.start, r.end));
    }
    for span in &partial.missing_spans {
        s.push_str(&format!(
            "  MISSING cells [{}..{}) ({} cells)\n",
            span.start,
            span.end,
            span.len()
        ));
    }
    s
}

/// The completed sub-grid of a partial merge as a table — the
/// [`sweep_axis_report`] layout (same columns, same label order) over only
/// the cells that arrived, headed by an explicit partial banner so the
/// output can never be mistaken for a full sweep.
pub fn partial_sweep_report(partial: &PartialSweep, markdown: bool) -> String {
    let mut shown: Vec<usize> =
        (0..partial.dims.len()).filter(|&i| partial.dims[i].len() > 1).collect();
    if shown.is_empty() {
        shown = (0..partial.dims.len()).collect();
    }
    let mut header: Vec<&str> = shown.iter().map(|&i| partial.dims[i].name).collect();
    header.extend(["cycles", "energy uJ"]);
    let rows: Vec<Vec<String>> = partial
        .segments
        .iter()
        .flat_map(|(_, cells)| cells.iter())
        .map(|cell| {
            let mut row: Vec<String> =
                shown.iter().map(|&i| cell.coords[i].label.clone()).collect();
            row.push(cell.cycles(partial.cell_model).to_string());
            row.push(format!("{:.3}", cell.analytic.energy.total_pj() / 1e6));
            row
        })
        .collect();
    let mut s = format!(
        "partial sweep: {}/{} cells ({} of {} shards missing)\n",
        partial.covered_cells(),
        partial.total_cells,
        partial.missing_shards(),
        partial.shard_count
    );
    s.push_str(&if markdown { markdown_table(&header, &rows) } else { csv(&header, &rows) });
    s
}

/// The machine-readable sweep benchmark (`BENCH_sweep.json`), emitted by
/// the CI merge job: total cells, per-shard wall-times and throughput, and
/// the warm (disk hits) vs cold (fresh profiles) split. Hand-rolled JSON —
/// the offline build has no serde (DESIGN.md §Dependencies).
pub fn bench_sweep_json(shards: &[SweepShard], grid: &SweepResult) -> String {
    // Throughput guards against a sub-millisecond wall-time reading as
    // infinite cells/sec on tiny grids.
    let cells_per_sec = |cells: usize, ms: u64| cells as f64 * 1000.0 / ms.max(1) as f64;
    let wall_sum: u64 = shards.iter().map(|s| s.meta.wall_ms).sum();
    let wall_critical = shards.iter().map(|s| s.meta.wall_ms).max().unwrap_or(0);
    let cold: u64 = shards.iter().map(|s| s.meta.profiles_run).sum();
    let warm: u64 = shards.iter().map(|s| s.meta.disk_hits).sum();
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"sweep\",\n");
    s.push_str(&format!("  \"cells\": {},\n", grid.cell_count()));
    s.push_str(&format!(
        "  \"fingerprint\": \"{:016x}\",\n",
        shards.first().map(|sh| sh.fingerprint).unwrap_or(0)
    ));
    s.push_str("  \"shards\": [\n");
    for (i, sh) in shards.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"index\": {}, \"count\": {}, \"cells\": {}, \"wall_ms\": {}, \
             \"cells_per_sec\": {:.3}, \"cold_profiles\": {}, \"warm_disk_hits\": {}}}{}\n",
            sh.spec.index,
            sh.spec.count,
            sh.cells.len(),
            sh.meta.wall_ms,
            cells_per_sec(sh.cells.len(), sh.meta.wall_ms),
            sh.meta.profiles_run,
            sh.meta.disk_hits,
            if i + 1 < shards.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n");
    // Shards run concurrently in the CI matrix, so the slowest shard is
    // the grid's wall-clock; the sum is the total compute burned.
    s.push_str(&format!("  \"wall_ms_sum\": {wall_sum},\n"));
    s.push_str(&format!("  \"wall_ms_critical_path\": {wall_critical},\n"));
    s.push_str(&format!(
        "  \"cells_per_sec\": {:.3},\n",
        cells_per_sec(grid.cell_count(), wall_critical)
    ));
    s.push_str(&format!("  \"cold_profiles\": {cold},\n"));
    s.push_str(&format!("  \"warm_disk_hits\": {warm}\n"));
    s.push_str("}\n");
    s
}

/// The machine-readable format-axis benchmark (`BENCH_format.json`),
/// emitted by the CI format job: the grid shape, sweep wall-clock and
/// throughput, and one entry per `fmt` point with its cell count,
/// authoritative cycle total, modeled DRAM traffic, and per-format
/// throughput. `None` when the grid has no `fmt` dimension. Hand-rolled
/// JSON like [`bench_sweep_json`].
pub fn bench_format_json(grid: &SweepResult, wall_ms: u64) -> Option<String> {
    let p = grid.dims.iter().position(|d| d.name == "fmt")?;
    let cells_per_sec = |cells: usize, ms: u64| cells as f64 * 1000.0 / ms.max(1) as f64;
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"format\",\n");
    s.push_str(&format!("  \"grid\": \"{}\",\n", grid.shape_line()));
    s.push_str(&format!("  \"cells\": {},\n", grid.cell_count()));
    s.push_str(&format!("  \"wall_ms\": {wall_ms},\n"));
    s.push_str(&format!(
        "  \"cells_per_sec\": {:.3},\n",
        cells_per_sec(grid.cell_count(), wall_ms)
    ));
    s.push_str("  \"formats\": [\n");
    let labels = &grid.dims[p].labels;
    for (fi, label) in labels.iter().enumerate() {
        let mut cells = 0usize;
        let mut cycles = 0u64;
        let mut dram = 0u64;
        for idx in 0..grid.cell_count() {
            let cell = grid.cell(idx);
            if cell.coords[p].index != fi {
                continue;
            }
            cells += 1;
            cycles += cell.cycles(grid.cell_model);
            dram += cell.analytic.counters.dram_read + cell.analytic.counters.dram_write;
        }
        s.push_str(&format!(
            "    {{\"format\": \"{label}\", \"cells\": {cells}, \"cycles\": {cycles}, \
             \"dram_words\": {dram}, \"cells_per_sec\": {:.3}}}{}\n",
            cells_per_sec(cells, wall_ms),
            if fi + 1 < labels.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    Some(s)
}

/// The `maple explore` report: one row per dataset search — sub-grid size,
/// the best point's axis coordinates and fitness, the fresh-simulation
/// counts per tier, and the memo/journal hit split — followed by each
/// dataset's best-so-far trajectory and the evaluations-vs-grid headline.
pub fn explore_report(result: &ExploreResult, markdown: bool) -> String {
    let mut s = format!(
        "explore: objective={} strategy={} tier={} budget={}/dataset grid={} cells \
         (fingerprint {:016x})\n\n",
        result.objective,
        result.strategy,
        result.tier,
        result.budget,
        result.grid_cells,
        result.fingerprint
    );
    let header = [
        "Dataset", "Cells", "Best point", "Fitness", "Est fitness", "Exact", "Est", "Memo",
        "Journal", "ms",
    ];
    let rows: Vec<Vec<String>> = result
        .searches
        .iter()
        .map(|d| {
            // Dataset is the row label; the remaining coordinates are the
            // design point.
            let point: Vec<String> = d.best_coords[1..]
                .iter()
                .map(|c| format!("{}={}", c.axis, c.label))
                .collect();
            vec![
                d.dataset.clone(),
                d.cells.to_string(),
                point.join(" "),
                format!("{:.1}", d.best_fitness),
                d.estimate_fitness.map_or("-".into(), |f| format!("{f:.1}")),
                d.evals_exact.to_string(),
                d.evals_estimate.to_string(),
                d.memo_hits.to_string(),
                d.journal_hits.to_string(),
                d.wall_ms.to_string(),
            ]
        })
        .collect();
    s.push_str(&if markdown { markdown_table(&header, &rows) } else { csv(&header, &rows) });
    for d in &result.searches {
        let steps: Vec<String> =
            d.trajectory.iter().map(|t| format!("{}:{:.1}", t.calls, t.fitness)).collect();
        s.push_str(&format!(
            "\n{} trajectory (calls:fitness): {}\n",
            d.dataset,
            steps.join(" → ")
        ));
    }
    s.push_str(&format!(
        "\nfresh evaluations: {} ({} exact + {} estimate) vs {} grid cells = {:.2}% of \
         exhaustive\n",
        result.evals_total(),
        result.evals_exact(),
        result.evals_estimate(),
        result.grid_cells,
        100.0 * result.eval_fraction()
    ));
    s
}

/// The `maple explore --exhaustive` verdict: per dataset, the search's best
/// point against the full-grid argmin, and whether it matched outright or
/// landed inside the estimator agreement band.
pub fn exhaustive_check_report(result: &ExploreResult, check: &ExhaustiveCheck) -> String {
    let mut s = String::new();
    for d in &check.per_dataset {
        let verdict = if d.argmin_match {
            "match=argmin"
        } else if d.in_band {
            "match=in-band"
        } else {
            "match=OUT-OF-BAND"
        };
        s.push_str(&format!(
            "{}: search {:.1} vs optimum {:.1} (cell {}) {}\n",
            d.dataset, d.search_fitness, d.best_fitness, d.best_index, verdict
        ));
    }
    let evals = result.evals_total().max(1);
    s.push_str(&format!(
        "exhaustive: {} cells in {} ms; search: {} fresh evals in {} ms — {:.0}x fewer \
         evaluations\n",
        check.cells,
        check.wall_ms,
        result.evals_total(),
        result.wall_ms,
        check.cells as f64 / evals as f64
    ));
    s
}

/// One dataset's row of the `maple estval` gate: the sampled profiler's
/// measured error against the exact profile, the bound it claimed, and the
/// row-nnz shape statistics the stratification responds to.
#[derive(Debug, Clone, PartialEq)]
pub struct EstvalRow {
    pub dataset: String,
    pub rows: usize,
    pub nnz: usize,
    /// Row-nnz coefficient of variation ([`crate::sparse::stats::RowNnzSummary`]).
    pub cv: f64,
    /// Heavy-row (> 2× mean nnz) share of all nonzeros.
    pub heavy_share: f64,
    pub sampled_rows: usize,
    pub exact_out: u64,
    pub est_out: u64,
    /// |est − exact| / exact for `out_nnz`.
    pub measured_rel_err: f64,
    /// The estimator's own claimed bound ([`crate::sim::WorkloadEstimate`]).
    pub claimed_rel_err: f64,
    /// Worst relative cycle error across the paper configs.
    pub max_cycle_err: f64,
    /// Worst relative energy error across the paper configs.
    pub max_energy_err: f64,
    /// All gates hold: measured ≤ claimed, and simulated cycles/energy
    /// within the agreement band.
    pub in_band: bool,
}

/// The `maple estval` cross-validation table (the sampled-profiler analogue
/// of [`des_validation_report`]).
pub fn estval_report(rows: &[EstvalRow], budget: usize, markdown: bool) -> String {
    let header = [
        "Dataset", "Rows", "Sampled", "CV", "Heavy %", "Exact out", "Est out", "Err %",
        "Claimed %", "Cycle err %", "Energy err %", "In band",
    ];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.rows.to_string(),
                r.sampled_rows.to_string(),
                format!("{:.2}", r.cv),
                format!("{:.1}", 100.0 * r.heavy_share),
                r.exact_out.to_string(),
                r.est_out.to_string(),
                format!("{:.2}", 100.0 * r.measured_rel_err),
                format!("{:.2}", 100.0 * r.claimed_rel_err),
                format!("{:.2}", 100.0 * r.max_cycle_err),
                format!("{:.2}", 100.0 * r.max_energy_err),
                if r.in_band { "yes" } else { "NO" }.to_string(),
            ]
        })
        .collect();
    let in_band = rows.iter().filter(|r| r.in_band).count();
    let mut s = if markdown { markdown_table(&header, &body) } else { csv(&header, &body) };
    s.push_str(&format!(
        "\nestimator agreement: {in_band}/{} datasets in band at budget {budget} \
         (band ±{:.0}%, measured ≤ claimed)\n",
        rows.len(),
        100.0 * crate::sim::ESTIMATE_BAND
    ));
    s
}

/// The machine-readable explore benchmark (`BENCH_explore.json`): the
/// search's fresh-evaluation counts and wall-clock, per-dataset best
/// points, and — when the exhaustive sweep ran — the measured reduction
/// factor. Hand-rolled JSON like [`bench_sweep_json`].
pub fn bench_explore_json(result: &ExploreResult, check: Option<&ExhaustiveCheck>) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"explore\",\n");
    s.push_str(&format!("  \"objective\": \"{}\",\n", result.objective));
    s.push_str(&format!("  \"strategy\": \"{}\",\n", result.strategy));
    s.push_str(&format!("  \"tier\": \"{}\",\n", result.tier));
    s.push_str(&format!("  \"budget_per_dataset\": {},\n", result.budget));
    s.push_str(&format!("  \"fingerprint\": \"{:016x}\",\n", result.fingerprint));
    s.push_str(&format!("  \"grid_cells\": {},\n", result.grid_cells));
    s.push_str(&format!("  \"evals_exact\": {},\n", result.evals_exact()));
    s.push_str(&format!("  \"evals_estimate\": {},\n", result.evals_estimate()));
    s.push_str(&format!("  \"evals_total\": {},\n", result.evals_total()));
    s.push_str(&format!("  \"eval_fraction\": {:.6},\n", result.eval_fraction()));
    s.push_str(&format!("  \"memo_hits\": {},\n", result.memo_hits()));
    s.push_str(&format!("  \"journal_hits\": {},\n", result.journal_hits()));
    s.push_str(&format!("  \"wall_ms\": {},\n", result.wall_ms));
    s.push_str("  \"datasets\": [\n");
    for (i, d) in result.searches.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"dataset\": \"{}\", \"cells\": {}, \"best_index\": {}, \"fitness\": {:.3}, \
             \"evals_exact\": {}, \"evals_estimate\": {}, \"memo_hits\": {}, \
             \"journal_hits\": {}, \"wall_ms\": {}}}{}\n",
            d.dataset,
            d.cells,
            d.best_index,
            d.best_fitness,
            d.evals_exact,
            d.evals_estimate,
            d.memo_hits,
            d.journal_hits,
            if i + 1 < result.searches.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    match check {
        Some(c) => {
            s.push_str(",\n  \"exhaustive\": {\n");
            s.push_str(&format!("    \"cells\": {},\n", c.cells));
            s.push_str(&format!("    \"wall_ms\": {},\n", c.wall_ms));
            s.push_str(&format!(
                "    \"eval_reduction\": {:.1},\n",
                c.cells as f64 / result.evals_total().max(1) as f64
            ));
            s.push_str(&format!(
                "    \"wall_clock_speedup\": {:.1},\n",
                c.wall_ms as f64 / result.wall_ms.max(1) as f64
            ));
            s.push_str(&format!("    \"all_in_band\": {},\n", c.all_in_band()));
            s.push_str(&format!(
                "    \"argmin_matches\": {}\n",
                c.per_dataset.iter().filter(|d| d.argmin_match).count()
            ));
            s.push_str("  }\n");
        }
        None => s.push('\n'),
    }
    s.push_str("}\n");
    s
}

/// Fig. 9 report over a set of dataset rows, with the paper-style mean.
pub fn fig9_report(title: &str, rows: &[Fig9Row], markdown: bool) -> String {
    let header = ["Dataset", "Energy benefit %", "Speedup %"];
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                format!("{:.1}", r.energy_benefit_pct),
                format!("{:.1}", r.speedup_pct),
            ]
        })
        .collect();
    let mean_e = rows.iter().map(|r| r.energy_benefit_pct).sum::<f64>() / rows.len().max(1) as f64;
    let mean_s = rows.iter().map(|r| r.speedup_pct).sum::<f64>() / rows.len().max(1) as f64;
    let mut s = format!("## {title}\n\n");
    s.push_str(&if markdown { markdown_table(&header, &body) } else { csv(&header, &body) });
    s.push_str(&format!("\nmean energy benefit: {mean_e:.1}%   mean speedup: {mean_s:.1}%\n"));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_fourteen() {
        let t = table1(true);
        assert_eq!(t.lines().count(), 2 + 14);
        assert!(t.contains("web-Google"));
        assert!(t.contains("6.1e-6"));
    }

    #[test]
    fn fig3_contains_all_lanes() {
        let f = fig3(false);
        for lane in ["MAC", "C/D", "IN", "L0<->MAC", "PE<->MAC", "L1<->MAC", "L2<->MAC"] {
            assert!(f.contains(lane), "missing {lane}");
        }
    }

    #[test]
    fn fig8_report_prints_ratio() {
        let s = fig8_report(
            &AcceleratorConfig::matraptor_baseline(),
            &AcceleratorConfig::matraptor_maple(),
            true,
        );
        assert!(s.contains("area ratio"));
        assert!(s.contains("matraptor-baseline"));
    }

    #[test]
    fn cache_stats_report_lists_every_metric() {
        let stats = CacheStats {
            dir: std::path::PathBuf::from("/tmp/maple-cache"),
            workloads: 14,
            matrices: 2,
            evals: 3,
            tiles: 5,
            stale: 1,
            bytes: 4096,
        };
        let md = cache_stats_report(&stats, true);
        for needle in
            ["/tmp/maple-cache", "workload artifacts", "eval journals", "tile partials", "14", "4096"]
        {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
        let c = cache_stats_report(&stats, false);
        assert!(c.lines().count() == 8 && c.starts_with("Metric,Value"));
    }

    #[test]
    fn tiling_report_covers_every_row_group() {
        use crate::sparse::gen::{generate, Profile};
        use crate::sparse::TileShape;
        let a = generate(64, 64, 800, Profile::PowerLaw { alpha: 0.8 }, 7);
        let shape = TileShape::new(16, 32);
        let md = tiling_report("pl", &a, shape, true);
        assert!(md.starts_with("tiling pl: 64x64 at tile 16x32"), "{md}");
        for g in 0..4 {
            assert!(md.contains(&format!("{} [{}, {})", g, g * 16, (g + 1) * 16)), "{md}");
        }
        let c = tiling_report("pl", &a, shape, false);
        // Title line + header + one row per group.
        assert_eq!(c.lines().count(), 6, "{c}");
        assert!(c.lines().nth(1).unwrap().starts_with("Group,Rows,nnz"), "{c}");
    }

    #[test]
    fn des_validation_report_covers_every_cell() {
        use crate::sim::{CellModel, SimEngine, SweepSpec, WorkloadKey};
        let engine = SimEngine::new();
        let key = WorkloadKey::suite("wv", 7, 64);
        let both = engine
            .sweep(&SweepSpec::paper(vec![key.clone()]).with_cell_model(CellModel::Both))
            .unwrap();
        let md = des_validation_report(&both, true);
        for cfg in &both.configs {
            assert!(md.contains(cfg.as_str()), "missing {cfg} in:\n{md}");
        }
        assert!(md.contains("4/4 cells in band"), "{md}");
        let c = des_validation_report(&both, false);
        assert!(c.starts_with("Dataset,Config,Analytic,DES,Ratio"));
        // An analytic sweep has nothing to cross-validate.
        let analytic = engine.sweep(&SweepSpec::paper(vec![key])).unwrap();
        assert!(des_validation_report(&analytic, true).starts_with("no DES cells"));
    }

    #[test]
    fn axis_report_and_pivot_cover_the_grid() {
        use crate::coordinator::Policy;
        use crate::noc::Topology;
        use crate::sim::{Axis, DesignSpace, SimEngine, WorkloadKey};
        let engine = SimEngine::new();
        let grid = engine
            .sweep(
                &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
                    .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
                    .with_axis(Axis::topology(vec![
                        Topology::Crossbar { ports: 8 },
                        Topology::Mesh { width: 4, height: 2 },
                    ]))
                    .with_axis(Axis::macs_per_pe(vec![2, 4])),
            )
            .unwrap();
        let md = sweep_axis_report(&grid, true);
        // Non-trivial axes appear as columns; each cell is one row.
        assert!(md.starts_with("| noc | macs | cycles | energy uJ |"), "{md}");
        assert_eq!(md.lines().count(), 2 + grid.cell_count(), "{md}");
        for needle in ["crossbar:8", "mesh:4x2", "| 2 |", "| 4 |"] {
            assert!(md.contains(needle), "missing {needle} in:\n{md}");
        }
        let c = sweep_axis_report(&grid, false);
        assert!(c.starts_with("noc,macs,cycles,energy uJ"), "{c}");

        // Pivot on the noc axis: one row per macs point, one cycles column
        // per topology; values match direct grid addressing.
        let pv = sweep_pivot_report(&grid, "noc", true).unwrap();
        assert!(pv.starts_with("| macs | noc=crossbar:8 | noc=mesh:4x2 |"), "{pv}");
        assert_eq!(pv.lines().count(), 2 + 2, "{pv}");
        let pv = sweep_pivot_report(&grid, "macs", false).unwrap();
        assert!(pv.starts_with("noc,macs=2,macs=4"), "{pv}");
        for (ni, mi) in [(0usize, 0usize), (1, 1)] {
            let cycles = grid
                .at(&[0, 0, ni, mi, 0])
                .cycles(grid.cell_model)
                .to_string();
            assert!(pv.contains(&cycles), "missing cycles {cycles} in:\n{pv}");
        }
        // Unknown axis → None.
        assert!(sweep_pivot_report(&grid, "warp", true).is_none());

        // A des-bearing sweep grows the DES columns.
        let both = engine
            .sweep(
                &DesignSpace::paper(vec![WorkloadKey::suite("wv", 7, 64)])
                    .with_cell_model(crate::sim::CellModel::Both),
            )
            .unwrap();
        let md = sweep_axis_report(&both, true);
        assert!(md.starts_with("| config | cycles | energy uJ | DES | ratio |"), "{md}");
        // Single-cell grid: every axis is shown rather than none.
        let single = engine
            .sweep(&DesignSpace::new(
                vec![AcceleratorConfig::extensor_maple()],
                vec![WorkloadKey::suite("wv", 7, 64)],
                vec![Policy::RoundRobin],
            ))
            .unwrap();
        let md = sweep_axis_report(&single, true);
        assert!(md.starts_with("| dataset | config | policy | cycles |"), "{md}");
    }

    #[test]
    fn merge_provenance_and_bench_json_cover_every_shard() {
        use crate::sim::{shard, ShardSpec, SimEngine, SweepSpec, WorkloadKey};
        let engine = SimEngine::new();
        let spec = SweepSpec::paper(vec![WorkloadKey::suite("wv", 7, 64)]);
        let shards: Vec<_> = (0..2)
            .map(|i| engine.sweep_shard(&spec, ShardSpec::new(i, 2).unwrap()).unwrap())
            .collect();
        let grid = shard::merge(&shards).unwrap();
        let prov = merge_provenance(&shards, &grid);
        assert!(prov.starts_with("merged 2 shards (fingerprint "), "{prov}");
        assert!(prov.contains("shard 0/2: cells [0..2)"), "{prov}");
        assert!(prov.contains("shard 1/2: cells [2..4)"), "{prov}");
        let json = bench_sweep_json(&shards, &grid);
        for needle in [
            "\"bench\": \"sweep\"",
            "\"cells\": 4",
            "\"wall_ms_sum\":",
            "\"wall_ms_critical_path\":",
            "\"cells_per_sec\":",
            // One dataset: shard 0 profiles it cold, shard 1 reuses the
            // shared engine's in-memory slot.
            "\"cold_profiles\": 1",
            "\"warm_disk_hits\": 0",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        assert_eq!(json.matches("\"index\":").count(), 2, "{json}");
    }

    #[test]
    fn bench_format_json_covers_every_format_point() {
        use crate::sim::{Axis, DesignSpace, SimEngine, WorkloadKey};
        use crate::sparse::SparseFormat;
        let engine = SimEngine::new();
        let grid = engine
            .sweep(
                &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
                    .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
                    .with_axis(Axis::format(SparseFormat::ALL.to_vec())),
            )
            .unwrap();
        let json = bench_format_json(&grid, 40).unwrap();
        for needle in [
            "\"bench\": \"format\"",
            "\"cells\": 5",
            "\"wall_ms\": 40",
            "\"format\": \"csr\"",
            "\"format\": \"csc\"",
            "\"format\": \"coo\"",
            "\"format\": \"bitmap\"",
            "\"format\": \"blocked\"",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        assert_eq!(json.matches("\"cells\": 1,").count(), 5, "{json}");
        // The `fmt` pivot rides the generic pivot report.
        let pv = sweep_pivot_report(&grid, "fmt", true).unwrap();
        assert!(pv.contains("fmt=csr") && pv.contains("fmt=blocked"), "{pv}");
        // A formatless grid has no format benchmark.
        let plain = engine
            .sweep(
                &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
                    .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)])),
            )
            .unwrap();
        assert!(bench_format_json(&plain, 40).is_none());
    }

    #[test]
    fn service_and_partial_reports_are_loud() {
        use crate::sim::{shard, ShardSpec, SimEngine, SweepSpec, WorkloadKey};
        let engine = SimEngine::new();
        let spec = SweepSpec::paper(vec![WorkloadKey::suite("wv", 7, 64)]);
        // Two of three shards: the middle one never arrives.
        let shards: Vec<_> = [0usize, 2]
            .iter()
            .map(|&i| engine.sweep_shard(&spec, ShardSpec::new(i, 3).unwrap()).unwrap())
            .collect();
        let partial = shard::merge_partial(&shards).unwrap();
        let prov = partial_provenance(&partial);
        assert!(prov.starts_with("PARTIAL merge: 2 of 3 shards"), "{prov}");
        assert!(prov.contains("shard 0/3"), "{prov}");
        assert!(prov.contains("MISSING cells [2..3) (1 cells)"), "{prov}");
        let table = partial_sweep_report(&partial, true);
        assert!(table.starts_with("partial sweep: 3/4 cells (1 of 3 shards missing)"), "{table}");
        assert_eq!(table.lines().count(), 1 + 2 + 3, "{table}");

        let stats = ServiceStats {
            fingerprint: 0xABCD,
            shard_count: 6,
            completed: 6,
            workers: 3,
            reassignments: 1,
            duplicates: 2,
            rejected: 0,
            quarantined: 1,
            wall_ms: 1234,
        };
        let s = service_provenance(&stats);
        assert!(s.starts_with("service: fingerprint 000000000000abcd: 6/6 shards"), "{s}");
        for needle in
            ["  reassignments: 1\n", "  duplicates: 2\n", "  rejected: 0\n", "  quarantined: 1\n"]
        {
            assert!(s.contains(needle), "missing {needle:?} in:\n{s}");
        }
    }

    #[test]
    fn csv_and_markdown_shapes() {
        let rows = vec![vec!["a".into(), "1".into()]];
        let md = markdown_table(&["x", "y"], &rows);
        assert!(md.starts_with("| x | y |"));
        let c = csv(&["x", "y"], &rows);
        assert_eq!(c, "x,y\na,1\n");
    }
}
