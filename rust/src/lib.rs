//! # Maple — row-wise product sparse tensor accelerator framework
//!
//! A full reproduction of *"Maple: A Processing Element for Row-Wise Product
//! Based Sparse Tensor Accelerators"* (Reshadi & Gregg, DAC'23) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the accelerator simulation framework: sparse
//!   matrix substrate, the Maple / Matraptor / Extensor processing-element
//!   micro-architectures, memory hierarchy, NoC, intersection units, a
//!   discrete-event simulator with per-action energy accounting, a
//!   CACTI-style area model, a row-partitioning coordinator, and report
//!   emitters for every table and figure in the paper.
//! * **Layer 2 (python/compile/model.py)** — the Gustavson dataflow as a JAX
//!   compute graph, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/maple_pe.py)** — the Maple PE datapath
//!   as a Pallas kernel, validated against a pure-jnp oracle.
//!
//! The `runtime` module (behind the `runtime` cargo feature) loads the AOT
//! artifacts via PJRT so the Rust hot path can execute the compiled datapath
//! with **no Python at runtime**.
//!
//! ## Quickstart
//!
//! Everything runs through [`sim::SimEngine`]: it profiles each workload
//! exactly once (cached by dataset/seed/scale) and fans sweep cells out
//! across worker threads, returning a deterministic result grid.
//!
//! ```no_run
//! use maple::prelude::*;
//!
//! let engine = SimEngine::new();
//! // The paper's Fig.-9 sweep on one Table-I dataset: all four
//! // configurations × wikiVote × round-robin routing.
//! let grid = engine
//!     .sweep(&SweepSpec::paper(vec![WorkloadKey::suite("wikiVote", 7, 16)]))
//!     .unwrap();
//! // Configs are in `paper_configs()` order; the headline comparison is
//! // baseline Extensor (2) vs Maple-based Extensor (3).
//! let (base, mpl) = (grid.get(0, 2, 0), grid.get(0, 3, 0));
//! println!("energy benefit: {:.1}%", mpl.analytic.energy_benefit_pct(&base.analytic));
//! println!("speedup: {:.1}%", mpl.analytic.speedup_pct(&base.analytic));
//! ```
//!
//! Design-space exploration generalises the same sweep: a
//! [`sim::DesignSpace`] is a base config set plus ordered typed
//! [`sim::Axis`] values (dataset, NoC topology, MACs/PE, prefetch depth,
//! PE model, policy), expanded into a deterministic index-addressed grid
//! whose cells carry named-axis coordinates:
//!
//! ```no_run
//! use maple::prelude::*;
//!
//! let grid = SimEngine::new()
//!     .sweep(
//!         &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
//!             .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 16)]))
//!             .with_axis(Axis::topology(vec![
//!                 Topology::Crossbar { ports: 8 },
//!                 Topology::Mesh { width: 4, height: 2 },
//!             ]))
//!             .with_axis(Axis::macs_per_pe(vec![2, 4, 8, 16])),
//!     )
//!     .unwrap();
//! assert_eq!(grid.shape(), vec![1, 1, 2, 4, 1]); // dataset·config·noc·macs·policy
//! let cell = grid.at(&[0, 0, 1, 2, 0]); // mesh:4x2, 8 MACs/PE
//! println!("{:?} -> {} cycles", cell.coords, cell.analytic.cycles_compute);
//! ```
//!
//! One-off runs skip the spec: [`sim::SimEngine::simulate`] gives a single
//! (config, dataset, policy) cell against the same cache, and the low-level
//! [`sim::simulate_spmspm`] drives caller-built matrices directly. New PE
//! micro-architectures plug in through [`pe::registry`] (see the [`pe`]
//! module docs) — no accelerator-layer changes required.

pub mod accel;
pub mod analysis;
pub mod area;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod gustavson;
pub mod intersect;
pub mod mem;
pub mod noc;
pub mod pe;
pub mod report;
#[cfg(feature = "runtime")]
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod trace;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::accel::Accelerator;
    pub use crate::config::{AcceleratorConfig, AcceleratorKind, PeKind};
    pub use crate::coordinator::Policy;
    pub use crate::energy::{EnergyBreakdown, TechModel};
    pub use crate::gustavson::spgemm_rowwise;
    pub use crate::config::ConfigAxis;
    pub use crate::noc::Topology;
    pub use crate::sim::{
        simulate_spmspm, Axis, CellModel, CellResult, DesResult, DesignSpace, DiskCache,
        ExploreResult, ExploreSpec, Explorer, Objective, ShardSpec, SimEngine, SimResult,
        Strategy, SweepResult, SweepShard, SweepSpec, Tier, WorkloadKey,
    };
    pub use crate::sparse::{Coo, Csc, Csr, FormatPlan, SparseFormat};
}
