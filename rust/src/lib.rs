//! # Maple — row-wise product sparse tensor accelerator framework
//!
//! A full reproduction of *"Maple: A Processing Element for Row-Wise Product
//! Based Sparse Tensor Accelerators"* (Reshadi & Gregg, DAC'23) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the accelerator simulation framework: sparse
//!   matrix substrate, the Maple / Matraptor / Extensor processing-element
//!   micro-architectures, memory hierarchy, NoC, intersection units, a
//!   discrete-event simulator with per-action energy accounting, a
//!   CACTI-style area model, a row-partitioning coordinator, and report
//!   emitters for every table and figure in the paper.
//! * **Layer 2 (python/compile/model.py)** — the Gustavson dataflow as a JAX
//!   compute graph, AOT-lowered to HLO text at build time.
//! * **Layer 1 (python/compile/kernels/maple_pe.py)** — the Maple PE datapath
//!   as a Pallas kernel, validated against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT so the Rust hot
//! path can execute the compiled datapath with **no Python at runtime**.
//!
//! ## Quickstart
//!
//! ```no_run
//! use maple::prelude::*;
//!
//! // A Table-I-like synthetic workload.
//! let a = maple::sparse::suite::by_name("wikiVote").unwrap().generate(7);
//! // The paper's headline comparison: Maple-based vs baseline Extensor.
//! let base = AcceleratorConfig::extensor_baseline();
//! let mpl  = AcceleratorConfig::extensor_maple();
//! let rb = maple::sim::simulate_spmspm(&base, &a, &a);
//! let rm = maple::sim::simulate_spmspm(&mpl, &a, &a);
//! println!("energy benefit: {:.1}%", 100.0 * (1.0 - rm.energy.total_pj() / rb.energy.total_pj()));
//! ```

pub mod accel;
pub mod area;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod gustavson;
pub mod intersect;
pub mod mem;
pub mod noc;
pub mod pe;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sparse;
pub mod trace;

/// Convenient re-exports for downstream users.
pub mod prelude {
    pub use crate::accel::Accelerator;
    pub use crate::config::{AcceleratorConfig, AcceleratorKind, PeKind};
    pub use crate::energy::{EnergyBreakdown, TechModel};
    pub use crate::gustavson::spgemm_rowwise;
    pub use crate::sim::{simulate_spmspm, SimResult};
    pub use crate::sparse::{Coo, Csc, Csr};
}
