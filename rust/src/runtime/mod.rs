//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! from the Rust hot path — Python never runs at request time.
//!
//! The interchange format is **HLO text** (not a serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see `python/compile/aot.py` and
//! /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

/// Errors from artifact loading / execution.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact not found: {0} (run `make artifacts` first)")]
    Missing(PathBuf),
    #[error("artifact metadata error: {0}")]
    Meta(String),
    #[error("xla error: {0}")]
    Xla(#[from] xla::Error),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Dimensions of the compiled Maple datapath tile, written by `aot.py`
/// alongside the HLO artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileMeta {
    /// ARB tile: A-row elements per invocation (`k'` window).
    pub kt: usize,
    /// PSB tile: output columns per invocation (the paper's `N`).
    pub nt: usize,
    /// Rows per batched model invocation.
    pub rows: usize,
}

impl TileMeta {
    /// Parse the flat-integer-object JSON `aot.py` writes, e.g.
    /// `{"kt": 16, "nt": 128, "rows": 8}` (no external JSON dependency in
    /// the offline build — see DESIGN.md §Dependencies).
    pub fn from_json(s: &str) -> Result<Self, RuntimeError> {
        let body = s
            .trim()
            .strip_prefix('{')
            .and_then(|t| t.trim_end().strip_suffix('}'))
            .ok_or_else(|| RuntimeError::Meta("meta.json: not a JSON object".into()))?;
        let (mut kt, mut nt, mut rows) = (None, None, None);
        for field in body.split(',') {
            let (key, val) = field
                .split_once(':')
                .ok_or_else(|| RuntimeError::Meta(format!("meta.json: bad field {field:?}")))?;
            let key = key.trim().trim_matches('"');
            let val: usize = val
                .trim()
                .parse()
                .map_err(|_| RuntimeError::Meta(format!("meta.json: bad value for {key}")))?;
            match key {
                "kt" => kt = Some(val),
                "nt" => nt = Some(val),
                "rows" => rows = Some(val),
                other => return Err(RuntimeError::Meta(format!("meta.json: unknown key {other}"))),
            }
        }
        match (kt, nt, rows) {
            (Some(kt), Some(nt), Some(rows)) => Ok(TileMeta { kt, nt, rows }),
            _ => Err(RuntimeError::Meta("meta.json: missing kt/nt/rows".into())),
        }
    }

    /// Serialise back to the same JSON shape.
    pub fn to_json(&self) -> String {
        format!("{{\"kt\": {}, \"nt\": {}, \"rows\": {}}}", self.kt, self.nt, self.rows)
    }
}

/// A compiled HLO module ready to execute on the PJRT CPU client.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl LoadedModule {
    /// Load HLO text from `path` and compile it.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self, RuntimeError> {
        if !path.exists() {
            return Err(RuntimeError::Missing(path.to_path_buf()));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("artifact path is valid UTF-8"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self { exe, name: path.file_stem().unwrap_or_default().to_string_lossy().into_owned() })
    }

    /// Execute with literal inputs; returns the unwrapped tuple elements.
    /// (aot.py lowers with `return_tuple=True`.)
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal, RuntimeError> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result)
    }

    /// Module name (artifact file stem).
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// The Maple PE datapath compiled from the Pallas kernel: one invocation
/// computes `PSB[0..nt] = Σ_k ARB_vals[k] · BRB_dense[k, 0..nt]` — Eq. (3)
/// plus the PSB accumulation of Eq. (7) for one (A-row-tile, PSB-tile) pair.
pub struct MapleDatapath {
    module: LoadedModule,
    meta: TileMeta,
}

impl MapleDatapath {
    /// Load `maple_pe.hlo.txt` + `meta.json` from the artifacts directory.
    pub fn load(client: &xla::PjRtClient, artifacts_dir: &Path) -> Result<Self, RuntimeError> {
        let meta_path = artifacts_dir.join("meta.json");
        if !meta_path.exists() {
            return Err(RuntimeError::Missing(meta_path));
        }
        let meta = TileMeta::from_json(&std::fs::read_to_string(meta_path)?)?;
        let module = LoadedModule::load(client, &artifacts_dir.join("maple_pe.hlo.txt"))?;
        Ok(Self { module, meta })
    }

    /// Tile dimensions.
    pub fn meta(&self) -> TileMeta {
        self.meta
    }

    /// Execute one tile: `a_vals` has length `kt` (zero-padded ARB lane
    /// values), `b_dense` is `kt × nt` row-major (gathered/decompressed BRB
    /// content). Returns the `nt` partial sums.
    pub fn run_tile(&self, a_vals: &[f32], b_dense: &[f32]) -> Result<Vec<f32>, RuntimeError> {
        let (kt, nt) = (self.meta.kt, self.meta.nt);
        if a_vals.len() != kt || b_dense.len() != kt * nt {
            return Err(RuntimeError::Meta(format!(
                "tile shape mismatch: got a={}, b={}, want a={kt}, b={}",
                a_vals.len(),
                b_dense.len(),
                kt * nt
            )));
        }
        let a = xla::Literal::vec1(a_vals);
        let b = xla::Literal::vec1(b_dense).reshape(&[kt as i64, nt as i64])?;
        let out = self.module.run(&[a, b])?;
        let tuple = out.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?)
    }
}

/// Default artifacts directory: `$MAPLE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("MAPLE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
        let err = LoadedModule::load(&client, Path::new("/nonexistent/x.hlo.txt"));
        assert!(matches!(err, Err(RuntimeError::Missing(_))));
        let err = MapleDatapath::load(&client, Path::new("/nonexistent"));
        assert!(matches!(err, Err(RuntimeError::Missing(_))));
    }

    #[test]
    fn tile_meta_round_trips_json() {
        let m = TileMeta { kt: 16, nt: 128, rows: 8 };
        let back = TileMeta::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn tile_meta_rejects_malformed_json() {
        assert!(TileMeta::from_json("not json").is_err());
        assert!(TileMeta::from_json("{\"kt\": 16}").is_err());
        assert!(TileMeta::from_json("{\"kt\": 16, \"nt\": 1, \"bogus\": 2}").is_err());
        assert!(TileMeta::from_json("{\"kt\": \"x\", \"nt\": 1, \"rows\": 2}").is_err());
    }

    // Execution against real artifacts is covered by rust/tests/runtime_aot.rs
    // (integration test, requires `make artifacts`).
}
