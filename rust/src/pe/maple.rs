//! The Maple processing element (paper §III, Figs. 6–7).
//!
//! Datapath: a row of **A** is loaded into the ARB (values + `col_id` +
//! `row_ptr` metadata); for each `k' ← A.col_id[i]` the nonzeros of
//! `B[k',:]` stream through the BRB; `k` MAC units consume the product
//! stream in parallel; each product `A.value[i][k'] × B.value[k'][j']`
//! accumulates into the PSB register addressed by `j'` (Eq. 8), whose
//! per-register adder performs Eq. (7) locally. Final sums drain straight
//! from the PSB — no sorting queues, no POB.
//!
//! When an output row has more distinct `j'` than PSB registers, the row is
//! processed in disjoint **column segments**: each pass handles one PSB-load
//! of output columns, re-scanning the ARB to re-issue B-row fetches for the
//! next range. Segments are exact (ranges are disjoint) so no re-merge is
//! ever needed; the cost is the extra ARB re-reads and per-segment setup,
//! which [`MaplePe::row_cost`] charges.

use super::{PeModel, RowCost, RowProfile};
use crate::config::{AcceleratorConfig, PeConfig};
use crate::sparse::Csr;
use crate::trace::Counters;

/// Cycles to refill the pipeline at each segment boundary.
const SEGMENT_SETUP: u64 = 4;
/// Row-setup cycles exposed per row: zero — the ARB is a double-buffered
/// FIFO (paper §III), so the next row's A elements and `row_ptr` metadata
/// stream in while the current row computes.
const ROW_SETUP: u64 = 0;

/// Cost + functional model of one Maple PE.
#[derive(Debug, Clone)]
pub struct MaplePe {
    macs: usize,
    arb_entries: usize,
    brb_entries: usize,
    psb_entries: usize,
}

impl MaplePe {
    /// Build from the PE section of an accelerator config.
    pub fn from_config(cfg: &AcceleratorConfig) -> Self {
        Self::new(&cfg.pe)
    }

    /// Build from a PE config (must be [`PeKind::Maple`](crate::config::PeKind::Maple)-shaped).
    pub fn new(pe: &PeConfig) -> Self {
        assert!(pe.macs_per_pe >= 1, "Maple PE needs at least one MAC");
        assert!(pe.psb_entries >= 1, "Maple PE needs a PSB");
        Self {
            macs: pe.macs_per_pe,
            arb_entries: pe.arb_entries.max(1),
            brb_entries: pe.brb_entries.max(1),
            psb_entries: pe.psb_entries,
        }
    }

    /// Number of column segments a row of `out_nnz` outputs needs.
    pub fn segments(&self, out_nnz: u32) -> u64 {
        (out_nnz as u64).div_ceil(self.psb_entries as u64).max(1)
    }

    /// ARB capacity in element pairs.
    pub fn arb_entries(&self) -> usize {
        self.arb_entries
    }

    /// BRB capacity in element pairs.
    pub fn brb_entries(&self) -> usize {
        self.brb_entries
    }

    /// PSB register count (the paper's `N`).
    pub fn psb_entries(&self) -> usize {
        self.psb_entries
    }

    /// Functional execution of one output row `C[i,:] = Σ A[i,k']·B[k',:]`
    /// through the Maple datapath: segment-by-segment, lane-by-lane. Returns
    /// `(col_ids, values, cycles)` and counts every buffer action.
    ///
    /// This is the numerics oracle for the cost model: tests assert the
    /// result equals the software reference and the counters/cycles equal
    /// [`Self::row_cost`]'s closed forms.
    pub fn simulate_row(
        &self,
        a: &Csr,
        b: &Csr,
        i: usize,
        c: &mut Counters,
    ) -> (Vec<u32>, Vec<f32>, u64) {
        let a_cols = a.row_cols(i);
        let a_vals = a.row_values(i);

        // Row load: ARB is double-buffered; charge the writes.
        c.arb_write += 2 * a_cols.len() as u64;
        // Control filters empty B rows via row_ptr subtraction (Fig. 7).
        c.intersect_cmp += a_cols.len() as u64;

        // Pass 1 (control): discover distinct output columns to plan
        // segments. Hardware does this with the PSB allocation itself; the
        // planning scan below touches only metadata already in the ARB/BRB
        // stream and is not charged extra energy.
        let mut out_cols: Vec<u32> = Vec::new();
        for &k in a_cols {
            out_cols.extend_from_slice(b.row_cols(k as usize));
        }
        out_cols.sort_unstable();
        out_cols.dedup();

        let mut result_cols = Vec::with_capacity(out_cols.len());
        let mut result_vals = Vec::with_capacity(out_cols.len());
        let mut row_products = 0u64;
        let mut cycles = ROW_SETUP;

        let nseg = out_cols.len().div_ceil(self.psb_entries).max(1);
        for seg in 0..nseg {
            let lo_idx = seg * self.psb_entries;
            let hi_idx = ((seg + 1) * self.psb_entries).min(out_cols.len());
            if lo_idx >= out_cols.len() && seg > 0 {
                break;
            }
            let (lo, hi) = if out_cols.is_empty() {
                (0u32, u32::MAX)
            } else {
                (out_cols[lo_idx], out_cols[hi_idx - 1])
            };
            if seg > 0 {
                // Segment transition: only the pipeline-refill bubble is
                // exposed — the ARB re-scan overlaps the previous segment's
                // PSB drain (double-buffered), though its reads still cost
                // energy (charged below).
                cycles += SEGMENT_SETUP;
            }
            // ARB re-scan for this segment.
            c.arb_read += a_cols.len() as u64;

            // PSB state for this segment, directly indexed by `j' − lo_idx`
            // over the segment's (sorted, deduped) output columns — the
            // software image of Eq. (8)'s register addressing. O(log) lookup
            // into the sorted column window, O(1) accumulate.
            let seg_cols = &out_cols[lo_idx..hi_idx];
            let mut psb_vals = vec![0f32; seg_cols.len()];

            for (&k, &av) in a_cols.iter().zip(a_vals) {
                let bc = b.row_cols(k as usize);
                let bv = b.row_values(k as usize);
                // BRB streams only the in-range slice (metadata skip).
                let start = bc.partition_point(|&x| x < lo);
                let end = bc.partition_point(|&x| x <= hi);
                for p in start..end {
                    let j = bc[p];
                    c.brb_write += 2;
                    c.brb_read += 2;
                    // MAC: multiply, then the PSB register's adder (Eq. 7).
                    c.mac_mul += 1;
                    c.mac_add += 1;
                    c.psb_read += 1;
                    c.psb_write += 1;
                    row_products += 1;
                    let pos = seg_cols.binary_search(&j).expect("j' is in the planned window");
                    psb_vals[pos] += av * bv[p];
                }
            }
            // Drain final sums (overlaps the next segment's fill in
            // hardware; the cost model charges it to the back stage).
            c.psb_read += seg_cols.len() as u64;
            result_cols.extend_from_slice(seg_cols);
            result_vals.extend_from_slice(&psb_vals);
        }
        // k MAC lanes consume the whole row's product stream; lanes stay
        // filled across segment boundaries apart from the setup bubbles
        // charged above.
        cycles += row_products.div_ceil(self.macs as u64);

        (result_cols, result_vals, cycles)
    }
}

impl PeModel for MaplePe {
    fn row_cost(&self, p: &RowProfile, c: &mut Counters) -> RowCost {
        if p.products == 0 {
            // Control still inspects row_ptr to skip the row (Fig. 7).
            c.intersect_cmp += p.a_nnz as u64;
            return RowCost { front: if p.a_nnz > 0 { ROW_SETUP } else { 0 }, back: 0 };
        }
        let segs = self.segments(p.out_nnz);

        // -- action counts (closed forms of simulate_row) --
        c.arb_write += 2 * p.a_nnz as u64;
        c.arb_read += p.a_nnz as u64 * segs;
        c.intersect_cmp += p.a_nnz as u64;
        c.brb_write += 2 * p.products;
        c.brb_read += 2 * p.products;
        c.mac_mul += p.products;
        c.mac_add += p.products;
        c.psb_read += p.products + p.out_nnz as u64;
        c.psb_write += p.products;

        // -- cycles --
        // Each product is processed exactly once (segments partition the
        // output columns), so the multiply stream is products/k; segment
        // transitions expose only the pipeline-refill bubble (the ARB
        // re-scan overlaps the previous segment's drain).
        let front = ROW_SETUP
            + p.products.div_ceil(self.macs as u64)
            + (segs - 1) * SEGMENT_SETUP;
        // PSB drain overlaps the next row (double buffering); drain width
        // scales with the lane count — the final sums leave on the k
        // accumulate-adder result buses (Fig. 6).
        let back = (p.out_nnz as u64).div_ceil(self.macs as u64);
        RowCost { front, back }
    }

    fn macs(&self) -> usize {
        self.macs
    }

    fn name(&self) -> &'static str {
        "maple"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::gustavson::spgemm_rowwise;
    use crate::sparse::gen::{generate, Profile};

    fn maple2() -> MaplePe {
        MaplePe::from_config(&AcceleratorConfig::matraptor_maple())
    }

    #[test]
    fn functional_row_matches_reference() {
        let a = generate(40, 40, 240, Profile::Uniform, 91);
        let c_ref = spgemm_rowwise(&a, &a);
        let pe = maple2();
        let mut counters = Counters::default();
        for i in 0..a.rows() {
            let (cols, vals, _) = pe.simulate_row(&a, &a, i, &mut counters);
            assert_eq!(cols.as_slice(), c_ref.row_cols(i), "row {i} cols");
            for (v, r) in vals.iter().zip(c_ref.row_values(i)) {
                assert!((v - r).abs() < 1e-4, "row {i}: {v} vs {r}");
            }
        }
    }

    #[test]
    fn functional_counters_match_cost_model() {
        let a = generate(30, 30, 150, Profile::PowerLaw { alpha: 0.6 }, 13);
        let c_ref = spgemm_rowwise(&a, &a);
        let pe = maple2();
        for i in 0..a.rows() {
            let profile = RowProfile {
                a_nnz: a.row_nnz(i) as u32,
                products: a.row_cols(i).iter().map(|&k| a.row_nnz(k as usize) as u64).sum(),
                out_nnz: c_ref.row_nnz(i) as u32,
            };
            let mut c_fun = Counters::default();
            let (_, _, cyc_fun) = pe.simulate_row(&a, &a, i, &mut c_fun);
            let mut c_cost = Counters::default();
            let cost = pe.row_cost(&profile, &mut c_cost);
            if profile.products > 0 {
                assert_eq!(c_fun, c_cost, "row {i} counters diverge");
                assert_eq!(cyc_fun, cost.front, "row {i} cycles diverge");
            }
        }
    }

    #[test]
    fn segmentation_kicks_in_beyond_psb_capacity() {
        let pe = maple2(); // PSB = 128
        assert_eq!(pe.segments(0), 1);
        assert_eq!(pe.segments(128), 1);
        assert_eq!(pe.segments(129), 2);
        assert_eq!(pe.segments(1525), 12);
    }

    #[test]
    fn segmented_row_still_exact() {
        // Force segmentation: tiny PSB, wide output row.
        let pe = MaplePe::new(&crate::config::PeConfig {
            kind: crate::config::PeKind::Maple,
            model: None,
            macs_per_pe: 2,
            arb_entries: 8,
            brb_entries: 8,
            psb_entries: 4, // absurdly small on purpose
            num_queues: 0,
            queue_bytes: 0,
            peb_bytes: 0,
            prefetch_depth: crate::config::DEFAULT_PREFETCH_DEPTH,
        });
        let a = generate(20, 20, 120, Profile::Uniform, 5);
        let c_ref = spgemm_rowwise(&a, &a);
        let mut c = Counters::default();
        for i in 0..a.rows() {
            let (cols, vals, _) = pe.simulate_row(&a, &a, i, &mut c);
            assert_eq!(cols.as_slice(), c_ref.row_cols(i));
            for (v, r) in vals.iter().zip(c_ref.row_values(i)) {
                assert!((v - r).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn more_macs_fewer_cycles_same_energy_actions() {
        let p = RowProfile { a_nnz: 10, products: 320, out_nnz: 100 };
        let mk = |k: usize| {
            let mut pe_cfg = AcceleratorConfig::extensor_maple().pe;
            pe_cfg.macs_per_pe = k;
            MaplePe::new(&pe_cfg)
        };
        let mut c4 = Counters::default();
        let mut c16 = Counters::default();
        let f4 = mk(4).row_cost(&p, &mut c4);
        let f16 = mk(16).row_cost(&p, &mut c16);
        assert!(f4.front > f16.front);
        assert_eq!(c4, c16, "MAC count changes time, not actions");
    }
}
