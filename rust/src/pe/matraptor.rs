//! The Matraptor baseline PE (paper §II.C, §IV.B.1; Srivastava et al.,
//! MICRO'20).
//!
//! One MAC per PE; partial sums are scattered round-robin into per-PE
//! *sorting queues* and accumulated by a multi-pass merge ("each PE must use
//! a large sorting queue buffers and conduct the accumulate operation
//! repeatedly in a round-robin fashion", §IV.B.4). The PE behaves as a
//! two-stage pipeline: row i's multiply phase overlaps row i−1's merge
//! phase, so the visible cost is `max(front_i, back_{i-1})`.

use super::{PeModel, RowCost, RowProfile};
use crate::config::AcceleratorConfig;
use crate::trace::Counters;

/// Cycles to flush the merge tree at the end of a row.
const MERGE_FLUSH: u64 = 8;
/// Row-setup cycles (pointer loads, queue reset).
const ROW_SETUP: u64 = 2;

/// Cost model of one baseline-Matraptor PE.
#[derive(Debug, Clone)]
pub struct MatraptorPe {
    /// Sorting queues per PE.
    num_queues: usize,
    /// Queue capacity in (value, col_id) entries across all queues.
    queue_entries: u64,
    /// Merge passes over each partial sum (round-robin accumulate).
    merge_passes: u64,
}

impl MatraptorPe {
    /// Build from an accelerator config.
    pub fn from_config(cfg: &AcceleratorConfig) -> Self {
        assert!(cfg.pe.num_queues > 0, "Matraptor baseline PE needs queues");
        Self {
            num_queues: cfg.pe.num_queues,
            queue_entries: (cfg.pe.queue_bytes / 8) as u64,
            merge_passes: cfg.merge_passes.max(1) as u64,
        }
    }

    /// Queue count.
    pub fn num_queues(&self) -> usize {
        self.num_queues
    }

    /// Total queue capacity in entries.
    pub fn queue_entries(&self) -> u64 {
        self.queue_entries
    }
}

impl PeModel for MatraptorPe {
    fn row_cost(&self, p: &RowProfile, c: &mut Counters) -> RowCost {
        if p.products == 0 {
            c.intersect_cmp += p.a_nnz as u64;
            return RowCost { front: if p.a_nnz > 0 { ROW_SETUP } else { 0 }, back: 0 };
        }
        c.intersect_cmp += p.a_nnz as u64;

        // -- multiply phase --
        // One MAC: one product per cycle; each partial sum (value, col_id)
        // is inserted into a sorting queue.
        c.mac_mul += p.products;
        c.queue_write += 2 * p.products;

        // Queue overflow: when a row's partial sums exceed the queues, the
        // merge must run mid-row and the multiply stalls for the drain.
        let overflow = p.products.saturating_sub(self.queue_entries);

        // -- merge phase (round-robin, multi-pass) --
        // Every pass re-reads each partial sum and writes the merged run
        // back; the final pass emits final sums instead of re-writing.
        let passes = self.merge_passes;
        c.queue_read += 2 * p.products * passes;
        c.queue_write += 2 * p.products * (passes - 1);
        c.intersect_cmp += p.products * passes; // merge comparators
        c.mac_add += p.products; // accumulation adds (Eq. 7 equivalent)

        let front = ROW_SETUP + p.products + overflow;
        // Merge tree consumes one entry per cycle per pass set; passes are
        // pipelined through the queue banks, so the visible back-stage cost
        // is one traversal plus the flush.
        let back = p.products + MERGE_FLUSH;
        RowCost { front, back }
    }

    fn macs(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str {
        "matraptor-baseline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    fn pe() -> MatraptorPe {
        MatraptorPe::from_config(&AcceleratorConfig::matraptor_baseline())
    }

    #[test]
    fn queue_traffic_scales_with_merge_passes() {
        let p = RowProfile { a_nnz: 4, products: 100, out_nnz: 90 };
        let mut c = Counters::default();
        pe().row_cost(&p, &mut c);
        let passes = AcceleratorConfig::matraptor_baseline().merge_passes as u64;
        assert_eq!(c.queue_read, 2 * 100 * passes);
        assert_eq!(c.queue_write, 2 * 100 + 2 * 100 * (passes - 1));
    }

    #[test]
    fn merge_overlaps_as_back_stage() {
        let p = RowProfile { a_nnz: 4, products: 100, out_nnz: 90 };
        let mut c = Counters::default();
        let cost = pe().row_cost(&p, &mut c);
        assert_eq!(cost.front, ROW_SETUP + 100);
        assert_eq!(cost.back, 100 + MERGE_FLUSH);
    }

    #[test]
    fn overflow_stalls_the_front() {
        let m = pe();
        let cap = m.queue_entries();
        let p = RowProfile { a_nnz: 10, products: cap + 500, out_nnz: 1000 };
        let mut c = Counters::default();
        let cost = m.row_cost(&p, &mut c);
        assert_eq!(cost.front, ROW_SETUP + (cap + 500) + 500);
    }

    #[test]
    fn single_mac() {
        assert_eq!(pe().macs(), 1);
    }
}
