//! Processing-element micro-architecture models.
//!
//! Three PEs are modelled (paper §II.C, §III, §IV.B):
//!
//! * [`MaplePe`] — the paper's contribution: ARB + BRB + PSB register
//!   buffers feeding `k` MAC units, operating directly on CSR metadata.
//! * [`MatraptorPe`] — the Matraptor baseline: one MAC plus per-PE sorting
//!   queues with a multi-pass round-robin merge.
//! * [`ExtensorPe`] — the Extensor baseline: one MAC plus a PEB, spilling
//!   partial output rows to the shared POB.
//!
//! Each model has two faces, and tests pin them to each other:
//!
//! 1. a **functional datapath** (`simulate_row` on [`MaplePe`]) that executes
//!    real CSR rows element-by-element — the numerics oracle;
//! 2. a **row-cost model** (`row_cost`) that produces the identical action
//!    counts plus a two-stage cycle cost from a row's work profile; the
//!    full-scale simulator runs on this (O(rows), not O(products)).
//!
//! # Adding a fourth PE
//!
//! The accelerator layer dispatches through [`registry`], so a new PE never
//! touches `accel/`:
//!
//! 1. add a `pe/<name>.rs` module with a type implementing [`PeModel`]
//!    (account actions into [`crate::trace::Counters`], return a two-stage
//!    [`RowCost`] per row);
//! 2. register its constructor once at startup:
//!    `pe::registry::register("my-pe", |cfg| Box::new(MyPe::from_config(cfg)))`;
//! 3. select it from any configuration (preset or TOML) with
//!    `cfg.pe.model = Some("my-pe".into())` / `model = "my-pe"` under
//!    `[pe]` — every sweep, bench and CLI path picks it up from there.
//!
//! `tests/engine.rs` (`dummy_pe_registers_without_touching_accel`) is a
//! working end-to-end example of exactly this recipe.

mod extensor;
mod maple;
mod matraptor;
pub mod registry;

pub use extensor::ExtensorPe;
pub use maple::MaplePe;
pub use matraptor::MatraptorPe;

use crate::trace::Counters;

/// The per-output-row work profile every cost model consumes. Produced by
/// the profile pass in [`crate::sim`] (an exact functional execution).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RowProfile {
    /// nnz of the A row (`row_ptr[i+1] - row_ptr[i]`, paper Fig. 7).
    pub a_nnz: u32,
    /// Scalar products this row generates (Σ_k' nnz(B[k',:]), Eq. 3).
    pub products: u64,
    /// nnz of the output row C[i,:] (distinct j' after accumulation, Eq. 7).
    pub out_nnz: u32,
}

/// Two-stage cycle cost of one row on one PE.
///
/// `front` occupies the PE's multiply datapath; `back` is post-processing
/// (Matraptor's merge, Extensor's POB round trips, Maple's PSB drain) that
/// overlaps the *next* row's front stage when the PE is double-buffered.
/// The simulator composes rows as `t += max(front_i, back_{i-1})`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RowCost {
    pub front: u64,
    pub back: u64,
}

/// A processing-element cost model.
pub trait PeModel {
    /// Account one output row: bump action counters, return its cycle cost.
    fn row_cost(&self, p: &RowProfile, c: &mut Counters) -> RowCost;

    /// MAC units in this PE.
    fn macs(&self) -> usize;

    /// Human-readable model name.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    fn profiles() -> Vec<RowProfile> {
        vec![
            RowProfile { a_nnz: 0, products: 0, out_nnz: 0 },
            RowProfile { a_nnz: 1, products: 1, out_nnz: 1 },
            RowProfile { a_nnz: 5, products: 31, out_nnz: 29 },
            RowProfile { a_nnz: 44, products: 1925, out_nnz: 1525 },
        ]
    }

    /// Every model: zero-work rows cost (almost) nothing and count nothing.
    #[test]
    fn empty_rows_are_cheap_everywhere() {
        let cfgs = AcceleratorConfig::paper_configs();
        let models: Vec<Box<dyn PeModel>> = vec![
            Box::new(MatraptorPe::from_config(&cfgs[0])),
            Box::new(MaplePe::from_config(&cfgs[1])),
            Box::new(ExtensorPe::from_config(&cfgs[2])),
            Box::new(MaplePe::from_config(&cfgs[3])),
        ];
        for m in &models {
            let mut c = Counters::default();
            let cost = m.row_cost(&RowProfile::default(), &mut c);
            assert_eq!(c.mac_mul, 0, "{}", m.name());
            assert!(cost.front <= 2 && cost.back <= 2, "{}", m.name());
        }
    }

    /// MAC work is invariant across PEs — the paper equalises MACs, the
    /// dataflow only moves *where* partial sums live (§IV.B).
    #[test]
    fn mac_counts_identical_across_models() {
        let cfgs = AcceleratorConfig::paper_configs();
        for p in profiles() {
            let mut c_base = Counters::default();
            let mut c_maple = Counters::default();
            MatraptorPe::from_config(&cfgs[0]).row_cost(&p, &mut c_base);
            MaplePe::from_config(&cfgs[1]).row_cost(&p, &mut c_maple);
            assert_eq!(c_base.mac_mul, c_maple.mac_mul);
            assert_eq!(c_base.mac_mul, p.products);
        }
    }

    /// Maple PEs never touch queues, PEB, or POB; baselines never touch
    /// ARB/BRB/PSB (paper Fig. 6 vs §II.C).
    #[test]
    fn lane_separation_between_pe_kinds() {
        let cfgs = AcceleratorConfig::paper_configs();
        let p = RowProfile { a_nnz: 5, products: 31, out_nnz: 29 };

        let mut c = Counters::default();
        MaplePe::from_config(&cfgs[1]).row_cost(&p, &mut c);
        assert_eq!(c.queue_read + c.queue_write + c.peb_read + c.peb_write, 0);
        assert_eq!(c.pob_read + c.pob_write, 0);
        assert!(c.psb_write > 0 && c.brb_read > 0);

        let mut c = Counters::default();
        MatraptorPe::from_config(&cfgs[0]).row_cost(&p, &mut c);
        assert_eq!(c.arb_read + c.brb_read + c.psb_read, 0);
        assert!(c.queue_write > 0);

        let mut c = Counters::default();
        ExtensorPe::from_config(&cfgs[2]).row_cost(&p, &mut c);
        assert_eq!(c.arb_read + c.brb_read + c.psb_read, 0);
        assert!(c.peb_write > 0 && c.pob_write > 0);
    }

    /// The Maple PE's front stage scales ~1/k with its MAC count (the
    /// parallelism claim of §III).
    #[test]
    fn maple_front_scales_with_macs() {
        let p = RowProfile { a_nnz: 8, products: 256, out_nnz: 200 };
        let cfg2 = AcceleratorConfig::matraptor_maple(); // k = 2
        let cfg16 = AcceleratorConfig::extensor_maple(); // k = 16
        let mut c = Counters::default();
        let f2 = MaplePe::from_config(&cfg2).row_cost(&p, &mut c).front;
        let f16 = MaplePe::from_config(&cfg16).row_cost(&p, &mut c).front;
        assert!(f2 > 6 * f16, "k=2 front {f2} vs k=16 front {f16}");
    }
}
