//! Open registry of processing-element cost models.
//!
//! The accelerator layer does not know the concrete PE types: it asks the
//! registry to build whatever model the configuration names. The three
//! in-tree PEs self-register at first use; an external PE plugs in with one
//! [`register`] call and is then selectable from any [`AcceleratorConfig`]
//! via `cfg.pe.model = Some("its-name".into())` — no change to `accel/`
//! (see the module docs in [`crate::pe`] for the full recipe).

use std::collections::BTreeMap;
use std::sync::{OnceLock, RwLock};

use super::{ExtensorPe, MaplePe, MatraptorPe, PeModel};
use crate::config::{AcceleratorConfig, AcceleratorKind, PeKind};

/// Builds one configured PE cost model. A plain `fn` pointer so entries are
/// `Send + Sync` and registration needs no allocation tricks.
pub type Constructor = fn(&AcceleratorConfig) -> Box<dyn PeModel>;

/// Registry lookup / registration errors.
#[derive(Debug, thiserror::Error)]
pub enum RegistryError {
    #[error("unknown PE model {0:?} (registered: {1})")]
    Unknown(String, String),
    #[error("PE model {0:?} is already registered")]
    Duplicate(String),
}

/// The registered built-in names, in `AcceleratorConfig::paper_configs`
/// comparison order.
pub const BUILTIN_MODELS: &[&str] = &["matraptor-baseline", "maple", "extensor-baseline"];

fn registry() -> &'static RwLock<BTreeMap<String, Constructor>> {
    static REG: OnceLock<RwLock<BTreeMap<String, Constructor>>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut m: BTreeMap<String, Constructor> = BTreeMap::new();
        m.insert("maple".into(), |cfg| Box::new(MaplePe::from_config(cfg)));
        m.insert("matraptor-baseline".into(), |cfg| Box::new(MatraptorPe::from_config(cfg)));
        m.insert("extensor-baseline".into(), |cfg| Box::new(ExtensorPe::from_config(cfg)));
        RwLock::new(m)
    })
}

/// Register a PE model constructor under `name`. Fails on a duplicate name
/// so two plugins cannot silently shadow each other (or a built-in).
pub fn register(name: &str, ctor: Constructor) -> Result<(), RegistryError> {
    let mut reg = registry().write().expect("PE registry poisoned");
    if reg.contains_key(name) {
        return Err(RegistryError::Duplicate(name.to_string()));
    }
    reg.insert(name.to_string(), ctor);
    Ok(())
}

/// Is `name` registered?
pub fn contains(name: &str) -> bool {
    registry().read().expect("PE registry poisoned").contains_key(name)
}

/// All registered model names, sorted.
pub fn names() -> Vec<String> {
    registry().read().expect("PE registry poisoned").keys().cloned().collect()
}

/// The registry key a configuration resolves to: the explicit
/// `cfg.pe.model` override when present, else the built-in mapping from
/// `(accelerator kind, PE kind)` the paper's four machines use.
pub fn resolve_key(cfg: &AcceleratorConfig) -> String {
    if let Some(name) = &cfg.pe.model {
        return name.clone();
    }
    match (cfg.kind, cfg.pe.kind) {
        (_, PeKind::Maple) => "maple",
        (AcceleratorKind::Matraptor, PeKind::Baseline) => "matraptor-baseline",
        (AcceleratorKind::Extensor, PeKind::Baseline) => "extensor-baseline",
    }
    .to_string()
}

/// Build the PE cost model `cfg` names.
pub fn build(cfg: &AcceleratorConfig) -> Result<Box<dyn PeModel>, RegistryError> {
    let key = resolve_key(cfg);
    let reg = registry().read().expect("PE registry poisoned");
    match reg.get(&key) {
        Some(ctor) => Ok(ctor(cfg)),
        None => {
            let known = reg.keys().cloned().collect::<Vec<_>>().join(", ");
            Err(RegistryError::Unknown(key, known))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_registered() {
        for name in BUILTIN_MODELS {
            assert!(contains(name), "{name} missing");
        }
    }

    #[test]
    fn paper_configs_resolve_to_expected_models() {
        let expect = ["matraptor-baseline", "maple", "extensor-baseline", "maple"];
        for (cfg, want) in AcceleratorConfig::paper_configs().iter().zip(expect) {
            assert_eq!(resolve_key(cfg), want, "{}", cfg.name);
            assert_eq!(build(cfg).unwrap().name(), want, "{}", cfg.name);
        }
    }

    #[test]
    fn unknown_model_is_a_clean_error() {
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.pe.model = Some("no-such-pe".into());
        match build(&cfg) {
            Err(RegistryError::Unknown(name, known)) => {
                assert_eq!(name, "no-such-pe");
                assert!(known.contains("maple"));
            }
            other => panic!("expected Unknown, got {other:?}"),
        }
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        assert!(matches!(
            register("maple", |cfg| Box::new(MaplePe::from_config(cfg))),
            Err(RegistryError::Duplicate(_))
        ));
    }
}
