//! The Extensor baseline PE (paper §II.C, §IV.B.2; Hegde et al., MICRO'19).
//!
//! One MAC per PE behind a PE buffer (PEB); partial output rows spill to the
//! shared partial-output buffer (POB) and are re-read once per k-tile group
//! to accumulate final sums ("the baseline Extensor has a data movement
//! between PE and POB that does not occur in the Maple based Extensor",
//! §IV.B.4). The POB round trips are the back stage; a k-tile of width
//! `ktile` determines how many groups a row's accumulation spans.

use super::{PeModel, RowCost, RowProfile};
use crate::config::AcceleratorConfig;
use crate::trace::Counters;

/// A-column tile width: distinct k' handled per POB round trip.
const KTILE: u64 = 4;
/// Exposed cycles per POB round trip (request + NoC traversal + bank access
/// + return — the POB sits across the mesh from the PE).
const POB_ROUND_TRIP: u64 = 12;
/// Row-setup cycles.
const ROW_SETUP: u64 = 1;
/// POB accumulation-round cap: beyond this the hierarchical merge folds
/// pairwise and the re-read volume is geometric, not linear.
const ROUNDS_CAP: u64 = 6;

/// Cost model of one baseline-Extensor PE.
#[derive(Debug, Clone)]
pub struct ExtensorPe {
    /// Reciprocal of the POB drain bandwidth share (words/cycle/PE) —
    /// stored inverted because the cost model multiplies per row
    /// (EXPERIMENTS.md §Perf).
    inv_pob_bw: f64,
}

impl ExtensorPe {
    /// Build from an accelerator config.
    pub fn from_config(cfg: &AcceleratorConfig) -> Self {
        Self { inv_pob_bw: 1.0 / cfg.pob_words_per_cycle_per_pe.max(1.0) }
    }

    /// POB drain bandwidth share in words per cycle.
    pub fn pob_words_per_cycle(&self) -> f64 {
        1.0 / self.inv_pob_bw
    }

    /// POB accumulation groups for a row with `a_nnz` A-elements.
    pub fn rounds(&self, a_nnz: u32) -> u64 {
        (a_nnz as u64).div_ceil(KTILE).max(1)
    }
}

impl PeModel for ExtensorPe {
    fn row_cost(&self, p: &RowProfile, c: &mut Counters) -> RowCost {
        if p.products == 0 {
            c.intersect_cmp += p.a_nnz as u64;
            return RowCost { front: if p.a_nnz > 0 { ROW_SETUP } else { 0 }, back: 0 };
        }
        let rounds = self.rounds(p.a_nnz);

        // Hierarchical intersection on the way in (DRAM→LLB→PE, §II.C).
        c.intersect_cmp += p.a_nnz as u64 + p.products;

        // -- PEB traffic: operands staged + partial-sum read-modify-write.
        //    PEB partials are coordinate-tagged (value + col_id), so the
        //    psum RMW is two words each way — exactly the tag overhead
        //    Maple's directly-indexed PSB eliminates (paper Eq. 8). --
        c.peb_write += 2 * p.products + 2 * p.products; // operands + tagged psum
        c.peb_read += 2 * p.products + 2 * p.products;

        // -- MAC --
        c.mac_mul += p.products;
        c.mac_add += p.products;

        // -- POB spill: each group writes its partial row once; the final
        //    accumulation re-reads every group's partials (pairwise-folded
        //    beyond ROUNDS_CAP, so both volume and latency saturate). --
        let eff_rounds = rounds.min(ROUNDS_CAP);
        let pob_write = 2 * p.products;
        let pob_read = 2 * p.products * eff_rounds;
        c.pob_write += pob_write;
        c.pob_read += pob_read;

        let front = ROW_SETUP + p.products;
        // POB drain at the PE's bandwidth share plus exposed round trips.
        let back = ((pob_write + pob_read) as f64 * self.inv_pob_bw).ceil() as u64
            + eff_rounds * POB_ROUND_TRIP;
        RowCost { front, back }
    }

    fn macs(&self) -> usize {
        1
    }

    fn name(&self) -> &'static str {
        "extensor-baseline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    fn pe() -> ExtensorPe {
        ExtensorPe::from_config(&AcceleratorConfig::extensor_baseline())
    }

    #[test]
    fn rounds_follow_ktile() {
        let m = pe();
        assert_eq!(m.rounds(1), 1);
        assert_eq!(m.rounds(4), 1);
        assert_eq!(m.rounds(5), 2);
        assert_eq!(m.rounds(16), 4);
    }

    #[test]
    fn pob_traffic_present_and_grows_with_rounds() {
        let mut c1 = Counters::default();
        let mut c4 = Counters::default();
        pe().row_cost(&RowProfile { a_nnz: 2, products: 100, out_nnz: 90 }, &mut c1);
        pe().row_cost(&RowProfile { a_nnz: 16, products: 100, out_nnz: 90 }, &mut c4);
        assert!(c4.pob_read > c1.pob_read);
        assert_eq!(c1.pob_write, c4.pob_write);
    }

    #[test]
    fn back_stage_reflects_pob_round_trips() {
        let m = pe();
        let p = RowProfile { a_nnz: 8, products: 50, out_nnz: 45 };
        let mut c = Counters::default();
        let cost = m.row_cost(&p, &mut c);
        assert!(cost.back >= m.rounds(8) * POB_ROUND_TRIP);
        assert_eq!(cost.front, ROW_SETUP + 50);
    }

    #[test]
    fn peb_rmw_traffic_is_eight_words_per_product() {
        // 2 operand words + 2 coordinate-tagged psum words, each way.
        let mut c = Counters::default();
        pe().row_cost(&RowProfile { a_nnz: 1, products: 10, out_nnz: 10 }, &mut c);
        assert_eq!(c.peb_read + c.peb_write, 80);
    }
}
