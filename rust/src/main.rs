//! `maple` — CLI launcher for the row-wise product accelerator framework.
//!
//! Every table and figure of the paper regenerates from here:
//!
//! ```text
//! maple datasets                     # Table I
//! maple fig3                         # Fig. 3  (energy of ops at 45nm)
//! maple fig8 --accel matraptor       # Fig. 8a (PE area comparison)
//! maple fig8 --accel extensor       # Fig. 8b
//! maple fig9 --scale 16              # Fig. 9a+9b over all 14 datasets
//! maple simulate --config matraptor-maple --dataset wv
//! maple sweep --dataset wv --axis noc=crossbar:8,mesh:4x2 --axis macs=2,4,8,16
//! maple config --preset extensor-maple > my.toml
//! ```
//!
//! All simulation commands sit on [`maple::sim::SimEngine`]: each dataset
//! is profiled once (cached by dataset/seed/scale) and sweep cells run
//! concurrently on worker threads. Profiled workloads additionally persist
//! to an on-disk cache ([`maple::sim::cache`]) so repeated runs start warm —
//! `--no-cache` (or `MAPLE_NO_CACHE=1`) opts out, `MAPLE_CACHE_DIR`
//! relocates it, and `maple cache stats|clear` inspects it. Argument parsing
//! lives in [`maple::cli`] — in-tree, shared by every command, no CLI
//! dependency (DESIGN.md §Dependencies).

use maple::analysis::{check, lint_path, ModelSpec, Mutation};
use maple::cli::{
    dataset_names, make_engine, parse_cell_model, parse_config, parse_gen_profile,
    parse_mem_budget, parse_policy, parse_tile, positional, space_from_args, Args, CliResult,
};
use maple::config::{AcceleratorConfig, ConfigAxis};
use maple::coordinator::Policy;
use maple::report;
use maple::sim::{
    cache, check_against_exhaustive, explore, profile_container_tiled, profile_workload,
    profile_workload_sampled, profile_workload_tiled_cached, run_chaos, shard, simulate_workload,
    Axis, CellModel, ChaosSpec, Coordinator, DesignSpace, DiskCache, ExploreSpec, Explorer,
    FaultPlan, LeasePolicy, Objective, ServiceConfig, ShardSpec, SimEngine, Strategy, SweepOutcome,
    SweepResult, Tier, WorkerConfig, WorkloadKey, ESTIMATE_BAND,
};
use maple::sparse::{gen, io as sparse_io, stats, suite, TileShape};

const USAGE: &str = "\
maple — row-wise product sparse tensor accelerator framework

USAGE: maple <command> [options] [--csv]

COMMANDS:
  datasets                 Table I (the simulation datasets)
  fig3                     Fig. 3 (normalized energy of ops at 45nm)
  fig8   --accel <name>    Fig. 8 (PE area, baseline vs Maple);
                           name = matraptor | extensor
  fig9   [--scale N] [--datasets wv,fb,...] [--seed S]
                           Fig. 9 (energy benefit + speedup per dataset)
  simulate --config <preset|file.toml> --dataset <name>
           [--scale N] [--seed S] [--policy round-robin|chunked|greedy]
           [--cell-model analytic|des|both]
  sweep  [--config <preset|file.toml|paper>] [--dataset wv[,fb,...]|all]
           [--axis noc=crossbar:8,mesh:4x2] [--axis macs=2,4,8,16]
           [--axis prefetch=2,4,8] [--axis pe-model=name,...]
           [--axis fmt=csr,csc,coo,bitmap,blocked]
           [--policy round-robin[,chunked,greedy]] [--pivot <axis>]
           [--scale N] [--seed S] [--threads N]
           [--cell-model analytic|des|both] [--bench-json <path>]
           [--shard i/n --out <dir>] [--fingerprint]
           Design-space sweep over the base config: each repeatable --axis
           adds one typed grid dimension (axes also load from a [sweep]
           block in the --config TOML); --pivot renders the cycle grid
           pivoted on that axis. The fmt axis re-prices each workload
           under an operand compression format (the csr point is
           bit-identical to a formatless sweep); with a fmt axis,
           --bench-json writes the per-format BENCH_format.json. The old
           --macs flag is deprecated; it warns and rewrites itself to
           --axis macs=...
           --config paper sweeps the four paper configurations (no default
           axis), --datasets all is the whole Table-I suite. --shard i/n
           computes only that contiguous slice of the cell grid and writes
           it to --out as a shard artifact; --fingerprint prints the
           design-space fingerprint (what merge validates) and exits.
  explore [same space flags as sweep] [--objective cycles|energy|edp]
           [--strategy hill|es|es:MU+LAMBDA] [--tier exact|estimate|two-tier]
           [--budget N] [--elite N] [--sample-budget N] [--search-seed S]
           [--exhaustive] [--bench-json <path>]
           Search the design space instead of sweeping it: hill-climb or a
           (mu+lambda) evolution strategy over the same grid the sweep
           enumerates, one search per dataset. The default two-tier
           evaluator scores candidates against the sampled profiler and
           re-scores the elite front exactly; every evaluation is memoized
           in the disk cache (warm re-runs cost zero simulations).
           --exhaustive additionally runs the full sweep and verifies the
           search landed on the argmin (or inside the estimator band),
           exiting non-zero otherwise; --bench-json writes
           BENCH_explore.json (evaluations vs grid cells, wall-clock).
  estval [--scale N] [--datasets wv,fb,...] [--seed S] [--budget N]
           Sampled-profiler cross-validation (the estimator analogue of
           crossval): per dataset, the measured out-nnz error vs the
           estimator's claimed bound, and the simulated cycle/energy error
           across the paper configs; exits non-zero if any dataset leaves
           the agreement band
  merge  <dir> [--allow-partial] [--pivot <axis>] [--bench-json <path>]
           Merge the shard artifacts in <dir> back into the full sweep
           grid. Validates compatibility (one fingerprint, one shard
           count, no gaps/overlaps/duplicates) and exits non-zero on any
           violation; on success renders exactly what the unsharded sweep
           would have printed. --allow-partial downgrades only the
           missing-shards violation into a loud partial render of the
           completed sub-grid (gaps become provenance lines); corrupt or
           incompatible artifacts stay fatal. --bench-json additionally
           writes the machine-readable BENCH_sweep.json (shard
           wall-times, cells/sec, warm-vs-cold cache hits).
  serve  --listen <host:port> [space flags as in sweep] [--shards N]
           [--lease-ms MS] [--max-wall-ms MS] [--allow-partial]
           Run the distributed-sweep coordinator: split the design space
           into N shard leases and serve them to connecting workers over
           TCP. Expired leases re-queue with exponential backoff
           (work-stealing); workers that fail repeatedly are quarantined;
           duplicate submissions are accepted idempotently and
           byte-divergent ones rejected loudly. On completion renders
           exactly what the unsharded sweep prints; if --max-wall-ms
           passes first the run exits non-zero (or renders the completed
           sub-grid with gap provenance under --allow-partial).
  work   --connect <host:port> [--worker-id ID] [--threads N]
           [--fault PLAN] [--fault-seed S] [--no-cache]
           Run one sweep worker: register, lease, compute, submit until
           the coordinator reports the sweep done. Survives coordinator
           restarts by reconnecting and re-registering (bounded retry
           budget, so a dead coordinator is an error, never a hang).
           --fault arms the deterministic fault injector with a plan
           (drop:N | corrupt:M | stall | dup | kill | die).
  chaos  [space flags as in sweep] [--workers N] [--shards N]
           [--fault PLAN] [--fault-seed S] [--lease-ms MS]
           Fault-injection harness: run a coordinator plus N in-process
           workers over loopback TCP with worker w0 executing the fault
           plan, then verify the merged grid is bit-identical to the
           unsharded sweep of the same space (exit non-zero otherwise).
  vet    [--lint-only | --model-only] [--src DIR] [--shards N] [--workers M]
           [--max-states N] [--mutant double-grant|quarantine-bypass]
           Static analysis of the simulator itself: a determinism lint
           over the crate sources (no HashMap/HashSet, no wall-clock in
           sim paths, no lossy casts in accounting code, no unscoped
           threads; escape hatch `// vet:allow(rule): reason`), plus a
           bounded model checker that exhausts the lease/ledger protocol
           over N shards x M workers and proves its safety invariants —
           any violation renders a minimal counterexample trace with a
           fault plan `maple chaos --fault <plan>` replays. Exits
           non-zero on any finding, violation, or a non-exhausted
           search. --mutant seeds a known protocol bug instead and exits
           zero only if the checker catches it (the CI self-test).
  ingest --gen <dataset|family> --mtx-out <p.mtx> [--scale N] [--seed S]
           [--rows N --cols N --nnz N]
         <in.mtx> --out <c.mrg> [--mem-budget N[K|M|G]]
         <in.mtx|in.mrg> --profile-out <w.mwl> [--tile RxC]
           [--threads N] [--stats-json <p.json>] [--no-cache]
         <in.mtx|in.mrg> --report [--tile RxC]
           Out-of-core streaming ingest. --gen writes a Table-I suite
           matrix (scaled by --scale) or a raw family — uniform,
           powerlaw:ALPHA, banded:REL_BW:CLUSTER sized by --rows/--nnz —
           as a Matrix-Market file. With --out, the .mtx streams
           into a row-group container (.mrg) without ever holding more
           than --mem-budget of it in memory (default 256M; a quarter of
           the budget bounds each row group). With --profile-out, the
           tiled profiler runs C = A x A and writes the workload
           artifact — bit-identical to the whole-matrix profile of the
           same matrix; .mrg inputs stay out-of-core and flow per-block
           partials through the disk cache, so an interrupted profile
           resumes warm. --report prints the per-row-group nnz balance.
  crossval [--scale N] [--datasets wv,fb,...] [--seed S] [--policy P]
           DES vs analytic cross-validation over the four paper configs;
           exits non-zero if any cell leaves the documented agreement band
  cache  [stats|clear]     Inspect or empty the on-disk workload cache
  config --preset <name>   Dump a preset configuration as TOML
  validate [--artifacts DIR]
                           Load the AOT Pallas datapath via PJRT and verify
                           it against the software reference (needs
                           `make artifacts` and `--features runtime`)

Simulation commands warm-start from the on-disk workload cache
(default target/maple-cache; override with MAPLE_CACHE_DIR). Pass
--no-cache (or set MAPLE_NO_CACHE=1) to recompute from scratch.
";

/// DES vs analytic cross-validation: one `CellModel::Both` sweep over the
/// four paper configurations, rendered as the agreement table; any cell
/// outside the documented band is a hard error (the CI gate).
fn crossval(
    engine: &SimEngine,
    scale: usize,
    datasets: Option<&str>,
    seed: u64,
    policy: Policy,
    csv: bool,
) -> CliResult {
    let names = dataset_names(datasets)?;
    let keys = names.iter().map(|&n| WorkloadKey::suite(n, seed, scale)).collect();
    let spec = DesignSpace::new(AcceleratorConfig::paper_configs(), keys, vec![policy])
        .with_cell_model(CellModel::Both);
    let grid = engine.sweep(&spec)?;
    print!("{}", report::des_validation_report(&grid, !csv));
    let violations = grid.des_out_of_band();
    if !violations.is_empty() {
        let mut msg = String::from("DES/analytic agreement violated in:");
        for (d, c, p) in violations {
            msg.push_str(&format!(
                "\n  {} / {} / {:?}",
                grid.datasets[d].dataset, grid.configs[c], grid.policies[p]
            ));
        }
        return Err(msg.into());
    }
    Ok(())
}

/// Fig. 9 across datasets: one engine sweep — each dataset profiled once,
/// all (config × dataset) cells in parallel.
fn fig9(
    engine: &SimEngine,
    scale: usize,
    datasets: Option<&str>,
    seed: u64,
    csv: bool,
) -> CliResult {
    let names = dataset_names(datasets)?;
    let keys = names.iter().map(|&n| WorkloadKey::suite(n, seed, scale)).collect();
    let grid = engine.sweep(&DesignSpace::paper(keys))?;

    // `paper_configs()` order: matraptor base (0) / maple (1), extensor
    // base (2) / maple (3).
    let matraptor = report::fig9_rows_from_sweep(&grid, 0, 1, 0);
    let extensor = report::fig9_rows_from_sweep(&grid, 2, 3, 0);
    let m_title = "Fig. 9 — Matraptor (Maple vs baseline)";
    let e_title = "Fig. 9 — Extensor (Maple vs baseline)";
    println!("{}", report::fig9_report(m_title, &matraptor, !csv));
    println!("{}", report::fig9_report(e_title, &extensor, !csv));
    Ok(())
}

/// Render a sweep grid exactly the way `maple sweep` prints it: the
/// grid-shape line on stderr, the (optionally pivoted) table on stdout,
/// then the DES cross-validation table when the grid ran a DES-bearing
/// cell model. `maple merge` shares this renderer, which is what makes
/// merged output byte-identical to the unsharded sweep's.
fn render_grid(grid: &SweepResult, pivot: Option<&str>, md: bool) -> CliResult {
    eprintln!("grid: {} -> {} cells", grid.shape_line(), grid.cell_count());
    match pivot {
        Some(pivot) => {
            let table = report::sweep_pivot_report(grid, pivot, md)
                .ok_or_else(|| format!("--pivot {pivot}: not an axis of this sweep"))?;
            print!("{table}");
        }
        None => print!("{}", report::sweep_axis_report(grid, md)),
    }
    if grid.cell_model.runs_des() {
        println!();
        print!("{}", report::des_validation_report(grid, md));
    }
    Ok(())
}

/// The `sweep` command: build the design space from flags/TOML
/// ([`space_from_args`]), then run it whole, run one shard of it
/// (`--shard i/n --out dir`), or just print its fingerprint
/// (`--fingerprint`). With a `fmt` axis, `--bench-json` writes the
/// per-format BENCH_format.json.
fn sweep_cmd(args: &Args, csv: bool) -> CliResult {
    let space = space_from_args(args)?;
    let pivot = args.opt("--pivot");

    // The space fingerprint alone — what `merge` validates shard sets
    // against — without profiling or simulating anything.
    if args.flag("--fingerprint") {
        println!("fingerprint: {:016x}", space.fingerprint()?);
        return Ok(());
    }

    let mut engine = make_engine(args);
    if let Some(threads) = args.opt("--threads") {
        let threads: usize =
            threads.parse().map_err(|_| format!("bad value for --threads: {threads}"))?;
        engine = engine.with_threads(threads);
    }

    if let Some(spec) = args.opt("--shard") {
        let shard_spec: ShardSpec = spec.parse()?;
        let out = args
            .opt("--out")
            .ok_or("--shard requires --out <dir> to receive the shard artifact")?;
        let result = engine.sweep_shard(&space, shard_spec)?;
        let path = result.write_to(std::path::Path::new(out))?;
        eprintln!(
            "shard {shard_spec}: cells [{}..{}) of {}, fingerprint {:016x} -> {}",
            result.range().start,
            result.range().end,
            result.total_cells(),
            result.fingerprint,
            path.display()
        );
        return Ok(());
    }

    let t = std::time::Instant::now();
    let grid = engine.sweep(&space)?;
    let wall_ms = t.elapsed().as_millis() as u64;
    render_grid(&grid, pivot, !csv)?;

    if let Some(path) = args.opt("--bench-json") {
        let json = report::bench_format_json(&grid, wall_ms)
            .ok_or("sweep --bench-json needs a fmt axis (--axis fmt=csr,coo,...)")?;
        std::fs::write(path, json).map_err(|e| format!("cannot write {path}: {e}"))?;
        eprintln!("bench: wrote {path}");
    }

    // When the grid ranges over tile shapes, also surface the per-row-group
    // nnz balance each shape induces on each dataset — the load skew a
    // tiled out-of-core profile of the same key would see.
    let shapes: &[TileShape] = space
        .axes
        .iter()
        .find_map(|a| match a {
            Axis::Config(ConfigAxis::Tiling(v)) => Some(v.as_slice()),
            _ => None,
        })
        .unwrap_or(&[]);
    if !shapes.is_empty() {
        let keys: &[WorkloadKey] = space
            .axes
            .iter()
            .find_map(|a| match a {
                Axis::Dataset(keys) => Some(keys.as_slice()),
                _ => None,
            })
            .unwrap_or(&[]);
        for key in keys {
            let a = explore::suite_matrix(key)?;
            for &shape in shapes {
                println!();
                print!("{}", report::tiling_report(&key.dataset, &a, shape, !csv));
            }
        }
    }
    Ok(())
}

/// The `explore` command: guided search over the same design space `sweep`
/// enumerates. Prints the per-dataset search report; `--exhaustive` also
/// runs the full sweep, prints the argmin comparison, and exits non-zero
/// if any dataset's search landed outside the estimator agreement band of
/// the true optimum; `--bench-json` writes BENCH_explore.json.
fn explore_cmd(args: &Args, csv: bool) -> CliResult {
    let space = space_from_args(args)?;
    let seed = args.parse_or("--seed", 7u64)?;
    let spec = ExploreSpec {
        objective: args.opt_or("--objective", "cycles").parse::<Objective>()?,
        strategy: args.opt_or("--strategy", "es").parse::<Strategy>()?,
        tier: args.opt_or("--tier", "two-tier").parse::<Tier>()?,
        budget: args.parse_or("--budget", 64usize)?,
        elite: args.parse_or("--elite", 4usize)?,
        sample_budget: args.parse_or("--sample-budget", 128usize)?,
        // The search RNG / sampling seed follows the dataset seed unless
        // pinned separately (so --seed alone moves the whole experiment).
        seed: args.parse_or("--search-seed", seed)?,
    };
    let mut engine = make_engine(args);
    if let Some(threads) = args.opt("--threads") {
        let threads: usize =
            threads.parse().map_err(|_| format!("bad value for --threads: {threads}"))?;
        engine = engine.with_threads(threads);
    }
    let result = Explorer::new(&engine, space.clone(), spec).run()?;
    print!("{}", report::explore_report(&result, !csv));

    let check = if args.flag("--exhaustive") {
        let t = std::time::Instant::now();
        let grid = engine.sweep(&space)?;
        let check = check_against_exhaustive(&result, &grid, t.elapsed().as_millis() as u64);
        println!();
        print!("{}", report::exhaustive_check_report(&result, &check));
        Some(check)
    } else {
        None
    };
    if let Some(path) = args.opt("--bench-json") {
        std::fs::write(path, report::bench_explore_json(&result, check.as_ref()))
            .map_err(|e| format!("cannot write {path}: {e}"))?;
        eprintln!("bench: wrote {path}");
    }
    if let Some(c) = &check {
        if !c.all_in_band() {
            return Err("explore landed outside the estimator agreement band of the \
                        exhaustive optimum"
                .into());
        }
    }
    Ok(())
}

/// The `estval` command: cross-validate the sampled profiler against the
/// exact one (the estimator analogue of `crossval`). Two gates per
/// dataset: the measured out-nnz error must not exceed the estimator's own
/// claimed bound, and replaying the estimated workload through the four
/// paper configs must keep cycles and energy inside the agreement band.
fn estval_cmd(args: &Args, csv: bool) -> CliResult {
    let scale = args.parse_or("--scale", 16usize)?;
    let seed = args.parse_or("--seed", 7u64)?;
    let budget = args.parse_or("--budget", 64usize)?;
    let names = dataset_names(args.opt("--datasets"))?;
    let mut rows = Vec::with_capacity(names.len());
    for name in names {
        let key = WorkloadKey::suite(name, seed, scale);
        let a = explore::suite_matrix(&key)?;
        let exact = profile_workload(&a, &a);
        let est = profile_workload_sampled(&a, &a, budget, seed);
        let summary = stats::row_nnz_summary(&a);
        let rel = |est_v: f64, exact_v: f64| (est_v - exact_v).abs() / exact_v.abs().max(1.0);
        let measured_rel_err = rel(est.workload.out_nnz as f64, exact.out_nnz as f64);
        let (mut max_cycle_err, mut max_energy_err) = (0f64, 0f64);
        for cfg in AcceleratorConfig::paper_configs() {
            let re = simulate_workload(&cfg, &exact, Policy::RoundRobin);
            let rs = simulate_workload(&cfg, &est.workload, Policy::RoundRobin);
            max_cycle_err =
                max_cycle_err.max(rel(rs.cycles_compute as f64, re.cycles_compute as f64));
            max_energy_err =
                max_energy_err.max(rel(rs.energy.total_pj(), re.energy.total_pj()));
        }
        let in_band = measured_rel_err <= est.out_nnz_rel_err + 1e-12
            && max_cycle_err <= ESTIMATE_BAND
            && max_energy_err <= ESTIMATE_BAND;
        rows.push(report::EstvalRow {
            dataset: name.to_string(),
            rows: exact.rows,
            nnz: exact.nnz_a as usize,
            cv: summary.cv,
            heavy_share: summary.heavy_share,
            sampled_rows: est.sampled_rows,
            exact_out: exact.out_nnz,
            est_out: est.workload.out_nnz,
            measured_rel_err,
            claimed_rel_err: est.out_nnz_rel_err,
            max_cycle_err,
            max_energy_err,
            in_band,
        });
    }
    print!("{}", report::estval_report(&rows, budget, !csv));
    let violations: Vec<&str> =
        rows.iter().filter(|r| !r.in_band).map(|r| r.dataset.as_str()).collect();
    if !violations.is_empty() {
        return Err(format!(
            "sampled-profiler agreement violated in: {}",
            violations.join(", ")
        )
        .into());
    }
    Ok(())
}

/// The `merge` command: reassemble a sharded sweep from its artifact
/// directory. Any compatibility violation — mixed fingerprints or shard
/// counts, missing/duplicate shards, an undecodable artifact — is a hard
/// error (non-zero exit); success renders exactly what the unsharded
/// sweep of the same design space prints. `--allow-partial` downgrades
/// exactly one violation — missing shards — into a loud partial render:
/// the completed sub-grid plus a provenance block naming every gap.
/// Corrupt or incompatible artifacts stay fatal even then.
fn merge_cmd(args: &Args, csv: bool) -> CliResult {
    // The shard directory is positional but may come before or after the
    // flags; `positional` skips flags *and* the values of the value-bearing
    // ones (`merge --bench-json out.json shards/` must not read `out.json`
    // as the directory).
    let dir = positional(args, &["--pivot", "--bench-json"]).ok_or(
        "usage: maple merge <dir> [--allow-partial] [--pivot <axis>] [--bench-json <path>]",
    )?;
    let shards = shard::read_dir(std::path::Path::new(dir))?;
    let grid = match shard::merge(&shards) {
        Ok(grid) => grid,
        Err(e @ shard::ShardError::MissingShards { .. }) if args.flag("--allow-partial") => {
            let partial = shard::merge_partial(&shards)?;
            eprintln!("merge: {e}");
            eprint!("{}", report::partial_provenance(&partial));
            print!("{}", report::partial_sweep_report(&partial, !csv));
            return Ok(());
        }
        Err(e) => return Err(e.into()),
    };
    eprint!("{}", report::merge_provenance(&shards, &grid));
    if let Some(path) = args.opt("--bench-json") {
        std::fs::write(path, report::bench_sweep_json(&shards, &grid))
            .map_err(|e| format!("cannot write {path}: {e}"))?;
        eprintln!("bench: wrote {path}");
    }
    render_grid(&grid, args.opt("--pivot"), !csv)
}

/// The `serve` command: run the distributed-sweep coordinator. Builds the
/// same design space as `sweep` from the same flags, splits it `--shards`
/// ways, and leases the shards to every worker that connects (`maple
/// work`). Expired leases re-queue with backoff (work-stealing), repeat
/// failers are quarantined, and submissions merge idempotently; on
/// completion the stdout rendering is byte-identical to the unsharded
/// `maple sweep`. When `--max-wall-ms` passes first the run is a loud
/// error — or, under `--allow-partial`, the completed sub-grid with gap
/// provenance.
fn serve_cmd(args: &Args, csv: bool) -> CliResult {
    let space = space_from_args(args)?;
    let listen = args.opt("--listen").ok_or("serve requires --listen <host:port>")?;
    let shard_count = args.parse_or("--shards", 8usize)?;
    let cfg = ServiceConfig {
        shard_count,
        lease: LeasePolicy {
            lease_ms: args.parse_or("--lease-ms", 30_000u64)?,
            ..LeasePolicy::default()
        },
        max_wall_ms: args.parse_or("--max-wall-ms", 600_000u64)?,
        allow_partial: args.flag("--allow-partial"),
        profile_threads: 1,
    };
    let coordinator = Coordinator::bind(listen, cfg)?;
    eprintln!(
        "serving {shard_count} shards (fingerprint {:016x}) on {}",
        space.fingerprint()?,
        coordinator.local_addr()?
    );
    let (outcome, stats) = coordinator.run(&space)?;
    eprint!("{}", report::service_provenance(&stats));
    match outcome {
        SweepOutcome::Full(grid) => render_grid(&grid, args.opt("--pivot"), !csv),
        SweepOutcome::Partial(partial) => {
            eprint!("{}", report::partial_provenance(&partial));
            print!("{}", report::partial_sweep_report(&partial, !csv));
            Ok(())
        }
    }
}

/// The `work` command: one sweep worker. Connects to a coordinator,
/// verifies the design-space fingerprint it receives against its own
/// decode, then leases, computes, and submits shards until the
/// coordinator says done. Transport failures — including a coordinator
/// restart — are survived by reconnecting and idempotently
/// re-registering; `--fault` arms the deterministic fault injector
/// (chaos testing against a live service).
fn work_cmd(args: &Args) -> CliResult {
    let addr = args.opt("--connect").ok_or("work requires --connect <host:port>")?;
    let mut engine = make_engine(args);
    if let Some(threads) = args.opt("--threads") {
        let threads: usize =
            threads.parse().map_err(|_| format!("bad value for --threads: {threads}"))?;
        engine = engine.with_threads(threads);
    }
    let fault = match args.opt("--fault") {
        Some(spec) => Some(FaultPlan::parse(spec, args.parse_or("--fault-seed", 7u64)?)?),
        None => None,
    };
    let cfg = WorkerConfig { fault, ..WorkerConfig::named(args.opt_or("--worker-id", "")) };
    let summary = maple::sim::service::worker::run(addr, engine, cfg)?;
    eprintln!(
        "worker {}: {} leases, {} submitted, {} duplicate, {} rejected, {} reconnects{}",
        summary.id,
        summary.leases,
        summary.submitted,
        summary.duplicates,
        summary.rejected,
        summary.reconnects,
        if summary.died { " — died (fault)" } else { "" }
    );
    for e in &summary.events {
        eprintln!("  fault {}: {}", e.kind, e.detail);
    }
    Ok(())
}

/// The `chaos` command: the fault-injection harness, self-contained. One
/// coordinator plus `--workers` in-process workers run the sweep over
/// loopback TCP while worker w0 executes the `--fault` plan; the merged
/// outcome is then checked bit-for-bit against the unsharded sweep of
/// the same space. Exit status is the verdict: zero only when the
/// service converged to the exact reference grid despite the faults.
fn chaos_cmd(args: &Args, csv: bool) -> CliResult {
    let space = space_from_args(args)?;
    let workers = args.parse_or("--workers", 3usize)?;
    let plan =
        FaultPlan::parse(args.opt_or("--fault", "die"), args.parse_or("--fault-seed", 7u64)?)?;
    let service = ServiceConfig {
        shard_count: args.parse_or("--shards", 6usize)?,
        lease: LeasePolicy {
            lease_ms: args.parse_or("--lease-ms", 2_000u64)?,
            ..LeasePolicy::default()
        },
        max_wall_ms: 600_000,
        allow_partial: false,
        profile_threads: 1,
    };
    eprintln!("chaos: {workers} workers, w0 runs plan {plan}");
    let spec = ChaosSpec { workers, faulty: 0, plan: Some(plan), service };
    let chaos = run_chaos(&space, &spec, &|| make_engine(args))?;
    eprint!("{}", report::service_provenance(&chaos.stats));
    for w in &chaos.workers {
        match w {
            Ok(r) => {
                eprintln!(
                    "worker {}: {} leases, {} submitted, {} reconnects{}",
                    r.id,
                    r.leases,
                    r.submitted,
                    r.reconnects,
                    if r.died { " — died (fault)" } else { "" }
                );
                for e in &r.events {
                    eprintln!("  fault {}: {}", e.kind, e.detail);
                }
            }
            Err(e) => eprintln!("worker error (an expected chaos outcome): {e}"),
        }
    }
    let reference = make_engine(args).sweep(&space)?;
    match chaos.outcome {
        SweepOutcome::Full(grid) if grid == reference => {
            eprintln!("chaos OK: merged sweep is bit-identical to the unsharded reference");
            render_grid(&grid, args.opt("--pivot"), !csv)
        }
        SweepOutcome::Full(_) => {
            Err("chaos FAILED: merged sweep diverges from the unsharded reference".into())
        }
        SweepOutcome::Partial(partial) => {
            eprint!("{}", report::partial_provenance(&partial));
            Err(format!(
                "chaos FAILED: sweep ended partial ({}/{} cells)",
                partial.covered_cells(),
                partial.total_cells
            )
            .into())
        }
    }
}

/// The `vet` command: static analysis and verification of the simulator
/// itself. Runs the determinism lint over the crate sources and the
/// bounded model checker over the lease/ledger protocol; exits non-zero on
/// any finding, invariant violation, or a search that hit its state cap
/// before exhausting the space. With `--mutant` the polarity flips: a
/// known protocol bug is seeded into the transition relation and the
/// command succeeds only if the checker catches it with a counterexample —
/// the CI self-test that keeps the checker honest.
fn vet_cmd(args: &Args) -> CliResult {
    let lint_only = args.flag("--lint-only");
    let model_only = args.flag("--model-only");
    let mut failed = false;

    if !model_only {
        let root = match args.opt("--src") {
            Some(dir) => std::path::PathBuf::from(dir),
            // Work from either the repo root or the crate root.
            None => ["rust/src", "src"]
                .iter()
                .map(std::path::PathBuf::from)
                .find(|p| p.is_dir())
                .ok_or("cannot find the crate sources (run from the repo or pass --src DIR)")?,
        };
        let report = lint_path(&root)?;
        print!("{report}");
        if !report.findings.is_empty() {
            failed = true;
        }
    }

    if !lint_only {
        let mutation = match args.opt("--mutant") {
            Some(m) => m.parse::<Mutation>()?,
            None => Mutation::None,
        };
        let spec = ModelSpec {
            shards: args.parse_or("--shards", 3usize)?,
            workers: args.parse_or("--workers", 2usize)?,
            max_states: args.parse_or("--max-states", 500_000usize)?,
            mutation,
            ..ModelSpec::default()
        };
        let report = check(&spec);
        print!("{report}");
        if mutation != Mutation::None {
            if report.violations.is_empty() {
                return Err("vet: seeded mutant escaped the model checker".into());
            }
            eprintln!("vet: seeded mutant caught with a replayable counterexample");
            return Ok(());
        }
        if !report.violations.is_empty() || !report.exhausted {
            failed = true;
        }
    }

    if failed {
        return Err("vet found violations (see the report above)".into());
    }
    eprintln!("vet OK");
    Ok(())
}

/// The `ingest` command: the out-of-core pipeline. Generate a Matrix-Market
/// file (`--gen`), stream it into a row-group container under a memory
/// budget (`--out`), run the tiled profiler over either form
/// (`--profile-out`), or print the per-row-group nnz balance (`--report`).
fn ingest_cmd(args: &Args, csv: bool) -> CliResult {
    // Matrix synthesis: the suite generators already back every simulation
    // command; here they give CI (and users) arbitrarily-large .mtx inputs.
    // Accepts a Table-I name (scaled with --scale) or a raw family spec
    // `uniform | powerlaw:ALPHA | banded:REL_BW:CLUSTER` sized with
    // --rows/--cols/--nnz.
    if let Some(spec) = args.opt("--gen") {
        let out = args.opt("--mtx-out").ok_or("--gen requires --mtx-out <path.mtx>")?;
        let seed = args.parse_or("--seed", 7u64)?;
        let a = if suite::by_name(spec).is_some() {
            let scale = args.parse_or("--scale", 4usize)?;
            explore::suite_matrix(&WorkloadKey::suite(spec, seed, scale))?
        } else {
            let profile = parse_gen_profile(spec)?;
            let rows = args.parse_or("--rows", 0usize)?;
            let nnz = args.parse_or("--nnz", 0usize)?;
            if rows == 0 || nnz == 0 {
                return Err(format!("--gen {spec} needs --rows N and --nnz N").into());
            }
            let cols = args.parse_or("--cols", rows)?;
            gen::generate(rows, cols, nnz.min(rows * cols), profile, seed)
        };
        sparse_io::write_matrix_market(std::path::Path::new(out), &a)?;
        eprintln!("ingest: wrote {out} ({}x{}, {} nnz)", a.rows(), a.cols(), a.nnz());
        return Ok(());
    }

    // The input path is positional; skip the values of value-bearing flags
    // (same scan as `merge`).
    const VALUE_FLAGS: [&str; 12] = [
        "--out",
        "--mem-budget",
        "--profile-out",
        "--tile",
        "--threads",
        "--stats-json",
        "--scale",
        "--seed",
        "--mtx-out",
        "--rows",
        "--cols",
        "--nnz",
    ];
    let input = positional(args, &VALUE_FLAGS)
        .ok_or("usage: maple ingest <in.mtx|in.mrg> [--out|--profile-out|--report] ...")?
        .to_string();
    let path = std::path::Path::new(&input);
    let is_container = input.ends_with(".mrg");

    // Conversion: .mtx -> .mrg under the budget.
    if let Some(out) = args.opt("--out") {
        if is_container {
            return Err("--out converts a .mtx input; this is already a container".into());
        }
        let budget = parse_mem_budget(args.opt_or("--mem-budget", "256M"))?;
        let stream = sparse_io::stream_matrix_market(path, budget)?;
        let groups = stream.group_count();
        let file = sparse_io::RowGroupFile::create(std::path::Path::new(out), stream)?;
        eprintln!(
            "ingest: {input} -> {out} ({groups} row groups, {}x{}, {} nnz, budget {budget} B)",
            file.rows(),
            file.cols(),
            file.nnz()
        );
        return Ok(());
    }

    // Tiled profiling: C = A x A through the partial cache.
    if let Some(out) = args.opt("--profile-out") {
        let shape = parse_tile(args)?;
        let threads = args.parse_or("--threads", 1usize)?;
        let t = std::time::Instant::now();
        let (w, stats) = if is_container {
            if args.flag("--no-cache") {
                return Err("out-of-core profiling resumes through the partial cache; \
                            --no-cache is not supported for .mrg inputs"
                    .into());
            }
            let file = sparse_io::RowGroupFile::open(path)?;
            let disk = DiskCache::from_env()
                .map_err(|e| format!("cannot open workload cache dir: {e}"))?;
            let key = format!("ingest-{:016x}", file.fingerprint());
            profile_container_tiled(&file, shape, &disk, &key)?
        } else {
            let a = sparse_io::read_matrix_market(path)?;
            profile_workload_tiled_cached(&a, &a, shape, threads, None)
        };
        let wall_ms = t.elapsed().as_millis() as u64;
        std::fs::write(out, cache::encode_workload(&w))
            .map_err(|e| format!("cannot write {out}: {e}"))?;
        let blocks = stats.blocks_computed + stats.blocks_loaded;
        let tiles_per_sec = blocks as f64 / (wall_ms.max(1) as f64 / 1e3);
        eprintln!(
            "ingest: profiled {input} at tile {shape} -> {out} \
             ({} x {} row groups x col tiles, {} computed + {} warm, \
             peak {} B resident, {wall_ms} ms)",
            stats.row_groups,
            stats.col_tiles,
            stats.blocks_computed,
            stats.blocks_loaded,
            stats.peak_bytes
        );
        if let Some(json_path) = args.opt("--stats-json") {
            let json = format!(
                "{{\n  \"input\": \"{input}\",\n  \"rows\": {},\n  \"cols\": {},\n  \
                 \"nnz\": {},\n  \"out_nnz\": {},\n  \"tile\": \"{shape}\",\n  \
                 \"row_groups\": {},\n  \"col_tiles\": {},\n  \"blocks_computed\": {},\n  \
                 \"blocks_loaded\": {},\n  \"peak_bytes\": {},\n  \"wall_ms\": {wall_ms},\n  \
                 \"tiles_per_sec\": {tiles_per_sec:.2}\n}}\n",
                w.rows,
                w.cols,
                w.nnz_a,
                w.out_nnz,
                stats.row_groups,
                stats.col_tiles,
                stats.blocks_computed,
                stats.blocks_loaded,
                stats.peak_bytes,
            );
            std::fs::write(json_path, json).map_err(|e| format!("cannot write {json_path}: {e}"))?;
            eprintln!("bench: wrote {json_path}");
        }
        return Ok(());
    }

    // Balance report: per-row-group nnz summary (satellite of the tiled
    // profiler — the skew a tiled run will see, before running it).
    if args.flag("--report") {
        let md = !csv;
        if is_container {
            let file = sparse_io::RowGroupFile::open(path)?;
            let header =
                ["Group", "Rows", "nnz", "Mean/row", "CV", "Max row", "Max share", "Heavy share"];
            let mut rows = Vec::with_capacity(file.group_count());
            for g in 0..file.group_count() {
                let slice = file.load_group(g)?;
                let s = stats::row_nnz_summary(&slice.matrix);
                rows.push(vec![
                    format!("{g} [{}, {})", slice.row_lo, slice.row_hi),
                    s.rows.to_string(),
                    s.nnz.to_string(),
                    format!("{:.2}", s.mean),
                    format!("{:.2}", s.cv),
                    s.max.to_string(),
                    format!("{:.3}", s.max_share),
                    format!("{:.3}", s.heavy_share),
                ]);
            }
            println!(
                "tiling {input}: {}x{} in {} row groups",
                file.rows(),
                file.cols(),
                file.group_count()
            );
            let table = if md {
                report::markdown_table(&header, &rows)
            } else {
                report::csv(&header, &rows)
            };
            print!("{table}");
        } else {
            let a = sparse_io::read_matrix_market(path)?;
            print!("{}", report::tiling_report(&input, &a, parse_tile(args)?, md));
        }
        return Ok(());
    }

    Err("ingest needs one of --gen/--out/--profile-out/--report (see --help)".into())
}

#[cfg(feature = "runtime")]
fn validate(args: &Args) -> CliResult {
    let dir = args
        .opt("--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(maple::runtime::artifacts_dir);
    let client = xla::PjRtClient::cpu()?;
    let dp = maple::runtime::MapleDatapath::load(&client, &dir)?;
    let meta = dp.meta();
    println!("loaded {} (kt={} nt={})", dir.join("maple_pe.hlo.txt").display(), meta.kt, meta.nt);
    // Drive random tiles through the compiled kernel vs scalar math.
    let mut rng = maple::sparse::SplitMix64::new(1234);
    let mut max_err = 0f32;
    const TILES: usize = 32;
    for _ in 0..TILES {
        let a: Vec<f32> = (0..meta.kt).map(|_| rng.value()).collect();
        let b: Vec<f32> = (0..meta.kt * meta.nt).map(|_| rng.value()).collect();
        let psb = dp.run_tile(&a, &b)?;
        for n in 0..meta.nt {
            let want: f32 = (0..meta.kt).map(|k| a[k] * b[k * meta.nt + n]).sum();
            max_err = max_err.max((psb[n] - want).abs());
        }
    }
    println!("{TILES} tiles executed via PJRT, max |err| vs reference = {max_err:.2e}");
    if max_err >= 1e-4 {
        return Err("compiled datapath diverges from reference".into());
    }
    println!("validate OK — artifacts are healthy");
    Ok(())
}

#[cfg(not(feature = "runtime"))]
fn validate(_args: &Args) -> CliResult {
    Err("validate needs the PJRT runtime: rebuild with `cargo build --features runtime`".into())
}

fn main() -> CliResult {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let args = Args::new(argv[1..].to_vec());
    let csv = args.flag("--csv");
    let md = !csv;

    match cmd.as_str() {
        "datasets" => print!("{}", report::table1(md)),
        "fig3" => print!("{}", report::fig3(md)),
        "fig8" => {
            let accel = args.opt_or("--accel", "matraptor");
            let (b, m) = match accel {
                "matraptor" => {
                    (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple())
                }
                "extensor" => {
                    (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple())
                }
                other => return Err(format!("unknown accelerator {other}").into()),
            };
            print!("{}", report::fig8_report(&b, &m, md));
        }
        "fig9" => {
            let scale = args.parse_or("--scale", 16usize)?;
            let seed = args.parse_or("--seed", 7u64)?;
            fig9(&make_engine(&args), scale, args.opt("--datasets"), seed, csv)?;
        }
        "simulate" => {
            let cfg = parse_config(args.opt_or("--config", "extensor-maple"))?;
            let dataset = args.opt_or("--dataset", "wikiVote");
            let scale = args.parse_or("--scale", 1usize)?;
            let seed = args.parse_or("--seed", 7u64)?;
            let engine = make_engine(&args);
            let key = WorkloadKey::suite(dataset, seed, scale);
            let w = engine.workload(&key)?;
            let policy = parse_policy(args.opt_or("--policy", "round-robin"))?;
            let model = parse_cell_model(&args)?;
            let cell = engine.simulate_cell(&cfg, &key, policy, model)?;
            let r = &cell.analytic;
            println!("config            : {}", r.config);
            println!("dataset           : {dataset} (scale 1/{scale})");
            println!("rows x cols       : {} x {}", w.rows, w.cols);
            println!("nnz(A)            : {}", w.nnz_a);
            println!("nnz(C)            : {}", r.out_nnz);
            println!("products          : {}", r.total_products);
            println!("cycles (compute)  : {}", r.cycles_compute);
            println!("cycles (dram-bnd) : {}", r.cycles_dram_bound);
            println!("MAC utilisation   : {:.1}%", 100.0 * r.mac_utilisation(&cfg));
            println!("PE balance        : {:.3}", r.balance);
            println!("energy total      : {:.3} uJ", r.energy.total_pj() / 1e6);
            println!("  mac             : {:.3} uJ", r.energy.mac_pj / 1e6);
            println!("  l0 (regs)       : {:.3} uJ", r.energy.l0_pj / 1e6);
            println!("  pe buffers      : {:.3} uJ", r.energy.pe_buffer_pj / 1e6);
            println!("  l1              : {:.3} uJ", r.energy.l1_pj / 1e6);
            println!("  dram            : {:.3} uJ", r.energy.dram_pj / 1e6);
            println!("  noc             : {:.3} uJ", r.energy.noc_pj / 1e6);
            println!("checksum          : {:.6e}", r.checksum);
            if let Some(des) = &cell.des {
                println!("--- DES cross-check ({model:?} cell model) ---");
                println!("cycles (DES)      : {}", des.cycles);
                println!("DES/analytic      : {:.3}", cell.agreement_ratio().unwrap_or(0.0));
                println!("DES PE util       : {:.1}%", 100.0 * des.pe_utilisation);
                println!("DES finish skew   : {:.2}", des.finish_skew());
                println!(
                    "agreement band    : {}",
                    if cell.des_in_band() == Some(true) { "in band" } else { "OUT OF BAND" }
                );
            }
        }
        "sweep" => sweep_cmd(&args, csv)?,
        "explore" => explore_cmd(&args, csv)?,
        "estval" => estval_cmd(&args, csv)?,
        "merge" => merge_cmd(&args, csv)?,
        "serve" => serve_cmd(&args, csv)?,
        "work" => work_cmd(&args)?,
        "chaos" => chaos_cmd(&args, csv)?,
        "vet" => vet_cmd(&args)?,
        "ingest" => ingest_cmd(&args, csv)?,
        "crossval" => {
            let scale = args.parse_or("--scale", 16usize)?;
            let seed = args.parse_or("--seed", 7u64)?;
            let policy = parse_policy(args.opt_or("--policy", "round-robin"))?;
            crossval(&make_engine(&args), scale, args.opt("--datasets"), seed, policy, csv)?;
        }
        "cache" => {
            let cache = DiskCache::from_env()
                .map_err(|e| format!("cannot open workload cache dir: {e}"))?;
            let action =
                args.argv.iter().find(|s| !s.starts_with("--")).map(|s| s.as_str());
            match action.unwrap_or("stats") {
                "stats" => print!("{}", report::cache_stats_report(&cache.stats(), md)),
                "clear" => {
                    let removed = cache.clear()?;
                    println!("removed {removed} cached artifacts from {}", cache.dir().display());
                }
                other => return Err(format!("unknown cache action {other} (stats|clear)").into()),
            }
        }
        "config" => {
            print!("{}", parse_config(args.opt_or("--preset", "extensor-maple"))?.to_toml())
        }
        "validate" => validate(&args)?,
        "--help" | "-h" | "help" => print!("{USAGE}"),
        other => {
            match closest_command(other) {
                Some(hint) => eprintln!("unknown command: {other} (did you mean {hint:?}?)\n"),
                None => eprintln!("unknown command: {other}\n"),
            }
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Every dispatchable command name, kept in sync with the `main` match (a
/// unit test walks USAGE against this list).
const COMMANDS: [&str; 18] = [
    "datasets", "fig3", "fig8", "fig9", "simulate", "sweep", "explore", "estval", "merge", "serve",
    "work", "chaos", "vet", "ingest", "crossval", "cache", "config", "validate",
];

/// The closest known command within a small edit distance — the
/// "did you mean" hint for typos like `sweeep` or `exlpore`.
fn closest_command(input: &str) -> Option<&'static str> {
    COMMANDS
        .iter()
        .map(|&c| (levenshtein(input, c), c))
        .filter(|&(d, _)| d <= 2)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

/// Plain O(n·m) Levenshtein distance (two-row rolling buffer).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_lists_every_command() {
        for cmd in COMMANDS {
            assert!(
                USAGE.lines().any(|l| {
                    let t = l.trim_start();
                    t == cmd || t.starts_with(&format!("{cmd} "))
                }),
                "USAGE is missing the {cmd} command"
            );
        }
    }

    #[test]
    fn typos_get_a_hint() {
        assert_eq!(closest_command("sweeep"), Some("sweep"));
        assert_eq!(closest_command("exploer"), Some("explore"));
        assert_eq!(closest_command("estvall"), Some("estval"));
        assert_eq!(closest_command("corssval"), Some("crossval"));
        assert_eq!(closest_command("zzzzzz"), None);
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
    }
}
