//! Work coordination: how output rows are routed to processing elements.
//!
//! Row-wise product accelerators are spatial machines — somebody must decide
//! which PE computes which output row. The coordinator implements the
//! partitioning policies the evaluation uses, plus the reuse-aware batcher:
//!
//! * [`Policy::RoundRobin`] — row `i` to PE `i mod n` (the reference
//!   accelerators' default; keeps loaders simple).
//! * [`Policy::Chunked`] — contiguous row blocks (maximises A-stream
//!   sequentiality, worst load balance on skewed matrices).
//! * [`Policy::GreedyBalance`] — longest-processing-time-first on the
//!   per-row multiply counts; near-optimal makespan, needs the profile pass.
//!
//! [`batch_rows_by_reuse`] additionally groups rows that touch overlapping
//! sets of B rows so BRB fills can be shared between consecutive rows — the
//! software analogue of the locality Maple's clustered MACs exploit.

use crate::pe::RowProfile;

/// Row-to-PE assignment policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// `i mod num_pes`.
    RoundRobin,
    /// Contiguous blocks of `ceil(rows / num_pes)`.
    Chunked,
    /// Longest-processing-time-first by per-row products.
    GreedyBalance,
}

/// A partition of output rows over PEs.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// `assignments[pe]` = row indices (in processing order) for that PE.
    pub assignments: Vec<Vec<u32>>,
}

impl Partition {
    /// Number of PEs.
    pub fn num_pes(&self) -> usize {
        self.assignments.len()
    }

    /// Total rows assigned.
    pub fn total_rows(&self) -> usize {
        self.assignments.iter().map(|a| a.len()).sum()
    }

    /// Load-balance factor: max PE work / mean PE work (1.0 = perfect),
    /// where work is the summed products of assigned rows.
    pub fn balance(&self, profiles: &[RowProfile]) -> f64 {
        let loads: Vec<u64> = self
            .assignments
            .iter()
            .map(|rows| rows.iter().map(|&r| profiles[r as usize].products).sum())
            .collect();
        let max = *loads.iter().max().unwrap_or(&0) as f64;
        let mean = loads.iter().sum::<u64>() as f64 / loads.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Partition `rows` output rows across `num_pes` PEs under `policy`.
pub fn partition(policy: Policy, num_pes: usize, profiles: &[RowProfile]) -> Partition {
    assert!(num_pes > 0);
    let rows = profiles.len();
    let mut assignments = vec![Vec::with_capacity(rows / num_pes + 1); num_pes];
    match policy {
        Policy::RoundRobin => {
            for i in 0..rows {
                assignments[i % num_pes].push(i as u32);
            }
        }
        Policy::Chunked => {
            let chunk = rows.div_ceil(num_pes).max(1);
            for i in 0..rows {
                assignments[(i / chunk).min(num_pes - 1)].push(i as u32);
            }
        }
        Policy::GreedyBalance => {
            // LPT: sort rows by descending products, place each on the
            // currently least-loaded PE.
            let mut order: Vec<u32> = (0..rows as u32).collect();
            order.sort_unstable_by_key(|&i| std::cmp::Reverse(profiles[i as usize].products));
            // Binary heap of (load, pe) — min-load first.
            let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, usize)>> =
                (0..num_pes).map(|p| std::cmp::Reverse((0u64, p))).collect();
            for i in order {
                let std::cmp::Reverse((load, pe)) = heap.pop().unwrap();
                assignments[pe].push(i);
                heap.push(std::cmp::Reverse((load + profiles[i as usize].products, pe)));
            }
            // Keep each PE's rows in ascending order for stream locality.
            for a in &mut assignments {
                a.sort_unstable();
            }
        }
    }
    Partition { assignments }
}

/// Split output rows whose product count exceeds `max_products` into
/// column-tile chunks, so one giant row does not serialise a whole PE.
/// Both reference accelerators do this in hardware — Extensor tiles the
/// output column space, Matraptor round-robins partial rows — so the split
/// applies uniformly to every configuration. Each chunk re-reads the A row
/// (`a_nnz` preserved per chunk), which is exactly the re-fetch cost column
/// tiling pays.
pub fn split_wide_rows(profiles: &[RowProfile], max_products: u64) -> Vec<RowProfile> {
    let max_products = max_products.max(1);
    let mut out = Vec::with_capacity(profiles.len());
    for p in profiles {
        if p.products <= max_products {
            out.push(*p);
            continue;
        }
        let chunks = p.products.div_ceil(max_products);
        let base_prod = p.products / chunks;
        let mut rem_prod = p.products - base_prod * chunks;
        let base_out = p.out_nnz as u64 / chunks;
        let mut rem_out = p.out_nnz as u64 - base_out * chunks;
        for _ in 0..chunks {
            let prod = base_prod + if rem_prod > 0 { rem_prod -= 1; 1 } else { 0 };
            let out_nnz = base_out + if rem_out > 0 { rem_out -= 1; 1 } else { 0 };
            out.push(RowProfile { a_nnz: p.a_nnz, products: prod, out_nnz: out_nnz as u32 });
        }
    }
    out
}

/// Group a PE's row list into batches whose A-rows reference overlapping
/// B rows (approximated by adjacent row indices sharing column locality).
/// Returns batch boundaries as index ranges into the row list. `max_batch`
/// bounds the ARB residency.
pub fn batch_rows_by_reuse(
    rows: &[u32],
    profiles: &[RowProfile],
    max_batch: usize,
) -> Vec<std::ops::Range<usize>> {
    let mut out = Vec::new();
    let mut start = 0usize;
    let mut batch_products = 0u64;
    // Heuristic: close a batch when it reaches max_batch rows or when the
    // accumulated product volume exceeds the per-batch budget (keeps merge
    // state bounded).
    const PRODUCT_BUDGET: u64 = 1 << 14;
    for (idx, &r) in rows.iter().enumerate() {
        let p = profiles[r as usize].products;
        let rows_in_batch = idx - start;
        if rows_in_batch > 0 && (rows_in_batch >= max_batch || batch_products + p > PRODUCT_BUDGET)
        {
            out.push(start..idx);
            start = idx;
            batch_products = 0;
        }
        batch_products += p;
    }
    if start < rows.len() {
        out.push(start..rows.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiles(products: &[u64]) -> Vec<RowProfile> {
        products
            .iter()
            .map(|&p| RowProfile { a_nnz: 1, products: p, out_nnz: p.min(u32::MAX as u64) as u32 })
            .collect()
    }

    #[test]
    fn round_robin_spreads_rows() {
        let pr = profiles(&[1; 10]);
        let part = partition(Policy::RoundRobin, 4, &pr);
        assert_eq!(part.total_rows(), 10);
        assert_eq!(part.assignments[0], vec![0, 4, 8]);
        assert_eq!(part.assignments[3], vec![3, 7]);
    }

    #[test]
    fn chunked_is_contiguous() {
        let pr = profiles(&[1; 10]);
        let part = partition(Policy::Chunked, 3, &pr);
        assert_eq!(part.assignments[0], vec![0, 1, 2, 3]);
        assert_eq!(part.assignments[1], vec![4, 5, 6, 7]);
        assert_eq!(part.assignments[2], vec![8, 9]);
    }

    #[test]
    fn greedy_beats_round_robin_on_skew() {
        // One giant row + many small ones: round-robin puts the giant on a
        // PE that also gets its share of small rows; greedy isolates it.
        let mut v = vec![1000u64];
        v.extend(std::iter::repeat(10).take(99));
        let pr = profiles(&v);
        let rr = partition(Policy::RoundRobin, 4, &pr).balance(&pr);
        let greedy = partition(Policy::GreedyBalance, 4, &pr).balance(&pr);
        assert!(greedy <= rr, "greedy {greedy} vs rr {rr}");
        // LPT is optimal here: the giant row alone bounds the makespan, so
        // balance = giant / mean-load = 1000 / 497.5 ≈ 2.01, and greedy must
        // achieve exactly that bound (RR additionally stacks small rows on
        // the giant's PE).
        let optimal = 1000.0 / ((1000.0 + 99.0 * 10.0) / 4.0);
        assert!((greedy - optimal).abs() < 1e-9, "greedy {greedy} vs optimal {optimal}");
    }

    #[test]
    fn every_row_assigned_exactly_once() {
        let pr = profiles(&(0..57).map(|i| i % 7 + 1).collect::<Vec<_>>());
        for policy in [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance] {
            let part = partition(policy, 5, &pr);
            let mut seen = vec![false; 57];
            for a in &part.assignments {
                for &r in a {
                    assert!(!seen[r as usize], "{policy:?} duplicated row {r}");
                    seen[r as usize] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{policy:?} dropped rows");
        }
    }

    #[test]
    fn batches_respect_limits() {
        let pr = profiles(&[100; 64]);
        let rows: Vec<u32> = (0..64).collect();
        let batches = batch_rows_by_reuse(&rows, &pr, 8);
        assert!(!batches.is_empty());
        let mut covered = 0;
        for b in &batches {
            assert!(b.len() <= 8);
            covered += b.len();
        }
        assert_eq!(covered, 64);
    }

    #[test]
    fn batch_budget_splits_heavy_rows() {
        let pr = profiles(&[1 << 13, 1 << 13, 1 << 13]);
        let rows: Vec<u32> = vec![0, 1, 2];
        let batches = batch_rows_by_reuse(&rows, &pr, 100);
        assert!(batches.len() >= 2, "product budget must split: {batches:?}");
    }
}
