//! Bounded model checking of the lease/ledger protocol.
//!
//! [`check`] drives the *real* [`LeaseTable`] and the pure
//! [`LedgerCore`] slot machine — not re-implementations — through every
//! interleaving of an abstract event alphabet (request/grant, lease
//! expiry, valid/stale/duplicate/divergent/corrupt submissions) for a
//! configurable N shards × M workers product state machine, breadth-first
//! with visited-state hashing. Breadth-first order makes the first
//! violation found a *minimal* counterexample trace.
//!
//! The search is exhaustive and terminates because the abstraction is
//! finite and monotone: the visited projection keeps slot states (with
//! attempt counters), per-worker failure counts, and ledger payload tags,
//! but drops absolute times, backoff durations, and the jitter RNG stream.
//! Every projection-changing transition strictly increases
//! `sum(attempts) + sum(failures) + #Done`, attempts are bounded by the
//! failure budget (a re-lease requires an expiry, which costs a failure;
//! failures quarantine at the policy budget), so the abstract graph is a
//! finite DAG — no cycles, every schedule reaches a terminal. Bounded
//! liveness then reduces to checking terminals: each must be `AllDone` or
//! the typed all-workers-quarantined `Incomplete`.
//!
//! Safety invariants, checked on every transition:
//!
//! 1. **merge-consistent** — a stored shard payload is immutable and
//!    always the canonical bytes; identical resubmissions are duplicates,
//!    divergent ones are conflicts (never accepted).
//! 2. **no-lost-shard** — slots only move `Pending{a} → Leased{·,a+1}`,
//!    `Leased → Pending{a}` (reap, only past the deadline), or `→ Done`.
//!    A live lease silently re-granted (the double-grant bug) is illegal.
//! 3. **quarantine-respected** — a quarantined worker's request is always
//!    answered `Quarantined`, and only canonical payloads reach the merge.
//! 4. **backoff-monotone** — each successive penalty's deterministic
//!    backoff floor `base << min(failures-1, 6)` is non-decreasing, and
//!    the observed backoff sits inside `[floor, floor + base)` (the jitter
//!    window).
//!
//! Each violation renders its trace plus a [`FaultPlan`]-parseable string
//! (`stall`, `corrupt:N`, `dup`) so `run_chaos` / `maple chaos --fault`
//! can replay the failure class dynamically. The seeded-bug self-test
//! ([`Mutation`]) proves the checker actually catches what it claims to.

use std::collections::{BTreeSet, VecDeque};
use std::fmt;

use crate::sim::service::coordinator::LedgerCore;
use crate::sim::service::lease::{Grant, LeasePolicy, LeaseTable, SlotView};

/// Canonical shard payload in the abstract ledger (stands in for the
/// canonical `MAPLESHD` bytes).
const CANONICAL: &[u8] = &[0xCA];
/// A byte-divergent payload for the same shard (a forged or corrupted
/// result that decoded "validly").
const DIVERGENT: &[u8] = &[0xD1];

/// A protocol bug the checker can seed into the transition relation — the
/// mutation self-test behind `maple vet --mutant`. The hooks live next to
/// the real transition code ([`LeaseTable::force_grant`],
/// [`LedgerCore::force_store`]) but are only ever called from here, and
/// only when a mutation is selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mutation {
    /// The faithful protocol.
    #[default]
    None,
    /// Grant a shard that is still under a live lease to a second worker
    /// (violates no-lost-shard).
    DoubleGrant,
    /// Store a byte-divergent resubmission over the merged payload instead
    /// of rejecting it (violates merge-consistent / quarantine-respected).
    QuarantineBypass,
}

impl std::str::FromStr for Mutation {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(Mutation::None),
            "double-grant" => Ok(Mutation::DoubleGrant),
            "quarantine-bypass" => Ok(Mutation::QuarantineBypass),
            other => Err(format!("unknown mutant {other:?} (double-grant | quarantine-bypass)")),
        }
    }
}

/// What to check: the product-machine bounds and the seeded mutation.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// N shards (slots in the lease table / ledger).
    pub shards: usize,
    /// M workers (`w0..w{M-1}`).
    pub workers: usize,
    /// The real policy under test. The default keeps `max_failures` at 2
    /// so the exhaustive space stays compact; raise it via the CLI to
    /// explore deeper retry ladders.
    pub policy: LeasePolicy,
    /// Hard cap on explored states; exceeding it reports `exhausted:
    /// false` (and fails `vet`) rather than running unbounded.
    pub max_states: usize,
    pub mutation: Mutation,
}

impl Default for ModelSpec {
    fn default() -> Self {
        Self {
            shards: 3,
            workers: 2,
            policy: LeasePolicy { lease_ms: 8, max_failures: 2, backoff_base_ms: 4, seed: 0xa5 },
            max_states: 500_000,
            mutation: Mutation::None,
        }
    }
}

/// The four safety invariants plus bounded liveness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Invariant {
    MergeConsistent,
    NoLostShard,
    QuarantineRespected,
    BackoffMonotone,
    BoundedTermination,
}

impl Invariant {
    pub fn id(self) -> &'static str {
        match self {
            Invariant::MergeConsistent => "merge-consistent",
            Invariant::NoLostShard => "no-lost-shard",
            Invariant::QuarantineRespected => "quarantine-respected",
            Invariant::BackoffMonotone => "backoff-monotone",
            Invariant::BoundedTermination => "bounded-termination",
        }
    }
}

/// A violated invariant with its minimal counterexample.
#[derive(Debug, Clone)]
pub struct Violation {
    pub invariant: Invariant,
    pub detail: String,
    /// Event labels from the initial state to (and including) the
    /// violating event.
    pub trace: Vec<String>,
    /// A `FaultPlan`-parseable dynamic trigger for the same failure class
    /// (`maple chaos --fault <plan>` replays it).
    pub fault_plan: String,
}

/// What one [`check`] run proved (or found).
#[derive(Debug)]
pub struct ModelReport {
    pub shards: usize,
    pub workers: usize,
    pub states: usize,
    pub transitions: usize,
    /// Terminals where every shard merged.
    pub all_done_terminals: usize,
    /// Terminals where every worker is quarantined and un-computed shards
    /// remain — the typed `ServiceError::Incomplete` outcome.
    pub incomplete_terminals: usize,
    /// True iff the frontier emptied under `max_states`: the full abstract
    /// space was searched.
    pub exhausted: bool,
    pub violations: Vec<Violation>,
}

impl fmt::Display for ModelReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "vet model: {} shards x {} workers, {} states, {} transitions, exhausted={}",
            self.shards, self.workers, self.states, self.transitions, self.exhausted
        )?;
        writeln!(
            f,
            "  terminals: {} AllDone, {} Incomplete (all workers quarantined)",
            self.all_done_terminals, self.incomplete_terminals
        )?;
        if self.violations.is_empty() {
            let proved = [
                Invariant::MergeConsistent,
                Invariant::NoLostShard,
                Invariant::QuarantineRespected,
                Invariant::BackoffMonotone,
                Invariant::BoundedTermination,
            ];
            let ids: Vec<&str> = proved.iter().map(|i| i.id()).collect();
            writeln!(f, "  invariants proved: {}", ids.join(", "))?;
        }
        for v in &self.violations {
            writeln!(f, "vet model VIOLATION [{}]: {}", v.invariant.id(), v.detail)?;
            writeln!(f, "  counterexample trace:")?;
            for (i, step) in v.trace.iter().enumerate() {
                writeln!(f, "    {}. {step}", i + 1)?;
            }
            writeln!(f, "  counterexample fault plan: {}", v.fault_plan)?;
            writeln!(
                f,
                "  replay: maple chaos --workers 1 --shards 2 --fault {} --lease-ms 300",
                v.fault_plan
            )?;
        }
        Ok(())
    }
}

// ------------------------------------------------------------- state space

/// Event kinds (the alphabet); labels carry the instance detail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Request,
    Expire,
    SubmitValid,
    StaleSubmit,
    Duplicate,
    Divergent,
    Corrupt,
}

/// One reached state: the real table + ledger plus the abstract clock
/// (excluded from the visited projection — only event order matters).
#[derive(Clone)]
struct Node {
    table: LeaseTable,
    ledger: LedgerCore,
    now: u64,
}

/// A stored search record: the node plus its parent edge (for traces).
struct Rec {
    node: Node,
    parent: usize,
    label: String,
    kind: Kind,
}

/// The visited-set projection: slot states with attempt counters, worker
/// failure records, and ledger payload tags. Absolute times, backoff
/// durations, and the jitter RNG stream are deliberately dropped — they do
/// not affect abstract behaviour (requests wait out backoff; expiries jump
/// to the deadline), and keeping them would make the space infinite.
fn project(node: &Node, ids: &[String]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(4 * ids.len() + 8);
    for slot in node.table.slot_views() {
        match slot {
            SlotView::Pending { attempt } => {
                bytes.push(0);
                bytes.push(attempt.min(250) as u8);
            }
            SlotView::Leased { worker, attempt, .. } => {
                let widx = ids.iter().position(|id| *id == worker).unwrap_or(255);
                bytes.push(1);
                bytes.push(widx as u8);
                bytes.push(attempt.min(250) as u8);
            }
            SlotView::Done => bytes.push(2),
        }
        bytes.push(0xFE);
    }
    bytes.push(0xFF);
    for view in node.table.worker_views() {
        bytes.push(view.failures.min(250) as u8);
        bytes.push(u8::from(view.quarantined));
    }
    bytes.push(0xFF);
    for i in 0..node.ledger.shard_count() {
        bytes.push(match node.ledger.payload(i) {
            None => 0,
            Some(p) if p == CANONICAL => 1,
            Some(_) => 2,
        });
    }
    bytes
}

/// Run the bounded check. Stops at the first violation (breadth-first, so
/// it is minimal); a clean run proves all invariants over the exhausted
/// space.
pub fn check(spec: &ModelSpec) -> ModelReport {
    let shards = spec.shards.max(1);
    let worker_count = spec.workers.max(1);
    let ids: Vec<String> = (0..worker_count).map(|i| format!("w{i}")).collect();
    let mut report = ModelReport {
        shards,
        workers: worker_count,
        states: 0,
        transitions: 0,
        all_done_terminals: 0,
        incomplete_terminals: 0,
        exhausted: false,
        violations: Vec::new(),
    };

    let mut table = LeaseTable::new(shards, spec.policy.clone());
    for id in &ids {
        table.register(id);
    }
    let root = Node { table, ledger: LedgerCore::new(shards), now: 0 };

    let mut recs: Vec<Rec> = Vec::new();
    let mut visited: BTreeSet<Vec<u8>> = BTreeSet::new();
    let mut queue: VecDeque<usize> = VecDeque::new();
    visited.insert(project(&root, &ids));
    recs.push(Rec {
        node: root,
        parent: usize::MAX,
        label: "initial state".into(),
        kind: Kind::Request,
    });
    queue.push_back(0);

    while let Some(at) = queue.pop_front() {
        if recs.len() > spec.max_states {
            report.states = recs.len();
            return report; // exhausted stays false: the cap was hit
        }
        let pre_projection = project(&recs[at].node, &ids);
        let mut progressed = false;
        for (kind, label, applied) in successors(&recs[at].node, &ids, spec) {
            report.transitions += 1;
            let outcome = verify_transition(&recs[at].node, &applied, &label, spec);
            if let Err((invariant, detail)) = outcome {
                report.violations.push(render_violation(
                    invariant, detail, &recs, at, kind, &label,
                ));
                report.states = recs.len();
                return report;
            }
            let projection = project(&applied, &ids);
            if projection == pre_projection {
                continue; // no-op transition (Wait, duplicate ack, ...)
            }
            progressed = true;
            if visited.insert(projection) {
                recs.push(Rec { node: applied, parent: at, label, kind });
                queue.push_back(recs.len() - 1);
            }
        }
        if !progressed {
            classify_terminal(&mut report, &recs, at);
            if !report.violations.is_empty() {
                report.states = recs.len();
                return report;
            }
        }
    }
    report.states = recs.len();
    report.exhausted = true;
    report
}

/// Enumerate every enabled event from `node`, each applied to a clone of
/// the real state. Deterministic order: workers, then shards, then the
/// submission alphabet.
fn successors(node: &Node, ids: &[String], spec: &ModelSpec) -> Vec<(Kind, String, Node)> {
    let mut out = Vec::new();
    let slots = node.table.slot_views();
    let workers = node.table.worker_views();
    let quarantined =
        |w: usize| workers.iter().find(|v| v.id == ids[w]).is_some_and(|v| v.quarantined);
    let backoff_until =
        |w: usize| workers.iter().find(|v| v.id == ids[w]).map_or(0, |v| v.backoff_until);

    // request(w): the worker asks for work, waiting out any backoff first
    // (fair scheduling — backoff delays, it never blocks forever).
    for w in 0..ids.len() {
        let mut next = node.clone();
        next.now = node.now.max(backoff_until(w));
        let mut grant = next.table.grant(&ids[w], next.now);
        if spec.mutation == Mutation::DoubleGrant {
            if let Grant::Wait { .. } = grant {
                // Seeded bug: hand out a shard that is still under a live
                // lease held by another worker.
                let stolen = slots.iter().enumerate().find_map(|(i, s)| match s {
                    SlotView::Leased { worker, .. } if *worker != ids[w] => Some(i),
                    _ => None,
                });
                if let Some(index) = stolen {
                    if let Some(attempt) = next.table.force_grant(index, &ids[w], next.now) {
                        grant = Grant::Lease { index, attempt };
                    }
                }
            }
        }
        out.push((Kind::Request, format!("request({}) -> {:?}", ids[w], grant), next));
    }

    // expire(shard): time jumps to the lease deadline and the reaper runs.
    for (i, slot) in slots.iter().enumerate() {
        if let SlotView::Leased { deadline, .. } = slot {
            let mut next = node.clone();
            next.now = node.now.max(*deadline);
            next.table.reap(next.now);
            out.push((Kind::Expire, format!("expire(shard {i}) at t={}", next.now), next));
        }
    }

    for (i, slot) in slots.iter().enumerate() {
        match slot {
            // submit-valid(w, shard): the lease holder delivers the
            // canonical result.
            SlotView::Leased { worker, .. } => {
                let w = ids.iter().position(|id| id == worker).unwrap_or(0);
                let mut next = node.clone();
                let res = next.ledger.offer(i, CANONICAL);
                if res.is_ok() {
                    next.table.complete(i);
                } else {
                    next.table.fail(&ids[w], next.now);
                }
                out.push((Kind::SubmitValid, format!("submit-valid({worker}, shard {i})"), next));
            }
            // stale-submit(shard): a reaped lease's original holder still
            // delivers a valid result (any valid result counts).
            SlotView::Pending { attempt } if *attempt >= 1 => {
                let mut next = node.clone();
                if next.ledger.offer(i, CANONICAL).is_ok() {
                    next.table.complete(i);
                }
                out.push((Kind::StaleSubmit, format!("stale-submit(shard {i})"), next));
            }
            // duplicate(shard): an identical resubmission of a merged
            // shard must be an idempotent no-op.
            SlotView::Done => {
                let mut next = node.clone();
                let res = next.ledger.offer(i, CANONICAL);
                next.table.complete(i);
                let label = format!("duplicate(shard {i}) -> {res:?}");
                out.push((Kind::Duplicate, label, next));
            }
            SlotView::Pending { .. } => {}
        }
    }

    // divergent-submit(w, shard) / corrupt-frame(w): rejected submissions
    // penalise the sender. Quarantined workers are skipped — the
    // coordinator already dropped their connections, and unbounded
    // post-quarantine penalties would make the space infinite.
    for w in 0..ids.len() {
        if quarantined(w) {
            continue;
        }
        for i in 0..node.ledger.shard_count() {
            if node.ledger.payload(i).is_none() {
                continue;
            }
            let mut next = node.clone();
            if spec.mutation == Mutation::QuarantineBypass {
                // Seeded bug: the divergent payload overwrites the merge
                // instead of being rejected.
                next.ledger.force_store(i, DIVERGENT);
                next.table.complete(i);
            } else if next.ledger.offer(i, DIVERGENT).is_err() {
                next.table.fail(&ids[w], next.now);
            }
            let label = format!("divergent-submit({}, shard {i})", ids[w]);
            out.push((Kind::Divergent, label, next));
        }
        let mut next = node.clone();
        next.table.fail(&ids[w], next.now);
        out.push((Kind::Corrupt, format!("corrupt-frame({})", ids[w]), next));
    }
    out
}

/// Check every safety invariant across one applied transition. Returns the
/// violated invariant and detail on failure.
fn verify_transition(
    pre: &Node,
    post: &Node,
    label: &str,
    spec: &ModelSpec,
) -> Result<(), (Invariant, String)> {
    // I2 no-lost-shard: per-slot legal transitions only.
    let pre_slots = pre.table.slot_views();
    let post_slots = post.table.slot_views();
    for (i, (a, b)) in pre_slots.iter().zip(post_slots.iter()).enumerate() {
        let legal = match (a, b) {
            _ if a == b => true,
            (SlotView::Pending { attempt: pa }, SlotView::Leased { attempt: la, .. }) => {
                *la == pa + 1
            }
            (SlotView::Pending { .. }, SlotView::Done) => true,
            (SlotView::Leased { attempt: la, deadline, .. }, SlotView::Pending { attempt: pa }) => {
                pa == la && *deadline <= post.now
            }
            (SlotView::Leased { .. }, SlotView::Done) => true,
            _ => false,
        };
        if !legal {
            return Err((
                Invariant::NoLostShard,
                format!("shard {i} moved illegally on {label}: {a:?} -> {b:?}"),
            ));
        }
    }

    // I3 quarantine-respected (grant side): encoded in the label because
    // the grant outcome is part of it — a quarantined worker whose request
    // produced anything but `Quarantined` leased or waited illegally.
    if label.starts_with("request(") {
        let wid = label.trim_start_matches("request(").split(')').next().unwrap_or("");
        let was_quarantined =
            pre.table.worker_views().iter().any(|v| v.id == wid && v.quarantined);
        if was_quarantined && !label.ends_with("-> Quarantined") {
            return Err((
                Invariant::QuarantineRespected,
                format!("quarantined worker {wid} was granted work: {label}"),
            ));
        }
    }

    // I1 / I3 (merge side): every stored payload must be the canonical
    // bytes — a divergent payload in the ledger is a forged merge.
    for i in 0..post.ledger.shard_count() {
        if let Some(p) = post.ledger.payload(i) {
            if p != CANONICAL {
                return Err((
                    Invariant::MergeConsistent,
                    format!("shard {i} holds non-canonical bytes after {label}"),
                ));
            }
        }
        // Immutability: a stored payload never changes identity.
        if pre.ledger.payload(i).is_some() && post.ledger.payload(i) != pre.ledger.payload(i) {
            return Err((
                Invariant::MergeConsistent,
                format!("shard {i}'s merged payload changed on {label}"),
            ));
        }
    }
    if label.starts_with("duplicate(") && !label.ends_with("-> Ok(Duplicate)") {
        return Err((
            Invariant::MergeConsistent,
            format!("identical resubmission was not idempotent: {label}"),
        ));
    }

    // I4 backoff-monotone: failure streaks never reset while the sweep
    // runs, and each new penalty's backoff sits inside the deterministic
    // jitter window `[base << min(f-1, 6), +base)` — whose floor is
    // therefore non-decreasing along the streak.
    let base = spec.policy.backoff_base_ms.max(1);
    let pre_workers = pre.table.worker_views();
    for view in post.table.worker_views() {
        let failures_before =
            pre_workers.iter().find(|v| v.id == view.id).map_or(0, |v| v.failures);
        if view.failures < failures_before {
            return Err((
                Invariant::BackoffMonotone,
                format!("worker {}'s failure streak reset on {label}", view.id),
            ));
        }
        if view.failures == failures_before || view.quarantined {
            continue;
        }
        let floor = base << (view.failures - 1).min(6);
        let duration = view.backoff_until.saturating_sub(post.now);
        if duration < floor || duration >= floor + base {
            return Err((
                Invariant::BackoffMonotone,
                format!(
                    "worker {} backoff {duration} ms outside [{floor}, {}) after {label}",
                    view.id,
                    floor + base
                ),
            ));
        }
        if failures_before > 0 {
            let prev_floor = base << (failures_before - 1).min(6);
            if floor < prev_floor {
                return Err((
                    Invariant::BackoffMonotone,
                    format!("worker {} backoff floor shrank to {floor} ms on {label}", view.id),
                ));
            }
        }
    }
    Ok(())
}

/// A node with no state-changing successor must be a sanctioned outcome:
/// all shards merged, or every worker quarantined with the remaining
/// shards never computed (the typed `Incomplete`).
fn classify_terminal(report: &mut ModelReport, recs: &[Rec], at: usize) {
    let node = &recs[at].node;
    if node.table.all_done() {
        report.all_done_terminals += 1;
    } else if node.table.quarantined() == node.table.workers() {
        report.incomplete_terminals += 1;
    } else {
        report.violations.push(render_violation(
            Invariant::BoundedTermination,
            format!(
                "stuck state: {}/{} shards done, {}/{} workers quarantined, no progress possible",
                node.table.completed(),
                report.shards,
                node.table.quarantined(),
                node.table.workers()
            ),
            recs,
            at,
            Kind::Request,
            "(terminal)",
        ));
    }
}

/// Build the violation record: the parent-chain trace plus the violating
/// event, and the `FaultPlan` string that re-triggers the failure class.
fn render_violation(
    invariant: Invariant,
    detail: String,
    recs: &[Rec],
    at: usize,
    kind: Kind,
    label: &str,
) -> Violation {
    let mut trace = Vec::new();
    let mut kinds = Vec::new();
    let mut cursor = at;
    while cursor != usize::MAX {
        if recs[cursor].parent != usize::MAX {
            trace.push(recs[cursor].label.clone());
            kinds.push(recs[cursor].kind);
        }
        cursor = recs[cursor].parent;
    }
    trace.reverse();
    kinds.reverse();
    if label != "(terminal)" {
        trace.push(label.to_string());
        kinds.push(kind);
    }
    Violation { invariant, detail, trace, fault_plan: fault_plan(&kinds) }
}

/// Map a counterexample's event kinds onto the fault injector's alphabet.
/// This is a dynamic *trigger* for the same failure class, not a literal
/// transcript: an expiry is what `stall` provokes, a divergent/corrupt
/// submission is what `corrupt:2` (the first post-register frame) forges
/// on the wire, and a duplicate is literally `dup`. A trace with no
/// fault-shaped event (e.g. pure double-grant request interleavings) maps
/// to `stall` — the trigger that makes two workers hold one shard.
fn fault_plan(kinds: &[Kind]) -> String {
    let mut tokens: Vec<String> = Vec::new();
    let mut push = |t: String| {
        if !tokens.contains(&t) {
            tokens.push(t);
        }
    };
    for kind in kinds {
        match kind {
            Kind::Expire => push("stall".to_string()),
            Kind::Divergent | Kind::Corrupt => push("corrupt:2".to_string()),
            Kind::Duplicate | Kind::StaleSubmit => push("dup".to_string()),
            Kind::Request | Kind::SubmitValid => {}
        }
    }
    if tokens.is_empty() {
        tokens.push("stall".to_string());
    }
    tokens.join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_small_space_proves_everything() {
        let spec = ModelSpec { shards: 2, workers: 1, ..ModelSpec::default() };
        let report = check(&spec);
        assert!(report.exhausted, "frontier must empty: {report}");
        assert!(report.violations.is_empty(), "{report}");
        assert!(report.all_done_terminals >= 1, "{report}");
        assert!(report.incomplete_terminals >= 1, "quarantine dead-end must exist: {report}");
    }

    #[test]
    fn double_grant_mutant_is_caught() {
        let spec = ModelSpec {
            shards: 2,
            workers: 2,
            mutation: Mutation::DoubleGrant,
            ..Default::default()
        };
        let report = check(&spec);
        let v = report.violations.first().expect("double-grant must be caught");
        assert_eq!(v.invariant, Invariant::NoLostShard, "{report}");
        assert!(!v.trace.is_empty());
        assert!(!v.fault_plan.is_empty());
    }

    #[test]
    fn quarantine_bypass_mutant_is_caught() {
        let spec = ModelSpec {
            shards: 1,
            workers: 1,
            mutation: Mutation::QuarantineBypass,
            ..Default::default()
        };
        let report = check(&spec);
        let v = report.violations.first().expect("quarantine-bypass must be caught");
        assert_eq!(v.invariant, Invariant::MergeConsistent, "{report}");
        assert!(v.fault_plan.contains("corrupt"), "plan {:?}", v.fault_plan);
    }

    #[test]
    fn mutant_spellings_parse() {
        assert_eq!("double-grant".parse::<Mutation>(), Ok(Mutation::DoubleGrant));
        assert_eq!("quarantine-bypass".parse::<Mutation>(), Ok(Mutation::QuarantineBypass));
        assert!("explode".parse::<Mutation>().is_err());
    }
}
