//! Static analysis and verification of the simulator itself (`maple vet`).
//!
//! Everything this repro promises rests on bit-exact determinism: sharded
//! merges, `MAPLESHD`/`MAPLEEVL` artifacts, warm-cache replays, and the
//! chaos tests all assert byte-identity. This module is the layer that
//! keeps future changes honest *before* they run:
//!
//! - [`lint`] — a token-level determinism lint over `src/**` enforcing the
//!   repo contract as a typed [`rules::Rule`] taxonomy with `file:line`
//!   findings and a linted `// vet:allow(rule): reason` escape hatch.
//! - [`model`] — a bounded model checker that drives the real
//!   [`crate::sim::service::LeaseTable`] and ledger slot machine through
//!   every abstract interleaving, proving the lease-protocol safety
//!   invariants the fault injector's finite plans only sample, and
//!   rendering each violation as a `FaultPlan` string `run_chaos` replays.
//!
//! Std-only, like the rest of the crate.

pub mod lint;
pub mod model;
pub mod rules;

pub use lint::{lint_path, lint_source, Finding, LintReport};
pub use model::{check, Invariant, ModelReport, ModelSpec, Mutation, Violation};
pub use rules::{Rule, RULES};
