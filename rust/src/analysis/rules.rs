//! The determinism-lint rule taxonomy.
//!
//! Every rule is a typed [`Rule`] with a stable kebab-case id (the spelling
//! used in findings and in `vet:allow(<id>)` pragmas), a one-line summary,
//! and a path scope — the crate-relative source paths it applies to. The
//! scopes encode the repo's determinism contract rather than a generic
//! style guide: wall-clock belongs in the service layer and the CLI (it
//! feeds `ShardMeta`/bench telemetry, which the canonical-bytes comparison
//! zeroes), accounting paths in `energy/` and `accel/` must not narrow
//! numeric types, and anything that emits ordered output must not iterate a
//! hash map.

/// One lint rule. Ordered so findings sort deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `HashMap`/`HashSet` anywhere in the crate: iteration order is
    /// nondeterministic (RandomState), which poisons every ordered-emission
    /// site downstream. Use `BTreeMap`/`BTreeSet` or sort explicitly.
    HashIter,
    /// `Instant::now`/`SystemTime` outside the allowlisted timing modules
    /// (`sim/service/` and the CLI): wall-clock must never feed cycle or
    /// energy accounting.
    WallClock,
    /// Narrowing `as` casts in `energy/`/`accel/` accounting paths
    /// (`as f32`/`as u32`/...): silent precision loss in the paper-facing
    /// numbers. Widening to `f64`/`u64` stays legal.
    LossyCast,
    /// `thread::spawn` in `sim/` code: an unscoped thread can outlive the
    /// sweep that spawned it. Use `thread::scope` or justify the join
    /// discipline with a pragma.
    UnscopedThread,
    /// A malformed `// vet:allow(rule): reason` pragma — unknown rule id,
    /// missing `(`/`)`/`:`, or an empty reason. The escape hatch itself is
    /// linted so suppressions always carry a justification.
    PragmaReason,
}

/// Every rule, in reporting order.
pub const RULES: [Rule; 5] = [
    Rule::HashIter,
    Rule::WallClock,
    Rule::LossyCast,
    Rule::UnscopedThread,
    Rule::PragmaReason,
];

impl Rule {
    /// Stable kebab-case id: the finding label and the pragma spelling.
    pub fn id(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::LossyCast => "lossy-cast",
            Rule::UnscopedThread => "unscoped-thread",
            Rule::PragmaReason => "pragma-reason",
        }
    }

    /// One-line summary (the README rule table and `--help` text).
    pub fn summary(self) -> &'static str {
        match self {
            Rule::HashIter => {
                "HashMap/HashSet iteration order is nondeterministic; use BTreeMap or sort"
            }
            Rule::WallClock => {
                "Instant::now/SystemTime outside sim/service/ and the CLI timing layer"
            }
            Rule::LossyCast => "narrowing `as` cast in an energy/accel accounting path",
            Rule::UnscopedThread => "thread::spawn in sim code (prefer thread::scope)",
            Rule::PragmaReason => "vet:allow pragma without a known rule id and non-empty reason",
        }
    }

    /// Parse a pragma/CLI spelling back to the rule.
    pub fn from_id(id: &str) -> Option<Rule> {
        RULES.iter().copied().find(|r| r.id() == id)
    }

    /// Does this rule apply to the crate-relative source path (`/`-separated,
    /// e.g. `sim/service/lease.rs`)? Paths outside a rule's scope are
    /// allowlisted by construction, not by pragma.
    pub fn applies_to(self, path: &str) -> bool {
        match self {
            Rule::HashIter | Rule::PragmaReason => true,
            Rule::WallClock => !(path.starts_with("sim/service/") || path == "main.rs"),
            Rule::LossyCast => path.starts_with("energy/") || path.starts_with("accel/"),
            Rule::UnscopedThread => path.starts_with("sim/"),
        }
    }
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_round_trip() {
        for rule in RULES {
            assert_eq!(Rule::from_id(rule.id()), Some(rule));
        }
        assert_eq!(Rule::from_id("bogus"), None);
    }

    #[test]
    fn scopes_encode_the_contract() {
        assert!(Rule::HashIter.applies_to("report.rs"));
        assert!(Rule::WallClock.applies_to("sim/engine.rs"));
        assert!(!Rule::WallClock.applies_to("sim/service/coordinator.rs"));
        assert!(!Rule::WallClock.applies_to("main.rs"));
        assert!(Rule::LossyCast.applies_to("energy/tech45.rs"));
        assert!(!Rule::LossyCast.applies_to("noc/mod.rs"));
        assert!(Rule::UnscopedThread.applies_to("sim/service/coordinator.rs"));
        assert!(!Rule::UnscopedThread.applies_to("report.rs"));
    }
}
