//! The determinism lint: a token-level scanner over the crate sources.
//!
//! [`lint_source`] lexes one Rust file just far enough to be sound about
//! *where code is* — line and nested block comments, string and raw-string
//! literals, and char-vs-lifetime `'` disambiguation are all handled, so a
//! `HashMap` inside a doc comment or a test fixture string never fires —
//! then matches the token stream against the [`Rule`] taxonomy.
//! [`lint_path`] walks a source tree in sorted order and aggregates, so two
//! runs over the same tree emit byte-identical reports.
//!
//! The escape hatch is an inline pragma on the flagged line or the line
//! directly above it:
//!
//! ```text
//! // vet:allow(wall-clock): wall time lands only in volatile ShardMeta stats
//! let start = Instant::now();
//! ```
//!
//! The pragma is itself linted ([`Rule::PragmaReason`]): an unknown rule id
//! or an empty reason is a finding, and `pragma-reason` findings cannot be
//! pragma-suppressed.

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

use super::rules::Rule;

/// One lint hit, anchored to a crate-relative path and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

/// The lint result for one file.
#[derive(Debug, Clone, Default)]
pub struct FileLint {
    pub findings: Vec<Finding>,
    /// Findings silenced by a valid `vet:allow` pragma.
    pub suppressed: usize,
}

/// The lint result for a source tree: what `maple vet` prints.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    pub files: usize,
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

impl fmt::Display for LintReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for finding in &self.findings {
            writeln!(f, "{finding}")?;
        }
        writeln!(
            f,
            "vet lint: {} files scanned, {} finding(s), {} suppressed by pragma",
            self.files,
            self.findings.len(),
            self.suppressed
        )
    }
}

// ------------------------------------------------------------------- lexer

/// One surviving token: an identifier/number word or a punctuation run we
/// care about (`::` is kept as a single token so `Instant::now` is a
/// three-token pattern).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Tok {
    line: usize,
    text: String,
}

/// One `//` line comment, with the leading slashes stripped.
#[derive(Debug, Clone, PartialEq, Eq)]
struct LineComment {
    line: usize,
    text: String,
}

/// Lex just enough Rust: returns the code tokens and the line comments
/// (pragma carriers). Everything inside strings, char literals, and block
/// comments is skipped; lifetimes are skipped (they are not identifiers a
/// rule could match against anyway).
fn lex(source: &str) -> (Vec<Tok>, Vec<LineComment>) {
    let chars: Vec<char> = source.chars().collect();
    let mut tokens = Vec::new();
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let n = chars.len();
    let at = |i: usize| chars.get(i).copied().unwrap_or('\0');
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c == '/' && at(i + 1) == '/' {
            // Line comment (incl. doc comments): capture to end of line.
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[start..j].iter().collect();
            comments.push(LineComment { line, text });
            i = j;
        } else if c == '/' && at(i + 1) == '*' {
            // Nested block comment: skip, tracking newlines.
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && at(j + 1) == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && at(j + 1) == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
        } else if c == '"' {
            i = skip_string(&chars, i, &mut line);
        } else if c == '\'' {
            // Lifetime (`'a`, `'static`, `'_`) vs char literal (`'x'`,
            // `'\n'`): a lifetime is `'` + ident with no closing quote.
            let c1 = at(i + 1);
            if (c1.is_alphanumeric() || c1 == '_') && c1 != '\\' && at(i + 2) != '\'' {
                i += 2;
                while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            } else {
                // Char literal: skip to the closing quote, honouring escapes.
                let mut j = i + 1;
                while j < n && chars[j] != '\'' {
                    if chars[j] == '\\' {
                        j += 1;
                    } else if chars[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = j + 1;
            }
        } else if c.is_alphanumeric() || c == '_' {
            let start = i;
            while i < n && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let word: String = chars[start..i].iter().collect();
            // Raw string (`r"…"`, `br#"…"#`) or byte string (`b"…"`)
            // immediately following the prefix word.
            if (word == "r" || word == "br") && (at(i) == '"' || at(i) == '#') {
                let mut hashes = 0usize;
                while at(i + hashes) == '#' {
                    hashes += 1;
                }
                if at(i + hashes) == '"' {
                    i = skip_raw_string(&chars, i + hashes + 1, hashes, &mut line);
                    continue;
                }
                // `r#ident` raw identifier: fall through, keep the word.
            }
            if word == "b" && at(i) == '"' {
                i = skip_string(&chars, i, &mut line);
                continue;
            }
            tokens.push(Tok { line, text: word });
        } else if c == ':' && at(i + 1) == ':' {
            tokens.push(Tok { line, text: "::".to_string() });
            i += 2;
        } else {
            tokens.push(Tok { line, text: c.to_string() });
            i += 1;
        }
    }
    (tokens, comments)
}

/// Skip a `"…"` literal starting at the opening quote; returns the index
/// past the closing quote.
fn skip_string(chars: &[char], open: usize, line: &mut usize) -> usize {
    let mut j = open + 1;
    while j < chars.len() {
        match chars[j] {
            '\\' => j += 2,
            '\n' => {
                *line += 1;
                j += 1;
            }
            '"' => return j + 1,
            _ => j += 1,
        }
    }
    j
}

/// Skip a raw string whose opening `"` is at `body - 1` with `hashes`
/// leading `#`s; returns the index past the closing `"##…`.
fn skip_raw_string(chars: &[char], body: usize, hashes: usize, line: &mut usize) -> usize {
    let n = chars.len();
    let mut j = body;
    while j < n {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if chars[j] == '"' && (1..=hashes).all(|k| chars.get(j + k) == Some(&'#')) {
            return j + 1 + hashes;
        }
        j += 1;
    }
    j
}

// ----------------------------------------------------------------- pragmas

/// A parsed, valid `vet:allow(rule): reason` pragma.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Pragma {
    line: usize,
    rule: Rule,
}

const PRAGMA_PREFIX: &str = "vet:allow";

/// Split the line comments into valid pragmas and `pragma-reason` findings.
fn parse_pragmas(path: &str, comments: &[LineComment]) -> (Vec<Pragma>, Vec<Finding>) {
    let mut pragmas = Vec::new();
    let mut findings = Vec::new();
    for c in comments {
        // Doc comments arrive as `/ …`/`! …`; strip the markers so the
        // prefix check sees the payload.
        let text = c.text.trim_start_matches(['/', '!']).trim();
        if !text.starts_with(PRAGMA_PREFIX) {
            continue;
        }
        let mut reject = |message: String| {
            findings.push(Finding {
                file: path.to_string(),
                line: c.line,
                rule: Rule::PragmaReason,
                message,
            });
        };
        let rest = &text[PRAGMA_PREFIX.len()..];
        let Some(rest) = rest.strip_prefix('(') else {
            reject(format!("malformed pragma {text:?}: expected vet:allow(rule): reason"));
            continue;
        };
        let Some(close) = rest.find(')') else {
            reject(format!("malformed pragma {text:?}: missing closing ')'"));
            continue;
        };
        let (id, tail) = (&rest[..close], &rest[close + 1..]);
        let Some(rule) = Rule::from_id(id.trim()) else {
            reject(format!("unknown rule {:?} in pragma (known: {})", id.trim(), known_ids()));
            continue;
        };
        let Some(reason) = tail.trim_start().strip_prefix(':') else {
            reject(format!("pragma for {rule} is missing the `: reason` tail"));
            continue;
        };
        if reason.trim().is_empty() {
            reject(format!("pragma for {rule} has an empty reason — justify the suppression"));
            continue;
        }
        pragmas.push(Pragma { line: c.line, rule });
    }
    (pragmas, findings)
}

fn known_ids() -> String {
    super::rules::RULES.iter().map(|r| r.id()).collect::<Vec<_>>().join(", ")
}

// ------------------------------------------------------------------ linter

/// Narrowing `as`-cast targets the lossy-cast rule rejects in accounting
/// paths. Widening (`as f64`, `as u64`, `as u128`, `as usize`) stays legal:
/// every counter in the crate is bounded far below 2^53.
const NARROW_TARGETS: [&str; 7] = ["f32", "u32", "i32", "u16", "i16", "u8", "i8"];

/// Lint one file's source. `path` is the crate-relative `/`-separated path
/// (it drives rule scoping); determinism: output order depends only on the
/// source text.
pub fn lint_source(path: &str, source: &str) -> FileLint {
    let (tokens, comments) = lex(source);
    let (pragmas, mut findings) = parse_pragmas(path, &comments);

    let mut raw: Vec<(usize, Rule, String)> = Vec::new();
    let mut hit = |line: usize, rule: Rule, message: String| {
        if rule.applies_to(path) && !raw.iter().any(|(l, r, _)| *l == line && *r == rule) {
            raw.push((line, rule, message));
        }
    };
    let word = |i: usize| tokens.get(i).map(|t| t.text.as_str()).unwrap_or("");
    for (i, tok) in tokens.iter().enumerate() {
        match tok.text.as_str() {
            "HashMap" | "HashSet" => hit(
                tok.line,
                Rule::HashIter,
                format!("{} iteration order is nondeterministic; use a BTree or sort", tok.text),
            ),
            "Instant" if word(i + 1) == "::" && word(i + 2) == "now" => hit(
                tok.line,
                Rule::WallClock,
                "Instant::now() outside the allowlisted timing layer (sim/service/, main.rs)"
                    .to_string(),
            ),
            "SystemTime" => hit(
                tok.line,
                Rule::WallClock,
                "SystemTime outside the allowlisted timing layer (sim/service/, main.rs)"
                    .to_string(),
            ),
            "thread" if word(i + 1) == "::" && word(i + 2) == "spawn" => hit(
                tok.line,
                Rule::UnscopedThread,
                "unscoped thread::spawn in sim code; use thread::scope or justify the join"
                    .to_string(),
            ),
            "as" if NARROW_TARGETS.contains(&word(i + 1)) => hit(
                tok.line,
                Rule::LossyCast,
                format!("narrowing cast `as {}` in an accounting path", word(i + 1)),
            ),
            _ => {}
        }
    }

    // A valid pragma suppresses findings of its rule on its own line and
    // the line directly below. `pragma-reason` findings are exempt: the
    // escape hatch cannot excuse itself.
    let mut suppressed = 0usize;
    for (line, rule, message) in raw {
        let covered = pragmas
            .iter()
            .any(|p| p.rule == rule && (p.line == line || p.line + 1 == line));
        if covered {
            suppressed += 1;
        } else {
            findings.push(Finding { file: path.to_string(), line, rule, message });
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    FileLint { findings, suppressed }
}

/// Recursively collect `*.rs` files under `root`, sorted by path — the
/// determinism anchor for the whole report.
fn rust_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut files = Vec::new();
    let mut dirs = vec![root.to_path_buf()];
    while let Some(dir) = dirs.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                dirs.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                files.push(path);
            }
        }
    }
    files.sort();
    Ok(files)
}

/// Lint every `*.rs` file under `root` (the crate `src/` directory).
/// Findings are sorted by (file, line, rule); two runs over the same tree
/// render byte-identical reports.
pub fn lint_path(root: &Path) -> io::Result<LintReport> {
    let files = rust_files(root)?;
    let mut report = LintReport { files: files.len(), ..LintReport::default() };
    for file in &files {
        let rel: String = file
            .strip_prefix(root)
            .unwrap_or(file)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let source = std::fs::read_to_string(file)?;
        let mut lint = lint_source(&rel, &source);
        report.findings.append(&mut lint.findings);
        report.suppressed += lint.suppressed;
    }
    report.findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(lint: &FileLint) -> Vec<Rule> {
        lint.findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn hash_iter_positive_hit() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u8, u8> = HashMap::new(); }\n";
        let lint = lint_source("sim/engine.rs", src);
        assert_eq!(rules_of(&lint), vec![Rule::HashIter, Rule::HashIter]);
        assert_eq!(lint.findings[0].line, 1);
        assert_eq!(lint.findings[1].line, 2, "one finding per (line, rule)");
    }

    #[test]
    fn pragma_suppresses_line_below_and_same_line() {
        let src = "// vet:allow(hash-iter): scratch map, drained into a sorted Vec\n\
                   use std::collections::HashMap;\n\
                   type T = std::collections::HashSet<u8>; // vet:allow(hash-iter): membership only\n";
        let lint = lint_source("report.rs", src);
        assert!(lint.findings.is_empty(), "{:?}", lint.findings);
        assert_eq!(lint.suppressed, 2);
    }

    #[test]
    fn pragma_for_the_wrong_rule_does_not_suppress() {
        let src = "// vet:allow(wall-clock): not the rule that fires below\n\
                   use std::collections::HashMap;\n";
        let lint = lint_source("report.rs", src);
        assert_eq!(rules_of(&lint), vec![Rule::HashIter]);
        assert_eq!(lint.suppressed, 0);
    }

    #[test]
    fn wall_clock_respects_the_allowlist() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules_of(&lint_source("sim/engine.rs", src)), vec![Rule::WallClock]);
        assert!(lint_source("sim/service/worker.rs", src).findings.is_empty());
        assert!(lint_source("main.rs", src).findings.is_empty());
        let sys = "fn f() -> std::time::SystemTime { std::time::SystemTime::now() }\n";
        assert_eq!(rules_of(&lint_source("energy/mod.rs", sys)), vec![Rule::WallClock]);
    }

    #[test]
    fn lossy_cast_scoped_to_accounting_paths() {
        let src = "fn f(x: u64) -> u32 { x as u32 }\nfn g(x: u32) -> f64 { x as f64 }\n";
        let lint = lint_source("energy/tech45.rs", src);
        assert_eq!(rules_of(&lint), vec![Rule::LossyCast]);
        assert_eq!(lint.findings[0].line, 1, "widening `as f64` stays legal");
        assert!(lint_source("noc/mod.rs", src).findings.is_empty(), "out of scope");
    }

    #[test]
    fn unscoped_thread_scoped_to_sim() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_of(&lint_source("sim/service/coordinator.rs", src)), vec![
            Rule::UnscopedThread
        ]);
        assert!(lint_source("report.rs", src).findings.is_empty());
        let scoped = "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_source("sim/engine.rs", scoped).findings.is_empty(), "scoped is fine");
    }

    #[test]
    fn pragma_without_reason_is_rejected() {
        for bad in [
            "// vet:allow(hash-iter):\nuse std::collections::HashMap;\n",
            "// vet:allow(hash-iter)\nuse std::collections::HashMap;\n",
            "// vet:allow(bogus-rule): because\nuse std::collections::HashMap;\n",
            "// vet:allow hash-iter: because\nuse std::collections::HashMap;\n",
        ] {
            let lint = lint_source("report.rs", bad);
            assert_eq!(
                rules_of(&lint),
                vec![Rule::PragmaReason, Rule::HashIter],
                "a broken pragma must both fire pragma-reason and fail to suppress: {bad:?}"
            );
        }
    }

    #[test]
    fn comments_strings_and_raw_strings_never_fire() {
        let src = "// HashMap in a comment\n\
                   /* Instant::now() in a /* nested */ block */\n\
                   fn f() { let s = \"HashMap and Instant::now()\"; }\n";
        assert!(lint_source("sim/engine.rs", src).findings.is_empty());
        let raw = "fn f() { let s = r#\"use std::collections::HashMap; \"quoted\" \"#; }\n";
        assert!(lint_source("sim/engine.rs", raw).findings.is_empty());
    }

    #[test]
    fn lifetimes_and_char_literals_lex_cleanly() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '\\n'; let d = 'x'; c.max(d) }\n\
                   fn g() { let m: std::collections::HashMap<u8, u8> = Default::default(); }\n";
        let lint = lint_source("sim/engine.rs", src);
        assert_eq!(rules_of(&lint), vec![Rule::HashIter], "lexer must survive to line 2");
        assert_eq!(lint.findings[0].line, 2);
    }

    #[test]
    fn identifier_boundaries_are_respected() {
        // `Instantiate` contains `Instant` but is one identifier token.
        let src = "fn instantiate() {} struct Instantiate; type HashMapLike = u8;\n";
        assert!(lint_source("sim/engine.rs", src).findings.is_empty());
    }

    #[test]
    fn reports_are_deterministic() {
        let src = "use std::collections::{HashMap, HashSet};\nfn f() { let _ = 1u64 as u32; }\n";
        let a = lint_source("energy/mod.rs", src);
        let b = lint_source("energy/mod.rs", src);
        assert_eq!(a.findings, b.findings);
    }
}
