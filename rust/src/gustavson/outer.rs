//! Outer-product dataflow baseline (paper §I: "maximizes the input matrix
//! reuse and sacrifices output matrix reuse... suffers from merging large
//! partial output matrices", cf. OuterSPACE).
//!
//! `C = Σ_k A[:,k] ⊗ B[k,:]` — each k produces a rank-1 partial matrix; all
//! of them must be merged, which is the data-movement cost the row-wise
//! product avoids.

use crate::sparse::{Coo, Csr};

/// `C = A × B` by outer product: generate all rank-1 partial products, then
/// merge. Exposes the partial-matrix volume via [`outer_partial_nnz`].
pub fn spgemm_outer(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let at = a.to_csc();
    let mut partials = Coo::zero(a.rows(), b.cols());
    for k in 0..a.cols() {
        for (i, av) in at.col_iter(k) {
            for (j, bv) in b.row_iter(k) {
                partials.push(i, j, av * bv);
            }
        }
    }
    // The merge phase: COO -> CSR with duplicate folding is exactly the
    // "merging large partial output matrices" step.
    partials.to_csr()
}

/// Total partial-product entries the outer-product dataflow materialises
/// before merging (its memory-traffic Achilles heel).
pub fn outer_partial_nnz(a: &Csr, b: &Csr) -> u64 {
    assert_eq!(a.cols(), b.rows());
    let at = a.to_csc();
    (0..a.cols()).map(|k| at.col_nnz(k) as u64 * b.row_nnz(k) as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gustavson::{dense_matmul, max_abs_diff, multiply_count};
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn matches_dense() {
        let a = generate(14, 10, 35, Profile::Uniform, 41);
        let b = generate(10, 16, 45, Profile::Uniform, 42);
        let c = spgemm_outer(&a, &b);
        assert!(max_abs_diff(&c, &dense_matmul(&a, &b)) < 1e-4);
    }

    #[test]
    fn partial_volume_equals_multiply_count() {
        // Outer and row-wise products perform the same multiplications;
        // they differ in *where* partial sums live. The counts must agree.
        let a = generate(20, 20, 80, Profile::Uniform, 51);
        assert_eq!(outer_partial_nnz(&a, &a), multiply_count(&a, &a));
    }

    #[test]
    fn rank_one_case() {
        // A = e0 column, B = single row -> C is that row scaled.
        let a = Csr::from_triplets(3, 1, vec![(0, 0, 2.0), (2, 0, -1.0)]);
        let b = Csr::from_triplets(1, 3, vec![(0, 0, 1.0), (0, 2, 4.0)]);
        let c = spgemm_outer(&a, &b);
        assert_eq!(c.get(0, 0), 2.0);
        assert_eq!(c.get(0, 2), 8.0);
        assert_eq!(c.get(2, 0), -1.0);
        assert_eq!(c.get(2, 2), -4.0);
        assert_eq!(c.nnz(), 4);
    }
}
