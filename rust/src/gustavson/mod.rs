//! Software reference SpGEMM implementations — the numeric oracles for every
//! accelerator model, plus the three dataflow strategies the paper contrasts
//! in §I (inner-product, outer-product, row-wise product / Gustavson).

mod inner;
mod outer;
mod rowwise;

pub use inner::{intersect_count, spgemm_inner};
pub use outer::{outer_partial_nnz, spgemm_outer};
pub use rowwise::{spgemm_rowwise, RowwiseScratch};

use crate::sparse::Csr;

/// Number of scalar multiplications Gustavson's algorithm performs for
/// `A × B`: for every stored `A[i,k]` one multiply per stored element of
/// `B[k,:]` (paper Eq. 3). This is the accelerator-independent work metric
/// every cycle/energy model is built on.
pub fn multiply_count(a: &Csr, b: &Csr) -> u64 {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let mut n = 0u64;
    for k in a.col_id.iter() {
        n += b.row_nnz(*k as usize) as u64;
    }
    n
}

/// Per-row multiply counts — the per-output-row work distribution used by
/// the coordinator's load balancer.
pub fn row_multiply_counts(a: &Csr, b: &Csr) -> Vec<u64> {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    (0..a.rows())
        .map(|i| a.row_cols(i).iter().map(|&k| b.row_nnz(k as usize) as u64).sum())
        .collect()
}

/// Dense matmul oracle (only for small test matrices).
pub fn dense_matmul(a: &Csr, b: &Csr) -> Vec<Vec<f32>> {
    assert_eq!(a.cols(), b.rows());
    let (m, n, k) = (a.rows(), b.cols(), a.cols());
    let da = a.to_dense();
    let db = b.to_dense();
    let mut c = vec![vec![0f32; n]; m];
    for i in 0..m {
        for p in 0..k {
            let av = da[i][p];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c[i][j] += av * db[p][j];
            }
        }
    }
    c
}

/// Max |x - y| over two sparse matrices (as dense); test helper.
pub fn max_abs_diff(x: &Csr, dense: &[Vec<f32>]) -> f32 {
    let dx = x.to_dense();
    let mut m = 0f32;
    for i in 0..dx.len() {
        for j in 0..dx[i].len() {
            m = m.max((dx[i][j] - dense[i][j]).abs());
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Profile};

    fn small_pair() -> (Csr, Csr) {
        let a = generate(12, 10, 30, Profile::Uniform, 21);
        let b = generate(10, 14, 40, Profile::Uniform, 22);
        (a, b)
    }

    #[test]
    fn all_three_dataflows_agree_with_dense() {
        let (a, b) = small_pair();
        let oracle = dense_matmul(&a, &b);
        for (name, c) in [
            ("rowwise", spgemm_rowwise(&a, &b)),
            ("inner", spgemm_inner(&a, &b)),
            ("outer", spgemm_outer(&a, &b)),
        ] {
            assert!(max_abs_diff(&c, &oracle) < 1e-4, "{name} diverges from dense oracle");
        }
    }

    #[test]
    fn multiply_count_matches_manual() {
        // A row 0 references B rows {1, 2}; counts add up per Eq. (3).
        let a = Csr::from_triplets(2, 3, vec![(0, 1, 1.0), (0, 2, 1.0), (1, 0, 1.0)]);
        let b = Csr::from_triplets(
            3,
            3,
            vec![(0, 0, 1.0), (1, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)],
        );
        // row0: nnz(B[1,:]) + nnz(B[2,:]) = 2 + 1 = 3; row1: nnz(B[0,:]) = 1
        assert_eq!(multiply_count(&a, &b), 4);
        assert_eq!(row_multiply_counts(&a, &b), vec![3, 1]);
    }

    #[test]
    fn identity_is_neutral() {
        let (a, _) = small_pair();
        let i = Csr::identity(a.cols());
        let c = spgemm_rowwise(&a, &i);
        assert_eq!(c, a);
    }

    #[test]
    fn multiply_count_equals_flops_of_rowwise() {
        let (a, b) = small_pair();
        // Count multiplications by instrumenting the dense algorithm.
        let mut manual = 0u64;
        for i in 0..a.rows() {
            for &k in a.row_cols(i) {
                manual += b.row_nnz(k as usize) as u64;
            }
        }
        assert_eq!(multiply_count(&a, &b), manual);
    }
}
