//! Row-wise product (Gustavson's algorithm), paper §III Eqs. (1)–(7).
//!
//! `C[i,:] = Σ_k A[i,k] · B[k,:]` — each output row is formed by scaling and
//! merging the B-rows selected by row i's nonzero columns
//! (`k' ← A.col_id[i]`, Eq. 4). The merge uses a sparse accumulator (SPA):
//! a dense value array with generation tags, so clearing is O(1) per row.

use crate::sparse::Csr;

/// Reusable sparse-accumulator scratch space, sized to `b.cols()`.
///
/// Allocated once and reused across rows (and across calls), which keeps the
/// hot loop allocation-free — the same discipline the hardware enforces with
/// its fixed PSB register file.
pub struct RowwiseScratch {
    /// Interleaved (generation tag, accumulated value) per output column —
    /// one cache line per SPA touch (EXPERIMENTS.md §Perf).
    spa: Vec<(u32, f32)>,
    /// Touched output columns of the current row (unsorted).
    touched: Vec<u32>,
    generation: u32,
}

impl RowwiseScratch {
    /// Scratch for output width `cols`.
    pub fn new(cols: usize) -> Self {
        Self { spa: vec![(0, 0.0); cols], touched: Vec::with_capacity(256), generation: 0 }
    }

    /// Grow (never shrink) to accommodate `cols` output columns.
    pub fn ensure(&mut self, cols: usize) {
        if self.spa.len() < cols {
            self.spa.resize(cols, (0, 0.0));
        }
    }

    /// Compute one output row `C[i,:] = Σ A[i,k']·B[k',:]` into `(cols, vals)`,
    /// appending in sorted column order. Returns the row's nnz.
    pub fn compute_row(
        &mut self,
        a: &Csr,
        b: &Csr,
        i: usize,
        out_cols: &mut Vec<u32>,
        out_vals: &mut Vec<f32>,
    ) -> usize {
        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            // Tag wrap: reset tags once every 2^32 rows.
            self.spa.fill((0, 0.0));
            self.generation = 1;
        }
        let gen = self.generation;
        self.touched.clear();

        for (k, av) in a.row_iter(i) {
            let k = k as usize;
            let bc = b.row_cols(k);
            let bv = b.row_values(k);
            for p in 0..bc.len() {
                // SAFETY: p < bc.len() == bv.len(); col ids < cols by the
                // CSR invariant (Csr::try_new).
                let (j, v) = unsafe { (*bc.get_unchecked(p), *bv.get_unchecked(p)) };
                let cell = unsafe { self.spa.get_unchecked_mut(j as usize) };
                if cell.0 == gen {
                    cell.1 += av * v;
                } else {
                    *cell = (gen, av * v);
                    self.touched.push(j);
                }
            }
        }

        self.touched.sort_unstable();
        let start = out_cols.len();
        for &j in &self.touched {
            let v = self.spa[j as usize].1;
            // A partial sum that cancels to exactly 0.0 is still stored by
            // real accelerators; we follow suit.
            out_cols.push(j);
            out_vals.push(v);
        }
        out_cols.len() - start
    }
}

/// `C = A × B` by row-wise product. Allocates its own scratch; for repeated
/// calls reuse a [`RowwiseScratch`] via [`spgemm_rowwise_with`].
pub fn spgemm_rowwise(a: &Csr, b: &Csr) -> Csr {
    let mut scratch = RowwiseScratch::new(b.cols());
    spgemm_rowwise_with(a, b, &mut scratch)
}

/// `C = A × B` using caller-provided scratch.
pub fn spgemm_rowwise_with(a: &Csr, b: &Csr, scratch: &mut RowwiseScratch) -> Csr {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    scratch.ensure(b.cols());
    let mut row_ptr = Vec::with_capacity(a.rows() + 1);
    row_ptr.push(0);
    let mut col_id = Vec::new();
    let mut value = Vec::new();
    for i in 0..a.rows() {
        scratch.compute_row(a, b, i, &mut col_id, &mut value);
        row_ptr.push(col_id.len());
    }
    Csr::try_new(a.rows(), b.cols(), row_ptr, col_id, value).expect("rowwise produced invalid CSR")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gustavson::{dense_matmul, max_abs_diff};
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn paper_fig5_example() {
        // Fig. 5: A row 0 = {A[0,0]=y, A[0,2]=y'}, B rows 0 and 2 as drawn.
        // We use concrete numbers: A[0,0]=2, A[0,2]=3; B[0,0]=5, B[0,2]=7,
        // B[2,2]=11. Then C[0,0] = 10 and C[0,2] = 2*7 + 3*11 = 47 — the
        // "yellow + blue = green" accumulation of C^0[0,2] and C^2[0,2].
        let a = Csr::from_triplets(4, 4, vec![(0, 0, 2.0), (0, 2, 3.0)]);
        let b = Csr::from_triplets(4, 4, vec![(0, 0, 5.0), (0, 2, 7.0), (2, 2, 11.0)]);
        let c = spgemm_rowwise(&a, &b);
        assert_eq!(c.get(0, 0), 10.0);
        assert_eq!(c.get(0, 2), 47.0);
        assert_eq!(c.row_nnz(0), 2);
    }

    #[test]
    fn matches_dense_on_random_pairs() {
        for seed in 0..5 {
            let a = generate(20, 16, 60, Profile::Uniform, seed);
            let b = generate(16, 24, 80, Profile::Uniform, seed + 100);
            let c = spgemm_rowwise(&a, &b);
            assert!(max_abs_diff(&c, &dense_matmul(&a, &b)) < 1e-4);
        }
    }

    #[test]
    fn square_self_multiply_like_paper_workload() {
        // The paper evaluates C = A × A (§IV.A).
        let a = generate(30, 30, 90, Profile::PowerLaw { alpha: 0.7 }, 9);
        let c = spgemm_rowwise(&a, &a);
        assert!(max_abs_diff(&c, &dense_matmul(&a, &a)) < 1e-4);
    }

    #[test]
    fn scratch_reuse_is_clean_across_calls() {
        let mut s = RowwiseScratch::new(8);
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 1, 2.0)]);
        let i = Csr::identity(2);
        let c1 = spgemm_rowwise_with(&a, &i, &mut s);
        let c2 = spgemm_rowwise_with(&a, &i, &mut s);
        assert_eq!(c1, c2);
        assert_eq!(c1, a);
    }

    #[test]
    fn empty_rows_produce_empty_output_rows() {
        let a = Csr::from_triplets(3, 3, vec![(1, 0, 1.0)]);
        let b = Csr::identity(3);
        let c = spgemm_rowwise(&a, &b);
        assert_eq!(c.row_nnz(0), 0);
        assert_eq!(c.row_nnz(1), 1);
        assert_eq!(c.row_nnz(2), 0);
    }

    #[test]
    fn output_columns_are_sorted() {
        let a = generate(40, 40, 200, Profile::Uniform, 77);
        let c = spgemm_rowwise(&a, &a);
        for i in 0..c.rows() {
            let cols = c.row_cols(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
