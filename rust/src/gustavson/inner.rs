//! Inner-product dataflow baseline (paper §I: "maximizes output matrix
//! reuse... inefficient with highly sparse matrices").
//!
//! `C[i,j] = A[i,:] · B[:,j]` — every output element requires an
//! *intersection* of a CSR row of A with a CSC column of B; with very sparse
//! inputs most intersections are empty, which is exactly the inefficiency
//! the paper's intersection-energy discussion (Fig. 3, `IN`) quantifies.

use crate::sparse::Csr;

/// `C = A × B` by inner product. Also a reference model for the intersection
/// unit: [`intersect_count`] counts the comparisons a two-finger merge does.
pub fn spgemm_inner(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let bt = b.to_csc();
    let mut row_ptr = Vec::with_capacity(a.rows() + 1);
    row_ptr.push(0usize);
    let mut col_id = Vec::new();
    let mut value = Vec::new();
    for i in 0..a.rows() {
        let (ac, av) = (a.row_cols(i), a.row_values(i));
        if ac.is_empty() {
            row_ptr.push(col_id.len());
            continue;
        }
        for j in 0..b.cols() {
            let (bc, bv) = (bt.col_rows(j), bt.col_values(j));
            if bc.is_empty() {
                continue;
            }
            let mut sum = 0f32;
            let mut hit = false;
            let (mut p, mut q) = (0usize, 0usize);
            while p < ac.len() && q < bc.len() {
                match ac[p].cmp(&bc[q]) {
                    std::cmp::Ordering::Less => p += 1,
                    std::cmp::Ordering::Greater => q += 1,
                    std::cmp::Ordering::Equal => {
                        sum += av[p] * bv[q];
                        hit = true;
                        p += 1;
                        q += 1;
                    }
                }
            }
            if hit {
                col_id.push(j as u32);
                value.push(sum);
            }
        }
        row_ptr.push(col_id.len());
    }
    Csr::try_new(a.rows(), b.cols(), row_ptr, col_id, value).expect("inner produced invalid CSR")
}

/// Number of index comparisons a two-finger merge intersection performs for
/// the full inner-product `A × B` (used by the dataflow-comparison example).
pub fn intersect_count(a: &Csr, b: &Csr) -> u64 {
    assert_eq!(a.cols(), b.rows());
    let bt = b.to_csc();
    let mut n = 0u64;
    for i in 0..a.rows() {
        let ac = a.row_cols(i);
        if ac.is_empty() {
            continue;
        }
        for j in 0..b.cols() {
            let bc = bt.col_rows(j);
            if bc.is_empty() {
                continue;
            }
            let (mut p, mut q) = (0usize, 0usize);
            while p < ac.len() && q < bc.len() {
                n += 1;
                match ac[p].cmp(&bc[q]) {
                    std::cmp::Ordering::Less => p += 1,
                    std::cmp::Ordering::Greater => q += 1,
                    std::cmp::Ordering::Equal => {
                        p += 1;
                        q += 1;
                    }
                }
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gustavson::{dense_matmul, max_abs_diff};
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn matches_dense() {
        let a = generate(15, 12, 40, Profile::Uniform, 31);
        let b = generate(12, 18, 50, Profile::Uniform, 32);
        let c = spgemm_inner(&a, &b);
        assert!(max_abs_diff(&c, &dense_matmul(&a, &b)) < 1e-4);
    }

    #[test]
    fn intersection_count_scales_with_density() {
        // Denser matrices force more comparisons per output element.
        let sparse_a = generate(30, 30, 60, Profile::Uniform, 1);
        let dense_a = generate(30, 30, 500, Profile::Uniform, 1);
        assert!(intersect_count(&dense_a, &dense_a) > intersect_count(&sparse_a, &sparse_a));
    }

    #[test]
    fn empty_intersections_emit_nothing() {
        // A hits only column 0, B's row 0 is empty -> C must be empty.
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (1, 0, 2.0)]);
        let b = Csr::from_triplets(2, 2, vec![(1, 1, 3.0)]);
        let c = spgemm_inner(&a, &b);
        assert_eq!(c.nnz(), 0);
    }
}
