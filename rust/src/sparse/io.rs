//! Matrix-Market I/O.
//!
//! The evaluation runs on synthetic Table-I workloads by default (no network
//! in this environment), but any real SuiteSparse `.mtx` file dropped next to
//! the binary loads through [`read_matrix_market`] and runs through the same
//! pipeline.

use super::{Coo, Csr};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Error type for Matrix-Market parsing.
#[derive(Debug, thiserror::Error)]
pub enum MmError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("not a MatrixMarket file (missing %%MatrixMarket header)")]
    MissingHeader,
    #[error("unsupported MatrixMarket variant: {0}")]
    Unsupported(String),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
}

/// Read a MatrixMarket `coordinate` file into CSR.
///
/// Supports `real` / `integer` / `pattern` fields and `general` / `symmetric`
/// symmetries (symmetric entries are mirrored). `pattern` entries get value
/// 1.0, matching common SpGEMM evaluation practice.
pub fn read_matrix_market(path: &Path) -> Result<Csr, MmError> {
    let f = std::fs::File::open(path)?;
    read_matrix_market_from(BufReader::new(f))
}

/// Parse MatrixMarket from any buffered reader (unit-testable without files).
pub fn read_matrix_market_from<R: BufRead>(r: R) -> Result<Csr, MmError> {
    let mut lines = r.lines().enumerate();

    // Header: %%MatrixMarket matrix coordinate <field> <symmetry>
    let (_, header) = lines.next().ok_or(MmError::MissingHeader)?;
    let header = header?;
    if !header.starts_with("%%MatrixMarket") {
        return Err(MmError::MissingHeader);
    }
    let toks: Vec<String> = header.split_whitespace().map(|t| t.to_ascii_lowercase()).collect();
    if toks.len() < 5 || toks[1] != "matrix" || toks[2] != "coordinate" {
        return Err(MmError::Unsupported(header));
    }
    let field = toks[3].clone();
    let symmetry = toks[4].clone();
    if !matches!(field.as_str(), "real" | "integer" | "pattern") {
        return Err(MmError::Unsupported(format!("field {field}")));
    }
    if !matches!(symmetry.as_str(), "general" | "symmetric") {
        return Err(MmError::Unsupported(format!("symmetry {symmetry}")));
    }

    // Skip comments, read size line.
    let (rows, cols, nnz_decl, size_line_no) = loop {
        let (no, line) = lines
            .next()
            .ok_or(MmError::Parse { line: 0, msg: "missing size line".into() })?;
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        if parts.len() != 3 {
            return Err(MmError::Parse { line: no + 1, msg: format!("bad size line: {t}") });
        }
        let p = |s: &str| -> Result<usize, MmError> {
            s.parse().map_err(|_| MmError::Parse { line: no + 1, msg: format!("bad int {s}") })
        };
        break (p(parts[0])?, p(parts[1])?, p(parts[2])?, no + 1);
    };

    let mut coo = Coo::zero(rows, cols);
    let mut seen = 0usize;
    for (no, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        let need = if field == "pattern" { 2 } else { 3 };
        if parts.len() < need {
            return Err(MmError::Parse { line: no + 1, msg: format!("bad entry: {t}") });
        }
        let r: usize = parts[0]
            .parse()
            .map_err(|_| MmError::Parse { line: no + 1, msg: format!("bad row {}", parts[0]) })?;
        let c: usize = parts[1]
            .parse()
            .map_err(|_| MmError::Parse { line: no + 1, msg: format!("bad col {}", parts[1]) })?;
        if r == 0 || c == 0 || r > rows || c > cols {
            return Err(MmError::Parse {
                line: no + 1,
                msg: format!("coordinate ({r},{c}) out of bounds"),
            });
        }
        let v: f32 = if field == "pattern" {
            1.0
        } else {
            parts[2].parse().map_err(|_| MmError::Parse {
                line: no + 1,
                msg: format!("bad value {}", parts[2]),
            })?
        };
        // MatrixMarket is 1-indexed.
        coo.push((r - 1) as u32, (c - 1) as u32, v);
        if symmetry == "symmetric" && r != c {
            coo.push((c - 1) as u32, (r - 1) as u32, v);
        }
        seen += 1;
    }
    if seen != nnz_decl {
        return Err(MmError::Parse {
            line: size_line_no,
            msg: format!("declared {nnz_decl} entries, found {seen}"),
        });
    }
    Ok(coo.to_csr())
}

/// Write a CSR matrix as MatrixMarket `coordinate real general`.
pub fn write_matrix_market(path: &Path, a: &Csr) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by maple (row-wise product accelerator framework)")?;
    writeln!(f, "{} {} {}", a.rows(), a.cols(), a.nnz())?;
    for i in 0..a.rows() {
        for (c, v) in a.row_iter(i) {
            writeln!(f, "{} {} {}", i + 1, c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   3 3 2\n\
                   1 2 5.0\n\
                   3 1 -1.5\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.rows(), 3);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(0, 1), 5.0);
        assert_eq!(a.get(2, 0), -1.5);
    }

    #[test]
    fn parse_symmetric_mirrors() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   2 2 2\n\
                   1 1 1.0\n\
                   2 1 3.0\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.nnz(), 3); // diagonal not mirrored
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }

    #[test]
    fn parse_pattern_gets_unit_values() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n\
                   2 3 2\n\
                   1 3\n\
                   2 1\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.get(0, 2), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read_matrix_market_from(Cursor::new("garbage\n1 1 0\n")).is_err());
        let wrong_count = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(wrong_count)).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(oob)).is_err());
    }

    #[test]
    fn round_trip_through_file() {
        let a = crate::sparse::gen::generate(
            20,
            30,
            100,
            crate::sparse::gen::Profile::Uniform,
            11,
        );
        let p = std::env::temp_dir().join(format!("maple-io-test-{}.mtx", std::process::id()));
        write_matrix_market(&p, &a).unwrap();
        let b = read_matrix_market(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.nnz(), b.nnz());
        for i in 0..a.rows() {
            assert_eq!(a.row_cols(i), b.row_cols(i));
        }
    }
}
