//! Matrix-Market I/O and the out-of-core row-group container.
//!
//! The evaluation runs on synthetic Table-I workloads by default (no network
//! in this environment), but any real SuiteSparse `.mtx` file dropped next to
//! the binary loads through [`read_matrix_market`] and runs through the same
//! pipeline.
//!
//! For matrices that do not fit in RAM, [`stream_matrix_market`] reads the
//! same `.mtx` format in two streaming passes under an explicit memory
//! budget, yielding bounded row-group [`CsrSlice`]s, and [`RowGroupFile`]
//! persists those groups in a random-access binary container (`.mrg`) built
//! from the cache codec's sealed envelopes
//! ([`crate::sim::cache::codec`]): a `MAPLERGS` header (dimensions + group
//! directory) followed by one ordinary `MAPLECSR` block per group, every
//! piece versioned and FNV-checksummed. The tiled profiler
//! ([`crate::sim::profile_container_tiled`]) streams groups and column
//! tiles out of the container so the whole matrix is never resident.

use super::tile;
use super::{Coo, Csr};
use crate::sim::cache::codec;
use std::fs;
use std::io::{self, BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Error type for Matrix-Market parsing and container I/O.
#[derive(Debug, thiserror::Error)]
pub enum MmError {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("not a MatrixMarket file (missing %%MatrixMarket header)")]
    MissingHeader,
    #[error("unsupported MatrixMarket variant: {0}")]
    Unsupported(String),
    #[error("parse error at line {line}: {msg}")]
    Parse { line: usize, msg: String },
    #[error("memory budget: {0}")]
    Budget(String),
    #[error("row-group container: {0}")]
    Container(String),
}

/// MatrixMarket value field (`integer` is folded into `Real`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Field {
    Real,
    Pattern,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
}

/// Parsed banner + size line of a coordinate MatrixMarket file.
#[derive(Debug, Clone, Copy)]
struct MmHead {
    field: Field,
    symmetry: Symmetry,
    rows: usize,
    cols: usize,
    /// Entry count the size line declares (file entries, before symmetric
    /// mirroring).
    nnz_decl: usize,
    /// Line number of the size line (for the count-mismatch error).
    size_line: usize,
}

/// Parse the banner and size line, leaving the reader at the first entry.
fn read_head<R: BufRead>(
    r: &mut R,
    buf: &mut String,
    line_no: &mut usize,
) -> Result<MmHead, MmError> {
    buf.clear();
    if r.read_line(buf)? == 0 {
        return Err(MmError::MissingHeader);
    }
    *line_no += 1;
    let header = buf.trim_end();
    if !header.starts_with("%%MatrixMarket") {
        return Err(MmError::MissingHeader);
    }
    let mut toks = header.split_ascii_whitespace().skip(1);
    let object = toks.next().map(str::to_ascii_lowercase);
    let format = toks.next().map(str::to_ascii_lowercase);
    let field_tok = toks.next().map(str::to_ascii_lowercase);
    let sym_tok = toks.next().map(str::to_ascii_lowercase);
    if object.as_deref() != Some("matrix") || format.as_deref() != Some("coordinate") {
        return Err(MmError::Unsupported(header.to_string()));
    }
    let field = match field_tok.as_deref() {
        Some("real") | Some("integer") => Field::Real,
        Some("pattern") => Field::Pattern,
        Some(f) => return Err(MmError::Unsupported(format!("field {f}"))),
        None => return Err(MmError::Unsupported(header.to_string())),
    };
    let symmetry = match sym_tok.as_deref() {
        Some("general") => Symmetry::General,
        Some("symmetric") => Symmetry::Symmetric,
        Some(s) => return Err(MmError::Unsupported(format!("symmetry {s}"))),
        None => return Err(MmError::Unsupported(header.to_string())),
    };

    // Skip comments, read the size line.
    loop {
        buf.clear();
        if r.read_line(buf)? == 0 {
            return Err(MmError::Parse { line: *line_no, msg: "missing size line".into() });
        }
        *line_no += 1;
        let t = buf.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (a, b, c) = (it.next(), it.next(), it.next());
        let (Some(a), Some(b), Some(c)) = (a, b, c) else {
            return Err(MmError::Parse { line: *line_no, msg: format!("bad size line: {t}") });
        };
        if it.next().is_some() {
            return Err(MmError::Parse { line: *line_no, msg: format!("bad size line: {t}") });
        }
        let p = |s: &str| -> Result<usize, MmError> {
            s.parse()
                .map_err(|_| MmError::Parse { line: *line_no, msg: format!("bad int {s}") })
        };
        return Ok(MmHead {
            field,
            symmetry,
            rows: p(a)?,
            cols: p(b)?,
            nnz_decl: p(c)?,
            size_line: *line_no,
        });
    }
}

/// Drive `f` over every (0-indexed) entry of the body, mirroring symmetric
/// off-diagonal entries, validating bounds and the declared entry count.
/// The hot loop is allocation-free: one reused line buffer, tokens split in
/// place — no per-line `Vec` — which is what makes the two-pass streaming
/// ingest's parse cost acceptable at out-of-core scale.
fn for_each_entry<R: BufRead>(
    r: &mut R,
    head: &MmHead,
    buf: &mut String,
    line_no: &mut usize,
    f: &mut dyn FnMut(u32, u32, f32) -> Result<(), MmError>,
) -> Result<(), MmError> {
    let mut seen = 0usize;
    loop {
        buf.clear();
        if r.read_line(buf)? == 0 {
            break;
        }
        *line_no += 1;
        let t = buf.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let (Some(rs), Some(cs)) = (it.next(), it.next()) else {
            return Err(MmError::Parse { line: *line_no, msg: format!("bad entry: {t}") });
        };
        let row: usize = rs
            .parse()
            .map_err(|_| MmError::Parse { line: *line_no, msg: format!("bad row {rs}") })?;
        let col: usize = cs
            .parse()
            .map_err(|_| MmError::Parse { line: *line_no, msg: format!("bad col {cs}") })?;
        if row == 0 || col == 0 || row > head.rows || col > head.cols {
            return Err(MmError::Parse {
                line: *line_no,
                msg: format!("coordinate ({row},{col}) out of bounds"),
            });
        }
        let v: f32 = match head.field {
            Field::Pattern => 1.0,
            Field::Real => {
                let vs = it.next().ok_or_else(|| MmError::Parse {
                    line: *line_no,
                    msg: format!("bad entry: {t}"),
                })?;
                vs.parse().map_err(|_| MmError::Parse {
                    line: *line_no,
                    msg: format!("bad value {vs}"),
                })?
            }
        };
        f((row - 1) as u32, (col - 1) as u32, v)?;
        if head.symmetry == Symmetry::Symmetric && row != col {
            f((col - 1) as u32, (row - 1) as u32, v)?;
        }
        seen += 1;
    }
    if seen != head.nnz_decl {
        return Err(MmError::Parse {
            line: head.size_line,
            msg: format!("declared {} entries, found {seen}", head.nnz_decl),
        });
    }
    Ok(())
}

/// Read a MatrixMarket `coordinate` file into CSR.
///
/// Supports `real` / `integer` / `pattern` fields and `general` / `symmetric`
/// symmetries (symmetric entries are mirrored). `pattern` entries get value
/// 1.0, matching common SpGEMM evaluation practice.
pub fn read_matrix_market(path: &Path) -> Result<Csr, MmError> {
    let f = std::fs::File::open(path)?;
    read_matrix_market_from(BufReader::new(f))
}

/// Parse MatrixMarket from any buffered reader (unit-testable without files).
pub fn read_matrix_market_from<R: BufRead>(mut r: R) -> Result<Csr, MmError> {
    let mut buf = String::new();
    let mut line_no = 0usize;
    let head = read_head(&mut r, &mut buf, &mut line_no)?;
    let mut coo = Coo::zero(head.rows, head.cols);
    for_each_entry(&mut r, &head, &mut buf, &mut line_no, &mut |row, col, v| {
        coo.push(row, col, v);
        Ok(())
    })?;
    Ok(coo.to_csr())
}

/// The header form [`write_matrix_market_as`] emits.
///
/// Symmetric forms store only the lower triangle (readers mirror it back),
/// pattern forms store coordinates only (readers assign value 1.0) — so a
/// pattern round trip is faithful exactly when every value is 1.0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmFormat {
    RealGeneral,
    RealSymmetric,
    PatternGeneral,
    PatternSymmetric,
}

impl MmFormat {
    fn banner(self) -> &'static str {
        match self {
            MmFormat::RealGeneral => "real general",
            MmFormat::RealSymmetric => "real symmetric",
            MmFormat::PatternGeneral => "pattern general",
            MmFormat::PatternSymmetric => "pattern symmetric",
        }
    }

    fn symmetric(self) -> bool {
        matches!(self, MmFormat::RealSymmetric | MmFormat::PatternSymmetric)
    }

    fn pattern(self) -> bool {
        matches!(self, MmFormat::PatternGeneral | MmFormat::PatternSymmetric)
    }
}

/// Write a CSR matrix as MatrixMarket `coordinate real general`.
pub fn write_matrix_market(path: &Path, a: &Csr) -> std::io::Result<()> {
    write_matrix_market_as(path, a, MmFormat::RealGeneral)
}

/// Write a CSR matrix in the chosen MatrixMarket header form.
///
/// Symmetric forms require a square, numerically symmetric matrix — an
/// asymmetric entry is an `InvalidInput` error, never a silently lossy
/// file.
pub fn write_matrix_market_as(path: &Path, a: &Csr, format: MmFormat) -> std::io::Result<()> {
    let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidInput, msg);
    let mut stored = a.nnz();
    if format.symmetric() {
        if a.rows() != a.cols() {
            return Err(bad(format!(
                "symmetric MatrixMarket needs a square matrix, got {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        stored = 0;
        for i in 0..a.rows() {
            for (c, v) in a.row_iter(i) {
                let c = c as usize;
                if c != i && a.get(c, i) != v {
                    return Err(bad(format!(
                        "matrix is not symmetric at ({i},{c}): {v} vs {}",
                        a.get(c, i)
                    )));
                }
                if c <= i {
                    stored += 1;
                }
            }
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate {}", format.banner())?;
    writeln!(f, "% written by maple (row-wise product accelerator framework)")?;
    writeln!(f, "{} {} {}", a.rows(), a.cols(), stored)?;
    for i in 0..a.rows() {
        for (c, v) in a.row_iter(i) {
            if format.symmetric() && c as usize > i {
                continue;
            }
            if format.pattern() {
                writeln!(f, "{} {}", i + 1, c + 1)?;
            } else {
                writeln!(f, "{} {} {}", i + 1, c + 1, v)?;
            }
        }
    }
    f.flush()
}

// ------------------------------------------------------------- streaming

/// One contiguous row group of a larger matrix, with its position in the
/// full matrix. `matrix` holds the group's rows re-based to local row 0
/// over the **full** column space, so `matrix.rows() == row_hi - row_lo`
/// and `matrix.cols() == cols`.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrSlice {
    pub row_lo: usize,
    pub row_hi: usize,
    /// Row count of the full matrix this slice was cut from.
    pub rows_total: usize,
    /// Column count of the full matrix (== `matrix.cols()`).
    pub cols: usize,
    pub matrix: Csr,
}

/// Distinguishes concurrent ingests within one process (the pid handles
/// concurrent processes), mirroring the cache store's temp-file counter.
static SPILL_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Stream a MatrixMarket file as bounded row groups under `budget_bytes`.
///
/// Two passes, both through the allocation-free entry parser:
///
/// 1. **Plan**: count per-row nonzeros (symmetric mirrors included) and cut
///    greedy contiguous row groups whose CSR storage — `(rows+1)·8 + nnz·8`
///    bytes — stays under the per-group target of `budget_bytes / 4`. The
///    4× headroom covers the profiler's working set (one row group + one
///    column tile + one partial) and the transient triplet buffers of group
///    assembly. A single row too heavy for the target is a loud
///    [`MmError::Budget`] error, never a silently oversized group.
/// 2. **Spill**: route every entry to its group's temp file as a fixed
///    12-byte record, so group assembly reads one small file per group
///    instead of re-scanning the whole matrix per group.
///
/// The returned iterator yields each group as a [`CsrSlice`] (duplicate
/// coordinates summed, exactly like [`read_matrix_market`]); the spill
/// files are deleted when it drops.
pub fn stream_matrix_market(path: &Path, budget_bytes: u64) -> Result<RowGroupStream, MmError> {
    let target = budget_bytes / 4;
    if target == 0 {
        return Err(MmError::Budget(format!(
            "budget of {budget_bytes} bytes leaves no room for a row group (target is budget / 4)"
        )));
    }

    // Pass 1 — plan the group bounds from per-row entry counts.
    let mut buf = String::new();
    let mut line_no = 0usize;
    let mut r = BufReader::new(fs::File::open(path)?);
    let head = read_head(&mut r, &mut buf, &mut line_no)?;
    let mut counts = vec![0u64; head.rows];
    for_each_entry(&mut r, &head, &mut buf, &mut line_no, &mut |row, _col, _v| {
        counts[row as usize] += 1;
        Ok(())
    })?;
    let bounds = plan_groups(&counts, target)?;

    // Pass 2 — spill each entry to its group's temp file.
    let n = SPILL_COUNTER.fetch_add(1, Ordering::Relaxed);
    let spill_dir = std::env::temp_dir()
        .join(format!("maple-ingest-{}-{n}", std::process::id()));
    fs::create_dir_all(&spill_dir)?;
    let stream = RowGroupStream {
        rows: head.rows,
        cols: head.cols,
        bounds,
        spill_dir,
        next: 0,
    };
    let mut writers = Vec::with_capacity(stream.bounds.len());
    for g in 0..stream.bounds.len() {
        writers.push(BufWriter::new(fs::File::create(stream.spill_path(g))?));
    }
    let mut line_no = 0usize;
    let mut r = BufReader::new(fs::File::open(path)?);
    let head = read_head(&mut r, &mut buf, &mut line_no)?;
    let bounds = &stream.bounds;
    for_each_entry(&mut r, &head, &mut buf, &mut line_no, &mut |row, col, v| {
        let g = bounds.partition_point(|&(_, hi)| hi <= row as usize);
        let w = &mut writers[g];
        w.write_all(&row.to_le_bytes())?;
        w.write_all(&col.to_le_bytes())?;
        w.write_all(&v.to_bits().to_le_bytes())?;
        Ok(())
    })?;
    for mut w in writers {
        w.flush()?;
    }
    Ok(stream)
}

/// Greedy contiguous row groups whose CSR bytes stay under `target`.
fn plan_groups(counts: &[u64], target: u64) -> Result<Vec<(usize, usize)>, MmError> {
    if counts.is_empty() {
        // One explicit empty group, mirroring `tile::cuts(0, t) == [0, 0]`.
        return Ok(vec![(0, 0)]);
    }
    let mut bounds = Vec::new();
    let mut lo = 0usize;
    let mut bytes = 8u64; // row_ptr[0]
    for (i, &nnz) in counts.iter().enumerate() {
        let row_bytes = 8 + nnz * 8;
        if row_bytes > target {
            return Err(MmError::Budget(format!(
                "row {} alone needs {row_bytes} bytes of CSR storage, more than the \
                 per-group target of {target} bytes (budget / 4); raise --mem-budget",
                i + 1,
            )));
        }
        if bytes + row_bytes > target && i > lo {
            bounds.push((lo, i));
            lo = i;
            bytes = 8;
        }
        bytes += row_bytes;
    }
    bounds.push((lo, counts.len()));
    Ok(bounds)
}

/// The iterator [`stream_matrix_market`] returns: planned group bounds plus
/// the spill directory the groups are assembled from. Yields groups in row
/// order; dropping it deletes the spill files.
#[derive(Debug)]
pub struct RowGroupStream {
    rows: usize,
    cols: usize,
    bounds: Vec<(usize, usize)>,
    spill_dir: PathBuf,
    next: usize,
}

impl RowGroupStream {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn group_count(&self) -> usize {
        self.bounds.len()
    }

    /// Half-open row bounds of group `g`.
    pub fn group_rows(&self, g: usize) -> (usize, usize) {
        self.bounds[g]
    }

    fn spill_path(&self, g: usize) -> PathBuf {
        self.spill_dir.join(format!("g{g}.bin"))
    }

    fn read_group(&self, g: usize) -> Result<CsrSlice, MmError> {
        let (lo, hi) = self.bounds[g];
        let bytes = fs::read(self.spill_path(g))?;
        if bytes.len() % 12 != 0 {
            return Err(MmError::Container(format!(
                "spill file for group {g} is torn ({} bytes)",
                bytes.len()
            )));
        }
        let mut coo = Coo::zero(hi - lo, self.cols);
        for rec in bytes.chunks_exact(12) {
            let row = u32::from_le_bytes(rec[0..4].try_into().expect("4-byte slice"));
            let col = u32::from_le_bytes(rec[4..8].try_into().expect("4-byte slice"));
            let v = f32::from_bits(u32::from_le_bytes(rec[8..12].try_into().expect("4-byte slice")));
            coo.push(row - lo as u32, col, v);
        }
        Ok(CsrSlice {
            row_lo: lo,
            row_hi: hi,
            rows_total: self.rows,
            cols: self.cols,
            matrix: coo.to_csr(),
        })
    }
}

impl Iterator for RowGroupStream {
    type Item = Result<CsrSlice, MmError>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.next >= self.bounds.len() {
            return None;
        }
        let g = self.next;
        self.next += 1;
        Some(self.read_group(g))
    }
}

impl Drop for RowGroupStream {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.spill_dir);
    }
}

// ------------------------------------------------------------- container

/// Header payload: rows, cols, nnz, group count (u64 each)…
const RGS_FIXED: usize = 32;
/// …then per group: row_lo, row_hi, nnz, offset, len (u64 each).
const RGS_PER_GROUP: usize = 40;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct GroupEntry {
    row_lo: usize,
    row_hi: usize,
    nnz: usize,
    offset: u64,
    len: u64,
}

/// A random-access row-group container (`.mrg`): a sealed `MAPLERGS`
/// header (dimensions + group directory) followed by one sealed `MAPLECSR`
/// block per row group, all through the cache codec's envelope — versioned,
/// FNV-checksummed, and bit-stable across platforms.
///
/// Unlike cache artifacts, a container is *user data*: a corrupt block is
/// a hard [`std::io::ErrorKind::InvalidData`] error on load, never a
/// silent eviction. Loads reopen the file per call, so `&self` methods are
/// freely shareable across the profiler's phases.
#[derive(Debug, Clone)]
pub struct RowGroupFile {
    path: PathBuf,
    rows: usize,
    cols: usize,
    nnz: usize,
    groups: Vec<GroupEntry>,
    fingerprint: u64,
}

fn container_err(e: codec::CodecError) -> MmError {
    MmError::Container(e.to_string())
}

impl RowGroupFile {
    /// Consume a [`RowGroupStream`] into a container at `path`.
    ///
    /// The header's length is fixed once the group count is known, so the
    /// header region is reserved up front, the group blocks stream out
    /// behind it, and the sealed header (whose directory needs the final
    /// offsets and nnz counts) is written back over the reservation last —
    /// one sequential pass over the groups, no second copy of the data.
    pub fn create(path: &Path, stream: RowGroupStream) -> Result<Self, MmError> {
        let (rows, cols) = (stream.rows(), stream.cols());
        let n_groups = stream.group_count();
        let header_total = codec::HEADER_LEN + RGS_FIXED + n_groups * RGS_PER_GROUP;
        let bounds: Vec<(usize, usize)> = (0..n_groups).map(|g| stream.group_rows(g)).collect();

        let mut w = BufWriter::new(fs::File::create(path)?);
        w.write_all(&vec![0u8; header_total])?;
        let mut groups = Vec::with_capacity(n_groups);
        let mut offset = header_total as u64;
        let mut nnz = 0usize;
        for (g, item) in stream.enumerate() {
            let slice = item?;
            if (slice.row_lo, slice.row_hi) != bounds[g] {
                return Err(MmError::Container(format!(
                    "stream yielded group {g} with bounds {}..{}, planned {}..{}",
                    slice.row_lo, slice.row_hi, bounds[g].0, bounds[g].1
                )));
            }
            let block = codec::encode_csr(&slice.matrix);
            w.write_all(&block)?;
            nnz += slice.matrix.nnz();
            groups.push(GroupEntry {
                row_lo: slice.row_lo,
                row_hi: slice.row_hi,
                nnz: slice.matrix.nnz(),
                offset,
                len: block.len() as u64,
            });
            offset += block.len() as u64;
        }

        let mut payload = Vec::with_capacity(RGS_FIXED + n_groups * RGS_PER_GROUP);
        codec::put_u64(&mut payload, rows as u64);
        codec::put_u64(&mut payload, cols as u64);
        codec::put_u64(&mut payload, nnz as u64);
        codec::put_u64(&mut payload, n_groups as u64);
        for e in &groups {
            codec::put_u64(&mut payload, e.row_lo as u64);
            codec::put_u64(&mut payload, e.row_hi as u64);
            codec::put_u64(&mut payload, e.nnz as u64);
            codec::put_u64(&mut payload, e.offset);
            codec::put_u64(&mut payload, e.len);
        }
        let sealed = codec::seal(codec::MAGIC_RGS, &payload);
        debug_assert_eq!(sealed.len(), header_total);
        let mut f = w.into_inner().map_err(|e| MmError::Io(e.into_error()))?;
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&sealed)?;
        f.flush()?;
        Ok(Self {
            path: path.to_path_buf(),
            rows,
            cols,
            nnz,
            groups,
            fingerprint: codec::fnv1a(&payload),
        })
    }

    /// Open a container, validating the sealed header and its directory
    /// (contiguous row coverage, blocks inside the file, nnz totals).
    pub fn open(path: &Path) -> Result<Self, MmError> {
        let mut f = fs::File::open(path)?;
        let file_len = f.metadata()?.len();
        let mut head = [0u8; codec::HEADER_LEN];
        f.read_exact(&mut head)?;
        let payload_len = codec::sealed_payload_len(codec::MAGIC_RGS, &head).map_err(container_err)?;
        let mut all = head.to_vec();
        all.resize(codec::HEADER_LEN + payload_len, 0);
        f.read_exact(&mut all[codec::HEADER_LEN..])?;
        let mut r = codec::open(codec::MAGIC_RGS, &all).map_err(container_err)?;
        let rows = r.index().map_err(container_err)?;
        let cols = r.index().map_err(container_err)?;
        let nnz = r.index().map_err(container_err)?;
        let n_groups = r.index().map_err(container_err)?;
        r.expect_items(n_groups, RGS_PER_GROUP).map_err(container_err)?;
        let mut groups = Vec::with_capacity(n_groups);
        let mut prev_hi = 0usize;
        let mut nnz_sum = 0usize;
        for g in 0..n_groups {
            let e = GroupEntry {
                row_lo: r.index().map_err(container_err)?,
                row_hi: r.index().map_err(container_err)?,
                nnz: r.index().map_err(container_err)?,
                offset: r.u64().map_err(container_err)?,
                len: r.u64().map_err(container_err)?,
            };
            if e.row_lo != prev_hi || e.row_hi < e.row_lo {
                return Err(MmError::Container(format!(
                    "group {g} bounds {}..{} do not continue coverage at row {prev_hi}",
                    e.row_lo, e.row_hi
                )));
            }
            match e.offset.checked_add(e.len) {
                Some(end) if end <= file_len => {}
                _ => {
                    return Err(MmError::Container(format!(
                        "group {g} block ({} bytes at offset {}) extends past the file \
                         ({file_len} bytes)",
                        e.len, e.offset
                    )));
                }
            }
            prev_hi = e.row_hi;
            nnz_sum += e.nnz;
            groups.push(e);
        }
        r.done().map_err(container_err)?;
        if prev_hi != rows || nnz_sum != nnz {
            return Err(MmError::Container(format!(
                "directory covers {prev_hi} of {rows} rows with {nnz_sum} of {nnz} nonzeros"
            )));
        }
        Ok(Self {
            path: path.to_path_buf(),
            rows,
            cols,
            nnz,
            groups,
            fingerprint: codec::fnv1a(&all[codec::HEADER_LEN..]),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Half-open row bounds of group `g`.
    pub fn group_rows(&self, g: usize) -> (usize, usize) {
        (self.groups[g].row_lo, self.groups[g].row_hi)
    }

    /// FNV-1a of the header payload — a cheap identity for cache keys: two
    /// containers with the same dimensions, grouping, and block layout
    /// share it, anything else (different matrix, budget, or codec
    /// version) does not.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Load one row group. A block that fails to decode or disagrees with
    /// the header directory is `InvalidData` — user data, not a cache.
    pub fn load_group(&self, g: usize) -> io::Result<CsrSlice> {
        let e = self.groups[g];
        let mut f = fs::File::open(&self.path)?;
        f.seek(SeekFrom::Start(e.offset))?;
        let mut bytes = vec![0u8; e.len as usize];
        f.read_exact(&mut bytes)?;
        let bad = |msg: String| io::Error::new(io::ErrorKind::InvalidData, msg);
        let matrix = codec::decode_csr(&bytes)
            .map_err(|err| bad(format!("container group {g}: {err}")))?;
        if matrix.rows() != e.row_hi - e.row_lo || matrix.cols() != self.cols
            || matrix.nnz() != e.nnz
        {
            return Err(bad(format!(
                "container group {g} ({}x{}, {} nnz) does not match its directory entry",
                matrix.rows(),
                matrix.cols(),
                matrix.nnz()
            )));
        }
        Ok(CsrSlice {
            row_lo: e.row_lo,
            row_hi: e.row_hi,
            rows_total: self.rows,
            cols: self.cols,
            matrix,
        })
    }

    /// Assemble the column tile `[col_lo, col_hi)` over **all** rows by
    /// streaming the groups in order — the B-side tile of the out-of-core
    /// profile pass. Column ids in the result are local (`j - col_lo`).
    /// Peak residency is the assembled tile plus one group.
    pub fn load_col_tile(&self, col_lo: usize, col_hi: usize) -> io::Result<Csr> {
        let col_hi = col_hi.min(self.cols);
        let col_lo = col_lo.min(col_hi);
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        row_ptr.push(0);
        let mut col_id = Vec::new();
        let mut value = Vec::new();
        for g in 0..self.groups.len() {
            let slice = self.load_group(g)?;
            let t = tile::extract_cols(&slice.matrix, col_lo, col_hi);
            let base = col_id.len();
            for &p in &t.row_ptr[1..] {
                row_ptr.push(base + p);
            }
            col_id.extend_from_slice(&t.col_id);
            value.extend_from_slice(&t.value);
        }
        Csr::try_new(self.rows, col_hi - col_lo, row_ptr, col_id, value)
            .map_err(|msg| io::Error::new(io::ErrorKind::InvalidData, msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Profile};
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n\
                   % comment\n\
                   3 3 2\n\
                   1 2 5.0\n\
                   3 1 -1.5\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.rows(), 3);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(0, 1), 5.0);
        assert_eq!(a.get(2, 0), -1.5);
    }

    #[test]
    fn parse_symmetric_mirrors() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n\
                   2 2 2\n\
                   1 1 1.0\n\
                   2 1 3.0\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.nnz(), 3); // diagonal not mirrored
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }

    #[test]
    fn parse_pattern_gets_unit_values() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n\
                   2 3 2\n\
                   1 3\n\
                   2 1\n";
        let a = read_matrix_market_from(Cursor::new(src)).unwrap();
        assert_eq!(a.get(0, 2), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read_matrix_market_from(Cursor::new("garbage\n1 1 0\n")).is_err());
        let wrong_count = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(wrong_count)).is_err());
        let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(oob)).is_err());
        let complex = "%%MatrixMarket matrix coordinate complex general\n1 1 0\n";
        assert!(read_matrix_market_from(Cursor::new(complex)).is_err());
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("maple-io-test-{}-{tag}", std::process::id()))
    }

    #[test]
    fn round_trip_through_file() {
        let a = generate(20, 30, 100, Profile::Uniform, 11);
        let p = tmp("general.mtx");
        write_matrix_market(&p, &a).unwrap();
        let b = read_matrix_market(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(a.rows(), b.rows());
        assert_eq!(a.nnz(), b.nnz());
        for i in 0..a.rows() {
            assert_eq!(a.row_cols(i), b.row_cols(i));
        }
    }

    /// Symmetrize a generated matrix: keep the lower triangle, mirror it up.
    fn symmetrized(n: usize, nnz: usize, seed: u64) -> Csr {
        let a = generate(n, n, nnz, Profile::Uniform, seed);
        let mut coo = Coo::zero(n, n);
        for i in 0..n {
            for (c, v) in a.row_iter(i) {
                if c as usize <= i {
                    coo.push(i as u32, c, v);
                    if (c as usize) < i {
                        coo.push(c, i as u32, v);
                    }
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn symmetric_writer_round_trips() {
        let a = symmetrized(25, 120, 3);
        let p = tmp("symmetric.mtx");
        write_matrix_market_as(&p, &a, MmFormat::RealSymmetric).unwrap();
        // The file stores only the lower triangle.
        let body = std::fs::read_to_string(&p).unwrap();
        let declared: usize = body
            .lines()
            .find(|l| !l.starts_with('%'))
            .and_then(|l| l.split_ascii_whitespace().nth(2))
            .and_then(|n| n.parse().ok())
            .unwrap();
        assert!(declared < a.nnz(), "lower triangle ({declared}) vs full ({})", a.nnz());
        let b = read_matrix_market(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(a, b, "symmetric round trip must be exact");
    }

    #[test]
    fn symmetric_writer_rejects_asymmetry() {
        let mut coo = Coo::zero(2, 2);
        coo.push(0, 1, 2.0); // no mirrored (1, 0) entry
        let p = tmp("asym.mtx");
        let err = write_matrix_market_as(&p, &coo.to_csr(), MmFormat::RealSymmetric);
        assert!(err.is_err());
        let rect = generate(3, 4, 6, Profile::Uniform, 1);
        assert!(write_matrix_market_as(&p, &rect, MmFormat::RealSymmetric).is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn pattern_writer_round_trips_unit_values() {
        // Pattern files carry no values; a round trip is exact when every
        // value is 1.0.
        let a = generate(15, 18, 60, Profile::Uniform, 7);
        let ones = Csr::try_new(
            a.rows(),
            a.cols(),
            a.row_ptr.clone(),
            a.col_id.clone(),
            vec![1.0; a.nnz()],
        )
        .unwrap();
        let p = tmp("pattern.mtx");
        write_matrix_market_as(&p, &ones, MmFormat::PatternGeneral).unwrap();
        let b = read_matrix_market(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(ones, b, "pattern round trip must be exact for unit values");
    }

    #[test]
    fn pattern_symmetric_round_trips() {
        let s = symmetrized(20, 90, 9);
        let ones = Csr::try_new(
            s.rows(),
            s.cols(),
            s.row_ptr.clone(),
            s.col_id.clone(),
            vec![1.0; s.nnz()],
        )
        .unwrap();
        let p = tmp("pattern-sym.mtx");
        write_matrix_market_as(&p, &ones, MmFormat::PatternSymmetric).unwrap();
        let b = read_matrix_market(&p).unwrap();
        let _ = std::fs::remove_file(&p);
        assert_eq!(ones, b);
    }

    #[test]
    fn streamed_groups_reassemble_the_whole_matrix() {
        let a = generate(60, 60, 900, Profile::PowerLaw { alpha: 0.8 }, 13);
        let p = tmp("stream.mtx");
        write_matrix_market(&p, &a).unwrap();
        // A budget far below the matrix size forces many groups.
        let budget = (a.storage_bytes(4, 8) as u64) / 2;
        let stream = stream_matrix_market(&p, budget).unwrap();
        assert_eq!((stream.rows(), stream.cols()), (60, 60));
        assert!(stream.group_count() > 1, "budget must force multiple groups");
        let target = budget / 4;
        let mut nnz = 0;
        let mut prev_hi = 0;
        for item in stream {
            let s = item.unwrap();
            assert_eq!(s.row_lo, prev_hi, "groups must tile the rows contiguously");
            prev_hi = s.row_hi;
            assert_eq!(s.matrix.rows(), s.row_hi - s.row_lo);
            assert_eq!(s.matrix.cols(), 60);
            let bytes = ((s.matrix.rows() + 1) * 8 + s.matrix.nnz() * 8) as u64;
            assert!(bytes <= target, "group {}..{} breaks the target", s.row_lo, s.row_hi);
            nnz += s.matrix.nnz();
            assert_eq!(s.matrix, tile::extract_rows(&a, s.row_lo, s.row_hi));
        }
        assert_eq!(prev_hi, 60);
        assert_eq!(nnz, a.nnz(), "groups must partition the nonzeros exactly");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn stream_rejects_impossible_budgets() {
        let a = generate(10, 10, 40, Profile::Uniform, 5);
        let p = tmp("budget.mtx");
        write_matrix_market(&p, &a).unwrap();
        assert!(matches!(stream_matrix_market(&p, 0), Err(MmError::Budget(_))));
        // A budget whose per-group target cannot hold the heaviest row.
        match stream_matrix_market(&p, 16) {
            Err(MmError::Budget(msg)) => assert!(msg.contains("raise --mem-budget"), "{msg}"),
            other => panic!("expected a budget error, got {other:?}"),
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn container_round_trips_groups_and_col_tiles() {
        let a = generate(48, 48, 700, Profile::PowerLaw { alpha: 0.7 }, 29);
        let mtx = tmp("container.mtx");
        let mrg = tmp("container.mrg");
        write_matrix_market(&mtx, &a).unwrap();
        let budget = (a.storage_bytes(4, 8) as u64) / 2;
        let stream = stream_matrix_market(&mtx, budget).unwrap();
        let created = RowGroupFile::create(&mrg, stream).unwrap();
        let opened = RowGroupFile::open(&mrg).unwrap();
        assert_eq!(created.fingerprint(), opened.fingerprint());
        for file in [&created, &opened] {
            assert_eq!((file.rows(), file.cols(), file.nnz()), (48, 48, a.nnz()));
            assert!(file.group_count() > 1);
            for g in 0..file.group_count() {
                let s = file.load_group(g).unwrap();
                let (lo, hi) = file.group_rows(g);
                assert_eq!((s.row_lo, s.row_hi), (lo, hi));
                assert_eq!(s.matrix, tile::extract_rows(&a, lo, hi));
            }
            for (c0, c1) in [(0, 16), (16, 48), (0, 48), (40, 48)] {
                assert_eq!(file.load_col_tile(c0, c1).unwrap(), tile::extract_cols(&a, c0, c1));
            }
        }
        let _ = std::fs::remove_file(&mtx);
        let _ = std::fs::remove_file(&mrg);
    }

    #[test]
    fn container_rejects_corruption_loudly() {
        let a = generate(30, 30, 300, Profile::Uniform, 41);
        let mtx = tmp("corrupt.mtx");
        let mrg = tmp("corrupt.mrg");
        write_matrix_market(&mtx, &a).unwrap();
        let stream = stream_matrix_market(&mtx, 1 << 20).unwrap();
        RowGroupFile::create(&mrg, stream).unwrap();
        let good = fs::read(&mrg).unwrap();
        // Flip a byte in the header: open() must fail.
        let mut bad = good.clone();
        bad[codec::HEADER_LEN + 3] ^= 0xFF;
        fs::write(&mrg, &bad).unwrap();
        assert!(matches!(RowGroupFile::open(&mrg), Err(MmError::Container(_))));
        // Flip a byte in a group block: the directory still opens, the
        // group load is a hard InvalidData error — user data, not a cache.
        let mut bad = good.clone();
        let last = bad.len() - 5;
        bad[last] ^= 0xFF;
        fs::write(&mrg, &bad).unwrap();
        let file = RowGroupFile::open(&mrg).unwrap();
        let g = file.group_count() - 1;
        let err = file.load_group(g).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        // A truncated file fails at open.
        fs::write(&mrg, &good[..good.len() / 2]).unwrap();
        assert!(RowGroupFile::open(&mrg).is_err());
        let _ = std::fs::remove_file(&mtx);
        let _ = std::fs::remove_file(&mrg);
    }

    #[test]
    fn empty_matrix_streams_and_containers() {
        let a = Csr::zero(0, 7);
        let mtx = tmp("empty.mtx");
        let mrg = tmp("empty.mrg");
        write_matrix_market(&mtx, &a).unwrap();
        let stream = stream_matrix_market(&mtx, 4096).unwrap();
        assert_eq!(stream.group_count(), 1);
        assert_eq!(stream.group_rows(0), (0, 0));
        let file = RowGroupFile::create(&mrg, stream).unwrap();
        assert_eq!((file.rows(), file.cols(), file.nnz()), (0, 7, 0));
        assert_eq!(file.load_col_tile(0, 7).unwrap(), Csr::zero(0, 7));
        let _ = std::fs::remove_file(&mtx);
        let _ = std::fs::remove_file(&mrg);
    }
}
