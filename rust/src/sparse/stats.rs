//! Row-structure statistics.
//!
//! These are the statistics the Maple PE is sensitive to: row-length
//! distribution (how full the ARB gets), and column-adjacency (how often
//! nonzeros form the "local clusters" that keep all of a Maple PE's MAC
//! units busy, paper §I).

use super::Csr;

/// Summary statistics over the rows of a CSR matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct RowStats {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    pub density: f64,
    pub mean_row_nnz: f64,
    pub max_row_nnz: usize,
    pub min_row_nnz: usize,
    pub empty_rows: usize,
    /// Standard deviation of row nnz (row-balance; drives PE load skew).
    pub row_nnz_stddev: f64,
    /// Fraction of nonzeros whose right neighbour is in the adjacent column
    /// (col_id difference of exactly 1) — the cluster locality metric.
    pub adjacency_fraction: f64,
    /// Mean length of maximal runs of consecutive column ids.
    pub mean_run_length: f64,
}

/// Compute [`RowStats`] in one pass over the matrix.
pub fn row_stats(a: &Csr) -> RowStats {
    let rows = a.rows();
    let mut max_r = 0usize;
    let mut min_r = usize::MAX;
    let mut empty = 0usize;
    let mut sum = 0usize;
    let mut sum_sq = 0f64;
    let mut adjacent = 0usize;
    let mut pairs = 0usize;
    let mut runs = 0usize;

    for i in 0..rows {
        let k = a.row_nnz(i);
        sum += k;
        sum_sq += (k * k) as f64;
        max_r = max_r.max(k);
        min_r = min_r.min(k);
        if k == 0 {
            empty += 1;
        }
        let cols = a.row_cols(i);
        if !cols.is_empty() {
            runs += 1; // first element starts a run
        }
        for w in cols.windows(2) {
            pairs += 1;
            if w[1] == w[0] + 1 {
                adjacent += 1;
            } else {
                runs += 1;
            }
        }
    }

    let mean = sum as f64 / rows.max(1) as f64;
    let var = (sum_sq / rows.max(1) as f64 - mean * mean).max(0.0);
    RowStats {
        rows,
        cols: a.cols(),
        nnz: a.nnz(),
        density: a.density(),
        mean_row_nnz: mean,
        max_row_nnz: max_r,
        min_row_nnz: if min_r == usize::MAX { 0 } else { min_r },
        empty_rows: empty,
        row_nnz_stddev: var.sqrt(),
        adjacency_fraction: if pairs == 0 { 0.0 } else { adjacent as f64 / pairs as f64 },
        mean_run_length: if runs == 0 { 0.0 } else { a.nnz() as f64 / runs as f64 },
    }
}

/// Row-nnz distribution summary — the shape statistics the sampled
/// profiler's stratification responds to ([`crate::sim`]'s
/// `profile_workload_sampled` cuts strata of equal product mass over the
/// product-sorted row order, so skew here predicts how unequal the *row
/// counts* per stratum get) and what `maple estval` prints next to each
/// dataset's measured estimator error.
#[derive(Debug, Clone, PartialEq)]
pub struct RowNnzSummary {
    pub rows: usize,
    pub nnz: usize,
    pub mean: f64,
    /// Coefficient of variation (stddev / mean) of row nnz — 0 for
    /// uniform rows, ≫1 for power-law graphs.
    pub cv: f64,
    pub max: usize,
    /// The single heaviest row's share of all nonzeros.
    pub max_share: f64,
    /// Rows holding more than 2× the mean nnz ("heavy" rows).
    pub heavy_rows: usize,
    /// Fraction of all nonzeros held by heavy rows.
    pub heavy_share: f64,
}

/// Compute [`RowNnzSummary`] in two passes over the row pointer.
pub fn row_nnz_summary(a: &Csr) -> RowNnzSummary {
    let rows = a.rows();
    let nnz = a.nnz();
    let mean = nnz as f64 / rows.max(1) as f64;
    let mut sum_sq = 0f64;
    let mut max = 0usize;
    let mut heavy_rows = 0usize;
    let mut heavy_nnz = 0usize;
    for i in 0..rows {
        let k = a.row_nnz(i);
        sum_sq += (k * k) as f64;
        max = max.max(k);
        if k as f64 > 2.0 * mean {
            heavy_rows += 1;
            heavy_nnz += k;
        }
    }
    let var = (sum_sq / rows.max(1) as f64 - mean * mean).max(0.0);
    RowNnzSummary {
        rows,
        nnz,
        mean,
        cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        max,
        max_share: if nnz == 0 { 0.0 } else { max as f64 / nnz as f64 },
        heavy_rows,
        heavy_share: if nnz == 0 { 0.0 } else { heavy_nnz as f64 / nnz as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_hand_matrix() {
        // rows: [0,1,2,3] -> run of 4 (3 adjacent pairs); [] ; [0, 5]
        let a = Csr::from_triplets(
            3,
            8,
            vec![(0, 0, 1.0), (0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0), (2, 0, 1.0), (2, 5, 1.0)],
        );
        let s = row_stats(&a);
        assert_eq!(s.nnz, 6);
        assert_eq!(s.max_row_nnz, 4);
        assert_eq!(s.min_row_nnz, 0);
        assert_eq!(s.empty_rows, 1);
        // pairs = 3 + 1 = 4, adjacent = 3
        assert!((s.adjacency_fraction - 0.75).abs() < 1e-12);
        // runs: row0 one run, row2 two runs -> 6 nnz / 3 runs
        assert!((s.mean_run_length - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_on_empty_matrix() {
        let a = Csr::zero(4, 4);
        let s = row_stats(&a);
        assert_eq!(s.nnz, 0);
        assert_eq!(s.empty_rows, 4);
        assert_eq!(s.adjacency_fraction, 0.0);
        assert_eq!(s.mean_run_length, 0.0);
    }

    #[test]
    fn row_nnz_summary_on_hand_matrix() {
        // rows of nnz [4, 0, 2]: mean 2; row 0 sits exactly at 2×mean,
        // which the strict > excludes from the heavy set.
        let a = Csr::from_triplets(
            3,
            8,
            vec![(0, 0, 1.0), (0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0), (2, 0, 1.0), (2, 5, 1.0)],
        );
        let s = row_nnz_summary(&a);
        assert_eq!((s.rows, s.nnz, s.max), (3, 6, 4));
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.max_share - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(s.heavy_rows, 0);
        assert_eq!(s.heavy_share, 0.0);
        // Add a dominant row: nnz [4, 0, 2, 10] → mean 4, row 3 is heavy.
        let mut t: Vec<(usize, usize, f32)> =
            vec![(0, 0, 1.0), (0, 1, 1.0), (0, 2, 1.0), (0, 3, 1.0), (2, 0, 1.0), (2, 5, 1.0)];
        t.extend((0..10).map(|c| (3, c, 1.0)));
        let s = row_nnz_summary(&Csr::from_triplets(4, 12, t));
        assert_eq!(s.heavy_rows, 1);
        assert!((s.heavy_share - 10.0 / 16.0).abs() < 1e-12);
        assert!(s.cv > 0.5);
    }

    #[test]
    fn row_nnz_summary_degenerate_inputs() {
        let s = row_nnz_summary(&Csr::zero(4, 4));
        assert_eq!((s.rows, s.nnz, s.max, s.heavy_rows), (4, 0, 0, 0));
        assert_eq!((s.mean, s.cv, s.max_share, s.heavy_share), (0.0, 0.0, 0.0, 0.0));
        let s = row_nnz_summary(&Csr::identity(10));
        assert_eq!((s.max, s.heavy_rows), (1, 0));
        assert_eq!(s.cv, 0.0);
        assert!((s.max_share - 0.1).abs() < 1e-12);
    }

    #[test]
    fn identity_has_no_adjacency() {
        let s = row_stats(&Csr::identity(10));
        assert_eq!(s.mean_row_nnz, 1.0);
        assert_eq!(s.adjacency_fraction, 0.0);
        assert_eq!(s.mean_run_length, 1.0);
        assert_eq!(s.row_nnz_stddev, 0.0);
    }
}
