//! Coordinate (triplet) format — used by outer-product dataflows (GAMMA
//! operates on a sparse coordinate format per paper §IV.A) and as the
//! interchange format for Matrix-Market I/O.

use super::{Csc, Csr};

/// A sparse matrix as parallel (row, col, value) triplet vectors.
#[derive(Debug, Clone, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    pub value: Vec<f32>,
}

impl Coo {
    /// An empty `rows × cols` matrix.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Self { rows, cols, row: Vec::new(), col: Vec::new(), value: Vec::new() }
    }

    /// Number of stored entries (before any duplicate folding).
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// Append one entry.
    pub fn push(&mut self, r: u32, c: u32, v: f32) {
        debug_assert!((r as usize) < self.rows && (c as usize) < self.cols);
        self.row.push(r);
        self.col.push(c);
        self.value.push(v);
    }

    /// Convert to CSR; duplicate coordinates are summed.
    pub fn to_csr(&self) -> Csr {
        let t: Vec<(u32, u32, f32)> = self
            .row
            .iter()
            .zip(&self.col)
            .zip(&self.value)
            .map(|((&r, &c), &v)| (r, c, v))
            .collect();
        Csr::from_triplets(self.rows, self.cols, t)
    }

    /// Convert to CSC. Canonical like every conversion here: routes
    /// through [`Csr::from_triplets`], so duplicates are summed and the
    /// result is identical to `self.to_csr().to_csc()`.
    pub fn to_csc(&self) -> Csc {
        self.to_csr().to_csc()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_convert() {
        let mut m = Coo::zero(3, 3);
        m.push(2, 1, 4.0);
        m.push(0, 0, 1.0);
        m.push(2, 1, 1.5); // duplicate -> summed in CSR
        assert_eq!(m.nnz(), 3);
        let c = m.to_csr();
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.get(2, 1), 5.5);
        assert_eq!(c.get(0, 0), 1.0);
    }

    #[test]
    fn to_csc_is_canonical() {
        // Direct COO -> CSC equals the CSR route exactly: duplicates are
        // summed and the column-major arrays come out sorted.
        let mut m = Coo::zero(3, 3);
        m.push(2, 1, 4.0);
        m.push(0, 0, 1.0);
        m.push(2, 1, 1.5);
        let c = m.to_csc();
        assert_eq!(c, m.to_csr().to_csc());
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.to_csr().get(2, 1), 5.5);
    }

    #[test]
    fn zero_is_empty() {
        let m = Coo::zero(5, 7);
        assert_eq!(m.nnz(), 0);
        let c = m.to_csr();
        assert_eq!(c.rows(), 5);
        assert_eq!(c.cols(), 7);
        assert_eq!(c.nnz(), 0);
    }
}
