//! Compressed Sparse Row — the paper's native format (§II.B, Fig. 1).

use super::{Coo, Csc};

/// A sparse matrix in CSR form.
///
/// Using the paper's notation: `value` holds the nonzeros row-major,
/// `col_id[p]` is the column coordinate of `value[p]`, and row `i` occupies
/// positions `row_ptr[i] .. row_ptr[i + 1]`. `A.value[i]` in the paper maps
/// to [`Csr::row_values`]`(i)` here, and `A.col_id[i]` to [`Csr::row_cols`].
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    rows: usize,
    cols: usize,
    /// `row_ptr[i]` = offset of row i's first nonzero; length `rows + 1`.
    pub row_ptr: Vec<usize>,
    /// Column coordinate of each nonzero (the CSR `col_id` metadata vector).
    pub col_id: Vec<u32>,
    /// The nonzero values (the CSR `value` vector).
    pub value: Vec<f32>,
}

impl Csr {
    /// Build from raw parts, validating every CSR invariant:
    /// monotone `row_ptr`, in-bounds strictly-increasing column ids per row,
    /// and matching vector lengths.
    pub fn try_new(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_id: Vec<u32>,
        value: Vec<f32>,
    ) -> Result<Self, String> {
        if row_ptr.len() != rows + 1 {
            return Err(format!(
                "row_ptr length {} != rows + 1 = {}",
                row_ptr.len(),
                rows + 1
            ));
        }
        if row_ptr[0] != 0 {
            return Err("row_ptr[0] must be 0".into());
        }
        if *row_ptr.last().unwrap() != value.len() {
            return Err(format!(
                "row_ptr[rows] = {} != nnz = {}",
                row_ptr[rows],
                value.len()
            ));
        }
        if col_id.len() != value.len() {
            return Err(format!(
                "col_id length {} != value length {}",
                col_id.len(),
                value.len()
            ));
        }
        for i in 0..rows {
            if row_ptr[i] > row_ptr[i + 1] {
                return Err(format!("row_ptr not monotone at row {i}"));
            }
            let r = &col_id[row_ptr[i]..row_ptr[i + 1]];
            for w in r.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("col_id not strictly increasing in row {i}"));
                }
            }
            if let Some(&last) = r.last() {
                if last as usize >= cols {
                    return Err(format!("col_id {last} out of bounds (cols = {cols}) in row {i}"));
                }
            }
        }
        Ok(Self { rows, cols, row_ptr, col_id, value })
    }

    /// Build from unsorted (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(rows: usize, cols: usize, mut t: Vec<(u32, u32, f32)>) -> Self {
        t.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_id = Vec::with_capacity(t.len());
        let mut value = Vec::with_capacity(t.len());
        for &(r, c, v) in &t {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            col_id.push(c);
            value.push(v);
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        // Entries are sorted, so duplicate (row, col) pairs are adjacent;
        // merge them in a second pass.
        Self { rows, cols, row_ptr, col_id, value }.dedup()
    }

    /// Merge equal (row, col) entries by summing their values.
    fn dedup(self) -> Self {
        let mut row_ptr = vec![0usize; self.rows + 1];
        let mut col_id = Vec::with_capacity(self.col_id.len());
        let mut value = Vec::with_capacity(self.value.len());
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let mut p = s;
            while p < e {
                let c = self.col_id[p];
                let mut v = self.value[p];
                let mut q = p + 1;
                while q < e && self.col_id[q] == c {
                    v += self.value[q];
                    q += 1;
                }
                col_id.push(c);
                value.push(v);
                p = q;
            }
            row_ptr[i + 1] = col_id.len();
        }
        Self { rows: self.rows, cols: self.cols, row_ptr, col_id, value }
    }

    /// An `rows × cols` matrix with no nonzeros.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Self { rows, cols, row_ptr: vec![0; rows + 1], col_id: Vec::new(), value: Vec::new() }
    }

    /// The identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        Self {
            rows: n,
            cols: n,
            row_ptr: (0..=n).collect(),
            col_id: (0..n as u32).collect(),
            value: vec![1.0; n],
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored nonzeros.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// Fraction of nonzero entries, `nnz / (rows * cols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Number of nonzeros in row `i` — what the paper's PE control logic
    /// derives by subtracting adjacent `row_ptr` entries (§III, Fig. 7).
    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    /// The nonzero values of row `i` (`A.value[i]` in the paper).
    #[inline]
    pub fn row_values(&self, i: usize) -> &[f32] {
        &self.value[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// The column ids of row `i` (`A.col_id[i]` in the paper).
    #[inline]
    pub fn row_cols(&self, i: usize) -> &[u32] {
        &self.col_id[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Iterate `(col, value)` pairs of row `i`.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.row_cols(i).iter().copied().zip(self.row_values(i).iter().copied())
    }

    /// Look up `A[i, j]`, returning 0.0 when the entry is not stored.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let r = self.row_cols(i);
        match r.binary_search(&(j as u32)) {
            Ok(p) => self.row_values(i)[p],
            Err(_) => 0.0,
        }
    }

    /// Transpose (CSR of Aᵀ). O(nnz + rows + cols).
    pub fn transpose(&self) -> Csr {
        let mut cnt = vec![0usize; self.cols + 1];
        for &c in &self.col_id {
            cnt[c as usize + 1] += 1;
        }
        for j in 0..self.cols {
            cnt[j + 1] += cnt[j];
        }
        let row_ptr = cnt.clone();
        let mut col_id = vec![0u32; self.nnz()];
        let mut value = vec![0f32; self.nnz()];
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                let p = cnt[c as usize];
                col_id[p] = i as u32;
                value[p] = v;
                cnt[c as usize] += 1;
            }
        }
        Csr { rows: self.cols, cols: self.rows, row_ptr, col_id, value }
    }

    /// Convert to COO triplets.
    pub fn to_coo(&self) -> Coo {
        let mut row = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            row.extend(std::iter::repeat(i as u32).take(self.row_nnz(i)));
        }
        Coo {
            rows: self.rows,
            cols: self.cols,
            row,
            col: self.col_id.clone(),
            value: self.value.clone(),
        }
    }

    /// Convert to CSC (column-compressed).
    pub fn to_csc(&self) -> Csc {
        let t = self.transpose();
        Csc {
            rows: self.rows,
            cols: self.cols,
            col_ptr: t.row_ptr,
            row_id: t.col_id,
            value: t.value,
        }
    }

    /// Densify (row-major). Only for small test matrices.
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0f32; self.cols]; self.rows];
        for i in 0..self.rows {
            for (c, v) in self.row_iter(i) {
                d[i][c as usize] = v;
            }
        }
        d
    }

    /// Build from a dense row-major matrix, dropping exact zeros.
    pub fn from_dense(d: &[Vec<f32>]) -> Self {
        let rows = d.len();
        let cols = d.first().map_or(0, |r| r.len());
        let mut t = Vec::new();
        for (i, r) in d.iter().enumerate() {
            for (j, &v) in r.iter().enumerate() {
                if v != 0.0 {
                    t.push((i as u32, j as u32, v));
                }
            }
        }
        Self::from_triplets(rows, cols, t)
    }

    /// Total bytes of the CSR image given an element width (value bytes) and
    /// index width — what the DRAM traffic model charges for streaming the
    /// matrix (value + col_id per nonzero, row_ptr per row).
    pub fn storage_bytes(&self, value_bytes: usize, index_bytes: usize) -> usize {
        self.nnz() * (value_bytes + index_bytes) + (self.rows + 1) * index_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 1 example matrix:
    /// row 0 = {a@1, b@2}, with a=1.0, b=2.0 etc.
    fn fig1_matrix() -> Csr {
        Csr::from_triplets(
            4,
            4,
            vec![
                (0, 1, 1.0), // a
                (0, 2, 2.0), // b
                (1, 0, 3.0), // c
                (2, 2, 4.0), // d
                (2, 3, 5.0), // e
                (3, 1, 6.0), // f
            ],
        )
    }

    #[test]
    fn fig1_layout_matches_paper() {
        let a = fig1_matrix();
        assert_eq!(a.row_ptr, vec![0, 2, 3, 5, 6]);
        assert_eq!(a.row_cols(0), &[1, 2]);
        assert_eq!(a.row_values(0), &[1.0, 2.0]);
        assert_eq!(a.row_nnz(2), 2);
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(0, 0), 0.0);
    }

    #[test]
    fn try_new_validates() {
        assert!(Csr::try_new(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        // bad row_ptr head
        assert!(Csr::try_new(2, 2, vec![1, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_err());
        // non-monotone
        assert!(Csr::try_new(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0]).is_err());
        // col out of bounds
        assert!(Csr::try_new(1, 2, vec![0, 1], vec![5], vec![1.0]).is_err());
        // unsorted cols
        assert!(Csr::try_new(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 2.0]).is_err());
        // length mismatch
        assert!(Csr::try_new(1, 3, vec![0, 2], vec![0], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn triplets_sum_duplicates() {
        let a = Csr::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.5), (1, 1, 1.0)]);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.get(0, 0), 3.5);
    }

    #[test]
    fn transpose_round_trips() {
        let a = fig1_matrix();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_moves_entries() {
        let a = fig1_matrix();
        let t = a.transpose();
        assert_eq!(t.get(1, 0), 1.0);
        assert_eq!(t.get(2, 0), 2.0);
        assert_eq!(t.get(0, 1), 3.0);
    }

    #[test]
    fn dense_round_trips() {
        let a = fig1_matrix();
        let b = Csr::from_dense(&a.to_dense());
        assert_eq!(a, b);
    }

    #[test]
    fn coo_and_csc_round_trip() {
        let a = fig1_matrix();
        assert_eq!(a.to_coo().to_csr(), a);
        assert_eq!(a.to_csc().to_csr(), a);
    }

    #[test]
    fn identity_multiplies_like_identity() {
        let i = Csr::identity(4);
        assert_eq!(i.nnz(), 4);
        assert_eq!(i.get(2, 2), 1.0);
        assert_eq!(i.get(2, 3), 0.0);
    }

    #[test]
    fn storage_bytes_counts_csr_image() {
        let a = fig1_matrix();
        // 6 nnz * (4 value + 4 col_id) + 5 row_ptr * 4
        assert_eq!(a.storage_bytes(4, 4), 6 * 8 + 5 * 4);
    }

    #[test]
    fn density_matches_definition() {
        let a = fig1_matrix();
        assert!((a.density() - 6.0 / 16.0).abs() < 1e-12);
    }
}
