//! The paper's Table-I dataset registry.
//!
//! Each entry mirrors one SuiteSparse matrix used in the evaluation
//! (§IV.A, Table I): name, abbreviation, dimensions, nnz and density, plus
//! the structural family used to synthesise it (see [`gen`] and DESIGN.md §2
//! for the substitution rationale). `C = A × A` is the workload, exactly as
//! Matraptor and Extensor evaluate (§IV.A).

use super::gen::{self, Profile};
use super::Csr;

/// One Table-I dataset: the statistics of a SuiteSparse matrix plus a
/// synthesis profile reproducing its structure.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    /// SuiteSparse name, e.g. `web-Google`.
    pub name: &'static str,
    /// Paper abbreviation, e.g. `wg`.
    pub abbrev: &'static str,
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Structural family for synthesis.
    pub profile: Profile,
}

impl DatasetSpec {
    /// Density `nnz / (rows*cols)` — the paper's Table-I `Density` column.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Synthesise the full-scale matrix. Deterministic in `seed`.
    pub fn generate(&self, seed: u64) -> Csr {
        gen::generate(self.rows, self.cols, self.nnz, self.profile, seed ^ hash_name(self.name))
    }

    /// Synthesise a down-scaled instance: dims and nnz both divided by
    /// `factor`, which **preserves the mean row length** (the quantity the
    /// Gustavson work profile depends on — products/row ≈ row-nnz × mean
    /// B-row-nnz) at the cost of a `factor×` higher density. Used by fast
    /// tests, CI and the scaled benches; full-scale runs use
    /// [`DatasetSpec::generate`].
    pub fn generate_scaled(&self, seed: u64, factor: usize) -> Csr {
        // Clamp the factor so scaled instances keep at least ~8K rows: the
        // evaluated machines have up to 128 PEs, and a workload with only a
        // handful of rows per PE measures scheduling noise, not dataflow.
        let factor = factor.clamp(1, (self.rows / 8192).max(1));
        let rows = (self.rows / factor).max(8);
        let cols = (self.cols / factor).max(8);
        let nnz = (self.nnz / factor).clamp(1, rows * cols);
        gen::generate(rows, cols, nnz, self.profile, seed ^ hash_name(self.name))
    }
}

/// FNV-1a so each dataset gets a distinct stream for the same user seed.
fn hash_name(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// The paper's Table I, in its row order (sorted by increasing density).
/// One aligned row per dataset — kept tabular on purpose.
#[rustfmt::skip]
pub const TABLE_I: &[DatasetSpec] = &[
    DatasetSpec { name: "web-Google",     abbrev: "wg", rows: 916_000, cols: 916_000, nnz: 5_100_000, profile: Profile::PowerLaw { alpha: 0.8 } },
    DatasetSpec { name: "mario002",       abbrev: "m2", rows: 390_000, cols: 390_000, nnz: 2_100_000, profile: Profile::Banded { rel_bandwidth: 0.002, cluster: 3 } },
    DatasetSpec { name: "amazon0312",     abbrev: "az", rows: 401_000, cols: 401_000, nnz: 3_200_000, profile: Profile::PowerLaw { alpha: 0.7 } },
    DatasetSpec { name: "m133-b3",        abbrev: "mb", rows: 200_000, cols: 200_000, nnz: 801_000,   profile: Profile::Uniform },
    DatasetSpec { name: "scircuit",       abbrev: "sc", rows: 171_000, cols: 171_000, nnz: 959_000,   profile: Profile::Uniform },
    DatasetSpec { name: "p2pGnutella31",  abbrev: "pg", rows: 63_000,  cols: 63_000,  nnz: 148_000,   profile: Profile::PowerLaw { alpha: 0.7 } },
    DatasetSpec { name: "offshore",       abbrev: "of", rows: 260_000, cols: 260_000, nnz: 4_200_000, profile: Profile::Banded { rel_bandwidth: 0.003, cluster: 5 } },
    DatasetSpec { name: "cage12",         abbrev: "cg", rows: 130_000, cols: 130_000, nnz: 2_000_000, profile: Profile::Banded { rel_bandwidth: 0.01, cluster: 4 } },
    DatasetSpec { name: "2cubes-sphere",  abbrev: "cs", rows: 101_000, cols: 101_000, nnz: 1_600_000, profile: Profile::Banded { rel_bandwidth: 0.005, cluster: 5 } },
    DatasetSpec { name: "filter3D",       abbrev: "f3", rows: 106_000, cols: 106_000, nnz: 2_700_000, profile: Profile::Banded { rel_bandwidth: 0.005, cluster: 6 } },
    DatasetSpec { name: "ca-CondMat",     abbrev: "cc", rows: 23_000,  cols: 23_000,  nnz: 187_000,   profile: Profile::PowerLaw { alpha: 0.6 } },
    DatasetSpec { name: "wikiVote",       abbrev: "wv", rows: 8_300,   cols: 8_300,   nnz: 104_000,   profile: Profile::PowerLaw { alpha: 0.6 } },
    DatasetSpec { name: "poisson3Da",     abbrev: "p3", rows: 14_000,  cols: 14_000,  nnz: 353_000,   profile: Profile::Banded { rel_bandwidth: 0.02, cluster: 5 } },
    DatasetSpec { name: "facebook",       abbrev: "fb", rows: 4_000,   cols: 4_000,   nnz: 176_000,   profile: Profile::PowerLaw { alpha: 0.5 } },
];

/// Look a dataset up by SuiteSparse name or paper abbreviation.
pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
    TABLE_I
        .iter()
        .find(|d| d.name.eq_ignore_ascii_case(name) || d.abbrev.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_fourteen_entries() {
        assert_eq!(TABLE_I.len(), 14);
    }

    #[test]
    fn densities_match_paper_column() {
        // Paper Table I reports densities to 2 significant figures.
        let expect = [
            ("wg", 6.1e-6),
            ("m2", 1.3e-5),
            ("az", 1.9e-5),
            ("mb", 2.0e-5),
            ("sc", 3.2e-5),
            ("pg", 3.7e-5),
            ("of", 6.2e-5),
            ("cg", 1.1e-4),
            ("cs", 1.5e-4),
            ("f3", 2.4e-4),
            ("cc", 3.5e-4),
            ("wv", 1.5e-3),
            ("p3", 1.8e-3),
            ("fb", 1.1e-2),
        ];
        for (ab, d) in expect {
            let spec = by_name(ab).unwrap();
            let rel = (spec.density() - d).abs() / d;
            assert!(rel < 0.25, "{ab}: density {} vs paper {d}", spec.density());
        }
    }

    #[test]
    fn lookup_by_both_names() {
        assert_eq!(by_name("web-Google").unwrap().abbrev, "wg");
        assert_eq!(by_name("WG").unwrap().name, "web-Google");
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn scaled_generation_preserves_row_profile() {
        for spec in TABLE_I {
            let factor = 64;
            let a = spec.generate_scaled(1, factor);
            assert!(a.rows() >= 8);
            assert!(a.nnz() > 0, "{} generated empty", spec.name);
            // Mean row nnz (the Gustavson work driver) is preserved.
            let full_mean = spec.nnz as f64 / spec.rows as f64;
            let scaled_mean = a.nnz() as f64 / a.rows() as f64;
            assert!(
                (scaled_mean / full_mean - 1.0).abs() < 0.35,
                "{}: mean row nnz {scaled_mean:.2} vs full {full_mean:.2}",
                spec.name
            );
        }
    }

    #[test]
    fn wikivote_full_scale_matches_table() {
        let spec = by_name("wv").unwrap();
        let a = spec.generate(7);
        assert_eq!(a.rows(), 8_300);
        assert_eq!(a.nnz(), 104_000);
    }
}
