//! Synthetic sparse-workload generators.
//!
//! The SuiteSparse matrices of the paper's Table I are not redistributable
//! inside this environment, so each dataset is synthesised to match the
//! statistics the simulator is actually sensitive to: dimensions, nnz,
//! density, and the row-length / locality profile of its matrix family
//! (power-law web/social graphs, banded FEM/PDE discretisations, uniform
//! circuit-like patterns). See DESIGN.md §2 for the substitution argument.

use super::{Csr, SplitMix64};

/// The structural family a generator mimics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Profile {
    /// Row lengths and column positions uniform at random (circuit-like,
    /// e.g. `scircuit`, `p2p-Gnutella31`).
    Uniform,
    /// Zipf-distributed row lengths and skewed column popularity
    /// (web / social graphs, e.g. `web-Google`, `wikiVote`, `facebook`).
    PowerLaw {
        /// Zipf exponent for the row-degree distribution (≈1.5–2.2 for webs).
        alpha: f64,
    },
    /// Nonzeros clustered in short contiguous runs inside a diagonal band
    /// (FEM / PDE meshes, e.g. `offshore`, `filter3D`, `poisson3Da`).
    /// These clusters are precisely the locality Maple's multi-MAC PE
    /// exploits (paper §I: "local clusters of non-zero values").
    Banded {
        /// Half-width of the diagonal band as a fraction of `cols`.
        rel_bandwidth: f64,
        /// Mean contiguous-run length inside the band.
        cluster: usize,
    },
}

/// Generate a `rows × cols` CSR matrix with exactly `nnz` nonzeros drawn
/// according to `profile`. Deterministic in `seed`.
pub fn generate(rows: usize, cols: usize, nnz: usize, profile: Profile, seed: u64) -> Csr {
    assert!(nnz <= rows * cols, "nnz exceeds capacity");
    let mut rng = SplitMix64::new(seed);
    let counts = match profile {
        Profile::Uniform => spread_counts(rows, cols, nnz, &mut rng, 0.0),
        Profile::PowerLaw { alpha } => zipf_counts(rows, cols, nnz, alpha, &mut rng),
        Profile::Banded { .. } => spread_counts(rows, cols, nnz, &mut rng, 0.15),
    };
    debug_assert_eq!(counts.iter().sum::<usize>(), nnz);

    let mut row_ptr = Vec::with_capacity(rows + 1);
    row_ptr.push(0usize);
    let mut col_id = Vec::with_capacity(nnz);
    let mut value = Vec::with_capacity(nnz);
    let mut scratch: Vec<u32> = Vec::new();

    for (i, &k) in counts.iter().enumerate() {
        scratch.clear();
        match profile {
            Profile::Uniform | Profile::PowerLaw { .. } => {
                sample_distinct(cols, k, &mut rng, &mut scratch);
            }
            Profile::Banded { rel_bandwidth, cluster } => {
                sample_banded(i, rows, cols, k, rel_bandwidth, cluster, &mut rng, &mut scratch);
            }
        }
        scratch.sort_unstable();
        scratch.dedup();
        // Top up if clustering produced overlaps (keeps nnz exact). Banded
        // rows top up *inside the band* so the structure stays banded.
        let (lo, hi) = match profile {
            Profile::Banded { rel_bandwidth, cluster } => {
                band_range(i, rows, cols, rel_bandwidth, cluster)
            }
            _ => (0u32, cols as u32 - 1),
        };
        let mut span = (hi - lo + 1) as u64;
        let (mut lo, mut hi) = (lo, hi);
        while scratch.len() < k {
            if span <= scratch.len() as u64 {
                // Band saturated: widen it symmetrically until k fits.
                lo = lo.saturating_sub(1);
                hi = (hi + 1).min(cols as u32 - 1);
                span = (hi - lo + 1) as u64;
            }
            let c = lo + rng.below(span) as u32;
            if let Err(p) = scratch.binary_search(&c) {
                scratch.insert(p, c);
            }
        }
        for &c in scratch.iter() {
            col_id.push(c);
            value.push(rng.value());
        }
        row_ptr.push(col_id.len());
    }

    Csr::try_new(rows, cols, row_ptr, col_id, value).expect("generator produced invalid CSR")
}

/// Row counts: near-uniform with optional multiplicative jitter.
fn spread_counts(
    rows: usize,
    cols: usize,
    nnz: usize,
    rng: &mut SplitMix64,
    jitter: f64,
) -> Vec<usize> {
    let mut counts = vec![nnz / rows; rows];
    let mut rem = nnz - (nnz / rows) * rows;
    // Distribute the remainder over random rows.
    while rem > 0 {
        let i = rng.below(rows as u64) as usize;
        if counts[i] < cols {
            counts[i] += 1;
            rem -= 1;
        }
    }
    if jitter > 0.0 {
        // Move entries between random row pairs to create mild variance
        // without changing the total.
        let moves = (rows as f64 * jitter) as usize;
        for _ in 0..moves {
            let a = rng.below(rows as u64) as usize;
            let b = rng.below(rows as u64) as usize;
            if counts[a] > 1 && counts[b] < cols {
                counts[a] -= 1;
                counts[b] += 1;
            }
        }
    }
    counts
}

/// Zipf row-length distribution scaled to sum exactly to `nnz`.
fn zipf_counts(
    rows: usize,
    cols: usize,
    nnz: usize,
    alpha: f64,
    rng: &mut SplitMix64,
) -> Vec<usize> {
    // Weight w_r = (r+1)^-alpha over a random permutation of rows, so heavy
    // rows are scattered (as in real web graphs after vertex relabeling).
    // Degrees are capped at 100× the mean: real web/social graphs have
    // max-degree ≈ 10²× mean (web-Google: max out-degree 456 vs mean 5.6),
    // whereas an uncapped Zipf head grows with the matrix size.
    let cap = ((100 * nnz) / rows).max(8).min(cols);
    let mut weights: Vec<f64> = (0..rows).map(|r| ((r + 1) as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    // Fisher–Yates permute the weights.
    for i in (1..rows).rev() {
        let j = rng.below((i + 1) as u64) as usize;
        weights.swap(i, j);
    }
    let mut counts: Vec<usize> = weights
        .iter()
        .map(|w| ((w / total) * nnz as f64).floor() as usize)
        .map(|c| c.min(cap))
        .collect();
    let mut have: usize = counts.iter().sum();
    // Fix rounding residual; add to (or steal from) random rows.
    while have < nnz {
        let i = rng.below(rows as u64) as usize;
        if counts[i] < cap {
            counts[i] += 1;
            have += 1;
        }
    }
    while have > nnz {
        let i = rng.below(rows as u64) as usize;
        if counts[i] > 0 {
            counts[i] -= 1;
            have -= 1;
        }
    }
    counts
}

/// `k` distinct columns uniform over `[0, cols)`.
fn sample_distinct(cols: usize, k: usize, rng: &mut SplitMix64, out: &mut Vec<u32>) {
    debug_assert!(k <= cols);
    if k * 4 >= cols {
        // Dense-ish row: reservoir-select k of cols.
        let mut chosen = 0usize;
        for c in 0..cols {
            let remaining = cols - c;
            let needed = k - chosen;
            if rng.below(remaining as u64) < needed as u64 {
                out.push(c as u32);
                chosen += 1;
                if chosen == k {
                    break;
                }
            }
        }
    } else {
        while out.len() < k {
            let c = rng.below(cols as u64) as u32;
            if !out.contains(&c) {
                out.push(c);
            }
        }
    }
}

/// The diagonal band `[lo, hi]` for row `i` under a banded profile.
fn band_range(
    i: usize,
    rows: usize,
    cols: usize,
    rel_bandwidth: f64,
    cluster: usize,
) -> (u32, u32) {
    let center = (i as f64 / rows as f64 * cols as f64) as i64;
    let half = ((rel_bandwidth * cols as f64) as i64).max(cluster as i64 + 1);
    let lo = (center - half).max(0) as u32;
    let hi = ((center + half) as u32).min(cols as u32 - 1);
    (lo, hi)
}

/// `k` columns clustered in runs of mean length `cluster` inside a diagonal
/// band of half-width `rel_bandwidth * cols` around row `i`'s diagonal.
#[allow(clippy::too_many_arguments)]
fn sample_banded(
    i: usize,
    rows: usize,
    cols: usize,
    k: usize,
    rel_bandwidth: f64,
    cluster: usize,
    rng: &mut SplitMix64,
    out: &mut Vec<u32>,
) {
    let (lo, hi) = band_range(i, rows, cols, rel_bandwidth, cluster);
    let span = (hi - lo + 1) as u64;
    while out.len() < k {
        let start = lo + rng.below(span) as u32;
        let run = 1 + rng.below(2 * cluster as u64) as usize;
        for d in 0..run {
            if out.len() >= k {
                break;
            }
            let c = start.saturating_add(d as u32).min(cols as u32 - 1);
            out.push(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats;

    #[test]
    fn uniform_hits_exact_nnz() {
        let a = generate(100, 100, 500, Profile::Uniform, 1);
        assert_eq!(a.nnz(), 500);
        assert_eq!(a.rows(), 100);
    }

    #[test]
    fn powerlaw_hits_exact_nnz_and_is_skewed() {
        let a = generate(1000, 1000, 8000, Profile::PowerLaw { alpha: 1.8 }, 2);
        assert_eq!(a.nnz(), 8000);
        let s = stats::row_stats(&a);
        // A Zipf profile must have max row length far above the mean.
        assert!(
            s.max_row_nnz as f64 > 4.0 * s.mean_row_nnz,
            "max={} mean={}",
            s.max_row_nnz,
            s.mean_row_nnz
        );
    }

    #[test]
    fn banded_stays_in_band_and_clusters() {
        let a = generate(
            200,
            200,
            2000,
            Profile::Banded { rel_bandwidth: 0.05, cluster: 4 },
            3,
        );
        assert_eq!(a.nnz(), 2000);
        // Band check: every nonzero within ~band of the diagonal.
        for i in 0..a.rows() {
            for &c in a.row_cols(i) {
                let d = (c as i64 - i as i64).abs();
                assert!(d <= 25, "row {i} col {c} outside band");
            }
        }
        // Clustered profile ⇒ high adjacency fraction.
        let s = stats::row_stats(&a);
        assert!(s.adjacency_fraction > 0.3, "adjacency {}", s.adjacency_fraction);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(50, 60, 300, Profile::PowerLaw { alpha: 2.0 }, 42);
        let b = generate(50, 60, 300, Profile::PowerLaw { alpha: 2.0 }, 42);
        assert_eq!(a, b);
        let c = generate(50, 60, 300, Profile::PowerLaw { alpha: 2.0 }, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn full_capacity_matrix() {
        let a = generate(8, 8, 64, Profile::Uniform, 5);
        assert_eq!(a.nnz(), 64);
        assert_eq!(a.density(), 1.0);
    }
}
