//! Compressed Sparse Column — needed by the inner-product dataflow baseline
//! (B is traversed by column when computing C[i,j] = A[i,:]·B[:,j]).

use super::Csr;

/// A sparse matrix in CSC form: the column-major dual of [`Csr`].
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// `col_ptr[j]` = offset of column j's first nonzero; length `cols + 1`.
    pub col_ptr: Vec<usize>,
    /// Row coordinate of each nonzero.
    pub row_id: Vec<u32>,
    /// The nonzero values, column-major.
    pub value: Vec<f32>,
}

impl Csc {
    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// Number of nonzeros in column `j`.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// The row ids of column `j`.
    #[inline]
    pub fn col_rows(&self, j: usize) -> &[u32] {
        &self.row_id[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// The nonzero values of column `j`.
    #[inline]
    pub fn col_values(&self, j: usize) -> &[f32] {
        &self.value[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Iterate `(row, value)` pairs of column `j`.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.col_rows(j).iter().copied().zip(self.col_values(j).iter().copied())
    }

    /// Convert to CSR.
    pub fn to_csr(&self) -> Csr {
        let mut t = Vec::with_capacity(self.nnz());
        for j in 0..self.cols {
            for (r, v) in self.col_iter(j) {
                t.push((r, j as u32, v));
            }
        }
        Csr::from_triplets(self.rows, self.cols, t)
    }
}

#[cfg(test)]
mod tests {
    use super::super::Csr;

    #[test]
    fn csc_columns_match_csr_rows_of_transpose() {
        let a = Csr::from_triplets(
            3,
            4,
            vec![(0, 1, 1.0), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0)],
        );
        let c = a.to_csc();
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.col_nnz(1), 2);
        assert_eq!(c.col_rows(1), &[0, 1]);
        assert_eq!(c.col_values(1), &[1.0, 3.0]);
        assert_eq!(c.col_nnz(2), 0);
        assert_eq!(c.to_csr(), a);
    }
}
