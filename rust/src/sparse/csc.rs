//! Compressed Sparse Column — needed by the inner-product dataflow baseline
//! (B is traversed by column when computing C[i,j] = A[i,:]·B[:,j]).

use super::{Coo, Csr};

/// A sparse matrix in CSC form: the column-major dual of [`Csr`].
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    /// `col_ptr[j]` = offset of column j's first nonzero; length `cols + 1`.
    pub col_ptr: Vec<usize>,
    /// Row coordinate of each nonzero.
    pub row_id: Vec<u32>,
    /// The nonzero values, column-major.
    pub value: Vec<f32>,
}

impl Csc {
    /// An empty `rows × cols` matrix.
    pub fn zero(rows: usize, cols: usize) -> Self {
        Self { rows, cols, col_ptr: vec![0; cols + 1], row_id: Vec::new(), value: Vec::new() }
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// Number of nonzeros in column `j`.
    #[inline]
    pub fn col_nnz(&self, j: usize) -> usize {
        self.col_ptr[j + 1] - self.col_ptr[j]
    }

    /// The row ids of column `j`.
    #[inline]
    pub fn col_rows(&self, j: usize) -> &[u32] {
        &self.row_id[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// The nonzero values of column `j`.
    #[inline]
    pub fn col_values(&self, j: usize) -> &[f32] {
        &self.value[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Iterate `(row, value)` pairs of column `j`.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        self.col_rows(j).iter().copied().zip(self.col_values(j).iter().copied())
    }

    /// Convert to CSR.
    pub fn to_csr(&self) -> Csr {
        let mut t = Vec::with_capacity(self.nnz());
        for j in 0..self.cols {
            for (r, v) in self.col_iter(j) {
                t.push((r, j as u32, v));
            }
        }
        Csr::from_triplets(self.rows, self.cols, t)
    }

    /// Convert to COO, in canonical (row-major, duplicate-summed) order —
    /// the symmetric inverse of [`Coo::to_csc`], not a raw column-major
    /// dump of the CSC arrays.
    pub fn to_coo(&self) -> Coo {
        self.to_csr().to_coo()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Csc, Csr};

    #[test]
    fn to_coo_is_canonical_row_major() {
        let a = Csr::from_triplets(
            3,
            4,
            vec![(0, 1, 1.0), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0)],
        );
        let c = a.to_csc();
        let coo = c.to_coo();
        // Canonical (row-major) order, not a column-major dump of the
        // CSC arrays — the symmetric inverse of `Coo::to_csc`.
        assert_eq!(coo.row, vec![0, 0, 1, 2]);
        assert_eq!(coo.col, vec![1, 3, 1, 0]);
        assert_eq!(coo.to_csc(), c);
    }

    #[test]
    fn zero_is_empty() {
        let z = Csc::zero(2, 5);
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.col_ptr.len(), 6);
        assert_eq!(z.to_csr(), Csr::zero(2, 5));
    }

    #[test]
    fn csc_columns_match_csr_rows_of_transpose() {
        let a = Csr::from_triplets(
            3,
            4,
            vec![(0, 1, 1.0), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0)],
        );
        let c = a.to_csc();
        assert_eq!(c.nnz(), 4);
        assert_eq!(c.col_nnz(1), 2);
        assert_eq!(c.col_rows(1), &[0, 1]);
        assert_eq!(c.col_values(1), &[1.0, 3.0]);
        assert_eq!(c.col_nnz(2), 0);
        assert_eq!(c.to_csr(), a);
    }
}
