//! The unified compression-format layer.
//!
//! The paper fixes CSR as the one true operand encoding (§II.B), but the
//! format choice itself is a results dimension: per sparsity regime a
//! bitmap or blocked encoding can shrink the operand image (and therefore
//! the compulsory DRAM traffic) well below CSR, while COO/CSC pay for
//! their redundant or column-major metadata. This module promotes the
//! format to a first-class value:
//!
//! * [`SparseFormat`] — the closed set of supported encodings, with stable
//!   CLI labels (`csr | csc | coo | bitmap | blocked`) and codec tags.
//! * [`SparseMatrix`] — one dispatch point over concrete encodings
//!   ([`Csr`], [`Csc`], [`Coo`], [`Bitmap`], [`BlockedCsr`]) with uniform
//!   constructors, dims/nnz accessors, canonical triplet iteration, exact
//!   per-format storage accounting ([`StorageWords`]), and explicit
//!   [`SparseMatrix::convert`] whose cost ([`ConvertCost`]) is modeled
//!   from the streamed words, not hand-waved.
//! * [`FormatPlan`] — the closed-form operand-traffic plan the simulator
//!   charges per workload: per-matrix format images, the column-major
//!   gather penalty, and the CSR→format conversion cost when the dataset's
//!   native encoding differs from the axis point.
//!
//! Storage model (32-bit index and value words, an `m × n` matrix with
//! `nnz` stored entries):
//!
//! | format    | index words                  | value words    |
//! |-----------|------------------------------|----------------|
//! | `csr`     | `nnz + m + 1`                | `nnz`          |
//! | `csc`     | `nnz + n + 1`                | `nnz`          |
//! | `coo`     | `2·nnz`                      | `nnz`          |
//! | `bitmap`  | `m · ⌈n/32⌉`                 | `nnz`          |
//! | `blocked` | `occupied + ⌈m/4⌉ + 1`       | `16·occupied`  |
//!
//! `occupied` is the number of nonempty 4×4 blocks. The *engine-side*
//! estimate ([`SparseFormat::estimate_words`]) upper-bounds it as
//! `min(nnz, ⌈m/4⌉·⌈n/4⌉)` so the traffic plan is a pure function of the
//! workload totals — cold (matrix in hand) and warm (profile loaded from
//! disk) runs charge identical traffic by construction.

use std::collections::BTreeMap;

use super::{Coo, Csc, Csr};

/// A supported sparse compression format. The CLI label (`Display` /
/// `FromStr`) and the codec tag are both stable: artifacts and sweep
/// labels written today decode tomorrow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum SparseFormat {
    /// Compressed sparse row — the paper's native operand encoding.
    #[default]
    Csr,
    /// Compressed sparse column: CSR's column-major dual.
    Csc,
    /// Coordinate triplets.
    Coo,
    /// Per-row occupancy bitmap (32-bit mask words) + packed values.
    Bitmap,
    /// CSR over dense 4×4 blocks (one block-column id + 16 values each).
    BlockedCsr,
}

impl SparseFormat {
    /// Every format, in label order — the full `--axis fmt=` point set.
    pub const ALL: [SparseFormat; 5] = [
        SparseFormat::Csr,
        SparseFormat::Csc,
        SparseFormat::Coo,
        SparseFormat::Bitmap,
        SparseFormat::BlockedCsr,
    ];

    /// The stable CLI/report label.
    pub fn label(self) -> &'static str {
        match self {
            SparseFormat::Csr => "csr",
            SparseFormat::Csc => "csc",
            SparseFormat::Coo => "coo",
            SparseFormat::Bitmap => "bitmap",
            SparseFormat::BlockedCsr => "blocked",
        }
    }

    /// Stable on-disk tag (workload codec, cache filenames).
    pub fn tag(self) -> u8 {
        match self {
            SparseFormat::Csr => 0,
            SparseFormat::Csc => 1,
            SparseFormat::Coo => 2,
            SparseFormat::Bitmap => 3,
            SparseFormat::BlockedCsr => 4,
        }
    }

    /// Inverse of [`SparseFormat::tag`]; `None` for a foreign tag.
    pub fn from_tag(tag: u8) -> Option<Self> {
        SparseFormat::ALL.into_iter().find(|f| f.tag() == tag)
    }

    /// Closed-form storage estimate (total 32-bit words) for an
    /// `rows × cols` matrix with `nnz` stored entries.
    ///
    /// Exact for `csr`/`csc`/`coo`/`bitmap`; for `blocked` the occupied
    /// block count is upper-bounded by `min(nnz, ⌈m/4⌉·⌈n/4⌉)` (every
    /// nonzero occupies at most one block, and there are only so many
    /// block slots), so the estimate depends on workload totals alone and
    /// the traffic plan stays identical between cold and warm runs.
    pub fn estimate_words(self, rows: usize, cols: usize, nnz: u64) -> u64 {
        let (m, n) = (rows as u64, cols as u64);
        match self {
            SparseFormat::Csr => 2 * nnz + m + 1,
            SparseFormat::Csc => 2 * nnz + n + 1,
            SparseFormat::Coo => 3 * nnz,
            SparseFormat::Bitmap => nnz + m * n.div_ceil(32),
            SparseFormat::BlockedCsr => {
                let occupied = nnz.min(m.div_ceil(4) * n.div_ceil(4));
                17 * occupied + m.div_ceil(4) + 1
            }
        }
    }
}

impl std::fmt::Display for SparseFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for SparseFormat {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        SparseFormat::ALL
            .into_iter()
            .find(|f| f.label() == s)
            .ok_or_else(|| format!("unknown format {s:?} (csr | csc | coo | bitmap | blocked)"))
    }
}

/// A sparse matrix as a per-row occupancy bitmap plus packed values:
/// `mask` holds `rows · ⌈cols/32⌉` 32-bit words row-major (bit `c % 32` of
/// word `⌊c/32⌋` marks column `c`), and `value` holds the nonzeros in
/// (row, ascending column) order. Metadata cost is independent of `nnz`,
/// which beats CSR once density clears ~1/32.
#[derive(Debug, Clone, PartialEq)]
pub struct Bitmap {
    rows: usize,
    cols: usize,
    /// Occupancy words, row-major; length `rows * words_per_row()`.
    pub mask: Vec<u32>,
    /// Nonzero values in (row, ascending column) order.
    pub value: Vec<f32>,
}

impl Bitmap {
    /// Encode a CSR matrix. Lossless: stored zeros keep their mask bit.
    pub fn from_csr(a: &Csr) -> Self {
        let wpr = a.cols().div_ceil(32);
        let mut mask = vec![0u32; a.rows() * wpr];
        let mut value = Vec::with_capacity(a.nnz());
        for i in 0..a.rows() {
            for (c, v) in a.row_iter(i) {
                mask[i * wpr + c as usize / 32] |= 1u32 << (c % 32);
                value.push(v);
            }
        }
        Self { rows: a.rows(), cols: a.cols(), mask, value }
    }

    /// Decode back to canonical CSR.
    pub fn to_csr(&self) -> Csr {
        let wpr = self.words_per_row();
        let mut t = Vec::with_capacity(self.value.len());
        let mut p = 0;
        for i in 0..self.rows {
            for w in 0..wpr {
                let mut bits = self.mask[i * wpr + w];
                while bits != 0 {
                    let b = bits.trailing_zeros();
                    bits &= bits - 1;
                    t.push((i as u32, (w * 32) as u32 + b, self.value[p]));
                    p += 1;
                }
            }
        }
        Csr::from_triplets(self.rows, self.cols, t)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored values (set mask bits).
    #[inline]
    pub fn nnz(&self) -> usize {
        self.value.len()
    }

    /// 32-bit mask words per row, `⌈cols/32⌉`.
    #[inline]
    pub fn words_per_row(&self) -> usize {
        self.cols.div_ceil(32)
    }
}

/// CSR over dense 4×4 blocks (Labini-style): block rows are compressed
/// like CSR rows, each occupied block carrying one block-column id and a
/// dense 16-value payload (row-major inside the block). Explicit zeros
/// *inside* an occupied block are representable, but a stored zero cannot
/// be told apart from structural absence on decode — [`BlockedCsr::to_csr`]
/// drops exact-zero entries.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockedCsr {
    rows: usize,
    cols: usize,
    /// Offset of each block row's first occupied block; length
    /// `⌈rows/4⌉ + 1`.
    pub block_ptr: Vec<usize>,
    /// Block-column coordinate of each occupied block, ascending per
    /// block row.
    pub block_col: Vec<u32>,
    /// Dense 4×4 payload per occupied block, row-major inside the block.
    pub block_values: Vec<[f32; 16]>,
}

impl BlockedCsr {
    /// Side length of the dense blocks.
    pub const BLOCK: usize = 4;

    /// Encode a CSR matrix, materialising every 4×4 block that holds at
    /// least one nonzero.
    pub fn from_csr(a: &Csr) -> Self {
        let mut blocks: BTreeMap<(u32, u32), [f32; 16]> = BTreeMap::new();
        for i in 0..a.rows() {
            for (c, v) in a.row_iter(i) {
                let slot = blocks.entry(((i / 4) as u32, c / 4)).or_insert([0.0; 16]);
                slot[(i % 4) * 4 + (c % 4) as usize] = v;
            }
        }
        let block_rows = a.rows().div_ceil(4);
        let mut block_ptr = vec![0usize; block_rows + 1];
        let mut block_col = Vec::with_capacity(blocks.len());
        let mut block_values = Vec::with_capacity(blocks.len());
        for (&(br, bc), vals) in &blocks {
            block_ptr[br as usize + 1] += 1;
            block_col.push(bc);
            block_values.push(*vals);
        }
        for i in 0..block_rows {
            block_ptr[i + 1] += block_ptr[i];
        }
        Self { rows: a.rows(), cols: a.cols(), block_ptr, block_col, block_values }
    }

    /// Decode back to canonical CSR, dropping exact-zero block slots.
    pub fn to_csr(&self) -> Csr {
        let mut t = Vec::new();
        for br in 0..self.block_ptr.len() - 1 {
            for p in self.block_ptr[br]..self.block_ptr[br + 1] {
                let bc = self.block_col[p];
                for (k, &v) in self.block_values[p].iter().enumerate() {
                    if v != 0.0 {
                        t.push(((br * 4 + k / 4) as u32, bc * 4 + (k % 4) as u32, v));
                    }
                }
            }
        }
        Csr::from_triplets(self.rows, self.cols, t)
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of nonzero entries across all occupied blocks.
    pub fn nnz(&self) -> usize {
        self.block_values.iter().flatten().filter(|&&v| v != 0.0).count()
    }

    /// Number of occupied (materialised) 4×4 blocks.
    #[inline]
    pub fn occupied_blocks(&self) -> usize {
        self.block_col.len()
    }
}

/// Exact storage footprint of one encoded matrix, split into index
/// (metadata) and value words — both 32-bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StorageWords {
    /// Structural metadata: pointers, coordinates, mask words.
    pub index_words: u64,
    /// Payload values (16 per block for `blocked`, `nnz` otherwise).
    pub value_words: u64,
}

impl StorageWords {
    /// Total words streamed when the image crosses DRAM.
    #[inline]
    pub fn total(self) -> u64 {
        self.index_words + self.value_words
    }
}

/// The modeled cost of one format conversion: the converter streams the
/// source image in and the destination image out (one word per cycle), so
/// both terms are pure functions of the two footprints.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ConvertCost {
    /// Cycles spent converting (read + write words at one word/cycle).
    pub cycles: u64,
    /// DRAM words moved (source image read + destination image written).
    pub dram_words: u64,
}

/// One sparse matrix behind one dispatch point: every encoding supported
/// by [`SparseFormat`], with uniform constructors, accessors, exact
/// storage accounting, and modeled conversion.
///
/// All conversions are *canonical*: they route through [`Csr`] (sorted,
/// duplicate-summed — see the module docs of [`crate::sparse`]), so any
/// conversion chain that starts and ends at the same format is an exact
/// identity on canonical matrices.
#[derive(Debug, Clone, PartialEq)]
pub enum SparseMatrix {
    Csr(Csr),
    Csc(Csc),
    Coo(Coo),
    Bitmap(Bitmap),
    BlockedCsr(BlockedCsr),
}

impl SparseMatrix {
    /// Encode a CSR matrix (the suite's native form) as `format`.
    pub fn from_csr(format: SparseFormat, a: &Csr) -> Self {
        match format {
            SparseFormat::Csr => SparseMatrix::Csr(a.clone()),
            SparseFormat::Csc => SparseMatrix::Csc(a.to_csc()),
            SparseFormat::Coo => SparseMatrix::Coo(a.to_coo()),
            SparseFormat::Bitmap => SparseMatrix::Bitmap(Bitmap::from_csr(a)),
            SparseFormat::BlockedCsr => SparseMatrix::BlockedCsr(BlockedCsr::from_csr(a)),
        }
    }

    /// Which encoding this matrix is stored in.
    pub fn format(&self) -> SparseFormat {
        match self {
            SparseMatrix::Csr(_) => SparseFormat::Csr,
            SparseMatrix::Csc(_) => SparseFormat::Csc,
            SparseMatrix::Coo(_) => SparseFormat::Coo,
            SparseMatrix::Bitmap(_) => SparseFormat::Bitmap,
            SparseMatrix::BlockedCsr(_) => SparseFormat::BlockedCsr,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            SparseMatrix::Csr(m) => m.rows(),
            SparseMatrix::Csc(m) => m.rows,
            SparseMatrix::Coo(m) => m.rows,
            SparseMatrix::Bitmap(m) => m.rows(),
            SparseMatrix::BlockedCsr(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SparseMatrix::Csr(m) => m.cols(),
            SparseMatrix::Csc(m) => m.cols,
            SparseMatrix::Coo(m) => m.cols,
            SparseMatrix::Bitmap(m) => m.cols(),
            SparseMatrix::BlockedCsr(m) => m.cols(),
        }
    }

    /// Number of stored nonzero entries.
    pub fn nnz(&self) -> usize {
        match self {
            SparseMatrix::Csr(m) => m.nnz(),
            SparseMatrix::Csc(m) => m.nnz(),
            SparseMatrix::Coo(m) => m.nnz(),
            SparseMatrix::Bitmap(m) => m.nnz(),
            SparseMatrix::BlockedCsr(m) => m.nnz(),
        }
    }

    /// Decode to canonical CSR.
    pub fn to_csr(&self) -> Csr {
        match self {
            SparseMatrix::Csr(m) => m.clone(),
            SparseMatrix::Csc(m) => m.to_csr(),
            SparseMatrix::Coo(m) => m.to_csr(),
            SparseMatrix::Bitmap(m) => m.to_csr(),
            SparseMatrix::BlockedCsr(m) => m.to_csr(),
        }
    }

    /// Canonical `(row, col, value)` triplets: row-major, ascending column
    /// within a row, duplicates summed — identical for any two encodings
    /// of the same matrix.
    pub fn triplets(&self) -> Vec<(u32, u32, f32)> {
        let a = self.to_csr();
        let mut t = Vec::with_capacity(a.nnz());
        for i in 0..a.rows() {
            for (c, v) in a.row_iter(i) {
                t.push((i as u32, c, v));
            }
        }
        t
    }

    /// Exact storage footprint of this concrete image (see the module-doc
    /// table; `blocked` uses the *actual* occupied block count).
    pub fn storage_words(&self) -> StorageWords {
        match self {
            SparseMatrix::Csr(m) => StorageWords {
                index_words: m.nnz() as u64 + m.rows() as u64 + 1,
                value_words: m.nnz() as u64,
            },
            SparseMatrix::Csc(m) => StorageWords {
                index_words: m.nnz() as u64 + m.cols as u64 + 1,
                value_words: m.nnz() as u64,
            },
            SparseMatrix::Coo(m) => StorageWords {
                index_words: 2 * m.nnz() as u64,
                value_words: m.nnz() as u64,
            },
            SparseMatrix::Bitmap(m) => StorageWords {
                index_words: m.mask.len() as u64,
                value_words: m.nnz() as u64,
            },
            SparseMatrix::BlockedCsr(m) => StorageWords {
                index_words: m.occupied_blocks() as u64 + m.block_ptr.len() as u64,
                value_words: 16 * m.occupied_blocks() as u64,
            },
        }
    }

    /// Convert to `to`, returning the re-encoded matrix and the modeled
    /// cost: the converter streams the source image in and the destination
    /// image out, so `dram_words = src.total() + dst.total()` and
    /// `cycles = dram_words` (one word per cycle). Converting to the
    /// current format is free and returns a clone.
    pub fn convert(&self, to: SparseFormat) -> (SparseMatrix, ConvertCost) {
        if self.format() == to {
            return (self.clone(), ConvertCost::default());
        }
        let read = self.storage_words().total();
        let out = SparseMatrix::from_csr(to, &self.to_csr());
        let write = out.storage_words().total();
        let cost = ConvertCost { cycles: read + write, dram_words: read + write };
        (out, cost)
    }
}

/// The per-workload operand-traffic plan for one format: how many DRAM
/// words each matrix image costs, plus the format-specific penalties the
/// accelerator model charges. A plan is a **pure function of the workload
/// totals** (dims + nnz counts) via [`FormatPlan::from_totals`], never of
/// the concrete matrices — that keeps cold and warm (disk-cached) runs
/// bit-identical.
///
/// Terms:
/// * `a/b/c_words` — the format images of A (`rows × rows_b`),
///   B (`rows_b × cols`) and C (`rows × cols`).
/// * `gather_words` — extra operand traffic for column-major layouts: the
///   row-wise dataflow walks A and B by row, so a CSC image pays one
///   extra pointer-chase word per nonzero.
/// * `convert_*` — charged when the axis format differs from the suite's
///   native CSR: A and B are re-encoded once up front (read the CSR
///   images, write the format images), at one word per cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FormatPlan {
    /// The operand encoding this plan charges for.
    pub format: SparseFormat,
    /// DRAM words of the A image (`rows × rows_b`, `nnz_a` nonzeros).
    pub a_words: u64,
    /// DRAM words of the B image (`rows_b × cols`, `nnz_b` nonzeros).
    pub b_words: u64,
    /// DRAM words of the C image (`rows × cols`, `out_nnz` nonzeros).
    pub c_words: u64,
    /// Extra row-gather traffic for column-major operand layouts.
    pub gather_words: u64,
    /// Words read by the one-time CSR→format conversion of A and B.
    pub convert_read_words: u64,
    /// Words written by the one-time CSR→format conversion of A and B.
    pub convert_write_words: u64,
    /// Cycles spent in that conversion (one word per cycle).
    pub convert_cycles: u64,
}

impl FormatPlan {
    /// The native-CSR plan — exactly the legacy traffic formulas
    /// (`2·nnz + rows + 1` per image), with no gather or conversion terms.
    pub fn csr(rows: usize, rows_b: usize, nnz_a: u64, nnz_b: u64, out_nnz: u64) -> Self {
        Self {
            format: SparseFormat::Csr,
            a_words: 2 * nnz_a + rows as u64 + 1,
            b_words: 2 * nnz_b + rows_b as u64 + 1,
            c_words: 2 * out_nnz + rows as u64 + 1,
            gather_words: 0,
            convert_read_words: 0,
            convert_write_words: 0,
            convert_cycles: 0,
        }
    }

    /// Derive the plan for any format from workload totals alone
    /// (`C[rows × cols] = A[rows × rows_b] × B[rows_b × cols]`).
    /// `from_totals(Csr, ..)` equals [`FormatPlan::csr`] exactly.
    pub fn from_totals(
        format: SparseFormat,
        rows: usize,
        cols: usize,
        rows_b: usize,
        nnz_a: u64,
        nnz_b: u64,
        out_nnz: u64,
    ) -> Self {
        let a_words = format.estimate_words(rows, rows_b, nnz_a);
        let b_words = format.estimate_words(rows_b, cols, nnz_b);
        let c_words = format.estimate_words(rows, cols, out_nnz);
        let gather_words = match format {
            SparseFormat::Csc => nnz_a + nnz_b,
            _ => 0,
        };
        let (convert_read_words, convert_write_words) = if format == SparseFormat::Csr {
            (0, 0)
        } else {
            let read = SparseFormat::Csr.estimate_words(rows, rows_b, nnz_a)
                + SparseFormat::Csr.estimate_words(rows_b, cols, nnz_b);
            (read, a_words + b_words)
        };
        Self {
            format,
            a_words,
            b_words,
            c_words,
            gather_words,
            convert_read_words,
            convert_write_words,
            convert_cycles: convert_read_words + convert_write_words,
        }
    }

    /// Total compulsory DRAM words under this plan: the three images plus
    /// the gather and conversion terms. For the CSR plan this is exactly
    /// the legacy `(2·nnz_a + rows + 1) + (2·nnz_b + rows_b + 1) +
    /// (2·out_nnz + rows + 1)`.
    pub fn compulsory_dram_words(&self) -> u64 {
        self.a_words
            + self.b_words
            + self.c_words
            + self.gather_words
            + self.convert_read_words
            + self.convert_write_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 1 example: 4×4, 6 nonzeros.
    fn fig1() -> Csr {
        Csr::from_triplets(
            4,
            4,
            vec![
                (0, 1, 1.0),
                (0, 2, 2.0),
                (1, 0, 3.0),
                (2, 2, 4.0),
                (2, 3, 5.0),
                (3, 1, 6.0),
            ],
        )
    }

    #[test]
    fn labels_and_tags_round_trip() {
        for f in SparseFormat::ALL {
            assert_eq!(f.label().parse::<SparseFormat>(), Ok(f));
            assert_eq!(format!("{f}"), f.label());
            assert_eq!(SparseFormat::from_tag(f.tag()), Some(f));
        }
        assert!("csr2".parse::<SparseFormat>().is_err());
        assert_eq!(SparseFormat::from_tag(9), None);
        assert_eq!(SparseFormat::default(), SparseFormat::Csr);
    }

    #[test]
    fn every_format_round_trips_fig1() {
        let a = fig1();
        for f in SparseFormat::ALL {
            let m = SparseMatrix::from_csr(f, &a);
            assert_eq!(m.format(), f);
            assert_eq!((m.rows(), m.cols(), m.nnz()), (4, 4, 6), "{f}");
            assert_eq!(m.to_csr(), a, "{f}");
        }
    }

    #[test]
    fn triplets_are_canonical_across_formats() {
        let a = fig1();
        let reference = SparseMatrix::Csr(a.clone()).triplets();
        assert_eq!(reference[0], (0, 1, 1.0));
        for f in SparseFormat::ALL {
            assert_eq!(SparseMatrix::from_csr(f, &a).triplets(), reference, "{f}");
        }
    }

    #[test]
    fn storage_words_match_hand_counts_on_fig1() {
        // 4×4, 6 nnz, one mask word per row, and 5 occupied 4×4 blocks is
        // impossible here: the whole matrix is a single block row of one
        // 4×4 block grid cell -> occupied = 1.
        let a = fig1();
        let words = |f| SparseMatrix::from_csr(f, &a).storage_words();
        // csr: (6 col ids + 5 row ptrs) + 6 values
        assert_eq!(words(SparseFormat::Csr), StorageWords { index_words: 11, value_words: 6 });
        // csc: (6 row ids + 5 col ptrs) + 6 values
        assert_eq!(words(SparseFormat::Csc), StorageWords { index_words: 11, value_words: 6 });
        // coo: (6 rows + 6 cols) + 6 values
        assert_eq!(words(SparseFormat::Coo), StorageWords { index_words: 12, value_words: 6 });
        // bitmap: 4 rows × 1 mask word + 6 values
        assert_eq!(
            words(SparseFormat::Bitmap),
            StorageWords { index_words: 4, value_words: 6 }
        );
        // blocked: 1 occupied block + 2 block ptrs, 16 dense values
        assert_eq!(
            words(SparseFormat::BlockedCsr),
            StorageWords { index_words: 3, value_words: 16 }
        );
    }

    #[test]
    fn estimates_cover_the_exact_images_on_fig1() {
        let a = fig1();
        for f in SparseFormat::ALL {
            let exact = SparseMatrix::from_csr(f, &a).storage_words().total();
            let est = f.estimate_words(4, 4, 6);
            assert!(est >= exact, "{f}: estimate {est} < exact {exact}");
        }
        // And the estimate is exact for the non-blocked formats.
        assert_eq!(SparseFormat::Csr.estimate_words(4, 4, 6), 17);
        assert_eq!(SparseFormat::Csc.estimate_words(4, 4, 6), 17);
        assert_eq!(SparseFormat::Coo.estimate_words(4, 4, 6), 18);
        assert_eq!(SparseFormat::Bitmap.estimate_words(4, 4, 6), 10);
        // blocked estimate: min(6 nnz, 1 block slot) = 1 -> 17 + 1 + 1.
        assert_eq!(SparseFormat::BlockedCsr.estimate_words(4, 4, 6), 19);
    }

    #[test]
    fn convert_is_canonical_and_costed() {
        let a = fig1();
        let src = SparseMatrix::from_csr(SparseFormat::Coo, &a);
        // Same-format conversion is free.
        let (same, cost) = src.convert(SparseFormat::Coo);
        assert_eq!(same, src);
        assert_eq!(cost, ConvertCost::default());
        // Cross-format conversion streams both images.
        let (bm, cost) = src.convert(SparseFormat::Bitmap);
        assert_eq!(bm.to_csr(), a);
        let expect = src.storage_words().total() + bm.storage_words().total();
        assert_eq!(cost, ConvertCost { cycles: expect, dram_words: expect });
        // Any chain back to the source format is the identity.
        let (back, _) = bm.convert(SparseFormat::Coo);
        assert_eq!(back, src);
    }

    #[test]
    fn csr_plan_reproduces_the_legacy_traffic_formula() {
        let plan = FormatPlan::csr(100, 80, 500, 400, 900);
        assert_eq!(plan.a_words, 2 * 500 + 101);
        assert_eq!(plan.b_words, 2 * 400 + 81);
        assert_eq!(plan.c_words, 2 * 900 + 101);
        assert_eq!(plan.gather_words + plan.convert_cycles, 0);
        assert_eq!(
            plan.compulsory_dram_words(),
            (2 * 500 + 101) + (2 * 400 + 81) + (2 * 900 + 101)
        );
        // from_totals(Csr, ..) is the same plan.
        assert_eq!(FormatPlan::from_totals(SparseFormat::Csr, 100, 60, 80, 500, 400, 900), plan);
    }

    #[test]
    fn non_csr_plans_charge_gather_and_conversion() {
        let (rows, cols, rows_b) = (100, 60, 80);
        let (nnz_a, nnz_b, out_nnz) = (500, 400, 900);
        for f in SparseFormat::ALL {
            let plan = FormatPlan::from_totals(f, rows, cols, rows_b, nnz_a, nnz_b, out_nnz);
            assert_eq!(plan.format, f);
            assert_eq!(plan.a_words, f.estimate_words(rows, rows_b, nnz_a));
            assert_eq!(plan.b_words, f.estimate_words(rows_b, cols, nnz_b));
            assert_eq!(plan.c_words, f.estimate_words(rows, cols, out_nnz));
            if f == SparseFormat::Csr {
                assert_eq!(plan.convert_cycles, 0);
            } else {
                assert_eq!(
                    plan.convert_read_words,
                    SparseFormat::Csr.estimate_words(rows, rows_b, nnz_a)
                        + SparseFormat::Csr.estimate_words(rows_b, cols, nnz_b)
                );
                assert_eq!(plan.convert_write_words, plan.a_words + plan.b_words);
                assert_eq!(
                    plan.convert_cycles,
                    plan.convert_read_words + plan.convert_write_words
                );
            }
            let gather = if f == SparseFormat::Csc { nnz_a + nnz_b } else { 0 };
            assert_eq!(plan.gather_words, gather, "{f}");
        }
    }

    #[test]
    fn rectangular_and_empty_matrices_encode_in_every_format() {
        let rect = Csr::from_triplets(2, 70, vec![(0, 0, 1.0), (1, 69, 2.0)]);
        let empty = Csr::zero(3, 5);
        for f in SparseFormat::ALL {
            let m = SparseMatrix::from_csr(f, &rect);
            assert_eq!(m.to_csr(), rect, "{f} rect");
            let e = SparseMatrix::from_csr(f, &empty);
            assert_eq!((e.rows(), e.cols(), e.nnz()), (3, 5, 0), "{f} empty");
            assert_eq!(e.to_csr(), empty, "{f} empty");
        }
        // 70 columns -> 3 mask words per row.
        let bm = SparseMatrix::from_csr(SparseFormat::Bitmap, &rect);
        assert_eq!(bm.storage_words().index_words, 2 * 3);
        // Two entries in two different block rows -> 2 occupied blocks.
        let bl = SparseMatrix::from_csr(SparseFormat::BlockedCsr, &rect);
        assert_eq!(bl.storage_words(), StorageWords { index_words: 2 + 2, value_words: 32 });
    }
}
