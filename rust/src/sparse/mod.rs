//! Sparse matrix substrate.
//!
//! The paper's entire stack operates on the **compressed sparse row** (CSR)
//! format (paper §II.B): a sparse matrix is three vectors — `value` (the
//! nonzeros), `col_id` (the column coordinate of each nonzero) and `row_ptr`
//! (the offset of each row's first nonzero in `value`). This module provides
//! CSR plus the CSC / COO / bitmap / blocked formats behind the unified
//! [`format::SparseFormat`] API, conversion between them, Matrix-Market
//! I/O, synthetic workload generators, and the Table-I dataset registry.
//!
//! # Ordering contract
//!
//! Every conversion in this module is **canonical**: the result is sorted
//! row-major (ascending row, then ascending column within a row) with
//! duplicate coordinates summed into one entry. [`Csr::from_triplets`] is
//! the single canonicalisation point — all pairwise conversions
//! (`Coo ↔ Csc`, `Csc ↔ Csr`, bitmap/blocked decode, …) route through it,
//! so for any formats `X`, `Y`, `Z` and canonical matrix `m`:
//!
//! * `m.to_x().to_y()` equals `m.to_y()` (path independence), and
//! * any conversion chain `X → Y → … → X` is the exact identity,
//!   bit-for-bit on the stored values.
//!
//! Column-major ([`Csc`]) data is stored column-major internally but
//! converts back to the same canonical row-major form as everyone else.
//! The one documented lossy edge: [`format::BlockedCsr`] stores dense 4×4
//! blocks, so an *explicitly stored zero* value cannot be distinguished
//! from structural absence and is dropped on decode (canonical matrices
//! built from the generators never contain stored zeros).

mod coo;
mod csc;
mod csr;
pub mod format;
pub mod gen;
pub mod io;
pub mod stats;
pub mod suite;
pub mod tile;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use format::{
    Bitmap, BlockedCsr, ConvertCost, FormatPlan, SparseFormat, SparseMatrix, StorageWords,
};
pub use tile::TileShape;

/// Deterministic 64-bit SplitMix PRNG.
///
/// The framework never pulls in an external RNG crate: every synthetic
/// workload must be exactly reproducible from a `u64` seed across platforms,
/// which SplitMix64 guarantees (it is the reference stream generator from
/// Steele et al., OOPSLA'14).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // bias is < 2^-32 for our bounds (< 2^32), far below workload noise.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Nonzero value for a synthetic matrix: uniform in `[-1, 1] \ {0}`.
    #[inline]
    pub fn value(&mut self) -> f32 {
        loop {
            let v = (self.unit_f64() * 2.0 - 1.0) as f32;
            if v != 0.0 {
                return v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn splitmix_unit_in_range() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let u = r.unit_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn splitmix_known_first_value() {
        // Reference value of SplitMix64 seeded with 0 (Steele et al.).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn value_never_zero() {
        let mut r = SplitMix64::new(3);
        for _ in 0..10_000 {
            assert_ne!(r.value(), 0.0);
        }
    }
}
