//! Capacity-aware CSR tiling: row groups, column tiles, and 2-D blocks.
//!
//! The out-of-core profile pass (`crate::sim`'s `profile_workload_tiled`)
//! streams A row-groups against B column-tiles, so tiling must uphold one
//! invariant above all: **tiles exactly partition the nonzeros** — every
//! nonzero of the source matrix lands in exactly one tile, and the tile
//! boundaries are a pure function of `(extent, tile size)`. The property
//! tests in `tests/tiling.rs` pin this for uniform, power-law, and banded
//! generators.
//!
//! Tile sizes are validated against [`Scratchpad`] capacities before any
//! work is scheduled (see [`check_fits`]): a tile whose working set cannot
//! fit the scratchpad is rejected loudly at design-space expansion time
//! ([`crate::sim::engine::DesignSpace::expand`]), or split down to a
//! feasible shape via [`fit_shape`] — never silently truncated.

use super::stats::{row_nnz_summary, RowNnzSummary};
use super::Csr;
use crate::mem::Scratchpad;

/// A tile shape: `rows × cols` of the output partition. Parsed from and
/// rendered as `RxC` (e.g. `256x128`) — the spelling used by the `tile`
/// design-space axis labels, `--tile`, and the cache artifact names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TileShape {
    pub rows: usize,
    pub cols: usize,
}

impl TileShape {
    /// A shape with both extents clamped to ≥ 1 (a zero extent would make
    /// the cut sequence degenerate instead of erroring usefully).
    pub fn new(rows: usize, cols: usize) -> Self {
        Self { rows: rows.max(1), cols: cols.max(1) }
    }

    /// Parse the `RxC` spelling (also accepts a single integer `N` as the
    /// square `NxN`).
    pub fn parse(s: &str) -> Result<Self, String> {
        let s = s.trim();
        let (r, c) = match s.split_once(['x', 'X']) {
            Some((r, c)) => (r, c),
            None => (s, s),
        };
        let rows: usize =
            r.trim().parse().map_err(|_| format!("bad tile rows {r:?} in {s:?}"))?;
        let cols: usize =
            c.trim().parse().map_err(|_| format!("bad tile cols {c:?} in {s:?}"))?;
        if rows == 0 || cols == 0 {
            return Err(format!("tile shape {s:?} has a zero extent"));
        }
        Ok(Self { rows, cols })
    }

    /// Conservative per-tile working set in 32-bit words: one accumulator
    /// strip over the tile's output columns (tag + partial per column, the
    /// generation-tagged SPA's footprint) plus the tile's row-pointer
    /// strip. This is what must fit the scratchpad for the tile to be
    /// schedulable — the feasibility rule documented in the README.
    pub fn working_set_words(&self) -> u64 {
        2 * self.cols as u64 + self.rows as u64 + 1
    }
}

impl std::fmt::Display for TileShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.rows, self.cols)
    }
}

impl std::str::FromStr for TileShape {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        Self::parse(s)
    }
}

/// Cut boundaries for tiling `extent` into chunks of at most `tile`:
/// `[0, tile, 2·tile, …, extent]`. Monotone, starts at 0, ends at
/// `extent`; an empty extent yields the single empty range `[0, 0]`.
/// Adjacent boundary pairs are exactly the tile ranges, so consumers
/// iterate `cuts(..).windows(2)` — the same idiom as the profile pass's
/// `nnz_balanced_bounds`.
pub fn cuts(extent: usize, tile: usize) -> Vec<usize> {
    let tile = tile.max(1);
    let mut bounds = Vec::with_capacity(extent / tile + 2);
    bounds.push(0);
    let mut at = tile;
    while at < extent {
        bounds.push(at);
        at += tile;
    }
    // An empty extent falls through to `[0, 0]` — one explicit empty range,
    // so `windows(2)` consumers still see exactly one (empty) tile.
    bounds.push(extent);
    bounds
}

/// The row slice `a[lo..hi, :]` as its own CSR (same column space).
pub fn extract_rows(a: &Csr, lo: usize, hi: usize) -> Csr {
    assert!(lo <= hi && hi <= a.rows(), "row range {lo}..{hi} out of {}", a.rows());
    let (s, e) = (a.row_ptr[lo], a.row_ptr[hi]);
    let row_ptr = a.row_ptr[lo..=hi].iter().map(|&p| p - s).collect();
    Csr::try_new(
        hi - lo,
        a.cols(),
        row_ptr,
        a.col_id[s..e].to_vec(),
        a.value[s..e].to_vec(),
    )
    .expect("row slice of a valid CSR is valid")
}

/// The column slice `a[:, lo..hi)` as its own CSR with **local** column
/// ids (`j - lo`). Column ids are ascending within each row, so the range
/// is found per row with two binary searches — `O(nnz_in_range + rows·log)`
/// overall, no full scan of out-of-range nonzeros' values.
pub fn extract_cols(a: &Csr, lo: usize, hi: usize) -> Csr {
    assert!(lo <= hi && hi <= a.cols(), "col range {lo}..{hi} out of {}", a.cols());
    let (lo32, hi32) = (lo as u32, hi as u32);
    let mut row_ptr = Vec::with_capacity(a.rows() + 1);
    row_ptr.push(0usize);
    let mut col_id = Vec::new();
    let mut value = Vec::new();
    for i in 0..a.rows() {
        let cols = a.row_cols(i);
        let vals = a.row_values(i);
        let s = cols.partition_point(|&c| c < lo32);
        let e = cols.partition_point(|&c| c < hi32);
        for p in s..e {
            col_id.push(cols[p] - lo32);
            value.push(vals[p]);
        }
        row_ptr.push(col_id.len());
    }
    Csr::try_new(a.rows(), hi - lo, row_ptr, col_id, value)
        .expect("column slice of a valid CSR is valid")
}

/// The 2-D block `a[r0..r1, c0..c1)` with local row and column ids.
pub fn extract_block(a: &Csr, r0: usize, r1: usize, c0: usize, c1: usize) -> Csr {
    extract_cols(&extract_rows(a, r0, r1), c0, c1)
}

/// Whether `shape`'s working set fits `spm`; the loud rejection path —
/// the error names both sides of the inequality so a failed sweep or
/// ingest says exactly which capacity was exceeded by how much.
pub fn check_fits(shape: TileShape, spm: &Scratchpad) -> Result<(), String> {
    let need = shape.working_set_words();
    let have = spm.capacity_words();
    if need > have {
        return Err(format!(
            "tile {shape} working set ({need} words) exceeds scratchpad {:?} capacity \
             ({have} words); shrink the tile or use fit_shape to split it",
            spm.name(),
        ));
    }
    Ok(())
}

/// Split `shape` (halving the larger extent first) until its working set
/// fits `spm`. Errors if even a 1×1 tile cannot fit — a scratchpad that
/// small cannot schedule any tile.
pub fn fit_shape(shape: TileShape, spm: &Scratchpad) -> Result<TileShape, String> {
    let mut s = TileShape::new(shape.rows, shape.cols);
    loop {
        if check_fits(s, spm).is_ok() {
            return Ok(s);
        }
        if s.rows == 1 && s.cols == 1 {
            return Err(format!(
                "scratchpad {:?} ({} words) cannot hold even a 1x1 tile ({} words)",
                spm.name(),
                spm.capacity_words(),
                TileShape::new(1, 1).working_set_words(),
            ));
        }
        if s.cols >= s.rows {
            s.cols = (s.cols / 2).max(1);
        } else {
            s.rows = (s.rows / 2).max(1);
        }
    }
}

/// One row-group's entry in the tiling report: the group's row range and
/// its [`RowNnzSummary`] — the skew statistics that make heavy-row tiles
/// visible in sweep output (a group whose `heavy_share` dominates is the
/// one that serialises a tiled schedule).
#[derive(Debug, Clone, PartialEq)]
pub struct TileSummary {
    pub index: usize,
    pub row_lo: usize,
    pub row_hi: usize,
    pub summary: RowNnzSummary,
}

/// Per-row-group [`RowNnzSummary`] under `shape` (column tiling does not
/// change row-nnz shape, so the report is per row group).
pub fn row_group_summaries(a: &Csr, shape: TileShape) -> Vec<TileSummary> {
    cuts(a.rows(), shape.rows)
        .windows(2)
        .enumerate()
        .map(|(index, w)| TileSummary {
            index,
            row_lo: w[0],
            row_hi: w[1],
            summary: row_nnz_summary(&extract_rows(a, w[0], w[1])),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Lane;
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn shape_parses_and_renders() {
        assert_eq!(TileShape::parse("256x128").unwrap(), TileShape { rows: 256, cols: 128 });
        assert_eq!(TileShape::parse(" 8X4 ").unwrap(), TileShape { rows: 8, cols: 4 });
        assert_eq!(TileShape::parse("64").unwrap(), TileShape { rows: 64, cols: 64 });
        assert_eq!(TileShape::parse("16x32").unwrap().to_string(), "16x32");
        assert!(TileShape::parse("0x4").is_err());
        assert!(TileShape::parse("axb").is_err());
        assert!(TileShape::parse("").is_err());
    }

    #[test]
    fn cuts_tile_the_extent_exactly() {
        assert_eq!(cuts(10, 4), vec![0, 4, 8, 10]);
        assert_eq!(cuts(8, 4), vec![0, 4, 8]);
        assert_eq!(cuts(3, 100), vec![0, 3]);
        assert_eq!(cuts(5, 1), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(cuts(0, 4), vec![0, 0]);
        for (extent, tile) in [(17usize, 5usize), (100, 7), (1, 1), (64, 64)] {
            let b = cuts(extent, tile);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), extent);
            assert!(b.windows(2).all(|w| w[0] < w[1] || extent == 0), "{b:?}");
            assert!(b.windows(2).all(|w| w[1] - w[0] <= tile), "{b:?}");
        }
    }

    #[test]
    fn row_and_col_slices_preserve_entries() {
        let a = generate(40, 30, 250, Profile::PowerLaw { alpha: 0.8 }, 5);
        let top = extract_rows(&a, 0, 17);
        let bot = extract_rows(&a, 17, 40);
        assert_eq!(top.nnz() + bot.nnz(), a.nnz());
        for i in 0..17 {
            assert_eq!(top.row_cols(i), a.row_cols(i));
            assert_eq!(top.row_values(i), a.row_values(i));
        }
        let left = extract_cols(&a, 0, 11);
        let right = extract_cols(&a, 11, 30);
        assert_eq!(left.nnz() + right.nnz(), a.nnz());
        assert_eq!((left.cols(), right.cols()), (11, 19));
        // Local ids shift back to the originals.
        for i in 0..a.rows() {
            let mut merged: Vec<u32> = left.row_cols(i).to_vec();
            merged.extend(right.row_cols(i).iter().map(|&c| c + 11));
            assert_eq!(merged, a.row_cols(i), "row {i}");
        }
    }

    #[test]
    fn blocks_partition_nnz_for_every_generator() {
        for profile in [
            Profile::Uniform,
            Profile::PowerLaw { alpha: 0.9 },
            Profile::Banded { rel_bandwidth: 0.2, cluster: 0.5 },
        ] {
            let a = generate(60, 45, 500, profile, 9);
            for shape in [TileShape::new(16, 16), TileShape::new(1, 45), TileShape::new(60, 1)] {
                let mut total = 0usize;
                for rw in cuts(a.rows(), shape.rows).windows(2) {
                    for cw in cuts(a.cols(), shape.cols).windows(2) {
                        total += extract_block(&a, rw[0], rw[1], cw[0], cw[1]).nnz();
                    }
                }
                assert_eq!(total, a.nnz(), "{profile:?} {shape}");
            }
        }
    }

    #[test]
    fn capacity_check_rejects_and_fit_shape_splits() {
        // 1 KiB = 256 words: a 256-col tile needs 2*256 + rows + 1 words.
        let spm = Scratchpad::new("l1", Lane::L1, 1024);
        assert!(check_fits(TileShape::new(4, 64), &spm).is_ok());
        let err = check_fits(TileShape::new(4, 256), &spm).unwrap_err();
        assert!(err.contains("exceeds scratchpad"), "{err}");
        assert!(err.contains("517 words"), "{err}");
        let fitted = fit_shape(TileShape::new(4, 256), &spm).unwrap();
        assert!(check_fits(fitted, &spm).is_ok());
        assert_eq!(fitted, TileShape::new(4, 128));
        // A scratchpad too small for any tile errors instead of looping.
        let tiny = Scratchpad::new("tiny", Lane::L1, 8);
        assert!(fit_shape(TileShape::new(64, 64), &tiny).is_err());
    }

    #[test]
    fn row_group_summaries_cover_all_rows_and_nnz() {
        let a = generate(50, 50, 400, Profile::PowerLaw { alpha: 0.9 }, 3);
        let groups = row_group_summaries(&a, TileShape::new(16, 50));
        assert_eq!(groups.len(), 4);
        assert_eq!(groups.iter().map(|g| g.row_hi - g.row_lo).sum::<usize>(), 50);
        assert_eq!(groups.iter().map(|g| g.summary.nnz).sum::<usize>(), a.nnz());
        assert_eq!(groups.last().unwrap().row_hi, 50);
    }
}
