//! 45 nm per-action energy constants.
//!
//! The paper extracts these from Accelergy (with CACTI and Aladdin plugins)
//! at 45 nm (§III, Fig. 3). We assemble the same table from the published
//! literature those tools are themselves calibrated against:
//!
//! * arithmetic + memory ladder: Horowitz, "Computing's energy problem (and
//!   what we can do about it)", ISSCC'14 — fp32 mult ≈ 3.7 pJ, fp32 add
//!   ≈ 0.9 pJ, 8 KB SRAM ≈ 10 pJ, 32 KB ≈ 20 pJ, 1 MB ≈ 100 pJ, DRAM
//!   ≈ 1.3–2.6 nJ per 64-bit access (we charge per 32-bit word).
//! * SRAM scaling: CACTI's near-√capacity dynamic-energy fit, anchored on
//!   the Horowitz points.
//! * comparator / mux-tree costs for intersection and CSR (de)compression:
//!   small fixed-function logic, an order of magnitude below a MAC.
//!
//! The resulting lane ordering — MAC ≪ PE-SRAM ≪ L1 ≪ DRAM, register file
//! below MAC — reproduces the paper's Fig. 3; `fig3_rows()` emits exactly
//! that figure and is asserted in tests.

/// Per-action energies for one technology node. All values picojoules per
/// action on a 32-bit word.
#[derive(Debug, Clone, PartialEq)]
pub struct TechModel {
    /// Node name, e.g. `"45nm"`.
    pub node: &'static str,
    fp32_mult_pj: f64,
    fp32_add_pj: f64,
    /// Register-file access at reference capacity (≤ 256 B).
    regfile_base_pj: f64,
    /// SRAM access energy coefficient: `pJ = k · √(capacity KiB)`.
    sram_coeff_pj: f64,
    dram_word_pj: f64,
    noc_hop_pj: f64,
    intersect_cmp_pj: f64,
    cd_elem_pj: f64,
}

impl TechModel {
    /// The paper's 45 nm node.
    pub fn tech45() -> Self {
        TechModel {
            node: "45nm",
            fp32_mult_pj: 3.7,
            fp32_add_pj: 0.9,
            regfile_base_pj: 0.8,
            // k·√8 = 10 pJ at 8 KiB  ⇒  k ≈ 3.54 (also hits 20 pJ @ 32 KiB,
            // ≈113 pJ @ 1 MiB — the three Horowitz anchor points).
            sram_coeff_pj: 3.54,
            // LPDDR4-class DRAM: ≈ 8 pJ/bit (Accelergy's LPDDR table,
            // Malladi et al. ISCA'12) ⇒ 256 pJ per 32-bit word. Still 56×
            // a MAC, preserving Fig. 3's "L2 dwarfs everything" ordering.
            dram_word_pj: 256.0,
            noc_hop_pj: 1.2,
            intersect_cmp_pj: 0.32,
            cd_elem_pj: 1.1,
        }
    }

    /// fp32 multiply.
    pub fn mult_pj(&self) -> f64 {
        self.fp32_mult_pj
    }

    /// fp32 add.
    pub fn add_pj(&self) -> f64 {
        self.fp32_add_pj
    }

    /// One multiply-accumulate (mult + add).
    pub fn mac_pj(&self) -> f64 {
        self.fp32_mult_pj + self.fp32_add_pj
    }

    /// Register-file access; grows gently (√) past 256 B.
    pub fn regfile_pj(&self, bytes: usize) -> f64 {
        let b = bytes.max(1) as f64;
        if b <= 256.0 {
            self.regfile_base_pj
        } else {
            self.regfile_base_pj * (b / 256.0).sqrt()
        }
    }

    /// SRAM access energy for a buffer of `bytes` capacity (per 32-bit word).
    pub fn sram_pj(&self, bytes: usize) -> f64 {
        let kib = (bytes.max(1024)) as f64 / 1024.0;
        self.sram_coeff_pj * kib.sqrt()
    }

    /// DRAM access per 32-bit word.
    pub fn dram_pj(&self) -> f64 {
        self.dram_word_pj
    }

    /// One 32-bit flit over one NoC hop (link + router).
    pub fn noc_hop_pj(&self) -> f64 {
        self.noc_hop_pj
    }

    /// One index comparison in an intersection unit.
    pub fn intersect_pj(&self) -> f64 {
        self.intersect_cmp_pj
    }

    /// One element through a CSR compressor/decompressor.
    pub fn cd_pj(&self) -> f64 {
        self.cd_elem_pj
    }

    /// The rows of the paper's Fig. 3: normalized energy of computations
    /// (MAC, C/D, IN) and data movement (L0↔MAC, PE↔MAC, L1↔MAC, L2↔MAC),
    /// normalized to one MAC. Buffer capacities follow Fig. 2's levels
    /// (register L0, 24 KiB PE buffer, 512 KiB L1, DRAM L2).
    pub fn fig3_rows(&self) -> Vec<(&'static str, f64)> {
        let mac = self.mac_pj();
        vec![
            ("MAC", 1.0),
            ("C/D", self.cd_pj() / mac),
            ("IN", self.intersect_pj() / mac),
            ("L0<->MAC", self.regfile_pj(2048) / mac),
            ("PE<->MAC", self.sram_pj(24 << 10) / mac),
            ("L1<->MAC", self.sram_pj(512 << 10) / mac),
            ("L2<->MAC", self.dram_pj() / mac),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn horowitz_anchor_points() {
        let t = TechModel::tech45();
        assert!((t.sram_pj(8 << 10) - 10.0).abs() < 0.5);
        assert!((t.sram_pj(32 << 10) - 20.0).abs() < 1.0);
        assert!((t.sram_pj(1 << 20) - 100.0).abs() < 15.0);
    }

    #[test]
    fn fig3_ordering_matches_paper() {
        // Fig. 3's message (§III): "arithmetic consumes less energy than
        // data movement, especially ... from lower levels of the memory
        // hierarchy" — i.e. MAC < PE↔MAC < L1↔MAC < L2↔MAC, with L2 orders
        // of magnitude above everything.
        let t = TechModel::tech45();
        let rows: std::collections::BTreeMap<_, _> = t.fig3_rows().into_iter().collect();
        let mac = rows["MAC"];
        assert!(rows["IN"] < mac);
        assert!(rows["C/D"] < mac);
        assert!(rows["L0<->MAC"] < rows["PE<->MAC"]);
        assert!(rows["PE<->MAC"] < rows["L1<->MAC"]);
        assert!(rows["L1<->MAC"] < rows["L2<->MAC"]);
        assert!(rows["L2<->MAC"] > 50.0 * mac, "DRAM must dwarf MAC");
    }

    #[test]
    fn regfile_cheaper_than_any_sram() {
        let t = TechModel::tech45();
        assert!(t.regfile_pj(2048) < t.sram_pj(1024));
    }

    #[test]
    fn sram_energy_monotone_in_capacity() {
        let t = TechModel::tech45();
        let mut last = 0.0;
        for kb in [1, 2, 8, 32, 128, 1024, 8192] {
            let e = t.sram_pj(kb << 10);
            assert!(e >= last);
            last = e;
        }
    }

    #[test]
    fn mac_is_mult_plus_add() {
        let t = TechModel::tech45();
        assert!((t.mac_pj() - (t.mult_pj() + t.add_pj())).abs() < 1e-12);
        assert!((t.mac_pj() - 4.6).abs() < 1e-9);
    }
}
