//! Per-action energy model (the Accelergy/CACTI substitution).
//!
//! [`TechModel`] holds the 45 nm per-action energies; [`EnergyBreakdown`]
//! aggregates a run's [`Counters`](crate::trace::Counters) into the paper's
//! Fig.-3 lanes (compute vs data movement per memory level).

pub mod tech45;

pub use tech45::TechModel;

use crate::trace::Counters;

/// Buffer capacities an energy aggregation needs (SRAM energy is
/// capacity-dependent; see [`TechModel::sram_pj`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BufferSizes {
    /// Per-PE L0 SRAM (Matraptor sorting queues / Extensor PEB), bytes.
    pub pe_buffer_bytes: usize,
    /// L1 storage element (SpAL+SpBL / LLB), bytes.
    pub l1_bytes: usize,
    /// Partial-output buffer (Extensor POB), bytes; 0 when absent.
    pub pob_bytes: usize,
    /// Maple register buffers (ARB+BRB+PSB), bytes; 0 for baseline PEs.
    pub reg_bytes: usize,
}

/// Energy of one simulated run, split into the paper's reporting lanes.
/// All values in picojoules.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// MAC arithmetic (multiplies + adds).
    pub mac_pj: f64,
    /// Intersection comparisons.
    pub intersect_pj: f64,
    /// CSR compress / decompress.
    pub cd_pj: f64,
    /// L0 register-buffer traffic (ARB/BRB/PSB) — the `L0 ↔ MAC` lane.
    pub l0_pj: f64,
    /// PE-level SRAM traffic (queues/PEB) — the `PE ↔ MAC` lane.
    pub pe_buffer_pj: f64,
    /// L1 traffic (SpAL/SpBL/LLB + POB) — the `L1 ↔ MAC` lane.
    pub l1_pj: f64,
    /// DRAM traffic — the `L2 ↔ MAC` lane.
    pub dram_pj: f64,
    /// NoC flit-hop traffic.
    pub noc_pj: f64,
}

impl EnergyBreakdown {
    /// Aggregate raw action counts into energy, Accelergy-style.
    pub fn from_counters(c: &Counters, tech: &TechModel, sizes: &BufferSizes) -> Self {
        let reg_pj = tech.regfile_pj(sizes.reg_bytes.max(64));
        let pe_sram_pj = tech.sram_pj(sizes.pe_buffer_bytes.max(1024));
        let l1_sram_pj = tech.sram_pj(sizes.l1_bytes.max(4096));
        let pob_sram_pj = tech.sram_pj(sizes.pob_bytes.max(4096));
        EnergyBreakdown {
            mac_pj: c.mac_mul as f64 * tech.mult_pj() + c.mac_add as f64 * tech.add_pj(),
            intersect_pj: c.intersect_cmp as f64 * tech.intersect_pj(),
            cd_pj: c.cd_elems as f64 * tech.cd_pj(),
            l0_pj: c.l0_accesses() as f64 * reg_pj,
            pe_buffer_pj: c.pe_buffer_accesses() as f64 * pe_sram_pj,
            l1_pj: (c.l1_read + c.l1_write) as f64 * l1_sram_pj
                + (c.pob_read + c.pob_write) as f64 * pob_sram_pj,
            dram_pj: c.dram_accesses() as f64 * tech.dram_pj(),
            noc_pj: c.noc_flit_hops as f64 * tech.noc_hop_pj(),
        }
    }

    /// Total energy in pJ.
    pub fn total_pj(&self) -> f64 {
        self.mac_pj
            + self.intersect_pj
            + self.cd_pj
            + self.l0_pj
            + self.pe_buffer_pj
            + self.l1_pj
            + self.dram_pj
            + self.noc_pj
    }

    /// Compute (arithmetic) share of the total.
    pub fn compute_pj(&self) -> f64 {
        self.mac_pj + self.intersect_pj + self.cd_pj
    }

    /// Data-movement share of the total (everything that isn't arithmetic).
    pub fn movement_pj(&self) -> f64 {
        self.total_pj() - self.compute_pj()
    }

    /// Element-wise sum.
    pub fn merge(&mut self, o: &EnergyBreakdown) {
        self.mac_pj += o.mac_pj;
        self.intersect_pj += o.intersect_pj;
        self.cd_pj += o.cd_pj;
        self.l0_pj += o.l0_pj;
        self.pe_buffer_pj += o.pe_buffer_pj;
        self.l1_pj += o.l1_pj;
        self.dram_pj += o.dram_pj;
        self.noc_pj += o.noc_pj;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sizes() -> BufferSizes {
        BufferSizes {
            pe_buffer_bytes: 24 << 10,
            l1_bytes: 512 << 10,
            pob_bytes: 128 << 10,
            reg_bytes: 2048,
        }
    }

    #[test]
    fn zero_counters_zero_energy() {
        let e =
            EnergyBreakdown::from_counters(&Counters::default(), &TechModel::tech45(), &sizes());
        assert_eq!(e.total_pj(), 0.0);
    }

    #[test]
    fn movement_dominates_for_dram_heavy_runs() {
        // The paper's Fig.-3 message: data movement ≫ arithmetic.
        let c = Counters { mac_mul: 1000, mac_add: 1000, dram_read: 1000, ..Default::default() };
        let e = EnergyBreakdown::from_counters(&c, &TechModel::tech45(), &sizes());
        assert!(e.movement_pj() > 10.0 * e.compute_pj());
    }

    #[test]
    fn aggregation_is_linear_in_counts() {
        let c1 = Counters { mac_mul: 10, l1_read: 5, ..Default::default() };
        let mut c2 = c1.clone();
        c2.merge(&c1);
        let t = TechModel::tech45();
        let e1 = EnergyBreakdown::from_counters(&c1, &t, &sizes());
        let e2 = EnergyBreakdown::from_counters(&c2, &t, &sizes());
        assert!((e2.total_pj() - 2.0 * e1.total_pj()).abs() < 1e-9);
    }

    #[test]
    fn pob_energy_counts_into_l1_lane() {
        let c = Counters { pob_read: 100, ..Default::default() };
        let e = EnergyBreakdown::from_counters(&c, &TechModel::tech45(), &sizes());
        assert!(e.l1_pj > 0.0);
        assert_eq!(e.dram_pj, 0.0);
    }
}
