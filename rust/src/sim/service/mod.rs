//! Fault-tolerant distributed sweep service.
//!
//! One coordinator ([`Coordinator`]) owns a [`crate::sim::engine::DesignSpace`],
//! splits it with the existing [`crate::sim::shard::ShardSpec`] tiling, and
//! leases shards to any number of workers ([`worker::run`]) over a
//! length-framed, checksummed TCP protocol ([`proto`]) built on `std::net`
//! and threads — no runtime, no new dependencies. Leases carry deadlines:
//! a worker that stalls or dies simply loses its lease to the reaper and
//! another worker steals the shard ([`lease`]). Workers that repeatedly
//! fail back off exponentially (seeded jitter) and are quarantined past a
//! retry budget. Submissions are idempotent — the first valid result for a
//! range wins, identical resubmissions are acknowledged as duplicates, and
//! byte-divergent ones are rejected loudly ([`SubmissionLedger`]).
//!
//! The end-to-end guarantee, enforced by `tests/service.rs`: a distributed
//! sweep either merges **bit-identical** to the unsharded
//! [`crate::sim::engine::SimEngine::sweep`], completes partially with loud
//! provenance (`--allow-partial`), or fails with a typed error — never a
//! hang and never a silent partial. The [`fault`] harness (CLI:
//! `maple chaos`) injects deterministic, seed-replayable failures through
//! the real worker code path to prove it.

pub mod coordinator;
pub mod fault;
pub mod lease;
pub mod proto;
pub mod worker;

pub use coordinator::{
    Coordinator, LedgerCore, ServiceConfig, ServiceStats, SubmissionLedger, SubmitError,
    SubmitOutcome, SweepOutcome,
};
pub use fault::{run_chaos, ChaosReport, ChaosSpec, Fault, FaultEvent, FaultPlan};
pub use lease::{Grant, LeasePolicy, LeaseTable, SlotView, WorkerView};
pub use proto::{AckCode, Message, ProtoError, PROTO_VERSION};
pub use worker::{WorkerConfig, WorkerReport};

use crate::sim::engine::EngineError;
use crate::sim::shard::ShardError;

/// Everything that can go wrong in a service run — every variant names the
/// failing layer so a chaos run never ends in a bare `io::Error`.
#[derive(Debug, thiserror::Error)]
pub enum ServiceError {
    #[error("engine error: {0}")]
    Engine(#[from] EngineError),
    #[error("shard error: {0}")]
    Shard(#[from] ShardError),
    #[error("protocol error: {0}")]
    Proto(#[from] ProtoError),
    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
    #[error(
        "sweep incomplete: {completed}/{count} shards arrived (missing {missing:?}); \
         rerun with --allow-partial to render the completed sub-grid"
    )]
    Incomplete { completed: usize, count: usize, missing: Vec<usize> },
    #[error("worker {0} was quarantined by the coordinator (retry budget exhausted)")]
    Quarantined(String),
    #[error("cannot reach coordinator at {addr} after {attempts} attempts: {source}")]
    Connect { addr: String, attempts: u32, source: std::io::Error },
    #[error(
        "space fingerprint skew: coordinator advertised {advertised:#018x} but the \
         decoded space hashes to {decoded:#018x} (codec or version mismatch)"
    )]
    FingerprintSkew { advertised: u64, decoded: u64 },
}
