//! Shard leasing: who owns which slice of the grid, for how long, and what
//! happens when they vanish.
//!
//! The coordinator holds one [`LeaseTable`] per sweep. Every shard is a
//! slot that moves `Pending → Leased → Done`; a lease carries a deadline,
//! and [`LeaseTable::reap`] moves expired leases back to `Pending` so any
//! live worker can steal the work. Per-worker failure accounting drives
//! exponential backoff with jitter ([`LeaseTable::fail`]) and, past the
//! retry budget, quarantine — a quarantined worker is told to stop and
//! never granted work again.
//!
//! The table is pure state-machine logic over a caller-supplied clock
//! (milliseconds since the coordinator's epoch), so every policy decision
//! is unit-testable with a fake clock — no sockets, no sleeps.

use std::collections::BTreeMap;

use crate::sparse::SplitMix64;

/// Retry/backoff policy knobs.
#[derive(Debug, Clone)]
pub struct LeasePolicy {
    /// How long a worker may hold a shard before the reaper re-queues it.
    pub lease_ms: u64,
    /// Failures (expired leases, corrupt frames, rejected submissions)
    /// before a worker is quarantined.
    pub max_failures: u32,
    /// Base of the exponential backoff a failed worker sits out:
    /// `base << (failures-1)` plus up to `base` of seeded jitter.
    pub backoff_base_ms: u64,
    /// Jitter seed (deterministic for a fixed grant/fail order).
    pub seed: u64,
}

impl Default for LeasePolicy {
    fn default() -> Self {
        Self { lease_ms: 30_000, max_failures: 3, backoff_base_ms: 200, seed: 0x6d61_706c_65 }
    }
}

/// What [`LeaseTable::grant`] hands a requesting worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Grant {
    /// Compute shard `index`; this is attempt number `attempt` on it.
    Lease { index: usize, attempt: u32 },
    /// Nothing grantable right now; ask again in about `ms`.
    Wait { ms: u64 },
    /// Every shard is done.
    Done,
    /// This worker exhausted its retry budget.
    Quarantined,
}

#[derive(Debug, Clone, PartialEq, Eq)]
enum Slot {
    Pending { attempt: u32 },
    Leased { worker: String, deadline: u64, attempt: u32 },
    Done,
}

#[derive(Debug, Clone, Default)]
struct WorkerState {
    failures: u32,
    backoff_until: u64,
    quarantined: bool,
}

/// A read-only snapshot of one slot — what [`LeaseTable::slot_views`]
/// exposes to the `analysis` model checker (and anything else that wants
/// to observe the table without reaching into its internals).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SlotView {
    Pending { attempt: u32 },
    Leased { worker: String, deadline: u64, attempt: u32 },
    Done,
}

/// A read-only snapshot of one worker's failure record
/// ([`LeaseTable::worker_views`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerView {
    pub id: String,
    pub failures: u32,
    pub backoff_until: u64,
    pub quarantined: bool,
}

/// The coordinator's authoritative shard/worker state. `Clone` so the
/// model checker can fork it at every abstract event.
#[derive(Debug, Clone)]
pub struct LeaseTable {
    slots: Vec<Slot>,
    /// BTreeMap for deterministic iteration order in stats and tests.
    workers: BTreeMap<String, WorkerState>,
    policy: LeasePolicy,
    rng: SplitMix64,
    reassignments: u64,
}

impl LeaseTable {
    pub fn new(shard_count: usize, policy: LeasePolicy) -> Self {
        let rng = SplitMix64::new(policy.seed);
        Self {
            slots: vec![Slot::Pending { attempt: 0 }; shard_count],
            workers: BTreeMap::new(),
            policy,
            rng,
            reassignments: 0,
        }
    }

    /// Register a worker (idempotent — re-registration after a reconnect or
    /// a coordinator restart keeps the existing failure record if there is
    /// one, so backoff/quarantine cannot be laundered by reconnecting).
    pub fn register(&mut self, id: &str) {
        self.workers.entry(id.to_string()).or_default();
    }

    /// Grant work to `id` at time `now` (ms since the coordinator epoch).
    /// Auto-registers unknown workers: a worker that re-registered with a
    /// restarted coordinator mid-request must not be refused.
    pub fn grant(&mut self, id: &str, now: u64) -> Grant {
        self.register(id);
        let w = &self.workers[id];
        if w.quarantined {
            return Grant::Quarantined;
        }
        if now < w.backoff_until {
            return Grant::Wait { ms: (w.backoff_until - now).clamp(10, 10_000) };
        }
        for (index, slot) in self.slots.iter_mut().enumerate() {
            if let Slot::Pending { attempt } = *slot {
                let attempt = attempt + 1;
                *slot = Slot::Leased {
                    worker: id.to_string(),
                    deadline: now + self.policy.lease_ms,
                    attempt,
                };
                return Grant::Lease { index, attempt };
            }
        }
        if self.all_done() {
            return Grant::Done;
        }
        // Everything is leased out: poll again around the earliest deadline
        // (clamped so workers neither spin nor oversleep a reassignment).
        let earliest = self
            .slots
            .iter()
            .filter_map(|s| match s {
                Slot::Leased { deadline, .. } => Some(*deadline),
                _ => None,
            })
            .min()
            .unwrap_or(now);
        Grant::Wait { ms: earliest.saturating_sub(now).clamp(10, 200) }
    }

    /// Re-queue every expired lease (work-stealing) and penalise the holder.
    /// Returns how many leases were reaped.
    pub fn reap(&mut self, now: u64) -> usize {
        let mut expired: Vec<(usize, String, u32)> = Vec::new();
        for (i, slot) in self.slots.iter().enumerate() {
            if let Slot::Leased { worker, deadline, attempt } = slot {
                if now >= *deadline {
                    expired.push((i, worker.clone(), *attempt));
                }
            }
        }
        for (i, worker, attempt) in &expired {
            self.slots[*i] = Slot::Pending { attempt: *attempt };
            self.reassignments += 1;
            self.penalise(worker, now);
        }
        expired.len()
    }

    /// Mark shard `index` done. Accepts completion from *any* worker — a
    /// stalled worker whose lease was stolen may still deliver first, and a
    /// valid result is a valid result. Returns false if out of range or
    /// already done.
    pub fn complete(&mut self, index: usize) -> bool {
        match self.slots.get_mut(index) {
            Some(slot @ (Slot::Pending { .. } | Slot::Leased { .. })) => {
                *slot = Slot::Done;
                true
            }
            _ => false,
        }
    }

    /// Record a failure for `id` (corrupt frame, rejected submission):
    /// exponential backoff with jitter, quarantine past the budget.
    pub fn fail(&mut self, id: &str, now: u64) {
        self.register(id);
        self.penalise(id, now);
    }

    fn penalise(&mut self, id: &str, now: u64) {
        let base = self.policy.backoff_base_ms.max(1);
        let max_failures = self.policy.max_failures;
        // Jitter draws from the table RNG even when unused below, keeping
        // the stream position a pure function of the penalty sequence.
        let jitter = self.rng.below(base);
        let Some(w) = self.workers.get_mut(id) else { return };
        w.failures += 1;
        if w.failures >= max_failures {
            w.quarantined = true;
        } else {
            let shift = (w.failures - 1).min(6);
            w.backoff_until = now + (base << shift) + jitter;
        }
    }

    pub fn all_done(&self) -> bool {
        self.slots.iter().all(|s| matches!(s, Slot::Done))
    }

    pub fn completed(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Done)).count()
    }

    /// How many expired leases were re-queued over the table's lifetime —
    /// the provenance counter the chaos CI job asserts on.
    pub fn reassignments(&self) -> u64 {
        self.reassignments
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    pub fn quarantined(&self) -> usize {
        self.workers.values().filter(|w| w.quarantined).count()
    }

    /// Snapshot every slot, index order.
    pub fn slot_views(&self) -> Vec<SlotView> {
        self.slots
            .iter()
            .map(|s| match s {
                Slot::Pending { attempt } => SlotView::Pending { attempt: *attempt },
                Slot::Leased { worker, deadline, attempt } => SlotView::Leased {
                    worker: worker.clone(),
                    deadline: *deadline,
                    attempt: *attempt,
                },
                Slot::Done => SlotView::Done,
            })
            .collect()
    }

    /// Snapshot every registered worker, id order (the map is a BTreeMap).
    pub fn worker_views(&self) -> Vec<WorkerView> {
        self.workers
            .iter()
            .map(|(id, w)| WorkerView {
                id: id.clone(),
                failures: w.failures,
                backoff_until: w.backoff_until,
                quarantined: w.quarantined,
            })
            .collect()
    }

    /// Mutation hook for `maple vet --mutant double-grant`: re-assign a
    /// *live* lease to another worker without reaping it — the classic
    /// double-grant bug. Only `analysis::model` calls this, and only when
    /// that mutation is selected; it exists so the checker's
    /// bug-detection claim is tested against the real table, not a copy.
    pub(crate) fn force_grant(&mut self, index: usize, id: &str, now: u64) -> Option<u32> {
        self.register(id);
        match self.slots.get_mut(index) {
            Some(Slot::Leased { worker, deadline, attempt }) => {
                *worker = id.to_string();
                *deadline = now + self.policy.lease_ms;
                Some(*attempt)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(count: usize) -> LeaseTable {
        LeaseTable::new(
            count,
            LeasePolicy { lease_ms: 100, max_failures: 3, backoff_base_ms: 50, seed: 1 },
        )
    }

    #[test]
    fn leases_then_waits_then_done() {
        let mut t = table(2);
        assert_eq!(t.grant("a", 0), Grant::Lease { index: 0, attempt: 1 });
        assert_eq!(t.grant("b", 0), Grant::Lease { index: 1, attempt: 1 });
        // Everything leased: a third worker waits, bounded by the deadline.
        match t.grant("c", 10) {
            Grant::Wait { ms } => assert!((10..=200).contains(&ms), "wait {ms}"),
            other => panic!("expected Wait, got {other:?}"),
        }
        assert!(t.complete(0));
        assert!(t.complete(1));
        assert!(!t.complete(1), "double-complete is a no-op");
        assert!(t.all_done());
        assert_eq!(t.grant("a", 20), Grant::Done);
        assert_eq!(t.completed(), 2);
    }

    #[test]
    fn expired_leases_are_reaped_and_stolen() {
        let mut t = table(1);
        assert_eq!(t.grant("slow", 0), Grant::Lease { index: 0, attempt: 1 });
        assert_eq!(t.reap(99), 0, "lease still live at 99 ms");
        assert_eq!(t.reap(100), 1, "lease expires at 100 ms");
        assert_eq!(t.reassignments(), 1);
        // The reassigned attempt counter increments; another worker steals.
        assert_eq!(t.grant("fast", 101), Grant::Lease { index: 0, attempt: 2 });
        // The slow worker's stale result is still a valid completion.
        assert!(t.complete(0));
        assert!(t.all_done());
    }

    #[test]
    fn failures_back_off_exponentially_then_quarantine() {
        let mut t = table(4);
        t.fail("w", 0);
        let wait1 = match t.grant("w", 1) {
            Grant::Wait { ms } => ms,
            other => panic!("expected backoff Wait, got {other:?}"),
        };
        // First failure: base(50) + jitter(<50) remaining.
        assert!((10..100).contains(&wait1), "first backoff {wait1}");
        // Past the backoff window the worker gets work again.
        assert!(matches!(t.grant("w", 1000), Grant::Lease { .. }));
        t.fail("w", 1000);
        // Second failure doubles the base: 100 + jitter.
        match t.grant("w", 1001) {
            Grant::Wait { ms } => assert!((99..200).contains(&ms), "second backoff {ms}"),
            other => panic!("expected Wait, got {other:?}"),
        }
        t.fail("w", 2000);
        assert_eq!(t.grant("w", 9999), Grant::Quarantined);
        assert_eq!(t.quarantined(), 1);
        // Re-registering does not launder the quarantine.
        t.register("w");
        assert_eq!(t.grant("w", 10_000), Grant::Quarantined);
        // Other workers are unaffected.
        assert!(matches!(t.grant("v", 10_000), Grant::Lease { .. }));
    }

    #[test]
    fn stalled_holder_is_penalised_by_the_reaper() {
        let mut t = table(1);
        for round in 0..3u64 {
            let now = round * 1000;
            match t.grant("stall", now + 900) {
                Grant::Lease { .. } => {
                    t.reap(now + 900 + 100); // let it expire
                }
                Grant::Wait { .. } => {} // still in backoff
                Grant::Quarantined => break,
                Grant::Done => panic!("nothing was completed"),
            }
        }
        assert_eq!(t.grant("stall", 10_000), Grant::Quarantined);
        assert_eq!(t.reassignments(), 3);
    }

    #[test]
    fn jitter_is_seed_deterministic() {
        let mk = || {
            let mut t = table(2);
            t.fail("w", 0);
            match t.grant("w", 0) {
                Grant::Wait { ms } => ms,
                other => panic!("expected Wait, got {other:?}"),
            }
        };
        assert_eq!(mk(), mk());
    }
}
