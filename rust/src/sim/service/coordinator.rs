//! The sweep coordinator: owns the design space, leases shards to workers,
//! merges `MAPLESHD` submissions incrementally, and survives every failure
//! mode the fault harness can throw at it.
//!
//! One [`Coordinator::run`] call serves one [`DesignSpace`]: it
//! fingerprints and splits the grid up front, then accepts worker
//! connections on a nonblocking listener, each served by its own handler
//! thread against shared [`LeaseTable`] + [`SubmissionLedger`] state. The
//! accept loop doubles as the reaper tick (expired leases re-queue for
//! work-stealing) and the wall-clock guard — a sweep can end complete,
//! partial (`allow_partial`), or as a loud typed
//! [`ServiceError::Incomplete`], but never as a hang: every socket read is
//! bounded by a timeout and the whole run by `max_wall_ms`.
//!
//! The [`SubmissionLedger`] is deliberately a pure, connection-free type:
//! it owns first-valid-wins idempotency (identical resubmissions are
//! acknowledged as duplicates, byte-divergent ones rejected loudly) and is
//! unit-tested in `tests/shard.rs` without a single socket. Submissions
//! are compared in *canonical* form — volatile run stats (wall-time,
//! cache-hit counters) zeroed — so the same cells computed at different
//! speeds by different workers still count as the same shard.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::lease::{Grant, LeasePolicy, LeaseTable};
use super::proto::{self, AckCode, Message, ProtoError};
use super::ServiceError;
use crate::sim::cache::codec::{self, CodecError};
use crate::sim::engine::DesignSpace;
use crate::sim::shard::{self, PartialSweep, SweepShard};
use crate::sim::SweepResult;

/// Coordinator knobs (CLI: `maple serve`).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// How many shards the grid splits into (work-stealing granularity).
    pub shard_count: usize,
    pub lease: LeasePolicy,
    /// Hard wall-clock bound on the whole sweep — the no-hang guarantee
    /// when every worker dies and nothing re-queues.
    pub max_wall_ms: u64,
    /// Render the completed sub-grid instead of erroring when the deadline
    /// passes with shards missing.
    pub allow_partial: bool,
    /// Profile-pass chunk count every worker must run with (checksum bits
    /// depend on it; the ledger rejects shards computed under any other).
    pub profile_threads: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            shard_count: 8,
            lease: LeasePolicy::default(),
            max_wall_ms: 600_000,
            allow_partial: false,
            profile_threads: 1,
        }
    }
}

/// What one service run did — the provenance block's inputs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServiceStats {
    pub fingerprint: u64,
    pub shard_count: usize,
    pub completed: usize,
    /// Distinct workers that ever registered.
    pub workers: usize,
    /// Expired leases re-queued to other workers.
    pub reassignments: u64,
    /// Idempotently-accepted identical resubmissions.
    pub duplicates: u64,
    /// Invalid or byte-divergent submissions dropped.
    pub rejected: u64,
    /// Workers that exhausted their retry budget.
    pub quarantined: usize,
    pub wall_ms: u64,
}

/// A completed service sweep: the full bit-exact grid, or — under
/// `allow_partial` — the completed sub-grid with explicit provenance.
#[derive(Debug)]
pub enum SweepOutcome {
    Full(SweepResult),
    Partial(PartialSweep),
}

// ------------------------------------------------------------------ ledger

/// Submission outcome for a valid shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// First valid submission for its range.
    Accepted,
    /// Byte-identical (canonical form) resubmission: idempotent no-op.
    Duplicate,
}

/// Why a submission was rejected. Loud and specific, like the merge-side
/// [`crate::sim::shard::ShardError`] taxonomy it mirrors.
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("shard artifact undecodable: {0}")]
    Artifact(#[from] CodecError),
    #[error("shard fingerprint {found:#018x} != space fingerprint {expected:#018x}")]
    Fingerprint { expected: u64, found: u64 },
    #[error("shard is part of a {found}-way split, the service runs {expected}-way")]
    Count { expected: usize, found: usize },
    #[error("shard profiled with {found} chunks, the service requires {expected}")]
    ProfileThreads { expected: usize, found: usize },
    #[error("shard grid has {found} cells, the space has {expected}")]
    Grid { expected: usize, found: usize },
    #[error(
        "shard {index} covers cells [{found_start}..{found_end}) but its canonical \
         range is [{expected_start}..{expected_end})"
    )]
    Range {
        index: usize,
        found_start: usize,
        found_end: usize,
        expected_start: usize,
        expected_end: usize,
    },
    #[error(
        "byte-divergent resubmission of shard {index}: the stored result differs \
         cell-for-cell from this one (first valid submission wins)"
    )]
    Conflict { index: usize },
}

/// The pure first-valid-wins slot machine under [`SubmissionLedger`]: one
/// optional canonical-bytes payload per shard index, nothing else. Split
/// out so the `analysis` model checker can drive the *exact* acceptance
/// logic the coordinator runs — store-on-first, duplicate on identical
/// bytes, conflict on divergent bytes — without decoding real `MAPLESHD`
/// artifacts.
#[derive(Debug, Clone)]
pub struct LedgerCore {
    slots: Vec<Option<Vec<u8>>>,
}

impl LedgerCore {
    pub fn new(shard_count: usize) -> Self {
        Self { slots: vec![None; shard_count] }
    }

    pub fn shard_count(&self) -> usize {
        self.slots.len()
    }

    /// Offer canonical bytes for `index`. First submission is stored;
    /// byte-identical resubmissions are idempotent duplicates; divergent
    /// ones are conflicts (the stored payload never changes).
    pub fn offer(&mut self, index: usize, canonical: &[u8]) -> Result<SubmitOutcome, SubmitError> {
        match &self.slots[index] {
            None => {
                self.slots[index] = Some(canonical.to_vec());
                Ok(SubmitOutcome::Accepted)
            }
            Some(stored) if stored == canonical => Ok(SubmitOutcome::Duplicate),
            Some(_) => Err(SubmitError::Conflict { index }),
        }
    }

    /// The stored canonical bytes for `index`, if any.
    pub fn payload(&self, index: usize) -> Option<&[u8]> {
        self.slots.get(index).and_then(|s| s.as_deref())
    }

    pub fn completed(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_complete(&self) -> bool {
        self.completed() == self.slots.len()
    }

    /// Missing shard indices (first 8 — the same bound as
    /// [`crate::sim::shard::ShardError::MissingShards`]).
    pub fn missing(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .take(8)
            .collect()
    }

    /// Mutation hook for `maple vet --mutant quarantine-bypass`: overwrite
    /// a merged payload unconditionally — the bug [`LedgerCore::offer`]
    /// exists to prevent. Only `analysis::model` calls this, and only when
    /// that mutation is selected.
    pub(crate) fn force_store(&mut self, index: usize, bytes: &[u8]) {
        self.slots[index] = Some(bytes.to_vec());
    }
}

/// Incremental, idempotent shard collection for one sweep. First valid
/// submission per range wins; identical resubmissions are duplicates;
/// divergent ones are conflicts. "Identical" means canonical-byte-identical:
/// volatile [`crate::sim::shard::ShardMeta`] stats are zeroed before
/// comparison (two workers computing the same cells at different speeds
/// submit the *same* shard). Validation (fingerprint, split arity, range,
/// profile chunking) lives here; the acceptance state machine is the
/// embedded [`LedgerCore`].
pub struct SubmissionLedger {
    fingerprint: u64,
    shard_count: usize,
    total_cells: usize,
    profile_threads: usize,
    core: LedgerCore,
    shards: Vec<Option<SweepShard>>,
    duplicates: u64,
    rejected: u64,
}

impl SubmissionLedger {
    pub fn new(
        fingerprint: u64,
        shard_count: usize,
        total_cells: usize,
        profile_threads: usize,
    ) -> Self {
        let mut shards = Vec::with_capacity(shard_count);
        shards.resize_with(shard_count, || None);
        Self {
            fingerprint,
            shard_count,
            total_cells,
            profile_threads,
            core: LedgerCore::new(shard_count),
            shards,
            duplicates: 0,
            rejected: 0,
        }
    }

    /// Offer raw `MAPLESHD` bytes. Returns the shard index with the
    /// outcome, or why the submission was rejected (rejections are counted
    /// but never stored).
    pub fn offer(&mut self, bytes: &[u8]) -> Result<(usize, SubmitOutcome), SubmitError> {
        match self.validate(bytes) {
            Ok(v) => Ok(v),
            Err(e) => {
                self.rejected += 1;
                Err(e)
            }
        }
    }

    fn validate(&mut self, bytes: &[u8]) -> Result<(usize, SubmitOutcome), SubmitError> {
        let shard = codec::decode_shard(bytes)?;
        if shard.fingerprint != self.fingerprint {
            return Err(SubmitError::Fingerprint {
                expected: self.fingerprint,
                found: shard.fingerprint,
            });
        }
        if shard.spec.count != self.shard_count {
            return Err(SubmitError::Count {
                expected: self.shard_count,
                found: shard.spec.count,
            });
        }
        if shard.meta.profile_threads != self.profile_threads {
            return Err(SubmitError::ProfileThreads {
                expected: self.profile_threads,
                found: shard.meta.profile_threads,
            });
        }
        if shard.total_cells() != self.total_cells {
            return Err(SubmitError::Grid {
                expected: self.total_cells,
                found: shard.total_cells(),
            });
        }
        let canonical_range = shard.spec.range(self.total_cells);
        if shard.range() != canonical_range {
            return Err(SubmitError::Range {
                index: shard.spec.index,
                found_start: shard.range().start,
                found_end: shard.range().end,
                expected_start: canonical_range.start,
                expected_end: canonical_range.end,
            });
        }
        let canonical = canonical_bytes(&shard);
        let index = shard.spec.index;
        let outcome = self.core.offer(index, &canonical)?;
        match outcome {
            SubmitOutcome::Accepted => self.shards[index] = Some(shard),
            SubmitOutcome::Duplicate => self.duplicates += 1,
        }
        Ok((index, outcome))
    }

    pub fn completed(&self) -> usize {
        self.core.completed()
    }

    pub fn is_complete(&self) -> bool {
        self.core.is_complete()
    }

    /// Missing shard indices (first 8 — the same bound as
    /// [`crate::sim::shard::ShardError::MissingShards`]).
    pub fn missing(&self) -> Vec<usize> {
        self.core.missing()
    }

    /// The stored shards, index order (for [`shard::merge`] /
    /// [`shard::merge_partial`]).
    pub fn shards(&self) -> Vec<SweepShard> {
        self.shards.iter().flatten().cloned().collect()
    }

    pub fn duplicates(&self) -> u64 {
        self.duplicates
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }
}

/// The comparison form for duplicate detection: the artifact re-encoded
/// with volatile run stats zeroed (`profile_threads` stays — it changes
/// checksum bits, so it is identity, not noise).
fn canonical_bytes(shard: &SweepShard) -> Vec<u8> {
    let mut c = shard.clone();
    c.meta.wall_ms = 0;
    c.meta.profiles_run = 0;
    c.meta.disk_hits = 0;
    codec::encode_shard(&c)
}

// ------------------------------------------------------------- coordinator

/// Shared state every connection handler works against.
struct Shared {
    lease: Mutex<LeaseTable>,
    ledger: Mutex<SubmissionLedger>,
    done: AtomicBool,
    epoch: Instant,
    lease_ms: u64,
    shard_count: usize,
    /// The `Space` frame, encoded once (it can be large).
    space_frame: Vec<u8>,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

/// A bound sweep service. [`Coordinator::bind`] then [`Coordinator::run`];
/// `run` consumes the listener's lifetime but the coordinator can be
/// re-bound for the next sweep.
pub struct Coordinator {
    listener: TcpListener,
    cfg: ServiceConfig,
}

impl Coordinator {
    /// Bind the service socket (use port 0 for an ephemeral test port).
    pub fn bind(addr: impl ToSocketAddrs, cfg: ServiceConfig) -> Result<Self, ServiceError> {
        let listener = TcpListener::bind(addr).map_err(ServiceError::Io)?;
        Ok(Self { listener, cfg })
    }

    pub fn local_addr(&self) -> Result<SocketAddr, ServiceError> {
        self.listener.local_addr().map_err(ServiceError::Io)
    }

    /// Serve one design space to completion (or the wall-clock bound).
    pub fn run(&self, space: &DesignSpace) -> Result<(SweepOutcome, ServiceStats), ServiceError> {
        let expanded = space.expand()?;
        let total_cells = expanded.total_cells();
        let fingerprint = expanded.fingerprint(space.cell_model);
        let shard_count = self.cfg.shard_count.max(1);
        let space_frame = proto::encode_message(&Message::Space {
            fingerprint,
            shard_count: shard_count as u64,
            profile_threads: self.cfg.profile_threads as u64,
            space: space.clone(),
        });
        let shared = Arc::new(Shared {
            lease: Mutex::new(LeaseTable::new(shard_count, self.cfg.lease.clone())),
            ledger: Mutex::new(SubmissionLedger::new(
                fingerprint,
                shard_count,
                total_cells,
                self.cfg.profile_threads,
            )),
            done: AtomicBool::new(false),
            epoch: Instant::now(),
            lease_ms: self.cfg.lease.lease_ms,
            shard_count,
            space_frame,
        });

        self.listener.set_nonblocking(true).map_err(ServiceError::Io)?;
        let mut handlers = Vec::new();
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let shared = Arc::clone(&shared);
                    // vet:allow(unscoped-thread): every handler is joined before run() returns
                    handlers.push(std::thread::spawn(move || handle_connection(&shared, stream)));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                // Transient accept errors (e.g. a peer resetting mid
                // handshake) must not kill the sweep.
                Err(_) => {}
            }
            let now = shared.now_ms();
            shared.lease.lock().expect("lease table poisoned").reap(now);
            if shared.ledger.lock().expect("ledger poisoned").is_complete()
                || now >= self.cfg.max_wall_ms
            {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        // Wind down: handlers keep answering `Done` for a short grace
        // period (so polite workers exit cleanly), then exit on their own
        // idle timers; joining bounds the run.
        shared.done.store(true, Ordering::SeqCst);
        for h in handlers {
            let _ = h.join();
        }

        let lease = shared.lease.lock().expect("lease table poisoned");
        let ledger = shared.ledger.lock().expect("ledger poisoned");
        let stats = ServiceStats {
            fingerprint,
            shard_count,
            completed: ledger.completed(),
            workers: lease.workers(),
            reassignments: lease.reassignments(),
            duplicates: ledger.duplicates(),
            rejected: ledger.rejected(),
            quarantined: lease.quarantined(),
            wall_ms: shared.now_ms(),
        };
        let shards = ledger.shards();
        let outcome = if ledger.is_complete() {
            SweepOutcome::Full(shard::merge(&shards)?)
        } else if self.cfg.allow_partial && !shards.is_empty() {
            SweepOutcome::Partial(shard::merge_partial(&shards)?)
        } else {
            return Err(ServiceError::Incomplete {
                completed: ledger.completed(),
                count: shard_count,
                missing: ledger.missing(),
            });
        };
        Ok((outcome, stats))
    }
}

/// What one 100 ms read tick on a worker connection produced.
enum Tick {
    /// First byte of a frame arrived.
    Byte(u8),
    /// Peer closed the stream.
    Eof,
    /// Nothing arrived inside the timeout.
    Idle,
}

fn read_tick(stream: &mut TcpStream) -> io::Result<Tick> {
    let mut byte = [0u8; 1];
    match stream.read(&mut byte) {
        Ok(0) => Ok(Tick::Eof),
        Ok(_) => Ok(Tick::Byte(byte[0])),
        Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
            Ok(Tick::Idle)
        }
        Err(e) => Err(e),
    }
}

/// Serve one worker connection. Any protocol violation — bad magic,
/// checksum mismatch, a read dying mid-frame — closes the connection and
/// penalises the worker (if it ever identified itself); the reaper handles
/// whatever lease it held. A clean EOF is just a disconnect.
fn handle_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut worker_id: Option<String> = None;
    let mut idle_ticks_after_done = 0u32;
    loop {
        let tick = match read_tick(&mut stream) {
            Ok(t) => t,
            Err(_) => break,
        };
        let first = match tick {
            Tick::Eof => break,
            Tick::Idle => {
                if shared.done.load(Ordering::SeqCst) {
                    idle_ticks_after_done += 1;
                    // ~2 s of post-completion silence: the worker is gone
                    // or asleep; stop holding the thread.
                    if idle_ticks_after_done > 20 {
                        break;
                    }
                }
                continue;
            }
            Tick::Byte(b) => b,
        };
        idle_ticks_after_done = 0;
        let msg = match proto::read_message_tail(first, &mut stream) {
            Ok(msg) => msg,
            Err(ProtoError::Io(_)) => break, // died mid-frame; reaper recovers
            Err(_) => {
                // A frame that decodes wrong (forged checksum, bad magic)
                // is a worker failure: penalise and force a reconnect —
                // there is no way to resynchronise a byte stream.
                if let Some(id) = &worker_id {
                    shared.lease.lock().expect("lease table poisoned").fail(id, shared.now_ms());
                }
                break;
            }
        };
        let reply = match msg {
            Message::Register { worker_id: id } => {
                shared.lease.lock().expect("lease table poisoned").register(&id);
                worker_id = Some(id);
                // The Space frame is pre-encoded; send it verbatim.
                if stream.write_all(&shared.space_frame).is_err() {
                    break;
                }
                continue;
            }
            Message::Request { worker_id: id } => {
                let grant = if shared.done.load(Ordering::SeqCst) {
                    Grant::Done
                } else {
                    shared
                        .lease
                        .lock()
                        .expect("lease table poisoned")
                        .grant(&id, shared.now_ms())
                };
                worker_id = Some(id);
                match grant {
                    Grant::Lease { index, attempt } => Message::Lease {
                        index: index as u64,
                        count: shared.shard_count as u64,
                        attempt,
                        lease_ms: shared.lease_ms,
                    },
                    Grant::Wait { ms } => Message::Wait { ms },
                    Grant::Done => Message::Done,
                    Grant::Quarantined => Message::Quarantined,
                }
            }
            Message::Submit { worker_id: id, shard } => {
                worker_id = Some(id.clone());
                let offered =
                    shared.ledger.lock().expect("ledger poisoned").offer(&shard);
                match offered {
                    Ok((index, outcome)) => {
                        shared.lease.lock().expect("lease table poisoned").complete(index);
                        let code = match outcome {
                            SubmitOutcome::Accepted => AckCode::Accepted,
                            SubmitOutcome::Duplicate => AckCode::Duplicate,
                        };
                        Message::Ack { code, reason: String::new() }
                    }
                    Err(e) => {
                        shared
                            .lease
                            .lock()
                            .expect("lease table poisoned")
                            .fail(&id, shared.now_ms());
                        Message::Ack { code: AckCode::Rejected, reason: e.to_string() }
                    }
                }
            }
            // Coordinator-bound kinds arriving here mean a confused peer:
            // drop the connection rather than guess.
            _ => break,
        };
        if proto::write_message(&mut stream, &reply).is_err() {
            break;
        }
    }
}
