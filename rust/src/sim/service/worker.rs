//! The sweep worker: connect, register, lease shards, compute, submit.
//!
//! A worker is a thin loop around [`crate::sim::engine::SimEngine::sweep_shard`]:
//! it registers with the coordinator, receives the full [`DesignSpace`] over
//! the wire (verifying the advertised fingerprint against its own decode —
//! a worker never computes against a space it cannot prove it agrees on),
//! and then requests leases until the coordinator says `Done`. Every
//! transport hiccup — a dropped connection, a timed-out read, a coordinator
//! restart — is survived by reconnecting and idempotently re-registering,
//! bounded by [`WorkerConfig::max_reconnects`] so a dead coordinator is a
//! loud [`ServiceError::Connect`], never a hang.
//!
//! All frames leave through the [`FaultInjector`] so `maple chaos` and the
//! integration tests can make *this exact code path* drop, corrupt, stall,
//! duplicate, kill-and-rejoin, or die on a deterministic schedule.

use std::io;
use std::net::TcpStream;
use std::time::Duration;

use super::fault::{FaultEvent, FaultInjector, FaultPlan};
use super::proto::{self, AckCode, Message};
use super::ServiceError;
use crate::sim::cache::codec;
use crate::sim::engine::{DesignSpace, SimEngine};
use crate::sim::shard::ShardSpec;

/// Worker knobs (CLI: `maple work`).
#[derive(Debug, Clone, Default)]
pub struct WorkerConfig {
    /// Stable identity across reconnects — the coordinator's failure
    /// accounting and lease table key. Defaults to `worker-<pid>`.
    pub id: String,
    /// Total (re)connection attempts before giving up on the coordinator.
    pub max_reconnects: u32,
    /// Pause between connection attempts.
    pub reconnect_ms: u64,
    /// Self-inflicted faults (chaos testing); `None` for honest work.
    pub fault: Option<FaultPlan>,
}

impl WorkerConfig {
    /// A default-tuned config with an explicit identity.
    pub fn named(id: impl Into<String>) -> Self {
        Self { id: id.into(), max_reconnects: 40, reconnect_ms: 100, fault: None }
    }

    fn with_defaults(mut self) -> Self {
        if self.id.is_empty() {
            self.id = format!("worker-{}", std::process::id());
        }
        if self.max_reconnects == 0 {
            self.max_reconnects = 40;
        }
        if self.reconnect_ms == 0 {
            self.reconnect_ms = 100;
        }
        self
    }
}

/// What one worker run did, for the CLI summary and chaos assertions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkerReport {
    pub id: String,
    /// Leases taken (including ones lost to faults).
    pub leases: u64,
    /// Shards submitted and accepted as first-valid.
    pub submitted: u64,
    /// Submissions acknowledged as idempotent duplicates.
    pub duplicates: u64,
    /// Submissions rejected by the coordinator.
    pub rejected: u64,
    /// Sessions re-established after a drop/kill/restart.
    pub reconnects: u64,
    /// The worker executed a `die` fault and exited mid-sweep.
    pub died: bool,
    /// The deterministic fault trace (empty for honest workers).
    pub events: Vec<FaultEvent>,
}

impl WorkerReport {
    fn new(id: String) -> Self {
        Self {
            id,
            leases: 0,
            submitted: 0,
            duplicates: 0,
            rejected: 0,
            reconnects: 0,
            died: false,
            events: Vec::new(),
        }
    }
}

/// Why the current session ended and what the outer loop should do.
enum Session {
    /// Coordinator said `Done` — the sweep is over.
    Finished,
    /// The worker executed a `die` fault.
    Died,
    /// Connection lost (fault or genuine) — reconnect and re-register.
    Reconnect,
}

/// Run one worker against `addr` until the sweep completes, the fault plan
/// kills it, or the coordinator becomes unreachable.
pub fn run(addr: &str, engine: SimEngine, cfg: WorkerConfig) -> Result<WorkerReport, ServiceError> {
    let cfg = cfg.with_defaults();
    let mut report = WorkerReport::new(cfg.id.clone());
    // The injector (and its frame counter) lives across reconnects, so a
    // plan like `drop:1,corrupt:3` counts frames over the whole run.
    let mut injector = FaultInjector::new(cfg.fault.as_ref());
    let mut engine = engine;
    let mut attempts_left = cfg.max_reconnects;
    let outcome = loop {
        let mut stream = match connect(addr, &cfg, &mut attempts_left) {
            Ok(stream) => stream,
            Err(e) => break Err(e),
        };
        match session(&mut stream, &mut engine, &cfg, &mut injector, &mut report) {
            Ok(Session::Finished) => break Ok(()),
            Ok(Session::Died) => {
                report.died = true;
                break Ok(());
            }
            Ok(Session::Reconnect) => {
                report.reconnects += 1;
                continue;
            }
            Err(SessionError::Fatal(e)) => break Err(e),
            Err(SessionError::Transport) => {
                report.reconnects += 1;
                continue;
            }
        }
    };
    report.events = injector.events.clone();
    outcome.map(|()| report)
}

fn connect(
    addr: &str,
    cfg: &WorkerConfig,
    attempts_left: &mut u32,
) -> Result<TcpStream, ServiceError> {
    let mut last_err: Option<io::Error> = None;
    while *attempts_left > 0 {
        *attempts_left -= 1;
        match TcpStream::connect(addr) {
            Ok(stream) => {
                let _ = stream.set_nodelay(true);
                // Generous read timeout: the no-hang backstop when the
                // coordinator vanishes between a request and its reply.
                let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
                return Ok(stream);
            }
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(Duration::from_millis(cfg.reconnect_ms));
            }
        }
    }
    let source = last_err.unwrap_or_else(|| io::Error::other("reconnect budget exhausted"));
    Err(ServiceError::Connect { addr: addr.to_string(), attempts: cfg.max_reconnects, source })
}

/// Session-scoped error split: transport errors trigger a reconnect, fatal
/// ones abort the worker.
enum SessionError {
    Transport,
    Fatal(ServiceError),
}

impl From<io::Error> for SessionError {
    fn from(_: io::Error) -> Self {
        SessionError::Transport
    }
}

impl From<proto::ProtoError> for SessionError {
    // Both I/O (peer vanished mid-frame) and decode failures (a frame that
    // cannot be trusted) resolve the same way: a fresh connection. The
    // bounded reconnect budget keeps a persistently-bad coordinator loud.
    fn from(_: proto::ProtoError) -> Self {
        SessionError::Transport
    }
}

fn session(
    stream: &mut TcpStream,
    engine: &mut SimEngine,
    cfg: &WorkerConfig,
    injector: &mut FaultInjector,
    report: &mut WorkerReport,
) -> Result<Session, SessionError> {
    injector.send(stream, &Message::Register { worker_id: cfg.id.clone() })?;
    let space: DesignSpace = match proto::read_message(stream)? {
        Message::Space { fingerprint, shard_count: _, profile_threads, space } => {
            // Prove the decoded space is the one the coordinator hashed —
            // a codec or version skew must fail here, not as a rejected
            // submission three minutes of compute later.
            let decoded = match space.fingerprint() {
                Ok(f) => f,
                Err(e) => return Err(SessionError::Fatal(ServiceError::Engine(e))),
            };
            if decoded != fingerprint {
                return Err(SessionError::Fatal(ServiceError::FingerprintSkew {
                    advertised: fingerprint,
                    decoded,
                }));
            }
            apply_profile_threads(engine, profile_threads as usize);
            space
        }
        _ => return Err(SessionError::Transport),
    };
    loop {
        injector.send(stream, &Message::Request { worker_id: cfg.id.clone() })?;
        match proto::read_message(stream)? {
            Message::Lease { index, count, attempt: _, lease_ms } => {
                report.leases += 1;
                if injector.take_die(index) {
                    return Ok(Session::Died);
                }
                if injector.take_kill(index) {
                    let _ = stream.shutdown(std::net::Shutdown::Both);
                    return Ok(Session::Reconnect);
                }
                if injector.take_stall(lease_ms) {
                    std::thread::sleep(Duration::from_millis(lease_ms + 150));
                }
                let spec = match ShardSpec::new(index as usize, count as usize) {
                    Ok(spec) => spec,
                    Err(e) => return Err(SessionError::Fatal(ServiceError::Shard(e))),
                };
                let shard = match engine.sweep_shard(&space, spec) {
                    Ok(shard) => shard,
                    Err(e) => return Err(SessionError::Fatal(ServiceError::Engine(e))),
                };
                let bytes = codec::encode_shard(&shard);
                submit(stream, injector, cfg, report, &bytes)?;
                if injector.take_dup(index) {
                    submit(stream, injector, cfg, report, &bytes)?;
                }
            }
            Message::Wait { ms } => {
                std::thread::sleep(Duration::from_millis(ms.clamp(1, 250)));
            }
            Message::Done => return Ok(Session::Finished),
            Message::Quarantined => {
                return Err(SessionError::Fatal(ServiceError::Quarantined(cfg.id.clone())))
            }
            _ => return Err(SessionError::Transport),
        }
    }
}

fn submit(
    stream: &mut TcpStream,
    injector: &mut FaultInjector,
    cfg: &WorkerConfig,
    report: &mut WorkerReport,
    bytes: &[u8],
) -> Result<(), SessionError> {
    injector.send(
        stream,
        &Message::Submit { worker_id: cfg.id.clone(), shard: bytes.to_vec() },
    )?;
    match proto::read_message(stream)? {
        Message::Ack { code, reason } => {
            match code {
                AckCode::Accepted => report.submitted += 1,
                AckCode::Duplicate => report.duplicates += 1,
                AckCode::Rejected => {
                    report.rejected += 1;
                    eprintln!("warning: worker {}: submission rejected: {reason}", cfg.id);
                }
            }
            Ok(())
        }
        _ => Err(SessionError::Transport),
    }
}

fn apply_profile_threads(engine: &mut SimEngine, profile_threads: usize) {
    // `with_profile_threads` is a by-value builder; route through a
    // temporary move to apply it in place.
    let current = std::mem::replace(engine, SimEngine::new());
    *engine = current.with_profile_threads(profile_threads);
}
