//! Length-framed wire protocol for the distributed sweep service.
//!
//! The same discipline as the on-disk codec ([`crate::sim::cache::codec`]):
//! hand-rolled on `std`, little-endian, checksummed, strictly defensive.
//! Every message travels in one frame:
//!
//! ```text
//! offset  size  field
//! 0       8     magic            (b"MAPLESVC")
//! 8       4     protocol version (u32, == PROTO_VERSION)
//! 12      1     message kind     (u8, one per [`Message`] variant)
//! 13      8     payload length   (u64)
//! 21      8     FNV-1a-64        (u64, over the payload bytes)
//! 29      n     payload sections
//! ```
//!
//! A bad magic, foreign version, oversized frame, checksum mismatch, or
//! internally inconsistent payload is a [`ProtoError`], never a partial
//! message — the coordinator treats any of them as a failed frame from that
//! worker (the fault-injection harness corrupts exactly one checksum byte
//! to exercise this path deterministically).
//!
//! The [`Message::Space`] payload ships a whole [`DesignSpace`]:
//! configurations as their full TOML (the same canonical form the space
//! fingerprint hashes), axes as typed sections whose labels re-parse
//! through [`ConfigAxis::parse`]. The worker re-fingerprints the decoded
//! space and refuses to work if it does not match the fingerprint in the
//! same frame, so a lossy round-trip can never silently compute the wrong
//! grid.

use std::io::{self, Read, Write};

use crate::config::{AcceleratorConfig, ConfigAxis};
use crate::sim::cache::codec::{
    fnv1a, policy_from_tag, policy_tag, put_str, put_u32, put_u64, Reader,
};
use crate::sim::engine::{Axis, CellModel, DesignSpace, WorkloadKey};

/// Bump on any frame or payload layout change; peers at different versions
/// refuse each other loudly instead of misinterpreting bytes.
pub const PROTO_VERSION: u32 = 1;

const MAGIC: [u8; 8] = *b"MAPLESVC";
const HEADER_LEN: usize = 29;

/// Upper bound on one frame's payload — far above any realistic shard
/// artifact, low enough that a corrupt length field cannot OOM the peer.
pub const MAX_FRAME: u64 = 256 * 1024 * 1024;

/// Byte offset of the frame checksum inside the header (the fault harness
/// flips one byte in `21..29` to forge a deterministic corrupt frame).
pub(crate) const CHECKSUM_OFFSET: usize = 21;

/// Wire-protocol errors. Every variant means "this frame cannot be
/// trusted"; the transport-level `Io` variant also covers a peer vanishing
/// mid-frame.
#[derive(Debug, thiserror::Error)]
pub enum ProtoError {
    #[error("service i/o: {0}")]
    Io(#[from] io::Error),
    #[error("bad magic: not a maple service frame")]
    BadMagic,
    #[error("protocol version {found} != supported {expected}")]
    VersionMismatch { found: u32, expected: u32 },
    #[error("frame of {len} bytes exceeds the {max}-byte limit")]
    TooLarge { len: u64, max: u64 },
    #[error("frame checksum mismatch: stored {stored:#018x}, computed {computed:#018x}")]
    ChecksumMismatch { stored: u64, computed: u64 },
    #[error("unknown message kind {0}")]
    UnknownKind(u8),
    #[error("malformed {kind} payload: {reason}")]
    Malformed { kind: &'static str, reason: String },
}

/// Outcome tag of a shard submission, carried in [`Message::Ack`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AckCode {
    /// First valid submission for its range: stored and counted.
    Accepted,
    /// Byte-identical resubmission of an already-stored range: idempotent.
    Duplicate,
    /// Invalid or byte-divergent submission: dropped, worker penalised.
    Rejected,
}

impl AckCode {
    fn tag(self) -> u8 {
        match self {
            AckCode::Accepted => 0,
            AckCode::Duplicate => 1,
            AckCode::Rejected => 2,
        }
    }

    fn from_tag(tag: u8) -> Option<Self> {
        match tag {
            0 => Some(AckCode::Accepted),
            1 => Some(AckCode::Duplicate),
            2 => Some(AckCode::Rejected),
            _ => None,
        }
    }
}

/// One service message. Worker → coordinator: `Register`, `Request`,
/// `Submit`. Coordinator → worker: everything else.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker announces itself (idempotent — re-sent on every reconnect,
    /// which is what makes coordinator restarts survivable).
    Register { worker_id: String },
    /// Coordinator's reply to `Register`: the design space to sweep, its
    /// fingerprint, the shard split, and the profile chunking every worker
    /// must run with (checksum bits depend on it).
    Space { fingerprint: u64, shard_count: u64, profile_threads: u64, space: DesignSpace },
    /// Worker asks for work.
    Request { worker_id: String },
    /// A shard lease: compute `index/count` and submit before `lease_ms`
    /// elapses, or the coordinator re-queues it for another worker.
    Lease { index: u64, count: u64, attempt: u32, lease_ms: u64 },
    /// No work right now (all shards leased, or the worker is in backoff);
    /// ask again in about `ms`.
    Wait { ms: u64 },
    /// The grid is complete (or the service is shutting down): disconnect.
    Done,
    /// A finished shard as raw `MAPLESHD` artifact bytes — the identical
    /// bytes `maple sweep --shard` would have written to disk.
    Submit { worker_id: String, shard: Vec<u8> },
    /// Coordinator's verdict on a `Submit`.
    Ack { code: AckCode, reason: String },
    /// The worker exhausted its retry budget; it must stop.
    Quarantined,
}

impl Message {
    fn kind_tag(&self) -> u8 {
        match self {
            Message::Register { .. } => 1,
            Message::Space { .. } => 2,
            Message::Request { .. } => 3,
            Message::Lease { .. } => 4,
            Message::Wait { .. } => 5,
            Message::Done => 6,
            Message::Submit { .. } => 7,
            Message::Ack { .. } => 8,
            Message::Quarantined => 9,
        }
    }

    /// Human name of the message kind (error context).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Message::Register { .. } => "register",
            Message::Space { .. } => "space",
            Message::Request { .. } => "request",
            Message::Lease { .. } => "lease",
            Message::Wait { .. } => "wait",
            Message::Done => "done",
            Message::Submit { .. } => "submit",
            Message::Ack { .. } => "ack",
            Message::Quarantined => "quarantined",
        }
    }
}

// ---------------------------------------------------------------- encoding

fn encode_payload(msg: &Message) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Message::Register { worker_id } | Message::Request { worker_id } => {
            put_str(&mut p, worker_id);
        }
        Message::Space { fingerprint, shard_count, profile_threads, space } => {
            put_u64(&mut p, *fingerprint);
            put_u64(&mut p, *shard_count);
            put_u64(&mut p, *profile_threads);
            encode_space(&mut p, space);
        }
        Message::Lease { index, count, attempt, lease_ms } => {
            put_u64(&mut p, *index);
            put_u64(&mut p, *count);
            put_u32(&mut p, *attempt);
            put_u64(&mut p, *lease_ms);
        }
        Message::Wait { ms } => put_u64(&mut p, *ms),
        Message::Done | Message::Quarantined => {}
        Message::Submit { worker_id, shard } => {
            put_str(&mut p, worker_id);
            put_u64(&mut p, shard.len() as u64);
            p.extend_from_slice(shard);
        }
        Message::Ack { code, reason } => {
            p.push(code.tag());
            put_str(&mut p, reason);
        }
    }
    p
}

/// A [`DesignSpace`] as payload sections: cell-model tag, configurations as
/// their full TOML, then each axis as a typed section. Config-axis labels
/// round-trip through [`ConfigAxis::parse`] (the CLI's own parser), so the
/// wire form is exactly the `--axis name=v1,v2` spelling.
fn encode_space(p: &mut Vec<u8>, space: &DesignSpace) {
    p.push(space.cell_model.tag());
    put_u64(p, space.configs.len() as u64);
    for cfg in &space.configs {
        put_str(p, &cfg.to_toml());
    }
    put_u64(p, space.axes.len() as u64);
    for axis in &space.axes {
        match axis {
            Axis::Dataset(keys) => {
                p.push(0);
                put_u64(p, keys.len() as u64);
                for k in keys {
                    put_str(p, &k.dataset);
                    put_u64(p, k.seed);
                    put_u64(p, k.scale as u64);
                }
            }
            Axis::Policy(ps) => {
                p.push(1);
                put_u64(p, ps.len() as u64);
                for &pol in ps {
                    put_u32(p, policy_tag(pol));
                }
            }
            Axis::Config(a) => {
                p.push(2);
                put_str(p, a.name());
                put_str(p, &a.labels().join(","));
            }
        }
    }
}

/// The full frame (header + payload) for one message.
pub fn encode_message(msg: &Message) -> Vec<u8> {
    let payload = encode_payload(msg);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC);
    put_u32(&mut out, PROTO_VERSION);
    out.push(msg.kind_tag());
    put_u64(&mut out, payload.len() as u64);
    put_u64(&mut out, fnv1a(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Encode and write one message to `w` (single `write_all`, so a frame is
/// never interleaved with another writer's bytes on the same stream).
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> io::Result<()> {
    w.write_all(&encode_message(msg))
}

// ---------------------------------------------------------------- decoding

/// Read one message from `r` (blocks for a whole frame).
pub fn read_message<R: Read>(r: &mut R) -> Result<Message, ProtoError> {
    let mut first = [0u8; 1];
    r.read_exact(&mut first)?;
    read_message_tail(first[0], r)
}

/// Read one message whose first header byte was already consumed — the
/// coordinator peeks one byte under a short timeout to distinguish an idle
/// connection from an arriving frame, then hands the byte here.
pub fn read_message_tail<R: Read>(first: u8, r: &mut R) -> Result<Message, ProtoError> {
    let mut header = [0u8; HEADER_LEN];
    header[0] = first;
    r.read_exact(&mut header[1..])?;
    if header[..8] != MAGIC {
        return Err(ProtoError::BadMagic);
    }
    let version = u32::from_le_bytes(header[8..12].try_into().unwrap());
    if version != PROTO_VERSION {
        return Err(ProtoError::VersionMismatch { found: version, expected: PROTO_VERSION });
    }
    let kind = header[12];
    let len = u64::from_le_bytes(header[13..21].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(ProtoError::TooLarge { len, max: MAX_FRAME });
    }
    let stored = u64::from_le_bytes(header[21..29].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let computed = fnv1a(&payload);
    if stored != computed {
        return Err(ProtoError::ChecksumMismatch { stored, computed });
    }
    decode_payload(kind, &payload)
}

fn malformed(kind: &'static str, reason: impl ToString) -> ProtoError {
    ProtoError::Malformed { kind, reason: reason.to_string() }
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Message, ProtoError> {
    let mut r = Reader::new(payload);
    let msg = match kind {
        1 => Message::Register { worker_id: r.string().map_err(|e| malformed("register", e))? },
        2 => {
            let e = |e: crate::sim::cache::codec::CodecError| malformed("space", e);
            let fingerprint = r.u64().map_err(e)?;
            let shard_count = r.u64().map_err(e)?;
            let profile_threads = r.u64().map_err(e)?;
            let space = decode_space(&mut r)?;
            Message::Space { fingerprint, shard_count, profile_threads, space }
        }
        3 => Message::Request { worker_id: r.string().map_err(|e| malformed("request", e))? },
        4 => {
            let e = |e: crate::sim::cache::codec::CodecError| malformed("lease", e);
            Message::Lease {
                index: r.u64().map_err(e)?,
                count: r.u64().map_err(e)?,
                attempt: r.u32().map_err(e)?,
                lease_ms: r.u64().map_err(e)?,
            }
        }
        5 => Message::Wait { ms: r.u64().map_err(|e| malformed("wait", e))? },
        6 => Message::Done,
        7 => {
            let e = |e: crate::sim::cache::codec::CodecError| malformed("submit", e);
            let worker_id = r.string().map_err(e)?;
            let n = r.index().map_err(e)?;
            r.expect_items(n, 1).map_err(e)?;
            let mut shard = Vec::with_capacity(n);
            for _ in 0..n {
                shard.push(r.byte().map_err(e)?);
            }
            Message::Submit { worker_id, shard }
        }
        8 => {
            let e = |e: crate::sim::cache::codec::CodecError| malformed("ack", e);
            let tag = r.byte().map_err(e)?;
            let code = AckCode::from_tag(tag)
                .ok_or_else(|| malformed("ack", format!("unknown ack code {tag}")))?;
            Message::Ack { code, reason: r.string().map_err(e)? }
        }
        9 => Message::Quarantined,
        other => return Err(ProtoError::UnknownKind(other)),
    };
    r.done().map_err(|e| malformed(msg.kind_name(), e))?;
    Ok(msg)
}

fn decode_space(r: &mut Reader<'_>) -> Result<DesignSpace, ProtoError> {
    let e = |e: crate::sim::cache::codec::CodecError| malformed("space", e);
    let model_tag = r.byte().map_err(e)?;
    let cell_model = CellModel::from_tag(model_tag as u32)
        .ok_or_else(|| malformed("space", format!("unknown cell model tag {model_tag}")))?;
    let n_configs = r.index().map_err(e)?;
    r.expect_items(n_configs, 8).map_err(e)?;
    let mut configs = Vec::with_capacity(n_configs);
    for _ in 0..n_configs {
        let toml = r.string().map_err(e)?;
        configs.push(
            AcceleratorConfig::from_toml(&toml)
                .map_err(|err| malformed("space", format!("config toml: {err}")))?,
        );
    }
    let n_axes = r.index().map_err(e)?;
    r.expect_items(n_axes, 1).map_err(e)?;
    let mut axes = Vec::with_capacity(n_axes);
    for _ in 0..n_axes {
        let tag = r.byte().map_err(e)?;
        axes.push(match tag {
            0 => {
                let n = r.index().map_err(e)?;
                r.expect_items(n, 24).map_err(e)?;
                let mut keys = Vec::with_capacity(n);
                for _ in 0..n {
                    let dataset = r.string().map_err(e)?;
                    let seed = r.u64().map_err(e)?;
                    let scale = r.u64().map_err(e)? as usize;
                    keys.push(WorkloadKey { dataset, seed, scale });
                }
                Axis::Dataset(keys)
            }
            1 => {
                let n = r.index().map_err(e)?;
                r.expect_items(n, 4).map_err(e)?;
                let mut ps = Vec::with_capacity(n);
                for _ in 0..n {
                    let t = r.u32().map_err(e)?;
                    ps.push(policy_from_tag(t).ok_or_else(|| {
                        malformed("space", format!("unknown policy tag {t}"))
                    })?);
                }
                Axis::Policy(ps)
            }
            2 => {
                let name = r.string().map_err(e)?;
                let labels = r.string().map_err(e)?;
                Axis::Config(
                    ConfigAxis::parse(&name, &labels)
                        .map_err(|err| malformed("space", format!("config axis: {err}")))?,
                )
            }
            other => return Err(malformed("space", format!("unknown axis tag {other}"))),
        });
    }
    Ok(DesignSpace { configs, axes, cell_model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use crate::noc::Topology;

    fn round_trip(msg: &Message) -> Message {
        let frame = encode_message(msg);
        read_message(&mut frame.as_slice()).expect("round trip")
    }

    fn sample_space() -> DesignSpace {
        DesignSpace::over(vec![
            AcceleratorConfig::extensor_maple(),
            AcceleratorConfig::matraptor_baseline(),
        ])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, 64),
            WorkloadKey::suite("fb", 9, 32),
        ]))
        .with_axis(Axis::macs_per_pe(vec![2, 4, 8]))
        .with_axis(Axis::topology(vec![
            Topology::Crossbar { ports: 8 },
            Topology::Mesh { width: 2, height: 2 },
        ]))
        .with_axis(Axis::Policy(vec![Policy::RoundRobin, Policy::GreedyBalance]))
        .with_cell_model(CellModel::Both)
    }

    #[test]
    fn every_message_kind_round_trips() {
        let msgs = [
            Message::Register { worker_id: "w0".into() },
            Message::Request { worker_id: "worker-π".into() },
            Message::Lease { index: 3, count: 8, attempt: 2, lease_ms: 30_000 },
            Message::Wait { ms: 120 },
            Message::Done,
            Message::Submit { worker_id: "w1".into(), shard: vec![0xAB; 257] },
            Message::Ack { code: AckCode::Duplicate, reason: String::new() },
            Message::Ack { code: AckCode::Rejected, reason: "byte-divergent".into() },
            Message::Quarantined,
        ];
        for msg in msgs {
            assert_eq!(round_trip(&msg), msg);
        }
    }

    #[test]
    fn space_round_trips_with_identical_fingerprint() {
        let space = sample_space();
        let fingerprint = space.fingerprint().unwrap();
        let msg = Message::Space {
            fingerprint,
            shard_count: 6,
            profile_threads: 2,
            space: space.clone(),
        };
        match round_trip(&msg) {
            Message::Space { fingerprint: f, shard_count, profile_threads, space: decoded } => {
                assert_eq!(f, fingerprint);
                assert_eq!((shard_count, profile_threads), (6, 2));
                // The wire round-trip must preserve the grid exactly — the
                // fingerprint covers every expanded config TOML and label.
                assert_eq!(decoded.fingerprint().unwrap(), fingerprint);
                assert_eq!(decoded, space);
            }
            other => panic!("expected Space, got {other:?}"),
        }
    }

    #[test]
    fn corruption_anywhere_is_rejected() {
        let frame = encode_message(&Message::Lease { index: 1, count: 4, attempt: 1, lease_ms: 5 });
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x40;
            assert!(
                read_message(&mut bad.as_slice()).is_err(),
                "flipping byte {i} must not decode cleanly"
            );
        }
    }

    #[test]
    fn truncation_and_version_skew_are_loud() {
        let frame = encode_message(&Message::Register { worker_id: "w".into() });
        for cut in 0..frame.len() {
            assert!(read_message(&mut frame[..cut].to_vec().as_slice()).is_err());
        }
        let mut skewed = frame.clone();
        skewed[8] ^= 0xFF; // version field
        assert!(matches!(
            read_message(&mut skewed.as_slice()),
            Err(ProtoError::VersionMismatch { .. })
        ));
        let mut huge = frame;
        huge[13..21].copy_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        assert!(matches!(read_message(&mut huge.as_slice()), Err(ProtoError::TooLarge { .. })));
    }

    #[test]
    fn trailing_bytes_in_a_payload_are_rejected() {
        let mut frame = encode_message(&Message::Done);
        // Forge one trailing payload byte with a matching checksum.
        frame[13..21].copy_from_slice(&1u64.to_le_bytes());
        frame[21..29].copy_from_slice(&fnv1a(&[0x55]).to_le_bytes());
        frame.push(0x55);
        assert!(matches!(
            read_message(&mut frame.as_slice()),
            Err(ProtoError::Malformed { .. })
        ));
    }
}
