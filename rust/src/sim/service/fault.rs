//! Deterministic fault injection for the sweep service, plus the chaos
//! harness that drives a real coordinator + workers through a plan.
//!
//! A [`FaultPlan`] is a seeded list of faults a *worker* inflicts on
//! itself, parsed from the CLI spelling `--fault drop:2,stall`:
//!
//! | spelling     | fault                                                   |
//! |--------------|---------------------------------------------------------|
//! | `drop:N`     | sever the connection when writing frame N+1             |
//! | `corrupt:M`  | flip one checksum byte of the M-th frame written        |
//! | `stall`      | sleep past the lease deadline before computing a shard  |
//! | `dup`        | submit the same finished shard twice                    |
//! | `kill`       | drop the connection after taking a lease, then rejoin   |
//! | `die`        | exit for good after taking a lease (no rejoin)          |
//!
//! Each fault fires **once**, at a position derived only from the plan and
//! its seed (frame counters, not wall-clock), and every firing is recorded
//! as a [`FaultEvent`] — so the same plan + seed always produces the same
//! event trace, which is exactly what `tests/service.rs` asserts. The
//! transport faults forge real wire-level damage (a severed socket, a
//! checksum that does not match) so the coordinator's defenses are
//! exercised end-to-end, not simulated.
//!
//! [`run_chaos`] is the in-process harness behind `maple chaos` and the
//! integration tests: bind a coordinator on a loopback port, run N worker
//! threads (one of them faulty) against it over real TCP, and return the
//! merged outcome next to every worker's report.

use std::fmt;
use std::io::{self, Write};
use std::net::TcpStream;
use std::str::FromStr;

use super::coordinator::{Coordinator, ServiceConfig, ServiceStats, SweepOutcome};
use super::proto::{Message, CHECKSUM_OFFSET};
use super::worker::{self, WorkerConfig, WorkerReport};
use super::ServiceError;
use crate::sim::engine::{DesignSpace, SimEngine};
use crate::sim::service::proto;

/// One self-inflicted worker fault (see the module table for spellings).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Sever the connection when attempting to write frame `n+1` (i.e.
    /// after `n` frames were written successfully).
    DropAfterFrames(u64),
    /// Flip one checksum byte of the `m`-th frame written (1-based).
    CorruptFrame(u64),
    /// Sleep past the lease deadline before computing the leased shard.
    StallPastLease,
    /// Submit the finished shard twice (exercises idempotent acceptance).
    DuplicateSubmit,
    /// Drop the connection right after taking a lease, then reconnect and
    /// re-register (kill-and-rejoin).
    KillRejoin,
    /// Exit for good right after taking a lease — the killed-mid-shard
    /// worker of the chaos CI job.
    Die,
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Fault::DropAfterFrames(n) => write!(f, "drop:{n}"),
            Fault::CorruptFrame(m) => write!(f, "corrupt:{m}"),
            Fault::StallPastLease => write!(f, "stall"),
            Fault::DuplicateSubmit => write!(f, "dup"),
            Fault::KillRejoin => write!(f, "kill"),
            Fault::Die => write!(f, "die"),
        }
    }
}

impl FromStr for Fault {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let s = s.trim();
        if let Some(n) = s.strip_prefix("drop:") {
            return n
                .parse()
                .map(Fault::DropAfterFrames)
                .map_err(|_| format!("bad frame count in {s:?}"));
        }
        if let Some(m) = s.strip_prefix("corrupt:") {
            let m: u64 =
                m.parse().map_err(|_| format!("bad frame number in {s:?}"))?;
            if m == 0 {
                return Err("corrupt frames are 1-based: corrupt:1 is the first".into());
            }
            return Ok(Fault::CorruptFrame(m));
        }
        match s {
            "stall" => Ok(Fault::StallPastLease),
            "dup" => Ok(Fault::DuplicateSubmit),
            "kill" => Ok(Fault::KillRejoin),
            "die" => Ok(Fault::Die),
            other => Err(format!(
                "unknown fault {other:?} (drop:N | corrupt:M | stall | dup | kill | die)"
            )),
        }
    }
}

/// A seeded, replayable list of faults for one worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// Drives every seed-dependent choice (currently: which checksum byte
    /// a corrupt frame flips). Same plan + seed ⇒ same event trace.
    pub seed: u64,
}

impl FaultPlan {
    /// Parse the CLI spelling: a comma-separated fault list.
    pub fn parse(spec: &str, seed: u64) -> Result<Self, String> {
        let faults = spec
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(str::parse)
            .collect::<Result<Vec<Fault>, String>>()?;
        if faults.is_empty() {
            return Err("empty fault plan".into());
        }
        Ok(Self { faults, seed })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, fault) in self.faults.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{fault}")?;
        }
        write!(f, " (seed {})", self.seed)
    }
}

/// One fault firing, recorded in plan order of occurrence. `detail` is a
/// pure function of the plan and seed (frame numbers, byte offsets — never
/// wall-clock), so equal plans produce equal traces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultEvent {
    pub kind: &'static str,
    pub detail: String,
}

/// Per-worker injector state: which faults are still armed, how many frames
/// were written (the deterministic clock), and the recorded trace. Lives
/// across reconnects — frame counts keep running, so `drop:1,corrupt:3`
/// corrupts the third frame *overall*, not the third of some session.
#[derive(Debug, Default)]
pub(crate) struct FaultInjector {
    drop_after: Option<u64>,
    corrupt_frame: Option<u64>,
    stall: bool,
    dup: bool,
    kill: bool,
    die: bool,
    seed: u64,
    frames_written: u64,
    pub(crate) events: Vec<FaultEvent>,
}

impl FaultInjector {
    pub(crate) fn new(plan: Option<&FaultPlan>) -> Self {
        let mut inj = Self::default();
        let Some(plan) = plan else { return inj };
        inj.seed = plan.seed;
        for f in &plan.faults {
            match *f {
                Fault::DropAfterFrames(n) => inj.drop_after = Some(n),
                Fault::CorruptFrame(m) => inj.corrupt_frame = Some(m),
                Fault::StallPastLease => inj.stall = true,
                Fault::DuplicateSubmit => inj.dup = true,
                Fault::KillRejoin => inj.kill = true,
                Fault::Die => inj.die = true,
            }
        }
        inj
    }

    fn record(&mut self, kind: &'static str, detail: String) {
        self.events.push(FaultEvent { kind, detail });
    }

    /// Encode and send one frame through the transport faults: an armed
    /// `drop` severs the socket instead of writing; an armed `corrupt`
    /// flips one checksum byte (offset seeded) before writing. Each fires
    /// once.
    pub(crate) fn send(&mut self, stream: &mut TcpStream, msg: &Message) -> io::Result<()> {
        if let Some(n) = self.drop_after {
            if self.frames_written >= n {
                self.drop_after = None;
                self.record("drop", format!("severed connection after {n} frames"));
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return Err(io::Error::new(io::ErrorKind::BrokenPipe, "fault: drop"));
            }
        }
        let mut frame = proto::encode_message(msg);
        self.frames_written += 1;
        if self.corrupt_frame == Some(self.frames_written) {
            self.corrupt_frame = None;
            let offset = CHECKSUM_OFFSET + (self.seed % 8) as usize;
            frame[offset] ^= 0x01;
            self.record(
                "corrupt",
                format!("flipped checksum byte {offset} of frame {}", self.frames_written),
            );
        }
        stream.write_all(&frame)
    }

    /// Behavioural faults, consumed (fire-once) by the worker loop.
    pub(crate) fn take_stall(&mut self, lease_ms: u64) -> bool {
        let fire = std::mem::take(&mut self.stall);
        if fire {
            self.record("stall", format!("holding lease past its {lease_ms} ms deadline"));
        }
        fire
    }

    pub(crate) fn take_dup(&mut self, index: u64) -> bool {
        let fire = std::mem::take(&mut self.dup);
        if fire {
            self.record("dup", format!("submitting shard {index} twice"));
        }
        fire
    }

    pub(crate) fn take_kill(&mut self, index: u64) -> bool {
        let fire = std::mem::take(&mut self.kill);
        if fire {
            self.record("kill", format!("dropping connection while holding shard {index}"));
        }
        fire
    }

    pub(crate) fn take_die(&mut self, index: u64) -> bool {
        let fire = std::mem::take(&mut self.die);
        if fire {
            self.record("die", format!("exiting while holding shard {index}"));
        }
        fire
    }
}

// ------------------------------------------------------------ chaos harness

/// One chaos experiment: `workers` workers against one coordinator, with
/// worker number `faulty` running `plan`.
#[derive(Debug, Clone)]
pub struct ChaosSpec {
    pub workers: usize,
    /// Index of the worker that runs the fault plan (the others are clean).
    pub faulty: usize,
    pub plan: Option<FaultPlan>,
    pub service: ServiceConfig,
}

/// Everything a chaos run produced: the merged outcome, the coordinator's
/// stats, and each worker's report (or its error, stringified — worker
/// errors like quarantine are expected outcomes of a chaos run, not
/// harness failures).
#[derive(Debug)]
pub struct ChaosReport {
    pub outcome: SweepOutcome,
    pub stats: ServiceStats,
    pub workers: Vec<Result<WorkerReport, String>>,
}

/// Run a coordinator + `spec.workers` workers over loopback TCP and return
/// the merged outcome. `make_engine` builds each worker's engine (tests
/// pass cold engines; the CLI passes disk-cached ones so chaos workers
/// share the artifact store like real ones would).
pub fn run_chaos(
    space: &DesignSpace,
    spec: &ChaosSpec,
    make_engine: &(dyn Fn() -> SimEngine + Sync),
) -> Result<ChaosReport, ServiceError> {
    let coordinator = Coordinator::bind("127.0.0.1:0", spec.service.clone())?;
    let addr = coordinator.local_addr()?.to_string();
    let (service_result, worker_results) = std::thread::scope(|scope| {
        let coord = scope.spawn(move || coordinator.run(space));
        let workers: Vec<_> = (0..spec.workers)
            .map(|i| {
                let addr = addr.clone();
                let plan = (i == spec.faulty).then(|| spec.plan.clone()).flatten();
                scope.spawn(move || {
                    let cfg = WorkerConfig { fault: plan, ..WorkerConfig::named(format!("w{i}")) };
                    worker::run(&addr, make_engine(), cfg)
                })
            })
            .collect();
        let worker_results: Vec<Result<WorkerReport, String>> = workers
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked").map_err(|e| e.to_string()))
            .collect();
        (coord.join().expect("coordinator thread panicked"), worker_results)
    });
    let (outcome, stats) = service_result?;
    Ok(ChaosReport { outcome, stats, workers: worker_results })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_parse_and_render() {
        let plan = FaultPlan::parse("drop:2, corrupt:3,stall,dup,kill,die", 9).unwrap();
        assert_eq!(
            plan.faults,
            vec![
                Fault::DropAfterFrames(2),
                Fault::CorruptFrame(3),
                Fault::StallPastLease,
                Fault::DuplicateSubmit,
                Fault::KillRejoin,
                Fault::Die,
            ]
        );
        assert_eq!(plan.to_string(), "drop:2,corrupt:3,stall,dup,kill,die (seed 9)");
        assert!(FaultPlan::parse("", 0).is_err());
        assert!(FaultPlan::parse("explode", 0).is_err());
        assert!(FaultPlan::parse("corrupt:0", 0).is_err(), "corrupt frames are 1-based");
        assert!(FaultPlan::parse("drop:x", 0).is_err());
    }

    #[test]
    fn behavioural_faults_fire_exactly_once() {
        let plan = FaultPlan::parse("stall,dup,kill,die", 3).unwrap();
        let mut inj = FaultInjector::new(Some(&plan));
        assert!(inj.take_stall(500));
        assert!(!inj.take_stall(500));
        assert!(inj.take_dup(1));
        assert!(!inj.take_dup(1));
        assert!(inj.take_kill(2));
        assert!(!inj.take_kill(2));
        assert!(inj.take_die(3));
        assert!(!inj.take_die(3));
        let kinds: Vec<&str> = inj.events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, ["stall", "dup", "kill", "die"]);
    }

    #[test]
    fn corrupt_offset_is_a_pure_function_of_the_seed() {
        for seed in 0..16 {
            let plan = FaultPlan::parse("corrupt:1", seed).unwrap();
            let inj = FaultInjector::new(Some(&plan));
            let offset = CHECKSUM_OFFSET + (inj.seed % 8) as usize;
            assert!((21..29).contains(&offset), "offset {offset} must hit the checksum field");
        }
    }
}
