//! Two-stage PE timeline composition.
//!
//! Every PE in the analytic model is a queue-decoupled two-stage pipeline:
//! the *front* (multiply) stage and the *back* (merge / POB round-trip /
//! PSB drain) stage of consecutive rows overlap, bounded by the fill of the
//! first front and the drain of the last back — nothing can hide those.
//! The makespan of a row sequence is therefore
//!
//! ```text
//! t = first_front + Σ back     when the back stage aggregates slower
//! t = Σ front + last_back      when the front stage dominates
//! ```
//!
//! This module owns that composition (it used to live inline in
//! [`crate::accel::Accelerator::run`]); the analytic model and any future
//! engine mode share it, and [`crate::sim::des`] cross-checks it: the
//! event-driven pipeline with explicit buffering must land at or above this
//! bound (`des_brackets_analytic_model`). The unit tests here additionally
//! pin it against an exact infinite-buffer pipeline recurrence.

use crate::pe::RowCost;

/// Accumulates one PE's row costs and reports the pipelined makespan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TwoStageTimeline {
    sum_front: u64,
    sum_back: u64,
    first_front: u64,
    last_back: u64,
    rows: u64,
}

impl TwoStageTimeline {
    /// An empty timeline (makespan 0).
    pub fn new() -> Self {
        Self::default()
    }

    /// Account one row. The first pushed row pins `first_front` — decided
    /// by an explicit row counter, not by `sum_front == 0`, so a leading
    /// row with a zero-cycle front (an empty output row) is still the one
    /// that fills the pipeline.
    pub fn push(&mut self, cost: RowCost) {
        if self.rows == 0 {
            self.first_front = cost.front;
        }
        self.rows += 1;
        self.sum_front += cost.front;
        self.sum_back += cost.back;
        self.last_back = cost.back;
    }

    /// Compose a whole row-cost sequence.
    pub fn from_costs<I: IntoIterator<Item = RowCost>>(costs: I) -> Self {
        let mut tl = Self::new();
        for c in costs {
            tl.push(c);
        }
        tl
    }

    /// Rows accounted so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// The pipelined makespan of the rows pushed so far.
    pub fn makespan(&self) -> u64 {
        if self.sum_back >= self.sum_front {
            // Back-stage (merge) bound: pipeline fills with the first
            // front, then merge throughput dominates.
            self.first_front + self.sum_back
        } else {
            self.sum_front + self.last_back
        }
    }

    /// Fully-serialised upper bound (no overlap between stages).
    pub fn serial_cycles(&self) -> u64 {
        self.sum_front + self.sum_back
    }

    /// Single-stage lower bound: the slower aggregate stage alone.
    pub fn stage_bound(&self) -> u64 {
        self.sum_front.max(self.sum_back)
    }
}

/// Exact makespan of an infinite-buffer two-stage pipeline — the same
/// machine [`crate::sim::des`] simulates event-by-event, as a direct
/// recurrence: fronts run back-to-back, and a row's back stage starts when
/// both its *own front* and the previous back have finished.
///
/// [`TwoStageTimeline::makespan`] is a closed-form lower bound of this;
/// `sim::des` with fetch latency zeroed and one PE must match it
/// cycle-for-cycle (`des::tests::zero_latency_single_pe_matches_exact_pipeline`).
pub fn exact_pipeline(seq: &[RowCost]) -> u64 {
    let (mut front_done, mut back_done) = (0u64, 0u64);
    for c in seq {
        front_done += c.front;
        back_done = back_done.max(front_done) + c.back;
    }
    back_done
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(pairs: &[(u64, u64)]) -> Vec<RowCost> {
        pairs.iter().map(|&(front, back)| RowCost { front, back }).collect()
    }

    #[test]
    fn empty_timeline_is_zero() {
        assert_eq!(TwoStageTimeline::new().makespan(), 0);
    }

    #[test]
    fn single_row_is_serial() {
        let tl = TwoStageTimeline::from_costs(costs(&[(5, 3)]));
        assert_eq!(tl.makespan(), 8);
        assert_eq!(tl.serial_cycles(), 8);
    }

    #[test]
    fn back_bound_fills_once_then_streams() {
        // Uniform back-heavy rows: t = first_front + Σ back, exactly the
        // infinite-buffer pipeline.
        let seq = costs(&[(2, 10), (2, 10), (2, 10), (2, 10)]);
        let tl = TwoStageTimeline::from_costs(seq.clone());
        assert_eq!(tl.makespan(), 2 + 40);
        assert_eq!(tl.makespan(), exact_pipeline(&seq));
    }

    #[test]
    fn front_bound_drains_once() {
        let seq = costs(&[(10, 2), (10, 2), (10, 2)]);
        let tl = TwoStageTimeline::from_costs(seq.clone());
        assert_eq!(tl.makespan(), 30 + 2);
        assert_eq!(tl.makespan(), exact_pipeline(&seq));
    }

    /// The regression the extraction fixes: a leading row whose front costs
    /// zero cycles must still be the pipeline-fill row. The old inline
    /// guard (`sum_front == 0`) let the *second* row overwrite
    /// `first_front`, inflating the back-bound makespan.
    #[test]
    fn zero_front_first_row_does_not_inflate_fill() {
        let seq = costs(&[(0, 1), (7, 20), (7, 20)]);
        let tl = TwoStageTimeline::from_costs(seq.clone());
        // Back-bound branch: fill = front of row 0 (= 0), not row 1's 7,
        // so the makespan is exactly Σ back = 41.
        assert_eq!(tl.makespan(), 41);
        // And the exact pipeline agrees the fill row is row 0.
        assert!(tl.makespan() <= exact_pipeline(&seq));
    }

    /// The analytic composition brackets between the aggregate-stage lower
    /// bound and the exact pipeline (which itself is below fully-serial),
    /// across a spread of shapes including zeros and heavy skew.
    #[test]
    fn bracketed_by_stage_bound_and_exact_pipeline() {
        let cases: Vec<Vec<RowCost>> = vec![
            costs(&[(0, 0), (0, 0)]),
            costs(&[(1, 1)]),
            costs(&[(3, 9), (4, 1), (0, 7), (12, 2)]),
            costs(&[(100, 1), (1, 100), (50, 50)]),
            (0..32).map(|i| RowCost { front: (i * 7) % 13, back: (i * 5) % 11 }).collect(),
        ];
        for seq in cases {
            let tl = TwoStageTimeline::from_costs(seq.clone());
            let exact = exact_pipeline(&seq);
            assert!(tl.makespan() >= tl.stage_bound(), "{seq:?}");
            assert!(tl.makespan() <= exact, "{seq:?}: {} > exact {exact}", tl.makespan());
            assert!(exact <= tl.serial_cycles(), "{seq:?}");
        }
    }

    #[test]
    fn push_matches_from_costs() {
        let seq = costs(&[(3, 9), (4, 1), (0, 7)]);
        let mut tl = TwoStageTimeline::new();
        for &c in &seq {
            tl.push(c);
        }
        assert_eq!(tl, TwoStageTimeline::from_costs(seq));
        assert_eq!(tl.rows(), 3);
    }
}
