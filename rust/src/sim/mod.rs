//! Simulation driver: the public entry point that turns (config, A, B) into
//! cycles + energy + action counts.
//!
//! Pipeline: [`profile_workload`] performs the exact functional execution
//! (once per workload — it is shared across the four configurations being
//! compared), then [`crate::accel::Accelerator::run`] replays the per-row
//! work profiles through the configured PE cost models ([`crate::pe::registry`]),
//! the coordinator's partition, the [`timeline`] composition, the run-level
//! memory/NoC flows, and the energy aggregation. Sweeps — a [`DesignSpace`]
//! of typed axes (dataset, config, NoC topology, MACs/PE, prefetch depth,
//! PE model, policy) — run through [`engine::SimEngine`], which caches
//! profiles and fans the expanded cell grid out across worker threads.
//! Grids too large for one process split by contiguous flat-index range
//! ([`shard`]): `SimEngine::sweep_shard` runs one [`ShardSpec`] slice and
//! persists it, [`shard::merge`] reassembles the full grid bit-exactly.

pub mod cache;
pub mod des;
pub mod engine;
pub mod explore;
mod profile;
pub mod service;
pub mod shard;
pub mod timeline;

pub use cache::{CacheStats, DiskCache};
pub use des::{agreement_band, simulate_des, DesPeStats, DesResult};
pub use engine::{
    Axis, AxisCoord, AxisDim, CellModel, CellResult, DesignSpace, EngineError, SimEngine,
    SweepResult, SweepSpec, WorkloadKey,
};
pub use explore::{
    check_against_exhaustive, exhaustive_argmin, DatasetSearch, EvalJournal, EvalRecord,
    ExhaustiveCheck, ExploreResult, ExploreSpec, Explorer, Objective, Strategy, Tier,
    TrajectoryPoint,
};
pub use profile::{
    estimate_in_band, profile_container_tiled, profile_workload, profile_workload_parallel,
    profile_workload_sampled, profile_workload_tiled, profile_workload_tiled_cached,
    StratumEstimate, TilePartial, TiledStats, Workload, WorkloadEstimate, ESTIMATE_BAND,
};
pub use service::{
    run_chaos, ChaosReport, ChaosSpec, Coordinator, FaultPlan, LeasePolicy, ServiceConfig,
    ServiceError, ServiceStats, SweepOutcome, WorkerConfig, WorkerReport,
};
pub use shard::{PartialSweep, ShardError, ShardMeta, ShardSpec, SweepShard};
pub use timeline::{exact_pipeline, TwoStageTimeline};

use crate::accel::Accelerator;
use crate::config::AcceleratorConfig;
use crate::coordinator::Policy;
use crate::energy::EnergyBreakdown;
use crate::sparse::Csr;
use crate::trace::Counters;

/// The result of simulating one workload on one accelerator configuration.
/// `PartialEq` compares every field bit-for-bit — the determinism contract
/// [`engine::SimEngine`] tests lean on.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Configuration name (e.g. `matraptor-maple`).
    pub config: String,
    /// Datapath-limited cycle count (max over PE timelines). This is the
    /// quantity the paper's Sparseloop methodology reports as performance
    /// (DESIGN.md §Modeling) and what Fig. 9(b) compares.
    pub cycles_compute: u64,
    /// Cycles if the run were purely DRAM-bandwidth-bound.
    pub cycles_dram_bound: u64,
    /// max(compute, dram) — the wall-clock lower bound.
    pub cycles: u64,
    /// All action counts.
    pub counters: Counters,
    /// Energy aggregation of `counters` under the 45 nm model.
    pub energy: EnergyBreakdown,
    /// Output nnz (verification).
    pub out_nnz: u64,
    /// Numeric checksum of C (verification).
    pub checksum: f64,
    /// Total scalar products (work).
    pub total_products: u64,
    /// PE load-balance factor (max/mean products per PE).
    pub balance: f64,
}

impl SimResult {
    /// Energy benefit (%) of `self` over a baseline run — the paper's
    /// Fig. 9(a) metric: `100 × (1 − E_maple / E_base)`.
    pub fn energy_benefit_pct(&self, baseline: &SimResult) -> f64 {
        100.0 * (1.0 - self.energy.total_pj() / baseline.energy.total_pj())
    }

    /// Speedup (%) of `self` over a baseline run — the paper's Fig. 9(b)
    /// metric: `100 × (cycles_base / cycles_maple − 1)`.
    pub fn speedup_pct(&self, baseline: &SimResult) -> f64 {
        100.0 * (baseline.cycles_compute as f64 / self.cycles_compute as f64 - 1.0)
    }

    /// MAC utilisation: products / (cycles × total MACs available). Needs
    /// the config to know the MAC count.
    pub fn mac_utilisation(&self, cfg: &AcceleratorConfig) -> f64 {
        if self.cycles_compute == 0 {
            return 0.0;
        }
        self.total_products as f64 / (self.cycles_compute as f64 * cfg.total_macs() as f64)
    }
}

/// Simulate `C = A × B` on `cfg` with the default (round-robin) row routing.
pub fn simulate_spmspm(cfg: &AcceleratorConfig, a: &Csr, b: &Csr) -> SimResult {
    let w = profile_workload(a, b);
    simulate_workload(cfg, &w, Policy::RoundRobin)
}

/// Simulate a pre-profiled workload (reuse the profile across configs).
pub fn simulate_workload(cfg: &AcceleratorConfig, w: &Workload, policy: Policy) -> SimResult {
    Accelerator::new(cfg.clone()).run(w, policy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{generate, Profile};

    fn workload() -> Workload {
        let a = generate(400, 400, 4000, Profile::PowerLaw { alpha: 0.6 }, 17);
        profile_workload(&a, &a)
    }

    #[test]
    fn all_four_configs_run_and_verify() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
            assert_eq!(r.out_nnz, w.out_nnz, "{}", cfg.name);
            assert_eq!(r.total_products, w.total_products);
            assert!(r.cycles_compute > 0);
            assert!(r.energy.total_pj() > 0.0);
            assert_eq!(r.counters.mac_mul, w.total_products, "{}", cfg.name);
        }
    }

    #[test]
    fn maple_beats_baseline_on_energy_and_speed() {
        // The paper's headline (abstract): Maple-based configs win on both
        // energy and cycles in both reference accelerators.
        let w = workload();
        for (base, maple) in [
            (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple()),
            (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple()),
        ] {
            let rb = simulate_workload(&base, &w, Policy::RoundRobin);
            let rm = simulate_workload(&maple, &w, Policy::RoundRobin);
            assert!(
                rm.energy_benefit_pct(&rb) > 0.0,
                "{}: energy benefit {:.1}%",
                base.name,
                rm.energy_benefit_pct(&rb)
            );
            assert!(
                rm.speedup_pct(&rb) > 0.0,
                "{}: speedup {:.1}%",
                base.name,
                rm.speedup_pct(&rb)
            );
        }
    }

    #[test]
    fn greedy_policy_never_worse_than_round_robin() {
        let w = workload();
        let cfg = AcceleratorConfig::matraptor_maple();
        let rr = simulate_workload(&cfg, &w, Policy::RoundRobin);
        let greedy = simulate_workload(&cfg, &w, Policy::GreedyBalance);
        assert!(greedy.cycles_compute <= rr.cycles_compute + rr.cycles_compute / 10);
        assert!(greedy.balance <= rr.balance + 0.05);
    }

    #[test]
    fn dram_bound_is_config_independent() {
        let w = workload();
        let r1 =
            simulate_workload(&AcceleratorConfig::matraptor_baseline(), &w, Policy::RoundRobin);
        let r2 = simulate_workload(&AcceleratorConfig::matraptor_maple(), &w, Policy::RoundRobin);
        assert_eq!(r1.cycles_dram_bound, r2.cycles_dram_bound);
    }
}
