//! Transaction-level discrete-event simulation.
//!
//! The headline results run on the row-granular analytic pipeline
//! ([`crate::accel::Accelerator::run`], O(rows)); this module is its
//! validation harness: a classic event-queue simulation where every row's
//! operand fetch is a DRAM transaction with latency and port contention,
//! every delivery crosses the NoC, and each PE is an explicit
//! fetch → compute → drain state machine with double buffering. On small
//! workloads the two models must agree on the datapath-bound cycle count
//! within a documented band (`tests::des_brackets_analytic_model`) — the
//! same methodological check Sparseloop runs against Timeloop/Accelergy
//! cycle simulations.

use crate::config::AcceleratorConfig;
use crate::coordinator::{partition, split_wide_rows, Policy};
use crate::mem::{DramModel, DramParams};
use crate::noc::{Cast, Noc};
use crate::pe::RowCost;
use crate::sim::Workload;
use crate::trace::Counters;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What happens when an event fires. (`Ord` is required by the event
/// queue's tuple key; the unique sequence number decides ties first.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    /// Operands for the PE's next row have arrived; compute may start.
    OperandsArrived { pe: usize },
    /// The PE finished the front (multiply) stage of its current row.
    FrontDone { pe: usize },
    /// The PE's back stage (merge/POB/drain) finished.
    BackDone { pe: usize },
}

/// Per-PE state machine.
#[derive(Debug)]
struct PeState {
    /// Rows assigned to this PE, next index to fetch and to compute.
    rows: Vec<u32>,
    next_fetch: usize,
    /// Next row index whose operands will arrive (arrival order = fetch
    /// order; the DRAM/NoC path is FIFO per PE).
    next_arrival: usize,
    next_compute: usize,
    /// Fetched-and-waiting row costs (double buffer: at most 2 in flight).
    ready: std::collections::VecDeque<RowCost>,
    /// Busy flags for the two pipeline stages.
    front_busy: bool,
    back_busy: bool,
    /// Pending back-stage work (from completed fronts).
    back_queue: std::collections::VecDeque<u64>,
    done_front_cycles: u64,
}

/// Result of a DES run.
#[derive(Debug, Clone)]
pub struct DesResult {
    /// Completion time of the last event (cycles).
    pub cycles: u64,
    /// Total DRAM transactions issued.
    pub dram_transactions: u64,
    /// Mean PE front-stage occupancy (busy front cycles / total).
    pub pe_utilisation: f64,
}

/// Run the transaction-level simulation of one workload on one config.
///
/// Functional results are not recomputed (the profile pass is exact); the
/// DES resolves *timing* only: DRAM port contention, NoC serialisation and
/// the two-stage PE pipeline with explicit double buffering.
pub fn simulate_des(cfg: &AcceleratorConfig, w: &Workload, policy: Policy) -> DesResult {
    let accel = crate::accel::Accelerator::new(cfg.clone());
    let pe_model = accel.pe_model();
    let split_at = (4 * w.total_products / (w.rows as u64).max(1)).max(2048);
    let profiles = split_wide_rows(&w.profiles, split_at);
    let part = partition(policy, cfg.num_pes, &profiles);

    let mut dram = DramModel::new(DramParams { ..cfg.dram });
    let mut noc = Noc::new(cfg.noc);
    let mut scratch = Counters::default(); // DES reuses cost models; counters discarded

    let mut pes: Vec<PeState> = part
        .assignments
        .iter()
        .map(|rows| PeState {
            rows: rows.clone(),
            next_fetch: 0,
            next_arrival: 0,
            next_compute: 0,
            ready: Default::default(),
            front_busy: false,
            back_busy: false,
            back_queue: Default::default(),
            done_front_cycles: 0,
        })
        .collect();

    let mut queue: BinaryHeap<Reverse<(u64, usize, EventKind)>> = BinaryHeap::new();
    let mut seq = 0usize;
    let mut push = |q: &mut BinaryHeap<Reverse<(u64, usize, EventKind)>>, t: u64, e: EventKind| {
        seq += 1;
        q.push(Reverse((t, seq, e)));
    };

    // Issue the initial fetches for every PE. The loaders (SpAL/SpBL/LLB,
    // or Maple's ARB/BRB FIFOs) are stream prefetchers running several rows
    // ahead; PREFETCH_DEPTH bounds the rows in flight per PE.
    const PREFETCH_DEPTH: usize = 6;
    for (pe_id, st) in pes.iter_mut().enumerate() {
        for _ in 0..PREFETCH_DEPTH {
            if st.next_fetch < st.rows.len() {
                let r = st.rows[st.next_fetch] as usize;
                st.next_fetch += 1;
                let p = &profiles[r];
                // Operand volume: A elements + B rows (value + col_id).
                let words = 2 * p.a_nnz as u64 + 2 * p.products;
                let t_dram = dram.read(&mut scratch, 0, words.max(1));
                let lat = noc.transfer(&mut scratch, Cast::Unicast { src: 0, dst: pe_id % noc.endpoints() }, words.max(1));
                push(&mut queue, t_dram + lat, EventKind::OperandsArrived { pe: pe_id });
            }
        }
    }

    let mut now = 0u64;
    while let Some(Reverse((t, _, ev))) = queue.pop() {
        now = t;
        match ev {
            EventKind::OperandsArrived { pe } => {
                let r = pes[pe].rows[pes[pe].next_arrival] as usize;
                pes[pe].next_arrival += 1;
                let cost = pe_model.row_cost(&profiles[r], &mut scratch);
                pes[pe].ready.push_back(cost);
                if !pes[pe].front_busy {
                    if let Some(c) = pes[pe].ready.pop_front() {
                        pes[pe].front_busy = true;
                        pes[pe].done_front_cycles += c.front;
                        pes[pe].back_queue.push_back(c.back);
                        push(&mut queue, now + c.front.max(1), EventKind::FrontDone { pe });
                    }
                }
            }
            EventKind::FrontDone { pe } => {
                pes[pe].front_busy = false;
                pes[pe].next_compute += 1;
                // Kick the back stage if idle.
                if !pes[pe].back_busy {
                    if let Some(b) = pes[pe].back_queue.pop_front() {
                        pes[pe].back_busy = true;
                        push(&mut queue, now + b.max(1), EventKind::BackDone { pe });
                    }
                }
                // Refill the fetch pipeline.
                if pes[pe].next_fetch < pes[pe].rows.len() {
                    let r = pes[pe].rows[pes[pe].next_fetch] as usize;
                    pes[pe].next_fetch += 1;
                    let p = &profiles[r];
                    let words = 2 * p.a_nnz as u64 + 2 * p.products;
                    let t_dram = dram.read(&mut scratch, now, words.max(1));
                    let lat = noc.transfer(
                        &mut scratch,
                        Cast::Unicast { src: 0, dst: pe % noc.endpoints() },
                        words.max(1),
                    );
                    push(&mut queue, t_dram + lat, EventKind::OperandsArrived { pe });
                }
                // Start the next ready row if any.
                if !pes[pe].front_busy {
                    if let Some(c) = pes[pe].ready.pop_front() {
                        pes[pe].front_busy = true;
                        pes[pe].done_front_cycles += c.front;
                        pes[pe].back_queue.push_back(c.back);
                        push(&mut queue, now + c.front.max(1), EventKind::FrontDone { pe });
                    }
                }
            }
            EventKind::BackDone { pe } => {
                pes[pe].back_busy = false;
                if let Some(b) = pes[pe].back_queue.pop_front() {
                    pes[pe].back_busy = true;
                    push(&mut queue, now + b.max(1), EventKind::BackDone { pe });
                }
            }
        }
    }

    let busy: u64 = pes.iter().map(|p| p.done_front_cycles).sum();
    DesResult {
        cycles: now,
        dram_transactions: dram.transactions(),
        pe_utilisation: if now == 0 {
            0.0
        } else {
            busy as f64 / (now as f64 * pes.len() as f64)
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sparse::gen::{generate, Profile};

    fn workload() -> Workload {
        let a = generate(300, 300, 3000, Profile::Uniform, 77);
        profile_workload(&a, &a)
    }

    #[test]
    fn des_completes_all_rows() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let r = simulate_des(&cfg, &w, Policy::RoundRobin);
            assert!(r.cycles > 0, "{}", cfg.name);
            assert!(r.dram_transactions > 0);
            assert!(r.pe_utilisation > 0.0 && r.pe_utilisation <= 1.0);
        }
    }

    /// The methodological check: the transaction-level simulation must
    /// bracket the analytic pipeline model. The DES adds DRAM/NoC fetch
    /// latency the analytic model idealises away, so DES ≥ analytic; it
    /// must not blow up beyond the fetch-overhead bound either.
    #[test]
    fn des_brackets_analytic_model() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let analytic = crate::sim::simulate_workload(&cfg, &w, Policy::RoundRobin);
            let des = simulate_des(&cfg, &w, Policy::RoundRobin);
            let lower = analytic.cycles_compute as f64 * 0.9;
            // Upper bound: compute + fully-serialised DRAM streaming.
            let upper = (analytic.cycles_compute + 2 * analytic.cycles_dram_bound) as f64 * 1.5
                + 10_000.0;
            let c = des.cycles as f64;
            assert!(
                c >= lower && c <= upper,
                "{}: DES {c} outside [{lower}, {upper}] (analytic {})",
                cfg.name,
                analytic.cycles_compute
            );
        }
    }

    /// Relative ordering must be preserved: if the analytic model says the
    /// Maple config is faster, the DES must agree (same direction).
    #[test]
    fn des_agrees_on_the_winner() {
        let w = workload();
        for (base, maple) in [
            (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple()),
            (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple()),
        ] {
            let ab = crate::sim::simulate_workload(&base, &w, Policy::RoundRobin);
            let am = crate::sim::simulate_workload(&maple, &w, Policy::RoundRobin);
            let db = simulate_des(&base, &w, Policy::RoundRobin);
            let dm = simulate_des(&maple, &w, Policy::RoundRobin);
            let analytic_says_maple = am.cycles_compute < ab.cycles_compute;
            let des_says_maple = dm.cycles < db.cycles;
            assert_eq!(
                analytic_says_maple, des_says_maple,
                "{}: analytic {} vs {} — DES {} vs {}",
                base.name, ab.cycles_compute, am.cycles_compute, db.cycles, dm.cycles
            );
        }
    }

    #[test]
    fn des_empty_workload() {
        let a = crate::sparse::Csr::zero(16, 16);
        let w = profile_workload(&a, &a);
        let r = simulate_des(&AcceleratorConfig::matraptor_maple(), &w, Policy::RoundRobin);
        // Rows exist (empty ones); simulation terminates quickly.
        assert!(r.cycles < 100_000);
    }
}
