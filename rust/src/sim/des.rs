//! Transaction-level discrete-event simulation.
//!
//! The headline results run on the row-granular analytic pipeline
//! ([`crate::accel::Accelerator::run`], O(rows)); this module is its
//! validation harness: a classic event-queue simulation where every row's
//! operand fetch is a DRAM transaction with latency and port contention,
//! every delivery crosses the NoC, and each PE is an explicit
//! fetch → compute → drain state machine with a bounded loader FIFO. On
//! small workloads the two models must agree on the datapath-bound cycle
//! count within a documented band ([`agreement_band`],
//! `tests::des_brackets_analytic_model`) — the same methodological check
//! Sparseloop runs against Timeloop/Accelergy cycle simulations.
//!
//! # Pipeline semantics (corrected)
//!
//! The per-PE machine implements exactly the infinite-buffer two-stage
//! recurrence of [`crate::sim::timeline::exact_pipeline`], plus fetch
//! latency and a finite prefetch credit:
//!
//! * a row's **back** stage (merge/POB/drain) may start only once that
//!   row's **front** (multiply) stage has finished *and* the previous back
//!   has drained — the back cost is enqueued at `FrontDone`, never at front
//!   start (an earlier revision enqueued it at front start, letting an idle
//!   back stage begin a row's merge before its multiply had finished and
//!   under-counting cycles; `tests::back_stage_waits_for_its_own_front`
//!   pins the fix);
//! * front-stage busy cycles are accounted at `FrontDone` — completion,
//!   not issue — so utilisation never counts cycles that have not elapsed;
//! * the loader FIFO holds at most `cfg.pe.prefetch_depth` rows per PE
//!   (fetched-and-waiting **plus** in-flight fetches); a new fetch is
//!   issued only when a credit frees up.
//!
//! With fetch latency zeroed and one PE the machine reproduces
//! `exact_pipeline` cycle-for-cycle
//! (`tests::zero_latency_single_pe_matches_exact_pipeline`).

use crate::config::AcceleratorConfig;
use crate::coordinator::{partition, split_wide_rows, Policy};
use crate::mem::DramModel;
use crate::noc::{Cast, Noc};
use crate::pe::RowCost;
use crate::sim::{SimResult, Workload};
use crate::trace::Counters;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// What happens when an event fires. (`Ord` is required by the event
/// queue's tuple key; the unique sequence number decides ties first.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    /// Operands for the PE's next row have arrived; compute may start.
    OperandsArrived { pe: usize },
    /// The PE finished the front (multiply) stage of its current row.
    FrontDone { pe: usize },
    /// The PE's back stage (merge/POB/drain) finished.
    BackDone { pe: usize },
}

/// Time-ordered event queue with a deterministic FIFO tie-break.
struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, usize, EventKind)>>,
    seq: usize,
}

impl EventQueue {
    fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0 }
    }

    fn push(&mut self, t: u64, e: EventKind) {
        self.seq += 1;
        self.heap.push(Reverse((t, self.seq, e)));
    }

    fn pop(&mut self) -> Option<(u64, EventKind)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e))
    }
}

/// Per-PE statistics of one DES run, reusable by [`crate::report`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DesPeStats {
    /// Rows fully retired (back stage drained).
    pub rows: u64,
    /// Cycles the front (multiply) stage was busy — accounted at
    /// completion, so a drained queue never counts unelapsed cycles.
    pub front_busy_cycles: u64,
    /// Cycles the back (merge/drain) stage was busy.
    pub back_busy_cycles: u64,
    /// Cycle at which this PE retired its last row (0 when it had none).
    pub finish: u64,
}

/// Per-PE state machine.
struct PeState {
    /// Row costs in fetch/arrival order (arrival order = fetch order; the
    /// DRAM/NoC path is FIFO per PE).
    costs: Vec<RowCost>,
    /// Operand words each row pulls from DRAM.
    fetch_words: Vec<u64>,
    next_fetch: usize,
    next_arrival: usize,
    /// Loader FIFO: fetched-and-waiting rows. Together with in-flight
    /// fetches (`next_fetch - next_arrival`) it never exceeds the
    /// configured prefetch depth — a hard buffer credit.
    ready: VecDeque<RowCost>,
    /// The row occupying the front stage, if any.
    front: Option<RowCost>,
    /// The back cost occupying the back stage, if any.
    back: Option<u64>,
    /// Back work from *completed* fronts, waiting for the back stage.
    back_queue: VecDeque<u64>,
    /// Latest scheduled arrival — later fetches clamp to it, making the
    /// per-PE delivery genuinely FIFO (a narrow row fetched after a wide
    /// one cannot overtake it on the NoC).
    last_arrival: u64,
    stats: DesPeStats,
}

impl PeState {
    fn new(costs: Vec<RowCost>, fetch_words: Vec<u64>) -> Self {
        Self {
            costs,
            fetch_words,
            next_fetch: 0,
            next_arrival: 0,
            ready: VecDeque::new(),
            front: None,
            back: None,
            back_queue: VecDeque::new(),
            last_arrival: 0,
            stats: DesPeStats::default(),
        }
    }

    /// Issue fetches while buffer credits remain: the loader may run ahead
    /// only as far as `depth` rows that are fetched-and-waiting or still in
    /// flight.
    fn refill(
        &mut self,
        pe: usize,
        now: u64,
        depth: usize,
        q: &mut EventQueue,
        fetch: &mut impl FnMut(usize, u64, u64) -> u64,
    ) {
        while self.next_fetch < self.costs.len()
            && self.ready.len() + (self.next_fetch - self.next_arrival) < depth
        {
            let words = self.fetch_words[self.next_fetch];
            self.next_fetch += 1;
            // FIFO delivery: an arrival never lands before an earlier
            // fetch of the same PE, so `next_arrival` indexing binds each
            // arrival event to the row that actually caused it.
            self.last_arrival = fetch(pe, words, now).max(self.last_arrival);
            q.push(self.last_arrival, EventKind::OperandsArrived { pe });
        }
    }

    /// Move the next ready row into the idle front stage.
    fn try_start_front(&mut self, pe: usize, now: u64, q: &mut EventQueue) {
        if self.front.is_none() {
            if let Some(c) = self.ready.pop_front() {
                q.push(now + c.front, EventKind::FrontDone { pe });
                self.front = Some(c);
            }
        }
    }

    /// Move the next queued back cost into the idle back stage.
    fn try_start_back(&mut self, pe: usize, now: u64, q: &mut EventQueue) {
        if self.back.is_none() {
            if let Some(b) = self.back_queue.pop_front() {
                q.push(now + b, EventKind::BackDone { pe });
                self.back = Some(b);
            }
        }
    }
}

/// Result of a DES run.
#[derive(Debug, Clone, PartialEq)]
pub struct DesResult {
    /// Completion time of the last event (cycles).
    pub cycles: u64,
    /// Total DRAM transactions issued.
    pub dram_transactions: u64,
    /// Mean PE front-stage occupancy (busy front cycles / total).
    pub pe_utilisation: f64,
    /// Per-PE pipeline statistics (fetch-order indexed, one per PE).
    pub per_pe: Vec<DesPeStats>,
}

impl DesResult {
    /// Finish-time skew across the PEs that retired at least one row:
    /// latest finish / mean finish (1.0 = perfectly balanced; 0.0 when no
    /// PE retired a row). Idle PEs are excluded so a small workload on a
    /// wide machine doesn't read as imbalance.
    pub fn finish_skew(&self) -> f64 {
        let finishes: Vec<u64> =
            self.per_pe.iter().filter(|p| p.rows > 0).map(|p| p.finish).collect();
        let max = finishes.iter().copied().max().unwrap_or(0);
        let sum: u64 = finishes.iter().sum();
        if sum == 0 {
            return 0.0;
        }
        max as f64 / (sum as f64 / finishes.len() as f64)
    }
}

/// The documented DES/analytic agreement band, in DES cycles.
///
/// Lower bound: the analytic datapath cycles themselves — each PE's DES
/// completion is the exact two-stage recurrence plus fetch stalls, and
/// [`crate::sim::timeline::TwoStageTimeline::makespan`] is a proven lower
/// bound of that recurrence, so the DES can never undercut it. Upper
/// bound: compute plus fully-serialised DRAM streaming with 50% headroom
/// for burst padding and NoC serialisation (plus a small-workload floor).
pub fn agreement_band(analytic: &SimResult) -> (u64, u64) {
    let lower = analytic.cycles_compute;
    let upper = ((analytic.cycles_compute + 2 * analytic.cycles_dram_bound) as f64 * 1.5) as u64
        + 10_000;
    (lower, upper)
}

/// Core event machine over per-PE row-cost sequences.
///
/// `fetch(pe, words, now)` schedules one row's operand fetch and returns
/// its arrival cycle — the production path routes this through the DRAM
/// port and NoC models; tests zero it to pin the pipeline semantics
/// against [`crate::sim::timeline::exact_pipeline`].
fn run_pipeline(
    per_pe: Vec<(Vec<RowCost>, Vec<u64>)>,
    depth: usize,
    mut fetch: impl FnMut(usize, u64, u64) -> u64,
) -> (u64, Vec<DesPeStats>) {
    let depth = depth.max(1);
    let mut pes: Vec<PeState> =
        per_pe.into_iter().map(|(costs, words)| PeState::new(costs, words)).collect();
    let mut q = EventQueue::new();
    for (pe, st) in pes.iter_mut().enumerate() {
        st.refill(pe, 0, depth, &mut q, &mut fetch);
    }

    let mut now = 0u64;
    while let Some((t, ev)) = q.pop() {
        now = t;
        match ev {
            EventKind::OperandsArrived { pe } => {
                let st = &mut pes[pe];
                let cost = st.costs[st.next_arrival];
                st.next_arrival += 1;
                st.ready.push_back(cost);
                st.try_start_front(pe, now, &mut q);
                // A front start frees one loader credit.
                st.refill(pe, now, depth, &mut q, &mut fetch);
            }
            EventKind::FrontDone { pe } => {
                let st = &mut pes[pe];
                let done = st.front.take().expect("front stage was busy");
                st.stats.front_busy_cycles += done.front;
                // Only now — with the multiply finished — may this row's
                // back work become eligible.
                st.back_queue.push_back(done.back);
                st.try_start_back(pe, now, &mut q);
                st.try_start_front(pe, now, &mut q);
                st.refill(pe, now, depth, &mut q, &mut fetch);
            }
            EventKind::BackDone { pe } => {
                let st = &mut pes[pe];
                let b = st.back.take().expect("back stage was busy");
                st.stats.back_busy_cycles += b;
                st.stats.rows += 1;
                st.stats.finish = now;
                st.try_start_back(pe, now, &mut q);
            }
        }
    }
    (now, pes.into_iter().map(|st| st.stats).collect())
}

/// Run the transaction-level simulation of one workload on one config.
///
/// Functional results are not recomputed (the profile pass is exact); the
/// DES resolves *timing* only: DRAM port contention, NoC serialisation and
/// the two-stage PE pipeline with a bounded loader FIFO
/// (`cfg.pe.prefetch_depth` rows of buffer credit per PE).
pub fn simulate_des(cfg: &AcceleratorConfig, w: &Workload, policy: Policy) -> DesResult {
    let accel = crate::accel::Accelerator::new(cfg.clone());
    let pe_model = accel.pe_model();
    let split_at = (4 * w.total_products / (w.rows as u64).max(1)).max(2048);
    let profiles = split_wide_rows(&w.profiles, split_at);
    let part = partition(policy, cfg.num_pes, &profiles);

    let mut scratch = Counters::default(); // DES reuses cost models; counters discarded
    let mut per_pe: Vec<(Vec<RowCost>, Vec<u64>)> = Vec::with_capacity(part.assignments.len());
    for rows in &part.assignments {
        let mut costs = Vec::with_capacity(rows.len());
        let mut words = Vec::with_capacity(rows.len());
        for &r in rows {
            let p = &profiles[r as usize];
            costs.push(pe_model.row_cost(p, &mut scratch));
            // Operand volume: A elements + B rows (value + col_id).
            words.push((2 * p.a_nnz as u64 + 2 * p.products).max(1));
        }
        per_pe.push((costs, words));
    }

    let mut dram = DramModel::new(cfg.dram);
    let mut noc = Noc::new(cfg.noc);
    let endpoints = noc.endpoints();
    let (cycles, per_pe_stats) = run_pipeline(per_pe, cfg.pe.prefetch_depth, |pe, words, now| {
        let t_dram = dram.read(&mut scratch, now, words);
        let lat = noc.transfer(&mut scratch, Cast::Unicast { src: 0, dst: pe % endpoints }, words);
        t_dram + lat
    });

    let busy: u64 = per_pe_stats.iter().map(|p| p.front_busy_cycles).sum();
    let n_pes = per_pe_stats.len().max(1);
    DesResult {
        cycles,
        dram_transactions: dram.transactions(),
        pe_utilisation: if cycles == 0 {
            0.0
        } else {
            busy as f64 / (cycles as f64 * n_pes as f64)
        },
        per_pe: per_pe_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sim::timeline::exact_pipeline;
    use crate::sparse::gen::{generate, Profile};

    fn workload() -> Workload {
        let a = generate(300, 300, 3000, Profile::Uniform, 77);
        profile_workload(&a, &a)
    }

    /// Zero-latency fetch: operands for every issued row arrive instantly.
    fn no_fetch(_pe: usize, _words: u64, now: u64) -> u64 {
        now
    }

    #[test]
    fn des_completes_all_rows() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let r = simulate_des(&cfg, &w, Policy::RoundRobin);
            assert!(r.cycles > 0, "{}", cfg.name);
            assert!(r.dram_transactions > 0);
            assert!(r.pe_utilisation > 0.0 && r.pe_utilisation <= 1.0);
            assert_eq!(r.per_pe.len(), cfg.num_pes, "{}", cfg.name);
            let retired: u64 = r.per_pe.iter().map(|p| p.rows).sum();
            assert!(retired > 0, "{}", cfg.name);
            for p in &r.per_pe {
                assert!(p.finish <= r.cycles);
                assert!(p.front_busy_cycles <= p.finish);
            }
        }
    }

    /// The regression the back-queue fix pins: a row's back stage must wait
    /// for that row's *own front* to finish, not merely for the back stage
    /// to go idle. Rows (front 1, back 50) then (front 100, back 50): the
    /// pre-fix machine enqueued row 1's back cost when its front *started*,
    /// so the idle back stage ran it over cycles 51–101 while the multiply
    /// was still in flight, finishing at cycle 101 — a 50-cycle under-count
    /// of the true pipeline (151).
    #[test]
    fn back_stage_waits_for_its_own_front() {
        let costs = vec![RowCost { front: 1, back: 50 }, RowCost { front: 100, back: 50 }];
        let (cycles, stats) = run_pipeline(vec![(costs.clone(), vec![1, 1])], 2, no_fetch);
        assert_eq!(cycles, exact_pipeline(&costs));
        assert_eq!(cycles, 151, "pre-fix jump-start under-counted this to 101");
        assert_eq!(stats[0].rows, 2);
        assert_eq!(stats[0].front_busy_cycles, 101);
        assert_eq!(stats[0].back_busy_cycles, 100);
    }

    /// With DRAM/NoC latency zeroed and one PE, the event machine must
    /// reproduce the exact infinite-buffer pipeline recurrence
    /// cycle-for-cycle, for any prefetch depth ≥ 1 and for every PE cost
    /// model's real row costs.
    #[test]
    fn zero_latency_single_pe_matches_exact_pipeline() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let pe = crate::accel::Accelerator::new(cfg.clone()).pe_model();
            let mut scratch = Counters::default();
            let costs: Vec<RowCost> =
                w.profiles.iter().map(|p| pe.row_cost(p, &mut scratch)).collect();
            let words = vec![1u64; costs.len()];
            let expect = exact_pipeline(&costs);
            for depth in [1, 2, 6] {
                let (cycles, stats) =
                    run_pipeline(vec![(costs.clone(), words.clone())], depth, no_fetch);
                assert_eq!(cycles, expect, "{} depth={depth}", cfg.name);
                assert_eq!(stats[0].rows, costs.len() as u64);
            }
        }
    }

    /// The loader FIFO is a hard credit: at most `depth` rows are in
    /// flight or waiting, so exactly `min(depth, rows)` fetches are issued
    /// before any operands arrive (the pre-credit code always issued six).
    #[test]
    fn prefetch_depth_bounds_initial_fetch_burst() {
        let costs = vec![RowCost { front: 5, back: 3 }; 10];
        let words = vec![1u64; 10];
        for depth in [1usize, 2, 4, 8, 16] {
            let mut initial = 0u64;
            let (_, stats) = run_pipeline(
                vec![(costs.clone(), words.clone())],
                depth,
                |_, _, now| {
                    if now == 0 {
                        initial += 1;
                    }
                    now + 1000 // arrivals land long after the initial burst
                },
            );
            assert_eq!(initial, depth.min(10) as u64, "depth={depth}");
            assert_eq!(stats[0].rows, 10);
        }
    }

    /// Deeper prefetch hides more fetch latency, never less.
    #[test]
    fn deeper_prefetch_is_monotonically_not_slower() {
        let costs: Vec<RowCost> =
            (0..64).map(|i| RowCost { front: 3 + i % 5, back: 2 + i % 3 }).collect();
        let words = vec![4u64; costs.len()];
        let run = |depth| {
            run_pipeline(vec![(costs.clone(), words.clone())], depth, |_, _, now| now + 40).0
        };
        let (d1, d2, d6) = (run(1), run(2), run(6));
        assert!(d1 >= d2 && d2 >= d6, "depths 1/2/6 gave {d1}/{d2}/{d6}");
        // And every run still bounds below by the pure pipeline.
        assert!(d6 >= exact_pipeline(&costs));
    }

    /// The methodological check: the transaction-level simulation must
    /// bracket the analytic pipeline model within the documented band —
    /// the DES adds DRAM/NoC fetch latency the analytic model idealises
    /// away, so DES ≥ analytic exactly (no slack); it must not blow up
    /// beyond the fetch-overhead bound either.
    #[test]
    fn des_brackets_analytic_model() {
        let w = workload();
        for cfg in AcceleratorConfig::paper_configs() {
            let analytic = crate::sim::simulate_workload(&cfg, &w, Policy::RoundRobin);
            let des = simulate_des(&cfg, &w, Policy::RoundRobin);
            let (lower, upper) = agreement_band(&analytic);
            assert!(
                des.cycles >= lower && des.cycles <= upper,
                "{}: DES {} outside [{lower}, {upper}] (analytic {})",
                cfg.name,
                des.cycles,
                analytic.cycles_compute
            );
        }
    }

    /// Relative ordering must be preserved: if the analytic model says the
    /// Maple config is faster, the DES must agree within a 2% tie margin
    /// (in the fetch-bound regime both configs saturate the same DRAM port
    /// and the "winner" is event-ordering noise).
    #[test]
    fn des_agrees_on_the_winner() {
        let w = workload();
        for (base, maple) in [
            (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple()),
            (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple()),
        ] {
            let ab = crate::sim::simulate_workload(&base, &w, Policy::RoundRobin);
            let am = crate::sim::simulate_workload(&maple, &w, Policy::RoundRobin);
            let db = simulate_des(&base, &w, Policy::RoundRobin);
            let dm = simulate_des(&maple, &w, Policy::RoundRobin);
            let msg = format!(
                "{}: analytic {} vs {} — DES {} vs {}",
                base.name, ab.cycles_compute, am.cycles_compute, db.cycles, dm.cycles
            );
            if am.cycles_compute < ab.cycles_compute {
                assert!(dm.cycles as f64 <= db.cycles as f64 * 1.02, "{msg}");
            } else {
                assert!(db.cycles as f64 <= dm.cycles as f64 * 1.02, "{msg}");
            }
        }
    }

    #[test]
    fn des_empty_workload() {
        let a = crate::sparse::Csr::zero(16, 16);
        let w = profile_workload(&a, &a);
        let cfg = AcceleratorConfig::matraptor_maple();
        let r = simulate_des(&cfg, &w, Policy::RoundRobin);
        // Rows exist (empty ones), so every row still pays its minimum
        // one-word fetch: the run can finish no earlier than the first
        // DRAM access + one burst + one NoC hop…
        let xfer = (cfg.dram.burst_words as f64 / cfg.dram.words_per_cycle).ceil() as u64;
        let floor = cfg.dram.access_latency + xfer + 1;
        assert!(r.cycles >= floor, "{} < fetch floor {floor}", r.cycles);
        // …and no later than fully-serialised one-burst fetches of all 16
        // rows plus a handful of zero-work pipeline events per row.
        let ceiling = cfg.dram.access_latency + (w.rows as u64 + 1) * xfer + 4 * w.rows as u64 + 16;
        assert!(r.cycles <= ceiling, "{} > serialised ceiling {ceiling}", r.cycles);
        assert_eq!(r.dram_transactions, w.rows as u64);
    }
}
