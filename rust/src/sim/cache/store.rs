//! The on-disk artifact store: [`WorkloadKey`] → cache file.
//!
//! A [`DiskCache`] owns one flat directory of codec-sealed artifacts
//! (workloads `.mwl`, matrices `.mcsr`, explore eval journals `.mevl`,
//! tiled-profile partials `.mtp`).
//! File names encode the full cache
//! key — sanitized dataset name, seed, scale divisor, profile chunk count,
//! an FNV-1a of the raw dataset name (collision-proofing the sanitization),
//! and the codec version:
//!
//! ```text
//! wv-s7-d64-pt1-af63bd4c8601b7be.v1.mwl
//! ```
//!
//! Invalidation rules:
//! * **codec version bump** — the `.vN.` component changes, so new runs
//!   start cold without touching old files; a hand-renamed stale file is
//!   still rejected (and evicted) by the envelope's version field.
//! * **decode failure** — any truncated, corrupted, or inconsistent
//!   artifact is deleted on load and the workload recomputed; a bad cache
//!   file is never trusted.
//! * **key change** — seed, scale, and profile chunk count are part of the
//!   file name, so a different sweep parameterisation never aliases.
//!
//! Publication is atomic: artifacts are written to a unique temp file in
//! the same directory and `rename`d into place, so concurrent engines
//! (scoped sweep threads or separate processes sharing the directory) see
//! either nothing or a complete artifact — the loser of a racing publish
//! simply overwrites the winner with identical bytes.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::codec::{self, CODEC_VERSION};
use crate::sim::engine::WorkloadKey;
use crate::sim::explore::EvalJournal;
use crate::sim::{TilePartial, Workload};
use crate::sparse::{Csr, SparseFormat};

/// Environment override for the cache directory (CLI and benches honour it).
pub const CACHE_DIR_ENV: &str = "MAPLE_CACHE_DIR";

const WORKLOAD_EXT: &str = "mwl";
const MATRIX_EXT: &str = "mcsr";
const EVALS_EXT: &str = "mevl";
const TILE_EXT: &str = "mtp";

/// Distinguishes racing writers within one process; the pid handles racing
/// processes.
static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to a unique sibling temp file, then `rename` over the
/// final path — atomic on POSIX, so readers never observe a torn file. The
/// temp name carries both the pid and a process-wide counter, so racing
/// threads *and* racing processes each write their own temp file; the
/// rename loser simply overwrites the winner. Shared by every artifact
/// writer (workload/matrix/eval store, shard artifacts).
pub(crate) fn atomic_publish(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp-{}-{n}", std::process::id()));
    fs::write(&tmp, bytes)?;
    match fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// One on-disk artifact directory (see the module docs for the layout).
#[derive(Debug, Clone)]
pub struct DiskCache {
    dir: PathBuf,
}

/// What `maple cache stats` reports.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub dir: PathBuf,
    /// Workload artifacts at the current codec version.
    pub workloads: usize,
    /// Matrix artifacts at the current codec version.
    pub matrices: usize,
    /// Explore eval-journal artifacts at the current codec version.
    pub evals: usize,
    /// Tiled-profile partial-block artifacts at the current codec version.
    pub tiles: usize,
    /// Old-version artifacts, orphaned temp files, foreign files.
    pub stale: usize,
    /// Total bytes across all files in the directory.
    pub bytes: u64,
}

impl DiskCache {
    /// Open (creating if needed) a cache at `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(Self { dir })
    }

    /// Open the cache at `$MAPLE_CACHE_DIR`, or [`DiskCache::default_dir`],
    /// proving the directory is actually writable. An unusable directory —
    /// a path under a regular file, a read-only mount — errors *here*, so
    /// [`crate::sim::engine::SimEngine::from_env`] can warn once and fall
    /// back to uncached operation instead of failing on every store later.
    pub fn from_env() -> io::Result<Self> {
        match std::env::var_os(CACHE_DIR_ENV) {
            Some(dir) => Self::open_checked(PathBuf::from(dir)),
            None => Self::open_checked(Self::default_dir()),
        }
    }

    /// [`DiskCache::new`] plus a write probe: create-write-delete a unique
    /// probe file so a directory that exists but cannot take writes is
    /// reported as an error up front.
    pub(crate) fn open_checked(dir: impl Into<PathBuf>) -> io::Result<Self> {
        let cache = Self::new(dir)?;
        let n = TEMP_COUNTER.fetch_add(1, Ordering::Relaxed);
        let probe = cache.dir.join(format!(".probe-{}-{n}", std::process::id()));
        fs::write(&probe, b"maple")?;
        fs::remove_file(&probe)?;
        Ok(cache)
    }

    /// The default location: a `target/`-style throwaway directory relative
    /// to the working directory, safe to delete at any time.
    pub fn default_dir() -> PathBuf {
        PathBuf::from("target").join("maple-cache")
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The artifact file for one profiled workload. `profile_chunks` is the
    /// engine's profile-pass chunk count: it is part of the key because the
    /// f64 checksum's addition order — and therefore its exact bits — depends
    /// on the chunking, and a warm load must be byte-identical to what the
    /// same engine would have computed cold.
    pub fn workload_path(&self, key: &WorkloadKey, profile_chunks: usize) -> PathBuf {
        self.dir.join(format!(
            "{}-s{}-d{}-pt{}-{:016x}.v{}.{}",
            sanitize(&key.dataset),
            key.seed,
            key.scale,
            profile_chunks,
            codec::fnv1a(key.dataset.as_bytes()),
            CODEC_VERSION,
            WORKLOAD_EXT,
        ))
    }

    /// The artifact file for a workload *derived* for a non-CSR operand
    /// format: the base workload key plus a `-f{label}` component, so a
    /// format axis point never aliases the native-CSR artifact or another
    /// format's.
    pub fn workload_fmt_path(
        &self,
        key: &WorkloadKey,
        profile_chunks: usize,
        fmt: SparseFormat,
    ) -> PathBuf {
        self.dir.join(format!(
            "{}-s{}-d{}-pt{}-f{}-{:016x}.v{}.{}",
            sanitize(&key.dataset),
            key.seed,
            key.scale,
            profile_chunks,
            fmt.label(),
            codec::fnv1a(key.dataset.as_bytes()),
            CODEC_VERSION,
            WORKLOAD_EXT,
        ))
    }

    /// The artifact file for a named matrix.
    pub fn matrix_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!(
            "{}-{:016x}.v{}.{}",
            sanitize(name),
            codec::fnv1a(name.as_bytes()),
            CODEC_VERSION,
            MATRIX_EXT,
        ))
    }

    /// Load a cached workload. A missing file is a plain miss; an artifact
    /// that fails to decode is **evicted** (deleted) and reported as a miss,
    /// so the caller recomputes instead of trusting bad bytes. The base
    /// workload name holds the native-CSR plan only — a non-CSR plan here
    /// (a hand-renamed format artifact) is evicted the same way.
    pub fn load_workload(&self, key: &WorkloadKey, profile_chunks: usize) -> Option<Workload> {
        let path = self.workload_path(key, profile_chunks);
        let bytes = fs::read(&path).ok()?;
        match codec::decode_workload(&bytes) {
            Ok(w) if w.fmt.format == SparseFormat::Csr => Some(w),
            _ => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Persist a profiled workload (atomic temp-file + rename publish).
    pub fn store_workload(
        &self,
        key: &WorkloadKey,
        profile_chunks: usize,
        w: &Workload,
    ) -> io::Result<()> {
        self.persist(&self.workload_path(key, profile_chunks), &codec::encode_workload(w))
    }

    /// Load a cached format-derived workload (same miss/eviction contract
    /// as [`DiskCache::load_workload`]); an artifact whose embedded plan
    /// format disagrees with the requested one — a hand-renamed file — is
    /// evicted too.
    pub fn load_workload_fmt(
        &self,
        key: &WorkloadKey,
        profile_chunks: usize,
        fmt: SparseFormat,
    ) -> Option<Workload> {
        let path = self.workload_fmt_path(key, profile_chunks, fmt);
        let bytes = fs::read(&path).ok()?;
        match codec::decode_workload(&bytes) {
            Ok(w) if w.fmt.format == fmt => Some(w),
            _ => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Persist a format-derived workload under its own plan's format
    /// (atomic publish).
    pub fn store_workload_fmt(
        &self,
        key: &WorkloadKey,
        profile_chunks: usize,
        w: &Workload,
    ) -> io::Result<()> {
        self.persist(
            &self.workload_fmt_path(key, profile_chunks, w.fmt.format),
            &codec::encode_workload(w),
        )
    }

    /// Load a cached matrix (same miss/eviction contract as workloads).
    pub fn load_matrix(&self, name: &str) -> Option<Csr> {
        let path = self.matrix_path(name);
        let bytes = fs::read(&path).ok()?;
        match codec::decode_csr(&bytes) {
            Ok(a) => Some(a),
            Err(_) => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Persist a matrix under `name` (atomic publish).
    pub fn store_matrix(&self, name: &str, a: &Csr) -> io::Result<()> {
        self.persist(&self.matrix_path(name), &codec::encode_csr(a))
    }

    /// The artifact file for one explore eval journal. The full journal key
    /// — design-space fingerprint, evaluator tier, and the estimate tier's
    /// sampling parameters — is in the name, so a different space or a
    /// different fitness parameterisation never aliases.
    pub fn evals_path(
        &self,
        fingerprint: u64,
        tier: u8,
        sample_budget: u64,
        sample_seed: u64,
    ) -> PathBuf {
        self.dir.join(format!(
            "evals-{:016x}-t{}-b{}-s{}.v{}.{}",
            fingerprint,
            tier,
            sample_budget,
            sample_seed,
            CODEC_VERSION,
            EVALS_EXT,
        ))
    }

    /// Load a cached eval journal (same miss/eviction contract as
    /// workloads). A decoded journal whose embedded key disagrees with the
    /// requested one — a hand-renamed file — is evicted too.
    pub fn load_evals(
        &self,
        fingerprint: u64,
        tier: u8,
        sample_budget: u64,
        sample_seed: u64,
    ) -> Option<EvalJournal> {
        let path = self.evals_path(fingerprint, tier, sample_budget, sample_seed);
        let bytes = fs::read(&path).ok()?;
        match codec::decode_evals(&bytes) {
            Ok(j)
                if j.fingerprint == fingerprint
                    && j.tier == tier
                    && j.sample_budget == sample_budget
                    && j.sample_seed == sample_seed =>
            {
                Some(j)
            }
            _ => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Persist an eval journal (atomic publish).
    pub fn store_evals(&self, j: &EvalJournal) -> io::Result<()> {
        self.persist(
            &self.evals_path(j.fingerprint, j.tier, j.sample_budget, j.sample_seed),
            &codec::encode_evals(j),
        )
    }

    /// The artifact file for one tiled-profile partial block. The block's
    /// half-open row/column bounds — not the tile *shape* — name the
    /// artifact, so two sweeps whose edge tiles clamp to the same bounds
    /// share the identical partial. `key` names the workload (dataset +
    /// parameterisation); the FNV component collision-proofs sanitization
    /// exactly as for workloads.
    pub fn tile_path(
        &self,
        key: &str,
        row_lo: usize,
        row_hi: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> PathBuf {
        self.dir.join(format!(
            "{}-{:016x}-r{row_lo}-{row_hi}-c{col_lo}-{col_hi}.v{}.{}",
            sanitize(key),
            codec::fnv1a(key.as_bytes()),
            CODEC_VERSION,
            TILE_EXT,
        ))
    }

    /// Whether a partial for this block is already published. Used by the
    /// out-of-core profiler to skip recomputing blocks on a warm resume
    /// *without* paying the load (the merge phase loads them later).
    pub fn has_tile_partial(
        &self,
        key: &str,
        row_lo: usize,
        row_hi: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> bool {
        self.tile_path(key, row_lo, row_hi, col_lo, col_hi).is_file()
    }

    /// Load a cached tile partial (same miss/eviction contract as
    /// workloads). A decoded partial whose embedded bounds disagree with the
    /// requested block — a hand-renamed file — is evicted too.
    pub fn load_tile_partial(
        &self,
        key: &str,
        row_lo: usize,
        row_hi: usize,
        col_lo: usize,
        col_hi: usize,
    ) -> Option<TilePartial> {
        let path = self.tile_path(key, row_lo, row_hi, col_lo, col_hi);
        let bytes = fs::read(&path).ok()?;
        match codec::decode_tile_partial(&bytes) {
            Ok(p)
                if p.row_lo == row_lo
                    && p.row_hi == row_hi
                    && p.col_lo == col_lo
                    && p.col_hi == col_hi =>
            {
                Some(p)
            }
            _ => {
                let _ = fs::remove_file(&path);
                None
            }
        }
    }

    /// Persist a tile partial under its own embedded bounds (atomic publish).
    pub fn store_tile_partial(&self, key: &str, p: &TilePartial) -> io::Result<()> {
        self.persist(
            &self.tile_path(key, p.row_lo, p.row_hi, p.col_lo, p.col_hi),
            &codec::encode_tile_partial(p),
        )
    }

    /// Atomic temp-file + rename publish (see [`atomic_publish`]).
    fn persist(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        atomic_publish(path, bytes)
    }

    /// Scan the directory. Infallible: an unreadable directory reports as
    /// empty, unreadable entries are skipped.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats { dir: self.dir.clone(), ..CacheStats::default() };
        let Ok(entries) = fs::read_dir(&self.dir) else {
            return s;
        };
        let current = format!(".v{CODEC_VERSION}.");
        let workload_suffix = format!(".{WORKLOAD_EXT}");
        let matrix_suffix = format!(".{MATRIX_EXT}");
        let evals_suffix = format!(".{EVALS_EXT}");
        let tile_suffix = format!(".{TILE_EXT}");
        for e in entries.flatten() {
            let path = e.path();
            if !path.is_file() {
                continue;
            }
            s.bytes += e.metadata().map(|m| m.len()).unwrap_or(0);
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                s.stale += 1;
                continue;
            };
            if name.ends_with(&workload_suffix) && name.contains(&current) {
                s.workloads += 1;
            } else if name.ends_with(&matrix_suffix) && name.contains(&current) {
                s.matrices += 1;
            } else if name.ends_with(&evals_suffix) && name.contains(&current) {
                s.evals += 1;
            } else if name.ends_with(&tile_suffix) && name.contains(&current) {
                s.tiles += 1;
            } else {
                s.stale += 1;
            }
        }
        s
    }

    /// Delete every file in the cache directory (all versions, leftover temp
    /// files included). Returns how many files were removed.
    pub fn clear(&self) -> io::Result<usize> {
        let mut removed = 0;
        for e in fs::read_dir(&self.dir)?.flatten() {
            let path = e.path();
            if path.is_file() {
                fs::remove_file(&path)?;
                removed += 1;
            }
        }
        Ok(removed)
    }
}

/// Keep file names portable: anything outside `[A-Za-z0-9._-]` becomes `_`
/// (the FNV component in the name disambiguates collapsed names).
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sparse::gen::{generate, Profile};

    fn tmp_cache(tag: &str) -> DiskCache {
        let dir = std::env::temp_dir()
            .join(format!("maple-store-test-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        DiskCache::new(dir).expect("temp cache dir")
    }

    fn sample() -> (WorkloadKey, Workload) {
        let a = generate(30, 30, 150, Profile::PowerLaw { alpha: 0.7 }, 5);
        (WorkloadKey::suite("wv", 5, 8), profile_workload(&a, &a))
    }

    fn sample_partial() -> TilePartial {
        TilePartial {
            row_lo: 0,
            row_hi: 2,
            col_lo: 4,
            col_hi: 8,
            products: vec![3, 1],
            out_counts: vec![2, 1],
            out_vals: vec![0.5, -1.25, 2.0],
        }
    }

    #[test]
    fn workload_store_load_round_trip() {
        let cache = tmp_cache("roundtrip");
        let (key, w) = sample();
        assert!(cache.load_workload(&key, 1).is_none(), "fresh dir must miss");
        cache.store_workload(&key, 1, &w).unwrap();
        let loaded = cache.load_workload(&key, 1).expect("hit after store");
        assert_eq!(loaded, w);
        assert_eq!(loaded.checksum.to_bits(), w.checksum.to_bits());
        // A different profile chunk count is a different artifact.
        assert!(cache.load_workload(&key, 4).is_none());
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn format_derived_workloads_never_alias_the_csr_artifact() {
        let cache = tmp_cache("fmt");
        let (key, w) = sample();
        cache.store_workload(&key, 1, &w).unwrap();
        // A derived CSC workload stores under its own `-f` name...
        let mut wc = w.clone();
        wc.fmt = crate::sparse::FormatPlan::from_totals(
            SparseFormat::Csc,
            wc.rows,
            wc.cols,
            wc.rows_b,
            wc.nnz_a,
            wc.nnz_b,
            wc.out_nnz,
        );
        cache.store_workload_fmt(&key, 1, &wc).unwrap();
        assert_ne!(
            cache.workload_path(&key, 1),
            cache.workload_fmt_path(&key, 1, SparseFormat::Csc)
        );
        // ...and each name loads back its own plan.
        assert_eq!(cache.load_workload(&key, 1).unwrap(), w);
        assert_eq!(cache.load_workload_fmt(&key, 1, SparseFormat::Csc).unwrap(), wc);
        // A format that was never stored is a plain miss.
        assert!(cache.load_workload_fmt(&key, 1, SparseFormat::Coo).is_none());
        // A hand-renamed artifact (CSC plan under the COO name) is evicted.
        let wrong = cache.workload_fmt_path(&key, 1, SparseFormat::Coo);
        fs::copy(cache.workload_fmt_path(&key, 1, SparseFormat::Csc), &wrong).unwrap();
        assert!(cache.load_workload_fmt(&key, 1, SparseFormat::Coo).is_none());
        assert!(!wrong.exists(), "mismatched format artifact must be evicted");
        // A non-CSR plan under the base workload name is evicted too.
        let base = cache.workload_path(&key, 1);
        fs::copy(cache.workload_fmt_path(&key, 1, SparseFormat::Csc), &base).unwrap();
        assert!(cache.load_workload(&key, 1).is_none());
        assert!(!base.exists(), "non-CSR plan must not hide under the CSR name");
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn matrix_store_load_round_trip() {
        let cache = tmp_cache("matrix");
        let a = generate(20, 35, 120, Profile::Uniform, 9);
        assert!(cache.load_matrix("external").is_none());
        cache.store_matrix("external", &a).unwrap();
        assert_eq!(cache.load_matrix("external").unwrap(), a);
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn corrupt_artifact_is_evicted_not_trusted() {
        let cache = tmp_cache("evict");
        let (key, w) = sample();
        cache.store_workload(&key, 1, &w).unwrap();
        let path = cache.workload_path(&key, 1);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(cache.load_workload(&key, 1).is_none(), "corrupt artifact must miss");
        assert!(!path.exists(), "corrupt artifact must be evicted");
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn stats_and_clear_see_every_file() {
        let cache = tmp_cache("stats");
        let (key, w) = sample();
        cache.store_workload(&key, 1, &w).unwrap();
        cache.store_matrix("m", &generate(10, 10, 20, Profile::Uniform, 1)).unwrap();
        cache.store_evals(&EvalJournal::empty(1, 0, 0, 0)).unwrap();
        cache.store_tile_partial("wv", &sample_partial()).unwrap();
        fs::write(cache.dir().join("foreign.bin"), b"junk").unwrap();
        let s = cache.stats();
        assert_eq!((s.workloads, s.matrices, s.evals, s.tiles, s.stale), (1, 1, 1, 1, 1));
        assert!(s.bytes > 0);
        assert_eq!(cache.clear().unwrap(), 5);
        let s = cache.stats();
        assert_eq!((s.workloads, s.matrices, s.evals, s.tiles, s.bytes), (0, 0, 0, 0, 0));
        assert_eq!(s.stale, 0);
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn evals_round_trip_and_key_mismatch_evicts() {
        let cache = tmp_cache("evals");
        let mut j = EvalJournal::empty(0xABCD, 1, 128, 7);
        j.entries.insert(4, crate::sim::explore::EvalRecord { cycles: 10, energy_pj: 2.0 });
        j.entries.insert(9, crate::sim::explore::EvalRecord { cycles: 8, energy_pj: 3.5 });
        assert!(cache.load_evals(0xABCD, 1, 128, 7).is_none(), "fresh dir must miss");
        cache.store_evals(&j).unwrap();
        assert_eq!(cache.load_evals(0xABCD, 1, 128, 7).unwrap(), j);
        // A different key component is a different artifact.
        assert!(cache.load_evals(0xABCD, 0, 0, 0).is_none());
        assert!(cache.load_evals(0xABCD, 1, 64, 7).is_none());
        // A hand-renamed artifact (embedded key disagrees with the file
        // name) must be evicted, not trusted.
        let wrong = cache.evals_path(0xEEEE, 1, 128, 7);
        fs::copy(cache.evals_path(0xABCD, 1, 128, 7), &wrong).unwrap();
        assert!(cache.load_evals(0xEEEE, 1, 128, 7).is_none());
        assert!(!wrong.exists(), "mismatched journal must be evicted");
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn tile_partial_round_trip_and_bounds_mismatch_evicts() {
        let cache = tmp_cache("tile");
        let p = sample_partial();
        assert!(!cache.has_tile_partial("wv", 0, 2, 4, 8), "fresh dir must miss");
        assert!(cache.load_tile_partial("wv", 0, 2, 4, 8).is_none());
        cache.store_tile_partial("wv", &p).unwrap();
        assert!(cache.has_tile_partial("wv", 0, 2, 4, 8));
        assert_eq!(cache.load_tile_partial("wv", 0, 2, 4, 8).unwrap(), p);
        // A different key or block is a different artifact.
        assert!(cache.load_tile_partial("other", 0, 2, 4, 8).is_none());
        assert!(cache.load_tile_partial("wv", 0, 2, 0, 4).is_none());
        // A hand-renamed partial (embedded bounds disagree with the file
        // name) must be evicted, not trusted.
        let wrong = cache.tile_path("wv", 2, 4, 4, 8);
        fs::copy(cache.tile_path("wv", 0, 2, 4, 8), &wrong).unwrap();
        assert!(cache.load_tile_partial("wv", 2, 4, 4, 8).is_none());
        assert!(!wrong.exists(), "mismatched partial must be evicted");
        // Corruption is evicted, not trusted.
        let path = cache.tile_path("wv", 0, 2, 4, 8);
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        fs::write(&path, &bytes).unwrap();
        assert!(cache.load_tile_partial("wv", 0, 2, 4, 8).is_none());
        assert!(!path.exists(), "corrupt partial must be evicted");
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn racing_writers_with_distinct_contents_publish_one_complete_file() {
        // Harsher than the identical-bytes race below: 16 threads publish
        // *different* payloads to the same path. Atomicity means the final
        // file is exactly one candidate, never an interleaving, and no temp
        // files survive.
        let cache = tmp_cache("race-distinct");
        let path = cache.dir().join("contended.bin");
        let candidates: Vec<Vec<u8>> =
            (0..16u8).map(|i| vec![i; 4096 + i as usize]).collect();
        std::thread::scope(|scope| {
            for c in &candidates {
                let path = path.clone();
                scope.spawn(move || atomic_publish(&path, c).unwrap());
            }
        });
        let published = fs::read(&path).unwrap();
        assert!(
            candidates.iter().any(|c| *c == published),
            "published file is not any single writer's payload (torn write)"
        );
        let leftovers: Vec<_> = fs::read_dir(cache.dir())
            .unwrap()
            .flatten()
            .map(|e| e.file_name())
            .filter(|n| n.to_string_lossy().contains("tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "orphaned temp files: {leftovers:?}");
        let _ = fs::remove_dir_all(cache.dir());
    }

    #[test]
    fn unusable_cache_dir_is_reported_up_front() {
        // A cache path *under a regular file* can never become a directory:
        // the checked open must error so the engine can degrade to uncached
        // operation with one warning instead of failing every store.
        let dir = std::env::temp_dir()
            .join(format!("maple-store-test-{}-unusable", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let blocker = dir.join("blocker");
        fs::write(&blocker, b"a file, not a directory").unwrap();
        assert!(DiskCache::open_checked(blocker.join("cache")).is_err());
        // And a good directory passes the probe without leaving it behind.
        let good = DiskCache::open_checked(dir.join("good")).unwrap();
        assert_eq!(good.stats().stale, 0, "probe file must not survive");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_publishes_leave_a_valid_artifact() {
        let cache = tmp_cache("race");
        let (key, w) = sample();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| cache.store_workload(&key, 1, &w).unwrap());
            }
        });
        assert_eq!(cache.load_workload(&key, 1).unwrap(), w);
        // No orphaned temp files left behind.
        assert_eq!(cache.stats().stale, 0);
        let _ = fs::remove_dir_all(cache.dir());
    }
}
