//! Versioned, checksummed binary codec for cache artifacts.
//!
//! Five artifact kinds share one envelope: a CSR matrix, a profiled
//! [`Workload`], a sweep shard ([`crate::sim::shard::SweepShard`] — one
//! contiguous cell range of a design-space grid plus its metadata), an
//! explore eval journal ([`crate::sim::explore::EvalJournal`] — memoized
//! search fitness evaluations keyed by design-space fingerprint), and a
//! tiled-profile block partial ([`TilePartial`] — one row-group ×
//! column-tile unit of the out-of-core profile pass). The row-group
//! container (`.mrg`, [`crate::sparse::io`]) reuses the same envelope for
//! its header and per-group blocks, which is why [`seal`]/[`open`] are
//! crate-visible. Everything is hand-rolled on `std` like the rest of the
//! crate (DESIGN.md §Dependencies) and byte-stable across platforms: all
//! integers are little-endian, floats are stored as their IEEE-754 bit
//! patterns, so an artifact decodes to *bit-identical* values everywhere.
//!
//! Envelope layout:
//!
//! ```text
//! offset  size  field
//! 0       8     magic            (b"MAPLECSR" | b"MAPLEWL\0" | b"MAPLESHD" | b"MAPLEEVL"
//!                                 | b"MAPLETIL" | b"MAPLERGS")
//! 8       4     codec version    (u32, == CODEC_VERSION)
//! 12      8     payload length   (u64, byte count of the payload section)
//! 20      8     FNV-1a-64        (u64, over the payload bytes)
//! 28      n     payload sections
//! ```
//!
//! Decoding is strictly defensive — a bad magic, foreign version, length
//! mismatch, checksum mismatch, or internally inconsistent section is an
//! error, never a partial result. The store layer treats *any* decode error
//! as an eviction: the artifact is deleted and the workload recomputed.
//!
//! Workload payload sections, in order: `rows`, `cols`, `rows_b`, `nnz_a`,
//! `nnz_b`, `out_nnz`, `total_products` (u64 each), `checksum` (f64 bits),
//! the operand-format plan (format tag byte, then `a_words`, `b_words`,
//! `c_words`, `gather_words`, `convert_read_words`, `convert_write_words`,
//! `convert_cycles` as u64 each), `profile count` (u64, must equal
//! `rows`), then one 16-byte record per row profile (`a_nnz` u32,
//! `products` u64, `out_nnz` u32). The summed per-row `out_nnz`/`products`
//! must reproduce the header totals, and a CSR plan must reproduce the
//! closed-form CSR word counts for the stored totals.
//!
//! CSR payload sections: `rows`, `cols`, `nnz` (u64 each), `row_ptr`
//! ((rows+1) × u64), `col_id` (nnz × u32), `value` (nnz × f32 bits). The
//! decoded parts are re-validated through [`Csr::try_new`], so a decoded
//! matrix upholds every CSR invariant the rest of the crate assumes.

use crate::coordinator::Policy;
use crate::energy::EnergyBreakdown;
use crate::pe::RowProfile;
use crate::sim::des::{DesPeStats, DesResult};
use crate::sim::engine::{coords_for, intern_dim_name, AxisDim, CellModel, CellResult, WorkloadKey};
use crate::sim::explore::{EvalJournal, EvalRecord, TIER_ESTIMATE};
use crate::sim::shard::{ShardMeta, ShardSpec, SweepShard};
use crate::sim::{SimResult, TilePartial, Workload};
use crate::sparse::{Csr, FormatPlan, SparseFormat};
use crate::trace::Counters;

/// Bump on any layout change: old artifacts are rejected (and evicted) on
/// load, and the store's file names change so caches start cold. CI keys
/// its `actions/cache` entry on this file's hash (plus the profile-pass
/// and generator sources, whose changes alter artifact contents without a
/// layout change) for the same reason.
///
/// v2: the profile pass drains its SPA in ascending column order (the
/// canonical order the tiled merge replays), which changes every stored
/// workload's checksum bits — a semantic change, so old artifacts must be
/// evicted, not reinterpreted.
///
/// v3: workload artifacts carry the operand-format plan
/// ([`crate::sparse::FormatPlan`]) — pre-format artifacts have no plan
/// section and must be evicted, not defaulted, or a warm sweep under a
/// `fmt` axis would silently alias every format to CSR.
pub const CODEC_VERSION: u32 = 3;

pub(crate) const MAGIC_CSR: [u8; 8] = *b"MAPLECSR";
const MAGIC_WORKLOAD: [u8; 8] = *b"MAPLEWL\0";
const MAGIC_SHARD: [u8; 8] = *b"MAPLESHD";
const MAGIC_EVALS: [u8; 8] = *b"MAPLEEVL";
const MAGIC_TILE: [u8; 8] = *b"MAPLETIL";
/// Row-group container header magic — the container's per-group blocks are
/// ordinary [`MAGIC_CSR`] envelopes (see [`crate::sparse::io`]).
pub(crate) const MAGIC_RGS: [u8; 8] = *b"MAPLERGS";
pub(crate) const HEADER_LEN: usize = 28;

/// Codec errors. Every variant means "do not trust this artifact".
#[derive(Debug, thiserror::Error)]
pub enum CodecError {
    #[error("bad magic: not a maple cache artifact")]
    BadMagic,
    #[error("codec version {found} != supported {expected}")]
    VersionMismatch { found: u32, expected: u32 },
    #[error("artifact truncated: need {needed} bytes, have {have}")]
    Truncated { needed: usize, have: usize },
    #[error("payload checksum mismatch: stored {stored:#018x}, computed {computed:#018x}")]
    ChecksumMismatch { stored: u64, computed: u64 },
    #[error("inconsistent artifact: {0}")]
    Inconsistent(String),
}

/// FNV-1a 64 — the crate's standard dependency-free hash (same constants as
/// the dataset-seed hash in `sparse::suite`). Also used by the store for
/// collision-proofing file names.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

// ---------------------------------------------------------------- encoding

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Wrap a payload in the versioned, checksummed envelope. Crate-visible:
/// the row-group container ([`crate::sparse::io`]) seals its header and
/// per-group blocks with the same envelope.
pub(crate) fn seal(magic: [u8; 8], payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&magic);
    put_u32(&mut out, CODEC_VERSION);
    put_u64(&mut out, payload.len() as u64);
    put_u64(&mut out, fnv1a(payload));
    out.extend_from_slice(payload);
    out
}

/// Encode a CSR matrix.
pub fn encode_csr(a: &Csr) -> Vec<u8> {
    let mut p = Vec::with_capacity(24 + (a.rows() + 1) * 8 + a.nnz() * 8);
    put_u64(&mut p, a.rows() as u64);
    put_u64(&mut p, a.cols() as u64);
    put_u64(&mut p, a.nnz() as u64);
    for &r in &a.row_ptr {
        put_u64(&mut p, r as u64);
    }
    for &c in &a.col_id {
        put_u32(&mut p, c);
    }
    for &v in &a.value {
        put_u32(&mut p, v.to_bits());
    }
    seal(MAGIC_CSR, &p)
}

/// Encode a profiled workload.
pub fn encode_workload(w: &Workload) -> Vec<u8> {
    let mut p = Vec::with_capacity(137 + w.profiles.len() * 16);
    put_u64(&mut p, w.rows as u64);
    put_u64(&mut p, w.cols as u64);
    put_u64(&mut p, w.rows_b as u64);
    put_u64(&mut p, w.nnz_a);
    put_u64(&mut p, w.nnz_b);
    put_u64(&mut p, w.out_nnz);
    put_u64(&mut p, w.total_products);
    put_u64(&mut p, w.checksum.to_bits());
    p.push(w.fmt.format.tag());
    put_u64(&mut p, w.fmt.a_words);
    put_u64(&mut p, w.fmt.b_words);
    put_u64(&mut p, w.fmt.c_words);
    put_u64(&mut p, w.fmt.gather_words);
    put_u64(&mut p, w.fmt.convert_read_words);
    put_u64(&mut p, w.fmt.convert_write_words);
    put_u64(&mut p, w.fmt.convert_cycles);
    put_u64(&mut p, w.profiles.len() as u64);
    for r in &w.profiles {
        put_u32(&mut p, r.a_nnz);
        put_u64(&mut p, r.products);
        put_u32(&mut p, r.out_nnz);
    }
    seal(MAGIC_WORKLOAD, &p)
}

/// Length-prefixed string section. Crate-visible: the design-space
/// fingerprint ([`crate::sim::engine::DesignSpace::fingerprint`]) reuses
/// the same framing, so hash layout and codec layout stay defined here.
pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

/// Stable on-disk policy tags (the `Debug` spelling is for humans only).
/// Crate-visible: the sweep-service wire protocol ([`crate::sim::service`])
/// ships policies with the same tags.
pub(crate) fn policy_tag(p: Policy) -> u32 {
    match p {
        Policy::RoundRobin => 0,
        Policy::Chunked => 1,
        Policy::GreedyBalance => 2,
    }
}

pub(crate) fn policy_from_tag(tag: u32) -> Option<Policy> {
    match tag {
        0 => Some(Policy::RoundRobin),
        1 => Some(Policy::Chunked),
        2 => Some(Policy::GreedyBalance),
        _ => None,
    }
}

/// [`Counters`] fields in their declared order — encode and decode walk
/// this same list, so the layout cannot drift between the two.
fn counters_fields(c: &Counters) -> [u64; 21] {
    [
        c.mac_mul,
        c.mac_add,
        c.intersect_cmp,
        c.cd_elems,
        c.arb_read,
        c.arb_write,
        c.brb_read,
        c.brb_write,
        c.psb_read,
        c.psb_write,
        c.queue_read,
        c.queue_write,
        c.peb_read,
        c.peb_write,
        c.l1_read,
        c.l1_write,
        c.pob_read,
        c.pob_write,
        c.dram_read,
        c.dram_write,
        c.noc_flit_hops,
    ]
}

/// [`EnergyBreakdown`] fields in their declared order (see
/// [`counters_fields`]).
fn energy_fields(e: &EnergyBreakdown) -> [f64; 8] {
    [
        e.mac_pj,
        e.intersect_pj,
        e.cd_pj,
        e.l0_pj,
        e.pe_buffer_pj,
        e.l1_pj,
        e.dram_pj,
        e.noc_pj,
    ]
}

fn put_sim_result(buf: &mut Vec<u8>, r: &SimResult) {
    put_str(buf, &r.config);
    put_u64(buf, r.cycles_compute);
    put_u64(buf, r.cycles_dram_bound);
    put_u64(buf, r.cycles);
    for v in counters_fields(&r.counters) {
        put_u64(buf, v);
    }
    for v in energy_fields(&r.energy) {
        put_f64(buf, v);
    }
    put_u64(buf, r.out_nnz);
    put_f64(buf, r.checksum);
    put_u64(buf, r.total_products);
    put_f64(buf, r.balance);
}

/// Encode one sweep shard (see [`crate::sim::shard`]): full grid metadata
/// plus the contiguous cell range this shard computed. Cell coordinates
/// are *not* stored — they are a pure function of the grid dimensions and
/// the flat index, and [`decode_shard`] recomputes them.
pub fn encode_shard(s: &SweepShard) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, s.fingerprint);
    put_u64(&mut p, s.spec.index as u64);
    put_u64(&mut p, s.spec.count as u64);
    put_u64(&mut p, s.start as u64);
    put_u32(&mut p, s.cell_model.tag() as u32);
    put_u64(&mut p, s.meta.wall_ms);
    put_u64(&mut p, s.meta.profiles_run);
    put_u64(&mut p, s.meta.disk_hits);
    put_u64(&mut p, s.meta.profile_threads as u64);
    put_u64(&mut p, s.dims.len() as u64);
    for d in &s.dims {
        put_str(&mut p, d.name);
        put_u64(&mut p, d.labels.len() as u64);
        for l in &d.labels {
            put_str(&mut p, l);
        }
    }
    put_u64(&mut p, s.datasets.len() as u64);
    for k in &s.datasets {
        put_str(&mut p, &k.dataset);
        put_u64(&mut p, k.seed);
        put_u64(&mut p, k.scale as u64);
    }
    put_u64(&mut p, s.configs.len() as u64);
    for c in &s.configs {
        put_str(&mut p, c);
    }
    put_u64(&mut p, s.policies.len() as u64);
    for &pol in &s.policies {
        put_u32(&mut p, policy_tag(pol));
    }
    put_u64(&mut p, s.cells.len() as u64);
    for cell in &s.cells {
        put_sim_result(&mut p, &cell.analytic);
        match &cell.des {
            Some(d) => {
                p.push(1);
                put_u64(&mut p, d.cycles);
                put_u64(&mut p, d.dram_transactions);
                put_f64(&mut p, d.pe_utilisation);
                put_u64(&mut p, d.per_pe.len() as u64);
                for pe in &d.per_pe {
                    put_u64(&mut p, pe.rows);
                    put_u64(&mut p, pe.front_busy_cycles);
                    put_u64(&mut p, pe.back_busy_cycles);
                    put_u64(&mut p, pe.finish);
                }
            }
            None => p.push(0),
        }
    }
    seal(MAGIC_SHARD, &p)
}

/// Encode an explore eval journal ([`crate::sim::explore::EvalJournal`]):
/// the design-space fingerprint + evaluator-tier key, then one 24-byte
/// record per evaluated flat grid index. `BTreeMap` iteration makes the
/// encoding canonical — equal journals are byte-identical artifacts.
pub fn encode_evals(j: &EvalJournal) -> Vec<u8> {
    let mut p = Vec::with_capacity(33 + j.entries.len() * 24);
    put_u64(&mut p, j.fingerprint);
    p.push(j.tier);
    put_u64(&mut p, j.sample_budget);
    put_u64(&mut p, j.sample_seed);
    put_u64(&mut p, j.entries.len() as u64);
    for (&idx, rec) in &j.entries {
        put_u64(&mut p, idx);
        put_u64(&mut p, rec.cycles);
        put_f64(&mut p, rec.energy_pj);
    }
    seal(MAGIC_EVALS, &p)
}

/// Decode an eval journal, rejecting unknown tiers, out-of-order or
/// duplicate indices, and non-finite energies.
pub fn decode_evals(bytes: &[u8]) -> Result<EvalJournal, CodecError> {
    let mut r = open(MAGIC_EVALS, bytes)?;
    let fingerprint = r.u64()?;
    let tier = r.byte()?;
    if tier > TIER_ESTIMATE {
        return Err(CodecError::Inconsistent(format!("unknown eval tier {tier}")));
    }
    let sample_budget = r.u64()?;
    let sample_seed = r.u64()?;
    let n = r.index()?;
    r.expect_items(n, 24)?;
    let mut entries = std::collections::BTreeMap::new();
    let mut last: Option<u64> = None;
    for _ in 0..n {
        let idx = r.u64()?;
        if last.is_some_and(|l| idx <= l) {
            return Err(CodecError::Inconsistent(format!(
                "eval indices not strictly increasing at {idx}"
            )));
        }
        last = Some(idx);
        let cycles = r.u64()?;
        let energy_pj = r.f64()?;
        if !energy_pj.is_finite() {
            return Err(CodecError::Inconsistent(format!(
                "non-finite energy for eval index {idx}"
            )));
        }
        entries.insert(idx, EvalRecord { cycles, energy_pj });
    }
    r.done()?;
    Ok(EvalJournal { fingerprint, tier, sample_budget, sample_seed, entries })
}

// ---------------------------------------------------------------- decoding

/// Bounds-checked little-endian reader over the payload section.
/// Crate-visible: the sweep-service wire protocol ([`crate::sim::service`])
/// decodes its message payloads through the same defensive reader.
pub(crate) struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Reader over raw payload bytes (no envelope; the caller has already
    /// verified framing and checksum).
    pub(crate) fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.bytes.len())
            .ok_or_else(|| CodecError::Truncated {
                needed: self.pos.saturating_add(n),
                have: self.bytes.len(),
            })?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    pub(crate) fn index(&mut self) -> Result<usize, CodecError> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| CodecError::Inconsistent(format!("index {v} overflows usize")))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn byte(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    /// Length-prefixed UTF-8 string.
    pub(crate) fn string(&mut self) -> Result<String, CodecError> {
        let n = self.index()?;
        self.expect_items(n, 1)?;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| CodecError::Inconsistent("non-UTF-8 string".into()))
    }

    /// Guard for count-prefixed sections: the claimed item count must fit
    /// in the remaining payload bytes. The envelope checksum only proves
    /// the payload matches its own stored hash — not that the counts are
    /// honest — so a crafted or foreign file must be a decode error here,
    /// never an over-allocation.
    pub(crate) fn expect_items(&self, items: usize, bytes_per: usize) -> Result<(), CodecError> {
        let needed = items
            .checked_mul(bytes_per)
            .and_then(|n| n.checked_add(self.pos))
            .ok_or_else(|| {
                CodecError::Inconsistent(format!("section of {items} items overflows usize"))
            })?;
        if needed > self.bytes.len() {
            return Err(CodecError::Truncated { needed, have: self.bytes.len() });
        }
        Ok(())
    }

    pub(crate) fn done(&self) -> Result<(), CodecError> {
        if self.pos == self.bytes.len() {
            Ok(())
        } else {
            Err(CodecError::Inconsistent(format!(
                "{} trailing payload bytes",
                self.bytes.len() - self.pos
            )))
        }
    }
}

/// The payload length a sealed envelope's first [`HEADER_LEN`] bytes
/// declare — what a streaming reader ([`crate::sparse::io`]'s container)
/// needs to know how many more bytes to pull before [`open`] can validate
/// the whole block. Magic and version are checked here too, so a foreign
/// file fails before any large read is sized from its length field.
pub(crate) fn sealed_payload_len(magic: [u8; 8], header: &[u8]) -> Result<usize, CodecError> {
    if header.len() < HEADER_LEN {
        return Err(CodecError::Truncated { needed: HEADER_LEN, have: header.len() });
    }
    if header[..8] != magic {
        return Err(CodecError::BadMagic);
    }
    let version = u32::from_le_bytes(header[8..12].try_into().expect("4-byte slice"));
    if version != CODEC_VERSION {
        return Err(CodecError::VersionMismatch { found: version, expected: CODEC_VERSION });
    }
    let len = u64::from_le_bytes(header[12..20].try_into().expect("8-byte slice"));
    usize::try_from(len)
        .map_err(|_| CodecError::Inconsistent(format!("payload length {len} overflows usize")))
}

/// Validate the envelope and return a reader positioned at the payload.
/// Crate-visible: see [`seal`].
pub(crate) fn open(magic: [u8; 8], bytes: &[u8]) -> Result<Reader<'_>, CodecError> {
    if bytes.len() < HEADER_LEN {
        return Err(CodecError::Truncated { needed: HEADER_LEN, have: bytes.len() });
    }
    if bytes[..8] != magic {
        return Err(CodecError::BadMagic);
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4-byte slice"));
    if version != CODEC_VERSION {
        return Err(CodecError::VersionMismatch { found: version, expected: CODEC_VERSION });
    }
    let len = u64::from_le_bytes(bytes[12..20].try_into().expect("8-byte slice"));
    let len = usize::try_from(len)
        .map_err(|_| CodecError::Inconsistent(format!("payload length {len} overflows usize")))?;
    let total = HEADER_LEN
        .checked_add(len)
        .ok_or_else(|| CodecError::Inconsistent(format!("payload length {len} overflows usize")))?;
    if bytes.len() < total {
        return Err(CodecError::Truncated { needed: total, have: bytes.len() });
    }
    if bytes.len() > total {
        return Err(CodecError::Inconsistent(format!(
            "{} trailing bytes after payload",
            bytes.len() - total
        )));
    }
    let stored = u64::from_le_bytes(bytes[20..28].try_into().expect("8-byte slice"));
    let computed = fnv1a(&bytes[HEADER_LEN..]);
    if stored != computed {
        return Err(CodecError::ChecksumMismatch { stored, computed });
    }
    Ok(Reader { bytes: &bytes[HEADER_LEN..], pos: 0 })
}

/// Decode a CSR matrix, re-validating every CSR invariant.
pub fn decode_csr(bytes: &[u8]) -> Result<Csr, CodecError> {
    let mut r = open(MAGIC_CSR, bytes)?;
    let rows = r.index()?;
    let cols = r.index()?;
    let nnz = r.index()?;
    let ptr_len = rows
        .checked_add(1)
        .ok_or_else(|| CodecError::Inconsistent("row count overflows usize".into()))?;
    r.expect_items(ptr_len, 8)?;
    let mut row_ptr = Vec::with_capacity(ptr_len);
    for _ in 0..ptr_len {
        row_ptr.push(r.index()?);
    }
    r.expect_items(nnz, 4)?;
    let mut col_id = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        col_id.push(r.u32()?);
    }
    r.expect_items(nnz, 4)?;
    let mut value = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        value.push(f32::from_bits(r.u32()?));
    }
    r.done()?;
    Csr::try_new(rows, cols, row_ptr, col_id, value).map_err(CodecError::Inconsistent)
}

/// Decode a profiled workload, cross-checking the per-row profiles against
/// the stored totals.
pub fn decode_workload(bytes: &[u8]) -> Result<Workload, CodecError> {
    let mut r = open(MAGIC_WORKLOAD, bytes)?;
    let rows = r.index()?;
    let cols = r.index()?;
    let rows_b = r.index()?;
    let nnz_a = r.u64()?;
    let nnz_b = r.u64()?;
    let out_nnz = r.u64()?;
    let total_products = r.u64()?;
    let checksum = f64::from_bits(r.u64()?);
    let tag = r.byte()?;
    let format = SparseFormat::from_tag(tag)
        .ok_or_else(|| CodecError::Inconsistent(format!("unknown format tag {tag}")))?;
    let fmt = FormatPlan {
        format,
        a_words: r.u64()?,
        b_words: r.u64()?,
        c_words: r.u64()?,
        gather_words: r.u64()?,
        convert_read_words: r.u64()?,
        convert_write_words: r.u64()?,
        convert_cycles: r.u64()?,
    };
    // The CSR plan is a pure function of the totals — a stored plan that
    // disagrees is corrupt, not merely stale.
    if format == SparseFormat::Csr && fmt != FormatPlan::csr(rows, rows_b, nnz_a, nnz_b, out_nnz) {
        return Err(CodecError::Inconsistent(
            "CSR format plan disagrees with the workload totals".into(),
        ));
    }
    let n_profiles = r.index()?;
    if n_profiles != rows {
        return Err(CodecError::Inconsistent(format!(
            "profile count {n_profiles} != rows {rows}"
        )));
    }
    r.expect_items(n_profiles, 16)?;
    let mut profiles = Vec::with_capacity(n_profiles);
    let (mut sum_out, mut sum_products) = (0u64, 0u64);
    for _ in 0..n_profiles {
        let p = RowProfile { a_nnz: r.u32()?, products: r.u64()?, out_nnz: r.u32()? };
        sum_out += p.out_nnz as u64;
        sum_products += p.products;
        profiles.push(p);
    }
    r.done()?;
    if sum_out != out_nnz {
        return Err(CodecError::Inconsistent(format!(
            "profile out_nnz sum {sum_out} != stored total {out_nnz}"
        )));
    }
    if sum_products != total_products {
        return Err(CodecError::Inconsistent(format!(
            "profile product sum {sum_products} != stored total {total_products}"
        )));
    }
    Ok(Workload {
        rows,
        cols,
        rows_b,
        nnz_a,
        nnz_b,
        out_nnz,
        total_products,
        profiles,
        checksum,
        fmt,
    })
}

/// Encode one tiled-profile block partial. Payload sections, in order:
/// `row_lo`, `row_hi`, `col_lo`, `col_hi` (u64 each), `out_vals` count
/// (u64), then per row in `[row_lo, row_hi)` its `products` (u64) and
/// `out_count` (u32), then the f32 bit patterns of `out_vals`. The row
/// count is implied by the bounds; [`decode_tile_partial`] cross-checks
/// that the out counts sum to the value count.
pub fn encode_tile_partial(p: &TilePartial) -> Vec<u8> {
    let rows = p.rows();
    let mut buf = Vec::with_capacity(40 + rows * 12 + p.out_vals.len() * 4);
    put_u64(&mut buf, p.row_lo as u64);
    put_u64(&mut buf, p.row_hi as u64);
    put_u64(&mut buf, p.col_lo as u64);
    put_u64(&mut buf, p.col_hi as u64);
    put_u64(&mut buf, p.out_vals.len() as u64);
    for i in 0..rows {
        put_u64(&mut buf, p.products[i]);
        put_u32(&mut buf, p.out_counts[i]);
    }
    for &v in &p.out_vals {
        put_u32(&mut buf, v.to_bits());
    }
    seal(MAGIC_TILE, &buf)
}

/// Decode a tiled-profile block partial, cross-checking the block bounds
/// and the out-count / value-count agreement.
pub fn decode_tile_partial(bytes: &[u8]) -> Result<TilePartial, CodecError> {
    let mut r = open(MAGIC_TILE, bytes)?;
    let row_lo = r.index()?;
    let row_hi = r.index()?;
    let col_lo = r.index()?;
    let col_hi = r.index()?;
    if row_hi < row_lo || col_hi < col_lo {
        return Err(CodecError::Inconsistent(format!(
            "inverted block bounds r{row_lo}..{row_hi} c{col_lo}..{col_hi}"
        )));
    }
    let rows = row_hi - row_lo;
    let n_vals = r.index()?;
    r.expect_items(rows, 12)?;
    let mut products = Vec::with_capacity(rows);
    let mut out_counts = Vec::with_capacity(rows);
    let mut sum_out = 0u64;
    for _ in 0..rows {
        products.push(r.u64()?);
        let c = r.u32()?;
        sum_out += c as u64;
        out_counts.push(c);
    }
    if sum_out != n_vals as u64 {
        return Err(CodecError::Inconsistent(format!(
            "out counts sum to {sum_out} but {n_vals} values are stored"
        )));
    }
    r.expect_items(n_vals, 4)?;
    let mut out_vals = Vec::with_capacity(n_vals);
    for _ in 0..n_vals {
        out_vals.push(f32::from_bits(r.u32()?));
    }
    r.done()?;
    Ok(TilePartial { row_lo, row_hi, col_lo, col_hi, products, out_counts, out_vals })
}

fn read_sim_result(r: &mut Reader<'_>) -> Result<SimResult, CodecError> {
    let config = r.string()?;
    let cycles_compute = r.u64()?;
    let cycles_dram_bound = r.u64()?;
    let cycles = r.u64()?;
    // Struct literals evaluate fields in source order, so this walks the
    // payload exactly as `counters_fields` wrote it.
    let counters = Counters {
        mac_mul: r.u64()?,
        mac_add: r.u64()?,
        intersect_cmp: r.u64()?,
        cd_elems: r.u64()?,
        arb_read: r.u64()?,
        arb_write: r.u64()?,
        brb_read: r.u64()?,
        brb_write: r.u64()?,
        psb_read: r.u64()?,
        psb_write: r.u64()?,
        queue_read: r.u64()?,
        queue_write: r.u64()?,
        peb_read: r.u64()?,
        peb_write: r.u64()?,
        l1_read: r.u64()?,
        l1_write: r.u64()?,
        pob_read: r.u64()?,
        pob_write: r.u64()?,
        dram_read: r.u64()?,
        dram_write: r.u64()?,
        noc_flit_hops: r.u64()?,
    };
    let energy = EnergyBreakdown {
        mac_pj: r.f64()?,
        intersect_pj: r.f64()?,
        cd_pj: r.f64()?,
        l0_pj: r.f64()?,
        pe_buffer_pj: r.f64()?,
        l1_pj: r.f64()?,
        dram_pj: r.f64()?,
        noc_pj: r.f64()?,
    };
    Ok(SimResult {
        config,
        cycles_compute,
        cycles_dram_bound,
        cycles,
        counters,
        energy,
        out_nnz: r.u64()?,
        checksum: r.f64()?,
        total_products: r.u64()?,
        balance: r.f64()?,
    })
}

/// Decode a sweep shard, cross-checking every structural invariant: valid
/// shard spec, known dimension names, a cell range inside the grid, and
/// grid metadata that agrees with the dimensions. Cell coordinates are
/// recomputed from the dimensions and the flat index (see
/// [`encode_shard`]).
pub fn decode_shard(bytes: &[u8]) -> Result<SweepShard, CodecError> {
    let mut r = open(MAGIC_SHARD, bytes)?;
    let fingerprint = r.u64()?;
    let index = r.index()?;
    let count = r.index()?;
    if count == 0 || index >= count {
        return Err(CodecError::Inconsistent(format!("shard index {index} not < count {count}")));
    }
    let start = r.index()?;
    let model_tag = r.u32()?;
    let cell_model = CellModel::from_tag(model_tag)
        .ok_or_else(|| CodecError::Inconsistent(format!("unknown cell-model tag {model_tag}")))?;
    let meta = ShardMeta {
        wall_ms: r.u64()?,
        profiles_run: r.u64()?,
        disk_hits: r.u64()?,
        profile_threads: r.index()?,
    };

    let n_dims = r.index()?;
    r.expect_items(n_dims, 16)?;
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        let name = r.string()?;
        let name = intern_dim_name(&name)
            .ok_or_else(|| CodecError::Inconsistent(format!("unknown grid dimension {name:?}")))?;
        let n_labels = r.index()?;
        r.expect_items(n_labels, 8)?;
        let mut labels = Vec::with_capacity(n_labels);
        for _ in 0..n_labels {
            labels.push(r.string()?);
        }
        if labels.is_empty() {
            return Err(CodecError::Inconsistent(format!("empty grid dimension {name}")));
        }
        dims.push(AxisDim { name, labels });
    }
    if dims.is_empty() {
        return Err(CodecError::Inconsistent("shard has no grid dimensions".into()));
    }
    let total = dims
        .iter()
        .try_fold(1usize, |acc, d| acc.checked_mul(d.len()))
        .ok_or_else(|| CodecError::Inconsistent("grid size overflows usize".into()))?;

    let n_datasets = r.index()?;
    r.expect_items(n_datasets, 24)?;
    let mut datasets = Vec::with_capacity(n_datasets);
    for _ in 0..n_datasets {
        datasets.push(WorkloadKey {
            dataset: r.string()?,
            seed: r.u64()?,
            scale: r.index()?,
        });
    }
    let n_configs = r.index()?;
    r.expect_items(n_configs, 8)?;
    let mut configs = Vec::with_capacity(n_configs);
    for _ in 0..n_configs {
        configs.push(r.string()?);
    }
    let n_policies = r.index()?;
    r.expect_items(n_policies, 4)?;
    let mut policies = Vec::with_capacity(n_policies);
    for _ in 0..n_policies {
        let tag = r.u32()?;
        policies.push(policy_from_tag(tag).ok_or_else(|| {
            CodecError::Inconsistent(format!("unknown policy tag {tag}"))
        })?);
    }
    // The legacy flat-addressing invariant: dataset × expanded-config ×
    // policy must cover the grid exactly.
    if datasets
        .len()
        .checked_mul(configs.len())
        .and_then(|v| v.checked_mul(policies.len()))
        != Some(total)
    {
        return Err(CodecError::Inconsistent(format!(
            "metadata ({} datasets x {} configs x {} policies) disagrees with a grid of {total}",
            datasets.len(),
            configs.len(),
            policies.len()
        )));
    }

    let n_cells = r.index()?;
    r.expect_items(n_cells, 8)?;
    let end = start
        .checked_add(n_cells)
        .ok_or_else(|| CodecError::Inconsistent("cell range overflows usize".into()))?;
    if end > total {
        return Err(CodecError::Inconsistent(format!(
            "cell range {start}..{end} exceeds the {total}-cell grid"
        )));
    }
    let mut cells = Vec::with_capacity(n_cells);
    for i in 0..n_cells {
        let analytic = read_sim_result(&mut r)?;
        let des = match r.byte()? {
            0 => None,
            1 => {
                let cycles = r.u64()?;
                let dram_transactions = r.u64()?;
                let pe_utilisation = r.f64()?;
                let n_pes = r.index()?;
                r.expect_items(n_pes, 32)?;
                let mut per_pe = Vec::with_capacity(n_pes);
                for _ in 0..n_pes {
                    per_pe.push(DesPeStats {
                        rows: r.u64()?,
                        front_busy_cycles: r.u64()?,
                        back_busy_cycles: r.u64()?,
                        finish: r.u64()?,
                    });
                }
                Some(DesResult { cycles, dram_transactions, pe_utilisation, per_pe })
            }
            b => return Err(CodecError::Inconsistent(format!("bad DES presence flag {b}"))),
        };
        cells.push(CellResult { analytic, des, coords: coords_for(&dims, start + i) });
    }
    r.done()?;
    Ok(SweepShard {
        fingerprint,
        spec: ShardSpec { index, count },
        start,
        datasets,
        configs,
        policies,
        cell_model,
        dims,
        cells,
        meta,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile_workload;
    use crate::sparse::gen::{generate, Profile};

    fn sample_workload() -> Workload {
        let a = generate(40, 60, 300, Profile::PowerLaw { alpha: 0.7 }, 11);
        let b = generate(60, 25, 200, Profile::Uniform, 13);
        profile_workload(&a, &b)
    }

    #[test]
    fn csr_round_trips_bit_exact() {
        let a = generate(50, 30, 400, Profile::PowerLaw { alpha: 0.8 }, 3);
        assert_eq!(decode_csr(&encode_csr(&a)).unwrap(), a);
        let z = Csr::zero(7, 3);
        assert_eq!(decode_csr(&encode_csr(&z)).unwrap(), z);
    }

    #[test]
    fn workload_round_trips_bit_exact() {
        let w = sample_workload();
        let d = decode_workload(&encode_workload(&w)).unwrap();
        assert_eq!(d, w);
        assert_eq!(d.checksum.to_bits(), w.checksum.to_bits());
    }

    #[test]
    fn workload_format_plans_round_trip_and_are_validated() {
        // Non-CSR plans survive the round trip bit-exactly.
        let mut w = sample_workload();
        w.fmt = FormatPlan::from_totals(
            SparseFormat::Bitmap,
            w.rows,
            w.cols,
            w.rows_b,
            w.nnz_a,
            w.nnz_b,
            w.out_nnz,
        );
        let d = decode_workload(&encode_workload(&w)).unwrap();
        assert_eq!(d, w);
        // A CSR plan that disagrees with the stored totals is corrupt.
        let mut w = sample_workload();
        w.fmt.a_words += 1;
        assert!(matches!(
            decode_workload(&encode_workload(&w)),
            Err(CodecError::Inconsistent(_))
        ));
        // Unknown format tags are rejected (re-seal so the checksum holds).
        let sealed = encode_workload(&sample_workload());
        let mut payload = sealed[HEADER_LEN..].to_vec();
        payload[64] = 9; // the tag byte follows the eight u64 header fields
        assert!(matches!(
            decode_workload(&seal(MAGIC_WORKLOAD, &payload)),
            Err(CodecError::Inconsistent(_))
        ));
    }

    fn sample_partial() -> TilePartial {
        TilePartial {
            row_lo: 4,
            row_hi: 7,
            col_lo: 8,
            col_hi: 16,
            products: vec![5, 0, 9],
            out_counts: vec![2, 0, 3],
            out_vals: vec![1.5, -2.25, 0.75, 3.0, -0.5],
        }
    }

    #[test]
    fn tile_partial_round_trips_bit_exact() {
        let p = sample_partial();
        let d = decode_tile_partial(&encode_tile_partial(&p)).unwrap();
        assert_eq!(d, p);
        // Canonical encoding: re-encode is byte-identical.
        assert_eq!(encode_tile_partial(&d), encode_tile_partial(&p));
        // An empty block (no rows, no values) is a valid artifact.
        let empty = TilePartial {
            row_lo: 0,
            row_hi: 0,
            col_lo: 0,
            col_hi: 4,
            products: vec![],
            out_counts: vec![],
            out_vals: vec![],
        };
        assert_eq!(decode_tile_partial(&encode_tile_partial(&empty)).unwrap(), empty);
    }

    #[test]
    fn tile_partial_structural_lies_are_rejected() {
        // Out counts that disagree with the stored value count.
        let mut p = sample_partial();
        p.out_counts[0] = 7;
        assert!(matches!(
            decode_tile_partial(&encode_tile_partial(&p)),
            Err(CodecError::Inconsistent(_))
        ));
        // Wrong magic and truncations.
        assert!(matches!(
            decode_tile_partial(&encode_workload(&sample_workload())),
            Err(CodecError::BadMagic)
        ));
        let bytes = encode_tile_partial(&sample_partial());
        for cut in [0, 12, 28, bytes.len() - 1] {
            assert!(decode_tile_partial(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn sealed_payload_len_validates_the_prefix() {
        let bytes = encode_tile_partial(&sample_partial());
        let len = sealed_payload_len(MAGIC_TILE, &bytes[..HEADER_LEN]).unwrap();
        assert_eq!(HEADER_LEN + len, bytes.len());
        assert!(matches!(
            sealed_payload_len(MAGIC_CSR, &bytes[..HEADER_LEN]),
            Err(CodecError::BadMagic)
        ));
        assert!(matches!(
            sealed_payload_len(MAGIC_TILE, &bytes[..10]),
            Err(CodecError::Truncated { .. })
        ));
        let mut stale = bytes.clone();
        stale[8..12].copy_from_slice(&(CODEC_VERSION - 1).to_le_bytes());
        assert!(matches!(
            sealed_payload_len(MAGIC_TILE, &stale[..HEADER_LEN]),
            Err(CodecError::VersionMismatch { .. })
        ));
    }

    #[test]
    fn magic_and_kind_are_enforced() {
        let w = sample_workload();
        // A workload artifact is not a CSR artifact and vice versa.
        assert!(matches!(decode_csr(&encode_workload(&w)), Err(CodecError::BadMagic)));
        let a = generate(10, 10, 30, Profile::Uniform, 1);
        assert!(matches!(decode_workload(&encode_csr(&a)), Err(CodecError::BadMagic)));
        assert!(matches!(decode_workload(b"junk"), Err(CodecError::Truncated { .. })));
        assert!(matches!(decode_shard(&encode_workload(&w)), Err(CodecError::BadMagic)));
    }

    fn tiny_shard() -> SweepShard {
        let dims = vec![
            AxisDim { name: "dataset", labels: vec!["wv".into()] },
            AxisDim { name: "config", labels: vec!["c".into()] },
            AxisDim { name: "policy", labels: vec!["RoundRobin".into()] },
        ];
        let analytic = SimResult {
            config: "c".into(),
            cycles_compute: 5,
            cycles_dram_bound: 3,
            cycles: 5,
            counters: Counters { mac_mul: 2, dram_read: 9, ..Counters::default() },
            energy: EnergyBreakdown { mac_pj: 1.25, ..EnergyBreakdown::default() },
            out_nnz: 1,
            checksum: 1.5,
            total_products: 2,
            balance: 1.0,
        };
        let cells = vec![CellResult { analytic, des: None, coords: coords_for(&dims, 0) }];
        SweepShard {
            fingerprint: 42,
            spec: ShardSpec { index: 0, count: 1 },
            start: 0,
            datasets: vec![WorkloadKey::suite("wv", 7, 64)],
            configs: vec!["c".into()],
            policies: vec![Policy::RoundRobin],
            cell_model: CellModel::Analytic,
            dims,
            cells,
            meta: ShardMeta { wall_ms: 3, profiles_run: 1, disk_hits: 0, profile_threads: 1 },
        }
    }

    #[test]
    fn shard_round_trips_with_recomputed_coords() {
        let s = tiny_shard();
        let d = decode_shard(&encode_shard(&s)).unwrap();
        assert_eq!(d, s);
        // Coordinates were not stored — they were recomputed and still
        // match the original cell-for-cell (asserted via PartialEq above,
        // spot-checked here).
        assert_eq!(d.cells[0].coords[0].label, "wv");
        // Re-encoding the decoded shard is byte-identical.
        assert_eq!(encode_shard(&d), encode_shard(&s));
    }

    #[test]
    fn shard_structural_lies_are_rejected() {
        // Metadata that disagrees with the grid dimensions must not decode,
        // even though the envelope checksum is internally consistent.
        let mut s = tiny_shard();
        s.configs.push("phantom".into());
        assert!(matches!(
            decode_shard(&encode_shard(&s)),
            Err(CodecError::Inconsistent(_))
        ));
        let mut s = tiny_shard();
        s.start = 5; // range 5..6 of a 1-cell grid
        assert!(matches!(
            decode_shard(&encode_shard(&s)),
            Err(CodecError::Inconsistent(_))
        ));
        let mut s = tiny_shard();
        s.spec = ShardSpec { index: 3, count: 2 };
        assert!(matches!(
            decode_shard(&encode_shard(&s)),
            Err(CodecError::Inconsistent(_))
        ));
    }

    fn sample_journal() -> EvalJournal {
        let mut entries = std::collections::BTreeMap::new();
        entries.insert(3u64, EvalRecord { cycles: 120, energy_pj: 4.5 });
        entries.insert(17u64, EvalRecord { cycles: 90, energy_pj: 6.25 });
        entries.insert(200u64, EvalRecord { cycles: 77, energy_pj: 1.0 });
        EvalJournal {
            fingerprint: 0xDEAD_BEEF,
            tier: TIER_ESTIMATE,
            sample_budget: 128,
            sample_seed: 7,
            entries,
        }
    }

    #[test]
    fn evals_round_trip_bit_exact() {
        let j = sample_journal();
        let d = decode_evals(&encode_evals(&j)).unwrap();
        assert_eq!(d, j);
        // Canonical encoding: re-encode is byte-identical.
        assert_eq!(encode_evals(&d), encode_evals(&j));
        // Empty journals are valid artifacts too.
        let empty = EvalJournal::empty(9, 0, 0, 0);
        assert_eq!(decode_evals(&encode_evals(&empty)).unwrap(), empty);
    }

    #[test]
    fn evals_structural_lies_are_rejected() {
        // Unknown tier.
        let mut j = sample_journal();
        j.tier = 9;
        assert!(matches!(decode_evals(&encode_evals(&j)), Err(CodecError::Inconsistent(_))));
        // Non-finite energy.
        let mut j = sample_journal();
        j.entries.insert(5, EvalRecord { cycles: 1, energy_pj: f64::NAN });
        assert!(matches!(decode_evals(&encode_evals(&j)), Err(CodecError::Inconsistent(_))));
        // Wrong magic.
        assert!(matches!(
            decode_evals(&encode_workload(&sample_workload())),
            Err(CodecError::BadMagic)
        ));
        // Truncations.
        let bytes = encode_evals(&sample_journal());
        for cut in [0, 10, 28, bytes.len() - 1] {
            assert!(decode_evals(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn version_bump_is_rejected() {
        let mut bytes = encode_workload(&sample_workload());
        bytes[8..12].copy_from_slice(&(CODEC_VERSION + 1).to_le_bytes());
        assert!(matches!(
            decode_workload(&bytes),
            Err(CodecError::VersionMismatch { found, expected })
                if found == CODEC_VERSION + 1 && expected == CODEC_VERSION
        ));
    }

    #[test]
    fn every_truncation_is_rejected() {
        let bytes = encode_workload(&sample_workload());
        for cut in [0, 7, 12, 27, 28, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode_workload(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing junk is just as untrustworthy as missing bytes.
        let mut extended = bytes;
        extended.push(0);
        assert!(decode_workload(&extended).is_err());
    }

    #[test]
    fn huge_declared_counts_are_rejected_without_allocating() {
        // A checksum-consistent artifact whose counts lie about the payload
        // (crafted / foreign file in a shared cache dir) must be a decode
        // error, never an over-allocation.
        let mut p = Vec::new();
        put_u64(&mut p, 1u64 << 40); // rows — would be an 8 TB row_ptr
        put_u64(&mut p, 4);
        put_u64(&mut p, 0);
        assert!(matches!(
            decode_csr(&seal(MAGIC_CSR, &p)),
            Err(CodecError::Truncated { .. } | CodecError::Inconsistent(_))
        ));

        let mut p = Vec::new();
        for v in [3u64, 3, 3, 0, 0, 0, 0] {
            put_u64(&mut p, v); // rows..total_products
        }
        put_u64(&mut p, 0f64.to_bits());
        put_u64(&mut p, 3); // profile count == rows, but no records follow
        assert!(matches!(
            decode_workload(&seal(MAGIC_WORKLOAD, &p)),
            Err(CodecError::Truncated { .. })
        ));
    }

    #[test]
    fn single_byte_corruption_is_always_detected() {
        // FNV-1a steps are injective in the running state, so two
        // equal-length payloads differing in one byte can never collide;
        // header fields are compared directly. Flip every 5th byte.
        let clean = encode_workload(&sample_workload());
        for pos in (0..clean.len()).step_by(5) {
            let mut bad = clean.clone();
            bad[pos] ^= 0x40;
            assert!(decode_workload(&bad).is_err(), "flip at byte {pos} went undetected");
        }
    }
}
