//! The persistent workload cache: the engine's second tier.
//!
//! The profile pass — an exact functional execution of `C = A × B` — is the
//! wall-clock-dominant stage of every sweep (EXPERIMENTS.md §Perf), and it
//! is a pure function of the workload key. [`SimEngine`] therefore layers
//! two caches:
//!
//! 1. **In-memory slots** (per engine): each key profiled at most once per
//!    process, shared via `Arc`.
//! 2. **This module** (per machine): profiled workloads serialised through
//!    a versioned, checksummed binary [`codec`] into an on-disk [`store`],
//!    keyed by the canonical `(dataset, seed, scale)` [`WorkloadKey`] plus
//!    the profile chunk count. A disk hit skips *both* synthesis and
//!    profiling; a miss computes and then atomically publishes.
//!
//! The separation mirrors Sparseloop's thesis (analytical sparse-accelerator
//! models win by making evaluation cheap enough to sweep) and the
//! sparsity-aware-blocking practice of persisting one-time structure
//! analysis: repeated CLI runs, benches, CI jobs, and future sharded
//! multi-process sweeps all start warm.
//!
//! [`SimEngine`]: crate::sim::SimEngine
//! [`WorkloadKey`]: crate::sim::WorkloadKey

pub mod codec;
pub mod store;

pub use codec::{
    decode_csr, decode_shard, decode_tile_partial, decode_workload, encode_csr, encode_shard,
    encode_tile_partial, encode_workload, CodecError, CODEC_VERSION,
};
pub use store::{CacheStats, DiskCache, CACHE_DIR_ENV};
