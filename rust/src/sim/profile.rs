//! The profile pass: one exact functional execution of `C = A × B` that
//! produces every per-row quantity the accelerator cost models need
//! (paper Eq. 3's product counts, Eq. 7's distinct-`j'` counts), plus a
//! checksum for end-to-end numeric verification against the AOT-compiled
//! Pallas datapath (see `examples/verify_numerics.rs`).
//!
//! The pass uses a generation-tagged sparse accumulator and never
//! materialises C (the full output of `web-Google²` is ~0.5 GB), so
//! profiling all fourteen Table-I workloads stays fast and memory-flat.

use crate::pe::RowProfile;
use crate::sparse::Csr;

/// Everything a simulation needs to know about one `C = A × B` workload.
/// `PartialEq` compares every field bit-for-bit (profiles and the f64
/// checksum included) — the warm-equals-cold contract the disk cache
/// ([`crate::sim::cache`]) tests lean on.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Output rows (= rows of A).
    pub rows: usize,
    /// Output columns (= cols of B).
    pub cols: usize,
    /// Rows of B (= cols of A, the contraction dimension). Needed to size
    /// B's `row_ptr` stream for rectangular `A(m×k) × B(k×n)`.
    pub rows_b: usize,
    pub nnz_a: u64,
    pub nnz_b: u64,
    /// nnz of the result C.
    pub out_nnz: u64,
    /// Total scalar products (Gustavson work).
    pub total_products: u64,
    /// Per-output-row work profiles.
    pub profiles: Vec<RowProfile>,
    /// Σ C[i,j] in f64 — the numeric fingerprint of the run.
    pub checksum: f64,
}

impl Workload {
    /// Compression ratio `products / out_nnz` — how much accumulation the
    /// output needs (1.0 = no collisions).
    pub fn accumulation_factor(&self) -> f64 {
        if self.out_nnz == 0 {
            1.0
        } else {
            self.total_products as f64 / self.out_nnz as f64
        }
    }

    /// Compulsory DRAM traffic in 32-bit words: stream both operands' CSR
    /// images in and the result's out (value + col_id per nonzero, row_ptr
    /// per row). Both baseline and Maple configurations incur exactly this
    /// (see DESIGN.md §Modeling).
    pub fn compulsory_dram_words(&self) -> u64 {
        let a = 2 * self.nnz_a + self.rows as u64 + 1;
        let b = 2 * self.nnz_b + self.rows_b as u64 + 1;
        let c = 2 * self.out_nnz + self.rows as u64 + 1;
        a + b + c
    }
}

/// Parallel profile pass: row ranges are independent, so each worker runs
/// the serial pass over a chunk with its own SPA and the results
/// concatenate. Chunk boundaries are split on the **nnz prefix of A**
/// (see [`nnz_balanced_bounds`]), not the row count: Gustavson work per row
/// is proportional to its nnz, so row-count splitting degrades badly on
/// power-law workloads where a few heavy rows pile into one chunk.
/// Deterministic for a fixed `threads` (the bounds are a pure function of
/// `(row_ptr, threads)`; checksum addition is reassociated across — but not
/// within — chunk boundaries).
pub fn profile_workload_parallel(a: &Csr, b: &Csr, threads: usize) -> Workload {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let threads = threads.clamp(1, a.rows().max(1));
    if threads == 1 {
        return profile_workload(a, b);
    }
    let bounds = nnz_balanced_bounds(a, threads);
    let parts: Vec<(Vec<RowProfile>, u64, u64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = bounds
            .windows(2)
            .map(|w| {
                let (lo, hi) = (w[0], w[1]);
                scope.spawn(move || profile_rows(a, b, lo, hi))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("profile worker panicked")).collect()
    });
    let mut profiles = Vec::with_capacity(a.rows());
    let (mut out_nnz, mut total_products, mut checksum) = (0u64, 0u64, 0f64);
    for (p, o, tp, cs) in parts {
        profiles.extend(p);
        out_nnz += o;
        total_products += tp;
        checksum += cs;
    }
    Workload {
        rows: a.rows(),
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products,
        profiles,
        checksum,
    }
}

/// Chunk boundaries for the parallel profile pass, balanced on A's nnz
/// prefix — which is exactly `row_ptr`, so no extra pass is needed: chunk
/// `t` starts at the first row whose offset reaches `t·nnz/threads`. Every
/// chunk therefore carries at most `⌈nnz/threads⌉ + max_row_nnz` nonzeros,
/// no matter how skewed the row-length distribution is. Monotone, starts at
/// 0, ends at `rows` (chunks over trailing empty rows may be empty).
fn nnz_balanced_bounds(a: &Csr, threads: usize) -> Vec<usize> {
    let rows = a.rows();
    let nnz = a.nnz();
    let mut bounds = Vec::with_capacity(threads + 1);
    bounds.push(0usize);
    for t in 1..threads {
        let target = nnz as u128 * t as u128 / threads as u128;
        let cut = a.row_ptr.partition_point(|&p| (p as u128) < target).min(rows);
        let prev = *bounds.last().expect("bounds non-empty");
        bounds.push(cut.max(prev));
    }
    bounds.push(rows);
    bounds
}

/// Run the profile pass for `C = A × B`.
pub fn profile_workload(a: &Csr, b: &Csr) -> Workload {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let (profiles, out_nnz, total_products, checksum) = profile_rows(a, b, 0, a.rows());
    Workload {
        rows: a.rows(),
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products,
        profiles,
        checksum,
    }
}

/// Serial profile over the row range `[lo, hi)` (the parallel pass's unit).
fn profile_rows(a: &Csr, b: &Csr, lo: usize, hi: usize) -> (Vec<RowProfile>, u64, u64, f64) {
    let cols = b.cols();
    // Interleaved (tag, acc) cells: one cache line per SPA touch instead of
    // two (EXPERIMENTS.md §Perf iteration 2).
    let mut spa: Vec<(u32, f32)> = vec![(0u32, 0f32); cols];
    let mut touched: Vec<u32> = Vec::with_capacity(1024);
    let mut generation = 0u32;

    let mut profiles = Vec::with_capacity(hi - lo);
    let mut out_nnz = 0u64;
    let mut total_products = 0u64;
    let mut checksum = 0f64;

    for i in lo..hi {
        generation = generation.wrapping_add(1);
        if generation == 0 {
            spa.fill((0, 0.0));
            generation = 1;
        }
        touched.clear();
        let mut products = 0u64;
        for (k, av) in a.row_iter(i) {
            let k = k as usize;
            let bc = b.row_cols(k);
            let bv = b.row_values(k);
            products += bc.len() as u64;
            // Hot loop: bc/bv are equal-length row slices and every col_id
            // is < cols by the CSR invariant (Csr::try_new), so unchecked
            // indexing is sound. This is the single hottest loop in the
            // framework (EXPERIMENTS.md §Perf).
            for p in 0..bc.len() {
                // SAFETY: p < bc.len() == bv.len(); col ids validated < cols.
                let (j, v) = unsafe { (*bc.get_unchecked(p), *bv.get_unchecked(p)) };
                let prod = av * v;
                let cell = unsafe { spa.get_unchecked_mut(j as usize) };
                if cell.0 == generation {
                    cell.1 += prod;
                } else {
                    *cell = (generation, prod);
                    touched.push(j);
                }
            }
        }
        for &j in &touched {
            // SAFETY: every j in `touched` was bounds-validated (< cols)
            // when the lane loop pushed it, so the drain can skip the
            // bounds check too.
            checksum += unsafe { spa.get_unchecked(j as usize) }.1 as f64;
        }
        out_nnz += touched.len() as u64;
        total_products += products;
        profiles.push(RowProfile {
            a_nnz: a.row_nnz(i) as u32,
            products,
            out_nnz: touched.len() as u32,
        });
    }

    (profiles, out_nnz, total_products, checksum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gustavson::{multiply_count, spgemm_rowwise};
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn profile_matches_reference_spgemm() {
        let a = generate(60, 60, 300, Profile::PowerLaw { alpha: 0.7 }, 3);
        let w = profile_workload(&a, &a);
        let c = spgemm_rowwise(&a, &a);
        assert_eq!(w.out_nnz, c.nnz() as u64);
        assert_eq!(w.total_products, multiply_count(&a, &a));
        for i in 0..a.rows() {
            assert_eq!(w.profiles[i].out_nnz as usize, c.row_nnz(i), "row {i}");
            assert_eq!(w.profiles[i].a_nnz as usize, a.row_nnz(i));
        }
        let direct: f64 = c.value.iter().map(|&v| v as f64).sum();
        assert!((w.checksum - direct).abs() < 1e-3 * direct.abs().max(1.0));
    }

    #[test]
    fn identity_workload_profile() {
        let a = generate(20, 20, 60, Profile::Uniform, 8);
        let i = crate::sparse::Csr::identity(20);
        let w = profile_workload(&a, &i);
        assert_eq!(w.out_nnz, a.nnz() as u64);
        assert_eq!(w.total_products, a.nnz() as u64);
        assert!((w.accumulation_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compulsory_words_formula() {
        let a = generate(10, 10, 20, Profile::Uniform, 2);
        let w = profile_workload(&a, &a);
        let expect = (2 * 20 + 11) + (2 * 20 + 11) + (2 * w.out_nnz + 11);
        assert_eq!(w.compulsory_dram_words(), expect);
    }

    #[test]
    fn parallel_profile_matches_serial() {
        let a = generate(500, 500, 5000, Profile::PowerLaw { alpha: 0.7 }, 19);
        let serial = profile_workload(&a, &a);
        for threads in [1, 2, 4, 7] {
            let par = profile_workload_parallel(&a, &a, threads);
            assert_eq!(par.profiles, serial.profiles, "threads={threads}");
            assert_eq!(par.out_nnz, serial.out_nnz);
            assert_eq!(par.total_products, serial.total_products);
            // Checksum reassociates across chunks: equal within fp noise.
            assert!(
                (par.checksum - serial.checksum).abs() < 1e-6 * serial.checksum.abs().max(1.0),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn chunk_bounds_are_nnz_balanced_and_deterministic() {
        let a = generate(2000, 2000, 40_000, Profile::PowerLaw { alpha: 0.9 }, 5);
        let threads = 8;
        let bounds = nnz_balanced_bounds(&a, threads);
        assert_eq!(bounds, nnz_balanced_bounds(&a, threads), "bounds must be deterministic");
        assert_eq!(bounds.len(), threads + 1);
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), a.rows());
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds monotone: {bounds:?}");
        // The balance guarantee: no chunk exceeds its fair nnz share by more
        // than one (indivisible) row.
        let max_row = (0..a.rows()).map(|i| a.row_nnz(i)).max().unwrap();
        let fair = a.nnz().div_ceil(threads);
        for w in bounds.windows(2) {
            let chunk_nnz = a.row_ptr[w[1]] - a.row_ptr[w[0]];
            assert!(
                chunk_nnz <= fair + max_row,
                "chunk {w:?} holds {chunk_nnz} nnz (fair {fair}, max row {max_row})"
            );
        }
    }

    #[test]
    fn skewed_and_empty_rows_profile_identically_in_parallel() {
        // One very heavy row up front, a sea of empty rows, one trailing
        // nonzero: the worst case for row-count chunking and an edge case
        // for nnz-prefix cuts (all cuts land on the same boundary).
        let mut t: Vec<(u32, u32, f32)> = (0..400u32).map(|j| (0, j, 1.0 + j as f32)).collect();
        t.push((499, 3, 2.0));
        let a = Csr::from_triplets(500, 500, t);
        let serial = profile_workload(&a, &a);
        for threads in [2, 3, 8, 500] {
            let par = profile_workload_parallel(&a, &a, threads);
            assert_eq!(par.profiles, serial.profiles, "threads={threads}");
            assert_eq!(par.out_nnz, serial.out_nnz);
            assert_eq!(par.total_products, serial.total_products);
            assert!(
                (par.checksum - serial.checksum).abs()
                    < 1e-6 * serial.checksum.abs().max(1.0),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn rectangular_workload_dimensions_and_dram_words() {
        // A(30×50) × B(50×20): B's row_ptr stream is 51 words, not 31.
        let a = generate(30, 50, 200, Profile::Uniform, 5);
        let b = generate(50, 20, 180, Profile::Uniform, 9);
        let w = profile_workload(&a, &b);
        assert_eq!(w.rows, 30);
        assert_eq!(w.cols, 20);
        assert_eq!(w.rows_b, 50);
        let expect = (2 * w.nnz_a + 31) + (2 * w.nnz_b + 51) + (2 * w.out_nnz + 31);
        assert_eq!(w.compulsory_dram_words(), expect);
        // And the functional numbers agree with the reference SpGEMM.
        let c = spgemm_rowwise(&a, &b);
        assert_eq!(w.out_nnz, c.nnz() as u64);
        assert_eq!(w.total_products, multiply_count(&a, &b));
    }

    #[test]
    fn empty_matrix_profiles_cleanly() {
        let a = crate::sparse::Csr::zero(5, 5);
        let w = profile_workload(&a, &a);
        assert_eq!(w.out_nnz, 0);
        assert_eq!(w.total_products, 0);
        assert_eq!(w.checksum, 0.0);
        assert_eq!(w.profiles.len(), 5);
    }
}
