//! The profile pass: one exact functional execution of `C = A × B` that
//! produces every per-row quantity the accelerator cost models need
//! (paper Eq. 3's product counts, Eq. 7's distinct-`j'` counts), plus a
//! checksum for end-to-end numeric verification against the AOT-compiled
//! Pallas datapath (see `examples/verify_numerics.rs`).
//!
//! The pass uses a generation-tagged sparse accumulator and never
//! materialises C (the full output of `web-Google²` is ~0.5 GB), so
//! profiling all fourteen Table-I workloads stays fast and memory-flat.

use crate::pe::RowProfile;
use crate::sim::cache::DiskCache;
use crate::sparse::io::RowGroupFile;
use crate::sparse::tile::{self, TileShape};
use crate::sparse::{Csr, FormatPlan, SplitMix64};

/// Everything a simulation needs to know about one `C = A × B` workload.
/// `PartialEq` compares every field bit-for-bit (profiles and the f64
/// checksum included) — the warm-equals-cold contract the disk cache
/// ([`crate::sim::cache`]) tests lean on.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Output rows (= rows of A).
    pub rows: usize,
    /// Output columns (= cols of B).
    pub cols: usize,
    /// Rows of B (= cols of A, the contraction dimension). Needed to size
    /// B's `row_ptr` stream for rectangular `A(m×k) × B(k×n)`.
    pub rows_b: usize,
    pub nnz_a: u64,
    pub nnz_b: u64,
    /// nnz of the result C.
    pub out_nnz: u64,
    /// Total scalar products (Gustavson work).
    pub total_products: u64,
    /// Per-output-row work profiles.
    pub profiles: Vec<RowProfile>,
    /// Σ C[i,j] in f64 — the numeric fingerprint of the run.
    pub checksum: f64,
    /// The operand-format traffic plan. Every profile pass produces the
    /// native CSR plan; the engine derives non-CSR plans from it when a
    /// `fmt` axis point asks for one ([`crate::sim::SimEngine`]).
    pub fmt: FormatPlan,
}

impl Workload {
    /// Compression ratio `products / out_nnz` — how much accumulation the
    /// output needs (1.0 = no collisions).
    pub fn accumulation_factor(&self) -> f64 {
        if self.out_nnz == 0 {
            1.0
        } else {
            self.total_products as f64 / self.out_nnz as f64
        }
    }

    /// Compulsory DRAM traffic in 32-bit words under the workload's
    /// operand-format plan ([`FormatPlan::compulsory_dram_words`]). For the
    /// default CSR plan this is exactly the legacy formula — stream both
    /// operands' CSR images in and the result's out (value + col_id per
    /// nonzero, row_ptr per row); non-CSR plans add their gather and
    /// conversion terms on top (see DESIGN.md §Modeling).
    pub fn compulsory_dram_words(&self) -> u64 {
        self.fmt.compulsory_dram_words()
    }
}

/// Parallel profile pass: row ranges are independent, so each worker runs
/// the serial pass over a chunk with its own SPA and the results
/// concatenate. Chunk boundaries are split on the **nnz prefix of A**
/// (see [`nnz_balanced_bounds`]), not the row count: Gustavson work per row
/// is proportional to its nnz, so row-count splitting degrades badly on
/// power-law workloads where a few heavy rows pile into one chunk.
/// Deterministic for a fixed `threads` (the bounds are a pure function of
/// `(row_ptr, threads)`; checksum addition is reassociated across — but not
/// within — chunk boundaries).
pub fn profile_workload_parallel(a: &Csr, b: &Csr, threads: usize) -> Workload {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let threads = threads.clamp(1, a.rows().max(1));
    if threads == 1 {
        return profile_workload(a, b);
    }
    let bounds = nnz_balanced_bounds(a, threads);
    let parts: Vec<(Vec<RowProfile>, u64, u64, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = bounds
            .windows(2)
            .map(|w| {
                let (lo, hi) = (w[0], w[1]);
                scope.spawn(move || profile_rows(a, b, lo, hi))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("profile worker panicked")).collect()
    });
    let mut profiles = Vec::with_capacity(a.rows());
    let (mut out_nnz, mut total_products, mut checksum) = (0u64, 0u64, 0f64);
    for (p, o, tp, cs) in parts {
        profiles.extend(p);
        out_nnz += o;
        total_products += tp;
        checksum += cs;
    }
    Workload {
        rows: a.rows(),
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products,
        profiles,
        checksum,
        fmt: FormatPlan::csr(a.rows(), b.rows(), a.nnz() as u64, b.nnz() as u64, out_nnz),
    }
}

/// Chunk boundaries for the parallel profile pass, balanced on A's nnz
/// prefix — which is exactly `row_ptr`, so no extra pass is needed: chunk
/// `t` starts at the first row whose offset reaches `t·nnz/threads`. Every
/// chunk therefore carries at most `⌈nnz/threads⌉ + max_row_nnz` nonzeros,
/// no matter how skewed the row-length distribution is. Monotone, starts at
/// 0, ends at `rows` (chunks over trailing empty rows may be empty).
pub(crate) fn nnz_balanced_bounds(a: &Csr, threads: usize) -> Vec<usize> {
    let rows = a.rows();
    let nnz = a.nnz();
    let mut bounds = Vec::with_capacity(threads + 1);
    bounds.push(0usize);
    for t in 1..threads {
        let target = nnz as u128 * t as u128 / threads as u128;
        let cut = a.row_ptr.partition_point(|&p| (p as u128) < target).min(rows);
        let prev = *bounds.last().expect("bounds non-empty");
        bounds.push(cut.max(prev));
    }
    bounds.push(rows);
    bounds
}

/// Stratum cuts over a cumulative-mass prefix (`prefix[j]` = mass of the
/// first `j` ranks, so `prefix.len()` = ranks + 1): cut `t` is the first
/// rank whose prefix reaches `t·total/parts`. Monotone, starts at 0, ends
/// at the rank count — the sampled pass's analogue of
/// [`nnz_balanced_bounds`], over the product-sorted row order instead of
/// the raw row order.
fn mass_balanced_bounds(prefix: &[u64], parts: usize) -> Vec<usize> {
    let n = prefix.len() - 1;
    let total = prefix[n];
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0usize);
    for t in 1..parts {
        let target = total as u128 * t as u128 / parts as u128;
        let cut = prefix[..n].partition_point(|&p| (p as u128) < target).min(n);
        let prev = *bounds.last().expect("bounds non-empty");
        bounds.push(cut.max(prev));
    }
    bounds.push(n);
    bounds
}

/// Run the profile pass for `C = A × B`.
pub fn profile_workload(a: &Csr, b: &Csr) -> Workload {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let (profiles, out_nnz, total_products, checksum) = profile_rows(a, b, 0, a.rows());
    Workload {
        rows: a.rows(),
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products,
        profiles,
        checksum,
        fmt: FormatPlan::csr(a.rows(), b.rows(), a.nnz() as u64, b.nnz() as u64, out_nnz),
    }
}

/// Serial profile over the row range `[lo, hi)` (the parallel pass's unit).
fn profile_rows(a: &Csr, b: &Csr, lo: usize, hi: usize) -> (Vec<RowProfile>, u64, u64, f64) {
    let mut spa = Spa::new(b.cols());
    let mut profiles = Vec::with_capacity(hi - lo);
    let mut out_nnz = 0u64;
    let mut total_products = 0u64;
    let mut checksum = 0f64;

    for i in lo..hi {
        let p = spa.profile_row(a, b, i, &mut checksum);
        out_nnz += p.out_nnz as u64;
        total_products += p.products;
        profiles.push(p);
    }

    (profiles, out_nnz, total_products, checksum)
}

/// The generation-tagged sparse accumulator, reusable across rows. The
/// exact pass ([`profile_rows`]), the sampled pass
/// ([`profile_workload_sampled`]), and the tiled pass
/// ([`profile_workload_tiled`]) all run rows through this one
/// implementation, so a sampled or tiled row's profile is bit-identical to
/// the exact pass's — and the exact pass's checksum association order
/// (**ascending column order** within a row, row order across rows) is
/// preserved, which both the disk cache's warm-equals-cold contract and
/// the tiled merge's bit-identity proof lean on (see
/// [`Spa::accumulate_row`] for why the drain is sorted).
struct Spa {
    /// Interleaved (tag, acc) cells: one cache line per SPA touch instead
    /// of two (EXPERIMENTS.md §Perf iteration 2).
    cells: Vec<(u32, f32)>,
    touched: Vec<u32>,
    generation: u32,
}

impl Spa {
    fn new(cols: usize) -> Self {
        Self {
            cells: vec![(0u32, 0f32); cols],
            touched: Vec::with_capacity(1024),
            generation: 0,
        }
    }

    /// Accumulate output row `i` of `C = A × B` into the SPA cells, leaving
    /// `touched` holding the row's distinct output columns **sorted
    /// ascending**. Returns the row's scalar-product count.
    ///
    /// The sort canonicalises the drain order: every consumer folds the
    /// row's values in ascending column order, independent of the SPA touch
    /// sequence. That is what makes the tiled pass bit-identical to the
    /// serial one — a column tile restricts this loop to a contiguous
    /// column range without changing the `k` order or any per-cell f32
    /// accumulation order, so per-cell values are bit-equal, and
    /// concatenating the tiles' ascending drains in tile order replays the
    /// serial pass's ascending drain exactly (`profile_workload_tiled`).
    fn accumulate_row(&mut self, a: &Csr, b: &Csr, i: usize) -> u64 {
        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            self.cells.fill((0, 0.0));
            self.generation = 1;
        }
        let generation = self.generation;
        self.touched.clear();
        let mut products = 0u64;
        for (k, av) in a.row_iter(i) {
            let k = k as usize;
            let bc = b.row_cols(k);
            let bv = b.row_values(k);
            products += bc.len() as u64;
            // Hot loop: bc/bv are equal-length row slices and every col_id
            // is < cols by the CSR invariant (Csr::try_new), so unchecked
            // indexing is sound. This is the single hottest loop in the
            // framework (EXPERIMENTS.md §Perf).
            for p in 0..bc.len() {
                // SAFETY: p < bc.len() == bv.len(); col ids validated < cols.
                let (j, v) = unsafe { (*bc.get_unchecked(p), *bv.get_unchecked(p)) };
                let prod = av * v;
                let cell = unsafe { self.cells.get_unchecked_mut(j as usize) };
                if cell.0 == generation {
                    cell.1 += prod;
                } else {
                    *cell = (generation, prod);
                    self.touched.push(j);
                }
            }
        }
        self.touched.sort_unstable();
        products
    }

    /// Functionally execute output row `i` of `C = A × B`, adding the row's
    /// value sum onto `checksum` in ascending column order.
    fn profile_row(&mut self, a: &Csr, b: &Csr, i: usize, checksum: &mut f64) -> RowProfile {
        let products = self.accumulate_row(a, b, i);
        for &j in &self.touched {
            // SAFETY: every j in `touched` was bounds-validated (< cols)
            // when the lane loop pushed it, so the drain can skip the
            // bounds check too.
            *checksum += unsafe { self.cells.get_unchecked(j as usize) }.1 as f64;
        }
        RowProfile {
            a_nnz: a.row_nnz(i) as u32,
            products,
            out_nnz: self.touched.len() as u32,
        }
    }

    /// Like [`Spa::profile_row`], but drains the row's accumulated values
    /// into `out_vals` (ascending column order) instead of folding them —
    /// the tiled pass's unit, which defers the checksum fold to the
    /// canonical merge. Returns `(products, out_nnz)` for this row.
    fn execute_row(&mut self, a: &Csr, b: &Csr, i: usize, out_vals: &mut Vec<f32>) -> (u64, u32) {
        let products = self.accumulate_row(a, b, i);
        for &j in &self.touched {
            // SAFETY: see `profile_row` — `touched` holds validated ids.
            out_vals.push(unsafe { self.cells.get_unchecked(j as usize) }.1);
        }
        (products, self.touched.len() as u32)
    }
}

/// One (row-group × column-tile) block of the tiled profile pass:
/// everything the canonical merge needs to reassemble the serial pass's
/// [`Workload`] bit-for-bit. `PartialEq` compares every field bit-exactly
/// (f32 values included) — the round-trip contract of the `.mtp` cache
/// artifact ([`crate::sim::cache`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TilePartial {
    /// Output-row range `[row_lo, row_hi)` of this block (global rows).
    pub row_lo: usize,
    pub row_hi: usize,
    /// Output-column range `[col_lo, col_hi)` of this block.
    pub col_lo: usize,
    pub col_hi: usize,
    /// Per row in the range: scalar products landing in this column tile.
    /// Column tiles partition B's columns, so these sum across a row's
    /// tiles to the untiled row product count exactly (u64 addition).
    pub products: Vec<u64>,
    /// Per row in the range: distinct output columns in this tile.
    pub out_counts: Vec<u32>,
    /// Accumulated output values, rows concatenated, ascending column
    /// order within each row — bit-equal to the serial SPA's cell values
    /// at drain time, so the merge can replay the serial checksum fold.
    pub out_vals: Vec<f32>,
}

impl TilePartial {
    /// Rows covered by this block.
    pub fn rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    /// Approximate resident bytes — the unit the out-of-core pass's memory
    /// gauge tracks against the budget.
    pub fn bytes(&self) -> u64 {
        32 + 8 * self.products.len() as u64
            + 4 * self.out_counts.len() as u64
            + 4 * self.out_vals.len() as u64
    }
}

/// Telemetry of one tiled profile run — what `BENCH_tiling.json` publishes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TiledStats {
    /// Row groups of A (ceil(rows / tile rows)).
    pub row_groups: usize,
    /// Column tiles of B (ceil(cols / tile cols)).
    pub col_tiles: usize,
    /// Blocks profiled from scratch this run.
    pub blocks_computed: u64,
    /// Blocks loaded warm from the disk cache (the resume path). Re-reads
    /// of blocks produced earlier in the same run do not count.
    pub blocks_loaded: u64,
    /// Peak bytes of matrix slices + partials simultaneously resident in
    /// the out-of-core pass (0 for the in-memory pass, which holds both
    /// operands anyway). This is the quantity the `--mem-budget` contract
    /// bounds; CI asserts it stays below the budget.
    pub peak_bytes: u64,
}

/// Resident bytes of a CSR as held in RAM (usize row_ptr + u32 col ids +
/// f32 values) — the gauge unit for the out-of-core budget model.
fn resident_bytes(a: &Csr) -> u64 {
    ((a.rows() + 1) * 8 + a.nnz() * 8) as u64
}

/// Running peak-memory gauge for the out-of-core pass. Deterministic —
/// tracks exactly the bytes this module allocates for slices and partials,
/// not process RSS (which adds code, allocator slack, and I/O buffers on
/// top). This is the peak-RSS proxy `BENCH_tiling.json` publishes.
#[derive(Default)]
struct MemGauge {
    resident: u64,
    peak: u64,
}

impl MemGauge {
    fn add(&mut self, bytes: u64) {
        self.resident += bytes;
        self.peak = self.peak.max(self.resident);
    }

    fn sub(&mut self, bytes: u64) {
        self.resident = self.resident.saturating_sub(bytes);
    }
}

/// Profile one block: `group` is the A row slice `[row_lo, row_hi)` with
/// local row ids, `btile` the B column slice `[col_lo, col_lo+btile.cols())`
/// with local column ids (all of B's rows, so A's `k` indices stay valid).
fn profile_block(
    group: &Csr,
    row_lo: usize,
    row_hi: usize,
    btile: &Csr,
    col_lo: usize,
) -> TilePartial {
    debug_assert_eq!(group.rows(), row_hi - row_lo);
    let mut spa = Spa::new(btile.cols());
    let rows = row_hi - row_lo;
    let mut products = Vec::with_capacity(rows);
    let mut out_counts = Vec::with_capacity(rows);
    let mut out_vals = Vec::new();
    for i in 0..rows {
        let (p, o) = spa.execute_row(group, btile, i, &mut out_vals);
        products.push(p);
        out_counts.push(o);
    }
    TilePartial {
        row_lo,
        row_hi,
        col_lo,
        col_hi: col_lo + btile.cols(),
        products,
        out_counts,
        out_vals,
    }
}

/// Fold one row group's partials (ascending column-tile order) into the
/// accumulating workload — the canonical merge. For each row, tile order ×
/// within-tile ascending order is globally ascending column order, so the
/// `checksum` fold here is the *same sequential f64 chain* the serial pass
/// runs; products and out counts are exact integer sums.
fn merge_group(
    group: &Csr,
    partials: &[TilePartial],
    profiles: &mut Vec<RowProfile>,
    out_nnz: &mut u64,
    total_products: &mut u64,
    checksum: &mut f64,
) {
    let rows = group.rows();
    for p in partials {
        assert_eq!(p.rows(), rows, "partial row span disagrees with the group");
    }
    let mut cursors = vec![0usize; partials.len()];
    for i in 0..rows {
        let mut row_products = 0u64;
        let mut row_out = 0u64;
        for (t, p) in partials.iter().enumerate() {
            row_products += p.products[i];
            let n = p.out_counts[i] as usize;
            for &v in &p.out_vals[cursors[t]..cursors[t] + n] {
                *checksum += v as f64;
            }
            cursors[t] += n;
            row_out += n as u64;
        }
        profiles.push(RowProfile {
            a_nnz: group.row_nnz(i) as u32,
            products: row_products,
            out_nnz: row_out as u32,
        });
        *out_nnz += row_out;
        *total_products += row_products;
    }
}

/// Tiled profile pass: stream A row-groups against B column-tiles and
/// merge the per-block [`TilePartial`]s canonically. The result is
/// **bit-identical** to [`profile_workload`] — checksum bits included —
/// for every tile shape and every `threads` value (the bit-identity
/// argument lives on [`Spa::accumulate_row`] and [`merge_group`]; the
/// property tests in `tests/tiling.rs` pin it across shapes, generators,
/// and thread counts).
pub fn profile_workload_tiled(a: &Csr, b: &Csr, shape: TileShape, threads: usize) -> Workload {
    profile_workload_tiled_cached(a, b, shape, threads, None).0
}

/// [`profile_workload_tiled`] with an optional disk-cache hookup: each
/// block's [`TilePartial`] is loaded from `disk` under `key` when present
/// and stored after a cold compute, so an interrupted tiled profile
/// resumes warm — only the missing blocks are recomputed. `key` must
/// identify the operand matrices (the store does not hash them); block
/// bounds are part of the artifact name *and* embedded in the payload, so
/// a stale or foreign partial is rejected and recomputed, never merged.
pub fn profile_workload_tiled_cached(
    a: &Csr,
    b: &Csr,
    shape: TileShape,
    threads: usize,
    cache: Option<(&DiskCache, &str)>,
) -> (Workload, TiledStats) {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let shape = TileShape::new(shape.rows, shape.cols);
    let row_cuts = tile::cuts(a.rows(), shape.rows);
    let col_cuts = tile::cuts(b.cols(), shape.cols);
    let btiles: Vec<Csr> =
        col_cuts.windows(2).map(|w| tile::extract_cols(b, w[0], w[1])).collect();
    let n_tiles = btiles.len();
    let mut stats = TiledStats {
        row_groups: row_cuts.len() - 1,
        col_tiles: n_tiles,
        ..TiledStats::default()
    };

    let mut profiles = Vec::with_capacity(a.rows());
    let (mut out_nnz, mut total_products, mut checksum) = (0u64, 0u64, 0f64);
    for gw in row_cuts.windows(2) {
        let (row_lo, row_hi) = (gw[0], gw[1]);
        let group = tile::extract_rows(a, row_lo, row_hi);

        // Warm blocks first: anything the cache already holds is a load.
        let mut partials: Vec<Option<TilePartial>> = (0..n_tiles).map(|_| None).collect();
        if let Some((disk, key)) = cache {
            for (t, slot) in partials.iter_mut().enumerate() {
                *slot = disk.load_tile_partial(key, row_lo, row_hi, col_cuts[t], col_cuts[t + 1]);
            }
        }
        let missing: Vec<usize> =
            (0..n_tiles).filter(|&t| partials[t].is_none()).collect();
        stats.blocks_loaded += (n_tiles - missing.len()) as u64;
        stats.blocks_computed += missing.len() as u64;

        // Cold blocks fan out over `threads` scoped workers (round-robin
        // over the missing tile indices — deterministic partition, and the
        // blocks themselves are order-independent pure functions).
        let computed: Vec<(usize, TilePartial)> = if threads <= 1 || missing.len() <= 1 {
            missing
                .iter()
                .map(|&t| (t, profile_block(&group, row_lo, row_hi, &btiles[t], col_cuts[t])))
                .collect()
        } else {
            let workers = threads.min(missing.len());
            let (group_ref, missing_ref, btiles_ref, cuts_ref) =
                (&group, &missing, &btiles, &col_cuts);
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..workers)
                    .map(|w| {
                        scope.spawn(move || {
                            let mut done = Vec::new();
                            let mut at = w;
                            while at < missing_ref.len() {
                                let t = missing_ref[at];
                                done.push((
                                    t,
                                    profile_block(
                                        group_ref,
                                        row_lo,
                                        row_hi,
                                        &btiles_ref[t],
                                        cuts_ref[t],
                                    ),
                                ));
                                at += workers;
                            }
                            done
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .flat_map(|h| h.join().expect("tile worker panicked"))
                    .collect()
            })
        };
        for (t, p) in computed {
            if let Some((disk, key)) = cache {
                // Best-effort: a full disk must not fail the profile.
                let _ = disk.store_tile_partial(key, &p);
            }
            partials[t] = Some(p);
        }
        let partials: Vec<TilePartial> =
            partials.into_iter().map(|p| p.expect("every tile resolved")).collect();
        merge_group(&group, &partials, &mut profiles, &mut out_nnz, &mut total_products, &mut checksum);
    }

    let w = Workload {
        rows: a.rows(),
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products,
        profiles,
        checksum,
        fmt: FormatPlan::csr(a.rows(), b.rows(), a.nnz() as u64, b.nnz() as u64, out_nnz),
    };
    (w, stats)
}

/// Out-of-core tiled profile of `C = A × A` over a row-group container
/// ([`RowGroupFile`]) — the whole matrix is never resident. Two phases:
///
/// 1. **Produce** (tile-major): for each column tile, assemble the B tile
///    by streaming the container's groups, then profile every row group
///    against it, publishing each block's [`TilePartial`] to `disk` under
///    `key`. Blocks already present — from an interrupted run — are
///    skipped, which is the warm-resume contract.
/// 2. **Merge** (group-major): load each group's partials back in
///    canonical tile order and fold them exactly as
///    [`profile_workload_tiled`] does, so the result is bit-identical to
///    [`profile_workload`] on the fully-resident matrix.
///
/// Peak residency is one column tile + one row group + one partial in
/// phase 1, and one row group + its tile row of partials in phase 2 —
/// reported in [`TiledStats::peak_bytes`] so callers can assert their
/// `--mem-budget`. The disk cache is load-bearing here (partials bridge
/// the phases), so a failed store is an error, not best-effort.
pub fn profile_container_tiled(
    file: &RowGroupFile,
    shape: TileShape,
    disk: &DiskCache,
    key: &str,
) -> std::io::Result<(Workload, TiledStats)> {
    let (rows, cols) = (file.rows(), file.cols());
    if rows != cols {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("container profiling computes C = A x A; matrix is {rows}x{cols}"),
        ));
    }
    let shape = TileShape::new(shape.rows, shape.cols);
    let col_cuts = tile::cuts(cols, shape.cols);
    let n_tiles = col_cuts.len() - 1;
    let n_groups = file.group_count();
    let mut stats = TiledStats {
        row_groups: n_groups,
        col_tiles: n_tiles,
        ..TiledStats::default()
    };
    let mut gauge = MemGauge::default();
    // Blocks produced by THIS run: their phase-2 re-reads are not warm
    // hits, so they must not count toward `blocks_loaded`.
    let mut fresh = vec![false; n_groups * n_tiles];

    // Phase 1 — produce. Tile-major so each B column tile is assembled
    // once, not once per group.
    for t in 0..n_tiles {
        let (c0, c1) = (col_cuts[t], col_cuts[t + 1]);
        let missing: Vec<usize> = (0..n_groups)
            .filter(|&g| {
                let (lo, hi) = file.group_rows(g);
                !disk.has_tile_partial(key, lo, hi, c0, c1)
            })
            .collect();
        if missing.is_empty() {
            continue;
        }
        let btile = file.load_col_tile(c0, c1)?;
        gauge.add(resident_bytes(&btile));
        for &g in &missing {
            let slice = file.load_group(g)?;
            gauge.add(resident_bytes(&slice.matrix));
            let p = profile_block(&slice.matrix, slice.row_lo, slice.row_hi, &btile, c0);
            gauge.add(p.bytes());
            disk.store_tile_partial(key, &p).map_err(|e| {
                std::io::Error::new(
                    e.kind(),
                    format!("out-of-core profiling needs a writable partial cache: {e}"),
                )
            })?;
            stats.blocks_computed += 1;
            fresh[g * n_tiles + t] = true;
            gauge.sub(p.bytes());
            gauge.sub(resident_bytes(&slice.matrix));
        }
        gauge.sub(resident_bytes(&btile));
    }

    // Phase 2 — canonical group-major merge.
    let mut profiles = Vec::with_capacity(rows);
    let (mut out_nnz, mut total_products, mut checksum) = (0u64, 0u64, 0f64);
    for g in 0..n_groups {
        let slice = file.load_group(g)?;
        gauge.add(resident_bytes(&slice.matrix));
        let mut partials = Vec::with_capacity(n_tiles);
        for t in 0..n_tiles {
            let (c0, c1) = (col_cuts[t], col_cuts[t + 1]);
            let p = match disk.load_tile_partial(key, slice.row_lo, slice.row_hi, c0, c1) {
                Some(p) => {
                    if !fresh[g * n_tiles + t] {
                        stats.blocks_loaded += 1;
                    }
                    p
                }
                None => {
                    // Evicted between phases (corruption, concurrent
                    // `cache clear`): recompute the block from the
                    // container rather than failing the whole run.
                    let btile = file.load_col_tile(c0, c1)?;
                    let p = profile_block(&slice.matrix, slice.row_lo, slice.row_hi, &btile, c0);
                    let _ = disk.store_tile_partial(key, &p);
                    stats.blocks_computed += 1;
                    p
                }
            };
            gauge.add(p.bytes());
            partials.push(p);
        }
        merge_group(
            &slice.matrix,
            &partials,
            &mut profiles,
            &mut out_nnz,
            &mut total_products,
            &mut checksum,
        );
        for p in &partials {
            gauge.sub(p.bytes());
        }
        gauge.sub(resident_bytes(&slice.matrix));
    }
    stats.peak_bytes = gauge.peak;

    let nnz = file.nnz() as u64;
    let w = Workload {
        rows,
        cols,
        rows_b: rows,
        nnz_a: nnz,
        nnz_b: nnz,
        out_nnz,
        total_products,
        profiles,
        checksum,
        fmt: FormatPlan::csr(rows, rows, nnz, nnz, out_nnz),
    };
    Ok((w, stats))
}

/// Relative agreement band for estimated quantities (out_nnz, cycles,
/// energy) versus their exact counterparts — the sampled-profiler analogue
/// of the DES band ([`crate::sim::des::agreement_band`]). `maple estval`
/// and `maple explore --exhaustive` gate on it.
pub const ESTIMATE_BAND: f64 = 0.10;

/// Whether `estimate` agrees with `exact` within [`ESTIMATE_BAND`]
/// (relative, with an absolute floor of 1 so near-zero exacts don't demand
/// impossible precision).
pub fn estimate_in_band(exact: f64, estimate: f64) -> bool {
    (estimate - exact).abs() <= ESTIMATE_BAND * exact.abs().max(1.0)
}

/// Upper bound on the stratum count of the sampled pass. Strata are cut on
/// the product-mass prefix of the **product-sorted** row order, so rows of
/// similar work share a stratum; 16 keeps per-stratum sample counts large
/// enough for the variance estimate to mean something.
const MAX_STRATA: usize = 16;

/// One stratum of the sampled profile pass: a contiguous rank range of the
/// product-sorted row order, its exact product mass, and what the sample
/// said about it.
#[derive(Debug, Clone, PartialEq)]
pub struct StratumEstimate {
    /// The stratum's rank range over the product-sorted row order (strata
    /// tile `0..rows` in rank space).
    pub rows: std::ops::Range<usize>,
    /// Rows profiled exactly.
    pub sampled_rows: usize,
    /// Exact scalar-product mass of the whole stratum (cheap pass).
    pub products: u64,
    /// Product mass covered by the sampled rows.
    pub sampled_products: u64,
    /// Estimated outputs-per-product compression ratio (`Σout / Σproducts`
    /// over the sample; in `[0, 1]` since a row's out_nnz ≤ its products).
    pub out_ratio: f64,
    /// Absolute out_nnz error bound this stratum contributes.
    pub out_err: u64,
}

/// The sampled profiler's result: a full [`Workload`] (exact dimensions,
/// nnz, and per-row product counts; estimated out_nnz and checksum) plus
/// the per-stratum estimators and the claimed relative error bound on
/// `out_nnz`. `PartialEq` is bit-for-bit — the determinism contract for a
/// fixed `(budget, seed)`.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadEstimate {
    /// Drop-in workload for the analytic/DES cost models. `rows`, `cols`,
    /// `nnz_*`, `total_products`, and every profile's `a_nnz`/`products`
    /// are **exact**; `out_nnz` (total and per row) and `checksum` are
    /// estimates.
    pub workload: Workload,
    /// The row budget the caller asked for.
    pub budget: usize,
    /// The sampling seed.
    pub seed: u64,
    /// Rows actually profiled exactly (≤ budget).
    pub sampled_rows: usize,
    /// Whether the budget covered every row — the estimate degenerated to
    /// the exact profile (zero error).
    pub exact: bool,
    /// Per-stratum telemetry, in ascending rank order.
    pub strata: Vec<StratumEstimate>,
    /// Claimed relative error bound on `workload.out_nnz`: the true value
    /// is claimed to lie within `est ± rel_err × max(est, 1)`. Zero when
    /// `exact`. Cross-validated by `maple estval` and the estimator
    /// property tests.
    pub out_nnz_rel_err: f64,
}

/// Profile a stratified sample of A's rows instead of all of them — the
/// fast fitness tier behind [`crate::sim::explore`].
///
/// The cheap part of the exact pass is kept exact: per-row products
/// (`Σ_{k ∈ A row i} nnz(B row k)`) cost `O(nnz(A))` without touching a
/// SPA, so `total_products`, `nnz`, and every profile's `a_nnz`/`products`
/// come out exact. Only the merge-dependent quantities — per-row `out_nnz`
/// and the checksum, the `O(total_products)` part — are estimated:
///
/// * Rows are sorted by their (exact) product mass and the **sorted order**
///   is cut into ≤ [`MAX_STRATA`] strata of equal product mass. Sorting is
///   what makes the strata homogeneous: heavy power-law rows share a
///   stratum with other heavy rows instead of being averaged against the
///   light tail, which is where a row-order ratio estimator picks up most
///   of its bias. Each stratum's heaviest row is always included, so the
///   rows that dominate the grid's cost are never extrapolated.
/// * Within a stratum, the sampled rows run through the exact [`Spa`] and
///   the unsampled rows get `out_nnz ≈ products × (Σout/Σproducts over the
///   sample)`, clamped to the row's products and the output width — a
///   per-stratum ratio estimator.
/// * Each stratum's error contribution is bounded by its unsampled product
///   mass times a ratio-spread band (4 sample standard deviations + a 5%
///   floor, clamped to 1); a stratum with fewer than two informative
///   samples is fully conservative (any ratio in `[0,1]` is possible).
///
/// Deterministic for a fixed `(budget, seed)`; `budget ≥ rows` returns the
/// exact profile verbatim with a zero error bound.
pub fn profile_workload_sampled(a: &Csr, b: &Csr, budget: usize, seed: u64) -> WorkloadEstimate {
    assert_eq!(a.cols(), b.rows(), "dimension mismatch");
    let rows = a.rows();
    let budget = budget.max(1);
    if budget >= rows {
        let workload = profile_workload(a, b);
        let out_ratio = if workload.total_products == 0 {
            0.0
        } else {
            workload.out_nnz as f64 / workload.total_products as f64
        };
        return WorkloadEstimate {
            budget,
            seed,
            sampled_rows: rows,
            exact: true,
            strata: vec![StratumEstimate {
                rows: 0..rows,
                sampled_rows: rows,
                products: workload.total_products,
                sampled_products: workload.total_products,
                out_ratio,
                out_err: 0,
            }],
            out_nnz_rel_err: 0.0,
            workload,
        };
    }

    // Cheap exact pass: per-row product mass in O(nnz(A)).
    let row_products: Vec<u64> = (0..rows)
        .map(|i| a.row_cols(i).iter().map(|&k| b.row_nnz(k as usize) as u64).sum())
        .collect();

    // Stratify over the product-sorted row order (ascending, index
    // tie-break keeps the sort deterministic), cut into strata of equal
    // product mass.
    let mut order: Vec<usize> = (0..rows).collect();
    order.sort_unstable_by_key(|&i| (row_products[i], i));
    let mut prefix: Vec<u64> = Vec::with_capacity(rows + 1);
    prefix.push(0);
    for &i in &order {
        let last = *prefix.last().expect("prefix non-empty");
        prefix.push(last + row_products[i]);
    }

    let n_strata = budget.min(MAX_STRATA);
    let bounds = mass_balanced_bounds(&prefix, n_strata);
    let mut rng = SplitMix64::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    let mut spa = Spa::new(b.cols());

    // Exact a_nnz/products everywhere; out_nnz filled per stratum below.
    let mut profiles: Vec<RowProfile> = (0..rows)
        .map(|i| RowProfile { a_nnz: a.row_nnz(i) as u32, products: row_products[i], out_nnz: 0 })
        .collect();
    let mut checksum = 0f64;
    let mut strata = Vec::with_capacity(n_strata);
    let mut err_abs = 0f64;
    let mut sampled_total = 0usize;
    let out_cap = b.cols() as u64;

    for (s, w) in bounds.windows(2).enumerate() {
        let (lo, hi) = (w[0], w[1]);
        let len = hi - lo;
        let stratum_products: u64 = prefix[hi] - prefix[lo];
        if len == 0 {
            strata.push(StratumEstimate {
                rows: lo..hi,
                sampled_rows: 0,
                products: 0,
                sampled_products: 0,
                out_ratio: 0.0,
                out_err: 0,
            });
            continue;
        }
        // Equal row quota per stratum, remainder to the leading strata.
        let quota = (budget / n_strata + usize::from(s < budget % n_strata)).clamp(1, len);

        // Sample `quota` distinct ranks: Floyd's algorithm for a uniform
        // distinct draw, then force-include the stratum's heaviest row —
        // with the ascending sort that is simply the last rank.
        let mut picks: Vec<usize> = Vec::with_capacity(quota);
        if quota == len {
            picks.extend(lo..hi);
        } else {
            for j in (len - quota)..len {
                let t = lo + rng.below((j + 1) as u64) as usize;
                if picks.contains(&t) {
                    picks.push(lo + j);
                } else {
                    picks.push(t);
                }
            }
            let heavy = hi - 1;
            if !picks.contains(&heavy) {
                picks[0] = heavy;
            }
            picks.sort_unstable();
        }

        // Profile the sample exactly.
        let mut stratum_checksum = 0f64;
        let mut sampled_products = 0u64;
        let mut sampled_out = 0u64;
        let mut ratios: Vec<f64> = Vec::with_capacity(picks.len());
        for &pos in &picks {
            let i = order[pos];
            let p = spa.profile_row(a, b, i, &mut stratum_checksum);
            sampled_products += p.products;
            sampled_out += p.out_nnz as u64;
            if p.products > 0 {
                ratios.push(p.out_nnz as f64 / p.products as f64);
            }
            profiles[i] = p;
        }
        sampled_total += picks.len();

        // Ratio estimator for the unsampled remainder.
        let out_ratio = if sampled_products == 0 {
            0.0
        } else {
            sampled_out as f64 / sampled_products as f64
        };
        let mut pick_iter = picks.iter().copied().peekable();
        for pos in lo..hi {
            if pick_iter.peek() == Some(&pos) {
                pick_iter.next();
                continue;
            }
            let i = order[pos];
            let est = (row_products[i] as f64 * out_ratio).round() as u64;
            profiles[i].out_nnz = est.min(row_products[i]).min(out_cap) as u32;
        }

        // Scale the sampled checksum up by the uncovered product mass.
        checksum += if sampled_products == 0 {
            stratum_checksum
        } else {
            stratum_checksum * (stratum_products as f64 / sampled_products as f64)
        };

        // Error bound: unsampled product mass × ratio-spread band.
        let unsampled_products = stratum_products - sampled_products;
        let err = if unsampled_products == 0 {
            0.0
        } else if ratios.len() >= 2 {
            let n = ratios.len() as f64;
            let mean = ratios.iter().sum::<f64>() / n;
            let var = ratios.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / (n - 1.0);
            let band = (4.0 * var.sqrt() + 0.05).min(1.0);
            unsampled_products as f64 * band
        } else {
            // Fewer than two informative samples: any compression ratio in
            // [0, 1] is possible, so the whole unsampled mass is at risk.
            unsampled_products as f64
        };
        err_abs += err;
        strata.push(StratumEstimate {
            rows: lo..hi,
            sampled_rows: picks.len(),
            products: stratum_products,
            sampled_products,
            out_ratio,
            out_err: err.ceil() as u64,
        });
    }

    let out_nnz: u64 = profiles.iter().map(|p| p.out_nnz as u64).sum();
    let workload = Workload {
        rows,
        cols: b.cols(),
        rows_b: b.rows(),
        nnz_a: a.nnz() as u64,
        nnz_b: b.nnz() as u64,
        out_nnz,
        total_products: row_products.iter().sum(),
        profiles,
        checksum,
        fmt: FormatPlan::csr(rows, b.rows(), a.nnz() as u64, b.nnz() as u64, out_nnz),
    };
    WorkloadEstimate {
        workload,
        budget,
        seed,
        sampled_rows: sampled_total,
        exact: false,
        strata,
        out_nnz_rel_err: err_abs / out_nnz.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gustavson::{multiply_count, spgemm_rowwise};
    use crate::sparse::gen::{generate, Profile};

    #[test]
    fn profile_matches_reference_spgemm() {
        let a = generate(60, 60, 300, Profile::PowerLaw { alpha: 0.7 }, 3);
        let w = profile_workload(&a, &a);
        let c = spgemm_rowwise(&a, &a);
        assert_eq!(w.out_nnz, c.nnz() as u64);
        assert_eq!(w.total_products, multiply_count(&a, &a));
        for i in 0..a.rows() {
            assert_eq!(w.profiles[i].out_nnz as usize, c.row_nnz(i), "row {i}");
            assert_eq!(w.profiles[i].a_nnz as usize, a.row_nnz(i));
        }
        let direct: f64 = c.value.iter().map(|&v| v as f64).sum();
        assert!((w.checksum - direct).abs() < 1e-3 * direct.abs().max(1.0));
    }

    #[test]
    fn identity_workload_profile() {
        let a = generate(20, 20, 60, Profile::Uniform, 8);
        let i = crate::sparse::Csr::identity(20);
        let w = profile_workload(&a, &i);
        assert_eq!(w.out_nnz, a.nnz() as u64);
        assert_eq!(w.total_products, a.nnz() as u64);
        assert!((w.accumulation_factor() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn compulsory_words_formula() {
        let a = generate(10, 10, 20, Profile::Uniform, 2);
        let w = profile_workload(&a, &a);
        let expect = (2 * 20 + 11) + (2 * 20 + 11) + (2 * w.out_nnz + 11);
        assert_eq!(w.compulsory_dram_words(), expect);
    }

    #[test]
    fn parallel_profile_matches_serial() {
        let a = generate(500, 500, 5000, Profile::PowerLaw { alpha: 0.7 }, 19);
        let serial = profile_workload(&a, &a);
        for threads in [1, 2, 4, 7] {
            let par = profile_workload_parallel(&a, &a, threads);
            assert_eq!(par.profiles, serial.profiles, "threads={threads}");
            assert_eq!(par.out_nnz, serial.out_nnz);
            assert_eq!(par.total_products, serial.total_products);
            // Checksum reassociates across chunks: equal within fp noise.
            assert!(
                (par.checksum - serial.checksum).abs() < 1e-6 * serial.checksum.abs().max(1.0),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn chunk_bounds_are_nnz_balanced_and_deterministic() {
        let a = generate(2000, 2000, 40_000, Profile::PowerLaw { alpha: 0.9 }, 5);
        let threads = 8;
        let bounds = nnz_balanced_bounds(&a, threads);
        assert_eq!(bounds, nnz_balanced_bounds(&a, threads), "bounds must be deterministic");
        assert_eq!(bounds.len(), threads + 1);
        assert_eq!(bounds[0], 0);
        assert_eq!(*bounds.last().unwrap(), a.rows());
        assert!(bounds.windows(2).all(|w| w[0] <= w[1]), "bounds monotone: {bounds:?}");
        // The balance guarantee: no chunk exceeds its fair nnz share by more
        // than one (indivisible) row.
        let max_row = (0..a.rows()).map(|i| a.row_nnz(i)).max().unwrap();
        let fair = a.nnz().div_ceil(threads);
        for w in bounds.windows(2) {
            let chunk_nnz = a.row_ptr[w[1]] - a.row_ptr[w[0]];
            assert!(
                chunk_nnz <= fair + max_row,
                "chunk {w:?} holds {chunk_nnz} nnz (fair {fair}, max row {max_row})"
            );
        }
    }

    #[test]
    fn skewed_and_empty_rows_profile_identically_in_parallel() {
        // One very heavy row up front, a sea of empty rows, one trailing
        // nonzero: the worst case for row-count chunking and an edge case
        // for nnz-prefix cuts (all cuts land on the same boundary).
        let mut t: Vec<(u32, u32, f32)> = (0..400u32).map(|j| (0, j, 1.0 + j as f32)).collect();
        t.push((499, 3, 2.0));
        let a = Csr::from_triplets(500, 500, t);
        let serial = profile_workload(&a, &a);
        for threads in [2, 3, 8, 500] {
            let par = profile_workload_parallel(&a, &a, threads);
            assert_eq!(par.profiles, serial.profiles, "threads={threads}");
            assert_eq!(par.out_nnz, serial.out_nnz);
            assert_eq!(par.total_products, serial.total_products);
            assert!(
                (par.checksum - serial.checksum).abs()
                    < 1e-6 * serial.checksum.abs().max(1.0),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn rectangular_workload_dimensions_and_dram_words() {
        // A(30×50) × B(50×20): B's row_ptr stream is 51 words, not 31.
        let a = generate(30, 50, 200, Profile::Uniform, 5);
        let b = generate(50, 20, 180, Profile::Uniform, 9);
        let w = profile_workload(&a, &b);
        assert_eq!(w.rows, 30);
        assert_eq!(w.cols, 20);
        assert_eq!(w.rows_b, 50);
        let expect = (2 * w.nnz_a + 31) + (2 * w.nnz_b + 51) + (2 * w.out_nnz + 31);
        assert_eq!(w.compulsory_dram_words(), expect);
        // And the functional numbers agree with the reference SpGEMM.
        let c = spgemm_rowwise(&a, &b);
        assert_eq!(w.out_nnz, c.nnz() as u64);
        assert_eq!(w.total_products, multiply_count(&a, &b));
    }

    #[test]
    fn empty_matrix_profiles_cleanly() {
        let a = crate::sparse::Csr::zero(5, 5);
        let w = profile_workload(&a, &a);
        assert_eq!(w.out_nnz, 0);
        assert_eq!(w.total_products, 0);
        assert_eq!(w.checksum, 0.0);
        assert_eq!(w.profiles.len(), 5);
    }

    #[test]
    fn tiled_profile_is_bit_identical_to_serial() {
        let a = generate(120, 120, 1400, Profile::PowerLaw { alpha: 0.8 }, 21);
        let serial = profile_workload(&a, &a);
        for shape in [
            TileShape::new(32, 32),
            TileShape::new(1, 120),
            TileShape::new(120, 1),
            TileShape::new(7, 13),
            TileShape::new(4096, 4096), // tile larger than the matrix
        ] {
            for threads in [1, 4] {
                let tiled = profile_workload_tiled(&a, &a, shape, threads);
                // Full bit-identity, f64 checksum bits included — stronger
                // than the parallel pass's tolerance comparison.
                assert_eq!(tiled, serial, "shape {shape} threads {threads}");
                assert_eq!(tiled.checksum.to_bits(), serial.checksum.to_bits());
            }
        }
    }

    #[test]
    fn tiled_profile_handles_rectangular_and_empty_inputs() {
        let a = generate(30, 50, 220, Profile::Uniform, 5);
        let b = generate(50, 20, 160, Profile::Uniform, 9);
        let serial = profile_workload(&a, &b);
        assert_eq!(profile_workload_tiled(&a, &b, TileShape::new(8, 6), 2), serial);
        let z = crate::sparse::Csr::zero(4, 4);
        assert_eq!(
            profile_workload_tiled(&z, &z, TileShape::new(2, 2), 1),
            profile_workload(&z, &z)
        );
    }

    #[test]
    fn tiled_stats_count_the_grid() {
        let a = generate(40, 40, 300, Profile::Uniform, 2);
        let (w, stats) =
            profile_workload_tiled_cached(&a, &a, TileShape::new(16, 10), 1, None);
        assert_eq!(w, profile_workload(&a, &a));
        assert_eq!((stats.row_groups, stats.col_tiles), (3, 4));
        assert_eq!(stats.blocks_computed, 12);
        assert_eq!(stats.blocks_loaded, 0);
    }
}
