//! The simulation engine: cached profiling + parallel sweep fan-out.
//!
//! The paper's evaluation is *design-space exploration*: a [`DesignSpace`]
//! names a base configuration set plus an ordered list of typed [`Axis`]
//! values (dataset, policy, NoC topology, MACs/PE, prefetch depth, PE
//! model), and [`SimEngine::sweep`] expands it into a deterministic,
//! index-addressed cell grid. The profile pass is the expensive part (an
//! exact functional execution of `C = A × B`), so the engine profiles each
//! workload **exactly once**, caches it keyed by (dataset, seed, scale),
//! and fans the sweep cells out across scoped worker threads; every caller
//! (CLI, benches, examples) sits on the same engine instead of hand-rolling
//! its own thread scope.
//!
//! Below the in-memory slots sits an optional **on-disk tier**
//! ([`crate::sim::cache`], opted in via [`SimEngine::with_disk_cache`]): a
//! disk hit loads the serialised profile and skips both synthesis and
//! profiling, a miss computes and then atomically publishes the artifact,
//! so repeated CLI/bench/CI runs — and concurrent processes sharing the
//! directory — start warm.
//!
//! Determinism: a [`SweepResult`] is a pure function of the
//! [`DesignSpace`] — cell results land in a fixed row-major grid over
//! `dataset × config × <config axes in order> × policy` no matter how many
//! worker threads ran (every cell carries its named-axis coordinates), and
//! the profile pass uses a dedicated `profile_threads` knob (default 1,
//! i.e. bit-exact with the serial pass) that is independent of the fan-out
//! width.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::axis::ConfigAxis;
use crate::config::AcceleratorConfig;
use crate::coordinator::Policy;
use crate::mem::{Lane, Scratchpad};
use crate::noc::Topology;
use crate::sim::cache::DiskCache;
use crate::sim::des::{agreement_band, simulate_des, DesResult};
use crate::sim::shard::{ShardMeta, ShardSpec, SweepShard};
use crate::sim::{profile_workload_parallel, simulate_workload, SimResult, Workload};
use crate::sparse::{suite, Csr, FormatPlan, SparseFormat};

/// Engine errors.
#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("unknown dataset {0:?} (use a Table-I name or abbreviation)")]
    UnknownDataset(String),
    #[error("empty sweep dimension: {0}")]
    EmptySweep(&'static str),
    #[error("conflicting sweep axes: {0} appears more than once")]
    ConflictingAxes(&'static str),
    #[error("axis {0}: invalid point {1}")]
    InvalidAxisPoint(&'static str, String),
    #[error(transparent)]
    Pe(#[from] crate::pe::registry::RegistryError),
    #[error(transparent)]
    Shard(#[from] crate::sim::shard::ShardError),
}

/// Cache key for one profiled workload: a Table-I dataset (by name or
/// abbreviation) at a given seed and down-scale factor.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct WorkloadKey {
    pub dataset: String,
    pub seed: u64,
    /// Down-scale divisor; `1` = full Table-I size.
    pub scale: usize,
}

impl WorkloadKey {
    /// Key for a Table-I dataset (scale is clamped to ≥ 1).
    pub fn suite(dataset: impl Into<String>, seed: u64, scale: usize) -> Self {
        Self { dataset: dataset.into(), seed, scale: scale.max(1) }
    }
}

/// Which cycle model runs in each sweep cell.
///
/// The analytic profile replay is always executed — it is the functional
/// oracle (checksums, energy, action counts) and costs O(rows). The knob
/// controls whether the transaction-level DES ([`crate::sim::des`]) runs
/// *alongside* it, attaching a [`DesResult`] and a DES/analytic agreement
/// ratio to every cell — the Sparseloop-style cross-validation at sweep
/// scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CellModel {
    /// Analytic pipeline only (the paper's headline numbers; default).
    #[default]
    Analytic,
    /// DES timing per cell: the event-driven cycle count is the one to
    /// report; the analytic result rides along as the functional oracle
    /// and agreement denominator.
    Des,
    /// Both models side by side — analytic stays authoritative, the DES
    /// attaches for cross-validation.
    Both,
}

impl CellModel {
    /// Does this model run the transaction-level DES per cell?
    pub fn runs_des(self) -> bool {
        !matches!(self, CellModel::Analytic)
    }

    /// Stable on-disk tag (shard codec + space fingerprint).
    pub(crate) fn tag(self) -> u8 {
        match self {
            CellModel::Analytic => 0,
            CellModel::Des => 1,
            CellModel::Both => 2,
        }
    }

    /// Inverse of [`CellModel::tag`]; `None` for a foreign tag.
    pub(crate) fn from_tag(tag: u32) -> Option<Self> {
        match tag {
            0 => Some(CellModel::Analytic),
            1 => Some(CellModel::Des),
            2 => Some(CellModel::Both),
            _ => None,
        }
    }
}

impl std::str::FromStr for CellModel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "analytic" => Ok(CellModel::Analytic),
            "des" => Ok(CellModel::Des),
            "both" => Ok(CellModel::Both),
            other => Err(format!("unknown cell model {other} (analytic|des|both)")),
        }
    }
}

/// One typed design-space axis. `Dataset` varies the workload and `Policy`
/// the row routing; every other axis is a pure transform of the base
/// [`AcceleratorConfig`] (see [`ConfigAxis`]). Constructors exist for each
/// kind so call sites read as the axis they vary.
#[derive(Debug, Clone, PartialEq)]
pub enum Axis {
    /// Workloads to sweep (grid-outermost dimension).
    Dataset(Vec<WorkloadKey>),
    /// Row-routing policies (grid-innermost dimension; defaults to
    /// round-robin when the axis is absent).
    Policy(Vec<Policy>),
    /// A configuration transform axis (NoC topology, MACs/PE, prefetch
    /// depth, PE model), expanding the config dimension in listed order.
    Config(ConfigAxis),
}

impl Axis {
    /// NoC topology axis (`noc`).
    pub fn topology(points: Vec<Topology>) -> Self {
        Axis::Config(ConfigAxis::Topology(points))
    }

    /// MACs-per-PE axis (`macs`).
    pub fn macs_per_pe(points: Vec<usize>) -> Self {
        Axis::Config(ConfigAxis::MacsPerPe(points))
    }

    /// Operand-loader FIFO depth axis (`prefetch`).
    pub fn prefetch_depth(points: Vec<usize>) -> Self {
        Axis::Config(ConfigAxis::PrefetchDepth(points))
    }

    /// Registered PE cost-model axis (`pe-model`).
    pub fn pe_model(points: Vec<String>) -> Self {
        Axis::Config(ConfigAxis::PeModel(points))
    }

    /// Out-of-core tile-shape axis (`tile`). Results are tiling-invariant
    /// by construction; expansion rejects shapes whose working set exceeds
    /// the config's scratchpad ([`crate::sparse::tile::check_fits`]).
    pub fn tiling(points: Vec<crate::sparse::TileShape>) -> Self {
        Axis::Config(ConfigAxis::Tiling(points))
    }

    /// Operand compression-format axis (`fmt`). Each point re-prices the
    /// same profiled workload under a different [`SparseFormat`] traffic
    /// plan; the CSR point is bit-identical to a formatless sweep.
    pub fn format(points: Vec<SparseFormat>) -> Self {
        Axis::Config(ConfigAxis::Format(points))
    }

    /// The axis name used for grid dimensions, coordinates, and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Axis::Dataset(_) => "dataset",
            Axis::Policy(_) => "policy",
            Axis::Config(a) => a.name(),
        }
    }

    /// Number of points on this axis.
    pub fn len(&self) -> usize {
        match self {
            Axis::Dataset(v) => v.len(),
            Axis::Policy(v) => v.len(),
            Axis::Config(a) => a.len(),
        }
    }

    /// Whether the axis has no points.
    pub fn is_empty(&self) -> bool {
        match self {
            Axis::Dataset(v) => v.is_empty(),
            Axis::Policy(v) => v.is_empty(),
            Axis::Config(a) => a.is_empty(),
        }
    }
}

/// One named dimension of an expanded sweep grid: the axis name plus one
/// label per point, in point order.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisDim {
    pub name: &'static str,
    pub labels: Vec<String>,
}

impl AxisDim {
    /// Number of points along this dimension.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the dimension is degenerate (never true in a valid grid).
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// One named-axis coordinate of a sweep cell: which point of which axis the
/// cell sits on.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisCoord {
    pub axis: &'static str,
    pub index: usize,
    pub label: String,
}

/// The closed set of grid-dimension names, as `'static` strs. Shard
/// artifacts store dimension names as plain bytes; decoding re-interns them
/// here (a foreign name is a decode error, never a leak). A new
/// [`ConfigAxis`] kind must be added to this list before its grids can ride
/// through shard artifacts.
pub(crate) fn intern_dim_name(name: &str) -> Option<&'static str> {
    const KNOWN: [&str; 9] =
        ["dataset", "config", "policy", "noc", "macs", "prefetch", "pe-model", "tile", "fmt"];
    KNOWN.into_iter().find(|&k| k == name)
}

/// Named-axis coordinates of the cell at flat `idx` in a row-major grid
/// over `dims` (innermost dimension last).
pub(crate) fn coords_for(dims: &[AxisDim], idx: usize) -> Vec<AxisCoord> {
    let mut out = Vec::with_capacity(dims.len());
    let mut rem = idx;
    for d in dims.iter().rev() {
        let i = rem % d.len();
        rem /= d.len();
        out.push(AxisCoord { axis: d.name, index: i, label: d.labels[i].clone() });
    }
    out.reverse();
    out
}

/// A design space: a base configuration set plus an ordered list of typed
/// [`Axis`] values, each point a pure transform over the base. The cell
/// grid is the full product, row-major over
/// `dataset × config × <config axes in listed order> × policy` — dataset
/// and policy have fixed outer/inner positions so the historical
/// `(dataset, config, policy)` addressing (and every `paper()` caller) is
/// unchanged when no config axes are present.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignSpace {
    /// Base configurations (the `config` grid dimension).
    pub configs: Vec<AcceleratorConfig>,
    /// Ordered typed axes; at most one of each kind.
    pub axes: Vec<Axis>,
    pub cell_model: CellModel,
}

/// The historical name for a design space: `SweepSpec::new` / `paper` are
/// thin constructors over [`DesignSpace`], so pre-axis callers compile and
/// produce identical grids.
pub type SweepSpec = DesignSpace;

impl DesignSpace {
    /// The classic grid: `configs × datasets × policies` under the default
    /// (analytic) cell model.
    pub fn new(
        configs: Vec<AcceleratorConfig>,
        datasets: Vec<WorkloadKey>,
        policies: Vec<Policy>,
    ) -> Self {
        Self::over(configs).with_axis(Axis::Dataset(datasets)).with_axis(Axis::Policy(policies))
    }

    /// The paper's Fig.-9 sweep: all four configurations, round-robin
    /// routing, over the given datasets.
    pub fn paper(datasets: Vec<WorkloadKey>) -> Self {
        Self::new(AcceleratorConfig::paper_configs(), datasets, vec![Policy::RoundRobin])
    }

    /// A bare design space over base configurations; add dimensions with
    /// [`DesignSpace::with_axis`].
    pub fn over(configs: Vec<AcceleratorConfig>) -> Self {
        Self { configs, axes: Vec::new(), cell_model: CellModel::Analytic }
    }

    /// Append one axis (grid order for config axes is append order).
    pub fn with_axis(mut self, axis: Axis) -> Self {
        self.axes.push(axis);
        self
    }

    /// The same space under a different cell model.
    pub fn with_cell_model(mut self, cell_model: CellModel) -> Self {
        self.cell_model = cell_model;
        self
    }

    /// Stable fingerprint of the expanded design space — the value shard
    /// artifacts carry and [`crate::sim::shard::merge`] compares. It covers
    /// everything that determines cell contents (grid dimensions and
    /// labels, dataset keys, every *expanded* configuration's full TOML,
    /// the policy list, the cell model, and the codec version), so two
    /// spaces fingerprint equal iff their grids are cell-for-cell
    /// compatible. Cheap: no profiling or simulation runs.
    pub fn fingerprint(&self) -> Result<u64, EngineError> {
        Ok(self.expand()?.fingerprint(self.cell_model))
    }

    /// The dataset axis points (empty when the axis is absent).
    pub fn datasets(&self) -> &[WorkloadKey] {
        self.axes
            .iter()
            .find_map(|a| match a {
                Axis::Dataset(keys) => Some(keys.as_slice()),
                _ => None,
            })
            .unwrap_or(&[])
    }

    /// Expand into concrete grid dimensions: validate the axes (one of each
    /// kind, no empty or degenerate ones), materialise the expanded config
    /// list (base × config-axis product, transforms applied in axis order),
    /// and name every dimension.
    pub(crate) fn expand(&self) -> Result<Expanded, EngineError> {
        if self.configs.is_empty() {
            return Err(EngineError::EmptySweep("configs"));
        }
        let mut seen: Vec<&'static str> = Vec::new();
        for axis in &self.axes {
            if seen.contains(&axis.name()) {
                return Err(EngineError::ConflictingAxes(axis.name()));
            }
            seen.push(axis.name());
        }
        let mut datasets: Vec<WorkloadKey> = Vec::new();
        let mut policies: Vec<Policy> = Vec::new();
        let mut config_axes: Vec<&ConfigAxis> = Vec::new();
        for axis in &self.axes {
            match axis {
                Axis::Dataset(keys) => datasets = keys.clone(),
                Axis::Policy(ps) => policies = ps.clone(),
                Axis::Config(a) => {
                    if a.is_empty() {
                        return Err(EngineError::EmptySweep(a.name()));
                    }
                    a.validate()
                        .map_err(|bad| EngineError::InvalidAxisPoint(a.name(), bad))?;
                    config_axes.push(a);
                }
            }
        }
        if datasets.is_empty() {
            return Err(EngineError::EmptySweep("datasets"));
        }
        if policies.is_empty() {
            if self.axes.iter().any(|a| matches!(a, Axis::Policy(_))) {
                return Err(EngineError::EmptySweep("policies"));
            }
            policies.push(Policy::RoundRobin);
        }

        // Expand the config dimension: base (outer) × config-axis product
        // (row-major, first listed axis outermost), transforms applied in
        // axis order so each expanded name reads base+axis1=..+axis2=..
        let combos: usize = config_axes.iter().map(|a| a.len()).product();
        let mut configs = Vec::with_capacity(self.configs.len() * combos);
        for base in &self.configs {
            for combo in 0..combos {
                let mut cfg = base.clone();
                let mut point = vec![0usize; config_axes.len()];
                let mut rem = combo;
                for (i, a) in config_axes.iter().enumerate().rev() {
                    point[i] = rem % a.len();
                    rem /= a.len();
                }
                for (a, &i) in config_axes.iter().zip(&point) {
                    a.apply(i, &mut cfg);
                }
                // Tiling feasibility is per expanded cell, not per axis:
                // whether a tile's working set fits depends on the config's
                // own scratchpad capacity, which other axes on this grid do
                // not change but different base configs do.
                if let Some(shape) = cfg.tiling {
                    if cfg.l1_bytes > 0 {
                        let spm = Scratchpad::new("l1", Lane::L1, cfg.l1_bytes);
                        crate::sparse::tile::check_fits(shape, &spm).map_err(|msg| {
                            EngineError::InvalidAxisPoint("tile", format!("{}: {msg}", cfg.name))
                        })?;
                    }
                }
                configs.push(cfg);
            }
        }

        let mut dims = vec![
            AxisDim {
                name: "dataset",
                labels: datasets.iter().map(|k| k.dataset.clone()).collect(),
            },
            AxisDim {
                name: "config",
                labels: self.configs.iter().map(|c| c.name.clone()).collect(),
            },
        ];
        for a in &config_axes {
            dims.push(AxisDim { name: a.name(), labels: a.labels() });
        }
        dims.push(AxisDim {
            name: "policy",
            labels: policies.iter().map(|p| format!("{p:?}")).collect(),
        });
        Ok(Expanded { datasets, configs, policies, dims })
    }
}

/// A [`DesignSpace`] expanded to concrete grid dimensions. Crate-visible so
/// [`crate::sim::explore`] can walk the same grid the sweep path runs.
pub(crate) struct Expanded {
    pub(crate) datasets: Vec<WorkloadKey>,
    /// Base × config-axis product, transforms applied, names suffixed.
    pub(crate) configs: Vec<AcceleratorConfig>,
    pub(crate) policies: Vec<Policy>,
    /// Row-major dimension order: dataset, config, config axes…, policy.
    pub(crate) dims: Vec<AxisDim>,
}

impl Expanded {
    /// Total cell count (product of the dimension lengths).
    pub(crate) fn total_cells(&self) -> usize {
        self.dims.iter().map(|d| d.len()).product()
    }

    /// Order-sensitive FNV-1a over everything that determines cell
    /// contents. Configurations hash as their full TOML, so two spaces
    /// whose configs differ in any knob — not just the name — fingerprint
    /// apart; every variable-length field is length-prefixed so adjacent
    /// fields can never alias.
    pub(crate) fn fingerprint(&self, model: CellModel) -> u64 {
        use crate::sim::cache::codec::put_str;
        let mut buf = Vec::new();
        put_str(&mut buf, "maple-design-space");
        buf.extend_from_slice(&crate::sim::cache::CODEC_VERSION.to_le_bytes());
        buf.push(model.tag());
        buf.extend_from_slice(&(self.dims.len() as u64).to_le_bytes());
        for d in &self.dims {
            put_str(&mut buf, d.name);
            buf.extend_from_slice(&(d.labels.len() as u64).to_le_bytes());
            for l in &d.labels {
                put_str(&mut buf, l);
            }
        }
        for k in &self.datasets {
            put_str(&mut buf, &k.dataset);
            buf.extend_from_slice(&k.seed.to_le_bytes());
            buf.extend_from_slice(&(k.scale as u64).to_le_bytes());
        }
        for cfg in &self.configs {
            put_str(&mut buf, &cfg.to_toml());
        }
        for p in &self.policies {
            put_str(&mut buf, &format!("{p:?}"));
        }
        crate::sim::cache::codec::fnv1a(&buf)
    }
}

/// One sweep cell: the analytic result, plus the DES cross-check when the
/// sweep's [`CellModel`] ran it, addressed by its named-axis coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    /// The analytic pipeline result — functional oracle and energy model.
    pub analytic: SimResult,
    /// The transaction-level DES result ([`CellModel::Des`] / `Both` only).
    pub des: Option<DesResult>,
    /// Where this cell sits in the grid: one coordinate per dimension, in
    /// row-major dimension order (dataset, config, config axes…, policy).
    pub coords: Vec<AxisCoord>,
}

impl CellResult {
    /// DES / analytic compute-cycle ratio (`None` when the DES didn't run).
    /// ≥ 1.0 by construction: the DES adds fetch latency to the exact
    /// pipeline recurrence the analytic makespan lower-bounds.
    pub fn agreement_ratio(&self) -> Option<f64> {
        self.des.as_ref().map(|d| d.cycles as f64 / self.analytic.cycles_compute.max(1) as f64)
    }

    /// Whether the DES cycles sit inside the documented agreement band
    /// ([`crate::sim::des::agreement_band`]); `None` when the DES didn't run.
    pub fn des_in_band(&self) -> Option<bool> {
        self.des.as_ref().map(|d| {
            let (lower, upper) = agreement_band(&self.analytic);
            d.cycles >= lower && d.cycles <= upper
        })
    }

    /// The cell's authoritative cycle count under `model`: DES cycles for
    /// [`CellModel::Des`], the analytic datapath cycles otherwise — or when
    /// no DES result is attached (prefer [`SweepResult::cell_cycles`],
    /// which supplies the model the grid actually ran under).
    pub fn cycles(&self, model: CellModel) -> u64 {
        match (&self.des, model) {
            (Some(d), CellModel::Des) => d.cycles,
            _ => self.analytic.cycles_compute,
        }
    }
}

/// The deterministic result grid of one sweep: row-major over the named
/// [`AxisDim`]s (`dataset × config × <config axes> × policy`). The
/// flattened legacy view — `cells[(d × |configs| + c) × |policies| + p]`
/// with `configs` the *expanded* config list — addresses the same cells,
/// because the config axes sit contiguously inside the config dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepResult {
    pub datasets: Vec<WorkloadKey>,
    /// Expanded configuration names (base × config axes), in grid order.
    pub configs: Vec<String>,
    pub policies: Vec<Policy>,
    /// The cell model the sweep ran under.
    pub cell_model: CellModel,
    /// Named grid dimensions, row-major; their length product equals
    /// [`SweepResult::cell_count`].
    pub dims: Vec<AxisDim>,
    /// Crate-visible so [`crate::sim::shard::merge`] can reassemble a grid
    /// from shard artifacts; external construction still goes through
    /// [`SimEngine::sweep`] or the merge path.
    pub(crate) cells: Vec<CellResult>,
}

impl SweepResult {
    /// The cell for (dataset, config, policy) spec indices.
    pub fn get(&self, dataset: usize, config: usize, policy: usize) -> &CellResult {
        assert!(dataset < self.datasets.len(), "dataset index {dataset} out of range");
        assert!(config < self.configs.len(), "config index {config} out of range");
        assert!(policy < self.policies.len(), "policy index {policy} out of range");
        &self.cells[(dataset * self.configs.len() + config) * self.policies.len() + policy]
    }

    /// Total number of cells.
    pub fn cell_count(&self) -> usize {
        self.cells.len()
    }

    /// The cell at a flat row-major grid index.
    pub fn cell(&self, idx: usize) -> &CellResult {
        &self.cells[idx]
    }

    /// Points per dimension, in row-major dimension order.
    pub fn shape(&self) -> Vec<usize> {
        self.dims.iter().map(|d| d.len()).collect()
    }

    /// Human-readable shape, e.g. `dataset=2 x config=4 x policy=1` — the
    /// one rendering shared by the CLI grid line and the merge provenance.
    pub fn shape_line(&self) -> String {
        self.dims
            .iter()
            .map(|d| format!("{}={}", d.name, d.len()))
            .collect::<Vec<_>>()
            .join(" x ")
    }

    /// The named dimension, if it is part of this grid.
    pub fn dim(&self, name: &str) -> Option<&AxisDim> {
        self.dims.iter().find(|d| d.name == name)
    }

    /// Flat index of the cell at per-dimension indices (row-major; one
    /// index per [`AxisDim`], in order).
    pub fn index_of(&self, coords: &[usize]) -> usize {
        assert_eq!(
            coords.len(),
            self.dims.len(),
            "expected one coordinate per grid dimension"
        );
        coords.iter().zip(&self.dims).fold(0, |acc, (&c, d)| {
            assert!(c < d.len(), "{} index {c} out of range (< {})", d.name, d.len());
            acc * d.len() + c
        })
    }

    /// The cell at per-dimension indices (see [`SweepResult::index_of`]).
    pub fn at(&self, coords: &[usize]) -> &CellResult {
        &self.cells[self.index_of(coords)]
    }

    /// All cells with their (dataset, config, policy) indices, grid order.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize, &CellResult)> {
        let (nc, np) = (self.configs.len(), self.policies.len());
        self.cells.iter().enumerate().map(move |(i, r)| {
            let (d, rem) = (i / (nc * np), i % (nc * np));
            (d, rem / np, rem % np, r)
        })
    }

    /// The authoritative cycle count of one cell under the model this grid
    /// actually ran with: the event-driven DES count for a
    /// [`CellModel::Des`] sweep, the analytic datapath cycles otherwise.
    pub fn cell_cycles(&self, dataset: usize, config: usize, policy: usize) -> u64 {
        self.get(dataset, config, policy).cycles(self.cell_model)
    }

    /// Grid indices of every cell whose DES cycles fall outside the
    /// documented agreement band. Empty for analytic-only sweeps (no DES
    /// ran) and for healthy cross-validation sweeps.
    pub fn des_out_of_band(&self) -> Vec<(usize, usize, usize)> {
        self.iter()
            .filter(|(_, _, _, cell)| cell.des_in_band() == Some(false))
            .map(|(d, c, p, _)| (d, c, p))
            .collect()
    }
}

/// One cache slot: the per-key mutex serialises profiling of *that* key
/// only, so concurrent misses on the same workload profile it once while
/// different workloads still profile in parallel.
type WorkloadSlot = Arc<Mutex<Option<Arc<Workload>>>>;

/// The reusable simulation engine. Cheap to create; share one per process
/// (or per evaluation) so the workload cache amortises across sweeps.
pub struct SimEngine {
    /// Sweep-cell fan-out width.
    threads: usize,
    /// Chunk count inside the profile pass. Kept separate from `threads`
    /// so results are bit-identical across fan-out widths; the default of 1
    /// reproduces the serial profile pass exactly (checksum included).
    profile_threads: usize,
    /// BTreeMap so cache-stat iteration is key-ordered and deterministic.
    cache: Mutex<BTreeMap<WorkloadKey, WorkloadSlot>>,
    /// Second cache tier: persisted profiles shared across processes.
    disk: Option<DiskCache>,
    /// Derived non-CSR workloads, memoized per (canonical key, format).
    /// Derivation is a closed form of the base totals, so entries are
    /// cheap; the map only avoids re-cloning profile vectors per cell.
    fmt_cache: Mutex<BTreeMap<(WorkloadKey, SparseFormat), Arc<Workload>>>,
    profiles_run: AtomicU64,
    disk_hits: AtomicU64,
    disk_stores: AtomicU64,
}

impl Default for SimEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SimEngine {
    /// Engine with one worker per available core and serial profiling.
    pub fn new() -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
        Self {
            threads,
            profile_threads: 1,
            cache: Mutex::new(BTreeMap::new()),
            disk: None,
            fmt_cache: Mutex::new(BTreeMap::new()),
            profiles_run: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            disk_stores: AtomicU64::new(0),
        }
    }

    /// Engine with the standard environment contract shared by the CLI,
    /// benches, and examples: the on-disk tier at `$MAPLE_CACHE_DIR` (or
    /// [`DiskCache::default_dir`]) unless `MAPLE_NO_CACHE` is set,
    /// degrading to a cold engine with a warning when the directory cannot
    /// be opened — caching must never fail a run.
    pub fn from_env() -> Self {
        let engine = Self::new();
        if std::env::var_os("MAPLE_NO_CACHE").is_some() {
            return engine;
        }
        match DiskCache::from_env() {
            Ok(disk) => engine.with_disk_cache(disk),
            Err(e) => {
                eprintln!("warning: workload cache disabled: {e}");
                engine
            }
        }
    }

    /// Override the sweep fan-out width (clamped to ≥ 1). Results are
    /// identical for any width — only wall-clock changes.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Override the profile-pass chunk count. Any fixed value is
    /// deterministic run-to-run; values > 1 reassociate the f64 checksum
    /// across chunk boundaries (cycle/energy results are unaffected —
    /// the per-row profiles are exact integers).
    pub fn with_profile_threads(mut self, profile_threads: usize) -> Self {
        self.profile_threads = profile_threads.max(1);
        self
    }

    /// Attach the on-disk cache tier: suite workloads load from `disk` when
    /// a valid artifact exists (skipping synthesis *and* profiling) and are
    /// persisted there after a cold profile. Caller-named workloads
    /// ([`SimEngine::workload_from_matrices`]) stay memory-only — their keys
    /// don't describe the matrices, so persisting them could alias.
    pub fn with_disk_cache(mut self, disk: DiskCache) -> Self {
        self.disk = Some(disk);
        self
    }

    /// The attached on-disk cache tier, if any.
    pub fn disk_cache(&self) -> Option<&DiskCache> {
        self.disk.as_ref()
    }

    /// How many profile passes this engine has actually executed (cache
    /// misses); in-memory and disk hits do not increment.
    pub fn profiles_run(&self) -> u64 {
        self.profiles_run.load(Ordering::Relaxed)
    }

    /// How many workloads were loaded from the disk tier instead of being
    /// synthesised and profiled.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// How many freshly profiled workloads were persisted to the disk tier.
    pub fn disk_stores(&self) -> u64 {
        self.disk_stores.load(Ordering::Relaxed)
    }

    /// Number of cache slots (profiled or currently being profiled).
    pub fn cached_workloads(&self) -> usize {
        self.cache.lock().expect("engine cache poisoned").len()
    }

    /// The slot for `key`, reserving it on first sight.
    fn slot(&self, key: &WorkloadKey) -> WorkloadSlot {
        let mut cache = self.cache.lock().expect("engine cache poisoned");
        Arc::clone(cache.entry(key.clone()).or_default())
    }

    /// A completed cache entry under exactly `key`, waiting out an
    /// in-flight profile of the same key if there is one.
    fn get_cached(&self, key: &WorkloadKey) -> Option<Arc<Workload>> {
        let slot = {
            let cache = self.cache.lock().expect("engine cache poisoned");
            Arc::clone(cache.get(key)?)
        };
        let filled = slot.lock().expect("workload slot poisoned");
        filled.as_ref().map(Arc::clone)
    }

    /// The profiled workload for `key`, from cache or freshly profiled.
    ///
    /// Suite keys are canonicalised (name/abbreviation/case aliases and
    /// `scale ∈ {0, 1}` collapse to one entry), and concurrent misses on
    /// the same key block on its slot instead of profiling twice — the
    /// profile-once guarantee holds for a shared engine.
    pub fn workload(&self, key: &WorkloadKey) -> Result<Arc<Workload>, EngineError> {
        // Fast path, also covering the caller-named keys registered via
        // [`SimEngine::workload_from_matrices`].
        if let Some(w) = self.get_cached(key) {
            return Ok(w);
        }
        let spec = suite::by_name(&key.dataset)
            .ok_or_else(|| EngineError::UnknownDataset(key.dataset.clone()))?;
        let canonical = WorkloadKey {
            dataset: spec.abbrev.to_string(),
            seed: key.seed,
            scale: key.scale.max(1),
        };
        let slot = self.slot(&canonical);
        let mut filled = slot.lock().expect("workload slot poisoned");
        if let Some(w) = &*filled {
            return Ok(Arc::clone(w));
        }
        // Disk tier: a valid artifact replaces synthesis + profiling with a
        // single sequential read (a bad one was evicted and reads as a miss).
        if let Some(disk) = &self.disk {
            if let Some(w) = disk.load_workload(&canonical, self.profile_threads) {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                let w = Arc::new(w);
                *filled = Some(Arc::clone(&w));
                return Ok(w);
            }
        }
        let a = if canonical.scale <= 1 {
            spec.generate(canonical.seed)
        } else {
            spec.generate_scaled(canonical.seed, canonical.scale)
        };
        let w = Arc::new(profile_workload_parallel(&a, &a, self.profile_threads));
        self.profiles_run.fetch_add(1, Ordering::Relaxed);
        // Publish best-effort: a full disk must not fail the sweep.
        if let Some(disk) = &self.disk {
            if disk.store_workload(&canonical, self.profile_threads, &w).is_ok() {
                self.disk_stores.fetch_add(1, Ordering::Relaxed);
            }
        }
        *filled = Some(Arc::clone(&w));
        Ok(w)
    }

    /// The profiled workload for `key` under an operand format: the native
    /// CSR workload itself for [`SparseFormat::Csr`], otherwise a derived
    /// copy whose [`FormatPlan`] charges that format's storage, gather, and
    /// conversion traffic. The plan is a closed form of the base workload's
    /// *totals* ([`FormatPlan::from_totals`]), never of matrix internals,
    /// so a warm (disk-loaded) derivation is bit-identical to a cold one.
    /// Derived artifacts persist under format-keyed names and never alias
    /// the CSR artifact; loading one is not a [`SimEngine::disk_hits`] —
    /// the base profile above is the expensive artifact either way.
    pub fn workload_for(
        &self,
        key: &WorkloadKey,
        fmt: SparseFormat,
    ) -> Result<Arc<Workload>, EngineError> {
        if fmt == SparseFormat::Csr {
            return self.workload(key);
        }
        let base = self.workload(key)?;
        // Canonicalise suite keys exactly as `workload` does. Caller-named
        // keys (workload_from_matrices) pass through unchanged and stay
        // memory-only — their keys don't describe the matrices.
        let (canonical, persist) = match suite::by_name(&key.dataset) {
            Some(spec) => (
                WorkloadKey {
                    dataset: spec.abbrev.to_string(),
                    seed: key.seed,
                    scale: key.scale.max(1),
                },
                true,
            ),
            None => (key.clone(), false),
        };
        let cache_key = (canonical.clone(), fmt);
        if let Some(w) = self.fmt_cache.lock().expect("format cache poisoned").get(&cache_key) {
            return Ok(Arc::clone(w));
        }
        let loaded = if persist {
            self.disk
                .as_ref()
                .and_then(|d| d.load_workload_fmt(&canonical, self.profile_threads, fmt))
        } else {
            None
        };
        let derived = match loaded {
            Some(w) => Arc::new(w),
            None => {
                let mut w = (*base).clone();
                w.fmt = FormatPlan::from_totals(
                    fmt,
                    w.rows,
                    w.cols,
                    w.rows_b,
                    w.nnz_a,
                    w.nnz_b,
                    w.out_nnz,
                );
                if persist {
                    if let Some(disk) = &self.disk {
                        // Best-effort: a full disk must not fail the sweep.
                        let _ = disk.store_workload_fmt(&canonical, self.profile_threads, &w);
                    }
                }
                Arc::new(w)
            }
        };
        let mut cache = self.fmt_cache.lock().expect("format cache poisoned");
        Ok(Arc::clone(cache.entry(cache_key).or_insert(derived)))
    }

    /// Profile a caller-supplied `C = A × B` (rectangular allowed) and
    /// cache it under `key` for subsequent [`SimEngine::simulate`] /
    /// [`SimEngine::workload`] calls with the same key.
    pub fn workload_from_matrices(&self, key: WorkloadKey, a: &Csr, b: &Csr) -> Arc<Workload> {
        let slot = self.slot(&key);
        let mut filled = slot.lock().expect("workload slot poisoned");
        if let Some(w) = &*filled {
            return Arc::clone(w);
        }
        let w = Arc::new(profile_workload_parallel(a, b, self.profile_threads));
        self.profiles_run.fetch_add(1, Ordering::Relaxed);
        *filled = Some(Arc::clone(&w));
        w
    }

    /// One sweep cell without building a [`SweepSpec`] — profile-cached.
    pub fn simulate(
        &self,
        cfg: &AcceleratorConfig,
        key: &WorkloadKey,
        policy: Policy,
    ) -> Result<SimResult, EngineError> {
        crate::pe::registry::build(cfg)?; // clean error before any profiling
        let w = self.workload_for(key, cfg.operand_format)?;
        Ok(simulate_workload(cfg, &w, policy))
    }

    /// One sweep cell under an explicit [`CellModel`] — profile-cached,
    /// with the DES cross-check attached when the model runs it. The cell
    /// carries the coordinates of the equivalent 1×1×1 grid, so it compares
    /// equal to the matching cell of a single-point sweep.
    pub fn simulate_cell(
        &self,
        cfg: &AcceleratorConfig,
        key: &WorkloadKey,
        policy: Policy,
        model: CellModel,
    ) -> Result<CellResult, EngineError> {
        crate::pe::registry::build(cfg)?; // clean error before any profiling
        let dims = [
            AxisDim { name: "dataset", labels: vec![key.dataset.clone()] },
            AxisDim { name: "config", labels: vec![cfg.name.clone()] },
            AxisDim { name: "policy", labels: vec![format!("{policy:?}")] },
        ];
        let w = self.workload_for(key, cfg.operand_format)?;
        Ok(Self::run_cell(cfg, &w, policy, model, coords_for(&dims, 0)))
    }

    /// The per-cell dispatch shared by [`SimEngine::simulate_cell`] and the
    /// sweep workers: the analytic replay always runs (functional oracle);
    /// the DES runs alongside when the cell model asks for it.
    pub(crate) fn run_cell(
        cfg: &AcceleratorConfig,
        w: &Workload,
        policy: Policy,
        model: CellModel,
        coords: Vec<AxisCoord>,
    ) -> CellResult {
        let analytic = simulate_workload(cfg, w, policy);
        let des = model.runs_des().then(|| simulate_des(cfg, w, policy));
        CellResult { analytic, des, coords }
    }

    /// Run the full expanded grid of a [`DesignSpace`]. Each distinct
    /// dataset is profiled exactly once (cache-wide, not just per sweep);
    /// cells then run concurrently on `threads` scoped workers, landing in
    /// the deterministic row-major grid regardless of fan-out width.
    pub fn sweep(&self, spec: &DesignSpace) -> Result<SweepResult, EngineError> {
        let ex = spec.expand()?;
        // Validate every expanded config's PE model up front: a typo'd
        // `pe.model` (or pe-model axis point) must be a clean error here,
        // not a panic inside a worker thread.
        for cfg in &ex.configs {
            crate::pe::registry::build(cfg)?;
        }
        let cells = self.run_range(&ex, spec.cell_model, 0..ex.total_cells())?;
        Ok(SweepResult {
            datasets: ex.datasets,
            configs: ex.configs.iter().map(|c| c.name.clone()).collect(),
            policies: ex.policies,
            cell_model: spec.cell_model,
            dims: ex.dims,
            cells,
        })
    }

    /// Run one shard of a [`DesignSpace`]: the contiguous flat-index range
    /// [`ShardSpec::range`] selects out of the expanded cell grid. Only the
    /// datasets that range touches are profiled (dataset is the outermost
    /// grid dimension, so a contiguous cell range maps to a contiguous
    /// dataset span), and the resulting [`SweepShard`] carries the full
    /// grid metadata, the space fingerprint, and per-shard run stats —
    /// everything [`crate::sim::shard::merge`] needs to reassemble a
    /// [`SweepResult`] identical to the unsharded [`SimEngine::sweep`].
    pub fn sweep_shard(
        &self,
        spec: &DesignSpace,
        shard: ShardSpec,
    ) -> Result<SweepShard, EngineError> {
        shard.validate()?;
        let ex = spec.expand()?;
        for cfg in &ex.configs {
            crate::pe::registry::build(cfg)?;
        }
        let fingerprint = ex.fingerprint(spec.cell_model);
        let range = shard.range(ex.total_cells());
        // vet:allow(wall-clock): lands only in volatile ShardMeta stats, zeroed before canonical comparison
        let start = Instant::now();
        let (profiles_before, hits_before) = (self.profiles_run(), self.disk_hits());
        let cells = self.run_range(&ex, spec.cell_model, range.clone())?;
        let meta = ShardMeta {
            wall_ms: start.elapsed().as_millis() as u64,
            profiles_run: self.profiles_run() - profiles_before,
            disk_hits: self.disk_hits() - hits_before,
            profile_threads: self.profile_threads,
        };
        Ok(SweepShard {
            fingerprint,
            spec: shard,
            start: range.start,
            datasets: ex.datasets,
            configs: ex.configs.iter().map(|c| c.name.clone()).collect(),
            policies: ex.policies,
            cell_model: spec.cell_model,
            dims: ex.dims,
            cells,
            meta,
        })
    }

    /// Profile the datasets a contiguous cell range touches, then run those
    /// cells on scoped workers; slot `i` of the returned vec is grid cell
    /// `range.start + i`. The full sweep is `run_range(.., 0..total)`; a
    /// shard passes its sub-range and computes the identical cells, because
    /// every cell is a pure function of its flat index.
    fn run_range(
        &self,
        ex: &Expanded,
        model: CellModel,
        range: Range<usize>,
    ) -> Result<Vec<CellResult>, EngineError> {
        if range.is_empty() {
            return Ok(Vec::new());
        }
        let (nc, np) = (ex.configs.len(), ex.policies.len());
        // Dataset is the outermost dimension, so this range touches the
        // contiguous dataset span below — a shard never synthesises or
        // loads workloads outside its slice of the grid.
        let span = (range.start / (nc * np))..((range.end - 1) / (nc * np) + 1);

        // Phase 1 — profile the span's distinct datasets, one worker each
        // (bounded by the fan-out width). Dedup keeps first-occurrence
        // order.
        let mut unique: Vec<&WorkloadKey> = Vec::new();
        for k in &ex.datasets[span.clone()] {
            if !unique.contains(&k) {
                unique.push(k);
            }
        }
        let profile_workers = self.threads.clamp(1, unique.len());
        let next = AtomicUsize::new(0);
        let profile_errors: Vec<EngineError> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..profile_workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut errs = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= unique.len() {
                                break;
                            }
                            if let Err(e) = self.workload(unique[i]) {
                                errs.push(e);
                            }
                        }
                        errs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("profile worker panicked"))
                .collect()
        });
        if let Some(e) = profile_errors.into_iter().next() {
            return Err(e);
        }

        // Phase 2 — every cell in range, work-stealing over a shared
        // offset counter. All touched workloads are cache hits now. The
        // flat index decomposes over the legacy (dataset, config, policy)
        // view; the named coordinates decompose the same index over the
        // full dimension list — both are row-major, so they address the
        // same cell.
        // Each dataset resolves once per distinct operand format among the
        // expanded configs (a CSR-only sweep sees exactly the base
        // workload); the derivations are closed-form and happen here, so
        // the cell workers below never fault.
        let formats: Vec<SparseFormat> = {
            let mut v: Vec<SparseFormat> = ex.configs.iter().map(|c| c.operand_format).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let workloads: Vec<Option<BTreeMap<SparseFormat, Arc<Workload>>>> = ex
            .datasets
            .iter()
            .enumerate()
            .map(|(d, k)| {
                if !span.contains(&d) {
                    return Ok(None);
                }
                let mut per_fmt = BTreeMap::new();
                for &fmt in &formats {
                    per_fmt.insert(fmt, self.workload_for(k, fmt)?);
                }
                Ok(Some(per_fmt))
            })
            .collect::<Result<_, EngineError>>()?;
        let count = range.len();
        let next = AtomicUsize::new(0);
        let cell_workers = self.threads.clamp(1, count);
        let parts: Vec<Vec<(usize, CellResult)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cell_workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut out = Vec::new();
                        loop {
                            let o = next.fetch_add(1, Ordering::Relaxed);
                            if o >= count {
                                break;
                            }
                            let idx = range.start + o;
                            let (d, rem) = (idx / (nc * np), idx % (nc * np));
                            let (c, p) = (rem / np, rem % np);
                            let per_fmt =
                                workloads[d].as_ref().expect("dataset in range profiled");
                            let w = per_fmt
                                .get(&ex.configs[c].operand_format)
                                .expect("format derived for every config");
                            out.push((
                                o,
                                Self::run_cell(
                                    &ex.configs[c],
                                    w,
                                    ex.policies[p],
                                    model,
                                    coords_for(&ex.dims, idx),
                                ),
                            ));
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("sweep worker panicked")).collect()
        });

        let mut cells: Vec<Option<CellResult>> = vec![None; count];
        for (o, r) in parts.into_iter().flatten() {
            cells[o] = Some(r);
        }
        Ok(cells.into_iter().map(|c| c.expect("sweep cell computed")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_key() -> WorkloadKey {
        WorkloadKey::suite("wv", 7, 64)
    }

    #[test]
    fn workload_is_profiled_once_and_cached() {
        let engine = SimEngine::new();
        let w1 = engine.workload(&small_key()).unwrap();
        let w2 = engine.workload(&small_key()).unwrap();
        assert!(Arc::ptr_eq(&w1, &w2));
        assert_eq!(engine.profiles_run(), 1);
        assert_eq!(engine.cached_workloads(), 1);
    }

    #[test]
    fn dataset_aliases_share_one_profile() {
        // Suite name, abbreviation, and case variants canonicalise to the
        // same cache entry; scale 0 and 1 both mean "full size".
        let engine = SimEngine::new();
        let w1 = engine.workload(&WorkloadKey::suite("wikiVote", 7, 64)).unwrap();
        let w2 = engine.workload(&WorkloadKey::suite("wv", 7, 64)).unwrap();
        let w3 = engine.workload(&WorkloadKey::suite("WV", 7, 64)).unwrap();
        assert!(Arc::ptr_eq(&w1, &w2) && Arc::ptr_eq(&w2, &w3));
        let f0 = engine.workload(&WorkloadKey { dataset: "fb".into(), seed: 7, scale: 0 }).unwrap();
        let f1 = engine.workload(&WorkloadKey::suite("facebook", 7, 1)).unwrap();
        assert!(Arc::ptr_eq(&f0, &f1));
        assert_eq!(engine.profiles_run(), 2);
    }

    #[test]
    fn concurrent_misses_profile_once() {
        let engine = SimEngine::new();
        let key = small_key();
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    engine.workload(&key).unwrap();
                });
            }
        });
        assert_eq!(engine.profiles_run(), 1);
        assert_eq!(engine.cached_workloads(), 1);
    }

    #[test]
    fn disk_tier_hits_skip_synthesis_and_profiling() {
        let dir = std::env::temp_dir().join(format!("maple-engine-disk-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cold = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
        let w1 = cold.workload(&small_key()).unwrap();
        assert_eq!((cold.profiles_run(), cold.disk_hits(), cold.disk_stores()), (1, 0, 1));
        let warm = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
        let w2 = warm.workload(&small_key()).unwrap();
        assert_eq!((warm.profiles_run(), warm.disk_hits()), (0, 1));
        assert_eq!(*w1, *w2);
        assert_eq!(w1.checksum.to_bits(), w2.checksum.to_bits());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_dataset_is_an_error() {
        let engine = SimEngine::new();
        assert!(matches!(
            engine.workload(&WorkloadKey::suite("nope", 7, 1)),
            Err(EngineError::UnknownDataset(_))
        ));
    }

    #[test]
    fn sweep_grid_shape_and_profile_reuse() {
        let engine = SimEngine::new();
        let spec = SweepSpec::new(
            AcceleratorConfig::paper_configs(),
            vec![small_key(), WorkloadKey::suite("fb", 7, 64)],
            vec![Policy::RoundRobin, Policy::GreedyBalance],
        );
        let grid = engine.sweep(&spec).unwrap();
        assert_eq!(grid.cell_count(), 2 * 4 * 2);
        // One profile per distinct dataset, not per cell.
        assert_eq!(engine.profiles_run(), 2);
        // Grid indexing round-trips through iter().
        for (d, c, p, r) in grid.iter() {
            assert_eq!(grid.get(d, c, p), r);
        }
        // Cells match direct simulation of the cached workload, and an
        // analytic sweep attaches no DES result.
        let w = engine.workload(&small_key()).unwrap();
        let direct = simulate_workload(&spec.configs[2], &w, Policy::GreedyBalance);
        assert_eq!(grid.get(0, 2, 1).analytic, direct);
        assert_eq!(grid.cell_model, CellModel::Analytic);
        assert!(grid.iter().all(|(_, _, _, cell)| cell.des.is_none()));
        assert!(grid.des_out_of_band().is_empty());
    }

    #[test]
    fn des_cell_model_attaches_cross_validation() {
        let engine = SimEngine::new();
        for model in [CellModel::Des, CellModel::Both] {
            let spec = SweepSpec::paper(vec![small_key()]).with_cell_model(model);
            let grid = engine.sweep(&spec).unwrap();
            assert_eq!(grid.cell_model, model);
            for (_, c, _, cell) in grid.iter() {
                let des = cell.des.as_ref().unwrap_or_else(|| panic!("{model:?}: no DES"));
                assert!(des.cycles > 0 && !des.per_pe.is_empty());
                // DES ≥ analytic exactly, and inside the documented band.
                assert!(
                    cell.agreement_ratio().unwrap() >= 1.0,
                    "{}: ratio {:?}",
                    grid.configs[c],
                    cell.agreement_ratio()
                );
                assert_eq!(cell.des_in_band(), Some(true), "{}", grid.configs[c]);
                // `Des` makes the event-driven count authoritative.
                assert_eq!(cell.cycles(CellModel::Des), des.cycles);
                assert_eq!(cell.cycles(CellModel::Both), cell.analytic.cycles_compute);
            }
            assert!(grid.des_out_of_band().is_empty());
        }
    }

    #[test]
    fn simulate_cell_matches_sweep_cell() {
        let engine = SimEngine::new();
        let cfg = AcceleratorConfig::extensor_maple();
        let cell = engine
            .simulate_cell(&cfg, &small_key(), Policy::RoundRobin, CellModel::Both)
            .unwrap();
        let spec = SweepSpec::new(
            vec![cfg],
            vec![small_key()],
            vec![Policy::RoundRobin],
        )
        .with_cell_model(CellModel::Both);
        let grid = engine.sweep(&spec).unwrap();
        assert_eq!(grid.get(0, 0, 0), &cell);
    }

    #[test]
    fn sweep_matches_serial_reference_path() {
        // The engine must reproduce the pre-engine serial path exactly:
        // profile_workload + simulate_workload per cell.
        let engine = SimEngine::new();
        let key = small_key();
        let grid = engine.sweep(&SweepSpec::paper(vec![key.clone()])).unwrap();
        let spec = suite::by_name("wv").unwrap();
        let a = spec.generate_scaled(7, 64);
        let w = crate::sim::profile_workload(&a, &a);
        for (ci, cfg) in AcceleratorConfig::paper_configs().iter().enumerate() {
            let reference = simulate_workload(cfg, &w, Policy::RoundRobin);
            assert_eq!(grid.get(0, ci, 0).analytic, reference, "{}", cfg.name);
        }
    }

    #[test]
    fn unregistered_pe_model_fails_before_any_work() {
        let engine = SimEngine::new();
        let mut cfg = AcceleratorConfig::extensor_maple();
        cfg.pe.model = Some("no-such-pe".into());
        let r = engine.simulate(&cfg, &small_key(), Policy::RoundRobin);
        assert!(matches!(r, Err(EngineError::Pe(_))), "{r:?}");
        let r = engine.simulate_cell(&cfg, &small_key(), Policy::RoundRobin, CellModel::Both);
        assert!(matches!(r, Err(EngineError::Pe(_))), "{r:?}");
        let spec = SweepSpec::new(vec![cfg], vec![small_key()], vec![Policy::RoundRobin]);
        assert!(matches!(engine.sweep(&spec), Err(EngineError::Pe(_))));
        // The error fired before any profiling happened.
        assert_eq!(engine.profiles_run(), 0);
    }

    #[test]
    fn empty_sweep_dimensions_are_rejected() {
        let engine = SimEngine::new();
        let configs = AcceleratorConfig::paper_configs();
        let rr = vec![Policy::RoundRobin];
        for (spec, dim) in [
            (DesignSpace::new(vec![], vec![small_key()], rr.clone()), "configs"),
            (DesignSpace::new(configs.clone(), vec![], rr.clone()), "datasets"),
            (DesignSpace::new(configs.clone(), vec![small_key()], vec![]), "policies"),
            (
                DesignSpace::paper(vec![small_key()]).with_axis(Axis::macs_per_pe(vec![])),
                "macs",
            ),
            (DesignSpace::over(configs).with_axis(Axis::Policy(rr)), "datasets"),
        ] {
            match engine.sweep(&spec) {
                Err(EngineError::EmptySweep(d)) => assert_eq!(d, dim),
                other => panic!("expected EmptySweep({dim}), got {other:?}"),
            }
        }
    }

    #[test]
    fn absent_policy_axis_defaults_to_round_robin() {
        let engine = SimEngine::new();
        let explicit = engine.sweep(&SweepSpec::paper(vec![small_key()])).unwrap();
        let implicit = engine
            .sweep(
                &DesignSpace::over(AcceleratorConfig::paper_configs())
                    .with_axis(Axis::Dataset(vec![small_key()])),
            )
            .unwrap();
        assert_eq!(explicit, implicit);
        assert_eq!(implicit.policies, vec![Policy::RoundRobin]);
    }

    #[test]
    fn conflicting_and_invalid_axes_are_rejected() {
        let engine = SimEngine::new();
        let base = DesignSpace::paper(vec![small_key()]);
        let dup = base.clone().with_axis(Axis::Dataset(vec![small_key()]));
        assert!(matches!(engine.sweep(&dup), Err(EngineError::ConflictingAxes("dataset"))));
        let dup = base
            .clone()
            .with_axis(Axis::macs_per_pe(vec![2]))
            .with_axis(Axis::macs_per_pe(vec![4]));
        assert!(matches!(engine.sweep(&dup), Err(EngineError::ConflictingAxes("macs"))));
        let bad = base.clone().with_axis(Axis::macs_per_pe(vec![2, 0]));
        assert!(matches!(
            engine.sweep(&bad),
            Err(EngineError::InvalidAxisPoint("macs", _))
        ));
        let bad = base.with_axis(Axis::topology(vec![crate::noc::Topology::Mesh {
            width: 0,
            height: 4,
        }]));
        assert!(matches!(engine.sweep(&bad), Err(EngineError::InvalidAxisPoint("noc", _))));
        // Nothing was profiled for any rejected space.
        assert_eq!(engine.profiles_run(), 0);
    }

    #[test]
    fn axis_expansion_grid_shape_addressing_and_coords() {
        // The acceptance grid: noc × macs over one base config.
        let engine = SimEngine::new();
        let spec = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
            .with_axis(Axis::Dataset(vec![small_key()]))
            .with_axis(Axis::topology(vec![
                Topology::Crossbar { ports: 8 },
                Topology::Mesh { width: 4, height: 2 },
            ]))
            .with_axis(Axis::macs_per_pe(vec![2, 4, 8, 16]));
        let grid = engine.sweep(&spec).unwrap();
        assert_eq!(grid.shape(), vec![1, 1, 2, 4, 1]);
        assert_eq!(grid.cell_count(), 8);
        let names: Vec<&str> = grid.dims.iter().map(|d| d.name).collect();
        assert_eq!(names, ["dataset", "config", "noc", "macs", "policy"]);
        // Expanded config names are self-describing, in row-major order.
        assert_eq!(grid.configs[0], "extensor-maple+noc=crossbar:8+macs=2");
        assert_eq!(grid.configs[7], "extensor-maple+noc=mesh:4x2+macs=16");
        // N-d addressing, flat addressing, and the legacy 3-d view agree.
        let cell = grid.at(&[0, 0, 1, 2, 0]);
        let flat = grid.index_of(&[0, 0, 1, 2, 0]);
        assert_eq!(flat, 6);
        assert_eq!(grid.cell(flat), cell);
        assert_eq!(grid.get(0, 6, 0), cell);
        // Every cell carries full named coordinates consistent with its index.
        for idx in 0..grid.cell_count() {
            let c = grid.cell(idx);
            assert_eq!(c.coords.len(), grid.dims.len());
            let ix: Vec<usize> = c.coords.iter().map(|k| k.index).collect();
            assert_eq!(grid.index_of(&ix), idx);
            for (k, d) in c.coords.iter().zip(&grid.dims) {
                assert_eq!(k.axis, d.name);
                assert_eq!(k.label, d.labels[k.index]);
            }
        }
        assert_eq!(cell.coords[2].label, "mesh:4x2");
        assert_eq!(cell.coords[3].label, "8");
        // The transform really landed: cell results match a direct run of
        // the transformed config.
        let mut direct = AcceleratorConfig::extensor_maple();
        direct.noc = Topology::Mesh { width: 4, height: 2 };
        direct.pe.macs_per_pe = 8;
        direct.name = "extensor-maple+noc=mesh:4x2+macs=8".into();
        let w = engine.workload(&small_key()).unwrap();
        assert_eq!(cell.analytic, simulate_workload(&direct, &w, Policy::RoundRobin));
        // The one dataset was profiled exactly once for all eight cells.
        assert_eq!(engine.profiles_run(), 1);
    }

    #[test]
    fn axis_grid_is_deterministic_across_thread_counts() {
        let spec = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
            .with_axis(Axis::Dataset(vec![small_key()]))
            .with_axis(Axis::topology(vec![
                Topology::Crossbar { ports: 8 },
                Topology::Mesh { width: 4, height: 2 },
            ]))
            .with_axis(Axis::macs_per_pe(vec![2, 4, 8, 16]))
            .with_cell_model(CellModel::Both);
        let reference = SimEngine::new().with_threads(1).sweep(&spec).unwrap();
        for threads in [2, 4, 16] {
            let grid = SimEngine::new().with_threads(threads).sweep(&spec).unwrap();
            assert_eq!(grid, reference, "threads={threads}");
        }
    }

    #[test]
    fn every_config_axis_name_is_internable() {
        // Shard artifacts round-trip dimension names through
        // `intern_dim_name`; a new `ConfigAxis` kind must be added to its
        // KNOWN list or sharded sweeps fail only at merge time. The
        // wildcard-free match makes this test a compile error for any new
        // variant until it is listed here (and interned).
        let axes = [
            ConfigAxis::Topology(vec![Topology::Crossbar { ports: 8 }]),
            ConfigAxis::MacsPerPe(vec![2]),
            ConfigAxis::PrefetchDepth(vec![4]),
            ConfigAxis::PeModel(vec!["maple".into()]),
            ConfigAxis::Tiling(vec![crate::sparse::TileShape::new(64, 64)]),
            ConfigAxis::Format(vec![SparseFormat::Csr]),
        ];
        for a in &axes {
            let name = match a {
                ConfigAxis::Topology(_)
                | ConfigAxis::MacsPerPe(_)
                | ConfigAxis::PrefetchDepth(_)
                | ConfigAxis::PeModel(_)
                | ConfigAxis::Tiling(_)
                | ConfigAxis::Format(_) => a.name(),
            };
            assert_eq!(intern_dim_name(name), Some(name), "axis {name} not internable");
        }
        for fixed in ["dataset", "config", "policy"] {
            assert_eq!(intern_dim_name(fixed), Some(fixed));
        }
        assert_eq!(intern_dim_name("warp"), None);
    }

    #[test]
    fn fingerprint_tracks_space_content() {
        let base = SweepSpec::paper(vec![small_key()]);
        let fp = base.fingerprint().unwrap();
        // Deterministic, and cheap enough to call twice.
        assert_eq!(fp, base.fingerprint().unwrap());
        // Every content change moves it: dataset, scale, cell model, axis
        // grid, and a config knob hidden behind an unchanged name.
        assert_ne!(
            fp,
            SweepSpec::paper(vec![WorkloadKey::suite("fb", 7, 64)]).fingerprint().unwrap()
        );
        assert_ne!(
            fp,
            SweepSpec::paper(vec![WorkloadKey::suite("wv", 7, 32)]).fingerprint().unwrap()
        );
        assert_ne!(
            fp,
            base.clone().with_cell_model(CellModel::Both).fingerprint().unwrap()
        );
        assert_ne!(
            fp,
            base.clone().with_axis(Axis::macs_per_pe(vec![2, 4])).fingerprint().unwrap()
        );
        let mut configs = AcceleratorConfig::paper_configs();
        configs[0].pe.macs_per_pe *= 2; // same name, different hardware
        let knob = DesignSpace::new(configs, vec![small_key()], vec![Policy::RoundRobin]);
        assert_ne!(fp, knob.fingerprint().unwrap());
        // An invalid space has no fingerprint.
        assert!(DesignSpace::new(vec![], vec![small_key()], vec![Policy::RoundRobin])
            .fingerprint()
            .is_err());
    }

    #[test]
    fn topology_axis_changes_noc_accounting() {
        // A mesh pays more flit-hops than a crossbar for the same traffic,
        // so NoC energy must differ across the axis — the knob is live.
        let engine = SimEngine::new();
        let grid = engine
            .sweep(
                &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
                    .with_axis(Axis::Dataset(vec![small_key()]))
                    .with_axis(Axis::topology(vec![
                        Topology::Crossbar { ports: 8 },
                        Topology::Mesh { width: 4, height: 2 },
                    ])),
            )
            .unwrap();
        let (xbar, mesh) = (grid.cell(0), grid.cell(1));
        assert!(
            mesh.analytic.counters.noc_flit_hops > xbar.analytic.counters.noc_flit_hops
        );
        assert!(mesh.analytic.energy.noc_pj > xbar.analytic.energy.noc_pj);
    }

    #[test]
    fn workload_for_derives_from_one_shared_profile() {
        let engine = SimEngine::new();
        let key = small_key();
        let csr = engine.workload_for(&key, SparseFormat::Csr).unwrap();
        assert!(Arc::ptr_eq(&csr, &engine.workload(&key).unwrap()));
        let coo = engine.workload_for(&key, SparseFormat::Coo).unwrap();
        let alias = WorkloadKey::suite("wikiVote", 7, 64);
        let coo2 = engine.workload_for(&alias, SparseFormat::Coo).unwrap();
        assert!(Arc::ptr_eq(&coo, &coo2), "key aliases share one derivation");
        assert_eq!(engine.profiles_run(), 1);
        // Same profile, different traffic plan.
        assert_eq!(coo.profiles, csr.profiles);
        assert_eq!(coo.checksum.to_bits(), csr.checksum.to_bits());
        let plan = FormatPlan::from_totals(
            SparseFormat::Coo,
            csr.rows,
            csr.cols,
            csr.rows_b,
            csr.nnz_a,
            csr.nnz_b,
            csr.out_nnz,
        );
        assert_eq!(coo.fmt, plan);
        let native = FormatPlan::csr(csr.rows, csr.rows_b, csr.nnz_a, csr.nnz_b, csr.out_nnz);
        assert_eq!(csr.fmt, native);
    }

    #[test]
    fn format_axis_reprices_one_profile_and_keeps_csr_identical() {
        let engine = SimEngine::new();
        let base = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
            .with_axis(Axis::Dataset(vec![small_key()]))
            .with_axis(Axis::macs_per_pe(vec![2, 4]));
        let plain = engine.sweep(&base).unwrap();
        let grid = engine
            .sweep(&base.clone().with_axis(Axis::format(SparseFormat::ALL.to_vec())))
            .unwrap();
        assert_eq!(grid.shape(), vec![1, 1, 2, 5, 1]);
        let names: Vec<&str> = grid.dims.iter().map(|d| d.name).collect();
        assert_eq!(names, ["dataset", "config", "macs", "fmt", "policy"]);
        assert_eq!(grid.configs[0], "extensor-maple+macs=2+fmt=csr");
        assert_eq!(grid.configs[9], "extensor-maple+macs=4+fmt=blocked");
        // The whole grid re-prices the one profiled workload.
        assert_eq!(engine.profiles_run(), 1);
        for m in 0..2 {
            // The `fmt=csr` point is bit-identical to the formatless sweep;
            // only the expanded config label differs (`+fmt=csr`).
            let csr = grid.at(&[0, 0, m, 0, 0]);
            let base = &plain.at(&[0, 0, m, 0]).analytic;
            let mut relabeled = csr.analytic.clone();
            assert_eq!(relabeled.config, format!("{}+fmt=csr", base.config));
            relabeled.config = base.config.clone();
            assert_eq!(&relabeled, base);
            // Every non-CSR point pays its conversion pre-pass on top of
            // its own operand footprint, so its DRAM-bound time is longer.
            for f in 1..5 {
                let cell = grid.at(&[0, 0, m, f, 0]);
                assert!(
                    cell.analytic.cycles_dram_bound > csr.analytic.cycles_dram_bound,
                    "fmt point {f} not charged over CSR"
                );
            }
        }
    }

    #[test]
    fn format_axis_disk_tier_never_aliases_and_stays_deterministic() {
        let dir = std::env::temp_dir().join(format!("maple-engine-fmt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
            .with_axis(Axis::Dataset(vec![small_key()]))
            .with_axis(Axis::format(SparseFormat::ALL.to_vec()));
        let cold = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
        let cold_grid = cold.sweep(&spec).unwrap();
        assert_eq!((cold.profiles_run(), cold.disk_hits(), cold.disk_stores()), (1, 0, 1));
        // Warm run at a different fan-out: the base artifact and the four
        // format-keyed derivations load from disk (the latter are not disk
        // hits — the base profile is the expensive artifact). Nothing
        // aliases, so the grid is bit-identical to the cold one.
        let warm = SimEngine::new()
            .with_threads(4)
            .with_disk_cache(DiskCache::new(&dir).unwrap());
        let warm_grid = warm.sweep(&spec).unwrap();
        assert_eq!((warm.profiles_run(), warm.disk_hits()), (0, 1));
        assert_eq!(warm_grid, cold_grid);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
