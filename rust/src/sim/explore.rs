//! Search-driven design-space exploration.
//!
//! PR 4 made axes cheap to add and PR 5 made sweeps sharded, which makes
//! the grid itself the bottleneck: a 5-axis space blows past 10⁶ cells.
//! Following SparseMap (evolutionary search over mapping spaces) and
//! Sparseloop (cheap statistical density models standing in for exact
//! per-datum profiling), this module navigates a [`DesignSpace`] with a
//! guided search instead of enumeration:
//!
//! * [`Explorer`] runs hill-climb or a small (μ+λ) evolution strategy over
//!   the grid's flat indices. A mutation is one step along one typed axis;
//!   fitness is cycles / energy / EDP from the same per-cell dispatch the
//!   sweep path uses ([`SimEngine::run_cell`]).
//! * A **two-tier evaluator**: the search runs against the sampled
//!   profiler ([`profile_workload_sampled`]) — exact dimensions and product
//!   counts, estimated merge behaviour — and only the elite front is
//!   re-scored against the exact profile. The search is per dataset
//!   (dataset is the outermost grid dimension): "which MAC count / prefetch
//!   depth / topology per sparsity regime" is the Maple-paper question, and
//!   a cross-dataset argmin would answer nothing.
//! * Every evaluated point is memoized in an [`EvalJournal`] keyed by the
//!   design-space fingerprint and persisted through the engine's
//!   [`crate::sim::cache::DiskCache`], so repeated or warm searches cost
//!   near zero simulations.
//!
//! The budget counts fitness *calls* (memo hits included), so a warm
//! re-run walks the identical deterministic trajectory with zero fresh
//! simulations. [`exhaustive_argmin`] + [`check_against_exhaustive`]
//! compare a search against the full grid — the `maple explore
//! --exhaustive` gate and the BENCH_explore headline.

use std::collections::{BTreeMap, BTreeSet};
use std::str::FromStr;
use std::sync::Arc;
use std::time::Instant;

use crate::sim::engine::{
    coords_for, AxisCoord, AxisDim, CellModel, DesignSpace, EngineError, Expanded, SimEngine,
    SweepResult, WorkloadKey,
};
use crate::sim::profile::{estimate_in_band, profile_workload_sampled};
use crate::sim::Workload;
use crate::sparse::{suite, Csr, SplitMix64};

/// Journal tag for the exact-profile evaluator.
pub(crate) const TIER_EXACT: u8 = 0;
/// Journal tag for the sampled-estimate evaluator.
pub(crate) const TIER_ESTIMATE: u8 = 1;

/// What the search minimises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Authoritative cycle count under the space's cell model.
    Cycles,
    /// Total energy (pJ).
    Energy,
    /// Energy-delay product (cycles × pJ).
    Edp,
}

impl Objective {
    /// The scalar the search minimises for one evaluated cell.
    pub fn fitness(self, rec: &EvalRecord) -> f64 {
        match self {
            Objective::Cycles => rec.cycles as f64,
            Objective::Energy => rec.energy_pj,
            Objective::Edp => rec.cycles as f64 * rec.energy_pj,
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Objective::Cycles => "cycles",
            Objective::Energy => "energy",
            Objective::Edp => "edp",
        })
    }
}

impl FromStr for Objective {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "cycles" => Ok(Objective::Cycles),
            "energy" => Ok(Objective::Energy),
            "edp" => Ok(Objective::Edp),
            other => Err(format!("unknown objective {other} (cycles|energy|edp)")),
        }
    }
}

/// Which fitness evaluator(s) the search runs against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Exact profile for every evaluation (engine workload cache).
    Exact,
    /// Sampled-profile estimates only — fastest, fitness carries the
    /// estimator's error band.
    Estimate,
    /// Search on estimates, then re-score the elite front exactly.
    TwoTier,
}

impl std::fmt::Display for Tier {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Tier::Exact => "exact",
            Tier::Estimate => "estimate",
            Tier::TwoTier => "two-tier",
        })
    }
}

impl FromStr for Tier {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "exact" => Ok(Tier::Exact),
            "estimate" => Ok(Tier::Estimate),
            "two" | "two-tier" => Ok(Tier::TwoTier),
            other => Err(format!("unknown tier {other} (exact|estimate|two-tier)")),
        }
    }
}

/// The search strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Steepest-descent over ±1 axis steps, random restarts until the
    /// budget runs out.
    HillClimb,
    /// A (μ+λ) evolution strategy: `lambda` children per generation, each
    /// one axis-step mutation of a random parent; best `mu` survive.
    Evolution { mu: usize, lambda: usize },
}

impl std::fmt::Display for Strategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Strategy::HillClimb => f.write_str("hill-climb"),
            Strategy::Evolution { mu, lambda } => write!(f, "es:{mu}+{lambda}"),
        }
    }
}

impl FromStr for Strategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "hill" | "hill-climb" => Ok(Strategy::HillClimb),
            "es" | "evolution" => Ok(Strategy::Evolution { mu: 4, lambda: 8 }),
            other => {
                // es:MU+LAMBDA, e.g. es:2+6.
                if let Some(spec) = other.strip_prefix("es:") {
                    if let Some((mu, lambda)) = spec.split_once('+') {
                        if let (Ok(mu), Ok(lambda)) = (mu.parse(), lambda.parse()) {
                            if mu >= 1 && lambda >= 1 {
                                return Ok(Strategy::Evolution { mu, lambda });
                            }
                        }
                    }
                }
                Err(format!("unknown strategy {other} (hill|es|es:MU+LAMBDA)"))
            }
        }
    }
}

/// One memoized fitness evaluation: the authoritative cycle count under
/// the space's cell model and total energy — enough to reconstruct every
/// [`Objective`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalRecord {
    pub cycles: u64,
    pub energy_pj: f64,
}

/// The on-disk memo of one (design space, evaluator tier): every evaluated
/// flat grid index with its record. `sample_budget`/`sample_seed` are zero
/// for the exact tier and part of the cache key for the estimate tier (a
/// different sampling parameterisation is a different fitness function).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalJournal {
    /// The design-space fingerprint the indices are valid against.
    pub fingerprint: u64,
    /// [`TIER_EXACT`] or [`TIER_ESTIMATE`].
    pub tier: u8,
    pub sample_budget: u64,
    pub sample_seed: u64,
    /// Flat grid index → record, ordered (stable encoding).
    pub entries: BTreeMap<u64, EvalRecord>,
}

impl EvalJournal {
    /// An empty journal for the given key.
    pub fn empty(fingerprint: u64, tier: u8, sample_budget: u64, sample_seed: u64) -> Self {
        Self { fingerprint, tier, sample_budget, sample_seed, entries: BTreeMap::new() }
    }
}

/// One point of a search's best-so-far trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct TrajectoryPoint {
    /// Fitness calls consumed when this best was found.
    pub calls: usize,
    /// The (search-tier) fitness at that point.
    pub fitness: f64,
    /// Full-grid flat index of the point.
    pub index: usize,
}

/// The per-dataset outcome of one explore run.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSearch {
    pub dataset: String,
    /// Sub-grid size searched (all dimensions but dataset).
    pub cells: usize,
    /// Full-grid flat index of the best point found.
    pub best_index: usize,
    pub best_coords: Vec<AxisCoord>,
    /// Authoritative fitness of the best point (exact tier when the run
    /// re-scored exactly, estimate fitness for a pure estimate run).
    pub best_fitness: f64,
    pub best: EvalRecord,
    /// Estimate-tier fitness of the best point (two-tier runs).
    pub estimate_fitness: Option<f64>,
    /// Fresh exact simulations this dataset's search ran.
    pub evals_exact: usize,
    /// Fresh estimate-tier simulations this dataset's search ran.
    pub evals_estimate: usize,
    /// Fitness calls answered by the in-run memo.
    pub memo_hits: usize,
    /// Fitness calls answered by the preloaded disk journal.
    pub journal_hits: usize,
    pub trajectory: Vec<TrajectoryPoint>,
    pub wall_ms: u64,
}

/// The outcome of one [`Explorer::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreResult {
    pub objective: Objective,
    pub strategy: Strategy,
    pub tier: Tier,
    /// Fitness-call budget per dataset.
    pub budget: usize,
    /// Full grid size (what an exhaustive sweep would evaluate).
    pub grid_cells: usize,
    pub fingerprint: u64,
    pub dims: Vec<AxisDim>,
    pub searches: Vec<DatasetSearch>,
    pub wall_ms: u64,
}

impl ExploreResult {
    /// Fresh exact simulations across all datasets (elite re-scoring
    /// included).
    pub fn evals_exact(&self) -> usize {
        self.searches.iter().map(|s| s.evals_exact).sum()
    }

    /// Fresh estimate-tier simulations across all datasets.
    pub fn evals_estimate(&self) -> usize {
        self.searches.iter().map(|s| s.evals_estimate).sum()
    }

    /// All fresh simulations — the number the ≥100× headline compares to
    /// [`ExploreResult::grid_cells`].
    pub fn evals_total(&self) -> usize {
        self.evals_exact() + self.evals_estimate()
    }

    /// Fresh simulations as a fraction of the exhaustive grid.
    pub fn eval_fraction(&self) -> f64 {
        self.evals_total() as f64 / self.grid_cells.max(1) as f64
    }

    pub fn memo_hits(&self) -> usize {
        self.searches.iter().map(|s| s.memo_hits).sum()
    }

    pub fn journal_hits(&self) -> usize {
        self.searches.iter().map(|s| s.journal_hits).sum()
    }
}

/// Per-dataset comparison against the exhaustive grid optimum.
#[derive(Debug, Clone, PartialEq)]
pub struct ExhaustiveBest {
    pub dataset: String,
    /// Flat index of the exhaustive argmin.
    pub best_index: usize,
    pub best_fitness: f64,
    /// The search's best fitness on the same (authoritative) scale.
    pub search_fitness: f64,
    /// The search found the argmin itself (same cell or bit-equal fitness).
    pub argmin_match: bool,
    /// The search landed within [`crate::sim::ESTIMATE_BAND`] of the
    /// optimum.
    pub in_band: bool,
}

/// The exhaustive-sweep side of a `maple explore --exhaustive` comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct ExhaustiveCheck {
    pub cells: usize,
    pub wall_ms: u64,
    pub per_dataset: Vec<ExhaustiveBest>,
}

impl ExhaustiveCheck {
    /// Whether every dataset's search result sits inside the band.
    pub fn all_in_band(&self) -> bool {
        self.per_dataset.iter().all(|d| d.in_band)
    }
}

/// Per-dataset `(flat index, fitness)` argmin of a full sweep grid under
/// `objective` — the ground truth a search is judged against.
pub fn exhaustive_argmin(grid: &SweepResult, objective: Objective) -> Vec<(usize, f64)> {
    let nd = grid.datasets.len().max(1);
    let per = grid.cell_count() / nd;
    (0..grid.datasets.len())
        .map(|d| {
            let mut best = (d * per, f64::INFINITY);
            for i in d * per..(d + 1) * per {
                let cell = grid.cell(i);
                let rec = EvalRecord {
                    cycles: cell.cycles(grid.cell_model),
                    energy_pj: cell.analytic.energy.total_pj(),
                };
                let f = objective.fitness(&rec);
                if f < best.1 {
                    best = (i, f);
                }
            }
            best
        })
        .collect()
}

/// Compare a finished search against the exhaustive sweep of the same
/// space (`wall_ms` is the sweep's wall-clock).
pub fn check_against_exhaustive(
    result: &ExploreResult,
    grid: &SweepResult,
    wall_ms: u64,
) -> ExhaustiveCheck {
    let argmin = exhaustive_argmin(grid, result.objective);
    let per_dataset = result
        .searches
        .iter()
        .zip(&argmin)
        .map(|(s, &(best_index, best_fitness))| ExhaustiveBest {
            dataset: s.dataset.clone(),
            best_index,
            best_fitness,
            search_fitness: s.best_fitness,
            argmin_match: s.best_index == best_index || s.best_fitness == best_fitness,
            in_band: estimate_in_band(best_fitness, s.best_fitness),
        })
        .collect();
    ExhaustiveCheck { cells: grid.cell_count(), wall_ms, per_dataset }
}

/// Synthesise the suite matrix a [`WorkloadKey`] names — the estimate
/// tier's input (and `maple estval`'s), bypassing the exact profile.
pub fn suite_matrix(key: &WorkloadKey) -> Result<Csr, EngineError> {
    let spec = suite::by_name(&key.dataset)
        .ok_or_else(|| EngineError::UnknownDataset(key.dataset.clone()))?;
    Ok(if key.scale.max(1) <= 1 {
        spec.generate(key.seed)
    } else {
        spec.generate_scaled(key.seed, key.scale)
    })
}

/// Search parameters; see the field docs for the knobs the CLI exposes.
#[derive(Debug, Clone, PartialEq)]
pub struct ExploreSpec {
    pub objective: Objective,
    pub strategy: Strategy,
    pub tier: Tier,
    /// Fitness calls per dataset (memo hits count, so warm re-runs walk
    /// the identical trajectory and terminate).
    pub budget: usize,
    /// Points of the estimate front re-scored exactly ([`Tier::TwoTier`]).
    pub elite: usize,
    /// Row budget of the sampled profiler (estimate tier).
    pub sample_budget: usize,
    /// Seed for both the search RNG and the sampled profiler.
    pub seed: u64,
}

impl Default for ExploreSpec {
    fn default() -> Self {
        Self {
            objective: Objective::Cycles,
            strategy: Strategy::Evolution { mu: 4, lambda: 8 },
            tier: Tier::TwoTier,
            budget: 64,
            elite: 4,
            sample_budget: 128,
            seed: 7,
        }
    }
}

/// In-run state of one evaluator tier: the (journal-backed) memo plus hit
/// counters.
struct TierState {
    journal: EvalJournal,
    /// Indices present when the journal was loaded from disk; first touch
    /// of one counts as a journal hit, later touches as memo hits.
    preloaded: BTreeSet<u64>,
    fresh: usize,
    memo_hits: usize,
    journal_hits: usize,
}

impl TierState {
    fn new(journal: EvalJournal) -> Self {
        let preloaded = journal.entries.keys().copied().collect();
        Self { journal, preloaded, fresh: 0, memo_hits: 0, journal_hits: 0 }
    }

    fn lookup(&mut self, idx: u64) -> Option<EvalRecord> {
        let rec = self.journal.entries.get(&idx).copied()?;
        if self.preloaded.remove(&idx) {
            self.journal_hits += 1;
        } else {
            self.memo_hits += 1;
        }
        Some(rec)
    }

    fn insert(&mut self, idx: u64, rec: EvalRecord) {
        self.journal.entries.insert(idx, rec);
        self.fresh += 1;
    }

    fn snapshot(&self) -> (usize, usize, usize) {
        (self.fresh, self.memo_hits, self.journal_hits)
    }
}

/// The per-dataset fitness oracle: lazily materialises the exact workload
/// (engine cache) and/or the sampled estimate, and dispatches cells
/// through the same [`SimEngine::run_cell`] the sweep path uses.
struct Eval<'a> {
    engine: &'a SimEngine,
    ex: &'a Expanded,
    model: CellModel,
    key: &'a WorkloadKey,
    sample_budget: usize,
    sample_seed: u64,
    exact_w: Option<Arc<Workload>>,
    estimate_w: Option<Arc<Workload>>,
}

impl Eval<'_> {
    fn record(
        &mut self,
        state: &mut TierState,
        idx: u64,
        exact: bool,
    ) -> Result<EvalRecord, EngineError> {
        if let Some(rec) = state.lookup(idx) {
            return Ok(rec);
        }
        let w = if exact { self.exact_workload()? } else { self.estimate_workload()? };
        let (nc, np) = (self.ex.configs.len(), self.ex.policies.len());
        let i = idx as usize;
        let rem = i % (nc * np);
        let (c, p) = (rem / np, rem % np);
        let cell = SimEngine::run_cell(
            &self.ex.configs[c],
            &w,
            self.ex.policies[p],
            self.model,
            coords_for(&self.ex.dims, i),
        );
        let rec = EvalRecord {
            cycles: cell.cycles(self.model),
            energy_pj: cell.analytic.energy.total_pj(),
        };
        state.insert(idx, rec);
        Ok(rec)
    }

    fn exact_workload(&mut self) -> Result<Arc<Workload>, EngineError> {
        if self.exact_w.is_none() {
            self.exact_w = Some(self.engine.workload(self.key)?);
        }
        Ok(Arc::clone(self.exact_w.as_ref().expect("just filled")))
    }

    /// Synthesis + sampled profile — `O(nnz + sampled products)` instead of
    /// the exact pass's `O(total products)`; never persisted as a workload
    /// artifact (only its fitness evaluations are journaled).
    fn estimate_workload(&mut self) -> Result<Arc<Workload>, EngineError> {
        if self.estimate_w.is_none() {
            let a = suite_matrix(self.key)?;
            let est = profile_workload_sampled(&a, &a, self.sample_budget, self.sample_seed);
            self.estimate_w = Some(Arc::new(est.workload));
        }
        Ok(Arc::clone(self.estimate_w.as_ref().expect("just filled")))
    }
}

/// One in-flight dataset search: budget accounting, the evaluated-point
/// map, and the best-so-far trajectory.
struct Search<'a, 'b> {
    eval: &'a mut Eval<'b>,
    state: &'a mut TierState,
    exact: bool,
    objective: Objective,
    evaluated: &'a mut BTreeMap<u64, f64>,
    trajectory: &'a mut Vec<TrajectoryPoint>,
    calls: usize,
    budget: usize,
    best: Option<(u64, f64)>,
}

impl Search<'_, '_> {
    fn exhausted(&self) -> bool {
        self.calls >= self.budget
    }

    fn eval_point(&mut self, idx: u64) -> Result<f64, EngineError> {
        let rec = self.eval.record(self.state, idx, self.exact)?;
        self.calls += 1;
        let fit = self.objective.fitness(&rec);
        self.evaluated.insert(idx, fit);
        let improved = match self.best {
            Some((_, b)) => fit < b,
            None => true,
        };
        if improved {
            self.best = Some((idx, fit));
            self.trajectory.push(TrajectoryPoint {
                calls: self.calls,
                fitness: fit,
                index: idx as usize,
            });
        }
        Ok(fit)
    }
}

/// Flat row-major index of per-dimension coordinates.
fn flat_index(dims: &[AxisDim], coords: &[usize]) -> u64 {
    coords.iter().zip(dims).fold(0u64, |acc, (&c, d)| acc * d.len() as u64 + c as u64)
}

/// A uniform random point of dataset `d`'s sub-grid.
fn random_point(dims: &[AxisDim], d: usize, rng: &mut SplitMix64) -> Vec<usize> {
    let mut p: Vec<usize> =
        dims.iter().map(|dim| rng.below(dim.len() as u64) as usize).collect();
    p[0] = d;
    p
}

/// One mutation: a step along one searchable axis. Three times out of
/// four a ±1 step with wraparound (ordered axes like MACs/prefetch); one
/// time in four a uniform jump to a different point, which keeps the
/// search ergodic on categorical axes (policy, topology, PE model).
fn mutate(
    point: &[usize],
    dims: &[AxisDim],
    searchable: &[usize],
    rng: &mut SplitMix64,
) -> Vec<usize> {
    let mut out = point.to_vec();
    let j = searchable[rng.below(searchable.len() as u64) as usize];
    let len = dims[j].len();
    let cur = out[j];
    out[j] = if rng.below(4) == 0 {
        let mut v = rng.below((len - 1) as u64) as usize;
        if v >= cur {
            v += 1;
        }
        v
    } else if rng.below(2) == 0 {
        (cur + 1) % len
    } else {
        (cur + len - 1) % len
    };
    out
}

/// Steepest-descent over ±1 axis steps with random restarts.
fn hill_climb(
    s: &mut Search<'_, '_>,
    dims: &[AxisDim],
    d: usize,
    rng: &mut SplitMix64,
) -> Result<(), EngineError> {
    let searchable: Vec<usize> = (1..dims.len()).filter(|&j| dims[j].len() > 1).collect();
    while !s.exhausted() {
        let mut cur = random_point(dims, d, rng);
        let mut cur_fit = s.eval_point(flat_index(dims, &cur))?;
        if searchable.is_empty() {
            break;
        }
        'climb: loop {
            let mut next: Option<(Vec<usize>, f64)> = None;
            for &j in &searchable {
                for dir in [-1i64, 1] {
                    let v = cur[j] as i64 + dir;
                    if v < 0 || v >= dims[j].len() as i64 {
                        continue;
                    }
                    if s.exhausted() {
                        break 'climb;
                    }
                    let mut cand = cur.clone();
                    cand[j] = v as usize;
                    let fit = s.eval_point(flat_index(dims, &cand))?;
                    if fit < next.as_ref().map_or(cur_fit, |(_, f)| *f) {
                        next = Some((cand, fit));
                    }
                }
            }
            match next {
                Some((p, f)) => {
                    cur = p;
                    cur_fit = f;
                }
                None => break,
            }
        }
    }
    Ok(())
}

/// The (μ+λ) evolution strategy.
fn evolution(
    s: &mut Search<'_, '_>,
    dims: &[AxisDim],
    d: usize,
    rng: &mut SplitMix64,
    mu: usize,
    lambda: usize,
) -> Result<(), EngineError> {
    let searchable: Vec<usize> = (1..dims.len()).filter(|&j| dims[j].len() > 1).collect();
    let mut pop: Vec<(Vec<usize>, f64)> = Vec::new();
    for _ in 0..mu {
        if s.exhausted() {
            return Ok(());
        }
        let p = random_point(dims, d, rng);
        let f = s.eval_point(flat_index(dims, &p))?;
        pop.push((p, f));
    }
    if searchable.is_empty() {
        return Ok(());
    }
    while !s.exhausted() {
        let parents = pop.len();
        for _ in 0..lambda {
            if s.exhausted() {
                break;
            }
            let parent = pop[rng.below(parents as u64) as usize].0.clone();
            let child = mutate(&parent, dims, &searchable, rng);
            let f = s.eval_point(flat_index(dims, &child))?;
            pop.push((child, f));
        }
        // Stable sort → deterministic survivor set under fitness ties.
        pop.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        pop.truncate(mu);
    }
    Ok(())
}

/// The search driver. Borrows an engine (for the workload cache tiers and
/// the disk journal) and owns the space + spec for one run.
pub struct Explorer<'e> {
    engine: &'e SimEngine,
    space: DesignSpace,
    spec: ExploreSpec,
}

impl<'e> Explorer<'e> {
    pub fn new(engine: &'e SimEngine, space: DesignSpace, spec: ExploreSpec) -> Self {
        Self { engine, space, spec }
    }

    /// Run the search over every dataset of the space. Deterministic for a
    /// fixed (space, spec): the RNG streams, the call budget, and the
    /// tie-breaking are all fixed, and memo hits consume budget exactly
    /// like fresh evaluations — so a warm run reproduces the cold run's
    /// answer with zero fresh simulations.
    pub fn run(&self) -> Result<ExploreResult, EngineError> {
        // vet:allow(wall-clock): bench wall-clock for the explore report only, never a fitness input
        let t_run = Instant::now();
        let ex = self.space.expand()?;
        for cfg in &ex.configs {
            crate::pe::registry::build(cfg)?;
        }
        let fingerprint = ex.fingerprint(self.space.cell_model);
        let spec = &self.spec;
        let disk = self.engine.disk_cache();
        let needs_exact = spec.tier != Tier::Estimate;
        let needs_estimate = spec.tier != Tier::Exact;
        let load = |wanted: bool, tier: u8, budget: u64, seed: u64| {
            wanted
                .then(|| disk.and_then(|d| d.load_evals(fingerprint, tier, budget, seed)))
                .flatten()
                .unwrap_or_else(|| EvalJournal::empty(fingerprint, tier, budget, seed))
        };
        let mut exact_state = TierState::new(load(needs_exact, TIER_EXACT, 0, 0));
        let mut estimate_state = TierState::new(load(
            needs_estimate,
            TIER_ESTIMATE,
            spec.sample_budget as u64,
            spec.seed,
        ));

        let mut searches = Vec::with_capacity(ex.datasets.len());
        for (d, key) in ex.datasets.iter().enumerate() {
            searches.push(self.search_dataset(
                &ex,
                d,
                key,
                &mut exact_state,
                &mut estimate_state,
            )?);
        }

        // Publish the journals best-effort (like workload artifacts, a
        // full disk must never fail a search).
        if let Some(disk) = disk {
            if needs_exact && exact_state.fresh > 0 {
                let _ = disk.store_evals(&exact_state.journal);
            }
            if needs_estimate && estimate_state.fresh > 0 {
                let _ = disk.store_evals(&estimate_state.journal);
            }
        }

        Ok(ExploreResult {
            objective: spec.objective,
            strategy: spec.strategy,
            tier: spec.tier,
            budget: spec.budget,
            grid_cells: ex.total_cells(),
            fingerprint,
            dims: ex.dims.clone(),
            searches,
            wall_ms: t_run.elapsed().as_millis() as u64,
        })
    }

    fn search_dataset(
        &self,
        ex: &Expanded,
        d: usize,
        key: &WorkloadKey,
        exact_state: &mut TierState,
        estimate_state: &mut TierState,
    ) -> Result<DatasetSearch, EngineError> {
        // vet:allow(wall-clock): bench wall-clock for the per-dataset report only, never a fitness input
        let t0 = Instant::now();
        let spec = &self.spec;
        let exact_before = exact_state.snapshot();
        let estimate_before = estimate_state.snapshot();
        let cells: usize = ex.dims[1..].iter().map(|x| x.len()).product();
        let mut eval = Eval {
            engine: self.engine,
            ex,
            model: self.space.cell_model,
            key,
            sample_budget: spec.sample_budget,
            sample_seed: spec.seed,
            exact_w: None,
            estimate_w: None,
        };
        // One independent, deterministic RNG stream per dataset.
        let mut rng = SplitMix64::new(
            spec.seed ^ 0x5851_F42D_4C95_7F2Du64.wrapping_mul(d as u64 + 1),
        );
        let mut evaluated: BTreeMap<u64, f64> = BTreeMap::new();
        let mut trajectory = Vec::new();
        let search_exact = spec.tier == Tier::Exact;
        {
            let mut s = Search {
                eval: &mut eval,
                state: if search_exact { &mut *exact_state } else { &mut *estimate_state },
                exact: search_exact,
                objective: spec.objective,
                evaluated: &mut evaluated,
                trajectory: &mut trajectory,
                calls: 0,
                budget: spec.budget.max(1),
                best: None,
            };
            match spec.strategy {
                Strategy::HillClimb => hill_climb(&mut s, &ex.dims, d, &mut rng)?,
                Strategy::Evolution { mu, lambda } => {
                    evolution(&mut s, &ex.dims, d, &mut rng, mu.max(1), lambda.max(1))?
                }
            }
        }

        // The search-tier front, best first; ties break on the lower index
        // (BTreeMap iteration order + strict improvement keep this
        // deterministic).
        let mut front: Vec<(f64, u64)> = evaluated.iter().map(|(&i, &f)| (f, i)).collect();
        front.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });

        let (best_index, best_fitness, best, estimate_fitness) = match spec.tier {
            Tier::Exact | Tier::Estimate => {
                let &(fit, idx) = front.first().expect("budget ≥ 1 evaluates a point");
                let state = if search_exact { &mut *exact_state } else { &mut *estimate_state };
                let rec = state.journal.entries[&idx];
                (idx, fit, rec, None)
            }
            Tier::TwoTier => {
                let mut best: Option<(u64, f64, EvalRecord, f64)> = None;
                for &(est_fit, idx) in front.iter().take(spec.elite.max(1)) {
                    let rec = eval.record(exact_state, idx, true)?;
                    let fit = spec.objective.fitness(&rec);
                    let improved = match best {
                        Some((_, b, _, _)) => fit < b,
                        None => true,
                    };
                    if improved {
                        best = Some((idx, fit, rec, est_fit));
                    }
                }
                let (idx, fit, rec, est_fit) = best.expect("elite front non-empty");
                (idx, fit, rec, Some(est_fit))
            }
        };

        let exact_after = exact_state.snapshot();
        let estimate_after = estimate_state.snapshot();
        Ok(DatasetSearch {
            dataset: key.dataset.clone(),
            cells,
            best_index: best_index as usize,
            best_coords: coords_for(&ex.dims, best_index as usize),
            best_fitness,
            best,
            estimate_fitness,
            evals_exact: exact_after.0 - exact_before.0,
            evals_estimate: estimate_after.0 - estimate_before.0,
            memo_hits: (exact_after.1 - exact_before.1) + (estimate_after.1 - estimate_before.1),
            journal_hits: (exact_after.2 - exact_before.2)
                + (estimate_after.2 - estimate_before.2),
            trajectory,
            wall_ms: t0.elapsed().as_millis() as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objective_strategy_tier_parse_and_display() {
        assert_eq!("cycles".parse::<Objective>().unwrap(), Objective::Cycles);
        assert_eq!("edp".parse::<Objective>().unwrap(), Objective::Edp);
        assert!("speed".parse::<Objective>().is_err());
        assert_eq!("hill".parse::<Strategy>().unwrap(), Strategy::HillClimb);
        assert_eq!(
            "es".parse::<Strategy>().unwrap(),
            Strategy::Evolution { mu: 4, lambda: 8 }
        );
        assert_eq!(
            "es:2+6".parse::<Strategy>().unwrap(),
            Strategy::Evolution { mu: 2, lambda: 6 }
        );
        assert!("es:0+3".parse::<Strategy>().is_err());
        assert_eq!("two".parse::<Tier>().unwrap(), Tier::TwoTier);
        assert_eq!("exact".parse::<Tier>().unwrap(), Tier::Exact);
        assert_eq!(Tier::TwoTier.to_string(), "two-tier");
        assert_eq!(Strategy::Evolution { mu: 4, lambda: 8 }.to_string(), "es:4+8");
    }

    #[test]
    fn objective_fitness_definitions() {
        let rec = EvalRecord { cycles: 100, energy_pj: 2.5 };
        assert_eq!(Objective::Cycles.fitness(&rec), 100.0);
        assert_eq!(Objective::Energy.fitness(&rec), 2.5);
        assert_eq!(Objective::Edp.fitness(&rec), 250.0);
    }

    #[test]
    fn flat_index_is_row_major() {
        let dims = vec![
            AxisDim { name: "dataset", labels: vec!["a".into(), "b".into()] },
            AxisDim { name: "config", labels: vec!["x".into(), "y".into(), "z".into()] },
            AxisDim { name: "policy", labels: vec!["p".into(), "q".into()] },
        ];
        assert_eq!(flat_index(&dims, &[0, 0, 0]), 0);
        assert_eq!(flat_index(&dims, &[0, 0, 1]), 1);
        assert_eq!(flat_index(&dims, &[0, 1, 0]), 2);
        assert_eq!(flat_index(&dims, &[1, 2, 1]), 11);
    }

    #[test]
    fn mutate_changes_exactly_one_searchable_dim() {
        let dims = vec![
            AxisDim { name: "dataset", labels: vec!["a".into()] },
            AxisDim { name: "macs", labels: (0..6).map(|i| i.to_string()).collect() },
            AxisDim { name: "policy", labels: vec!["p".into(), "q".into()] },
        ];
        let searchable = vec![1usize, 2];
        let mut rng = SplitMix64::new(42);
        let point = vec![0usize, 3, 1];
        for _ in 0..200 {
            let m = mutate(&point, &dims, &searchable, &mut rng);
            let diff: Vec<usize> =
                (0..3).filter(|&j| m[j] != point[j]).collect();
            assert_eq!(diff.len(), 1, "{m:?}");
            assert!(searchable.contains(&diff[0]));
            assert!(m[diff[0]] < dims[diff[0]].len());
        }
    }
}
