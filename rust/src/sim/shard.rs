//! Sharded multi-process sweeps: partition a [`DesignSpace`] cell grid by
//! contiguous flat-index range, run each range in its own process (or CI
//! job), and merge the on-disk shard artifacts back into the exact
//! [`SweepResult`] a single-process sweep would have produced.
//!
//! The grid made this possible: PR 4's axis refactor made every sweep cell
//! a pure function of its flat row-major index, so a [`ShardSpec`] only
//! has to name *which* contiguous index range a process owns — the same
//! shard-then-reduce shape distributed dataframe systems use. The pieces:
//!
//! * [`ShardSpec`] — `index/count`, parsed from the CLI as `--shard i/n`;
//!   [`ShardSpec::range`] splits `0..total` into `count` contiguous,
//!   near-equal ranges that tile the grid exactly.
//! * [`SweepShard`] — one executed range plus the full grid metadata
//!   (dims, datasets, configs, policies, cell model), the space
//!   fingerprint ([`DesignSpace::fingerprint`]), and per-shard run stats.
//!   Persisted through the [`crate::sim::cache`] codec envelope (magic
//!   `MAPLESHD`, same version/checksum discipline) via
//!   [`SweepShard::write_to`] / [`read_dir`].
//! * [`merge`] — validates that a shard set is complete and compatible
//!   (one fingerprint, one shard count, no missing or duplicate shards,
//!   ranges tiling the grid exactly) and reassembles the [`SweepResult`].
//!   Every violation is a hard error: a partial merge must never pass for
//!   a full-grid result.
//!
//! Unlike the workload cache — where a bad artifact is silently evicted
//! and recomputed — shard artifacts fail *loudly*: a merge that cannot
//! prove it has every cell of the one intended grid exits non-zero.
//!
//! [`DesignSpace`]: crate::sim::DesignSpace
//! [`DesignSpace::fingerprint`]: crate::sim::DesignSpace::fingerprint

use std::fmt;
use std::fs;
use std::io;
use std::ops::Range;
use std::path::{Path, PathBuf};

use crate::coordinator::Policy;
use crate::sim::cache::codec::{self, CodecError};
use crate::sim::cache::CODEC_VERSION;
use crate::sim::engine::{AxisDim, CellModel, CellResult, SweepResult, WorkloadKey};

/// Shard artifact file extension (the full name also carries the codec
/// version, so a version bump starts cold without touching old files).
pub const SHARD_EXT: &str = "mshd";

/// Shard-layer errors. Merge-side variants are deliberately loud and
/// specific: CI logs must say *which* invariant a bad shard set broke.
#[derive(Debug, thiserror::Error)]
pub enum ShardError {
    #[error("invalid shard {index}/{count}: need index < count and count >= 1")]
    InvalidSpec { index: usize, count: usize },
    #[error("bad shard spec {0:?}: expected i/n, e.g. 0/4")]
    BadSpec(String),
    #[error("cannot merge an empty shard set")]
    Empty,
    #[error("no shard artifacts (*.mshd) in {}", .0.display())]
    NoShards(PathBuf),
    #[error("{}: {source}", .path.display())]
    Io {
        path: PathBuf,
        #[source]
        source: io::Error,
    },
    #[error("shard artifact {} is invalid: {source}", .path.display())]
    Artifact {
        path: PathBuf,
        #[source]
        source: CodecError,
    },
    #[error(
        "shard {index}/{count} fingerprint {found:#018x} != {expected:#018x}: \
         shards come from different design spaces"
    )]
    FingerprintMismatch { index: usize, count: usize, expected: u64, found: u64 },
    #[error("shard count mismatch: {a}-way and {b}-way shards cannot merge")]
    CountMismatch { a: usize, b: usize },
    #[error("duplicate shard {index}/{count}: overlapping cell ranges")]
    DuplicateShard { index: usize, count: usize },
    #[error("missing shards {missing:?} of a {count}-way split: gap in the cell grid")]
    MissingShards { missing: Vec<usize>, count: usize },
    #[error(
        "shard {index}/{count} covers cells {found_start}..{found_end} but the grid \
         expects it to start at {expected_start}"
    )]
    RangeMismatch {
        index: usize,
        count: usize,
        found_start: usize,
        found_end: usize,
        expected_start: usize,
    },
    #[error("incompatible shards: {0}")]
    Incompatible(String),
}

/// Which contiguous slice of a sweep grid one process owns: shard `index`
/// of a `count`-way split (zero-based, so the CLI spelling is `--shard
/// 0/4` … `--shard 3/4`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    /// A validated spec (`index < count`, `count ≥ 1`).
    pub fn new(index: usize, count: usize) -> Result<Self, ShardError> {
        let spec = Self { index, count };
        spec.validate()?;
        Ok(spec)
    }

    /// Re-check the invariant (the fields are public, so a hand-built or
    /// decoded spec revalidates before use).
    pub fn validate(&self) -> Result<(), ShardError> {
        if self.count == 0 || self.index >= self.count {
            return Err(ShardError::InvalidSpec { index: self.index, count: self.count });
        }
        Ok(())
    }

    /// This shard's contiguous flat-index range over a grid of `total`
    /// cells. Cells split as evenly as possible — the first `total %
    /// count` shards take one extra — so the `count` ranges tile
    /// `0..total` exactly, in index order, and no two shard sizes differ
    /// by more than one cell. With `count > total`, trailing shards are
    /// empty (and still required at merge time: an empty shard proves its
    /// slice was computed, not lost).
    pub fn range(&self, total: usize) -> Range<usize> {
        let base = total / self.count;
        let extra = total % self.count;
        let start = self.index * base + self.index.min(extra);
        let len = base + usize::from(self.index < extra);
        start..start + len
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

impl std::str::FromStr for ShardSpec {
    type Err = ShardError;

    fn from_str(s: &str) -> Result<Self, ShardError> {
        let (i, n) = s.split_once('/').ok_or_else(|| ShardError::BadSpec(s.into()))?;
        let index = i.trim().parse().map_err(|_| ShardError::BadSpec(s.into()))?;
        let count = n.trim().parse().map_err(|_| ShardError::BadSpec(s.into()))?;
        ShardSpec::new(index, count)
    }
}

/// Per-shard run statistics, persisted in the artifact so the merge job
/// can report wall-times and warm-vs-cold cache behaviour without access
/// to the shard processes (the `BENCH_sweep.json` inputs).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ShardMeta {
    /// Wall-clock of the shard's profile + simulate phases, milliseconds.
    pub wall_ms: u64,
    /// Workloads this shard profiled from scratch (cold).
    pub profiles_run: u64,
    /// Workloads this shard loaded from the disk cache (warm).
    pub disk_hits: u64,
    /// The engine's profile-pass chunk count. Checksum bits depend on it,
    /// so all shards of one merge must agree — it is part of the
    /// compatibility check even though it is not part of the space.
    pub profile_threads: usize,
}

/// One executed shard: a contiguous run of grid cells plus everything
/// needed to validate and reassemble the full [`SweepResult`]. `cells[i]`
/// is grid cell `start + i`; the grid metadata is carried whole (it is
/// tiny next to the cells) so `merge` needs no access to the original
/// [`crate::sim::DesignSpace`].
#[derive(Debug, Clone, PartialEq)]
pub struct SweepShard {
    /// [`crate::sim::DesignSpace::fingerprint`] of the space that produced
    /// this shard.
    pub fingerprint: u64,
    /// Which slice of which split this is.
    pub spec: ShardSpec,
    /// First flat cell index of this shard's range.
    pub start: usize,
    pub datasets: Vec<WorkloadKey>,
    /// Expanded configuration names, grid order.
    pub configs: Vec<String>,
    pub policies: Vec<Policy>,
    pub cell_model: CellModel,
    /// Named grid dimensions, row-major (dims product = total cells).
    pub dims: Vec<AxisDim>,
    /// The computed cells, in flat-index order from `start`.
    pub cells: Vec<CellResult>,
    pub meta: ShardMeta,
}

impl SweepShard {
    /// The flat cell range this shard covers.
    pub fn range(&self) -> Range<usize> {
        self.start..self.start + self.cells.len()
    }

    /// Total cells of the full grid (all shards together).
    pub fn total_cells(&self) -> usize {
        self.dims.iter().map(|d| d.len()).product()
    }

    /// Canonical artifact file name: shard position and codec version are
    /// both in the name, so a re-run overwrites its own artifact and a
    /// codec bump starts cold next to old files.
    pub fn file_name(&self) -> String {
        format!(
            "shard-{:04}-of-{:04}.v{}.{}",
            self.spec.index, self.spec.count, CODEC_VERSION, SHARD_EXT
        )
    }

    /// Encode and atomically publish this shard into `dir` (created if
    /// needed): unique temp file + `rename`, the same
    /// [`crate::sim::cache::store::atomic_publish`] discipline as the
    /// workload store, so a concurrently merging reader never sees a torn
    /// artifact and racing writers (threads or processes) never share a
    /// temp name.
    pub fn write_to(&self, dir: &Path) -> io::Result<PathBuf> {
        fs::create_dir_all(dir)?;
        let path = dir.join(self.file_name());
        crate::sim::cache::store::atomic_publish(&path, &codec::encode_shard(self))?;
        Ok(path)
    }
}

/// Decode every current-version shard artifact (`*.v<N>.mshd`) in `dir`,
/// sorted by shard index. Discovery filters on the codec version embedded
/// in the file name, so a codec bump really does start cold next to old
/// artifacts instead of tripping over them. Within the current version,
/// loud by design: an unreadable or undecodable artifact is an error, not
/// a skip — a merge must never silently proceed past a corrupt shard.
/// Non-shard files (temp files, workload artifacts) are ignored.
pub fn read_dir(dir: &Path) -> Result<Vec<SweepShard>, ShardError> {
    let entries = fs::read_dir(dir)
        .map_err(|e| ShardError::Io { path: dir.to_path_buf(), source: e })?;
    let suffix = format!(".v{CODEC_VERSION}.{SHARD_EXT}");
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.is_file()
                && p.file_name().and_then(|n| n.to_str()).is_some_and(|n| n.ends_with(&suffix))
        })
        .collect();
    paths.sort();
    let mut shards = Vec::with_capacity(paths.len());
    for path in paths {
        let bytes =
            fs::read(&path).map_err(|e| ShardError::Io { path: path.clone(), source: e })?;
        let shard = codec::decode_shard(&bytes)
            .map_err(|e| ShardError::Artifact { path: path.clone(), source: e })?;
        shards.push(shard);
    }
    if shards.is_empty() {
        return Err(ShardError::NoShards(dir.to_path_buf()));
    }
    shards.sort_by_key(|s| s.spec.index);
    Ok(shards)
}

/// Validate everything about a shard set that does not require it to be
/// *complete*: non-empty; one fingerprint (same design space); one shard
/// count; identical grid metadata and profile chunking; no duplicate shard
/// indices; and every present shard sitting exactly on its canonical
/// [`ShardSpec::range`] — a shard with the right index but the wrong cells
/// is tampering, complete set or not. Returns the shards sorted by index
/// plus the grid's total cell count. Shared by [`merge`] (which then
/// requires completeness) and [`merge_partial`] (which reports the gaps
/// instead).
fn validate_set(shards: &[SweepShard]) -> Result<(Vec<&SweepShard>, usize), ShardError> {
    let first = shards.first().ok_or(ShardError::Empty)?;
    for s in shards {
        s.spec.validate()?;
        if s.fingerprint != first.fingerprint {
            return Err(ShardError::FingerprintMismatch {
                index: s.spec.index,
                count: s.spec.count,
                expected: first.fingerprint,
                found: s.fingerprint,
            });
        }
        if s.spec.count != first.spec.count {
            return Err(ShardError::CountMismatch { a: first.spec.count, b: s.spec.count });
        }
        // Defense in depth: with equal fingerprints these can only differ
        // if an artifact was hand-edited past the checksum.
        if s.dims != first.dims
            || s.datasets != first.datasets
            || s.configs != first.configs
            || s.policies != first.policies
            || s.cell_model != first.cell_model
        {
            return Err(ShardError::Incompatible(format!(
                "shard {} grid metadata disagrees with shard {}",
                s.spec, first.spec
            )));
        }
        if s.meta.profile_threads != first.meta.profile_threads {
            return Err(ShardError::Incompatible(format!(
                "profile chunking differs across shards ({} vs {}): checksum bits \
                 would not match an unsharded run",
                first.meta.profile_threads, s.meta.profile_threads
            )));
        }
    }

    let count = first.spec.count;
    let mut ordered: Vec<&SweepShard> = shards.iter().collect();
    ordered.sort_by_key(|s| s.spec.index);
    for pair in ordered.windows(2) {
        if pair[0].spec.index == pair[1].spec.index {
            return Err(ShardError::DuplicateShard { index: pair[0].spec.index, count });
        }
    }

    // Every present shard must sit exactly on its canonical range — this
    // catches a tampered or truncated shard even in a partial set, where
    // the running expected-start walk of a complete merge has no anchor.
    let total = first.total_cells();
    for s in &ordered {
        let canonical = s.spec.range(total);
        if s.start != canonical.start || s.cells.len() != canonical.len() {
            return Err(ShardError::RangeMismatch {
                index: s.spec.index,
                count,
                found_start: s.start,
                found_end: s.range().end,
                expected_start: canonical.start,
            });
        }
    }
    Ok((ordered, total))
}

/// Merge a complete shard set back into the [`SweepResult`] the unsharded
/// sweep would have produced — cell-for-cell, bit-for-bit.
///
/// [`validate_set`] plus completeness: every index `0..count` present.
/// Only then are the cells concatenated.
pub fn merge(shards: &[SweepShard]) -> Result<SweepResult, ShardError> {
    let (ordered, total) = validate_set(shards)?;
    let first = ordered[0];
    let count = first.spec.count;
    if ordered.len() != count {
        // Report the first few missing indices (the list itself could be
        // near-`count` long for a crafted artifact).
        let mut missing = Vec::new();
        let mut present = ordered.iter().map(|s| s.spec.index).peekable();
        for i in 0..count {
            match present.peek() {
                Some(&p) if p == i => {
                    present.next();
                }
                _ => {
                    missing.push(i);
                    if missing.len() >= 8 {
                        break;
                    }
                }
            }
        }
        return Err(ShardError::MissingShards { missing, count });
    }

    let mut cells = Vec::with_capacity(total);
    for s in &ordered {
        cells.extend(s.cells.iter().cloned());
    }
    Ok(SweepResult {
        datasets: first.datasets.clone(),
        configs: first.configs.clone(),
        policies: first.policies.clone(),
        cell_model: first.cell_model,
        dims: first.dims.clone(),
        cells,
    })
}

/// The completed sub-grid of an interrupted sharded sweep, with the gaps
/// named: contiguous runs of present cells ([`PartialSweep::segments`]) and
/// the missing index spans between them. Only produced by an *explicit*
/// opt-in (`--allow-partial`); the strict [`merge`] path never returns one.
/// Every present shard passed the full [`validate_set`] compatibility and
/// canonical-range checks — partial means incomplete, never invalid.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialSweep {
    pub fingerprint: u64,
    pub shard_count: usize,
    /// Total cells of the full grid (covered + missing).
    pub total_cells: usize,
    pub datasets: Vec<WorkloadKey>,
    pub configs: Vec<String>,
    pub policies: Vec<Policy>,
    pub cell_model: CellModel,
    pub dims: Vec<AxisDim>,
    /// Which shards arrived, index order.
    pub present: Vec<ShardSpec>,
    /// Contiguous missing flat-index spans, in order (empty iff complete).
    pub missing_spans: Vec<Range<usize>>,
    /// Contiguous covered runs: `(first flat index, cells)`.
    pub segments: Vec<(usize, Vec<CellResult>)>,
}

impl PartialSweep {
    pub fn covered_cells(&self) -> usize {
        self.segments.iter().map(|(_, c)| c.len()).sum()
    }

    pub fn missing_cells(&self) -> usize {
        self.total_cells - self.covered_cells()
    }

    pub fn missing_shards(&self) -> usize {
        self.shard_count - self.present.len()
    }

    pub fn is_complete(&self) -> bool {
        self.missing_spans.is_empty()
    }
}

/// Merge whatever subset of a shard set arrived into a [`PartialSweep`].
/// Same compatibility validation as [`merge`]; the difference is that gaps
/// become provenance ([`PartialSweep::missing_spans`]) instead of a
/// [`ShardError::MissingShards`] error. Missing spans are computed from the
/// *present* shards' canonical ranges, never by iterating `0..count` — an
/// artifact can claim an absurd `count` and must not drive allocation.
pub fn merge_partial(shards: &[SweepShard]) -> Result<PartialSweep, ShardError> {
    let (ordered, total) = validate_set(shards)?;
    let first = ordered[0];

    let mut missing_spans: Vec<Range<usize>> = Vec::new();
    let mut segments: Vec<(usize, Vec<CellResult>)> = Vec::new();
    let mut next_expected = 0usize;
    for s in &ordered {
        let r = s.range();
        if r.start > next_expected {
            missing_spans.push(next_expected..r.start);
        }
        // Adjacent present shards coalesce into one covered segment. Empty
        // shards (count > total) cover nothing but still count as present.
        match segments.last_mut() {
            Some((seg_start, cells)) if *seg_start + cells.len() == r.start => {
                cells.extend(s.cells.iter().cloned());
            }
            _ if !s.cells.is_empty() => segments.push((r.start, s.cells.clone())),
            _ => {}
        }
        next_expected = r.end;
    }
    if next_expected < total {
        missing_spans.push(next_expected..total);
    }

    Ok(PartialSweep {
        fingerprint: first.fingerprint,
        shard_count: first.spec.count,
        total_cells: total,
        datasets: first.datasets.clone(),
        configs: first.configs.clone(),
        policies: first.policies.clone(),
        cell_model: first.cell_model,
        dims: first.dims.clone(),
        present: ordered.iter().map(|s| s.spec).collect(),
        missing_spans,
        segments,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_tile_every_grid_exactly() {
        for total in 0..60 {
            for count in 1..10 {
                let mut covered = 0;
                let mut next_start = 0;
                let mut sizes = Vec::new();
                for index in 0..count {
                    let r = ShardSpec::new(index, count).unwrap().range(total);
                    assert_eq!(r.start, next_start, "total={total} count={count} i={index}");
                    next_start = r.end;
                    covered += r.len();
                    sizes.push(r.len());
                }
                assert_eq!(next_start, total, "total={total} count={count}");
                assert_eq!(covered, total);
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "unbalanced split: {sizes:?}");
            }
        }
    }

    #[test]
    fn spec_parses_and_validates() {
        let s: ShardSpec = "0/4".parse().unwrap();
        assert_eq!(s, ShardSpec { index: 0, count: 4 });
        assert_eq!("3/4".parse::<ShardSpec>().unwrap().to_string(), "3/4");
        assert_eq!(" 1 / 2 ".parse::<ShardSpec>().unwrap(), ShardSpec { index: 1, count: 2 });
        assert!(matches!("4/4".parse::<ShardSpec>(), Err(ShardError::InvalidSpec { .. })));
        assert!(matches!("0/0".parse::<ShardSpec>(), Err(ShardError::InvalidSpec { .. })));
        assert!(matches!("7".parse::<ShardSpec>(), Err(ShardError::BadSpec(_))));
        assert!(matches!("a/b".parse::<ShardSpec>(), Err(ShardError::BadSpec(_))));
        assert!(ShardSpec { index: 9, count: 2 }.validate().is_err());
    }

    #[test]
    fn empty_merge_is_an_error() {
        assert!(matches!(merge(&[]), Err(ShardError::Empty)));
    }
}
