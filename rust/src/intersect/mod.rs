//! Intersection logic (the `∩` blocks of paper Fig. 2).
//!
//! "The intersection logic identifies matching non-zero values that must be
//! multiplied from each of the two input matrices" (§II.C). Extensor places
//! it between DRAM and L1; Matraptor between SpAL and SpBL. In Gustavson
//! dataflow the intersection is between the *column ids of an A row* and the
//! *row ids present in B* — a B row with no stored elements produces no work
//! and should be filtered before it moves down the hierarchy.
//!
//! Two hardware strategies are modelled, both counted in comparisons:
//! two-finger merge (streaming, what Matraptor's loaders do) and skip-based
//! (binary-search, what Extensor's hierarchical intersection approximates).

use crate::trace::Counters;

/// Result of an intersection: the matching positions of the left list.
pub type Matches = Vec<usize>;

/// Two-finger merge intersection of two sorted id lists. Counts one
/// comparison per pointer advance, like a streaming comparator array.
/// Returns positions `p` in `a` such that `a[p] ∈ b`.
pub fn merge_intersect(c: &mut Counters, a: &[u32], b: &[u32]) -> Matches {
    let mut out = Vec::new();
    let (mut p, mut q) = (0usize, 0usize);
    while p < a.len() && q < b.len() {
        c.intersect_cmp += 1;
        match a[p].cmp(&b[q]) {
            std::cmp::Ordering::Less => p += 1,
            std::cmp::Ordering::Greater => q += 1,
            std::cmp::Ordering::Equal => {
                out.push(p);
                p += 1;
                q += 1;
            }
        }
    }
    out
}

/// Skip-based intersection: for each id of the (shorter) list `a`, binary
/// search in `b`. Counts log₂ comparisons per probe. Wins when
/// `|a| ≪ |b|` — the shape Extensor's hierarchical scheme exploits.
pub fn skip_intersect(c: &mut Counters, a: &[u32], b: &[u32]) -> Matches {
    let mut out = Vec::new();
    for (p, &x) in a.iter().enumerate() {
        let mut lo = 0usize;
        let mut hi = b.len();
        let mut found = false;
        while lo < hi {
            c.intersect_cmp += 1;
            let mid = (lo + hi) / 2;
            match b[mid].cmp(&x) {
                std::cmp::Ordering::Less => lo = mid + 1,
                std::cmp::Ordering::Greater => hi = mid,
                std::cmp::Ordering::Equal => {
                    found = true;
                    break;
                }
            }
        }
        if found {
            out.push(p);
        }
    }
    out
}

/// Filter an A row's column ids against the set of non-empty B rows:
/// the Gustavson-specific intersection both reference accelerators perform
/// before fetching B rows. `b_row_nnz[k] > 0` marks a useful row. Counts one
/// comparison (a row_ptr subtract + test, paper Fig. 7) per id.
pub fn filter_nonempty(
    c: &mut Counters,
    a_cols: &[u32],
    b_row_nnz: impl Fn(usize) -> usize,
) -> Matches {
    let mut out = Vec::new();
    for (p, &k) in a_cols.iter().enumerate() {
        c.intersect_cmp += 1;
        if b_row_nnz(k as usize) > 0 {
            out.push(p);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_and_skip_agree() {
        let a = [1u32, 4, 6, 9, 12];
        let b = [2u32, 4, 9, 10, 30];
        let mut c1 = Counters::default();
        let mut c2 = Counters::default();
        let m1 = merge_intersect(&mut c1, &a, &b);
        let m2 = skip_intersect(&mut c2, &a, &b);
        assert_eq!(m1, vec![1, 3]);
        assert_eq!(m1, m2);
        assert!(c1.intersect_cmp > 0 && c2.intersect_cmp > 0);
    }

    #[test]
    fn skip_wins_when_sizes_are_lopsided() {
        let a: Vec<u32> = (0..4).map(|i| i * 1000).collect();
        let b: Vec<u32> = (0..4096).collect();
        let mut cm = Counters::default();
        let mut cs = Counters::default();
        merge_intersect(&mut cm, &a, &b);
        skip_intersect(&mut cs, &a, &b);
        assert!(cs.intersect_cmp < cm.intersect_cmp);
    }

    #[test]
    fn merge_wins_on_similar_dense_lists() {
        let a: Vec<u32> = (0..256).collect();
        let b: Vec<u32> = (0..256).collect();
        let mut cm = Counters::default();
        let mut cs = Counters::default();
        merge_intersect(&mut cm, &a, &b);
        skip_intersect(&mut cs, &a, &b);
        assert!(cm.intersect_cmp < cs.intersect_cmp);
    }

    #[test]
    fn empty_inputs() {
        let mut c = Counters::default();
        assert!(merge_intersect(&mut c, &[], &[1, 2]).is_empty());
        assert!(skip_intersect(&mut c, &[1], &[]).is_empty());
        assert_eq!(c.intersect_cmp, 0);
    }

    #[test]
    fn filter_nonempty_drops_empty_b_rows() {
        let nnz = [2usize, 0, 3, 0];
        let mut c = Counters::default();
        let m = filter_nonempty(&mut c, &[0, 1, 2, 3], |k| nnz[k]);
        assert_eq!(m, vec![0, 2]);
        assert_eq!(c.intersect_cmp, 4);
    }
}
