//! Datapath / control logic area at 45 nm.
//!
//! Anchors (FreePDK45-class synthesis, matching the table popularised by
//! Horowitz ISSCC'14 and Han et al.): fp32 multiplier ≈ 0.0081 mm²,
//! fp32 adder ≈ 0.0042 mm², int32 adder ≈ 0.000137 mm². Control is modelled
//! as a base FSM plus per-MAC decode overhead — Maple's control counts the
//! multiplications per A-element from `row_ptr` (paper Fig. 7), which is a
//! subtractor + counter per PE, not per MAC.

/// fp32 multiplier area, mm².
pub fn multiplier_mm2() -> f64 {
    0.0081
}

/// fp32 adder area, mm².
pub fn adder_mm2() -> f64 {
    0.0042
}

/// One MAC datapath (multiplier + adder + pipeline registers), mm².
pub fn mac_mm2() -> f64 {
    multiplier_mm2() + adder_mm2() + 0.0006
}

/// Control area for a PE with `n_macs` MAC units: a base FSM with `row_ptr`
/// subtract/count logic plus per-MAC operand steering.
pub fn control_mm2(n_macs: usize) -> f64 {
    const BASE: f64 = 0.0030; // FSM + row_ptr counter + address gen
    const PER_MAC: f64 = 0.0009; // operand mux / steering per MAC
    BASE + PER_MAC * n_macs as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_is_mult_plus_add_plus_pipe() {
        assert!(mac_mm2() > multiplier_mm2() + adder_mm2());
        assert!(mac_mm2() < 0.02);
    }

    #[test]
    fn control_grows_with_macs() {
        assert!(control_mm2(16) > control_mm2(1));
        // ...but sub-linearly vs the MAC datapath itself.
        assert!(control_mm2(16) - control_mm2(1) < 15.0 * mac_mm2());
    }
}
