//! 45 nm area model (the CACTI 7.0 / Aladdin / Yosys-FreePDK45 substitution,
//! paper §IV.B.3).
//!
//! The paper sizes memories with CACTI 7.0 and logic with Aladdin, verified
//! by Verilog + Yosys on FreePDK45. We use published FreePDK45/45 nm
//! figures for the same primitives; Fig. 8's split (MAC vs buffers vs logic)
//! is produced by [`PeArea`] and the accelerator-level comparison by
//! [`crate::accel`].

mod logic;
mod sram;

pub use logic::{adder_mm2, control_mm2, mac_mm2, multiplier_mm2};
pub use sram::{latch_mm2, regfile_mm2, sram_mm2};

/// Area of one processing element, split into the paper's Fig.-8 categories.
/// All values mm² at 45 nm.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PeArea {
    /// Multiply-accumulate datapath area.
    pub mac_mm2: f64,
    /// PE-local buffer area (sorting queues / PEB / ARB+BRB+PSB).
    pub buffers_mm2: f64,
    /// Everything else: parallel adders, control FSM, decoders — the paper's
    /// "Maple logic" category.
    pub logic_mm2: f64,
}

impl PeArea {
    /// Total PE area.
    pub fn total_mm2(&self) -> f64 {
        self.mac_mm2 + self.buffers_mm2 + self.logic_mm2
    }

    /// Scale by the number of PE instances in the accelerator.
    pub fn scaled(&self, n: usize) -> PeArea {
        PeArea {
            mac_mm2: self.mac_mm2 * n as f64,
            buffers_mm2: self.buffers_mm2 * n as f64,
            logic_mm2: self.logic_mm2 * n as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_scaling() {
        let p = PeArea { mac_mm2: 1.0, buffers_mm2: 2.0, logic_mm2: 0.5 };
        assert!((p.total_mm2() - 3.5).abs() < 1e-12);
        let s = p.scaled(4);
        assert!((s.total_mm2() - 14.0).abs() < 1e-12);
    }
}
