//! SRAM / register-file area at 45 nm.
//!
//! Anchors: a 6T SRAM bit cell at 45 nm is ≈ 0.35 µm²; with peripheral
//! overhead (decoders, sense amps, margins) effective density is
//! ≈ 0.7 µm²/bit for KB-scale arrays — matching CACTI 7.0's 45 nm outputs
//! of roughly 5–6 mm²/MB. Register files built from flip-flops with mux
//! read ports cost ≈ 8 µm²/bit (a FreePDK45 DFF is ≈ 7.5 µm² before
//! routing), an order denser per-access but far costlier per bit — the
//! trade Maple makes by keeping its ARB/BRB/PSB tiny.

/// Effective SRAM area in mm² for a buffer of `bytes` capacity.
///
/// Small arrays amortise their periphery poorly; below 1 KiB we charge a
/// floor corresponding to CACTI's minimum macro.
pub fn sram_mm2(bytes: usize) -> f64 {
    const UM2_PER_BIT: f64 = 0.7;
    const MIN_MACRO_MM2: f64 = 0.0008; // ~minimum sensible SRAM macro
    let bits = (bytes * 8) as f64;
    (bits * UM2_PER_BIT * 1e-6).max(MIN_MACRO_MM2)
}

/// Register-file (flip-flop array) area in mm² for `bytes` capacity.
pub fn regfile_mm2(bytes: usize) -> f64 {
    const UM2_PER_BIT: f64 = 8.0;
    (bytes * 8) as f64 * UM2_PER_BIT * 1e-6
}

/// Latch-array area in mm² for `bytes` capacity — the implementation style
/// of Maple's ARB/BRB/PSB: denser than a multi-ported flip-flop register
/// file, cheaper periphery than an SRAM macro at these tiny capacities.
pub fn latch_mm2(bytes: usize) -> f64 {
    const UM2_PER_BIT: f64 = 4.0;
    (bytes * 8) as f64 * UM2_PER_BIT * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_density_near_cacti_45nm() {
        // ~5.9 mm²/MB at the chosen density.
        let per_mb = sram_mm2(1 << 20);
        assert!(per_mb > 4.0 && per_mb < 8.0, "per MB: {per_mb}");
    }

    #[test]
    fn regfile_denser_per_access_but_costlier_per_bit() {
        assert!(regfile_mm2(1024) > sram_mm2(1024));
    }

    #[test]
    fn latch_sits_between_sram_and_regfile() {
        assert!(latch_mm2(1024) < regfile_mm2(1024));
        assert!(latch_mm2(1024) > sram_mm2(1024));
    }

    #[test]
    fn floors_and_monotonicity() {
        assert!(sram_mm2(16) >= 0.0008);
        assert!(sram_mm2(64 << 10) > sram_mm2(8 << 10));
        assert!(regfile_mm2(512) > regfile_mm2(128));
    }
}
